//! Domain scenario: external-sort run merging (the database/LSM use
//! case the paper's merge primitive serves).
//!
//! A disk-backed sort produces many sorted runs; the merge phase
//! dominates. We compare three mergers on realistic run-structured
//! data:
//!
//! 1. sequential k-way loser tree (the classical external-sort merge)
//! 2. the paper's parallel two-way merge applied as a merge tree
//! 3. pairwise sequential merging (naive baseline)
//!
//! ```bash
//! cargo run --release --example external_sort -- [--runs K] [--n N]
//! ```

use traff_merge::cli::Args;
use traff_merge::core::multiway::{loser_tree_merge, parallel_kway_merge};
use traff_merge::metrics::{fmt_duration, melems_per_sec, time, Table};
use traff_merge::util::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let k = args.get_usize("runs", 32).unwrap_or(32);
    let n = args.get_usize("n", 4_000_000).unwrap_or(4_000_000);
    let p = traff_merge::util::num_cpus();
    let per_run = n / k;
    println!("external sort merge phase: {k} runs × {per_run} records, p={p}\n");

    // Simulate spilled runs: each run is sorted, runs overlap in range
    // (as real partitioned spills do).
    let mut rng = Rng::new(2024);
    let runs: Vec<Vec<i64>> = (0..k)
        .map(|_| {
            let mut v: Vec<i64> = (0..per_run).map(|_| rng.range(0, 1 << 40)).collect();
            v.sort();
            v
        })
        .collect();
    let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();

    let (t_tree, merged_tree) = time(|| parallel_kway_merge(&refs, p));
    let (t_loser, merged_loser) = time(|| loser_tree_merge(&refs));
    let (t_pairwise, merged_pairwise) = time(|| {
        // Naive: fold runs left-to-right with sequential merges.
        let mut acc: Vec<i64> = Vec::new();
        for r in &refs {
            acc = traff_merge::baseline::seq_merge(&acc, r);
        }
        acc
    });
    assert_eq!(merged_tree, merged_loser);
    assert_eq!(merged_tree, merged_pairwise);
    assert!(merged_tree.windows(2).all(|w| w[0] <= w[1]));

    let total = merged_tree.len();
    let mut t = Table::new(vec!["merger", "time", "Melem/s", "speedup vs loser tree"]);
    for (name, secs) in [
        ("parallel merge tree (Träff)", t_tree),
        ("sequential loser tree", t_loser),
        ("naive pairwise fold", t_pairwise),
    ] {
        t.row(vec![
            name.to_string(),
            fmt_duration(secs),
            format!("{:.1}", melems_per_sec(total as u64, secs)),
            format!("{:.2}x", t_loser / secs),
        ]);
    }
    t.print();
    println!("\n{total} records merged identically by all three ✓");
}

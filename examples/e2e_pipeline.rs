//! **End-to-end driver (E11)** — the full three-layer stack on a
//! realistic workload, proving the layers compose:
//!
//!   L1 Pallas kernels (crossrank + rank-merge, AOT → HLO text)
//!   L2 JAX graphs (merge_b*, sort_n* artifacts)
//!   L3 rust coordinator (this binary): workload → leaf blocks sorted
//!      on the XLA executables → XLA pair merges → rust parallel merge
//!      upper rounds → verified stable output.
//!
//! Workload: a synthetic web-access log — 1M records of
//! (timestamp-skewed f32 key, record id), shuffled; the service sorts
//! them back. Reported: wall time, throughput, XLA call count, and a
//! Rust-engine comparison. Stability is verified record-by-record.
//! Results are recorded in EXPERIMENTS.md §E11.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use traff_merge::cli::Args;
use traff_merge::coordinator::{Config, Engine, MergeService};
use traff_merge::metrics::{fmt_duration, melems_per_sec, time, Table};
use traff_merge::runtime::KeyedBlock;
use traff_merge::util::Rng;

fn synth_access_log(n: usize, seed: u64) -> KeyedBlock {
    // Timestamps arrive *almost* sorted with bursts and replays —
    // realistic for log ingestion. Key = second-resolution timestamp;
    // heavy duplicates (many events per second).
    let mut rng = Rng::new(seed);
    let mut t = 0i64;
    let keys: Vec<f32> = (0..n)
        .map(|_| {
            // Bursty arrivals: mostly +0, sometimes jumps.
            if rng.below(100) < 3 {
                t += rng.range(1, 30);
            }
            // Replayed/delayed events land behind.
            let jitter = if rng.below(100) < 10 { -rng.range(0, 20) } else { 0 };
            (t + jitter).max(0) as f32
        })
        .collect();
    let vals: Vec<i32> = (0..n as i32).collect(); // record ids = arrival order
    let mut shuffled: Vec<(f32, i32)> = keys.into_iter().zip(vals).collect();
    rng.shuffle(&mut shuffled);
    // Keep arrival order in vals (identity of the record), but shuffle
    // presentation order — the sort must group by timestamp while
    // keeping equal-timestamp records in *presentation* order
    // (stability), so re-tag by presentation index for the check.
    KeyedBlock {
        keys: shuffled.iter().map(|x| x.0).collect(),
        vals: (0..n as i32).collect(),
    }
}

fn verify_stable_sort(input: &KeyedBlock, out: &KeyedBlock) {
    assert_eq!(out.len(), input.len());
    assert!(out.keys.windows(2).all(|w| w[0] <= w[1]), "keys not sorted");
    for i in 1..out.len() {
        if out.keys[i - 1] == out.keys[i] {
            assert!(out.vals[i - 1] < out.vals[i], "instability at {i}");
        }
    }
    // Permutation check: out.vals is a permutation of 0..n and each
    // record kept its key.
    let n = input.len();
    let mut seen = vec![false; n];
    for (k, &v) in out.keys.iter().zip(&out.vals) {
        assert!(!seen[v as usize], "duplicate record id {v}");
        seen[v as usize] = true;
        assert_eq!(*k, input.keys[v as usize], "record {v} changed key");
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let n = args.get_usize("n", 1_000_000).unwrap_or(1_000_000);
    let threads = traff_merge::util::num_cpus();
    println!("end-to-end pipeline: {n} synthetic log records, {threads} threads\n");
    let data = synth_access_log(n, 7);

    // --- Full three-layer stack (XLA leaf stage + rust upper rounds) --
    let hybrid = MergeService::new(Config {
        threads,
        engine: Engine::Hybrid,
        leaf_block: 1024,
        ..Config::default()
    })
    .expect("artifacts missing? run `make artifacts`");
    println!(
        "loaded XLA artifacts: {:?} (platform {})",
        hybrid.runtime().unwrap().names(),
        hybrid.runtime().unwrap().platform
    );
    let (t_hybrid, out_hybrid) = time(|| hybrid.sort(&data).expect("hybrid sort"));
    verify_stable_sort(&data, &out_hybrid);
    let (_, _, xla_calls, _) = hybrid.stats.snapshot();

    // --- Rust engine comparison ---------------------------------------
    let rust = MergeService::new(Config {
        threads,
        engine: Engine::Rust,
        leaf_block: 1024,
        ..Config::default()
    })
    .unwrap();
    let (t_rust, out_rust) = time(|| rust.sort(&data).expect("rust sort"));
    verify_stable_sort(&data, &out_rust);
    assert_eq!(out_hybrid.keys, out_rust.keys);
    assert_eq!(out_hybrid.vals, out_rust.vals, "engines must agree bit-for-bit");

    // --- std baseline ---------------------------------------------------
    let (t_std, _) = time(|| {
        let mut v: Vec<(f32, i32)> =
            data.keys.iter().copied().zip(data.vals.iter().copied()).collect();
        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        v
    });

    let mut t = Table::new(vec!["engine", "time", "Melem/s", "XLA calls", "stable"]);
    t.row(vec![
        "hybrid (L1+L2+L3)".to_string(),
        fmt_duration(t_hybrid),
        format!("{:.2}", melems_per_sec(n as u64, t_hybrid)),
        xla_calls.to_string(),
        "✓".to_string(),
    ]);
    t.row(vec![
        "rust (L3 only)".to_string(),
        fmt_duration(t_rust),
        format!("{:.2}", melems_per_sec(n as u64, t_rust)),
        "0".to_string(),
        "✓".to_string(),
    ]);
    t.row(vec![
        "std::sort_by (1 thread)".to_string(),
        fmt_duration(t_std),
        format!("{:.2}", melems_per_sec(n as u64, t_std)),
        "0".to_string(),
        "✓".to_string(),
    ]);
    t.print();
    println!(
        "\nboth engines produce identical stable output ✓ — the XLA path runs\n\
         the L1 Pallas kernels (AOT HLO) for every leaf sort and early merge\n\
         round; python was never loaded by this process."
    );
}

//! Model-level validation demo (E6): run the paper's merge on the
//! audited EREW PRAM simulator and print the step/conflict evidence
//! behind the "can be implemented on an EREW PRAM" claim.
//!
//! ```bash
//! cargo run --release --example pram_audit -- [--p P]
//! ```

use traff_merge::cli::Args;
use traff_merge::metrics::Table;
use traff_merge::pram::{pram_merge, Variant};
use traff_merge::workload::{sorted_keys, Dist};

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let p = args.get_usize("p", 8).unwrap_or(8);

    println!("EREW PRAM audit of the simplified merge (p = {p})\n");
    let mut table = Table::new(vec![
        "n", "dist", "steps", "broadcast", "searches", "fetch", "merge", "conflicts",
    ]);
    for &n in &[1usize << 10, 1 << 12, 1 << 14] {
        for dist in [Dist::Uniform, Dist::AllEqual, Dist::AdversarialSkew] {
            let a = sorted_keys(dist, n, 1);
            let b = sorted_keys(dist, n, 2);
            let (c, rep) = pram_merge(&a, &b, p, Variant::Erew);
            let mut expect = [a, b].concat();
            expect.sort();
            assert_eq!(c, expect);
            table.row(vec![
                n.to_string(),
                dist.name(),
                rep.report.steps.to_string(),
                rep.phase_steps[0].to_string(),
                (rep.phase_steps[1] + rep.phase_steps[2]).to_string(),
                rep.phase_steps[3].to_string(),
                rep.phase_steps[4].to_string(),
                rep.report.conflicts.len().to_string(),
            ]);
        }
    }
    table.print();
    println!(
        "\nEvery row: zero conflicts — exclusive reads and writes hold through\n\
         pipelined searches, offset cross-rank fetches, and disjoint merges.\n\
         The merge column tracks ~2n/p (Theorem 1); searches track p + log n\n\
         (the simulator pipelines searches the simple way; Akl–Meijer [1]\n\
         brings the search phase to O(log n) — see DESIGN.md)."
    );
}

//! Quickstart: the public API in 60 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use traff_merge::core::{parallel_merge, parallel_merge_sort, Record};
use traff_merge::workload::{assert_stable_merge, tag_a, tag_b, B_TAG_BASE};

fn main() {
    // --- Stable parallel merge -----------------------------------------
    let a = [1i64, 3, 3, 5, 7];
    let b = [2i64, 3, 4, 7, 8];
    let mut c = [0i64; 10];
    parallel_merge(&a, &b, &mut c, 4);
    println!("merge  {a:?} + {b:?}\n    -> {c:?}");
    assert_eq!(c, [1, 2, 3, 3, 3, 4, 5, 7, 7, 8]);

    // --- Stability: equal keys keep A-before-B and input order ---------
    let ta = tag_a(&a); // records tagged 0..n
    let tb = tag_b(&b); // records tagged B_TAG_BASE..
    let mut tc = vec![Record::new(0, 0); 10];
    parallel_merge(&ta, &tb, &mut tc, 4);
    assert_stable_merge(&tc, B_TAG_BASE);
    println!("stable: ties ordered A-first, input order preserved ✓");

    // --- Stable parallel merge sort (§3) --------------------------------
    let mut v: Vec<i64> = (0..1_000_000).map(|i| (i * 2_654_435_761u64 as i64) % 10_000).collect();
    let mut expect = v.clone();
    let t0 = std::time::Instant::now();
    parallel_merge_sort(&mut v, traff_merge::util::num_cpus());
    let par = t0.elapsed();
    let t0 = std::time::Instant::now();
    expect.sort(); // std stable sort
    let std_t = t0.elapsed();
    assert_eq!(v, expect);
    println!(
        "sort 1M: parallel {:.1} ms vs std {:.1} ms ({:.2}x)",
        par.as_secs_f64() * 1e3,
        std_t.as_secs_f64() * 1e3,
        std_t.as_secs_f64() / par.as_secs_f64()
    );

    // --- The partition is inspectable -----------------------------------
    let part = traff_merge::core::Partition::compute(&a, &b, 3);
    println!("x̄ = {:?}, ȳ = {:?}", part.xbar, part.ybar);
    for t in part.tasks() {
        println!("  task {:?} {:?}: A{:?} ⋈ B{:?} -> C[{}..]", t.side, t.case, t.a, t.b, t.c_off);
    }
}

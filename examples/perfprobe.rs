//! §Perf measurement probe — the fixed microbenchmarks used by the
//! optimization loop in EXPERIMENTS.md §Perf (median of 7 runs):
//! stable merge of 2M+2M i64 and stable sort of 1M i64.
//!
//! ```bash
//! cargo run --release --example perfprobe
//! ```

use traff_merge::core::{parallel_merge, parallel_merge_sort};
use traff_merge::workload::{raw_keys, sorted_keys, Dist};
fn med(mut v: Vec<f64>) -> f64 { v.sort_by(|a,b| a.partial_cmp(b).unwrap()); v[v.len()/2] }
fn main() {
    let a = sorted_keys(Dist::Uniform, 2_000_000, 3);
    let b = sorted_keys(Dist::Uniform, 2_000_000, 4);
    let mut out = vec![0i64; 4_000_000];
    for (name, p) in [("merge p=1", 1usize), ("merge p=4", 4)] {
        let mut s = vec![];
        for _ in 0..7 { let t = std::time::Instant::now(); parallel_merge(&a, &b, &mut out, p); s.push(t.elapsed().as_secs_f64()); }
        println!("{name}: {:.2} ms", med(s)*1e3);
    }
    let base = raw_keys(Dist::Uniform, 1_000_000, 5);
    for (name, p) in [("sort p=1", 1usize), ("sort p=4", 4), ("sort p=8", 8)] {
        let mut s = vec![];
        for _ in 0..7 { let mut v = base.clone(); let t = std::time::Instant::now(); parallel_merge_sort(&mut v, p); s.push(t.elapsed().as_secs_f64()); }
        println!("{name}: {:.2} ms", med(s)*1e3);
    }
}

//! E9 — load balance: the paper guarantees every task is O(n/p) with a
//! worst-case factor ~2 ("the sizes of the blocks ... can differ by a
//! factor of two"); cases (a)/(e) may produce tiny tasks. We measure
//! the actual task-size distribution and case census per workload, and
//! compare with the merge-path family's perfect (±1) balance.

use traff_merge::baseline::merge_path::merge_path_segment_sizes;
use traff_merge::core::merge::{carve_output, chunk_tasks, run_tasks_parallel};
use traff_merge::core::seqmerge::merge_into;
use traff_merge::core::{Case, Partition};
use traff_merge::harness::{quick_mode, section, Bench};
use traff_merge::metrics::Table;
use traff_merge::workload::{adversarial_pair, sorted_keys, Dist};

fn main() {
    let n = if quick_mode() { 100_000 } else { 1_000_000 };
    let p = 16;

    section(&format!("E9a: task size distribution (n = m = {n}, p = {p})"));
    let mut t = Table::new(vec![
        "dist", "tasks", "max", "bound 2⌈n/p⌉", "max/bound", "mean", "min",
    ]);
    for dist in Dist::all() {
        let a = sorted_keys(dist, n, 30);
        let b = sorted_keys(dist, n, 31);
        let part = Partition::compute(&a, &b, p);
        let tasks = part.tasks();
        part.validate_tasks(&tasks).unwrap();
        let sizes: Vec<usize> = tasks.iter().map(|t| t.len()).collect();
        let bound = 2 * part.pa.big.max(part.pb.big);
        let mx = *sizes.iter().max().unwrap();
        t.row(vec![
            dist.name(),
            tasks.len().to_string(),
            mx.to_string(),
            bound.to_string(),
            format!("{:.3}", mx as f64 / bound as f64),
            format!("{:.0}", sizes.iter().sum::<usize>() as f64 / sizes.len() as f64),
            sizes.iter().min().unwrap().to_string(),
        ]);
    }
    t.print();

    section("E9b: adversarial pair (all of B inside one A gap)");
    let mut t = Table::new(vec!["p", "tasks", "max", "bound", "within bound?"]);
    for &pp in &[4usize, 16, 64] {
        let (a, b) = adversarial_pair(n, n / 2, 5);
        let part = Partition::compute(&a, &b, pp);
        let tasks = part.tasks();
        let bound = 2 * part.pa.big.max(part.pb.big);
        let mx = tasks.iter().map(|t| t.len()).max().unwrap();
        t.row(vec![
            pp.to_string(),
            tasks.len().to_string(),
            mx.to_string(),
            bound.to_string(),
            (mx <= bound).to_string(),
        ]);
    }
    t.print();

    section("E9c: case census per workload (which of (a)-(e) fire)");
    let mut t = Table::new(vec!["dist", "(a) copy", "(b) same", "(c) cross", "(d) aligned", "(e) start"]);
    for dist in Dist::all() {
        let a = sorted_keys(dist, n, 30);
        let b = sorted_keys(dist, n, 31);
        let tasks = Partition::compute(&a, &b, p).tasks();
        let count = |c: Case| tasks.iter().filter(|t| t.case == c).count().to_string();
        t.row(vec![
            dist.name(),
            count(Case::CopyA),
            count(Case::SameBlock),
            count(Case::CrossBlock),
            count(Case::CrossBlockAligned),
            count(Case::StartAligned),
        ]);
    }
    t.print();

    section("E9d: the other family's balance (merge path, for contrast)");
    let sizes = merge_path_segment_sizes(2 * n, p);
    println!(
        "merge-path segments: min {} max {} (perfect ±1; Träff trades this\n\
         for the simpler one-sync partition — factor ≤ 2, measured above)",
        sizes.iter().min().unwrap(),
        sizes.iter().max().unwrap()
    );

    section("E9e: merge phase — persistent executor vs per-call thread::scope");
    {
        let threads = traff_merge::util::num_cpus();
        // out.len() must exceed the largest possible
        // parallel_merge_cutoff (2^18) or run_tasks_parallel would
        // silently take its sequential bail and the comparison would
        // be meaningless.
        let n = n.max(1 << 18);
        let a = sorted_keys(Dist::Uniform, n, 40);
        let b = sorted_keys(Dist::Uniform, n, 41);
        let mut out = vec![0i64; 2 * n];
        let part = Partition::compute(&a, &b, p);
        let tasks = part.tasks();
        let r_exec = Bench::new("exec").run(|| {
            run_tasks_parallel(&a, &b, &mut out, &tasks, threads).expect("tasks tile");
        });
        let (ar, br): (&[i64], &[i64]) = (&a, &b);
        let r_scoped = Bench::new("scoped").run(|| {
            let pairs = carve_output(&tasks, &mut out).expect("tasks tile");
            let groups = chunk_tasks(pairs, threads);
            std::thread::scope(|s| {
                for group in groups {
                    s.spawn(move || {
                        for (t, slice) in group {
                            merge_into(&ar[t.a.clone()], &br[t.b.clone()], slice);
                        }
                    });
                }
            });
        });
        println!(
            "same task set, same chunking: exec {:.2} ms | scoped spawn {:.2} ms",
            r_exec.median() * 1e3,
            r_scoped.median() * 1e3
        );
    }
}

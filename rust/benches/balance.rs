//! E9 — load balance: the paper guarantees every task is O(n/p) with a
//! worst-case factor ~2 ("the sizes of the blocks ... can differ by a
//! factor of two"); cases (a)/(e) may produce tiny tasks. We measure
//! the actual task-size distribution and case census per workload, and
//! compare with the merge-path family's perfect (±1) balance.

use std::sync::Arc;
use traff_merge::baseline::merge_path::merge_path_segment_sizes;
use traff_merge::baseline::merge_path_merge;
use traff_merge::core::merge::{carve_output, chunk_tasks, run_tasks_parallel};
use traff_merge::core::seqmerge::merge_into;
use traff_merge::core::{adaptive_merge, parallel_merge, Case, Partition};
use traff_merge::exec::{Executor, JobClass};
use traff_merge::harness::{quick_mode, section, Bench};
use traff_merge::metrics::{fmt_duration, percentile, Table};
use traff_merge::workload::{adversarial_pair, sorted_keys, Dist};

/// The PR-1 executor's `Mutex<VecDeque>` substrate, preserved (minus
/// the scope machinery) as the bench baseline for E9f: round-robin
/// injection across per-worker locked deques, lock-guarded pop-front /
/// steal-back, condvar parking. The production executor replaced this
/// with lock-free Chase–Lev deques.
mod mutex_pool {
    use std::collections::VecDeque;
    use std::sync::mpsc::{channel, Receiver};
    use traff_merge::model::sync::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{Arc, Condvar, Mutex};
    use std::thread::JoinHandle;
    use std::time::Duration;

    type Job = Box<dyn FnOnce() + Send + 'static>;

    struct Shared {
        queues: Vec<Mutex<VecDeque<Job>>>,
        rr: AtomicUsize,
        sleep: Mutex<()>,
        wake: Condvar,
        shutdown: AtomicBool,
    }

    impl Shared {
        fn pop(&self, id: usize) -> Option<Job> {
            if let Some(job) = self.queues[id].lock().unwrap().pop_front() {
                return Some(job);
            }
            let n = self.queues.len();
            for k in 1..n {
                if let Some(job) = self.queues[(id + k) % n].lock().unwrap().pop_back() {
                    return Some(job);
                }
            }
            None
        }

        fn queues_empty(&self) -> bool {
            self.queues.iter().all(|q| q.lock().unwrap().is_empty())
        }

        fn notify_all(&self) {
            let _guard = self.sleep.lock().unwrap();
            self.wake.notify_all();
        }
    }

    pub struct MutexPool {
        shared: Arc<Shared>,
        handles: Vec<JoinHandle<()>>,
    }

    impl MutexPool {
        pub fn new(threads: usize) -> MutexPool {
            let shared = Arc::new(Shared {
                queues: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
                rr: AtomicUsize::new(0),
                sleep: Mutex::new(()),
                wake: Condvar::new(),
                shutdown: AtomicBool::new(false),
            });
            let handles = (0..threads)
                .map(|i| {
                    let shared = Arc::clone(&shared);
                    std::thread::spawn(move || loop {
                        if let Some(job) = shared.pop(i) {
                            job();
                            continue;
                        }
                        if shared.shutdown.load(Ordering::Acquire) {
                            return;
                        }
                        let guard = shared.sleep.lock().unwrap();
                        if shared.queues_empty()
                            && !shared.shutdown.load(Ordering::Acquire)
                        {
                            let _ = shared
                                .wake
                                .wait_timeout(guard, Duration::from_millis(50))
                                .unwrap();
                        }
                    })
                })
                .collect();
            MutexPool { shared, handles }
        }

        pub fn submit_many<R, F>(&self, jobs: Vec<F>) -> Receiver<(usize, R)>
        where
            R: Send + 'static,
            F: FnOnce() -> R + Send + 'static,
        {
            let (tx, rx) = channel();
            let n = self.shared.queues.len();
            let start = self.shared.rr.fetch_add(jobs.len().max(1), Ordering::Relaxed);
            let mut buckets: Vec<Vec<Job>> = (0..n).map(|_| Vec::new()).collect();
            for (i, job) in jobs.into_iter().enumerate() {
                let tx = tx.clone();
                buckets[(start + i) % n].push(Box::new(move || {
                    let _ = tx.send((i, job()));
                }));
            }
            drop(tx);
            for (queue, bucket) in self.shared.queues.iter().zip(buckets) {
                if !bucket.is_empty() {
                    queue.lock().unwrap().extend(bucket);
                }
            }
            self.shared.notify_all();
            rx
        }
    }

    impl Drop for MutexPool {
        fn drop(&mut self) {
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.notify_all();
            for handle in self.handles.drain(..) {
                let _ = handle.join();
            }
        }
    }
}

fn main() {
    let n = if quick_mode() { 100_000 } else { 1_000_000 };
    let p = 16;

    section(&format!("E9a: task size distribution (n = m = {n}, p = {p})"));
    let mut t = Table::new(vec![
        "dist", "tasks", "max", "bound 2⌈n/p⌉", "max/bound", "mean", "min",
    ]);
    for dist in Dist::all() {
        let a = sorted_keys(dist, n, 30);
        let b = sorted_keys(dist, n, 31);
        let part = Partition::compute(&a, &b, p);
        let tasks = part.tasks();
        part.validate_tasks(&tasks).unwrap();
        let sizes: Vec<usize> = tasks.iter().map(|t| t.len()).collect();
        let bound = 2 * part.pa.big.max(part.pb.big);
        let mx = *sizes.iter().max().unwrap();
        t.row(vec![
            dist.name(),
            tasks.len().to_string(),
            mx.to_string(),
            bound.to_string(),
            format!("{:.3}", mx as f64 / bound as f64),
            format!("{:.0}", sizes.iter().sum::<usize>() as f64 / sizes.len() as f64),
            sizes.iter().min().unwrap().to_string(),
        ]);
    }
    t.print();

    section("E9b: adversarial pair (all of B inside one A gap)");
    let mut t = Table::new(vec!["p", "tasks", "max", "bound", "within bound?"]);
    for &pp in &[4usize, 16, 64] {
        let (a, b) = adversarial_pair(n, n / 2, 5);
        let part = Partition::compute(&a, &b, pp);
        let tasks = part.tasks();
        let bound = 2 * part.pa.big.max(part.pb.big);
        let mx = tasks.iter().map(|t| t.len()).max().unwrap();
        t.row(vec![
            pp.to_string(),
            tasks.len().to_string(),
            mx.to_string(),
            bound.to_string(),
            (mx <= bound).to_string(),
        ]);
    }
    t.print();

    section("E9c: case census per workload (which of (a)-(e) fire)");
    let mut t = Table::new(vec!["dist", "(a) copy", "(b) same", "(c) cross", "(d) aligned", "(e) start"]);
    for dist in Dist::all() {
        let a = sorted_keys(dist, n, 30);
        let b = sorted_keys(dist, n, 31);
        let tasks = Partition::compute(&a, &b, p).tasks();
        let count = |c: Case| tasks.iter().filter(|t| t.case == c).count().to_string();
        t.row(vec![
            dist.name(),
            count(Case::CopyA),
            count(Case::SameBlock),
            count(Case::CrossBlock),
            count(Case::CrossBlockAligned),
            count(Case::StartAligned),
        ]);
    }
    t.print();

    section("E9d: the other family's balance (merge path, for contrast)");
    let sizes = merge_path_segment_sizes(2 * n, p);
    println!(
        "merge-path segments: min {} max {} (perfect ±1; Träff trades this\n\
         for the simpler one-sync partition — factor ≤ 2, measured above)",
        sizes.iter().min().unwrap(),
        sizes.iter().max().unwrap()
    );

    section("E9e: merge phase — persistent executor vs per-call thread::scope");
    {
        let threads = traff_merge::util::num_cpus();
        // out.len() must exceed the largest possible
        // parallel_merge_cutoff (2^18) or run_tasks_parallel would
        // silently take its sequential bail and the comparison would
        // be meaningless.
        let n = n.max(1 << 18);
        let a = sorted_keys(Dist::Uniform, n, 40);
        let b = sorted_keys(Dist::Uniform, n, 41);
        let mut out = vec![0i64; 2 * n];
        let part = Partition::compute(&a, &b, p);
        let tasks = part.tasks();
        let r_exec = Bench::new("exec").run(|| {
            run_tasks_parallel(&a, &b, &mut out, &tasks, threads).expect("tasks tile");
        });
        let (ar, br): (&[i64], &[i64]) = (&a, &b);
        let r_scoped = Bench::new("scoped").run(|| {
            let pairs = carve_output(&tasks, &mut out).expect("tasks tile");
            let groups = chunk_tasks(pairs, threads);
            std::thread::scope(|s| {
                for group in groups {
                    s.spawn(move || {
                        for (t, slice) in group {
                            merge_into(&ar[t.a.clone()], &br[t.b.clone()], slice);
                        }
                    });
                }
            });
        });
        println!(
            "same task set, same chunking: exec {:.2} ms | scoped spawn {:.2} ms",
            r_exec.median() * 1e3,
            r_scoped.median() * 1e3
        );
    }

    section("E9f: executor substrate — lock-free Chase–Lev vs Mutex-deque baseline");
    {
        let threads = traff_merge::util::num_cpus();
        let exec = Executor::new(threads);
        let pool = mutex_pool::MutexPool::new(threads);
        // One job = one sequential merge of an input pair; the job set
        // is rebuilt per run (jobs are consumed), the inputs are shared
        // behind Arcs so rebuild cost is just closure allocation.
        fn merge_jobs(
            pairs: &[(Arc<Vec<i64>>, Arc<Vec<i64>>)],
        ) -> Vec<impl FnOnce() -> usize + Send + 'static> {
            pairs
                .iter()
                .map(|(a, b)| {
                    let a = Arc::clone(a);
                    let b = Arc::clone(b);
                    move || {
                        let mut out = vec![0i64; a.len() + b.len()];
                        merge_into(&a, &b, &mut out);
                        std::hint::black_box(out.len())
                    }
                })
                .collect()
        }

        // (i) uniform coarse tasks: 2 jobs per worker, equal sizes —
        // the Mutex baseline's best case (no steal pressure). The
        // acceptance bar is "no slower".
        let coarse_n = if quick_mode() { 20_000 } else { 100_000 };
        let coarse: Vec<(Arc<Vec<i64>>, Arc<Vec<i64>>)> = (0..2 * threads)
            .map(|i| {
                (
                    Arc::new(sorted_keys(Dist::Uniform, coarse_n, 100 + i as u64)),
                    Arc::new(sorted_keys(Dist::Uniform, coarse_n, 500 + i as u64)),
                )
            })
            .collect();
        let r_cl_coarse = Bench::new("chase-lev coarse")
            .run(|| exec.submit_many(merge_jobs(&coarse)).iter().count());
        let r_mx_coarse = Bench::new("mutex coarse")
            .run(|| pool.submit_many(merge_jobs(&coarse)).iter().count());

        // (ii) skewed fine-grained tasks: 1/i-sized jobs — round-robin
        // pre-assignment load-imbalances the Mutex pool, and every
        // rebalancing pop pays a lock; the Chase–Lev fleet rebalances
        // with CAS steals. The acceptance bar is "faster".
        let head = if quick_mode() { 40_000 } else { 200_000 };
        let skewed: Vec<(Arc<Vec<i64>>, Arc<Vec<i64>>)> = (0..256)
            .map(|i| {
                let n = (head / (i + 1)).max(64);
                (
                    Arc::new(sorted_keys(Dist::Uniform, n, 1000 + i as u64)),
                    Arc::new(sorted_keys(Dist::Uniform, n, 2000 + i as u64)),
                )
            })
            .collect();
        let r_cl_skew = Bench::new("chase-lev skewed")
            .run(|| exec.submit_many(merge_jobs(&skewed)).iter().count());
        let r_mx_skew = Bench::new("mutex skewed")
            .run(|| pool.submit_many(merge_jobs(&skewed)).iter().count());

        let mut t = Table::new(vec!["task set", "chase-lev", "mutex-deque", "speedup"]);
        t.row(vec![
            format!("uniform coarse ({} x {}k)", 2 * threads, coarse_n / 1000),
            format!("{:.2} ms", r_cl_coarse.median() * 1e3),
            format!("{:.2} ms", r_mx_coarse.median() * 1e3),
            format!("{:.2}x", r_mx_coarse.median() / r_cl_coarse.median()),
        ]);
        t.row(vec![
            "skewed fine (256 x 1/i)".to_string(),
            format!("{:.2} ms", r_cl_skew.median() * 1e3),
            format!("{:.2} ms", r_mx_skew.median() * 1e3),
            format!("{:.2}x", r_mx_skew.median() / r_cl_skew.median()),
        ]);
        t.print();
        let tel = exec.telemetry();
        println!(
            "chase-lev fleet: {} executed, {} steals, {} misses, {} injector batches",
            tel.executed(),
            tel.steals(),
            tel.steal_misses(),
            tel.injector_pops()
        );
    }

    section("E9g: steal-driven fine chunking vs greedy k-group pre-balance");
    {
        let threads = traff_merge::util::num_cpus();
        // Keep the output above the largest possible merge cutoff
        // (2^18) so the merge phase cannot take its sequential bail.
        let n = n.max(1 << 18);
        let (a, b) = adversarial_pair(n, n / 2, 5);
        let mut out = vec![0i64; a.len() + b.len()];
        // Full production path (`parallel_merge`): fine mode must act
        // at the PARTITION — grouping can only combine tasks, never
        // split one — so the over-partitioning happens inside
        // parallel_merge via exec::chunk_groups. The adversarial pair
        // packs most of the work into few p-lane tasks, exactly the
        // skew a finer partition plus steals recovers.
        std::env::set_var("EXEC_FINE_CHUNK", "1"); // pin: greedy, p lanes
        let r_greedy = Bench::new("greedy").run(|| {
            parallel_merge(&a, &b, &mut out, threads);
        });
        std::env::set_var("EXEC_FINE_CHUNK", "8"); // pin: 8p lanes
        let r_fine = Bench::new("fine").run(|| {
            parallel_merge(&a, &b, &mut out, threads);
        });
        std::env::remove_var("EXEC_FINE_CHUNK"); // back to telemetry-driven
        println!(
            "adversarial-skew merge (n = {n}, p = {threads}): greedy {:.2} ms | fine (8x lanes) {:.2} ms | ratio {:.2}x",
            r_greedy.median() * 1e3,
            r_fine.median() * 1e3,
            r_greedy.median() / r_fine.median()
        );
    }

    section("E9h: injector — lock-free sharded vs Mutex baseline, 8 external submitters");
    {
        // High external submission rate: many NON-worker threads
        // firing small batches concurrently. The Mutex baseline pays
        // lock round-trips on the entry path (and its workers pay one
        // per pop); the sharded injector spreads submitters over
        // per-shard lock-free FIFO queues and workers drain batches
        // with one CAS claim. Jobs are tiny merges, so the entry path
        // (not the work) dominates — exactly the regime the ROADMAP
        // named as the next contention target.
        let threads = traff_merge::util::num_cpus();
        let exec = Executor::new(threads);
        let pool = mutex_pool::MutexPool::new(threads);
        const SUBMITTERS: usize = 8;
        let batches = if quick_mode() { 8 } else { 30 };
        let batch_jobs = 64usize;
        let job_n = 256usize;
        let a = Arc::new(sorted_keys(Dist::Uniform, job_n, 7000));
        let b = Arc::new(sorted_keys(Dist::Uniform, job_n, 7001));
        let make_jobs = |a: &Arc<Vec<i64>>, b: &Arc<Vec<i64>>| {
            (0..batch_jobs)
                .map(|_| {
                    let a = Arc::clone(a);
                    let b = Arc::clone(b);
                    move || {
                        let mut out = vec![0i64; a.len() + b.len()];
                        merge_into(&a, &b, &mut out);
                        std::hint::black_box(out.len())
                    }
                })
                .collect::<Vec<_>>()
        };

        let r_sharded = Bench::new("sharded injector").run(|| {
            std::thread::scope(|s| {
                for _ in 0..SUBMITTERS {
                    s.spawn(|| {
                        for _ in 0..batches {
                            // Each submitter waits for its batch before
                            // firing the next: round-trip under fire.
                            let rx = exec.submit_many(make_jobs(&a, &b));
                            assert_eq!(rx.iter().count(), batch_jobs);
                        }
                    });
                }
            });
        });
        let r_mutex = Bench::new("mutex injector").run(|| {
            std::thread::scope(|s| {
                for _ in 0..SUBMITTERS {
                    s.spawn(|| {
                        for _ in 0..batches {
                            let rx = pool.submit_many(make_jobs(&a, &b));
                            assert_eq!(rx.iter().count(), batch_jobs);
                        }
                    });
                }
            });
        });
        let mut t = Table::new(vec!["entry path", "time", "vs mutex"]);
        t.row(vec![
            format!("sharded lock-free ({SUBMITTERS} submitters x {batches} x {batch_jobs})"),
            format!("{:.2} ms", r_sharded.median() * 1e3),
            format!("{:.2}x", r_mutex.median() / r_sharded.median()),
        ]);
        t.row(vec![
            "Mutex<VecDeque> baseline".to_string(),
            format!("{:.2} ms", r_mutex.median() * 1e3),
            "1.00x".to_string(),
        ]);
        t.print();
        let (rates, _) = exec.recalibrate_now();
        println!(
            "sharded fleet windowed rates: {:.0} exec/s | {:.0} steals/s (miss ratio {:.2}) \
             | {:.0} injector batches/s",
            rates.executed_per_sec,
            rates.steals_per_sec,
            rates.miss_ratio(),
            rates.injector_per_sec
        );
    }

    section("E9i: QoS lanes — service p99 under a background flood vs classless");
    {
        // 8 flooder threads keep a deep backlog of small background
        // merge jobs queued while a service tenant submits small
        // batches and measures per-job latency (submit -> completion,
        // queue wait included). Run twice: flood in the BACKGROUND
        // lane (the new QoS path) vs flood submitted classless (all
        // Service — the pre-PR-4 behavior). The lanes must cut the
        // service tenant's p99 while total throughput stays within
        // noise (the same jobs run either way; only who waits moves).
        use std::time::{Duration, Instant};
        use traff_merge::model::sync::{AtomicBool, AtomicUsize, Ordering};
        let threads = traff_merge::util::num_cpus();
        const FLOODERS: usize = 8;
        let service_batches = if quick_mode() { 10 } else { 40 };
        let service_jobs = 8usize;
        let flood_batch = 64usize;
        let job_n = 2048usize;
        let a = Arc::new(sorted_keys(Dist::Uniform, job_n, 9100));
        let b = Arc::new(sorted_keys(Dist::Uniform, job_n, 9101));
        let merge_job = |a: &Arc<Vec<i64>>, b: &Arc<Vec<i64>>| {
            let a = Arc::clone(a);
            let b = Arc::clone(b);
            move || {
                let mut out = vec![0i64; a.len() + b.len()];
                merge_into(&a, &b, &mut out);
                std::hint::black_box(out.len())
            }
        };

        let run_mode = |flood_class: JobClass| -> (Vec<f64>, f64) {
            let exec = Executor::new(threads);
            let stop = AtomicBool::new(false);
            let flood_done = AtomicUsize::new(0);
            let mut latencies: Vec<f64> = Vec::new();
            let t_all = Instant::now();
            std::thread::scope(|s| {
                for _ in 0..FLOODERS {
                    s.spawn(|| {
                        // Each flooder keeps one batch in flight: a
                        // sustained, bounded backlog (~FLOODERS x 64
                        // jobs) across up to FLOODERS shards.
                        while !stop.load(Ordering::Acquire) {
                            let jobs: Vec<_> =
                                (0..flood_batch).map(|_| merge_job(&a, &b)).collect();
                            let rx = exec.submit_many_with_class(flood_class, jobs);
                            flood_done.fetch_add(rx.iter().count(), Ordering::Relaxed);
                        }
                    });
                }
                // Let the flood establish its backlog first.
                std::thread::sleep(Duration::from_millis(20));
                for _ in 0..service_batches {
                    let jobs: Vec<_> = (0..service_jobs).map(|_| merge_job(&a, &b)).collect();
                    let t0 = Instant::now();
                    let rx = exec.submit_many(jobs);
                    for _ in rx.iter() {
                        latencies.push(t0.elapsed().as_secs_f64());
                    }
                }
                stop.store(true, Ordering::Release);
            });
            let secs = t_all.elapsed().as_secs_f64();
            latencies.sort_by(f64::total_cmp);
            (latencies, flood_done.load(Ordering::Relaxed) as f64 / secs)
        };

        let (lanes_lat, lanes_tput) = run_mode(JobClass::Background);
        let (classless_lat, classless_tput) = run_mode(JobClass::Service);
        let mut t = Table::new(vec![
            "flood mode", "service p50", "service p99", "service max", "flood jobs/s",
        ]);
        let row = |name: &str, lat: &[f64], tput: f64| {
            vec![
                name.to_string(),
                fmt_duration(percentile(lat, 50.0)),
                fmt_duration(percentile(lat, 99.0)),
                fmt_duration(lat[lat.len() - 1]),
                format!("{tput:.0}"),
            ]
        };
        t.row(row("background lane (QoS)", &lanes_lat, lanes_tput));
        t.row(row("classless (all service)", &classless_lat, classless_tput));
        t.print();
        println!(
            "service p99 ratio (classless / lanes): {:.2}x — the lanes' win; flood \
             throughput ratio {:.2}x (expect ~1: same work, different waiters)",
            percentile(&classless_lat, 99.0) / percentile(&lanes_lat, 99.0).max(1e-9),
            classless_tput / lanes_tput.max(1.0)
        );
    }

    section("E12: adaptive sequential-until-stolen vs fixed partition vs merge path (p = 8)");
    {
        // The adaptive kernel's claim: on shapes where the fixed
        // upfront partition pays p-1 binary-search splits for work that
        // one core could stream through triviality fast paths
        // (nearly-disjoint key ranges, long duplicate blocks), merging
        // sequentially in quanta and splitting only on observed steal
        // requests wins; on uniform keys it must stay within noise of
        // the fixed partition. Quanta run co-rank prefixes through the
        // seqmerge fast paths, so a disjoint or constant quantum is a
        // block copy regardless of where the steal requests land.
        let p = 8usize;
        // Above the largest possible parallel_merge_cutoff (2^18) so
        // neither kernel takes its sequential bail.
        let n = n.max(1 << 18);
        let m = n as i64;
        let shapes: Vec<(&str, Vec<i64>, Vec<i64>)> = vec![
            ("uniform", sorted_keys(Dist::Uniform, n, 60), sorted_keys(Dist::Uniform, n, 61)),
            (
                // Thin 16-key overlap seam between two key bands.
                "nearly-disjoint",
                (0..m).collect(),
                (0..m).map(|k| m - 16 + k).collect(),
            ),
            (
                "dup-heavy",
                sorted_keys(Dist::DupHeavy(16), n, 62),
                sorted_keys(Dist::DupHeavy(16), n, 63),
            ),
        ];
        let mut t =
            Table::new(vec!["shape", "adaptive", "fixed", "merge path", "fixed/adaptive"]);
        for (name, a, b) in &shapes {
            let (a, b) = (a.as_slice(), b.as_slice());
            let mut out = vec![0i64; a.len() + b.len()];
            // Correctness cross-check before timing.
            adaptive_merge(a, b, &mut out, p);
            let mut expect = [a, b].concat();
            expect.sort();
            assert_eq!(out, expect, "adaptive mis-merged {name}");
            let r_ad = Bench::new("adaptive").run(|| adaptive_merge(a, b, &mut out, p));
            let r_fx = Bench::new("fixed").run(|| parallel_merge(a, b, &mut out, p));
            let r_mp = Bench::new("merge path").run(|| merge_path_merge(a, b, &mut out, p));
            t.row(vec![
                name.to_string(),
                format!("{:.2} ms", r_ad.median() * 1e3),
                format!("{:.2} ms", r_fx.median() * 1e3),
                format!("{:.2} ms", r_mp.median() * 1e3),
                format!("{:.2}x", r_fx.median() / r_ad.median()),
            ]);
        }
        t.print();
        println!(
            "(acceptance: adaptive ≥ 1.5x fixed on nearly-disjoint and dup-heavy,\n\
             within 10% on uniform; EXEC_ADAPTIVE_QUANTUM pins the poll quantum)"
        );
    }
}

//! E5 — the headline simplification claim: no distinguished-element
//! merge phase ⇒ fewer phases, one synchronization, lower constants,
//! and stability for free.
//!
//! Head-to-head per workload distribution:
//!   - simplified (Träff)      — 1 sync, stable
//!   - distinguished (classic) — 2 syncs, extra splitter merge, unstable
//!   - merge path (equal-split)— stable, perfectly balanced (other family)
//!   - sequential              — the 1-thread floor

use traff_merge::baseline::{distinguished_merge, merge_path_merge};
use traff_merge::core::{parallel_merge, Record};
use traff_merge::harness::{quick_mode, section, Bench};
use traff_merge::metrics::Table;
use traff_merge::workload::{check_stable_merge, sorted_keys, tag_a, tag_b, Dist, B_TAG_BASE};

fn main() {
    let n = if quick_mode() { 200_000 } else { 2_000_000 };
    let p = 8;

    section(&format!("E5a: merge algorithms head-to-head (n = m = {n}, p = {p})"));
    let mut t = Table::new(vec!["dist", "traff", "distinguished", "merge path", "seq"]);
    for dist in [Dist::Uniform, Dist::DupHeavy(16), Dist::AllEqual, Dist::AdversarialSkew] {
        let a = sorted_keys(dist, n, 10);
        let b = sorted_keys(dist, n, 11);
        let mut out = vec![0i64; 2 * n];
        let r_t = Bench::new("traff").run(|| parallel_merge(&a, &b, &mut out, p));
        let r_d = Bench::new("dist").run(|| distinguished_merge(&a, &b, &mut out, p));
        let r_m = Bench::new("mp").run(|| merge_path_merge(&a, &b, &mut out, p));
        let r_s =
            Bench::new("seq").run(|| traff_merge::core::seqmerge::merge_into(&a, &b, &mut out));
        t.row(vec![
            dist.name(),
            format!("{:.2} ms", r_t.median() * 1e3),
            format!("{:.2} ms", r_d.median() * 1e3),
            format!("{:.2} ms", r_m.median() * 1e3),
            format!("{:.2} ms", r_s.median() * 1e3),
        ]);
    }
    t.print();

    section("E5b: structural costs (the simplification itself)");
    let a = sorted_keys(Dist::Uniform, n, 12);
    let b = sorted_keys(Dist::Uniform, n, 13);
    let mut out = vec![0i64; 2 * n];
    let stats = distinguished_merge(&a, &b, &mut out, p);
    let part = traff_merge::core::Partition::compute(&a, &b, p);
    let tasks = part.tasks();
    let mut t = Table::new(vec!["metric", "simplified (Träff)", "distinguished (classic)"]);
    t.row(vec!["synchronization points".into(), "1".to_string(), stats.sync_points.to_string()]);
    t.row(vec![
        "binary searches".into(),
        format!("{}", 2 * (p + 1)),
        stats.searches.to_string(),
    ]);
    t.row(vec![
        "extra splitter-merge ops".into(),
        "0 (eliminated)".to_string(),
        stats.splitter_merge_ops.to_string(),
    ]);
    t.row(vec!["merge tasks".into(), tasks.len().to_string(), format!("<= {}", 2 * p + 1)]);
    t.print();

    section("E5c: stability under duplicate-heavy inputs");
    let mut t = Table::new(vec!["algorithm", "stable?", "violations found / 200 trials"]);
    let mut traff_bad = 0;
    let mut dist_bad = 0;
    let mut mp_bad = 0;
    let mut rng = traff_merge::util::Rng::new(99);
    for _ in 0..200 {
        let na = 64 + rng.index(128);
        let nb = 64 + rng.index(128);
        let mut ka: Vec<i64> = (0..na).map(|_| rng.range(0, 4)).collect();
        let mut kb: Vec<i64> = (0..nb).map(|_| rng.range(0, 4)).collect();
        ka.sort();
        kb.sort();
        let ta = tag_a(&ka);
        let tb = tag_b(&kb);
        let mut out = vec![Record::new(0, 0); na + nb];
        parallel_merge(&ta, &tb, &mut out, 2 + rng.index(8));
        traff_bad += check_stable_merge(&out, B_TAG_BASE).is_err() as usize;
        distinguished_merge(&ta, &tb, &mut out, 2 + rng.index(8));
        dist_bad += check_stable_merge(&out, B_TAG_BASE).is_err() as usize;
        merge_path_merge(&ta, &tb, &mut out, 2 + rng.index(8));
        mp_bad += check_stable_merge(&out, B_TAG_BASE).is_err() as usize;
    }
    t.row(vec!["traff (simplified)".into(), "YES (by construction)".into(), traff_bad.to_string()]);
    t.row(vec!["distinguished".into(), "no".into(), dist_bad.to_string()]);
    t.row(vec!["merge path".into(), "yes".into(), mp_bad.to_string()]);
    t.print();
    assert_eq!(traff_bad, 0);
    assert_eq!(mp_bad, 0);
    assert!(dist_bad > 0, "the classic baseline should show instability");
    println!("\n(paper: \"such algorithms are not naturally stable\" — observed above)");
}

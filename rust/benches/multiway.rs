//! E12 — §3 extension: k-way merging built from the two-way primitive
//! (merge tree, ceil(log2 k) rounds) vs the classical sequential loser
//! tree and the naive pairwise fold.

use traff_merge::core::multiway::{loser_tree_merge, parallel_kway_merge};
use traff_merge::harness::{quick_mode, section, Bench};
use traff_merge::metrics::{melems_per_sec, Table};
use traff_merge::util::Rng;

fn main() {
    let total = if quick_mode() { 200_000 } else { 2_000_000 };

    section(&format!("E12: k-way merge of {total} total records vs k"));
    let mut t = Table::new(vec![
        "k", "merge tree (p=8)", "loser tree", "pairwise fold", "tree Melem/s",
    ]);
    for &k in &[2usize, 4, 8, 16, 64, 256] {
        let per = total / k;
        let mut rng = Rng::new(k as u64);
        let runs: Vec<Vec<i64>> = (0..k)
            .map(|_| {
                let mut v: Vec<i64> = (0..per).map(|_| rng.range(0, 1 << 40)).collect();
                v.sort();
                v
            })
            .collect();
        let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
        let r_tree = Bench::new("tree").samples(5).run(|| parallel_kway_merge(&refs, 8));
        let r_loser = Bench::new("loser").samples(5).run(|| loser_tree_merge(&refs));
        let r_fold = Bench::new("fold").samples(if k > 64 { 2 } else { 5 }).run(|| {
            let mut acc: Vec<i64> = Vec::new();
            for r in &refs {
                acc = traff_merge::baseline::seq_merge(&acc, r);
            }
            acc
        });
        t.row(vec![
            k.to_string(),
            format!("{:.1} ms", r_tree.median() * 1e3),
            format!("{:.1} ms", r_loser.median() * 1e3),
            format!("{:.1} ms", r_fold.median() * 1e3),
            format!("{:.1}", melems_per_sec(total as u64, r_tree.median())),
        ]);
    }
    t.print();
    println!("(tree does log2(k) passes of n; loser tree one pass with log2(k)\n\
              compares per element; fold degrades as k·n — the shape to check)");
}

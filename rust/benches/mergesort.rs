//! E7 — §3 merge sort: O(n log n / p + log p log n), stable, two
//! buffers only. Throughput vs n and p across distributions, against
//! std stable sort and our sequential merge sort.

use traff_merge::core::parallel_merge_sort;
use traff_merge::core::sort::expected_rounds;
use traff_merge::harness::{quick_mode, section, Bench};
use traff_merge::metrics::{melems_per_sec, Table};
use traff_merge::workload::{raw_keys, Dist};

fn main() {
    let n = if quick_mode() { 200_000 } else { 2_000_000 };

    section(&format!("E7a: sort throughput by distribution (n = {n}, p = 8)"));
    let mut t = Table::new(vec!["dist", "parallel p=8", "seq (ours)", "std stable", "par Melem/s"]);
    for dist in [Dist::Uniform, Dist::DupHeavy(16), Dist::OrganPipe, Dist::Presorted, Dist::Reversed]
    {
        let base = raw_keys(dist, n, 20);
        let r_par = Bench::new("par").run(|| {
            let mut v = base.clone();
            parallel_merge_sort(&mut v, 8);
            v
        });
        let r_seq = Bench::new("seq").run(|| {
            let mut v = base.clone();
            traff_merge::baseline::seq_sort(&mut v);
            v
        });
        let r_std = Bench::new("std").run(|| {
            let mut v = base.clone();
            v.sort();
            v
        });
        t.row(vec![
            dist.name(),
            format!("{:.1} ms", r_par.median() * 1e3),
            format!("{:.1} ms", r_seq.median() * 1e3),
            format!("{:.1} ms", r_std.median() * 1e3),
            format!("{:.1}", melems_per_sec(n, r_par.median())),
        ]);
    }
    t.print();
    println!("(single-core testbed: parallel wins appear only via the clone-cost\n\
              amortization; the model-level round count below carries the §3 claim)");

    section("E7b: merge rounds = ceil(log2 p) (the §3 structure)");
    let mut t = Table::new(vec!["p", "expected rounds", "measured rounds"]);
    for &p in &[2usize, 3, 4, 8, 16, 32] {
        let mut data = raw_keys(Dist::Uniform, 64 * p, 3);
        let blocks = traff_merge::core::Blocks::new(data.len(), p);
        let mut runs = blocks.starts();
        for i in 0..p {
            let (s, e) = (blocks.start(i), blocks.start(i + 1));
            data[s..e].sort();
        }
        let mut src = data.clone();
        let mut dst = data.clone();
        let mut rounds = 0;
        while runs.len() > 2 {
            runs = traff_merge::core::sort::merge_round(&src, &mut dst, &runs, p);
            std::mem::swap(&mut src, &mut dst);
            rounds += 1;
        }
        t.row(vec![p.to_string(), expected_rounds(p).to_string(), rounds.to_string()]);
    }
    t.print();

    section("E7c: PRAM-model sort steps (O(n log n / p + log p log n), EREW)");
    {
        use traff_merge::pram::{pram_sort, Variant};
        let mut t = Table::new(vec![
            "n", "p", "steps", "(n/p)·log n", "ratio", "rounds", "conflicts",
        ]);
        let ns: &[usize] = if quick_mode() { &[1 << 10] } else { &[1 << 10, 1 << 12, 1 << 14] };
        for &n in ns {
            for &p in &[2usize, 4, 8, 16] {
                let v = raw_keys(Dist::Uniform, n, 9);
                let (out, rep) = pram_sort(&v, p, Variant::Erew);
                assert!(out.windows(2).all(|w| w[0] <= w[1]));
                let denom = (n / p) * (traff_merge::util::log2_ceil(n) as usize);
                t.row(vec![
                    n.to_string(),
                    p.to_string(),
                    rep.report.steps.to_string(),
                    denom.to_string(),
                    format!("{:.3}", rep.report.steps as f64 / denom as f64),
                    rep.rounds.to_string(),
                    rep.report.conflicts.len().to_string(),
                ]);
            }
        }
        t.print();
        println!("(ratio flat in n and p => the §3 bound's dominant term; rounds = ⌈log₂ p⌉)");
    }

    section("E7d: wall-clock sort vs p (n = 1M uniform)");
    let base = raw_keys(Dist::Uniform, if quick_mode() { 100_000 } else { 1_000_000 }, 21);
    let mut t = Table::new(vec!["p", "median", "Melem/s"]);
    for &p in &[1usize, 2, 4, 8] {
        let r = Bench::new(format!("sort p={p}")).run(|| {
            let mut v = base.clone();
            parallel_merge_sort(&mut v, p);
            v
        });
        t.row(vec![
            p.to_string(),
            format!("{:.1} ms", r.median() * 1e3),
            format!("{:.1}", melems_per_sec(base.len(), r.median())),
        ]);
    }
    t.print();
}

//! E7 — §3 merge sort: O(n log n / p + log p log n), stable, two
//! buffers only. Throughput vs n and p across distributions, against
//! std stable sort and our sequential merge sort.

use traff_merge::core::merge::{carve_output, chunk_tasks};
use traff_merge::core::seqmerge::{merge_into, merge_sort};
use traff_merge::core::sort::expected_rounds;
use traff_merge::core::{
    parallel_merge_sort, parallel_merge_sort_with, Blocks, Case, MergeStrategy, MergeTask,
    Partition, Side,
};
use traff_merge::harness::{quick_mode, section, Bench};
use traff_merge::metrics::{melems_per_sec, Table};
use traff_merge::workload::{raw_keys, Dist};

/// The pre-executor implementation, preserved verbatim for the
/// comparison: a fresh `std::thread::scope` fleet for phase 1 and for
/// every merge round (spawn/join cost on every call).
fn scoped_sort(data: &mut [i64], p: usize) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    if p == 1 || n < 2 * p {
        let mut scratch = data.to_vec();
        merge_sort(data, &mut scratch);
        return;
    }
    let blocks = Blocks::new(n, p);
    let bounds = blocks.starts();
    {
        let mut rest: &mut [i64] = data;
        let mut slices = Vec::with_capacity(p);
        for i in 0..p {
            let (head, tail) = rest.split_at_mut(blocks.block_len(i));
            rest = tail;
            slices.push(head);
        }
        std::thread::scope(|s| {
            for slice in slices {
                s.spawn(move || {
                    let mut scratch = slice.to_vec();
                    merge_sort(slice, &mut scratch);
                });
            }
        });
    }
    let mut aux: Vec<i64> = data.to_vec();
    let mut runs: Vec<usize> = bounds;
    let mut in_data = true;
    while runs.len() > 2 {
        runs = if in_data {
            scoped_round(&*data, &mut aux, &runs, p)
        } else {
            scoped_round(&aux, data, &runs, p)
        };
        in_data = !in_data;
    }
    if !in_data {
        data.copy_from_slice(&aux);
    }
}

fn scoped_round(src: &[i64], dst: &mut [i64], runs: &[usize], p: usize) -> Vec<usize> {
    let nruns = runs.len() - 1;
    let npairs = nruns / 2;
    let per_pair = (p / npairs).max(1);
    let mut tasks: Vec<MergeTask> = Vec::new();
    let mut new_runs = vec![0usize];
    for pair in 0..npairs {
        let lo = runs[2 * pair];
        let mid = runs[2 * pair + 1];
        let hi = runs[2 * pair + 2];
        let part = Partition::compute(&src[lo..mid], &src[mid..hi], per_pair);
        for mut t in part.tasks() {
            t.a = (t.a.start + lo)..(t.a.end + lo);
            t.b = (t.b.start + mid)..(t.b.end + mid);
            t.c_off += lo;
            tasks.push(t);
        }
        new_runs.push(hi);
    }
    if nruns % 2 == 1 {
        let lo = runs[nruns - 1];
        let hi = runs[nruns];
        if hi > lo {
            tasks.push(MergeTask {
                a: lo..hi,
                b: hi..hi,
                c_off: lo,
                case: Case::CopyA,
                side: Side::A,
            });
            new_runs.push(hi);
        }
    }
    tasks.sort_by_key(|t| t.c_off);
    let pairs = carve_output(&tasks, dst).expect("tasks tile");
    let groups = chunk_tasks(pairs, p);
    std::thread::scope(|s| {
        for group in groups {
            s.spawn(move || {
                for (t, slice) in group {
                    merge_into(&src[t.a.clone()], &src[t.b.clone()], slice);
                }
            });
        }
    });
    new_runs
}

fn main() {
    let n = if quick_mode() { 200_000 } else { 2_000_000 };

    section(&format!("E7a: sort throughput by distribution (n = {n}, p = 8)"));
    let mut t = Table::new(vec!["dist", "parallel p=8", "seq (ours)", "std stable", "par Melem/s"]);
    for dist in [Dist::Uniform, Dist::DupHeavy(16), Dist::OrganPipe, Dist::Presorted, Dist::Reversed]
    {
        let base = raw_keys(dist, n, 20);
        let r_par = Bench::new("par").run(|| {
            let mut v = base.clone();
            parallel_merge_sort(&mut v, 8);
            v
        });
        let r_seq = Bench::new("seq").run(|| {
            let mut v = base.clone();
            traff_merge::baseline::seq_sort(&mut v);
            v
        });
        let r_std = Bench::new("std").run(|| {
            let mut v = base.clone();
            v.sort();
            v
        });
        t.row(vec![
            dist.name(),
            format!("{:.1} ms", r_par.median() * 1e3),
            format!("{:.1} ms", r_seq.median() * 1e3),
            format!("{:.1} ms", r_std.median() * 1e3),
            format!("{:.1}", melems_per_sec(n as u64, r_par.median())),
        ]);
    }
    t.print();
    println!("(single-core testbed: parallel wins appear only via the clone-cost\n\
              amortization; the model-level round count below carries the §3 claim)");

    section("E7b: merge rounds = ceil(log2 p) (the §3 structure)");
    let mut t = Table::new(vec!["p", "expected rounds", "measured rounds"]);
    for &p in &[2usize, 3, 4, 8, 16, 32] {
        let mut data = raw_keys(Dist::Uniform, 64 * p, 3);
        let blocks = traff_merge::core::Blocks::new(data.len(), p);
        let mut runs = blocks.starts();
        for i in 0..p {
            let (s, e) = (blocks.start(i), blocks.start(i + 1));
            data[s..e].sort();
        }
        let mut src = data.clone();
        let mut dst = data.clone();
        let mut rounds = 0;
        while runs.len() > 2 {
            runs = traff_merge::core::sort::merge_round(&src, &mut dst, &runs, p);
            std::mem::swap(&mut src, &mut dst);
            rounds += 1;
        }
        t.row(vec![p.to_string(), expected_rounds(p).to_string(), rounds.to_string()]);
    }
    t.print();

    section("E7c: PRAM-model sort steps (O(n log n / p + log p log n), EREW)");
    {
        use traff_merge::pram::{pram_sort, Variant};
        let mut t = Table::new(vec![
            "n", "p", "steps", "(n/p)·log n", "ratio", "rounds", "conflicts",
        ]);
        let ns: &[usize] = if quick_mode() { &[1 << 10] } else { &[1 << 10, 1 << 12, 1 << 14] };
        for &n in ns {
            for &p in &[2usize, 4, 8, 16] {
                let v = raw_keys(Dist::Uniform, n, 9);
                let (out, rep) = pram_sort(&v, p, Variant::Erew);
                assert!(out.windows(2).all(|w| w[0] <= w[1]));
                let denom = (n / p) * (traff_merge::util::log2_ceil(n) as usize);
                t.row(vec![
                    n.to_string(),
                    p.to_string(),
                    rep.report.steps.to_string(),
                    denom.to_string(),
                    format!("{:.3}", rep.report.steps as f64 / denom as f64),
                    rep.rounds.to_string(),
                    rep.report.conflicts.len().to_string(),
                ]);
            }
        }
        t.print();
        println!("(ratio flat in n and p => the §3 bound's dominant term; rounds = ⌈log₂ p⌉)");
    }

    section("E7d: wall-clock sort vs p (n = 1M uniform)");
    let base = raw_keys(Dist::Uniform, if quick_mode() { 100_000 } else { 1_000_000 }, 21);
    let mut t = Table::new(vec!["p", "median", "Melem/s"]);
    for &p in &[1usize, 2, 4, 8] {
        let r = Bench::new(format!("sort p={p}")).run(|| {
            let mut v = base.clone();
            parallel_merge_sort(&mut v, p);
            v
        });
        t.row(vec![
            p.to_string(),
            format!("{:.1} ms", r.median() * 1e3),
            format!("{:.1}", melems_per_sec(base.len() as u64, r.median())),
        ]);
    }
    t.print();

    section("E7e: persistent executor vs per-call thread::scope (n = 1M, p = num_cpus)");
    {
        // Keep n above the largest possible parallel_merge_cutoff
        // (2^18) even in quick mode, so BOTH paths genuinely run
        // parallel — otherwise the table would compare a sequential
        // bail against a threaded run.
        let n = if quick_mode() { 1 << 19 } else { 1_000_000 };
        let p = traff_merge::util::num_cpus();
        let base = raw_keys(Dist::Uniform, n, 33);
        // Correctness cross-check before timing.
        let mut check_exec = base.clone();
        let mut check_scoped = base.clone();
        parallel_merge_sort(&mut check_exec, p);
        scoped_sort(&mut check_scoped, p);
        assert_eq!(check_exec, check_scoped, "paths must agree");
        let r_exec = Bench::new("executor").run(|| {
            let mut v = base.clone();
            parallel_merge_sort(&mut v, p);
            v
        });
        let r_scoped = Bench::new("scoped spawn").run(|| {
            let mut v = base.clone();
            scoped_sort(&mut v, p);
            v
        });
        let mut t = Table::new(vec!["path", "median", "Melem/s"]);
        t.row(vec![
            "exec (persistent workers)".to_string(),
            format!("{:.1} ms", r_exec.median() * 1e3),
            format!("{:.1}", melems_per_sec(n as u64, r_exec.median())),
        ]);
        t.row(vec![
            "std::thread::scope per call".to_string(),
            format!("{:.1} ms", r_scoped.median() * 1e3),
            format!("{:.1}", melems_per_sec(n as u64, r_scoped.median())),
        ]);
        t.print();
        println!(
            "(acceptance: executor ≥ scoped — {} spawn/join generations per sort are gone)",
            1 + expected_rounds(p)
        );
    }

    section("E7f: steal-driven fine chunking vs greedy pre-balance (skewed keys)");
    {
        // Above the largest possible merge cutoff so every round runs
        // the parallel phase in BOTH modes.
        let n = if quick_mode() { 1 << 19 } else { 2_000_000 };
        let p = traff_merge::util::num_cpus();
        let mut t = Table::new(vec!["dist", "greedy (p lanes)", "fine (8p lanes)", "ratio"]);
        for dist in [Dist::Zipf, Dist::AdversarialSkew, Dist::Uniform] {
            let base = raw_keys(dist, n, 55);
            // Correctness cross-check in each mode before timing.
            std::env::set_var("EXEC_FINE_CHUNK", "1"); // pin: greedy
            let mut check = base.clone();
            parallel_merge_sort(&mut check, p);
            let mut expect = base.clone();
            expect.sort();
            assert_eq!(check, expect, "greedy mode mis-sorted {dist:?}");
            let r_greedy = Bench::new("greedy").run(|| {
                let mut v = base.clone();
                parallel_merge_sort(&mut v, p);
                v
            });
            std::env::set_var("EXEC_FINE_CHUNK", "8"); // pin: 8x finer
            let mut check = base.clone();
            parallel_merge_sort(&mut check, p);
            assert_eq!(check, expect, "fine mode mis-sorted {dist:?}");
            let r_fine = Bench::new("fine").run(|| {
                let mut v = base.clone();
                parallel_merge_sort(&mut v, p);
                v
            });
            std::env::remove_var("EXEC_FINE_CHUNK"); // back to telemetry-driven
            t.row(vec![
                dist.name(),
                format!("{:.1} ms", r_greedy.median() * 1e3),
                format!("{:.1} ms", r_fine.median() * 1e3),
                format!("{:.2}x", r_greedy.median() / r_fine.median()),
            ]);
        }
        t.print();
        println!(
            "(fine mode partitions each merge round below the greedy per-pair\n\
             lane share; cheap Chase–Lev steals absorb the extra groups and\n\
             recover skew dynamically)"
        );
    }

    section("E12: sort merge rounds — adaptive sequential-until-stolen vs fixed partition");
    {
        // Above the largest possible merge cutoff so every round's pair
        // merges run the parallel phase in both strategies.
        let n = if quick_mode() { 1 << 19 } else { 2_000_000 };
        let p = traff_merge::util::num_cpus();
        let mut t = Table::new(vec!["dist", "fixed", "adaptive", "fixed/adaptive"]);
        for dist in [Dist::Uniform, Dist::DupHeavy(16), Dist::Presorted] {
            let base = raw_keys(dist, n, 77);
            // Correctness cross-check before timing.
            let mut check = base.clone();
            parallel_merge_sort_with(&mut check, p, MergeStrategy::Adaptive);
            let mut expect = base.clone();
            expect.sort();
            assert_eq!(check, expect, "adaptive rounds mis-sorted {dist:?}");
            let r_fixed = Bench::new("fixed").run(|| {
                let mut v = base.clone();
                parallel_merge_sort_with(&mut v, p, MergeStrategy::Fixed);
                v
            });
            let r_adaptive = Bench::new("adaptive").run(|| {
                let mut v = base.clone();
                parallel_merge_sort_with(&mut v, p, MergeStrategy::Adaptive);
                v
            });
            t.row(vec![
                dist.name(),
                format!("{:.1} ms", r_fixed.median() * 1e3),
                format!("{:.1} ms", r_adaptive.median() * 1e3),
                format!("{:.2}x", r_fixed.median() / r_adaptive.median()),
            ]);
        }
        t.print();
        println!(
            "(adaptive rounds skip the per-pair partition entirely: each run pair\n\
             is one task that splits via co-rank only on observed steal requests)"
        );
    }
}

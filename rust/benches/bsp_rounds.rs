//! E8 — the §3 BSP remark: eliminating the distinguished-element merge
//! "can save at least one expensive round of communication".
//! Supersteps, h-relations, and total BSP cost vs p, plus sensitivity
//! to the barrier latency L (the saving grows with L).

use traff_merge::bsp::{bsp_merge_baseline, bsp_merge_simplified, BspParams};
use traff_merge::harness::{quick_mode, section};
use traff_merge::metrics::Table;
use traff_merge::workload::{sorted_keys, Dist};

fn main() {
    let n = if quick_mode() { 50_000 } else { 500_000 };
    let a = sorted_keys(Dist::Uniform, n, 1);
    let b = sorted_keys(Dist::Uniform, n, 2);

    section(&format!("E8a: supersteps and cost vs p (n = m = {n}, g = 4, L = 10k)"));
    let mut t = Table::new(vec![
        "p", "rounds simpl", "rounds classic", "h simpl", "h classic", "cost ratio (s/c)",
    ]);
    for &p in &[2usize, 4, 8, 16, 32, 64] {
        let params = BspParams { p, g: 4.0, l: 10_000.0 };
        let s = bsp_merge_simplified(&a, &b, params);
        let c = bsp_merge_baseline(&a, &b, params);
        t.row(vec![
            p.to_string(),
            s.cost.supersteps.to_string(),
            c.cost.supersteps.to_string(),
            s.cost.comm_words.to_string(),
            c.cost.comm_words.to_string(),
            format!("{:.3}", s.cost.cost / c.cost.cost),
        ]);
    }
    t.print();

    section("E8b: sensitivity to barrier latency L (p = 16)");
    let mut t = Table::new(vec!["L", "cost simplified", "cost classic", "saving"]);
    for &l in &[0.0f64, 1_000.0, 10_000.0, 100_000.0, 1_000_000.0] {
        let params = BspParams { p: 16, g: 4.0, l };
        let s = bsp_merge_simplified(&a, &b, params);
        let c = bsp_merge_baseline(&a, &b, params);
        t.row(vec![
            format!("{l:.0}"),
            format!("{:.0}", s.cost.cost),
            format!("{:.0}", c.cost.cost),
            format!("{:.1}%", 100.0 * (1.0 - s.cost.cost / c.cost.cost)),
        ]);
    }
    t.print();
    println!("(the absolute saving is exactly one L + the splitter h-relation —\n\
              it dominates as barriers get expensive, the paper's \"expensive\n\
              round of communication\")");
}

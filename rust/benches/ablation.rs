//! Ablation bench — the design choices DESIGN.md calls out, each
//! toggled in isolation:
//!
//!   A1. sequential-partition crossover (p ≤ 64 inline vs always
//!       threaded searches)
//!   A2. per-thread task assignment: greedy length-balanced chunks vs
//!       naive fixed-count chunks
//!   A3. leaf run width of the sequential merge sort
//!   A4. the two-sided task construction itself: paper's 2p tasks vs
//!       merge-path's p tasks (partition-strategy ablation)

use traff_merge::core::merge::{carve_output, partition_parallel_with_cutoff, run_tasks_parallel};
use traff_merge::core::seqmerge::merge_into;
use traff_merge::core::Partition;
use traff_merge::harness::{quick_mode, section, Bench};
use traff_merge::metrics::Table;
use traff_merge::workload::{sorted_keys, Dist};

fn main() {
    let n = if quick_mode() { 200_000 } else { 2_000_000 };
    let a = sorted_keys(Dist::Uniform, n, 50);
    let b = sorted_keys(Dist::Uniform, n, 51);
    let mut out = vec![0i64; 2 * n];

    section("A1: partition execution strategy (searches inline vs threaded)");
    let mut t = Table::new(vec!["p", "inline (crossover)", "forced threads"]);
    for &p in &[8usize, 64, 256, 1024] {
        let r_inline =
            Bench::new("inline").run(|| Partition::compute(&a, &b, p));
        let r_thread =
            Bench::new("threads").run(|| partition_parallel_with_cutoff(&a, &b, p, 4, 0));
        t.row(vec![
            p.to_string(),
            format!("{:.1} µs", r_inline.median() * 1e6),
            format!("{:.1} µs", r_thread.median() * 1e6),
        ]);
    }
    t.print();
    println!(
        "(measured crossover: p < {} stays inline — exec::tunables)",
        traff_merge::exec::tunables().parallel_search_cutoff
    );

    section("A2: task-to-thread assignment policy");
    let part = Partition::compute(&a, &b, 16);
    let tasks = part.tasks();
    let r_greedy = Bench::new("greedy").run(|| {
        run_tasks_parallel(&a, &b, &mut out, &tasks, 4).expect("tasks tile");
    });
    // Naive: fixed two-tasks-per-group regardless of size.
    let (a_ref, b_ref): (&[i64], &[i64]) = (&a, &b);
    let r_naive = Bench::new("naive").run(|| {
        let pairs = carve_output(&tasks, &mut out).expect("tasks tile");
        let groups: Vec<Vec<_>> = {
            let mut gs = Vec::new();
            let mut it = pairs.into_iter().peekable();
            while it.peek().is_some() {
                gs.push(it.by_ref().take(2).collect());
            }
            gs
        };
        std::thread::scope(|s| {
            for group in groups {
                s.spawn(move || {
                    for (task, slice) in group {
                        merge_into(&a_ref[task.a.clone()], &b_ref[task.b.clone()], slice);
                    }
                });
            }
        });
    });
    println!(
        "greedy length-balanced: {:.2} ms | fixed 2-per-group: {:.2} ms",
        r_greedy.median() * 1e3,
        r_naive.median() * 1e3
    );

    section("A3: leaf run width of the block sort (paper leaves this free)");
    let raw = traff_merge::workload::raw_keys(Dist::Uniform, n / 2, 52);
    let mut t = Table::new(vec!["leaf width", "sort time"]);
    for &width in &[16usize, 32, 64, 128] {
        let r = Bench::new(format!("w{width}")).run(|| {
            let mut v = raw.clone();
            // Bottom-up with explicit width: insertion-sort leaves then
            // merge rounds (mirrors seqmerge::merge_sort's structure).
            let mut lo = 0;
            while lo < v.len() {
                let hi = (lo + width).min(v.len());
                traff_merge::core::seqmerge::insertion_sort(&mut v[lo..hi]);
                lo = hi;
            }
            let mut scratch = v.clone();
            let mut w = width;
            let mut in_data = true;
            let nn = v.len();
            while w < nn {
                {
                    let (src, dst): (&[i64], &mut [i64]) =
                        if in_data { (&v, &mut scratch) } else { (&scratch, &mut v) };
                    let mut lo = 0;
                    while lo < nn {
                        let mid = (lo + w).min(nn);
                        let hi = (lo + 2 * w).min(nn);
                        merge_into(&src[lo..mid], &src[mid..hi], &mut dst[lo..hi]);
                        lo = hi;
                    }
                }
                in_data = !in_data;
                w *= 2;
            }
            if !in_data {
                v.copy_from_slice(&scratch);
            }
            v
        });
        t.row(vec![width.to_string(), format!("{:.1} ms", r.median() * 1e3)]);
    }
    t.print();

    section("A4: partition strategy — 2p two-sided tasks (paper) vs p diagonal cuts");
    let r_traff =
        Bench::new("traff").run(|| traff_merge::core::parallel_merge(&a, &b, &mut out, 8));
    let r_mp = Bench::new("mp")
        .run(|| traff_merge::baseline::merge_path_merge(&a, &b, &mut out, 8));
    println!(
        "paper partition: {:.2} ms | merge-path partition: {:.2} ms\n\
         (same merging work; the paper buys one-sync locality, merge-path\n\
         buys perfect balance — measured balance in E9)",
        r_traff.median() * 1e3,
        r_mp.median() * 1e3
    );
}

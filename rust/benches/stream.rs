//! E10 — the streaming run-merge subsystem:
//!
//! - **E10a** compaction throughput: the paper's co-rank parallel
//!   compactor (segment merges on the executor's background lane) vs
//!   the classical sequential loser-tree compactor over the same two
//!   overlapping sorted runs.
//! - **E10b** QoS under compaction: service-lane sort p99 with a
//!   background compaction flood running vs compaction off — the
//!   acceptance target is p99(on) within 2x of p99(off), i.e. the
//!   injector's priority lanes actually shield the service tenant
//!   from maintenance work.
//! - **E10c** k-way major compaction: the paged cursor driver merging
//!   a whole run backlog in one pass vs the pairwise cascade it
//!   replaces (fold of E10a's compactor, k−1 rewrites).
//! - **E11** multi-writer ingest scaling: 8 writer threads pushing the
//!   same record stream through one shared `Mutex<Ingestor>` (every
//!   push serialized) vs one owned `ShardWriter` per thread sealing
//!   through the shared generation clock — the acceptance target is
//!   sharded throughput >= 2x the single-mutex path.

use std::sync::Arc;
use traff_merge::model::sync::{AtomicBool, Ordering};
use std::time::Instant;
use traff_merge::coordinator::{Config, Engine, MergeService};
use traff_merge::core::record::Record;
use traff_merge::harness::{quick_mode, section, Bench};
use traff_merge::metrics::{fmt_duration, melems_per_sec, percentile, Table};
use traff_merge::runtime::KeyedBlock;
use traff_merge::stream::{
    kway_merge_to_vec, merge_runs_parallel, merge_runs_sequential, Ingestor, RunStore,
    StreamConfig, WriterSet,
};
use traff_merge::util::Rng;

fn sorted_run(rng: &mut Rng, n: usize, key_range: i64, tag0: u64) -> Vec<Record> {
    let mut keys: Vec<i64> = (0..n).map(|_| rng.range(0, key_range)).collect();
    keys.sort();
    keys.iter().enumerate().map(|(i, &k)| Record::new(k, tag0 + i as u64)).collect()
}

/// Submit one sorted batch and collect per-job completion latencies
/// (measured from batch submission, i.e. including queue wait — the
/// number a service caller sees). Returns `(p50, p99)`.
fn sort_batch_p99(svc: &MergeService, blocks: Vec<KeyedBlock>) -> (f64, f64) {
    let expect = blocks.len();
    let t0 = Instant::now();
    let rx = svc.submit_sort_batch(blocks);
    let mut lat: Vec<f64> = Vec::with_capacity(expect);
    for (_i, result) in rx.iter() {
        let out = result.expect("sort job succeeds");
        assert!(out.is_key_sorted());
        lat.push(t0.elapsed().as_secs_f64());
    }
    assert_eq!(lat.len(), expect, "every job reports back");
    lat.sort_by(f64::total_cmp);
    (percentile(&lat, 50.0), percentile(&lat, 99.0))
}

fn main() {
    let quick = quick_mode();
    let p = traff_merge::util::num_cpus();
    let mut rng = Rng::new(0xE10);

    // ---- E10a: compaction throughput --------------------------------
    section("E10a: compaction throughput — co-rank parallel vs sequential loser tree");
    let run_len = if quick { 200_000 } else { 1_000_000 };
    let a = sorted_run(&mut rng, run_len, 1 << 30, 0);
    let b = sorted_run(&mut rng, run_len, 1 << 30, 1 << 40);
    // Correctness pin before timing: both compactors agree.
    {
        let par = merge_runs_parallel(&a, &b, p);
        let seq = merge_runs_sequential(&a, &b);
        assert_eq!(par.len(), seq.len());
        assert!(par
            .iter()
            .zip(&seq)
            .all(|(x, y)| x.key == y.key && x.tag == y.tag));
    }
    let total = (2 * run_len) as u64;
    let r_par = Bench::new(format!("co-rank parallel compactor (p={p}, background lane)"))
        .run(|| merge_runs_parallel(&a, &b, p));
    let r_seq =
        Bench::new("sequential loser-tree compactor").run(|| merge_runs_sequential(&a, &b));
    let mut t = Table::new(vec!["compactor", "median", "Melem/s", "speedup"]);
    for r in [&r_par, &r_seq] {
        t.row(vec![
            r.name.clone(),
            fmt_duration(r.median()),
            format!("{:.1}", melems_per_sec(total, r.median())),
            format!("{:.2}x", r_seq.median() / r.median()),
        ]);
    }
    t.print();

    // ---- E10b: service p99 with compaction on vs off ----------------
    section("E10b: service-lane sort p99 — background compaction on vs off");
    let jobs = if quick { 8 } else { 16 };
    let job_n = if quick { 50_000 } else { 100_000 };
    let make_blocks = |rng: &mut Rng| -> Vec<KeyedBlock> {
        (0..jobs)
            .map(|_| KeyedBlock {
                keys: (0..job_n).map(|_| rng.range(0, 1 << 20) as f32).collect(),
                vals: (0..job_n as i32).collect(),
            })
            .collect()
    };
    let svc = MergeService::new(Config {
        threads: p,
        engine: Engine::Rust,
        leaf_block: 1024,
        ..Config::default()
    })
    .expect("rust-engine service");
    // Warm the executor + tunables off the record.
    sort_batch_p99(&svc, make_blocks(&mut rng));

    // Compaction OFF: the baseline.
    let (off_p50, off_p99) = sort_batch_p99(&svc, make_blocks(&mut rng));

    // Compaction ON: two flood threads re-merging a big run pair on
    // the background lane for the whole batch.
    let stop = Arc::new(AtomicBool::new(false));
    let ca = Arc::new(sorted_run(&mut rng, run_len, 1 << 30, 0));
    let cb = Arc::new(sorted_run(&mut rng, run_len, 1 << 30, 1 << 40));
    let floods: Vec<_> = (0..2)
        .map(|_| {
            let stop = Arc::clone(&stop);
            let ca = Arc::clone(&ca);
            let cb = Arc::clone(&cb);
            std::thread::spawn(move || {
                let mut rounds = 0usize;
                while !stop.load(Ordering::Acquire) {
                    std::hint::black_box(merge_runs_parallel(&ca, &cb, p));
                    rounds += 1;
                }
                rounds
            })
        })
        .collect();
    let (on_p50, on_p99) = sort_batch_p99(&svc, make_blocks(&mut rng));
    stop.store(true, Ordering::Release);
    let compactions: usize = floods.into_iter().map(|h| h.join().expect("flood thread")).sum();

    let mut t = Table::new(vec!["mode", "p50", "p99"]);
    t.row(vec![
        "compaction off".to_string(),
        fmt_duration(off_p50),
        fmt_duration(off_p99),
    ]);
    t.row(vec![
        format!("compaction on ({compactions} background merges)"),
        fmt_duration(on_p50),
        fmt_duration(on_p99),
    ]);
    t.print();
    let ratio = on_p99 / off_p99.max(1e-9);
    println!(
        "\nservice p99 with compaction on = {ratio:.2}x the compaction-off baseline \
         (acceptance target <= 2x)"
    );

    // ---- E10c: k-way major compaction vs pairwise cascade -----------
    section("E10c: k-way major compaction — one paged pass vs pairwise cascade");
    let k = 8usize;
    let n_total = if quick { 400_000 } else { 2_000_000 };
    let store = Arc::new(
        RunStore::new(
            StreamConfig::builder()
                .run_capacity(n_total / k)
                .fanout(64) // never auto-triggers: the bench drives merging
                .threads(p)
                .build()
                .expect("static bench config"),
        )
        .expect("in-memory store"),
    );
    let mut ing = Ingestor::new(Arc::clone(&store));
    for _ in 0..n_total {
        ing.push_key(rng.range(0, 1 << 16)).expect("ingest"); // dup-heavy
    }
    ing.flush().expect("flush");
    let snap = store.snapshot();
    assert_eq!(snap.len(), k, "bench shape: exactly k runs");
    // The pairwise cascade the k-way driver replaces: fold E10a's
    // compactor left to right (k−1 full rewrites, as the old
    // adjacent-pair-only store had to).
    let cascade = || {
        let mut acc = snap[0].load().expect("run data");
        for run in &snap[1..] {
            acc = merge_runs_parallel(&acc, &run.load().expect("run data"), p);
        }
        acc
    };
    // Correctness pin before timing: identical stable output.
    {
        let pair = cascade();
        let kway = kway_merge_to_vec(&snap, p).expect("k-way merge");
        assert_eq!(pair.len(), kway.len());
        assert!(pair.iter().zip(&kway).all(|(x, y)| x.key == y.key && x.tag == y.tag));
    }
    let r_kway = Bench::new(format!("k-way cursor driver (k={k}, one pass)"))
        .run(|| kway_merge_to_vec(&snap, p).expect("k-way merge"));
    let r_cascade = Bench::new(format!("pairwise cascade ({} rewrites)", k - 1)).run(cascade);
    let mut t = Table::new(vec!["major compaction", "median", "Melem/s", "speedup"]);
    for r in [&r_kway, &r_cascade] {
        t.row(vec![
            r.name.clone(),
            fmt_duration(r.median()),
            format!("{:.1}", melems_per_sec(n_total as u64, r.median())),
            format!("{:.2}x", r_cascade.median() / r.median()),
        ]);
    }
    t.print();

    // ---- E11: multi-writer ingest scaling ---------------------------
    section("E11: multi-writer ingest — sharded writers vs single Mutex<Ingestor>");
    let writers = 8usize;
    let n_ing = if quick { 400_000 } else { 2_000_000 };
    let keys: Vec<i64> = (0..n_ing).map(|_| rng.range(0, 1 << 16)).collect(); // dup-heavy
    let chunk = traff_merge::util::div_ceil(n_ing, writers).max(1);
    let ing_cfg = || {
        StreamConfig::builder()
            .run_capacity(n_ing / 16)
            .fanout(64) // never auto-triggers: pure ingest under test
            .threads(1)
            .build()
            .expect("static bench config")
    };
    // Correctness pin before timing: both paths seal every record.
    {
        let store = Arc::new(RunStore::new(ing_cfg()).expect("in-memory store"));
        let set = WriterSet::new(Arc::clone(&store), writers);
        std::thread::scope(|s| {
            for ch in keys.chunks(chunk) {
                let mut w = set.owned_writer();
                s.spawn(move || {
                    for &k in ch {
                        w.push(k, 0).expect("ingest");
                    }
                    w.flush().expect("flush");
                });
            }
        });
        assert_eq!(store.record_count(), n_ing as u64);
    }
    let r_mutex = Bench::new(format!("single Mutex<Ingestor> ({writers} threads, one lock)"))
        .run(|| {
            let store = Arc::new(RunStore::new(ing_cfg()).expect("in-memory store"));
            let ing = std::sync::Mutex::new(Ingestor::new(Arc::clone(&store)));
            std::thread::scope(|s| {
                for ch in keys.chunks(chunk) {
                    let ing = &ing;
                    s.spawn(move || {
                        for &k in ch {
                            ing.lock().unwrap().push_key(k).expect("ingest");
                        }
                    });
                }
            });
            ing.into_inner().unwrap().flush().expect("flush");
            store.record_count()
        });
    let r_shard = Bench::new(format!("sharded writers ({writers} owned shards, shared clock)"))
        .run(|| {
            let store = Arc::new(RunStore::new(ing_cfg()).expect("in-memory store"));
            let set = WriterSet::new(Arc::clone(&store), writers);
            std::thread::scope(|s| {
                for ch in keys.chunks(chunk) {
                    let mut w = set.owned_writer();
                    s.spawn(move || {
                        for &k in ch {
                            w.push(k, 0).expect("ingest");
                        }
                        w.flush().expect("flush");
                    });
                }
            });
            store.record_count()
        });
    let mut t = Table::new(vec!["ingest path", "median", "Melem/s", "speedup"]);
    for r in [&r_shard, &r_mutex] {
        t.row(vec![
            r.name.clone(),
            fmt_duration(r.median()),
            format!("{:.1}", melems_per_sec(n_ing as u64, r.median())),
            format!("{:.2}x", r_mutex.median() / r.median()),
        ]);
    }
    t.print();
    let speedup = r_mutex.median() / r_shard.median();
    println!(
        "\nsharded ingest = {speedup:.2}x the single-mutex path at {writers} writers \
         (acceptance target >= 2x)"
    );
}

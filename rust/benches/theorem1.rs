//! E3 — Theorem 1: merge cost scales as O(n/p + log n).
//!
//! Two views:
//!   1. Model level (exact): PRAM step counts over an (n, p) grid —
//!      the clean validation of the bound, independent of host cores.
//!   2. Wall clock: merge time vs n and vs p on OS threads. NOTE: this
//!      testbed exposes a single CPU; wall-clock p-scaling shows
//!      overhead, not speedup — the model-level table carries the
//!      claim (see EXPERIMENTS.md §Testbed).

use traff_merge::harness::{quick_mode, section, Bench};
use traff_merge::metrics::{melems_per_sec, Table};
use traff_merge::pram::{pram_merge, Variant};
use traff_merge::util::log2_ceil;
use traff_merge::workload::{sorted_keys, Dist};

fn main() {
    section("E3a: PRAM steps vs (n, p) — the O(n/p + log n) shape");
    let mut t = Table::new(vec!["n", "p", "steps", "2n/p", "steps/(2n/p)", "log2 n"]);
    let ns: &[usize] = if quick_mode() { &[1 << 12] } else { &[1 << 12, 1 << 14, 1 << 16] };
    for &n in ns {
        for &p in &[1usize, 2, 4, 8, 16, 32] {
            let a = sorted_keys(Dist::Uniform, n, 1);
            let b = sorted_keys(Dist::Uniform, n, 2);
            let (_, rep) = pram_merge(&a, &b, p, Variant::Erew);
            assert!(rep.report.conflict_free());
            let per = rep.report.steps as f64 / (2.0 * n as f64 / p as f64);
            t.row(vec![
                n.to_string(),
                p.to_string(),
                rep.report.steps.to_string(),
                (2 * n / p).to_string(),
                format!("{per:.3}"),
                log2_ceil(n).to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "(steps/(2n/p) must approach a constant as n/p grows — the merge\n\
         phase dominates at ~1 step/element; small n/p rows expose the\n\
         +log n and +p pipeline terms.)"
    );

    section("E3b: wall-clock merge vs n (p = 4)");
    let mut t = Table::new(vec!["n", "traff p=4", "seq merge", "Melem/s (traff)"]);
    let sizes: &[usize] =
        if quick_mode() { &[100_000] } else { &[100_000, 1_000_000, 4_000_000] };
    for &n in sizes {
        let a = sorted_keys(Dist::Uniform, n, 3);
        let b = sorted_keys(Dist::Uniform, n, 4);
        let mut out = vec![0i64; 2 * n];
        let r_par = Bench::new(format!("merge n={n} p=4"))
            .run(|| traff_merge::core::parallel_merge(&a, &b, &mut out, 4));
        let r_seq = Bench::new(format!("seq n={n}"))
            .run(|| traff_merge::core::seqmerge::merge_into(&a, &b, &mut out));
        t.row(vec![
            n.to_string(),
            format!("{:.3} ms", r_par.median() * 1e3),
            format!("{:.3} ms", r_seq.median() * 1e3),
            format!("{:.1}", melems_per_sec(2 * n as u64, r_par.median())),
        ]);
    }
    t.print();

    section("E3c: wall-clock merge vs p (single-core testbed: expect flat/overhead)");
    let n = if quick_mode() { 100_000 } else { 1_000_000 };
    let a = sorted_keys(Dist::Uniform, n, 5);
    let b = sorted_keys(Dist::Uniform, n, 6);
    let mut out = vec![0i64; 2 * n];
    let mut t = Table::new(vec!["p", "median", "Melem/s"]);
    for &p in &[1usize, 2, 4, 8, 16] {
        let r = Bench::new(format!("merge p={p}"))
            .run(|| traff_merge::core::parallel_merge(&a, &b, &mut out, p));
        t.row(vec![
            p.to_string(),
            format!("{:.3} ms", r.median() * 1e3),
            format!("{:.1}", melems_per_sec(2 * n as u64, r.median())),
        ]);
    }
    t.print();

    section("E3d: partition cost alone is O(p log n) — negligible");
    let full = Bench::new("full merge")
        .run(|| traff_merge::core::parallel_merge(&a, &b, &mut out, 8))
        .median();
    let mut t = Table::new(vec!["p", "partition", "fraction of full merge"]);
    for &p in &[8usize, 64, 512] {
        let r = Bench::new(format!("partition p={p}"))
            .run(|| traff_merge::core::Partition::compute(&a, &b, p));
        t.row(vec![
            p.to_string(),
            format!("{:.1} µs", r.median() * 1e6),
            format!("{:.4}", r.median() / full),
        ]);
    }
    t.print();
}

//! E6 — the EREW PRAM claims, quantified:
//!   (i)  zero concurrent accesses on every workload shape;
//!   (ii) step counts decompose into the Theorem 1 terms;
//!   (iii) CREW vs EREW costs the same here (the algorithm never
//!         *needed* concurrent reads — that is the point).

use traff_merge::harness::{quick_mode, section};
use traff_merge::metrics::Table;
use traff_merge::pram::{pram_merge, Variant};
use traff_merge::workload::{sorted_keys, Dist};

fn main() {
    section("E6a: phase-level step decomposition (n = m, uniform)");
    let mut t = Table::new(vec![
        "n", "p", "broadcast", "searches", "fetch", "merge", "total", "conflicts",
    ]);
    let ns: &[usize] = if quick_mode() { &[1 << 12] } else { &[1 << 12, 1 << 14, 1 << 16] };
    for &n in ns {
        for &p in &[2usize, 8, 32] {
            let a = sorted_keys(Dist::Uniform, n, 1);
            let b = sorted_keys(Dist::Uniform, n, 2);
            let (_, rep) = pram_merge(&a, &b, p, Variant::Erew);
            t.row(vec![
                n.to_string(),
                p.to_string(),
                rep.phase_steps[0].to_string(),
                (rep.phase_steps[1] + rep.phase_steps[2]).to_string(),
                rep.phase_steps[3].to_string(),
                rep.phase_steps[4].to_string(),
                rep.report.steps.to_string(),
                rep.report.conflicts.len().to_string(),
            ]);
        }
    }
    t.print();
    println!("(merge ≈ 2n/p·(1 ± balance); searches ≈ p + log n pipelined;\n\
              fetch is the O(1) cross-rank access window — conflicts are 0 everywhere)");

    section("E6b: conflict-freedom across workload shapes");
    let mut t = Table::new(vec!["dist", "p", "EREW conflicts", "steps"]);
    for dist in Dist::all() {
        for &p in &[4usize, 16] {
            let a = sorted_keys(dist, 4096, 5);
            let b = sorted_keys(dist, 4096, 6);
            let (c, rep) = pram_merge(&a, &b, p, Variant::Erew);
            let mut expect = [a, b].concat();
            expect.sort();
            assert_eq!(c, expect);
            t.row(vec![
                dist.name(),
                p.to_string(),
                rep.report.conflicts.len().to_string(),
                rep.report.steps.to_string(),
            ]);
        }
    }
    t.print();

    section("E6c: EREW vs CREW — same step counts (no concurrent reads needed)");
    let mut t = Table::new(vec!["p", "EREW steps", "CREW steps"]);
    let a = sorted_keys(Dist::Uniform, 1 << 14, 7);
    let b = sorted_keys(Dist::Uniform, 1 << 14, 8);
    for &p in &[2usize, 8, 32] {
        let (_, e) = pram_merge(&a, &b, p, Variant::Erew);
        let (_, c) = pram_merge(&a, &b, p, Variant::Crew);
        assert!(e.report.conflict_free() && c.report.conflict_free());
        t.row(vec![p.to_string(), e.report.steps.to_string(), c.report.steps.to_string()]);
    }
    t.print();

    section("E6d: work (total ops) is O(n + m) — processor-time product");
    let mut t = Table::new(vec!["n", "p", "work", "work / (n+m)"]);
    for &n in ns {
        for &p in &[2usize, 8, 32] {
            let a = sorted_keys(Dist::Uniform, n, 1);
            let b = sorted_keys(Dist::Uniform, n, 2);
            let (_, rep) = pram_merge(&a, &b, p, Variant::Erew);
            t.row(vec![
                n.to_string(),
                p.to_string(),
                rep.report.work.to_string(),
                format!("{:.3}", rep.report.work as f64 / (2 * n) as f64),
            ]);
        }
    }
    t.print();
}

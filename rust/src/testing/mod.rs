//! `qcheck` — a small generative property-testing framework (the
//! offline registry has no proptest; DESIGN.md §3).
//!
//! Usage:
//! ```
//! use traff_merge::testing::{qcheck, Gen};
//! qcheck("merge is sorted", 200, |g| {
//!     let mut a = g.vec_i64(0..300, -50..50);
//!     a.sort();
//!     // ... property body panics (or returns Err) on failure
//!     Ok(())
//! });
//! ```
//!
//! On failure the failing case index and seed are printed so the exact
//! case can be replayed with `QCHECK_SEED`. A simple halving shrinker
//! reruns the property with truncated generator output when the
//! property uses `g.shrinkable_vec_i64` (vectors are the dominant input
//! shape in this crate).

use crate::core::Record;
use crate::util::Rng;
use std::ops::Range;

/// Check that `output` is the **stable permutation** of `inputs`: the
/// same record multiset, key-sorted, with equal keys ordered first by
/// input slice, then by position within their slice — the paper's
/// stability contract, verified exactly against a reference stable
/// sort of the concatenation (Rust's `sort_by_key` is stable).
///
/// Returns `Err` in the qcheck property style so bodies can `?` it;
/// non-property callers `.unwrap()`. Pass a single input slice to
/// check a stable sort, several to check a stable merge.
pub fn assert_stable_permutation(
    inputs: &[&[Record]],
    output: &[Record],
) -> Result<(), String> {
    let total: usize = inputs.iter().map(|s| s.len()).sum();
    if total != output.len() {
        return Err(format!(
            "not a permutation: {} input records, {} output records",
            total,
            output.len()
        ));
    }
    let mut expect: Vec<Record> = Vec::with_capacity(total);
    for input in inputs {
        expect.extend_from_slice(input);
    }
    expect.sort_by_key(|r| r.key);
    for (i, (got, want)) in output.iter().zip(&expect).enumerate() {
        if (got.key, got.tag) != (want.key, want.tag) {
            return Err(format!(
                "stable permutation broken at output[{i}]: got (key {}, tag {}), want (key {}, tag {})",
                got.key, got.tag, want.key, want.tag
            ));
        }
    }
    Ok(())
}

/// The per-case random value source handed to properties.
pub struct Gen {
    rng: Rng,
    /// When set, `shrinkable` vectors are truncated to this length
    /// (used by the shrinking loop).
    pub truncate: Option<usize>,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), truncate: None }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        r.start + self.rng.index(r.end - r.start)
    }

    pub fn i64_in(&mut self, r: Range<i64>) -> i64 {
        self.rng.range(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// A vector with length drawn from `len` and elements from `vals`.
    pub fn vec_i64(&mut self, len: Range<usize>, vals: Range<i64>) -> Vec<i64> {
        let mut n = self.usize_in(len);
        if let Some(t) = self.truncate {
            n = n.min(t);
        }
        (0..n).map(|_| self.rng.range(vals.start, vals.end)).collect()
    }

    /// A sorted vector (merge-input convenience).
    pub fn sorted_vec_i64(&mut self, len: Range<usize>, vals: Range<i64>) -> Vec<i64> {
        let mut v = self.vec_i64(len, vals);
        v.sort();
        v
    }

    /// Pick one of the provided choices.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }
}

/// Run `cases` generated cases of `prop`. Panics with replay info on
/// the first failure, after attempting a truncation shrink.
pub fn qcheck<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("QCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            // Shrink: progressively halve the truncation bound.
            let mut best: Option<(usize, String)> = None;
            let mut bound = 1usize;
            while bound <= 4096 {
                let mut g = Gen::new(seed);
                g.truncate = Some(bound);
                if let Err(m) = prop(&mut g) {
                    best = Some((bound, m));
                    break;
                }
                bound *= 2;
            }
            match best {
                Some((bound, m)) => panic!(
                    "qcheck '{name}' failed (case {case}, seed {seed}, shrunk to len<={bound}):\n  {m}\n  replay: QCHECK_SEED={base_seed}"
                ),
                None => panic!(
                    "qcheck '{name}' failed (case {case}, seed {seed}):\n  {msg}\n  replay: QCHECK_SEED={base_seed}"
                ),
            }
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality helper with debug output.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {}\n  left: {:?}\n  right: {:?}",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        qcheck("trivial", 50, |g| {
            let _ = g.u64();
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "qcheck 'fails'")]
    fn failing_property_panics_with_seed() {
        qcheck("fails", 10, |g| {
            let v = g.vec_i64(0..100, 0..10);
            prop_assert!(v.len() < 5, "too long: {}", v.len());
            Ok(())
        });
    }

    #[test]
    fn stable_permutation_accepts_stable_and_rejects_swaps() {
        let a = [Record::new(1, 0), Record::new(3, 1)];
        let b = [Record::new(1, 10), Record::new(2, 11)];
        // Stable merge: a's key-1 record precedes b's.
        let ok = [Record::new(1, 0), Record::new(1, 10), Record::new(2, 11), Record::new(3, 1)];
        assert_stable_permutation(&[&a, &b], &ok).unwrap();
        // Same multiset, equal keys swapped: content-correct but
        // unstable — must be rejected.
        let swapped =
            [Record::new(1, 10), Record::new(1, 0), Record::new(2, 11), Record::new(3, 1)];
        assert!(assert_stable_permutation(&[&a, &b], &swapped).is_err());
        // Wrong cardinality.
        assert!(assert_stable_permutation(&[&a], &ok).is_err());
        // Single input = stable sort check.
        let v = [Record::new(2, 0), Record::new(1, 1), Record::new(2, 2)];
        let sorted = [Record::new(1, 1), Record::new(2, 0), Record::new(2, 2)];
        assert_stable_permutation(&[&v], &sorted).unwrap();
    }

    #[test]
    fn sorted_vec_is_sorted() {
        qcheck("sorted", 50, |g| {
            let v = g.sorted_vec_i64(0..200, -100..100);
            prop_assert!(v.windows(2).all(|w| w[0] <= w[1]), "not sorted");
            Ok(())
        });
    }
}

//! Multiway merging (paper §3 remarks, extension).
//!
//! The paper's merge sort repeatedly applies two-way merges in a tree;
//! this module packages that as a reusable k-way merge:
//!
//! - [`parallel_kway_merge`] — `ceil(log2 k)` levels of the simplified
//!   parallel two-way merge (each level is one §3 round over all pairs,
//!   executed on the persistent [`crate::exec`] executor via
//!   [`merge_round`]).
//! - [`loser_tree_merge`] — the classical sequential k-way loser tree,
//!   used as the comparison baseline (one pass, k-way comparisons).
//!
//! Both are stable across runs: ties favour the earlier run.

use super::adaptive::MergeStrategy;
use super::sort::merge_round_with;
use crate::exec::JobClass;

/// Stable k-way merge of `runs` (each individually sorted) using the
/// paper's two-way parallel merge per tree level, `p` threads total,
/// on the [`JobClass::Service`] lane.
pub fn parallel_kway_merge<T: Copy + Ord + Send + Sync>(runs: &[&[T]], p: usize) -> Vec<T> {
    parallel_kway_merge_with_class(runs, p, JobClass::Service)
}

/// [`parallel_kway_merge`] with an explicit QoS lane — the stream
/// layer's major compaction runs its merge levels on
/// [`JobClass::Background`].
pub fn parallel_kway_merge_with_class<T: Copy + Ord + Send + Sync>(
    runs: &[&[T]],
    p: usize,
    class: JobClass,
) -> Vec<T> {
    parallel_kway_merge_with(runs, p, class, MergeStrategy::default())
}

/// [`parallel_kway_merge_with_class`] with an explicit
/// [`MergeStrategy`] for every tree level — the stream compactor
/// routes its configured strategy through here.
pub fn parallel_kway_merge_with<T: Copy + Ord + Send + Sync>(
    runs: &[&[T]],
    p: usize,
    class: JobClass,
    strategy: MergeStrategy,
) -> Vec<T> {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    let mut src: Vec<T> = Vec::with_capacity(total);
    let mut bounds = vec![0usize];
    for r in runs {
        src.extend_from_slice(r);
        bounds.push(src.len());
    }
    if runs.len() <= 1 {
        return src;
    }
    let mut dst = src.clone();
    let mut runs_b = bounds;
    while runs_b.len() > 2 {
        runs_b = merge_round_with(&src, &mut dst, &runs_b, p, class, strategy);
        std::mem::swap(&mut src, &mut dst);
    }
    src
}

/// Sequential k-way merge via a loser tree (tournament tree) — the
/// classical one-pass baseline. Stable: ties resolve to the lower run
/// index.
pub fn loser_tree_merge<T: Copy + Ord>(runs: &[&[T]]) -> Vec<T> {
    let k = runs.len();
    let total: usize = runs.iter().map(|r| r.len()).sum();
    let mut out = Vec::with_capacity(total);
    if k == 0 {
        return out;
    }
    if k == 1 {
        out.extend_from_slice(runs[0]);
        return out;
    }
    // Heads of each run; None = exhausted.
    let mut pos = vec![0usize; k];
    // Simple binary-heap-free tournament: k is typically small, so a
    // linear scan with (key, run) lexicographic min is both simple and
    // cache-friendly; the loser-tree structure matters at k >> 8, where
    // we switch to the tree.
    if k <= 8 {
        loop {
            let mut best: Option<(usize, &T)> = None;
            for (r, &i) in pos.iter().enumerate() {
                if i < runs[r].len() {
                    let v = &runs[r][i];
                    best = match best {
                        None => Some((r, v)),
                        Some((_br, bv)) if v < bv => Some((r, v)),
                        other => other,
                    };
                }
            }
            match best {
                None => break,
                Some((r, _)) => {
                    out.push(runs[r][pos[r]]);
                    pos[r] += 1;
                }
            }
        }
        return out;
    }
    // Loser tree proper for large k: internal nodes store the LOSER of
    // the sub-tournament; the overall winner bubbles to the root.
    let size = k.next_power_of_two();
    // `tree[1..size]` internal nodes hold run indices; usize::MAX = empty.
    let mut tree = vec![usize::MAX; size];
    let key_of = |r: usize, pos: &[usize]| -> Option<&T> { runs[r].get(pos[r]) };
    // `beats(a, b)`: run a's head should be output before run b's head.
    let beats = |a: usize, b: usize, pos: &[usize]| -> bool {
        match (key_of(a, pos), key_of(b, pos)) {
            (None, _) => false,
            (_, None) => true,
            (Some(x), Some(y)) => x < y || (x == y && a < b),
        }
    };
    // Build: play leaves upward.
    let mut winner_at = vec![usize::MAX; 2 * size];
    for leaf in 0..size {
        winner_at[size + leaf] = if leaf < k { leaf } else { usize::MAX };
    }
    for node in (1..size).rev() {
        let (l, r) = (winner_at[2 * node], winner_at[2 * node + 1]);
        let (win, lose) = match (l, r) {
            (usize::MAX, x) => (x, usize::MAX),
            (x, usize::MAX) => (x, usize::MAX),
            (a, b) => {
                if beats(a, b, &pos) {
                    (a, b)
                } else {
                    (b, a)
                }
            }
        };
        winner_at[node] = win;
        tree[node] = lose;
    }
    let mut winner = winner_at[1];
    while winner != usize::MAX && pos[winner] < runs[winner].len() {
        out.push(runs[winner][pos[winner]]);
        pos[winner] += 1;
        // Replay from the winner's leaf to the root.
        let mut node = (size + winner) / 2;
        let mut cur = winner;
        while node >= 1 {
            let challenger = tree[node];
            if challenger != usize::MAX && !beats(cur, challenger, &pos) {
                tree[node] = cur;
                cur = challenger;
            }
            if node == 1 {
                break;
            }
            node /= 2;
        }
        winner = cur;
        if key_of(winner, &pos).is_none() {
            // Winner exhausted: replay fully to find the next best.
            let mut best = usize::MAX;
            for r in 0..k {
                if pos[r] < runs[r].len() && (best == usize::MAX || beats(r, best, &pos)) {
                    best = r;
                }
            }
            winner = best;
            if winner == usize::MAX {
                break;
            }
            // Rebuild the tree lazily (exhaustion happens k times total).
            for leaf in 0..size {
                winner_at[size + leaf] =
                    if leaf < k && pos[leaf] < runs[leaf].len() { leaf } else { usize::MAX };
            }
            for node in (1..size).rev() {
                let (l, r) = (winner_at[2 * node], winner_at[2 * node + 1]);
                let (win, lose) = match (l, r) {
                    (usize::MAX, x) => (x, usize::MAX),
                    (x, usize::MAX) => (x, usize::MAX),
                    (a, b) => {
                        if beats(a, b, &pos) {
                            (a, b)
                        } else {
                            (b, a)
                        }
                    }
                };
                winner_at[node] = win;
                tree[node] = lose;
            }
            winner = winner_at[1];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn runs_of(rng: &mut Rng, k: usize, max_len: usize) -> Vec<Vec<i64>> {
        (0..k)
            .map(|_| {
                let n = rng.index(max_len);
                let mut v: Vec<i64> = (0..n).map(|_| rng.range(0, 100)).collect();
                v.sort();
                v
            })
            .collect()
    }

    #[test]
    fn kway_matches_flat_sort() {
        let mut rng = Rng::new(3);
        for &k in &[0usize, 1, 2, 3, 5, 9, 17] {
            let runs = runs_of(&mut rng, k, 200);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut expect: Vec<i64> = runs.concat();
            expect.sort();
            assert_eq!(parallel_kway_merge(&refs, 4), expect, "parallel k={k}");
            assert_eq!(loser_tree_merge(&refs), expect, "loser tree k={k}");
        }
    }

    #[test]
    fn loser_tree_large_k() {
        let mut rng = Rng::new(8);
        let runs = runs_of(&mut rng, 40, 100);
        let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
        let mut expect: Vec<i64> = runs.concat();
        expect.sort();
        assert_eq!(loser_tree_merge(&refs), expect);
    }

    #[test]
    fn kway_adaptive_matches_flat_sort() {
        let mut rng = Rng::new(11);
        for &k in &[2usize, 3, 5, 9] {
            let runs = runs_of(&mut rng, k, 300);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut expect: Vec<i64> = runs.concat();
            expect.sort();
            let got =
                parallel_kway_merge_with(&refs, 4, JobClass::Service, MergeStrategy::Adaptive);
            assert_eq!(got, expect, "adaptive k={k}");
        }
    }

    #[test]
    fn kway_adaptive_with_empty_runs() {
        let runs: Vec<Vec<i64>> = vec![vec![], vec![1, 3], vec![], vec![2], vec![]];
        let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
        let got = parallel_kway_merge_with(&refs, 3, JobClass::Service, MergeStrategy::Adaptive);
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn kway_with_empty_runs() {
        let runs: Vec<Vec<i64>> = vec![vec![], vec![1, 3], vec![], vec![2], vec![]];
        let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
        assert_eq!(parallel_kway_merge(&refs, 3), vec![1, 2, 3]);
        assert_eq!(loser_tree_merge(&refs), vec![1, 2, 3]);
    }
}

//! The paper's rank primitives (§2).
//!
//! For an element `x` and a sorted array `X` (non-decreasing, duplicates
//! allowed), with implicit sentinels `X[-1] = -inf`, `X[len] = +inf`:
//!
//! - [`rank_low`]:  the unique `i` with `X[i-1] <  x <= X[i]`
//! - [`rank_high`]: the unique `j` with `X[j-1] <= x <  X[j]`
//!
//! `rank_low(A[i], B)` is the number of B elements that must precede
//! `A[i]` in a stable merge where equal A elements come first;
//! `rank_high(B[j], A)` is the number of A elements that must precede
//! `B[j]`. This asymmetry is what makes the whole algorithm stable for
//! free (paper §2) — every use in this crate goes through these two
//! functions so the convention cannot drift.
//!
//! Midpoint invariant: every halving loop computes its midpoint as
//! `lo + (hi - lo) / 2`, never `(lo + hi) >> 1` — the sum form
//! overflows once `lo + hi > usize::MAX`, which is reachable for
//! slices longer than `usize::MAX / 2` (the classic binary-search
//! bug). The subtraction form cannot overflow because `lo <= hi <=
//! len` holds throughout.

use std::cmp::Ordering;

/// `rank_low(x, xs)`: the unique `i` with `xs[i-1] < x <= xs[i]`.
///
/// Equivalent to the index of the first element `>= x` (lower bound).
/// `O(log len)` comparisons, branch-predictable halving loop.
#[inline]
pub fn rank_low<T: Ord>(x: &T, xs: &[T]) -> usize {
    let mut lo = 0usize;
    let mut hi = xs.len();
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        // SAFETY-free: mid < hi <= len.
        if xs[mid] < *x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// `rank_high(x, xs)`: the unique `j` with `xs[j-1] <= x < xs[j]`.
///
/// Equivalent to the index of the first element `> x` (upper bound).
#[inline]
pub fn rank_high<T: Ord>(x: &T, xs: &[T]) -> usize {
    let mut lo = 0usize;
    let mut hi = xs.len();
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if xs[mid] <= *x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Comparator-general variants (used by the keyed-record paths where
/// ordering is by key only).
#[inline]
pub fn rank_low_by<T, F: FnMut(&T, &T) -> Ordering>(x: &T, xs: &[T], mut cmp: F) -> usize {
    let mut lo = 0usize;
    let mut hi = xs.len();
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if cmp(&xs[mid], x) == Ordering::Less {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

#[inline]
pub fn rank_high_by<T, F: FnMut(&T, &T) -> Ordering>(x: &T, xs: &[T], mut cmp: F) -> usize {
    let mut lo = 0usize;
    let mut hi = xs.len();
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if cmp(&xs[mid], x) != Ordering::Greater {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Count of comparisons an element's rank costs — used by the PRAM step
/// accounting (each comparison is one PRAM step for the searching PE).
#[inline]
pub fn search_steps(len: usize) -> usize {
    // The halving loop runs exactly ceil(log2(len + 1)) iterations in the
    // worst case (rank range is [0, len], len+1 possible answers).
    crate::util::log2_ceil(len + 1) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_low_window_invariant() {
        // X[i-1] < x <= X[i] with sentinels.
        let xs = [1, 1, 3, 3, 3, 3, 4, 5, 6, 6, 6, 6, 7, 7, 7];
        for x in -1..9 {
            let i = rank_low(&x, &xs);
            if i > 0 {
                assert!(xs[i - 1] < x, "x={x} i={i}");
            }
            if i < xs.len() {
                assert!(x <= xs[i], "x={x} i={i}");
            }
        }
    }

    #[test]
    fn rank_high_window_invariant() {
        let xs = [1, 1, 3, 3, 3, 3, 4, 5, 6, 6, 6, 6, 7, 7, 7];
        for x in -1..9 {
            let j = rank_high(&x, &xs);
            if j > 0 {
                assert!(xs[j - 1] <= x, "x={x} j={j}");
            }
            if j < xs.len() {
                assert!(x < xs[j], "x={x} j={j}");
            }
        }
    }

    #[test]
    fn figure1_cross_ranks_a_into_b() {
        // x̄_i = rank_low(A[x_i], B) for x_i in [0, 4, 8, 12, 15].
        let a = [0, 0, 1, 1, 1, 2, 2, 2, 4, 5, 5, 5, 5, 5, 6, 6, 7, 7];
        let b = [1, 1, 3, 3, 3, 3, 4, 5, 6, 6, 6, 6, 7, 7, 7];
        let xbar: Vec<usize> = [0usize, 4, 8, 12, 15]
            .iter()
            .map(|&xi| rank_low(&a[xi], &b))
            .collect();
        assert_eq!(xbar, vec![0, 0, 6, 7, 8]);
    }

    #[test]
    fn figure1_cross_ranks_b_into_a() {
        // ȳ_j = rank_high(B[y_j], A) for y_j in [0, 3, 6, 9, 12].
        let a = [0, 0, 1, 1, 1, 2, 2, 2, 4, 5, 5, 5, 5, 5, 6, 6, 7, 7];
        let b = [1, 1, 3, 3, 3, 3, 4, 5, 6, 6, 6, 6, 7, 7, 7];
        let ybar: Vec<usize> = [0usize, 3, 6, 9, 12]
            .iter()
            .map(|&yj| rank_high(&b[yj], &a))
            .collect();
        assert_eq!(ybar, vec![5, 8, 9, 16, 18]);
    }

    #[test]
    fn empty_array_ranks() {
        let xs: [i64; 0] = [];
        assert_eq!(rank_low(&5, &xs), 0);
        assert_eq!(rank_high(&5, &xs), 0);
    }

    #[test]
    fn all_equal_splits_low_high() {
        let xs = [7i64; 64];
        assert_eq!(rank_low(&7, &xs), 0);
        assert_eq!(rank_high(&7, &xs), 64);
        assert_eq!(rank_low(&6, &xs), 0);
        assert_eq!(rank_high(&8, &xs), 64);
    }

    #[test]
    fn matches_std_partition_point() {
        let mut xs: Vec<i64> = (0..500).map(|i| (i * 7919) % 97).collect();
        xs.sort();
        for x in -5..105 {
            assert_eq!(rank_low(&x, &xs), xs.partition_point(|e| *e < x));
            assert_eq!(rank_high(&x, &xs), xs.partition_point(|e| *e <= x));
        }
    }

    #[test]
    fn by_variants_match() {
        let mut xs: Vec<i64> = (0..200).map(|i| (i * 31) % 23).collect();
        xs.sort();
        for x in -2..26 {
            assert_eq!(rank_low(&x, &xs), rank_low_by(&x, &xs, |a, b| a.cmp(b)));
            assert_eq!(rank_high(&x, &xs), rank_high_by(&x, &xs, |a, b| a.cmp(b)));
        }
    }

    #[test]
    fn search_steps_bounds() {
        assert_eq!(search_steps(0), 0);
        assert_eq!(search_steps(1), 1);
        assert_eq!(search_steps(15), 4);
        assert_eq!(search_steps(16), 5);
    }
}

//! The five-case subproblem classifier (paper §2, Steps 3–4, Figure 2).
//!
//! After the two parallel binary-search steps have produced the cross
//! ranks `x̄_i = rank_low(A[x_i], B)` and `ȳ_j = rank_high(B[y_j], A)`,
//! each of the `2p` processing elements determines its disjoint merge
//! subproblem **locally in O(1)** from two adjacent cross ranks — this
//! locality is exactly the simplification over [9, 14], which needed a
//! separate parallel merge of the distinguished elements.
//!
//! A-side (Step 3), PE assigned to block start `x_i`, with `j` the block
//! of B containing `x̄_i`:
//!
//! - (a) `x̄_i = x̄_{i+1}`                              → copy `A[x_i..x_{i+1})`
//! - (b) both in block j, `x̄_i ≠ y_j`                 → merge `A[x_i..x_{i+1})` with `B[x̄_i..x̄_{i+1})`
//! - (c) different blocks, `x̄_i ≠ y_j`, `x̄_{i+1} ≠ y_{j+1}` → merge `A[x_i..ȳ_{j+1})` with `B[x̄_i..y_{j+1})`
//! - (d) different blocks, `x̄_i ≠ y_j`, `x̄_{i+1} = y_{j+1}` → merge `A[x_i..x_{i+1})` with `B[x̄_i..y_{j+1})`
//! - (e) `x̄_i = y_j`, `x̄_i ≠ x̄_{i+1}`                → copy `A[x_i..ȳ_j)`
//!
//! all writing to `C[x_i + x̄_i ..)`. The B-side (Step 4) is the same
//! *mutatis mutandis* (swap A↔B, x↔y, rank_low↔rank_high); output goes
//! to `C[y_j + ȳ_j ..)`. Tie-breaking asymmetry is preserved: in every
//! produced task, ties are won by the A side, which is what makes the
//! overall merge stable.
//!
//! This module is pure index arithmetic — no data movement — so it can
//! be property-tested exhaustively (tasks disjoint, tile C, sizes ≤
//! 2⌈n/p⌉ + O(1)) independent of element types.

use super::blocks::Blocks;
use super::ranks::{rank_high, rank_low};

/// Which of the paper's five cases produced a task (diagnostics, E2/E9).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Case {
    /// (a)/(a′): copy — the opposite sequence contributes nothing here.
    CopyA,
    /// (b)/(b′): both cross ranks inside one opposite block.
    SameBlock,
    /// (c)/(c′): cross ranks straddle an opposite block boundary.
    CrossBlock,
    /// (d)/(d′): the right cross rank lands exactly on a block start.
    CrossBlockAligned,
    /// (e)/(e′): the left cross rank lands exactly on a block start.
    StartAligned,
}

/// Which input sequence the task's *initiating* block came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// Step 3: PE assigned to an A block start.
    A,
    /// Step 4: PE assigned to a B block start.
    B,
}

/// One disjoint merge subproblem: stable-merge `A[a.clone()]` with
/// `B[b.clone()]` into `C[c_off .. c_off + a.len() + b.len())`,
/// ties won by A.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MergeTask {
    pub a: std::ops::Range<usize>,
    pub b: std::ops::Range<usize>,
    pub c_off: usize,
    pub case: Case,
    pub side: Side,
}

impl MergeTask {
    #[inline]
    pub fn len(&self) -> usize {
        (self.a.end - self.a.start) + (self.b.end - self.b.start)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The partition state computed by the two binary-search steps:
/// block starts and cross ranks for both sequences. `p + 1` entries
/// each, with the sentinels `x̄_p = m`, `ȳ_p = n` (paper Steps 1–2).
#[derive(Clone, Debug)]
pub struct Partition {
    pub pa: Blocks,
    pub pb: Blocks,
    /// `x_i` for `0..=p`.
    pub x: Vec<usize>,
    /// `y_j` for `0..=p`.
    pub y: Vec<usize>,
    /// `x̄_i = rank_low(A[x_i], B)`, `x̄_p = m`.
    pub xbar: Vec<usize>,
    /// `ȳ_j = rank_high(B[y_j], A)`, `ȳ_p = n`.
    pub ybar: Vec<usize>,
}

impl Partition {
    /// Steps 1–2: the `2p` binary searches (sequential driver; the
    /// parallel drivers in `merge.rs`/`pram` distribute the same calls).
    pub fn compute<T: Ord>(a: &[T], b: &[T], p: usize) -> Self {
        let pa = Blocks::new(a.len(), p);
        let pb = Blocks::new(b.len(), p);
        let x = pa.starts();
        let y = pb.starts();
        let xbar = Self::xbar_of(a, b, &x);
        let ybar = Self::ybar_of(a, b, &y);
        Partition { pa, pb, x, y, xbar, ybar }
    }

    /// `x̄` from precomputed block starts (one entry per start; the
    /// last is the sentinel `m`). Each entry is one independent binary
    /// search — the unit the parallel drivers distribute.
    pub fn xbar_of<T: Ord>(a: &[T], b: &[T], x: &[usize]) -> Vec<usize> {
        x.iter()
            .map(|&xi| if xi < a.len() { rank_low(&a[xi], b) } else { b.len() })
            .collect()
    }

    pub fn ybar_of<T: Ord>(a: &[T], b: &[T], y: &[usize]) -> Vec<usize> {
        y.iter()
            .map(|&yj| if yj < b.len() { rank_high(&b[yj], a) } else { a.len() })
            .collect()
    }

    /// Steps 3–4: classify every block start into its merge task.
    /// Pure O(p) index arithmetic; returns the `<= 2p` non-empty tasks.
    pub fn tasks(&self) -> Vec<MergeTask> {
        let p = self.pa.p;
        let mut out = Vec::with_capacity(2 * p);
        for i in 0..p {
            if let Some(t) = self.a_side_task(i) {
                if !t.is_empty() {
                    out.push(t);
                }
            }
            if let Some(t) = self.b_side_task(i) {
                if !t.is_empty() {
                    out.push(t);
                }
            }
        }
        out
    }

    /// Step 3 for one PE: the task initiated by A block `i`.
    pub fn a_side_task(&self, i: usize) -> Option<MergeTask> {
        let (x, y, xbar, ybar) = (&self.x, &self.y, &self.xbar, &self.ybar);
        let xi = x[i];
        let xi1 = x[i + 1];
        if xi == xi1 {
            return None; // empty A block (n < p tail) — no PE work
        }
        let (xb, xb1) = (xbar[i], xbar[i + 1]);
        let c_off = xi + xb;
        // (a): no B elements strictly between — plain copy of the block.
        if xb == xb1 {
            return Some(MergeTask {
                a: xi..xi1,
                b: xb..xb,
                c_off,
                case: Case::CopyA,
                side: Side::A,
            });
        }
        // x̄_i < x̄_{i+1} <= m, so x̄_i < m: block j of B is defined.
        let j = self.pb.block_of(xb);
        let yj = y[j];
        if xb == yj {
            // (e): our cross rank sits exactly on B block start y_j; the
            // Step-4 PE for y_j merges from there — we only copy the A
            // prefix that precedes B[y_j], i.e. A[x_i .. ȳ_j).
            return Some(MergeTask {
                a: xi..ybar[j],
                b: xb..xb,
                c_off,
                case: Case::StartAligned,
                side: Side::A,
            });
        }
        let yj1 = y[j + 1];
        if xb1 < yj1 {
            // (b): both cross ranks inside block j — merge the full A
            // block with the B slice between the cross ranks.
            Some(MergeTask {
                a: xi..xi1,
                b: xb..xb1,
                c_off,
                case: Case::SameBlock,
                side: Side::A,
            })
        } else if xb1 == yj1 {
            // (d): right cross rank aligned with the next block start —
            // the A block still falls entirely before B[y_{j+1}]
            // (ȳ_{j+1} > x_{i+1} by Observation 1), so merge all of it.
            Some(MergeTask {
                a: xi..xi1,
                b: xb..yj1,
                c_off,
                case: Case::CrossBlockAligned,
                side: Side::A,
            })
        } else {
            // (c): cross ranks straddle y_{j+1} strictly — hand over at
            // the block boundary: A up to ȳ_{j+1} (which is <= x_{i+1}
            // since x̄_{i+1} > y_{j+1}), B up to y_{j+1}. The Step-4 PE
            // assigned to y_{j+1} continues from there.
            Some(MergeTask {
                a: xi..self.ybar[j + 1],
                b: xb..yj1,
                c_off,
                case: Case::CrossBlock,
                side: Side::A,
            })
        }
    }

    /// Step 4 for one PE: the task initiated by B block `j`
    /// (mutatis mutandis, with the rank roles swapped: B's cross ranks
    /// are high ranks, and the A-boundary handovers use x̄).
    pub fn b_side_task(&self, j: usize) -> Option<MergeTask> {
        let (x, y, xbar, ybar) = (&self.x, &self.y, &self.xbar, &self.ybar);
        let yj = y[j];
        let yj1 = y[j + 1];
        if yj == yj1 {
            return None;
        }
        let (yb, yb1) = (ybar[j], ybar[j + 1]);
        let c_off = yj + yb;
        if yb == yb1 {
            // (a′): copy the B block.
            return Some(MergeTask {
                a: yb..yb,
                b: yj..yj1,
                c_off,
                case: Case::CopyA,
                side: Side::B,
            });
        }
        let i = self.pa.block_of(yb);
        let xi = x[i];
        if yb == xi {
            // (e′): copy B[y_j .. x̄_i) — the B prefix strictly preceding
            // A[x_i]; the Step-3 PE for x_i merges from there.
            return Some(MergeTask {
                a: yb..yb,
                b: yj..xbar[i],
                c_off,
                case: Case::StartAligned,
                side: Side::B,
            });
        }
        let xi1 = x[i + 1];
        if yb1 < xi1 {
            // (b′)
            Some(MergeTask {
                a: yb..yb1,
                b: yj..yj1,
                c_off,
                case: Case::SameBlock,
                side: Side::B,
            })
        } else if yb1 == xi1 {
            // (d′)
            Some(MergeTask {
                a: yb..xi1,
                b: yj..yj1,
                c_off,
                case: Case::CrossBlockAligned,
                side: Side::B,
            })
        } else {
            // (c′): hand over at A block boundary x_{i+1}.
            Some(MergeTask {
                a: yb..xi1,
                b: yj..self.xbar[i + 1],
                c_off,
                case: Case::CrossBlock,
                side: Side::B,
            })
        }
    }

    /// Validate that the produced tasks exactly tile `C` and respect the
    /// per-task size bound. Used by debug assertions and the E2/E9 tests.
    pub fn validate_tasks(&self, tasks: &[MergeTask]) -> Result<(), String> {
        let n = self.pa.len;
        let m = self.pb.len;
        let mut sorted: Vec<&MergeTask> = tasks.iter().collect();
        sorted.sort_by_key(|t| t.c_off);
        let mut cursor = 0usize;
        let mut a_cursor = 0usize;
        let mut b_cursor = 0usize;
        for t in &sorted {
            if t.c_off != cursor {
                return Err(format!(
                    "gap/overlap at C[{cursor}]: next task starts at {} ({t:?})",
                    t.c_off
                ));
            }
            if t.a.start != a_cursor && !t.a.is_empty() {
                return Err(format!("A not consumed in order at {a_cursor}: {t:?}"));
            }
            if t.b.start != b_cursor && !t.b.is_empty() {
                return Err(format!("B not consumed in order at {b_cursor}: {t:?}"));
            }
            a_cursor = t.a.end.max(a_cursor);
            b_cursor = t.b.end.max(b_cursor);
            cursor += t.len();
        }
        if cursor != n + m {
            return Err(format!("tasks cover {cursor} of {} output slots", n + m));
        }
        if a_cursor != n || b_cursor != m {
            return Err(format!("inputs not fully consumed: A {a_cursor}/{n}, B {b_cursor}/{m}"));
        }
        // Theorem 1 balance bound: every task has O(n/p) elements —
        // concretely at most 2*max(ceil(n/p), ceil(m/p)).
        let cap = 2 * self.pa.big.max(self.pb.big);
        for t in &sorted {
            if t.len() > cap.max(2) {
                return Err(format!("task exceeds 2*ceil(n/p) = {cap}: {t:?}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig1() -> (Vec<i64>, Vec<i64>) {
        (
            vec![0, 0, 1, 1, 1, 2, 2, 2, 4, 5, 5, 5, 5, 5, 6, 6, 7, 7],
            vec![1, 1, 3, 3, 3, 3, 4, 5, 6, 6, 6, 6, 7, 7, 7],
        )
    }

    #[test]
    fn figure1_partition_state() {
        let (a, b) = fig1();
        let part = Partition::compute(&a, &b, 5);
        assert_eq!(part.x, vec![0, 4, 8, 12, 15, 18]);
        assert_eq!(part.y, vec![0, 3, 6, 9, 12, 15]);
        assert_eq!(part.xbar, vec![0, 0, 6, 7, 8, 15]);
        assert_eq!(part.ybar, vec![5, 8, 9, 16, 18, 18]);
    }

    /// The ten subproblems listed verbatim in the Figure 1 caption.
    #[test]
    fn figure1_tasks_match_caption() {
        let (a, b) = fig1();
        let part = Partition::compute(&a, &b, 5);
        let tasks = part.tasks();
        part.validate_tasks(&tasks).unwrap();

        let find = |c_off: usize| -> &MergeTask {
            tasks.iter().find(|t| t.c_off == c_off).expect("missing task")
        };
        // Step 3 (A-side): A[0..3] -> C[0..3]
        let t = find(0);
        assert_eq!((t.a.clone(), t.b.clone()), (0..4, 0..0));
        assert_eq!(t.case, Case::CopyA); // x̄_0 = x̄_1 = 0: case (a)
        // A[4] -> C[4]
        let t = find(4);
        assert_eq!((t.a.clone(), t.b.clone()), (4..5, 0..0));
        // A[8] -> C[14]
        let t = find(14);
        assert_eq!((t.a.clone(), t.b.clone()), (8..9, 6..6));
        // A[12..14] + B[7] -> C[19..22]
        let t = find(19);
        assert_eq!((t.a.clone(), t.b.clone()), (12..15, 7..8));
        // A[15] + B[8] -> C[23..24]
        let t = find(23);
        assert_eq!((t.a.clone(), t.b.clone()), (15..16, 8..9));
        // Step 4 (B-side): B[0..2] + A[5..7] -> C[5..10]
        let t = find(5);
        assert_eq!((t.a.clone(), t.b.clone()), (5..8, 0..3));
        assert_eq!(t.side, Side::B);
        // B[3..5] -> C[11..13]
        let t = find(11);
        assert_eq!((t.a.clone(), t.b.clone()), (8..8, 3..6));
        // B[6] + A[9..11] -> C[15..18]
        let t = find(15);
        assert_eq!((t.a.clone(), t.b.clone()), (9..12, 6..7));
        // B[9..11] + A[16,17] -> C[25..29]
        let t = find(25);
        assert_eq!((t.a.clone(), t.b.clone()), (16..18, 9..12));
        // B[12..14] -> C[30..32]
        let t = find(30);
        assert_eq!((t.a.clone(), t.b.clone()), (18..18, 12..15));
        assert_eq!(tasks.len(), 10);
    }

    #[test]
    fn figure1_case_census() {
        // Caption: x_0 is (a)... actually x_0 illustrates (e)-like copy per
        // the caption's mapping: x_0 (a), x_1 and x_2 (e), x_3 (b), x_4 (c);
        // ȳ_0 and ȳ_3 illustrate (d). Our classifier distinguishes the
        // same five shapes; assert all five appear in this one example.
        let (a, b) = fig1();
        let part = Partition::compute(&a, &b, 5);
        let tasks = part.tasks();
        use std::collections::HashSet;
        let seen: HashSet<Case> = tasks.iter().map(|t| t.case).collect();
        assert!(seen.len() >= 4, "figure 1 exercises most cases: {seen:?}");
    }

    #[test]
    fn exhaustive_small_inputs() {
        // Every (n, m, p) with duplicate-rich values: tasks must tile C.
        for n in 0..=12usize {
            for m in 0..=12usize {
                for p in 1..=6usize {
                    let a: Vec<i64> = (0..n).map(|i| (i as i64 * 3) / 4).collect();
                    let b: Vec<i64> = (0..m).map(|i| (i as i64 * 2) / 3).collect();
                    let part = Partition::compute(&a, &b, p);
                    let tasks = part.tasks();
                    part.validate_tasks(&tasks)
                        .unwrap_or_else(|e| panic!("n={n} m={m} p={p}: {e}"));
                }
            }
        }
    }

    #[test]
    fn empty_inputs() {
        let part = Partition::compute::<i64>(&[], &[], 4);
        assert!(part.tasks().is_empty());
        let part = Partition::compute(&[1, 2, 3], &[], 4);
        let tasks = part.tasks();
        part.validate_tasks(&tasks).unwrap();
        assert_eq!(tasks.iter().map(|t| t.len()).sum::<usize>(), 3);
    }

    #[test]
    fn all_equal_keys_tasks_tile() {
        let a = vec![7i64; 23];
        let b = vec![7i64; 17];
        for p in 1..=8 {
            let part = Partition::compute(&a, &b, p);
            let tasks = part.tasks();
            part.validate_tasks(&tasks).unwrap_or_else(|e| panic!("p={p}: {e}"));
        }
    }

    #[test]
    fn adversarial_all_b_inside_one_a_gap() {
        // Every B element falls between two adjacent A elements.
        let a: Vec<i64> = (0..40).map(|i| i * 1000).collect();
        let b: Vec<i64> = (0..37).map(|i| 5000 + i).collect();
        for p in 1..=9 {
            let part = Partition::compute(&a, &b, p);
            let tasks = part.tasks();
            part.validate_tasks(&tasks).unwrap_or_else(|e| panic!("p={p}: {e}"));
        }
    }
}

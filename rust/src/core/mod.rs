//! The paper's algorithm, layered exactly as §2 presents it:
//!
//! - [`ranks`]    — `rank_low` / `rank_high` binary searches (defs)
//! - [`blocks`]   — the p-way block partition arithmetic
//! - [`cases`]    — the five-case O(1) subproblem classifier (Fig. 2)
//! - [`seqmerge`] — stable sequential merge/copy kernels (per task)
//! - [`merge`]    — **Theorem 1**: the simplified stable parallel merge
//! - [`adaptive`] — sequential-until-stolen merge kernel (on-demand §2 splits)
//! - [`sort`]     — §3: stable parallel merge sort
//! - [`multiway`] — §3 extension: k-way merging
//! - [`record`]   — keyed records for stability observation

pub mod adaptive;
pub mod blocks;
pub mod cases;
pub mod merge;
pub mod multiway;
pub mod ranks;
pub mod record;
pub mod seqmerge;
pub mod sort;

pub use adaptive::{adaptive_merge, merge_with_strategy, MergeStrategy};
pub use blocks::Blocks;
pub use cases::{Case, MergeTask, Partition, Side};
pub use merge::{parallel_merge, parallel_merge_instrumented};
pub use record::{F32Key, Record};
pub use sort::{parallel_merge_sort, parallel_merge_sort_with};

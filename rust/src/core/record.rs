//! Keyed records for stability verification and the XLA interchange.
//!
//! [`Record`] orders by `key` only; `tag` is an opaque payload used to
//! *observe* stability (a stable algorithm must keep equal-key tags in
//! their original relative order, with all A tags before B tags).
//!
//! [`F32Key`] is a total-order wrapper over the f32 keys used by the AOT
//! artifacts (the runtime path marshals f32/i32 literals).

use std::cmp::Ordering;

/// A sortable record: ordered by `key`, carrying a stability `tag`.
#[derive(Clone, Copy, Debug)]
pub struct Record {
    pub key: i64,
    pub tag: u64,
}

impl Record {
    #[inline]
    pub fn new(key: i64, tag: u64) -> Self {
        Record { key, tag }
    }
}

impl PartialEq for Record {
    #[inline]
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl Eq for Record {}

impl PartialOrd for Record {
    #[inline]
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Record {
    /// Orders by key ONLY — equal keys are `Equal` regardless of tag,
    /// which is exactly what lets tags detect (in)stability.
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        self.key.cmp(&other.key)
    }
}

/// Total order for f32 (no NaNs expected in workloads; NaN sorts last).
#[derive(Clone, Copy, Debug)]
pub struct F32Key(pub f32);

impl PartialEq for F32Key {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for F32Key {}

impl PartialOrd for F32Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for F32Key {
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_orders_by_key_only() {
        assert_eq!(Record::new(3, 0), Record::new(3, 99));
        assert!(Record::new(2, 9) < Record::new(3, 0));
    }

    #[test]
    fn f32key_total_order() {
        assert!(F32Key(1.0) < F32Key(2.0));
        assert!(F32Key(f32::NEG_INFINITY) < F32Key(-1e30));
        assert!(F32Key(f32::INFINITY) > F32Key(1e30));
        assert!(F32Key(f32::NAN) > F32Key(f32::INFINITY));
        assert_eq!(F32Key(0.5), F32Key(0.5));
    }
}

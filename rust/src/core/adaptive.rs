//! Adaptive sequential-until-stolen merge kernel.
//!
//! [`parallel_merge`](super::merge::parallel_merge) *always* pays the
//! §2 partition up front: `2(p+1)` binary searches, task
//! classification, and a scatter of `p` (or telemetry-inflated) tasks
//! across the fleet — even when every worker is busy and the partition
//! buys nothing, and even on nearly-disjoint or duplicate-heavy inputs
//! where one `memcpy`-class pass wins outright.
//!
//! This module inverts the decision: [`adaptive_merge`] runs the
//! *sequential* stable merge in bounded quanta
//! ([`crate::exec::adaptive_quantum_for`] elements at a time,
//! overridable via `EXEC_ADAPTIVE_QUANTUM`) and polls a
//! [`StealToken`] between quanta. Only when an idle worker has
//! actually raised a steal request does the kernel split the
//! *remaining* input — one §2 single-rank co-partition
//! ([`super::ranks`]) halving the larger side into exactly two stable
//! halves. The right half is published as a stealable scope task; the
//! left half continues sequentially on the current worker. Work
//! migrates only when somebody is there to take it.
//!
//! ```text
//!   a ─┬────────────┬─────────────────────┐
//!      │ quantum k  │      remainder      │
//!   b ─┴────────────┴─────────────────────┘
//!        │               │
//!        ▼               ▼ token.should_split()?
//!   co_rank(k) →     no: next quantum
//!   merge_into       yes: i = |a|/2, j = rank_low(a[i], b)
//!   (block-copy           left  = (a[..i], b[..j])   — continue
//!    fast paths)          right = (a[i..], b[j..])   — s.spawn(...)
//! ```
//!
//! **Stability argument for splitting mid-merge.** The quantum
//! boundary is the §2 co-rank `(i, j)` with `i + j = k`: `a[i-1] <=
//! b[j]` (an `a`-element may tie its successor in `b` — `a` wins ties)
//! and `b[j-1] < a[i]` (strictly — a `b`-element must NOT tie an
//! `a`-element that is still unmerged, because the `a`-element would
//! have to precede it). So `merge(a[..i], b[..j])` is exactly the
//! first `k` elements of the stable merge, and the remainder merges
//! independently. The steal split uses the same two rank primitives
//! ([`super::ranks::rank_low`] / [`super::ranks::rank_high`]) with the
//! same tie asymmetry, so every element of the left half precedes —
//! in stable order — every element of the right half. Concatenating
//! per-half stable merges is therefore THE stable merge.
//!
//! Triviality fast paths run at *quantum* granularity: each quantum is
//! merged through [`merge_into`], whose non-interleaving and
//! constant-block checks (see [`super::seqmerge`]) turn nearly-disjoint
//! and duplicate-heavy quanta into whole-block copies — the dominant
//! win on those distributions (cf. Merge Path, arXiv:1406.2628, and
//! the block-granular analysis in arXiv:2005.12648).

use super::seqmerge::merge_into;
use crate::exec::{Scope, StealToken};

/// Which merge kernel the coordinator / sort rounds / stream
/// compaction use. Selected through `Config`/`JobBuilder`,
/// `StreamConfig`, and `repro --strategy`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum MergeStrategy {
    /// The paper's fixed pre-partition: split into `p` (or
    /// telemetry-inflated) lanes up front, one synchronization point.
    #[default]
    Fixed,
    /// Sequential-until-stolen: merge in bounded quanta, split on
    /// demand via the §2 co-rank partition when an idle worker raises
    /// a steal request.
    Adaptive,
}

impl MergeStrategy {
    /// CLI-facing name (`repro --strategy <name>`).
    pub fn name(self) -> &'static str {
        match self {
            MergeStrategy::Fixed => "fixed",
            MergeStrategy::Adaptive => "adaptive",
        }
    }

    /// Inverse of [`MergeStrategy::name`]; `None` for unknown names.
    pub fn parse(s: &str) -> Option<MergeStrategy> {
        match s {
            "fixed" => Some(MergeStrategy::Fixed),
            "adaptive" => Some(MergeStrategy::Adaptive),
            _ => None,
        }
    }
}

impl std::fmt::Display for MergeStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Strategy-dispatched stable merge: the one entry point the
/// coordinator and stream layers route through.
pub fn merge_with_strategy<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    p: usize,
    strategy: MergeStrategy,
) {
    match strategy {
        MergeStrategy::Fixed => super::merge::parallel_merge(a, b, out, p),
        MergeStrategy::Adaptive => adaptive_merge(a, b, out, p),
    }
}

/// Stable adaptive merge of sorted `a` and `b` into `out`.
///
/// Merges sequentially in bounded quanta and splits only on observed
/// steal requests (see the module docs). `p` gates only the
/// sequential crossover — the kernel itself discovers parallelism
/// dynamically, so there is no per-`p` partition cost.
///
/// # Panics
/// If `out.len() != a.len() + b.len()` or `p == 0`.
pub fn adaptive_merge<T: Copy + Ord + Send + Sync>(a: &[T], b: &[T], out: &mut [T], p: usize) {
    assert_eq!(out.len(), a.len() + b.len(), "output length mismatch");
    assert!(p > 0, "p must be positive");
    if p == 1 || out.len() < crate::exec::tunables_for::<T>().parallel_merge_cutoff {
        merge_into(a, b, out);
        return;
    }
    let quantum = crate::exec::adaptive_quantum_for::<T>();
    crate::exec::global().scope(|s| merge_adaptive_scoped(s, a, b, out, quantum, None));
}

/// [`adaptive_merge`] with an explicit quantum and [`StealToken`] —
/// the deterministic entry for tests and benches
/// ([`StealToken::never`] forces the pure sequential-quanta path,
/// [`StealToken::always`] splits at every poll). Skips the sequential
/// crossover: the scoped kernel always runs.
pub fn adaptive_merge_with_token<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    quantum: usize,
    token: &StealToken,
) {
    assert_eq!(out.len(), a.len() + b.len(), "output length mismatch");
    let quantum = quantum.max(1);
    crate::exec::global().scope(|s| merge_adaptive_scoped(s, a, b, out, quantum, Some(token)));
}

/// The kernel proper, running inside an executor scope so split-off
/// right halves can be spawned as stealable tasks (and can split
/// again, recursively). Callers outside this module
/// ([`super::sort::merge_round_with`]) enter here with one task per
/// run pair.
///
/// `token`: `None` derives the executing worker's own token
/// per task ([`crate::exec::steal_token`]) — the right choice for
/// production, where each spawned half must poll its *own* flag.
/// `Some` pins a caller-provided token (deterministic tests/benches).
pub(crate) fn merge_adaptive_scoped<'scope, T: Copy + Ord + Send + Sync>(
    s: &'scope Scope<'scope, '_>,
    mut a: &'scope [T],
    mut b: &'scope [T],
    mut out: &'scope mut [T],
    quantum: usize,
    token: Option<&'scope StealToken>,
) {
    let derived;
    let token: &StealToken = match token {
        Some(t) => t,
        None => {
            derived = crate::exec::steal_token();
            &derived
        }
    };
    loop {
        debug_assert_eq!(out.len(), a.len() + b.len());
        // Small or one-sided remainder: finish inline. The 2·quantum
        // floor guarantees a split (below) always has a quantum's
        // worth of work for BOTH halves.
        if a.is_empty() || b.is_empty() || out.len() <= quantum.saturating_mul(2) {
            merge_into(a, b, out);
            return;
        }
        // Poll FIRST: a pending steal request means an idle worker is
        // parked right now — splitting before the next quantum (or
        // before a big trivial block copy) hands it work a poll
        // earlier, and consecutive polls keep splitting while more
        // workers are waiting.
        if token.should_split() {
            // §2 single-rank co-partition of the remainder, halving
            // the larger input side. Tie asymmetry (ties-to-A):
            // `rank_low` sends b-elements equal to a[i] RIGHT (they
            // follow a[i]); `rank_high` sends a-elements equal to
            // b[j] LEFT (they precede b[j]). Both sides of each half
            // are non-empty checks are not needed — only the halves'
            // *output* ranges matter, and both are non-empty because
            // the larger side has >= 2 elements here.
            let (i, j) = if a.len() >= b.len() {
                let i = a.len() / 2;
                (i, super::ranks::rank_low(&a[i], b))
            } else {
                let j = b.len() / 2;
                (super::ranks::rank_high(&b[j], a), j)
            };
            let (al, ar) = a.split_at(i);
            let (bl, br) = b.split_at(j);
            let cur = out;
            let (ol, or_) = cur.split_at_mut(i + j);
            // The spawned half derives its own token (None): it runs
            // on whatever worker steals it, and must poll THAT
            // worker's flag, not ours.
            crate::obs::trace::instant(
                crate::obs::SpanKind::AdaptiveSplit,
                (ar.len() + br.len()) as u64,
            );
            s.spawn(move || merge_adaptive_scoped(s, ar, br, or_, quantum, None));
            a = al;
            b = bl;
            out = ol;
            continue;
        }
        // Whole-remainder triviality: the inputs no longer interleave,
        // so the rest is two block copies (merge_into's fast path).
        let (n, m) = (a.len(), b.len());
        if a[n - 1] <= b[0] || b[m - 1] < a[0] {
            merge_into(a, b, out);
            return;
        }
        // One bounded quantum of stable sequential merging: cut the
        // next `quantum` output elements at the co-rank boundary and
        // run the (fast-pathed) sequential kernel on them.
        let (i, j) = co_rank(quantum, a, b);
        let cur = out;
        let (head, tail) = cur.split_at_mut(quantum);
        merge_into(&a[..i], &b[..j], head);
        a = &a[i..];
        b = &b[j..];
        out = tail;
    }
}

/// The §2 co-rank at output position `k`: the unique `(i, j)` with
/// `i + j = k` such that the stable merge of `a[..i]` and `b[..j]` is
/// exactly the first `k` elements of the stable merge of `a` and `b`:
///
/// - `i == 0 || j == m || a[i-1] <= b[j]` — the last taken a-element
///   does not exceed b's next (ties allowed: a wins them), and
/// - `j == 0 || i == n || b[j-1] < a[i]` — the last taken b-element is
///   *strictly* below a's next (a tie would belong to `a` first).
///
/// Binary search over `i` in `[max(0, k-m), min(k, n)]`; each probe
/// violating a condition strictly shrinks the interval toward the
/// (existing, unique) fixed point, so the loop terminates in
/// `O(log min(k, n, m))` probes.
fn co_rank<T: Ord>(k: usize, a: &[T], b: &[T]) -> (usize, usize) {
    let (n, m) = (a.len(), b.len());
    debug_assert!(k <= n + m);
    let mut lo = k.saturating_sub(m);
    let mut hi = k.min(n);
    loop {
        let i = lo + (hi - lo) / 2;
        let j = k - i;
        if i > 0 && j < m && a[i - 1] > b[j] {
            // Took too many from a: a[i-1] belongs after b[j].
            hi = i - 1;
        } else if j > 0 && i < n && b[j - 1] >= a[i] {
            // Took too many from b: b[j-1] ties or exceeds a[i], and a
            // wins ties, so a[i] belongs inside the prefix.
            lo = i + 1;
        } else {
            return (i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::record::Record;
    use crate::util::Rng;
    use crate::workload::{tag_a, tag_b};
    #[cfg(not(miri))]
    use crate::workload::{check_stable_merge, sorted_keys, Dist, B_TAG_BASE};

    fn keyed(out: &[Record]) -> Vec<(i64, u64)> {
        out.iter().map(|r| (r.key, r.tag)).collect()
    }

    #[test]
    fn co_rank_prefix_is_exact_and_stable() {
        // Duplicate-rich small inputs, every output position k, both
        // orientations. Records make tie misplacement visible.
        let shapes: Vec<(Vec<i64>, Vec<i64>)> = vec![
            (vec![0, 0, 1, 2, 2, 2, 5], vec![0, 2, 2, 3, 5, 5]),
            (vec![1, 1, 1, 1], vec![1, 1, 1]),
            (vec![0, 1, 2, 3], vec![10, 11]),
            (vec![10, 11], vec![0, 1, 2, 3]),
            (vec![5], vec![5, 5, 5, 5, 5]),
            (vec![], vec![1, 2, 3]),
            (vec![1, 2, 3], vec![]),
        ];
        for (ka, kb) in shapes {
            let a = tag_a(&ka);
            let b = tag_b(&kb);
            let mut full = vec![Record::new(0, 0); a.len() + b.len()];
            merge_into(&a, &b, &mut full);
            for k in 0..=a.len() + b.len() {
                let (i, j) = co_rank(k, &a, &b);
                assert_eq!(i + j, k, "ka={ka:?} kb={kb:?} k={k}");
                let mut head = vec![Record::new(0, 0); k];
                merge_into(&a[..i], &b[..j], &mut head);
                assert_eq!(keyed(&head), keyed(&full[..k]), "ka={ka:?} kb={kb:?} k={k}");
            }
        }
    }

    #[test]
    fn co_rank_random_sweep() {
        let mut rng = Rng::new(41);
        // Miri runs the same sweep at interpreter-friendly volume.
        let iters = if cfg!(miri) { 25 } else { 200 };
        for _ in 0..iters {
            let n = rng.index(120);
            let m = rng.index(120);
            let mut ka: Vec<i64> = (0..n).map(|_| rng.range(0, 12)).collect();
            let mut kb: Vec<i64> = (0..m).map(|_| rng.range(0, 12)).collect();
            ka.sort();
            kb.sort();
            let a = tag_a(&ka);
            let b = tag_b(&kb);
            let mut full = vec![Record::new(0, 0); n + m];
            merge_into(&a, &b, &mut full);
            let k = rng.index(n + m + 1);
            let (i, j) = co_rank(k, &a, &b);
            assert_eq!(i + j, k);
            let mut head = vec![Record::new(0, 0); k];
            merge_into(&a[..i], &b[..j], &mut head);
            assert_eq!(keyed(&head), keyed(&full[..k]), "n={n} m={m} k={k}");
        }
    }

    #[cfg(not(miri))]
    fn check_adaptive(ka: &[i64], kb: &[i64], quantum: usize, token: &StealToken) {
        let a = tag_a(ka);
        let b = tag_b(kb);
        let mut out = vec![Record::new(0, 0); a.len() + b.len()];
        adaptive_merge_with_token(&a, &b, &mut out, quantum, token);
        let mut expect = [a, b].concat();
        expect.sort_by_key(|r| (r.key, r.tag)); // == stable merge here
        assert_eq!(keyed(&out), keyed(&expect), "quantum={quantum}");
        check_stable_merge(&out, B_TAG_BASE).expect("adaptive merge not stable");
    }

    // The token-driven kernel tests run inside an executor scope, so
    // they are native-only: under Miri the persistent global worker
    // fleet would outlive the test harness (Miri rejects an exit with
    // live threads). Miri covers the pure co-rank math above and the
    // steal-flag protocol itself via `exec::deque`.
    #[test]
    #[cfg(not(miri))]
    fn never_token_is_pure_sequential_quanta() {
        let mut rng = Rng::new(42);
        for &q in &[1usize, 2, 7, 64, 1 << 20] {
            let n = 500 + rng.index(500);
            let m = 500 + rng.index(500);
            let mut ka: Vec<i64> = (0..n).map(|_| rng.range(0, 40)).collect();
            let mut kb: Vec<i64> = (0..m).map(|_| rng.range(0, 40)).collect();
            ka.sort();
            kb.sort();
            check_adaptive(&ka, &kb, q, &StealToken::never());
        }
    }

    #[test]
    #[cfg(not(miri))]
    fn always_token_splits_and_stays_stable() {
        let mut rng = Rng::new(43);
        for &q in &[3usize, 32, 200] {
            let n = 800 + rng.index(400);
            let m = 800 + rng.index(400);
            let mut ka: Vec<i64> = (0..n).map(|_| rng.range(0, 25)).collect();
            let mut kb: Vec<i64> = (0..m).map(|_| rng.range(0, 25)).collect();
            ka.sort();
            kb.sort();
            check_adaptive(&ka, &kb, q, &StealToken::always());
        }
    }

    #[test]
    #[cfg(not(miri))]
    fn all_distributions_stay_stable_under_both_tokens() {
        for dist in Dist::all() {
            let ka = sorted_keys(dist, 700, 7);
            let kb = sorted_keys(dist, 650, 8);
            check_adaptive(&ka, &kb, 48, &StealToken::never());
            check_adaptive(&ka, &kb, 48, &StealToken::always());
        }
    }

    #[test]
    #[cfg(not(miri))]
    fn nearly_disjoint_and_dup_heavy_shapes() {
        // Nearly disjoint: a in [0, 1000), b in [990, 1990) — one
        // quantum of interleaving, then pure block copies.
        let ka: Vec<i64> = (0..1000).collect();
        let kb: Vec<i64> = (990..1990).collect();
        check_adaptive(&ka, &kb, 64, &StealToken::never());
        check_adaptive(&ka, &kb, 64, &StealToken::always());
        check_adaptive(&kb, &ka, 64, &StealToken::always());
        // Dup-heavy: long constant runs on both sides.
        let ka: Vec<i64> = (0..1200).map(|i| i / 400).collect();
        let kb: Vec<i64> = (0..900).map(|i| i / 300).collect();
        check_adaptive(&ka, &kb, 32, &StealToken::never());
        check_adaptive(&ka, &kb, 32, &StealToken::always());
    }

    #[test]
    #[cfg(not(miri))]
    fn public_entry_matches_fixed_partition() {
        // Big enough to clear any calibrated crossover (cutoff clamps
        // at 2^18 total elements).
        let mut rng = Rng::new(44);
        let mut ka: Vec<i64> = (0..160_000).map(|_| rng.range(0, 5_000)).collect();
        let mut kb: Vec<i64> = (0..140_000).map(|_| rng.range(0, 5_000)).collect();
        ka.sort();
        kb.sort();
        let a = tag_a(&ka);
        let b = tag_b(&kb);
        let mut got = vec![Record::new(0, 0); a.len() + b.len()];
        adaptive_merge(&a, &b, &mut got, 8);
        let mut want = vec![Record::new(0, 0); a.len() + b.len()];
        super::super::merge::parallel_merge(&a, &b, &mut want, 8);
        assert_eq!(keyed(&got), keyed(&want));
        check_stable_merge(&got, B_TAG_BASE).expect("adaptive merge not stable");
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in [MergeStrategy::Fixed, MergeStrategy::Adaptive] {
            assert_eq!(MergeStrategy::parse(s.name()), Some(s));
            assert_eq!(format!("{s}"), s.name());
        }
        assert_eq!(MergeStrategy::parse("bogus"), None);
        assert_eq!(MergeStrategy::default(), MergeStrategy::Fixed);
    }

    #[test]
    fn merge_with_strategy_dispatches_both_ways() {
        // Under Miri the sizes stay below the smallest possible
        // parallel cutoff (4096), so both strategies resolve
        // sequentially without starting the executor fleet.
        let (n, m) = if cfg!(miri) { (300, 250) } else { (3000, 2500) };
        let mut ka: Vec<i64> = (0..n).map(|i| (i * 7) % 500).collect();
        ka.sort();
        let mut kb: Vec<i64> = (0..m).map(|i| (i * 11) % 500).collect();
        kb.sort();
        let a = tag_a(&ka);
        let b = tag_b(&kb);
        let mut expect = [a.clone(), b.clone()].concat();
        expect.sort_by_key(|r| (r.key, r.tag));
        for strategy in [MergeStrategy::Fixed, MergeStrategy::Adaptive] {
            let mut out = vec![Record::new(0, 0); a.len() + b.len()];
            merge_with_strategy(&a, &b, &mut out, 4, strategy);
            assert_eq!(keyed(&out), keyed(&expect), "strategy={strategy}");
        }
    }
}

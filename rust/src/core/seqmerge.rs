//! Stable sequential merge kernels — the per-task workhorses (Step 3/4
//! bodies). The paper requires only that these are *stable*: within one
//! task, ties are won by the A side and original order is preserved.
//!
//! Three entry points:
//! - [`merge_into`]: the general two-slice stable merge.
//! - [`copy_into`]: the degenerate cases (a)/(e) — a straight copy.
//! - [`merge_by_into`]: comparator-general variant.
//!
//! The hot path is the galloping-free two-pointer loop; `merge_into`
//! falls back to `copy_nonoverlapping`-speed tails via the slice copy
//! intrinsics (`copy_from_slice`) once either side is exhausted.
//!
//! Before entering that loop, [`merge_into`] probes two triviality
//! shapes (ROADMAP item 2, after kvik's `manual_merge`) that turn the
//! whole call into `memcpy`-class block copies: non-interleaving
//! ranges (two O(1) endpoint compares) and a constant-valued block
//! (one endpoint compare + one rank search). Nearly-disjoint and
//! duplicate-heavy workloads hit these constantly; both the fixed
//! pre-partitioned path and the adaptive kernel
//! ([`crate::core::adaptive`]) route their per-task merges through
//! here, so both benefit. The tie rules mirror the two-pointer loop
//! exactly (A first), so the fast paths are stability-invisible.

use std::cmp::Ordering;

/// Stable merge of `a` and `b` into `out` (`out.len() == a.len() +
/// b.len()`). Ties are won by `a` — the paper's stability convention.
#[inline]
pub fn merge_into<T: Copy + Ord>(a: &[T], b: &[T], out: &mut [T]) {
    // Hard assert: the unchecked hot loop below relies on it.
    assert_eq!(out.len(), a.len() + b.len());
    // Degenerate tasks (cases a/e) — straight copies.
    if b.is_empty() {
        out.copy_from_slice(a);
        return;
    }
    if a.is_empty() {
        out.copy_from_slice(b);
        return;
    }
    let (n, m) = (a.len(), b.len());
    // Triviality fast path 1: the ranges do not interleave — the merge
    // is two block copies. `<=` on the A-before-B side and strict `<`
    // on the B-before-A side reproduce the loop's tie rule: an A
    // element equal to a B element must land first.
    if a[n - 1] <= b[0] {
        out[..n].copy_from_slice(a);
        out[n..].copy_from_slice(b);
        return;
    }
    if b[m - 1] < a[0] {
        out[..m].copy_from_slice(b);
        out[m..].copy_from_slice(a);
        return;
    }
    // Triviality fast path 2: a constant-valued block placed whole by
    // one rank search. `rank_low` puts the A block before B's equal
    // keys; `rank_high` puts A's equal keys before the B block — the
    // same asymmetry as `core::ranks` (stability for free).
    if a[0] == a[n - 1] {
        let j = super::ranks::rank_low(&a[0], b);
        out[..j].copy_from_slice(&b[..j]);
        out[j..j + n].copy_from_slice(a);
        out[j + n..].copy_from_slice(&b[j..]);
        return;
    }
    if b[0] == b[m - 1] {
        let i = super::ranks::rank_high(&b[0], a);
        out[..i].copy_from_slice(&a[..i]);
        out[i..i + m].copy_from_slice(b);
        out[i + m..].copy_from_slice(&a[i..]);
        return;
    }
    let mut ai = 0;
    let mut bi = 0;
    let mut oi = 0;
    // Two-pointer loop; `<=` keeps A first on ties (stability).
    // SAFETY: ai < a.len(), bi < b.len() are the loop guards, and
    // oi = ai + bi < out.len() by the length precondition (asserted
    // above in debug builds and by every caller's construction).
    // §Perf iteration 2: eliding the per-element bounds checks is
    // worth ~8% on the 2M-merge microbench.
    unsafe {
        while ai < a.len() && bi < b.len() {
            let av = *a.get_unchecked(ai);
            let bv = *b.get_unchecked(bi);
            let take_a = av <= bv;
            *out.get_unchecked_mut(oi) = if take_a { av } else { bv };
            ai += take_a as usize;
            bi += !take_a as usize;
            oi += 1;
        }
    }
    if ai < a.len() {
        out[oi..].copy_from_slice(&a[ai..]);
    } else {
        out[oi..].copy_from_slice(&b[bi..]);
    }
}

/// Copy-only kernel for the degenerate cases.
#[inline]
pub fn copy_into<T: Copy>(src: &[T], out: &mut [T]) {
    debug_assert_eq!(out.len(), src.len());
    out.copy_from_slice(src);
}

/// Comparator-general stable merge (ties to `a`).
pub fn merge_by_into<T: Copy, F: FnMut(&T, &T) -> Ordering>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    mut cmp: F,
) {
    debug_assert_eq!(out.len(), a.len() + b.len());
    let mut ai = 0;
    let mut bi = 0;
    let mut oi = 0;
    while ai < a.len() && bi < b.len() {
        if cmp(&a[ai], &b[bi]) != Ordering::Greater {
            out[oi] = a[ai];
            ai += 1;
        } else {
            out[oi] = b[bi];
            bi += 1;
        }
        oi += 1;
    }
    if ai < a.len() {
        out[oi..].copy_from_slice(&a[ai..]);
    } else {
        out[oi..].copy_from_slice(&b[bi..]);
    }
}

/// Bottom-up stable sequential merge sort using a caller-provided
/// scratch buffer of the same length (ping-pong). This is the
/// "sequential sort in parallel" leaf of the §3 merge sort and the
/// sequential baseline's building block.
pub fn merge_sort<T: Copy + Ord>(data: &mut [T], scratch: &mut [T]) {
    let n = data.len();
    debug_assert!(scratch.len() >= n);
    if n <= 1 {
        return;
    }
    // Insertion-sort small runs first — classic cutoff.
    const RUN: usize = 32;
    let mut start = 0;
    while start < n {
        let end = (start + RUN).min(n);
        insertion_sort(&mut data[start..end]);
        start = end;
    }
    // Bottom-up rounds, ping-ponging between data and scratch.
    let scratch = &mut scratch[..n];
    let mut width = RUN;
    let mut in_data = true; // current valid runs live in `data`
    while width < n {
        {
            let (src, dst): (&[T], &mut [T]) = if in_data {
                (&*data, scratch)
            } else {
                (&*scratch, data)
            };
            let mut lo = 0;
            while lo < n {
                let mid = (lo + width).min(n);
                let hi = (lo + 2 * width).min(n);
                merge_into(&src[lo..mid], &src[mid..hi], &mut dst[lo..hi]);
                lo = hi;
            }
        }
        in_data = !in_data;
        width *= 2;
    }
    if !in_data {
        data.copy_from_slice(scratch);
    }
}

/// Stable insertion sort (the leaf cutoff).
///
/// SAFETY of the unchecked accesses: `j` starts at `i < len` and only
/// decreases while `> 0`; all indices are in `[0, i]`.
#[inline]
pub fn insertion_sort<T: Copy + Ord>(xs: &mut [T]) {
    for i in 1..xs.len() {
        unsafe {
            let v = *xs.get_unchecked(i);
            let mut j = i;
            while j > 0 && *xs.get_unchecked(j - 1) > v {
                *xs.get_unchecked_mut(j) = *xs.get_unchecked(j - 1);
                j -= 1;
            }
            *xs.get_unchecked_mut(j) = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::record::Record;
    use crate::util::Rng;

    #[test]
    fn merges_basic() {
        let mut out = [0i64; 6];
        merge_into(&[1, 3, 5], &[2, 4, 6], &mut out);
        assert_eq!(out, [1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn ties_go_to_a() {
        let a = [Record::new(5, 0), Record::new(5, 1)];
        let b = [Record::new(5, 100), Record::new(5, 101)];
        let mut out = [Record::new(0, 0); 4];
        merge_into(&a, &b, &mut out);
        let tags: Vec<u64> = out.iter().map(|r| r.tag).collect();
        assert_eq!(tags, vec![0, 1, 100, 101]);
    }

    #[test]
    fn empty_sides() {
        let mut out = [0i64; 3];
        merge_into(&[], &[1, 2, 3], &mut out);
        assert_eq!(out, [1, 2, 3]);
        merge_into(&[1, 2, 3], &[], &mut out);
        assert_eq!(out, [1, 2, 3]);
        let mut empty: [i64; 0] = [];
        merge_into(&[], &[], &mut empty);
    }

    #[test]
    fn matches_std_sort_result() {
        let mut rng = Rng::new(17);
        for _ in 0..50 {
            let n = rng.index(200);
            let m = rng.index(200);
            let mut a: Vec<i64> = (0..n).map(|_| rng.range(0, 50)).collect();
            let mut b: Vec<i64> = (0..m).map(|_| rng.range(0, 50)).collect();
            a.sort();
            b.sort();
            let mut out = vec![0i64; n + m];
            merge_into(&a, &b, &mut out);
            let mut expect = [a.clone(), b.clone()].concat();
            expect.sort();
            assert_eq!(out, expect);
        }
    }

    #[test]
    fn merge_sort_matches_std_stable_sort() {
        let mut rng = Rng::new(23);
        for _ in 0..30 {
            let n = rng.index(600);
            let mut data: Vec<Record> = (0..n)
                .map(|i| Record::new(rng.range(0, 40), i as u64))
                .collect();
            let mut expect = data.clone();
            expect.sort_by_key(|r| r.key); // std stable sort
            let mut scratch = vec![Record::new(0, 0); n];
            merge_sort(&mut data, &mut scratch);
            assert_eq!(
                data.iter().map(|r| (r.key, r.tag)).collect::<Vec<_>>(),
                expect.iter().map(|r| (r.key, r.tag)).collect::<Vec<_>>(),
                "stability violated at n={n}"
            );
        }
    }

    #[test]
    fn insertion_sort_stable() {
        let mut xs = vec![
            Record::new(2, 0),
            Record::new(1, 1),
            Record::new(2, 2),
            Record::new(1, 3),
        ];
        insertion_sort(&mut xs);
        let pairs: Vec<(i64, u64)> = xs.iter().map(|r| (r.key, r.tag)).collect();
        assert_eq!(pairs, vec![(1, 1), (1, 3), (2, 0), (2, 2)]);
    }

    #[test]
    fn merge_by_reverse_order() {
        let mut out = [0i64; 5];
        merge_by_into(&[5, 3, 1], &[4, 2], &mut out, |x, y| y.cmp(x));
        assert_eq!(out, [5, 4, 3, 2, 1]);
    }

    /// ISSUE 9 satellite: the triviality fast paths are
    /// stability-invisible across EVERY workload distribution — the
    /// merged records match std's stable sort of the concatenation,
    /// record for record, and the A-before-B tie oracle holds.
    #[test]
    fn fast_paths_stable_across_all_distributions() {
        use crate::workload::{check_stable_merge, sorted_keys, tag_a, tag_b, Dist, B_TAG_BASE};
        let sizes: [(usize, usize, u64); 3] = [(300, 300, 11), (257, 64, 12), (3, 500, 13)];
        for dist in Dist::all() {
            for (n, m, seed) in sizes {
                let a = tag_a(&sorted_keys(dist, n, seed));
                let b = tag_b(&sorted_keys(dist, m, seed.wrapping_add(100)));
                let mut out = vec![Record::new(0, 0); n + m];
                merge_into(&a, &b, &mut out);
                let mut expect = [a, b].concat();
                expect.sort_by_key(|r| r.key); // std sort is stable
                assert_eq!(
                    out.iter().map(|r| (r.key, r.tag)).collect::<Vec<_>>(),
                    expect.iter().map(|r| (r.key, r.tag)).collect::<Vec<_>>(),
                    "{} n={n} m={m}: fast path broke stability",
                    dist.name()
                );
                check_stable_merge(&out, B_TAG_BASE)
                    .unwrap_or_else(|e| panic!("{} n={n} m={m}: {e}", dist.name()));
            }
        }
    }

    /// Each triviality shape individually: disjoint-below,
    /// disjoint-above, boundary ties, constant-A, constant-B, both
    /// constant and equal — the shapes the fast paths claim.
    #[test]
    fn fast_path_shapes_exact() {
        use crate::workload::{check_stable_merge, tag_a, tag_b, B_TAG_BASE};
        let shapes: Vec<(Vec<i64>, Vec<i64>)> = vec![
            ((0..100).collect(), (100..180).collect()), // a entirely below b
            ((0..100).collect(), (99..180).collect()),  // tie at the boundary: A copy first
            ((50..150).collect(), (0..50).collect()),   // b strictly below a
            ((50..150).collect(), (0..51).collect()),   // equal at the boundary: not trivial
            (vec![7; 64], (0..40).collect()),           // constant A straddling b
            ((0..40).collect(), vec![7; 64]),           // constant B straddling a
            (vec![7; 64], vec![7; 16]),                 // both constant, same key
            (vec![7; 64], vec![9; 16]),                 // both constant, disjoint
        ];
        for (ka, kb) in shapes {
            let (n, m) = (ka.len(), kb.len());
            let a = tag_a(&ka);
            let b = tag_b(&kb);
            let mut out = vec![Record::new(0, 0); n + m];
            merge_into(&a, &b, &mut out);
            let mut expect = [a, b].concat();
            expect.sort_by_key(|r| r.key);
            assert_eq!(
                out.iter().map(|r| (r.key, r.tag)).collect::<Vec<_>>(),
                expect.iter().map(|r| (r.key, r.tag)).collect::<Vec<_>>(),
                "shape a={ka:?}.. b={kb:?}.."
            );
            check_stable_merge(&out, B_TAG_BASE).expect("tie oracle");
        }
    }
}

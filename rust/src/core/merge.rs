//! The paper's contribution: simplified, stable parallel merge
//! (Theorem 1) — `O(n/p + log n)` operations on `p` processing
//! elements, constant extra space, a single synchronization point.
//!
//! Phases (paper Steps 1–4):
//! 1. **Search phase** (parallel): the `2(p+1)` cross ranks, each an
//!    independent `O(log)` binary search.
//! 2. *the* synchronization point.
//! 3. **Merge phase** (parallel): each PE classifies its case locally
//!    (O(1), `cases.rs`) and runs a stable sequential merge/copy into
//!    its disjoint `C` range.
//!
//! The disjointness of output ranges (Observation 1 / `validate_tasks`)
//! is what lets the merge phase write `C` from `p` threads without any
//! locking: we materialize the disjointness for the borrow checker by
//! carving `out` with `split_at_mut` along task boundaries.

use super::cases::{MergeTask, Partition};
use super::seqmerge::merge_into;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};

/// Execute the 2(p+1) binary searches of Steps 1–2, distributing them
/// over `threads` OS threads. Returns the completed [`Partition`].
///
/// For small `p` the searches are cheaper than thread spawn; the driver
/// inlines them sequentially below a crossover (measured in §Perf).
pub fn partition_parallel<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    p: usize,
    threads: usize,
) -> Partition {
    // Sequential crossover: 2(p+1) searches of <= log2(n)+log2(m) total
    // comparisons are cheaper than a thread spawn below ~64 searches.
    if threads <= 1 || p <= 64 {
        return Partition::compute(a, b, p);
    }
    let pa = super::blocks::Blocks::new(a.len(), p);
    let pb = super::blocks::Blocks::new(b.len(), p);
    let x = pa.starts();
    let y = pb.starts();
    let mut xbar = vec![0usize; p + 1];
    let mut ybar = vec![0usize; p + 1];
    let next = AtomicUsize::new(0);
    let chunk = crate::util::div_ceil(p + 1, threads * 4).max(8);
    // Carve the output arrays into fixed chunks; a shared atomic
    // cursor hands chunks to threads (cheap dynamic load balance).
    let mut slots: Vec<(usize, &mut [usize], &mut [usize])> = Vec::new();
    {
        let mut xb_rest: &mut [usize] = &mut xbar;
        let mut yb_rest: &mut [usize] = &mut ybar;
        let mut off = 0usize;
        while off <= p {
            let take = chunk.min(p + 1 - off);
            let (xh, xt) = xb_rest.split_at_mut(take);
            let (yh, yt) = yb_rest.split_at_mut(take);
            xb_rest = xt;
            yb_rest = yt;
            slots.push((off, xh, yh));
            off += take;
        }
    }
    let slots = std::sync::Mutex::new(slots.into_iter().map(Some).collect::<Vec<_>>());
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for _ in 0..threads {
            let next = &next;
            let slots = &slots;
            let x = &x;
            let y = &y;
            handles.push(s.spawn(move || loop {
                let idx = next.fetch_add(1, AtomicOrdering::Relaxed);
                let slot = {
                    let mut guard = slots.lock().unwrap();
                    if idx >= guard.len() {
                        return;
                    }
                    guard[idx].take()
                };
                let Some((off, xh, yh)) = slot else { return };
                for (k, slot) in xh.iter_mut().enumerate() {
                    let xi = x[off + k];
                    *slot = if xi < a.len() {
                        super::ranks::rank_low(&a[xi], b)
                    } else {
                        b.len()
                    };
                }
                for (k, slot) in yh.iter_mut().enumerate() {
                    let yj = y[off + k];
                    *slot = if yj < b.len() {
                        super::ranks::rank_high(&b[yj], a)
                    } else {
                        a.len()
                    };
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    drop(slots);
    Partition { pa, pb, x, y, xbar, ybar }
}

/// Carve `out` into the per-task disjoint output slices.
///
/// Tasks must tile `out` exactly (guaranteed by the classifier,
/// re-checked here in debug builds). Tasks are returned sorted by
/// output offset, paired with their `&mut` slice.
pub fn carve_output<'t, 'o, T>(
    tasks: &'t [MergeTask],
    out: &'o mut [T],
) -> Vec<(&'t MergeTask, &'o mut [T])> {
    let mut order: Vec<&MergeTask> = tasks.iter().collect();
    order.sort_by_key(|t| t.c_off);
    let mut pairs = Vec::with_capacity(order.len());
    let mut rest = out;
    let mut cursor = 0usize;
    for t in order {
        debug_assert_eq!(t.c_off, cursor, "tasks must tile the output");
        let (slice, tail) = rest.split_at_mut(t.len());
        rest = tail;
        cursor += t.len();
        pairs.push((t, slice));
    }
    debug_assert!(rest.is_empty(), "tasks must cover the whole output");
    pairs
}

/// Execute a set of merge tasks sequentially (used by tests, the PRAM
/// driver, and as the `threads == 1` fast path).
pub fn run_tasks_seq<T: Copy + Ord>(a: &[T], b: &[T], out: &mut [T], tasks: &[MergeTask]) {
    for (t, slice) in carve_output(tasks, out) {
        merge_into(&a[t.a.clone()], &b[t.b.clone()], slice);
    }
}

/// Execute merge tasks across `threads` OS threads. Each thread takes a
/// contiguous group of tasks (every task is already `O(n/p)`, so simple
/// round-chunking is within 2x of optimal — the paper's own balance
/// bound).
pub fn run_tasks_parallel<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    tasks: &[MergeTask],
    threads: usize,
) {
    if threads <= 1 || tasks.len() <= 1 {
        run_tasks_seq(a, b, out, tasks);
        return;
    }
    let pairs = carve_output(tasks, out);
    let groups = chunk_tasks(pairs, threads);
    std::thread::scope(|s| {
        for group in groups {
            s.spawn(move || {
                for (t, slice) in group {
                    merge_into(&a[t.a.clone()], &b[t.b.clone()], slice);
                }
            });
        }
    });
}

/// Split task/slice pairs into at most `k` contiguous groups with
/// near-equal total element counts (linear greedy walk).
pub fn chunk_tasks<'t, 'o, T>(
    pairs: Vec<(&'t MergeTask, &'o mut [T])>,
    k: usize,
) -> Vec<Vec<(&'t MergeTask, &'o mut [T])>> {
    let total: usize = pairs.iter().map(|(t, _)| t.len()).sum();
    let target = crate::util::div_ceil(total.max(1), k);
    let mut groups = Vec::with_capacity(k);
    let mut cur = Vec::new();
    let mut acc = 0usize;
    for (t, s) in pairs {
        let l = t.len();
        if acc + l > target && !cur.is_empty() && groups.len() + 1 < k {
            groups.push(std::mem::take(&mut cur));
            acc = 0;
        }
        acc += l;
        cur.push((t, s));
    }
    if !cur.is_empty() {
        groups.push(cur);
    }
    groups
}

/// **The headline API**: stable parallel merge of sorted `a` and `b`
/// into `out`, using `p` logical processing elements executed on
/// `p.min(available)` OS threads. Implements the paper end to end.
///
/// Stability: for equal elements, everything from `a` precedes
/// everything from `b`, and each input's internal order is preserved.
///
/// # Panics
/// If `out.len() != a.len() + b.len()` or `p == 0`.
pub fn parallel_merge<T: Copy + Ord + Send + Sync>(a: &[T], b: &[T], out: &mut [T], p: usize) {
    assert_eq!(out.len(), a.len() + b.len(), "output length mismatch");
    assert!(p > 0, "p must be positive");
    // The paper assumes m <= n WLOG; the classifier is written for
    // arbitrary n, m, so no swap is needed — but degenerate inputs
    // short-circuit.
    if a.is_empty() {
        out.copy_from_slice(b);
        return;
    }
    if b.is_empty() {
        out.copy_from_slice(a);
        return;
    }
    if p == 1 {
        merge_into(a, b, out);
        return;
    }
    let part = partition_parallel(a, b, p, p);
    let tasks = part.tasks();
    debug_assert!(part.validate_tasks(&tasks).is_ok());
    run_tasks_parallel(a, b, out, &tasks, p);
}

/// Like [`parallel_merge`] but returns the partition + per-case task
/// census for diagnostics (used by the balance bench, E9).
pub fn parallel_merge_instrumented<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    p: usize,
) -> (Partition, Vec<MergeTask>) {
    assert_eq!(out.len(), a.len() + b.len());
    let part = partition_parallel(a, b, p, p);
    let tasks = part.tasks();
    run_tasks_parallel(a, b, out, &tasks, p);
    (part, tasks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::record::Record;
    use crate::util::Rng;

    fn check_merge(a: &[i64], b: &[i64], p: usize) {
        let mut out = vec![0i64; a.len() + b.len()];
        parallel_merge(a, b, &mut out, p);
        let mut expect = [a, b].concat();
        expect.sort();
        assert_eq!(out, expect, "a={a:?} b={b:?} p={p}");
    }

    #[test]
    fn figure1_end_to_end() {
        let a = vec![0, 0, 1, 1, 1, 2, 2, 2, 4, 5, 5, 5, 5, 5, 6, 6, 7, 7];
        let b = vec![1, 1, 3, 3, 3, 3, 4, 5, 6, 6, 6, 6, 7, 7, 7];
        check_merge(&a, &b, 5);
    }

    #[test]
    fn random_sweep() {
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let n = rng.index(300);
            let m = rng.index(300);
            let p = 1 + rng.index(16);
            let mut a: Vec<i64> = (0..n).map(|_| rng.range(0, 60)).collect();
            let mut b: Vec<i64> = (0..m).map(|_| rng.range(0, 60)).collect();
            a.sort();
            b.sort();
            check_merge(&a, &b, p);
        }
    }

    #[test]
    fn stability_tags_in_order() {
        let mut rng = Rng::new(5);
        for _ in 0..60 {
            let n = rng.index(200) + 1;
            let m = rng.index(200) + 1;
            let p = 1 + rng.index(12);
            let mut ka: Vec<i64> = (0..n).map(|_| rng.range(0, 8)).collect();
            let mut kb: Vec<i64> = (0..m).map(|_| rng.range(0, 8)).collect();
            ka.sort();
            kb.sort();
            let a: Vec<Record> =
                ka.iter().enumerate().map(|(i, &k)| Record::new(k, i as u64)).collect();
            let b: Vec<Record> = kb
                .iter()
                .enumerate()
                .map(|(i, &k)| Record::new(k, 1_000_000 + i as u64))
                .collect();
            let mut out = vec![Record::new(0, 0); n + m];
            parallel_merge(&a, &b, &mut out, p);
            crate::workload::stability::assert_stable_merge(&out, 1_000_000);
        }
    }

    #[test]
    fn p_exceeds_lengths() {
        check_merge(&[1, 5, 9], &[2, 3], 16);
        check_merge(&[4], &[4], 8);
    }

    #[test]
    fn identical_arrays() {
        let a: Vec<i64> = (0..100).map(|i| i / 3).collect();
        check_merge(&a.clone(), &a, 7);
    }

    #[test]
    fn one_sided() {
        check_merge(&[1, 2, 3], &[], 4);
        check_merge(&[], &[1, 2, 3], 4);
    }

    #[test]
    fn large_p_equals_cpus() {
        let mut rng = Rng::new(77);
        let mut a: Vec<i64> = (0..50_000).map(|_| rng.range(0, 10_000)).collect();
        let mut b: Vec<i64> = (0..30_000).map(|_| rng.range(0, 10_000)).collect();
        a.sort();
        b.sort();
        check_merge(&a, &b, crate::util::num_cpus());
    }

    #[test]
    fn partition_parallel_matches_sequential() {
        let mut rng = Rng::new(31);
        let mut a: Vec<i64> = (0..5000).map(|_| rng.range(0, 500)).collect();
        let mut b: Vec<i64> = (0..4000).map(|_| rng.range(0, 500)).collect();
        a.sort();
        b.sort();
        for p in [1, 2, 65, 128, 301] {
            let par = partition_parallel(&a, &b, p, 8);
            let seq = Partition::compute(&a, &b, p);
            assert_eq!(par.xbar, seq.xbar, "p={p}");
            assert_eq!(par.ybar, seq.ybar, "p={p}");
        }
    }
}

//! The paper's contribution: simplified, stable parallel merge
//! (Theorem 1) — `O(n/p + log n)` operations on `p` processing
//! elements, constant extra space, a single synchronization point.
//!
//! Phases (paper Steps 1–4):
//! 1. **Search phase** (parallel): the `2(p+1)` cross ranks, each an
//!    independent `O(log)` binary search.
//! 2. *the* synchronization point.
//! 3. **Merge phase** (parallel): each PE classifies its case locally
//!    (O(1), `cases.rs`) and runs a stable sequential merge/copy into
//!    its disjoint `C` range.
//!
//! The disjointness of output ranges (Observation 1 / `validate_tasks`)
//! is what lets the merge phase write `C` from `p` threads without any
//! locking: we materialize the disjointness for the borrow checker by
//! carving `out` with `split_at_mut` along task boundaries — and we
//! validate the tiling *unconditionally* ([`carve_output`] returns
//! `Err` instead of silently mis-slicing in release builds).
//!
//! Both parallel phases execute on the persistent [`crate::exec`]
//! executor (no per-call thread spawn/join); the sequential crossovers
//! come from the measured [`crate::exec::tunables_for`] instead of
//! hardcoded constants.

use super::cases::{MergeTask, Partition};
use super::seqmerge::merge_into;
use std::fmt;

/// Error returned when a task list does not exactly tile the output
/// buffer (a broken classifier invariant — previously only caught by a
/// `debug_assert!`, i.e. silent corruption in release builds).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TilingError {
    detail: String,
}

impl TilingError {
    fn new(detail: String) -> TilingError {
        TilingError { detail }
    }
}

impl fmt::Display for TilingError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "merge tasks do not tile the output: {}", self.detail)
    }
}

impl std::error::Error for TilingError {}

/// Execute the 2(p+1) binary searches of Steps 1–2, distributing them
/// over the persistent executor. Returns the completed [`Partition`].
///
/// For small `p` the searches are cheaper than a dispatch round-trip;
/// the crossover is the measured `exec::tunables()` value rather than a
/// hardcoded guess.
pub fn partition_parallel<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    p: usize,
    threads: usize,
) -> Partition {
    partition_parallel_with_cutoff(
        a,
        b,
        p,
        threads,
        crate::exec::tunables_for::<T>().parallel_search_cutoff,
    )
}

/// [`partition_parallel`] with an explicit sequential crossover —
/// exposed so tests and benches can force either path.
pub fn partition_parallel_with_cutoff<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    p: usize,
    threads: usize,
    cutoff: usize,
) -> Partition {
    if threads <= 1 || p < cutoff {
        return Partition::compute(a, b, p);
    }
    let pa = super::blocks::Blocks::new(a.len(), p);
    let pb = super::blocks::Blocks::new(b.len(), p);
    let x = pa.starts();
    let y = pb.starts();
    let mut xbar = vec![0usize; p + 1];
    let mut ybar = vec![0usize; p + 1];
    let exec = crate::exec::global();
    // Fixed chunks over the 0..=p search indices; idle workers steal,
    // which replaces the old atomic-cursor-plus-Mutex double dispatch.
    let chunk = crate::util::div_ceil(p + 1, threads.min(exec.size()) * 4).max(8);
    {
        let x_ref = &x;
        let y_ref = &y;
        exec.scope(|s| {
            let mut xb_rest: &mut [usize] = &mut xbar;
            let mut yb_rest: &mut [usize] = &mut ybar;
            let mut off = 0usize;
            while off <= p {
                let take = chunk.min(p + 1 - off);
                let (xh, xt) = xb_rest.split_at_mut(take);
                let (yh, yt) = yb_rest.split_at_mut(take);
                xb_rest = xt;
                yb_rest = yt;
                s.spawn(move || {
                    for (k, slot) in xh.iter_mut().enumerate() {
                        let xi = x_ref[off + k];
                        *slot = if xi < a.len() {
                            super::ranks::rank_low(&a[xi], b)
                        } else {
                            b.len()
                        };
                    }
                    for (k, slot) in yh.iter_mut().enumerate() {
                        let yj = y_ref[off + k];
                        *slot = if yj < b.len() {
                            super::ranks::rank_high(&b[yj], a)
                        } else {
                            a.len()
                        };
                    }
                });
                off += take;
            }
        });
    }
    Partition { pa, pb, x, y, xbar, ybar }
}

/// Carve `out` into the per-task disjoint output slices.
///
/// Tasks must tile `out` exactly (guaranteed by the classifier);
/// violations are detected **unconditionally** and reported as
/// [`TilingError`] instead of corrupting the output. Tasks are
/// returned sorted by output offset, paired with their `&mut` slice.
pub fn carve_output<'t, 'o, T>(
    tasks: &'t [MergeTask],
    out: &'o mut [T],
) -> Result<Vec<(&'t MergeTask, &'o mut [T])>, TilingError> {
    let mut order: Vec<&MergeTask> = tasks.iter().collect();
    order.sort_by_key(|t| t.c_off);
    let mut pairs = Vec::with_capacity(order.len());
    let mut rest = out;
    let mut cursor = 0usize;
    for t in order {
        if t.c_off != cursor {
            return Err(TilingError::new(format!(
                "gap/overlap at C[{cursor}]: next task starts at {} ({t:?})",
                t.c_off
            )));
        }
        if t.len() > rest.len() {
            return Err(TilingError::new(format!(
                "task overruns the output ({} elements left, task {t:?})",
                rest.len()
            )));
        }
        let (slice, tail) = rest.split_at_mut(t.len());
        rest = tail;
        cursor += t.len();
        pairs.push((t, slice));
    }
    if !rest.is_empty() {
        return Err(TilingError::new(format!(
            "tasks cover only C[..{cursor}] of {} output slots",
            cursor + rest.len()
        )));
    }
    Ok(pairs)
}

/// Execute a set of merge tasks sequentially (used by tests, the PRAM
/// driver, and as the small-input fast path).
pub fn run_tasks_seq<T: Copy + Ord>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    tasks: &[MergeTask],
) -> Result<(), TilingError> {
    for (t, slice) in carve_output(tasks, out)? {
        merge_into(&a[t.a.clone()], &b[t.b.clone()], slice);
    }
    Ok(())
}

/// Execute merge tasks on the persistent executor. Each spawned task
/// takes a contiguous group of merge tasks (every task is already
/// `O(n/p)`, so chunking to near-equal element counts is within 2x of
/// optimal — the paper's own balance bound). The group count comes
/// from [`crate::exec::chunk_groups_for`] (keyed by `T`'s size class):
/// one group per lane by default, or finer groups when the executor's
/// windowed steal telemetry says cheap Chase–Lev steals will absorb
/// the skew dynamically.
pub fn run_tasks_parallel<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    tasks: &[MergeTask],
    threads: usize,
) -> Result<(), TilingError> {
    if threads <= 1
        || tasks.len() <= 1
        || out.len() < crate::exec::tunables_for::<T>().parallel_merge_cutoff
    {
        return run_tasks_seq(a, b, out, tasks);
    }
    let groups_wanted = crate::exec::chunk_groups_for::<T>(out.len(), threads);
    run_tasks_grouped(a, b, out, tasks, groups_wanted)
}

/// Parallel task execution with a caller-decided group budget — used by
/// [`parallel_merge`] to thread the SAME lane count it partitioned with,
/// so partition granularity and execution grouping cannot drift apart
/// (and the telemetry sweep runs once per phase). Callers are expected
/// to have applied the sequential crossover already.
fn run_tasks_grouped<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    tasks: &[MergeTask],
    groups_wanted: usize,
) -> Result<(), TilingError> {
    let pairs = carve_output(tasks, out)?;
    let groups = chunk_tasks(pairs, groups_wanted.max(1));
    crate::exec::global().scope(|s| {
        for group in groups {
            s.spawn(move || {
                for (t, slice) in group {
                    merge_into(&a[t.a.clone()], &b[t.b.clone()], slice);
                }
            });
        }
    });
    Ok(())
}

/// Split task/slice pairs into at most `k` contiguous groups with
/// near-equal total element counts.
///
/// The target is recomputed from the *remaining* elements and groups
/// each time a group closes, so an early oversized task (cases (c)/(d)
/// can produce up to `2⌈n/p⌉` elements) shrinks only its own group's
/// budget instead of starving the tail groups — the old single fixed
/// target could emit far fewer than `k` groups and over-pack the last
/// one, idling threads.
pub fn chunk_tasks<'t, 'o, T>(
    pairs: Vec<(&'t MergeTask, &'o mut [T])>,
    k: usize,
) -> Vec<Vec<(&'t MergeTask, &'o mut [T])>> {
    let k = k.max(1);
    let mut remaining: usize = pairs.iter().map(|(t, _)| t.len()).sum();
    let mut groups: Vec<Vec<(&MergeTask, &mut [T])>> = Vec::with_capacity(k);
    let mut cur = Vec::new();
    let mut acc = 0usize;
    for (t, s) in pairs {
        let l = t.len();
        let groups_left = k - groups.len();
        // Fair share of everything not yet sealed into a closed group.
        let target = crate::util::div_ceil((acc + remaining).max(1), groups_left);
        if !cur.is_empty() && groups_left > 1 && acc + l > target {
            groups.push(std::mem::take(&mut cur));
            acc = 0;
        }
        acc += l;
        remaining -= l;
        cur.push((t, s));
    }
    if !cur.is_empty() {
        groups.push(cur);
    }
    groups
}

/// **The headline API**: stable parallel merge of sorted `a` and `b`
/// into `out`, using `p` logical processing elements executed on the
/// persistent executor's workers. Implements the paper end to end.
///
/// Stability: for equal elements, everything from `a` precedes
/// everything from `b`, and each input's internal order is preserved.
///
/// # Panics
/// If `out.len() != a.len() + b.len()` or `p == 0`, and on a broken
/// classifier invariant (non-tiling tasks — checked unconditionally).
pub fn parallel_merge<T: Copy + Ord + Send + Sync>(a: &[T], b: &[T], out: &mut [T], p: usize) {
    assert_eq!(out.len(), a.len() + b.len(), "output length mismatch");
    assert!(p > 0, "p must be positive");
    // The paper assumes m <= n WLOG; the classifier is written for
    // arbitrary n, m, so no swap is needed — but degenerate inputs
    // short-circuit.
    if a.is_empty() {
        out.copy_from_slice(b);
        return;
    }
    if b.is_empty() {
        out.copy_from_slice(a);
        return;
    }
    if p == 1 {
        merge_into(a, b, out);
        return;
    }
    // The sequential crossover is decided FIRST: below it, the whole
    // partition apparatus (binary searches, task classification, the
    // `chunk_groups_for` telemetry sweep) is pure overhead for a merge
    // that runs inline anyway, so we go straight to the sequential
    // kernel. Previously this path still partitioned into `p` lanes
    // and swept the task list sequentially — same output, wasted
    // `O(p log n)` searches per call.
    if out.len() < crate::exec::tunables_for::<T>().parallel_merge_cutoff {
        merge_into(a, b, out);
        return;
    }
    // Fine-granularity mode happens HERE, at the partition: grouping
    // (`chunk_tasks`) can only combine tasks, never split one, so a
    // skewed task list must be born finer. When the executor's steal
    // telemetry says cheap steals will rebalance the surplus (see
    // [`crate::exec::chunk_groups_for`]), partition into more lanes than
    // `p`; otherwise `lanes == p` and this is the paper's partition
    // exactly. Correctness is granularity-independent (the partition
    // is exact for every lane count).
    let lanes = crate::exec::chunk_groups_for::<T>(out.len(), p);
    let part = partition_parallel(a, b, lanes, p);
    let tasks = part.tasks();
    debug_assert!(part.validate_tasks(&tasks).is_ok());
    if tasks.len() <= 1 {
        run_tasks_seq(a, b, out, &tasks).expect("classifier produced non-tiling tasks");
    } else {
        // Same lane budget for partition and grouping — decided once.
        run_tasks_grouped(a, b, out, &tasks, lanes)
            .expect("classifier produced non-tiling tasks");
    }
}

/// Like [`parallel_merge`] but returns the partition + per-case task
/// census for diagnostics (used by the balance bench, E9). Unlike the
/// production path it always partitions with exactly `p` lanes — the
/// census is a view of the *paper's* structure at the requested `p`,
/// not of the steal-telemetry-driven over-partitioning.
pub fn parallel_merge_instrumented<T: Copy + Ord + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    p: usize,
) -> (Partition, Vec<MergeTask>) {
    assert_eq!(out.len(), a.len() + b.len());
    let part = partition_parallel(a, b, p, p);
    let tasks = part.tasks();
    run_tasks_parallel(a, b, out, &tasks, p).expect("classifier produced non-tiling tasks");
    (part, tasks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::record::Record;
    use crate::util::Rng;

    fn check_merge(a: &[i64], b: &[i64], p: usize) {
        let mut out = vec![0i64; a.len() + b.len()];
        parallel_merge(a, b, &mut out, p);
        let mut expect = [a, b].concat();
        expect.sort();
        assert_eq!(out, expect, "a={a:?} b={b:?} p={p}");
    }

    #[test]
    fn figure1_end_to_end() {
        let a = vec![0, 0, 1, 1, 1, 2, 2, 2, 4, 5, 5, 5, 5, 5, 6, 6, 7, 7];
        let b = vec![1, 1, 3, 3, 3, 3, 4, 5, 6, 6, 6, 6, 7, 7, 7];
        check_merge(&a, &b, 5);
    }

    #[test]
    fn random_sweep() {
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let n = rng.index(300);
            let m = rng.index(300);
            let p = 1 + rng.index(16);
            let mut a: Vec<i64> = (0..n).map(|_| rng.range(0, 60)).collect();
            let mut b: Vec<i64> = (0..m).map(|_| rng.range(0, 60)).collect();
            a.sort();
            b.sort();
            check_merge(&a, &b, p);
        }
    }

    #[test]
    fn stability_tags_in_order() {
        let mut rng = Rng::new(5);
        for _ in 0..60 {
            let n = rng.index(200) + 1;
            let m = rng.index(200) + 1;
            let p = 1 + rng.index(12);
            let mut ka: Vec<i64> = (0..n).map(|_| rng.range(0, 8)).collect();
            let mut kb: Vec<i64> = (0..m).map(|_| rng.range(0, 8)).collect();
            ka.sort();
            kb.sort();
            let a: Vec<Record> =
                ka.iter().enumerate().map(|(i, &k)| Record::new(k, i as u64)).collect();
            let b: Vec<Record> = kb
                .iter()
                .enumerate()
                .map(|(i, &k)| Record::new(k, 1_000_000 + i as u64))
                .collect();
            let mut out = vec![Record::new(0, 0); n + m];
            parallel_merge(&a, &b, &mut out, p);
            crate::workload::stability::assert_stable_merge(&out, 1_000_000);
        }
    }

    #[test]
    fn p_exceeds_lengths() {
        check_merge(&[1, 5, 9], &[2, 3], 16);
        check_merge(&[4], &[4], 8);
    }

    #[test]
    fn identical_arrays() {
        let a: Vec<i64> = (0..100).map(|i| i / 3).collect();
        check_merge(&a.clone(), &a, 7);
    }

    #[test]
    fn one_sided() {
        check_merge(&[1, 2, 3], &[], 4);
        check_merge(&[], &[1, 2, 3], 4);
    }

    #[test]
    fn large_p_equals_cpus() {
        let mut rng = Rng::new(77);
        let mut a: Vec<i64> = (0..50_000).map(|_| rng.range(0, 10_000)).collect();
        let mut b: Vec<i64> = (0..30_000).map(|_| rng.range(0, 10_000)).collect();
        a.sort();
        b.sort();
        check_merge(&a, &b, crate::util::num_cpus());
    }

    #[test]
    fn partition_parallel_matches_sequential() {
        let mut rng = Rng::new(31);
        let mut a: Vec<i64> = (0..5000).map(|_| rng.range(0, 500)).collect();
        let mut b: Vec<i64> = (0..4000).map(|_| rng.range(0, 500)).collect();
        a.sort();
        b.sort();
        for p in [1, 2, 65, 128, 301] {
            let par = partition_parallel(&a, &b, p, 8);
            let seq = Partition::compute(&a, &b, p);
            assert_eq!(par.xbar, seq.xbar, "p={p}");
            assert_eq!(par.ybar, seq.ybar, "p={p}");
        }
    }

    #[test]
    fn forced_parallel_partition_matches_sequential() {
        // cutoff 0 forces the executor path even for tiny p, including
        // threads > p and (p + 1) not divisible by the chunk size.
        let mut rng = Rng::new(33);
        let mut a: Vec<i64> = (0..3000).map(|_| rng.range(0, 300)).collect();
        let mut b: Vec<i64> = (0..2000).map(|_| rng.range(0, 300)).collect();
        a.sort();
        b.sort();
        for p in [1usize, 2, 3, 7, 9, 23, 64, 100] {
            let par = partition_parallel_with_cutoff(&a, &b, p, 16, 0);
            let seq = Partition::compute(&a, &b, p);
            assert_eq!(par.xbar, seq.xbar, "p={p}");
            assert_eq!(par.ybar, seq.ybar, "p={p}");
        }
    }

    fn copy_task(off: usize, len: usize) -> MergeTask {
        MergeTask {
            a: 0..len,
            b: 0..0,
            c_off: off,
            case: crate::core::cases::Case::CopyA,
            side: crate::core::cases::Side::A,
        }
    }

    #[test]
    fn carve_output_rejects_non_tiling_tasks() {
        let mut out = vec![0u8; 10];
        // Gap: second task starts at 6, not 4.
        let gap = vec![copy_task(0, 4), copy_task(6, 4)];
        assert!(carve_output(&gap, &mut out).is_err());
        // Short cover: only 8 of 10 slots.
        let short = vec![copy_task(0, 4), copy_task(4, 4)];
        assert!(carve_output(&short, &mut out).is_err());
        // Overrun: 12 of 10 slots.
        let long = vec![copy_task(0, 4), copy_task(4, 8)];
        assert!(carve_output(&long, &mut out).is_err());
        // Exact tiling is accepted, in any input order.
        let ok = vec![copy_task(6, 4), copy_task(0, 6)];
        let pairs = carve_output(&ok, &mut out).unwrap();
        assert_eq!(pairs.len(), 2);
        assert_eq!(pairs[0].1.len(), 6);
        assert_eq!(pairs[1].1.len(), 4);
    }

    #[test]
    fn run_tasks_propagate_tiling_errors() {
        let a = [1i64, 2, 3, 4];
        let b: [i64; 0] = [];
        let mut out = vec![0i64; 4];
        let bad = vec![copy_task(1, 3)];
        assert!(run_tasks_seq(&a, &b, &mut out, &bad).is_err());
        assert!(run_tasks_parallel(&a, &b, &mut out, &bad, 4).is_err());
    }

    #[test]
    fn chunk_tasks_rebalances_after_oversized_task() {
        // One oversized task first (the regression shape): the old
        // fixed-target walk produced < k groups with an over-packed
        // tail; the remaining-aware walk must fill all k groups evenly.
        let sizes = [100usize, 10, 10, 10, 10, 10, 10, 10, 10, 10, 10];
        let total: usize = sizes.iter().sum();
        let mut tasks = Vec::new();
        let mut off = 0;
        for &len in &sizes {
            tasks.push(copy_task(off, len));
            off += len;
        }
        let mut out = vec![0u8; total];
        let k = 4;
        let pairs = carve_output(&tasks, &mut out).unwrap();
        let groups = chunk_tasks(pairs, k);
        assert_eq!(groups.len(), k, "no thread may idle");
        let sums: Vec<usize> =
            groups.iter().map(|g| g.iter().map(|(t, _)| t.len()).sum()).collect();
        assert_eq!(sums[0], 100, "oversized task isolated in its own group");
        // Remaining 100 elements over 3 groups: ceil = 34; allow one
        // task of slack.
        for s in &sums[1..] {
            assert!((*s as i64 - 33).unsigned_abs() <= 10, "unbalanced tail: {sums:?}");
        }
    }

    #[test]
    fn chunk_tasks_uniform_stays_balanced() {
        let mut tasks = Vec::new();
        for i in 0..32 {
            tasks.push(copy_task(i * 5, 5));
        }
        let mut out = vec![0u8; 160];
        let pairs = carve_output(&tasks, &mut out).unwrap();
        let groups = chunk_tasks(pairs, 8);
        assert_eq!(groups.len(), 8);
        for g in &groups {
            let s: usize = g.iter().map(|(t, _)| t.len()).sum();
            assert_eq!(s, 20, "uniform tasks split evenly");
        }
    }
}

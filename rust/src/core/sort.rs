//! Stable parallel merge sort (paper §3).
//!
//! `O(n log n / p + log p log n)` parallel time: first the `p` blocks
//! are sorted sequentially in parallel, then `ceil(log p)` rounds merge
//! pairs of adjacent runs — each round uses the *modified* merge
//! algorithm that works in parallel on all `ceil(p/2^i)` pairs at once
//! (the paper's "the latter can easily be accomplished"): every pair is
//! partitioned with its share of the processing elements and ALL
//! resulting tasks across ALL pairs execute in one parallel phase.
//!
//! Space: input buffer + one output buffer (ping-pong), as the paper
//! claims ("no extra space apart from input and output arrays").
//!
//! Both the block-sort phase and every merge round run on the
//! persistent [`crate::exec`] executor — one fixed worker fleet for the
//! whole sort instead of `1 + ceil(log p)` spawn/join generations.

use super::adaptive::{merge_adaptive_scoped, MergeStrategy};
use super::blocks::Blocks;
use super::cases::{MergeTask, Partition};
use super::merge::{carve_output, chunk_tasks};
use super::seqmerge::{merge_into, merge_sort};

/// Stable parallel merge sort of `data` using `p` processing elements
/// and the default (fixed pre-partition) merge rounds.
pub fn parallel_merge_sort<T: Copy + Ord + Send + Sync>(data: &mut [T], p: usize) {
    parallel_merge_sort_with(data, p, MergeStrategy::default());
}

/// [`parallel_merge_sort`] with an explicit [`MergeStrategy`] for the
/// §3 merge rounds: `Fixed` pre-partitions every round's pairs;
/// `Adaptive` runs each pair sequentially-until-stolen (one task per
/// pair, splitting on observed steal requests).
pub fn parallel_merge_sort_with<T: Copy + Ord + Send + Sync>(
    data: &mut [T],
    p: usize,
    strategy: MergeStrategy,
) {
    assert!(p > 0);
    let n = data.len();
    if n <= 1 {
        return;
    }
    // Sequential bail: the crossover is calibrated for ONE merge pass;
    // a sort does 1 + ceil(log2 p) parallel phases over O(n log n)
    // work, so compare the cutoff against n·log2(n), not n.
    let seq_work = n.saturating_mul((crate::util::log2_ceil(n) as usize).max(1));
    if p == 1 || n < 2 * p || seq_work < crate::exec::tunables_for::<T>().parallel_merge_cutoff
    {
        let mut scratch = data.to_vec();
        merge_sort(data, &mut scratch);
        return;
    }

    // ---- Phase 1: sort p blocks sequentially, in parallel. ----------
    let blocks = Blocks::new(n, p);
    let bounds = blocks.starts();
    {
        let mut rest: &mut [T] = data;
        let mut slices = Vec::with_capacity(p);
        for i in 0..p {
            let (head, tail) = rest.split_at_mut(blocks.block_len(i));
            rest = tail;
            slices.push(head);
        }
        crate::exec::global().scope(|s| {
            for slice in slices {
                s.spawn(move || {
                    let mut scratch = slice.to_vec();
                    merge_sort(slice, &mut scratch);
                });
            }
        });
    }

    // ---- Phase 2: ceil(log p) parallel pairwise merge rounds. -------
    // Ping-pong directly between `data` and ONE aux buffer (paper:
    // input + output arrays only); a final copy is needed only when
    // the round count is odd. (§Perf iteration 1: this removed one
    // full-buffer copy per sort vs the initial two-Vec version.)
    let mut aux: Vec<T> = data.to_vec();
    let mut runs: Vec<usize> = bounds; // run boundaries incl. 0 and n
    let mut rounds = 0usize;
    let mut in_data = true;
    while runs.len() > 2 {
        runs = if in_data {
            merge_round_with(&*data, &mut aux, &runs, p, crate::exec::JobClass::Service, strategy)
        } else {
            merge_round_with(&aux, data, &runs, p, crate::exec::JobClass::Service, strategy)
        };
        in_data = !in_data;
        rounds += 1;
        debug_assert!(rounds <= crate::util::log2_ceil(p) as usize + 1);
    }
    if !in_data {
        data.copy_from_slice(&aux);
    }
}

/// One §3 merge round: merge adjacent run pairs `(0,1), (2,3), ...`
/// from `src` into `dst`; an odd trailing run is copied. Returns the
/// new run boundary vector. All pairs' tasks execute in ONE parallel
/// phase on the persistent executor (the paper's modified multi-pair
/// merge), submitted on the [`crate::exec::JobClass::Service`] lane.
pub fn merge_round<T: Copy + Ord + Send + Sync>(
    src: &[T],
    dst: &mut [T],
    runs: &[usize],
    p: usize,
) -> Vec<usize> {
    merge_round_with_class(src, dst, runs, p, crate::exec::JobClass::Service)
}

/// [`merge_round`] with an explicit QoS lane — the stream compactor
/// runs its rounds on [`crate::exec::JobClass::Background`] so major
/// compactions never starve service merges.
pub fn merge_round_with_class<T: Copy + Ord + Send + Sync>(
    src: &[T],
    dst: &mut [T],
    runs: &[usize],
    p: usize,
    class: crate::exec::JobClass,
) -> Vec<usize> {
    let nruns = runs.len() - 1;
    debug_assert!(nruns >= 2);
    let npairs = nruns / 2;
    // Fine-granularity mode is decided at the per-pair partition width:
    // grouping can only combine tasks, never split one, so when the
    // executor's windowed steal telemetry favours finer work (see
    // [`crate::exec::chunk_groups_for`]) each pair is partitioned with its
    // share of an over-provisioned lane budget. With fine mode off —
    // or below the sequential crossover, where a finer partition would
    // be wasted search work — `lanes == p`, the original split.
    let out_len = dst.len();
    let parallel = out_len >= crate::exec::tunables_for::<T>().parallel_merge_cutoff;
    let lanes = if parallel { crate::exec::chunk_groups_for::<T>(out_len, p) } else { p };
    let per_pair = (lanes / npairs).max(1);

    // Build the global task list: each pair contributes its partition's
    // tasks, rebased into global coordinates. MergeTask.{a,b} index into
    // `src` directly; c_off into `dst`.
    let mut global: Vec<(usize, usize, MergeTask)> = Vec::with_capacity(2 * lanes + 2);
    let mut new_runs = Vec::with_capacity(npairs + 2);
    new_runs.push(0usize);
    for pair in 0..npairs {
        let lo = runs[2 * pair];
        let mid = runs[2 * pair + 1];
        let hi = runs[2 * pair + 2];
        let part = Partition::compute(&src[lo..mid], &src[mid..hi], per_pair);
        for t in part.tasks() {
            global.push((lo, mid, t));
        }
        new_runs.push(hi);
    }
    // Odd trailing run: a pure copy task.
    if nruns % 2 == 1 {
        let lo = runs[nruns - 1];
        let hi = runs[nruns];
        if hi > lo {
            global.push((
                lo,
                hi, // b side empty; base irrelevant
                MergeTask {
                    a: 0..(hi - lo),
                    b: 0..0,
                    c_off: 0,
                    case: super::cases::Case::CopyA,
                    side: super::cases::Side::A,
                },
            ));
            new_runs.push(hi);
        }
    }

    // Rebase into global coordinates.
    let mut tasks: Vec<MergeTask> = global
        .into_iter()
        .map(|(a_base, b_base, mut t)| {
            t.a = (t.a.start + a_base)..(t.a.end + a_base);
            t.b = (t.b.start + b_base)..(t.b.end + b_base);
            t.c_off += a_base; // pair output starts at `lo` in dst
            t
        })
        .collect();
    tasks.sort_by_key(|t| t.c_off);

    // One parallel execution phase over all pairs' tasks. (`out_len`
    // was read before carving: the carved pairs hold exclusive borrows
    // of `dst` for the rest of the function.)
    let pairs = carve_output(&tasks, dst).expect("round tasks tile the destination");
    if !parallel {
        for (t, slice) in pairs {
            merge_into(&src[t.a.clone()], &src[t.b.clone()], slice);
        }
        return new_runs;
    }
    // Same lane budget for the grouping: `lanes` groups over ~2·lanes
    // tasks realizes the fine granularity the partition produced.
    let groups = chunk_tasks(pairs, lanes);
    crate::exec::global().scope_with_class(class, |s| {
        for group in groups {
            s.spawn(move || {
                for (t, slice) in group {
                    merge_into(&src[t.a.clone()], &src[t.b.clone()], slice);
                }
            });
        }
    });
    new_runs
}

/// Strategy dispatch for one §3 merge round: `Fixed` is the paper's
/// pre-partitioned round ([`merge_round_with_class`]); `Adaptive`
/// spawns ONE sequential-until-stolen task per run pair and lets the
/// kernel split on observed steal requests — no up-front searches at
/// all when the fleet is saturated (which, during a sort's merge
/// rounds, it usually is: every pair is already a task).
pub fn merge_round_with<T: Copy + Ord + Send + Sync>(
    src: &[T],
    dst: &mut [T],
    runs: &[usize],
    p: usize,
    class: crate::exec::JobClass,
    strategy: MergeStrategy,
) -> Vec<usize> {
    match strategy {
        MergeStrategy::Fixed => merge_round_with_class(src, dst, runs, p, class),
        MergeStrategy::Adaptive => merge_round_adaptive(src, dst, runs, p, class),
    }
}

/// The adaptive round: carve `dst` at the merged-pair boundaries (the
/// same tiling the fixed round's tasks produce, so the returned run
/// vector is identical) and run one adaptive kernel per pair. An odd
/// trailing run is copied. Below the sequential crossover the pairs
/// merge inline with no scope at all.
fn merge_round_adaptive<T: Copy + Ord + Send + Sync>(
    src: &[T],
    dst: &mut [T],
    runs: &[usize],
    p: usize,
    class: crate::exec::JobClass,
) -> Vec<usize> {
    let nruns = runs.len() - 1;
    debug_assert!(nruns >= 2);
    debug_assert_eq!(runs[0], 0);
    debug_assert_eq!(*runs.last().unwrap(), dst.len());
    let npairs = nruns / 2;
    let parallel =
        p > 1 && dst.len() >= crate::exec::tunables_for::<T>().parallel_merge_cutoff;
    let quantum = crate::exec::adaptive_quantum_for::<T>();

    let mut new_runs = Vec::with_capacity(npairs + 2);
    new_runs.push(0usize);
    // Carve dst into per-pair output slices up front (disjointness for
    // the borrow checker), exactly like the fixed round's carve.
    let mut pairs: Vec<(&[T], &[T], &mut [T])> = Vec::with_capacity(npairs);
    let mut rest: &mut [T] = dst;
    for pair in 0..npairs {
        let lo = runs[2 * pair];
        let mid = runs[2 * pair + 1];
        let hi = runs[2 * pair + 2];
        let (head, tail) = rest.split_at_mut(hi - lo);
        rest = tail;
        pairs.push((&src[lo..mid], &src[mid..hi], head));
        new_runs.push(hi);
    }
    // Odd trailing run: a pure copy (done inline — it is sequential
    // bandwidth either way).
    if nruns % 2 == 1 {
        let lo = runs[nruns - 1];
        let hi = runs[nruns];
        if hi > lo {
            let (head, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            head.copy_from_slice(&src[lo..hi]);
            new_runs.push(hi);
        }
    }
    debug_assert!(rest.is_empty());

    if !parallel {
        for (a, b, out) in pairs {
            merge_into(a, b, out);
        }
        return new_runs;
    }
    crate::exec::global().scope_with_class(class, |s| {
        for (a, b, out) in pairs {
            s.spawn(move || merge_adaptive_scoped(s, a, b, out, quantum, None));
        }
    });
    new_runs
}

/// Sequential stable merge sort into a fresh Vec (convenience used by
/// baselines and tests).
pub fn seq_sorted<T: Copy + Ord>(input: &[T]) -> Vec<T> {
    let mut v = input.to_vec();
    let mut scratch = v.clone();
    merge_sort(&mut v, &mut scratch);
    v
}

/// Expected §3 round count: `ceil(log2 p)`.
pub fn expected_rounds(p: usize) -> usize {
    crate::util::log2_ceil(p) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::record::Record;
    use crate::util::Rng;

    #[test]
    fn sorts_random() {
        let mut rng = Rng::new(1);
        for &p in &[1usize, 2, 3, 4, 7, 8, 16] {
            for _ in 0..20 {
                let n = rng.index(2000);
                let mut v: Vec<i64> = (0..n).map(|_| rng.range(-500, 500)).collect();
                let mut expect = v.clone();
                expect.sort();
                parallel_merge_sort(&mut v, p);
                assert_eq!(v, expect, "p={p} n={n}");
            }
        }
    }

    #[test]
    fn sort_is_stable() {
        let mut rng = Rng::new(2);
        for &p in &[2usize, 5, 8, 13] {
            let n = 3000;
            let mut v: Vec<Record> = (0..n)
                .map(|i| Record::new(rng.range(0, 50), i as u64))
                .collect();
            let mut expect = v.clone();
            expect.sort_by_key(|r| r.key); // std stable sort as oracle
            parallel_merge_sort(&mut v, p);
            let got: Vec<(i64, u64)> = v.iter().map(|r| (r.key, r.tag)).collect();
            let want: Vec<(i64, u64)> = expect.iter().map(|r| (r.key, r.tag)).collect();
            assert_eq!(got, want, "instability at p={p}");
        }
    }

    #[test]
    fn tiny_and_edge_sizes() {
        for n in 0..40 {
            for &p in &[1usize, 2, 3, 8, 32] {
                let mut v: Vec<i64> = (0..n).map(|i| ((i * 37) % 11) as i64).collect();
                let mut expect = v.clone();
                expect.sort();
                parallel_merge_sort(&mut v, p);
                assert_eq!(v, expect, "n={n} p={p}");
            }
        }
    }

    #[test]
    fn presorted_and_reversed() {
        let mut asc: Vec<i64> = (0..5000).collect();
        let mut desc: Vec<i64> = (0..5000).rev().collect();
        parallel_merge_sort(&mut asc, 8);
        parallel_merge_sort(&mut desc, 8);
        assert!(asc.windows(2).all(|w| w[0] <= w[1]));
        assert!(desc.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn all_equal() {
        let mut v = vec![Record::new(4, 0); 1000];
        for (i, r) in v.iter_mut().enumerate() {
            r.tag = i as u64;
        }
        parallel_merge_sort(&mut v, 8);
        // Stability on an all-equal array = identity permutation.
        assert!(v.iter().enumerate().all(|(i, r)| r.tag == i as u64));
    }

    #[test]
    fn round_count_matches_log_p() {
        // Count rounds by driving merge_round manually.
        let mut rng = Rng::new(9);
        for &p in &[2usize, 3, 4, 6, 8, 16] {
            let n = 64 * p;
            let mut data: Vec<i64> = (0..n).map(|_| rng.range(0, 1000)).collect();
            let blocks = Blocks::new(n, p);
            let mut runs = blocks.starts();
            for i in 0..p {
                let s = blocks.start(i);
                let e = blocks.start(i + 1);
                data[s..e].sort();
            }
            let mut src = data.clone();
            let mut dst = data.clone();
            let mut rounds = 0;
            while runs.len() > 2 {
                runs = merge_round(&src, &mut dst, &runs, p);
                std::mem::swap(&mut src, &mut dst);
                rounds += 1;
            }
            assert!(
                rounds == expected_rounds(p) || rounds == expected_rounds(p) + 1,
                "p={p} rounds={rounds} expected~{}",
                expected_rounds(p)
            );
        }
    }

    #[test]
    fn adaptive_rounds_sort_and_stay_stable() {
        let mut rng = Rng::new(21);
        for &p in &[2usize, 5, 8, 13] {
            let n = 4000;
            let mut v: Vec<Record> =
                (0..n).map(|i| Record::new(rng.range(0, 60), i as u64)).collect();
            let mut expect = v.clone();
            expect.sort_by_key(|r| r.key); // std stable sort as oracle
            parallel_merge_sort_with(&mut v, p, MergeStrategy::Adaptive);
            let got: Vec<(i64, u64)> = v.iter().map(|r| (r.key, r.tag)).collect();
            let want: Vec<(i64, u64)> = expect.iter().map(|r| (r.key, r.tag)).collect();
            assert_eq!(got, want, "adaptive instability at p={p}");
        }
    }

    #[test]
    fn large_adaptive_sort_exercises_executor_rounds() {
        // Above the cutoff clamp (2^18) so every adaptive round runs
        // scoped kernels, with real steal-request traffic.
        let mut rng = Rng::new(13);
        let n = 1 << 19;
        let mut v: Vec<i64> = (0..n).map(|_| rng.range(0, 1 << 20)).collect();
        let mut expect = v.clone();
        expect.sort();
        parallel_merge_sort_with(&mut v, 8, MergeStrategy::Adaptive);
        assert_eq!(v, expect);
    }

    #[test]
    fn large_sort_exercises_executor_rounds() {
        // Big enough that phase 1 and every round take the executor
        // path regardless of the calibrated crossover (cutoff clamps
        // at 2^18).
        let mut rng = Rng::new(12);
        let n = 1 << 19;
        let mut v: Vec<i64> = (0..n).map(|_| rng.range(0, 1 << 20)).collect();
        let mut expect = v.clone();
        expect.sort();
        parallel_merge_sort(&mut v, 8);
        assert_eq!(v, expect);
    }
}

//! Block partition arithmetic (paper §2).
//!
//! A length-`n` array is divided among `p` processing elements into
//! consecutive, contiguous blocks differing in size by at most one: the
//! first `r = n mod p` blocks get `ceil(n/p)` elements, the rest
//! `floor(n/p)`. Start index of block `i`:
//!
//! ```text
//! x_i = i*ceil(n/p)            for i <  r
//! x_i = i*floor(n/p) + n mod p for i >= r      (x_p = n)
//! ```
//!
//! (The paper's displayed formula has a typo — `i⌈n/p⌉ + n mod p` — the
//! derivation `r*ceil + (i-r)*floor = i*floor + r` gives the form used
//! here; it agrees with the worked Figure 1 values.)
//!
//! Both "start of block i" and "block containing index k" are O(1),
//! which is what lets each processing element classify its merge case
//! locally (paper: "all constant time operations").

use crate::util::div_ceil;

/// Immutable description of a p-way block partition of `len` elements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Blocks {
    pub len: usize,
    pub p: usize,
    /// `ceil(len/p)`
    pub big: usize,
    /// `floor(len/p)`
    pub small: usize,
    /// `len mod p` — number of big blocks.
    pub r: usize,
}

impl Blocks {
    pub fn new(len: usize, p: usize) -> Self {
        assert!(p > 0, "p must be positive");
        Blocks { len, p, big: div_ceil(len, p), small: len / p, r: len % p }
    }

    /// Start index `x_i` of block `i`, for `0 <= i <= p` (`x_p = len`).
    #[inline]
    pub fn start(&self, i: usize) -> usize {
        debug_assert!(i <= self.p);
        if i < self.r {
            i * (self.small + 1)
        } else {
            i * self.small + self.r
        }
    }

    /// Length of block `i`.
    #[inline]
    pub fn block_len(&self, i: usize) -> usize {
        self.start(i + 1) - self.start(i)
    }

    /// The block containing element index `k` (`0 <= k < len`), O(1).
    ///
    /// Paper §2: `k` belongs to block `i` iff either `k < r*ceil` and
    /// `floor(k/ceil) = i`, or `k >= r*ceil` and
    /// `floor((k - r*ceil)/floor) + r = i`.
    #[inline]
    pub fn block_of(&self, k: usize) -> usize {
        debug_assert!(k < self.len, "index {k} out of range {}", self.len);
        let big = self.small + 1;
        let boundary = self.r * big;
        if k < boundary {
            k / big
        } else {
            debug_assert!(self.small > 0);
            (k - boundary) / self.small + self.r
        }
    }

    /// All `p + 1` start indices (the `x_0..x_p` array of the paper).
    pub fn starts(&self) -> Vec<usize> {
        (0..=self.p).map(|i| self.start(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_a_blocks() {
        // n = 18, p = 5: starts [0, 4, 8, 12, 15, 18] (r = 3 big blocks of 4).
        let b = Blocks::new(18, 5);
        assert_eq!(b.starts(), vec![0, 4, 8, 12, 15, 18]);
    }

    #[test]
    fn figure1_b_blocks() {
        // m = 15, p = 5: starts [0, 3, 6, 9, 12, 15] (all blocks of 3).
        let b = Blocks::new(15, 5);
        assert_eq!(b.starts(), vec![0, 3, 6, 9, 12, 15]);
    }

    #[test]
    fn starts_monotone_and_cover() {
        for len in 0..60 {
            for p in 1..20 {
                let b = Blocks::new(len, p);
                let s = b.starts();
                assert_eq!(s[0], 0);
                assert_eq!(s[p], len);
                for w in s.windows(2) {
                    assert!(w[0] <= w[1]);
                    assert!(w[1] - w[0] <= div_ceil(len, p).max(1));
                }
            }
        }
    }

    #[test]
    fn sizes_differ_by_at_most_one() {
        for len in 1..100 {
            for p in 1..=len {
                let b = Blocks::new(len, p);
                let sizes: Vec<usize> = (0..p).map(|i| b.block_len(i)).collect();
                let mx = *sizes.iter().max().unwrap();
                let mn = *sizes.iter().min().unwrap();
                assert!(mx - mn <= 1, "len={len} p={p} sizes={sizes:?}");
            }
        }
    }

    #[test]
    fn block_of_inverts_start() {
        for len in 1..80 {
            for p in 1..25 {
                let b = Blocks::new(len, p);
                for k in 0..len {
                    let i = b.block_of(k);
                    assert!(b.start(i) <= k && k < b.start(i + 1), "len={len} p={p} k={k} i={i}");
                }
            }
        }
    }

    #[test]
    fn more_blocks_than_elements() {
        // n < p: the tail blocks are empty; starts saturate at len.
        let b = Blocks::new(3, 7);
        assert_eq!(b.starts(), vec![0, 1, 2, 3, 3, 3, 3, 3]);
        assert_eq!(b.block_of(2), 2);
    }

    #[test]
    fn single_block() {
        let b = Blocks::new(10, 1);
        assert_eq!(b.starts(), vec![0, 10]);
        assert_eq!(b.block_of(9), 0);
    }
}

//! Coordinator service (S14): the deployable layer on top of the
//! algorithm — a job API (merge / sort over keyed data) on the shared
//! persistent executor, engine selection (pure-rust threads vs
//! XLA-offloaded block pipeline), and service metrics.
//!
//! Thread budget: service jobs and each job's internal parallel phases
//! run on the same [`crate::exec`] worker fleet, so concurrent jobs
//! overlap without oversubscribing the machine — and every
//! *asynchronous* job entry ([`MergeService::submit_sort`],
//! [`MergeService::submit_sort_batch`],
//! [`MergeService::submit_background`]) is **admission controlled** by
//! the service's [`WorkerPool`] (`Config.threads` permits; see
//! `coordinator::pool`), so a tenant's submitted backlog cannot occupy
//! the whole fleet. Jobs carry a [`JobClass`](crate::exec::JobClass)
//! (`Config.class`, or [`MergeService::submit_background`] per job):
//! background traffic enters the executor's yielding injector lane.
//! The *synchronous* calls ([`MergeService::merge`],
//! [`MergeService::sort`], [`MergeService::merge_many`]) are one job
//! each from the caller's perspective and fan their internal
//! parallelism out through `exec::scope` directly — cooperative
//! shared-fleet work, not admission-gated (a caller can only have as
//! many in flight as it has blocked threads).
//!
//! Engines:
//! - [`Engine::Rust`]  — the paper's algorithm on OS threads (L3 only).
//! - [`Engine::Hybrid`]— leaf blocks sorted/merged on the AOT XLA
//!   executables (`sort_n*`, `merge_b*` artifacts: the L1 Pallas
//!   kernels), upper merge-sort rounds on the rust parallel merge —
//!   i.e. the full three-layer stack with Python nowhere at runtime.
//!
//! Streaming is **handle-based**: [`MergeService::open_stream`] returns
//! a [`StreamHandle`], and each writer thread takes its own
//! [`IngestWriter`] ([`StreamHandle::writer`]) — an owned ingest shard
//! that never contends with the other writers' pushes (see
//! [`crate::stream::writer`] for the sharding and ordering story). The
//! older implicit single-tenant trio ([`MergeService::init_stream`] /
//! [`MergeService::ingest`] / [`MergeService::flush_stream`]) survives
//! as deprecated wrappers over the service's default handle.
//!
//! Asynchronous sort submission is consolidated behind
//! [`MergeService::job`] — a [`JobBuilder`] with a per-job
//! [`JobClass`] and single/batch submission; `submit_sort`,
//! `submit_background` and `submit_sort_batch` are thin wrappers over
//! it.

pub mod pool;

use crate::core::record::F32Key;
use crate::core::{merge_with_strategy, parallel_merge_sort_with, MergeStrategy};
use crate::exec::JobClass;
use crate::obs::{trace, Hist, HistSnapshot, Registry};
use crate::runtime::{KeyedBlock, XlaMerger, XlaRuntime, XlaSorter};
use crate::stream::{self, RunStore, SeqClock, ShardWriter, StreamConfig, StreamError};
use anyhow::{anyhow, Result};
use crate::model::sync::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

pub use pool::WorkerPool;

/// A keyed record with f32 key (the runtime interchange key type) and
/// i32 payload; orders by key only.
#[derive(Clone, Copy, Debug)]
pub struct KRec {
    pub key: F32Key,
    pub val: i32,
}

impl PartialEq for KRec {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl Eq for KRec {}
impl PartialOrd for KRec {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for KRec {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// Convert between the runtime layout and record layout.
pub fn to_recs(block: &KeyedBlock) -> Vec<KRec> {
    block
        .keys
        .iter()
        .zip(&block.vals)
        .map(|(&k, &v)| KRec { key: F32Key(k), val: v })
        .collect()
}

pub fn to_block(recs: &[KRec]) -> KeyedBlock {
    KeyedBlock {
        keys: recs.iter().map(|r| r.key.0).collect(),
        vals: recs.iter().map(|r| r.val).collect(),
    }
}

/// Stable merge of two keyed blocks on the rust engine with an
/// explicit thread budget (free function so executor tasks can call it
/// without capturing the service).
fn merge_blocks(
    a: &KeyedBlock,
    b: &KeyedBlock,
    threads: usize,
    strategy: MergeStrategy,
) -> KeyedBlock {
    let ra = to_recs(a);
    let rb = to_recs(b);
    let mut out = vec![KRec { key: F32Key(0.0), val: 0 }; ra.len() + rb.len()];
    merge_with_strategy(&ra, &rb, &mut out, threads, strategy);
    to_block(&out)
}

/// Execution engine selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Pure rust: the paper's parallel merge/sort on `p` threads.
    Rust,
    /// XLA leaf stage + rust upper rounds (full three-layer stack).
    Hybrid,
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// This service's concurrency budget, doing double duty:
    /// the parallelism granularity for its algorithms (the `p` handed
    /// to merge/sort — all services still share the process-wide
    /// [`crate::exec`] fleet, pin its width with `EXEC_THREADS`), AND
    /// the service's **admission bound**: at most `threads` of this
    /// service's submitted jobs are in flight at once (the
    /// [`WorkerPool`] semaphore — see `coordinator::pool` for the full
    /// semantics and history).
    pub threads: usize,
    pub engine: Engine,
    /// Leaf block size for the hybrid pipeline (must be within the
    /// sort artifact capacity).
    pub leaf_block: usize,
    /// Default [`JobClass`] for this service's submitted jobs: a
    /// `Background` service's traffic enters the executor's background
    /// injector lane and yields to service-class tenants fleet-wide.
    /// [`MergeService::submit_background`] forces the background lane
    /// per job regardless of this default.
    pub class: JobClass,
    /// Merge kernel for the rust engine's merges and sort rounds:
    /// [`MergeStrategy::Fixed`] is the paper's up-front partition;
    /// [`MergeStrategy::Adaptive`] merges sequentially in bounded
    /// quanta and splits only on observed steal requests (see
    /// [`crate::core::adaptive`]). Overridable per job via
    /// [`JobBuilder::strategy`]; the default stream tenant inherits it.
    pub strategy: MergeStrategy,
    /// Tenant label for this service's observability instruments: its
    /// job-latency histogram registers as `svc.<tenant>.job_latency`
    /// and its streams as `stream.<tenant>.{ingest,scan}_latency` in
    /// the process [`Registry`]. Tenants sharing a label share the
    /// instruments (the registry is get-or-create by name).
    pub tenant: String,
    /// Enable span tracing ([`crate::obs::trace`]) when this service
    /// is built. Sticky process-wide (tracing has one global switch);
    /// `EXEC_TRACE=1` enables it regardless of this flag.
    pub trace: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            threads: crate::util::num_cpus(),
            engine: Engine::Rust,
            leaf_block: 1024,
            class: JobClass::Service,
            strategy: MergeStrategy::Fixed,
            tenant: String::from("default"),
            trace: false,
        }
    }
}

/// Ingress policy for f32 keys, decided per engine (ROADMAP item):
///
/// - [`Engine::Rust`] **accepts** non-finite keys: every comparison on
///   the rust path is `f32::total_cmp` (via `F32Key`), under which
///   NaN and ±inf have well-defined, deterministic positions — there
///   is nothing unsound to reject.
/// - [`Engine::Hybrid`] **rejects** NaN/±inf at job entry: the XLA
///   marshalling layer pads blocks with `+inf` sentinels and slices
///   the tail back off, so a real `+inf`/NaN key is indistinguishable
///   from padding and the kernel's output is not defined for it.
///   Failing fast at ingress (with the offending index) beats
///   returning silently wrong data.
pub fn validate_ingress(engine: Engine, block: &KeyedBlock) -> Result<(), String> {
    if engine == Engine::Rust {
        return Ok(());
    }
    match block.keys.iter().position(|k| !k.is_finite()) {
        None => Ok(()),
        Some(i) => Err(format!(
            "hybrid engine rejects non-finite key {} at index {i}: XLA blocks are \
             +inf-padded, so NaN/±inf inputs have undefined merge output (use the \
             rust engine for total_cmp ordering of non-finite keys)",
            block.keys[i]
        )),
    }
}

/// Rolling service metrics.
///
/// All counters are `AtomicU64` end to end: `busy_nanos` in particular
/// used to accumulate `as_nanos() as usize`, which truncates on 32-bit
/// targets (usize = u32 wraps after ~4.3 seconds of busy time) and
/// silently wraps on long-running services. A u64 of nanoseconds holds
/// ~584 years.
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub jobs: AtomicU64,
    pub elements: AtomicU64,
    pub xla_calls: AtomicU64,
    pub busy_nanos: AtomicU64,
    /// Per-job latency histogram (`svc.<tenant>.job_latency`), wired
    /// by [`MergeService::new`] from the process [`Registry`]. Unset
    /// on bare `ServiceStats::default()` instances, where `record`
    /// keeps only the scalar counters — exact-bucket percentiles are
    /// then available via [`MergeService::latency_snapshot`] instead
    /// of sampling job vectors.
    pub latency: OnceLock<Arc<Hist>>,
}

impl ServiceStats {
    /// Record one completed job: the single bookkeeping path every
    /// sync and async entry point shares.
    pub fn record(&self, elems: usize, t0: Instant) {
        let elapsed = t0.elapsed();
        self.jobs.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(elems as u64, Ordering::Relaxed);
        self.busy_nanos
            .fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        if let Some(h) = self.latency.get() {
            h.record_duration(elapsed);
        }
    }

    /// `(jobs, elements, xla_calls, busy_seconds)`.
    pub fn snapshot(&self) -> (u64, u64, u64, f64) {
        (
            self.jobs.load(Ordering::Relaxed),
            self.elements.load(Ordering::Relaxed),
            self.xla_calls.load(Ordering::Relaxed),
            self.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        )
    }
}

/// Total-order-preserving map from an `f32` service key to the `i64`
/// stream key: exactly the key transform `f32::total_cmp` applies
/// before its integer compare, so `f32_ordered(a) <= f32_ordered(b)`
/// iff `a.total_cmp(&b) != Greater` — for EVERY bit pattern, NaN and
/// ±0.0 included. Bijective (the XOR mask never touches the sign bit
/// it is derived from), so [`f32_unordered`] recovers the exact key.
fn f32_ordered(key: f32) -> i64 {
    total_order_xform(key.to_bits() as i32) as i64
}

/// Inverse of [`f32_ordered`].
fn f32_unordered(code: i64) -> f32 {
    f32::from_bits(total_order_xform(code as i32) as u32)
}

/// The sign-extension XOR both codec directions share: flips the
/// magnitude bits of negative values (mask `0x7FFF_FFFF`), leaves the
/// sign bit alone — which is exactly why it is an involution (the
/// mask is derived from the bit it never touches), so one function
/// serves as both map and inverse.
fn total_order_xform(mut bits: i32) -> i32 {
    bits ^= (((bits >> 31) as u32) >> 1) as i32;
    bits
}

/// The **legacy** per-stream record cap: a v1-format stream ([`
/// StreamConfig::legacy_pages`](crate::stream::StreamConfig)) packs
/// the whole ingest sequence into the tag's 32 high bits
/// ([`pack_tag`]), so sequence `2^32` would collide with sequence 0
/// and silently corrupt the stability order — ingest fails typed at
/// the boundary instead ([`StreamError::CapExceeded`]). Default
/// (v2-format) streams are **not** capped: the sequence is 64-bit,
/// with the high half stored out of line in the page aux column (see
/// [`crate::stream::writer`]).
pub const STREAM_RECORD_CAP: u64 = 1 << 32;

/// Typed ingest-refused error: a legacy-format stream hit
/// [`STREAM_RECORD_CAP`] records. Carries the sequence number that
/// would have overflowed the packed tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecordCapExceeded {
    /// The ingest sequence number that did not fit.
    pub seq: u64,
}

impl std::fmt::Display for RecordCapExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "stream record cap exceeded: ingest sequence {} does not fit the packed \
             tag's 32 sequence bits (cap {} records per tenant stream)",
            self.seq, STREAM_RECORD_CAP
        )
    }
}

impl std::error::Error for RecordCapExceeded {}

/// Stream tag layout for service records: ingest sequence number in
/// the high 32 bits (strictly increasing in arrival order — the
/// stability observation), the record's `i32` payload in the low 32.
/// Fails with [`RecordCapExceeded`] once `seq` no longer fits — the
/// legacy 2^32-records-per-stream boundary. The live write path
/// ([`crate::stream::ShardWriter`]) packs the same low-32 layout but
/// carries the sequence's high half out of line, so only
/// `legacy_pages` streams ever hit this cap.
pub fn pack_tag(seq: u64, val: i32) -> Result<u64, RecordCapExceeded> {
    if seq >= STREAM_RECORD_CAP {
        return Err(RecordCapExceeded { seq });
    }
    Ok((seq << 32) | (val as u32 as u64))
}

/// Payload half of [`pack_tag`].
pub fn unpack_val(tag: u64) -> i32 {
    tag as u32 as i32
}

/// Clears the compaction-scheduled flag on every exit path of the
/// drain job (including a panic), so a wedged drain cannot block all
/// future scheduling.
struct ClearOnDrop(Arc<AtomicBool>);

impl Drop for ClearOnDrop {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Release);
    }
}

/// One service's streaming state: the run store, the shared ingest
/// sequence clock, an implicit (mutex-guarded) writer shard for the
/// block-at-a-time facade, and a one-permit background pool that
/// drains the compaction backlog. The service entry points
/// ([`StreamHandle`], and the deprecated [`MergeService::ingest`] /
/// [`MergeService::flush_stream`] wrappers) reach this directly or
/// through the service's admission pool; compaction never does — it
/// rides the executor's background lane under its own single permit,
/// so maintenance cannot consume the tenant's service permits.
struct StreamTenant {
    store: Arc<RunStore>,
    /// The stream's 64-bit ingest sequence space, shared by the
    /// implicit writer and every [`IngestWriter`] the handle vends —
    /// sequence numbers stay globally unique across all of them.
    clock: Arc<SeqClock>,
    /// The implicit writer shard behind the block-at-a-time facade
    /// ([`StreamHandle::ingest`] and the deprecated trio). Serialized
    /// on purpose: a solo writer draws contiguous sequence numbers, so
    /// block ingest order is total. Scaling writers means taking
    /// per-thread [`IngestWriter`]s instead.
    implicit: Mutex<ShardWriter>,
    compact_pool: WorkerPool,
    /// Dedup flag: each backlog burst schedules at most one drain job.
    /// A seal racing the drain's empty-check can go unscheduled for a
    /// moment — the next seal (or flush) re-triggers, and the policy
    /// drain loops until the backlog is below fanout anyway.
    compact_scheduled: Arc<AtomicBool>,
    threads: usize,
    /// Block-ingest latency (`stream.<tenant>.ingest_latency`): one
    /// sample per ingested block / writer flush, not per record.
    ingest_hist: Arc<Hist>,
    /// Merged-scan latency (`stream.<tenant>.scan_latency`).
    scan_hist: Arc<Hist>,
}

impl StreamTenant {
    fn new(cfg: StreamConfig, tenant: &str) -> Result<Arc<StreamTenant>, StreamError> {
        let threads = cfg.threads.max(1);
        let store = Arc::new(RunStore::new(cfg)?);
        Ok(StreamTenant::from_store(store, threads, tenant))
    }

    /// Restart path: rebuild the tenant from a spill directory's
    /// manifest ([`RunStore::recover`]) — sealed runs reappear, only
    /// unsealed buffered records are lost.
    fn recover(cfg: StreamConfig, tenant: &str) -> Result<Arc<StreamTenant>, StreamError> {
        let threads = cfg.threads.max(1);
        let store = Arc::new(RunStore::recover(cfg)?);
        Ok(StreamTenant::from_store(store, threads, tenant))
    }

    fn from_store(store: Arc<RunStore>, threads: usize, tenant: &str) -> Arc<StreamTenant> {
        let clock = Arc::new(SeqClock::new());
        let registry = Registry::global();
        Arc::new(StreamTenant {
            implicit: Mutex::new(ShardWriter::new(Arc::clone(&store), Arc::clone(&clock))),
            clock,
            store,
            compact_pool: WorkerPool::with_class(1, JobClass::Background),
            compact_scheduled: Arc::new(AtomicBool::new(false)),
            threads,
            ingest_hist: registry.hist(&format!("stream.{tenant}.ingest_latency")),
            scan_hist: registry.hist(&format!("stream.{tenant}.scan_latency")),
        })
    }

    fn ingest_block(&self, block: &KeyedBlock) -> Result<usize, StreamError> {
        let t0 = Instant::now();
        let mut w = self.implicit.lock().unwrap();
        let mut sealed = 0usize;
        for (k, v) in block.keys.iter().zip(&block.vals) {
            if w.push(f32_ordered(*k), *v as u32)?.is_some() {
                sealed += 1;
            }
        }
        drop(w);
        self.ingest_hist.record_duration(t0.elapsed());
        if sealed > 0 {
            self.maybe_schedule_compaction();
        }
        Ok(sealed)
    }

    fn flush(&self) -> Result<Option<u64>, StreamError> {
        let sealed = self.implicit.lock().unwrap().flush()?;
        if sealed.is_some() {
            self.maybe_schedule_compaction();
        }
        Ok(sealed)
    }

    /// Bounded (~5s) wait for any scheduled background compaction
    /// drain to go idle — a reporting convenience; correctness never
    /// needs it.
    fn quiesce(&self) {
        for _ in 0..5_000 {
            if !self.compact_scheduled.load(Ordering::Acquire) && !self.store.is_compacting() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    fn scan_block(&self) -> Result<KeyedBlock, String> {
        let t0 = Instant::now();
        let records = stream::scan(&self.store)?;
        let out = KeyedBlock {
            keys: records.iter().map(|r| f32_unordered(r.key)).collect(),
            vals: records.iter().map(|r| unpack_val(r.tag)).collect(),
        };
        self.scan_hist.record_duration(t0.elapsed());
        Ok(out)
    }

    /// Schedule one background compaction drain if the backlog asks
    /// for it and none is already scheduled. Fire-and-forget: the
    /// result receiver is dropped; the job still runs.
    fn maybe_schedule_compaction(&self) {
        if !self.store.needs_compaction() {
            return;
        }
        if self
            .compact_scheduled
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return;
        }
        let store = Arc::clone(&self.store);
        let flag = Arc::clone(&self.compact_scheduled);
        let threads = self.threads;
        let _ = self.compact_pool.submit(move || {
            let _clear = ClearOnDrop(flag);
            // Drain until the policy is satisfied; claim losers exit
            // immediately (another drain is already on it). A failure
            // (e.g. spill I/O) must NOT vanish: it is counted on the
            // store (`StoreStats::compaction_failures`) and logged —
            // the backlog it leaves behind makes the next seal retry.
            loop {
                match stream::compact_once(&store, threads) {
                    Ok(Some(_)) => continue,
                    Ok(None) => break,
                    Err(e) => {
                        store.note_compaction_failure();
                        eprintln!("background compaction failed (will retry on next seal): {e}");
                        break;
                    }
                }
            }
        });
    }
}

/// A handle to one open stream: the service-level face of the sharded
/// ingest path. Cheap to clone (all clones share the tenant); vends
/// one owned [`IngestWriter`] per writer thread so concurrent ingest
/// never serializes on a shared buffer.
///
/// ```
/// use traff_merge::coordinator::{Config, MergeService};
/// use traff_merge::stream::StreamConfig;
///
/// let svc = MergeService::new(Config::default()).unwrap();
/// let cfg = StreamConfig::builder().run_capacity(4).build().unwrap();
/// let handle = svc.open_stream(cfg).unwrap();
/// let mut w = handle.writer();
/// for (i, key) in [2.0f32, 1.0, 1.0, 3.0].iter().enumerate() {
///     w.push(*key, i as i32).unwrap();
/// }
/// w.flush().unwrap();
/// let out = handle.scan().unwrap();
/// assert_eq!(out.keys, vec![1.0, 1.0, 2.0, 3.0]);
/// assert_eq!(out.vals, vec![1, 2, 0, 3]); // equal keys keep ingest order
/// ```
#[derive(Clone)]
pub struct StreamHandle {
    tenant: Arc<StreamTenant>,
}

impl StreamHandle {
    /// A new owned writer shard for one thread (the writer is `Send`:
    /// make one per thread and move it in). All writers of this handle
    /// share the stream's sequence clock and run store; none of them
    /// share a buffer. Cross-writer duplicate-key order is decided by
    /// seal generation; each writer's own order is preserved exactly —
    /// see [`crate::stream::writer`].
    pub fn writer(&self) -> IngestWriter {
        IngestWriter {
            inner: ShardWriter::new(
                Arc::clone(&self.tenant.store),
                Arc::clone(&self.tenant.clock),
            ),
            tenant: Arc::clone(&self.tenant),
        }
    }

    /// Block-at-a-time ingest on the stream's implicit (serialized)
    /// writer — the convenience path; per-thread [`IngestWriter`]s are
    /// the scalable one. Returns the number of runs the block sealed.
    pub fn ingest(&self, block: &KeyedBlock) -> Result<usize> {
        Ok(self.tenant.ingest_block(block)?)
    }

    /// Seal the implicit writer's partial buffer (if any) so its
    /// records become scan-visible. Per-thread [`IngestWriter`]s flush
    /// themselves.
    pub fn flush(&self) -> Result<Option<u64>> {
        Ok(self.tenant.flush()?)
    }

    /// Stable merged scan of the stream's sealed data: globally
    /// key-sorted (under `f32::total_cmp`), duplicate keys in exact
    /// ingest order per writer, cross-writer by seal generation. Runs
    /// against a snapshot; a concurrent compaction neither blocks nor
    /// disturbs it.
    pub fn scan(&self) -> Result<KeyedBlock> {
        self.tenant.scan_block().map_err(|e| anyhow!("{e}"))
    }

    /// Store statistics for this stream.
    pub fn stats(&self) -> stream::StoreStats {
        self.tenant.store.stats()
    }

    /// Bounded wait for background compaction to go idle (reporting
    /// convenience; correctness never needs it).
    pub fn quiesce(&self) {
        self.tenant.quiesce()
    }
}

/// One writer thread's owned ingest shard at the service layer: wraps
/// a [`crate::stream::ShardWriter`] with the service's f32 key codec
/// and background-compaction scheduling. `Send` — take one per thread
/// from [`StreamHandle::writer`] and move it in; pushes touch no
/// shared buffer.
///
/// ```
/// use traff_merge::coordinator::{Config, MergeService};
/// use traff_merge::stream::StreamConfig;
///
/// let svc = MergeService::new(Config::default()).unwrap();
/// let cfg = StreamConfig::builder().run_capacity(8).build().unwrap();
/// let handle = svc.open_stream(cfg).unwrap();
/// std::thread::scope(|s| {
///     for w in 0..2 {
///         let mut wr = handle.writer();
///         s.spawn(move || {
///             for i in 0..8 {
///                 wr.push(i as f32, (w * 8 + i) as i32).unwrap();
///             }
///             wr.flush().unwrap();
///         });
///     }
/// });
/// let out = handle.scan().unwrap();
/// assert_eq!(out.keys.len(), 16);
/// assert!(out.keys.windows(2).all(|p| p[0] <= p[1]));
/// ```
pub struct IngestWriter {
    inner: ShardWriter,
    tenant: Arc<StreamTenant>,
}

impl IngestWriter {
    /// Ingest one `(key, val)` record into this writer's shard.
    /// Returns the sealed run's generation when this push filled the
    /// shard. Non-finite keys are accepted and ordered by
    /// `f32::total_cmp` (the stream path is always the rust
    /// total-order path).
    pub fn push(&mut self, key: f32, val: i32) -> Result<Option<u64>> {
        let sealed = self.inner.push(f32_ordered(key), val as u32)?;
        if sealed.is_some() {
            self.tenant.maybe_schedule_compaction();
        }
        Ok(sealed)
    }

    /// Seal this shard's partial buffer so its records become
    /// scan-visible. Dropping a writer with pending records loses
    /// them — flush first.
    pub fn flush(&mut self) -> Result<Option<u64>> {
        let t0 = Instant::now();
        let sealed = self.inner.flush()?;
        self.tenant.ingest_hist.record_duration(t0.elapsed());
        if sealed.is_some() {
            self.tenant.maybe_schedule_compaction();
        }
        Ok(sealed)
    }

    /// Records buffered in this shard (not yet sealed, not yet
    /// scan-visible).
    pub fn pending(&self) -> usize {
        self.inner.pending()
    }
}

/// The merge/sort service.
pub struct MergeService {
    pub config: Config,
    pub pool: WorkerPool,
    pub stats: Arc<ServiceStats>,
    runtime: Option<Arc<XlaRuntime>>,
    /// Lazily (or explicitly, [`MergeService::init_stream`]) created
    /// streaming tenant.
    stream: OnceLock<Arc<StreamTenant>>,
}

impl MergeService {
    /// Build the service; the XLA runtime is loaded only for hybrid
    /// configs (artifacts must exist — `make artifacts`).
    pub fn new(config: Config) -> Result<MergeService> {
        let runtime = match config.engine {
            Engine::Rust => None,
            Engine::Hybrid => Some(Arc::new(XlaRuntime::load_dir(&XlaRuntime::default_dir())?)),
        };
        if config.trace {
            trace::set_enabled(true);
        }
        trace::enable_from_env();
        let stats = Arc::new(ServiceStats::default());
        let _ = stats
            .latency
            .set(Registry::global().hist(&format!("svc.{}.job_latency", config.tenant)));
        Ok(MergeService {
            pool: WorkerPool::with_class(config.threads.max(1), config.class),
            config,
            stats,
            runtime,
            stream: OnceLock::new(),
        })
    }

    /// Exact-bucket snapshot of this service's per-job latency
    /// histogram (`svc.<tenant>.job_latency`) — the sensor ROADMAP
    /// item 1's PID controller reads: `p99()` over the tenant's own
    /// jobs, not a sampled vector.
    pub fn latency_snapshot(&self) -> HistSnapshot {
        self.stats
            .latency
            .get()
            .map(|h| h.snapshot())
            .unwrap_or_default()
    }

    pub fn runtime(&self) -> Option<&XlaRuntime> {
        self.runtime.as_deref()
    }

    /// Synchronous stable merge of two sorted keyed blocks. The hybrid
    /// engine rejects non-finite keys at entry ([`validate_ingress`]).
    pub fn merge(&self, a: &KeyedBlock, b: &KeyedBlock) -> Result<KeyedBlock> {
        validate_ingress(self.config.engine, a).map_err(|e| anyhow!("{e}"))?;
        validate_ingress(self.config.engine, b).map_err(|e| anyhow!("{e}"))?;
        let t0 = Instant::now();
        let out = match self.config.engine {
            Engine::Rust => {
                let ra = to_recs(a);
                let rb = to_recs(b);
                let mut out = vec![KRec { key: F32Key(0.0), val: 0 }; ra.len() + rb.len()];
                merge_with_strategy(&ra, &rb, &mut out, self.config.threads, self.config.strategy);
                to_block(&out)
            }
            Engine::Hybrid => {
                let rt = self.runtime.as_ref().expect("hybrid runtime");
                let merger = XlaMerger::new(rt)?;
                let out = self.hybrid_merge(&merger, a, b)?;
                self.stats.xla_calls.fetch_add(merger.calls.get() as u64, Ordering::Relaxed);
                out
            }
        };
        self.note_job(a.len() + b.len(), t0);
        Ok(out)
    }

    /// Synchronous stable sort of a keyed block. The hybrid engine
    /// rejects non-finite keys at entry ([`validate_ingress`]).
    pub fn sort(&self, data: &KeyedBlock) -> Result<KeyedBlock> {
        validate_ingress(self.config.engine, data).map_err(|e| anyhow!("{e}"))?;
        let t0 = Instant::now();
        let out = match self.config.engine {
            Engine::Rust => {
                let mut recs = to_recs(data);
                parallel_merge_sort_with(&mut recs, self.config.threads, self.config.strategy);
                to_block(&recs)
            }
            Engine::Hybrid => {
                let rt = self.runtime.as_ref().expect("hybrid runtime");
                let merger = XlaMerger::new(rt)?;
                let sorter = XlaSorter::new(rt)?;
                let batcher = crate::runtime::XlaBatchMerger::new(rt).ok();
                let out = self.hybrid_sort(&merger, batcher.as_ref(), &sorter, data)?;
                self.stats.xla_calls.fetch_add(
                    (merger.calls.get()
                        + sorter.calls.get()
                        + batcher.map(|b| b.calls.get()).unwrap_or(0))
                        as u64,
                    Ordering::Relaxed,
                );
                out
            }
        };
        self.note_job(data.len(), t0);
        Ok(out)
    }

    /// Hybrid merge: XLA per-block stable merges composed by the
    /// paper's partition. The two inputs are partitioned with the
    /// five-case classifier; each task's (A-part, B-part) pair — both
    /// `O(n/p)` and within artifact capacity by construction of `p` —
    /// is merged on the XLA executable; results concatenate by task
    /// output offset.
    fn hybrid_merge(
        &self,
        merger: &XlaMerger<'_>,
        a: &KeyedBlock,
        b: &KeyedBlock,
    ) -> Result<KeyedBlock> {
        let cap = merger.max_block();
        let ra = to_recs(a);
        let rb = to_recs(b);
        // Choose p so every task fits the artifact: tasks are at most
        // 2*ceil(max(n,m)/p) elements total, each side <= cap.
        let biggest = ra.len().max(rb.len());
        let p = crate::util::div_ceil(biggest.max(1), cap / 2).max(1);
        let part = crate::core::Partition::compute(&ra, &rb, p);
        let tasks = part.tasks();
        let mut out = KeyedBlock { keys: vec![0.0; a.len() + b.len()], vals: vec![0; a.len() + b.len()] };
        let mut ordered: Vec<&crate::core::MergeTask> = tasks.iter().collect();
        ordered.sort_by_key(|t| t.c_off);
        for t in ordered {
            let ab = KeyedBlock {
                keys: a.keys[t.a.clone()].to_vec(),
                vals: a.vals[t.a.clone()].to_vec(),
            };
            let bb = KeyedBlock {
                keys: b.keys[t.b.clone()].to_vec(),
                vals: b.vals[t.b.clone()].to_vec(),
            };
            let merged = if bb.is_empty() {
                ab
            } else if ab.is_empty() {
                bb
            } else {
                merger.merge(&ab, &bb)?
            };
            out.keys[t.c_off..t.c_off + merged.len()].copy_from_slice(&merged.keys);
            out.vals[t.c_off..t.c_off + merged.len()].copy_from_slice(&merged.vals);
        }
        Ok(out)
    }

    /// Hybrid sort: leaf blocks sorted on the XLA sort executable,
    /// then pairwise XLA merges while runs fit the merge artifact,
    /// then the paper's rust parallel merge for the upper rounds.
    fn hybrid_sort(
        &self,
        merger: &XlaMerger<'_>,
        batcher: Option<&crate::runtime::XlaBatchMerger<'_>>,
        sorter: &XlaSorter<'_>,
        data: &KeyedBlock,
    ) -> Result<KeyedBlock> {
        let n = data.len();
        if n == 0 {
            return Ok(data.clone());
        }
        let leaf = self.config.leaf_block.min(sorter.max_block());
        // Leaf stage: sort ceil(n/leaf) blocks on XLA.
        let mut runs: Vec<KeyedBlock> = Vec::new();
        let mut off = 0;
        while off < n {
            let hi = (off + leaf).min(n);
            let block = KeyedBlock {
                keys: data.keys[off..hi].to_vec(),
                vals: data.vals[off..hi].to_vec(),
            };
            runs.push(sorter.sort(&block)?);
            off = hi;
        }
        // XLA merge rounds while run length fits the artifact.
        let cap = merger.max_block();
        while runs.len() > 1 {
            let use_xla = runs[0].len() <= cap;
            // Dynamic batching: when the whole round fits the batch
            // artifact, pack all of the round's pair merges into
            // ceil(pairs / batch) executable calls instead of one call
            // per pair (§Perf: 8x fewer dispatches on the leaf rounds).
            if let Some(b) = batcher {
                if use_xla && runs[0].len() <= b.block && runs.len() >= 4 {
                    let mut pairs = Vec::with_capacity(runs.len() / 2);
                    let mut i = 0;
                    while i + 1 < runs.len() {
                        if runs[i].len() <= b.block && runs[i + 1].len() <= b.block {
                            pairs.push((runs[i].clone(), runs[i + 1].clone()));
                            i += 2;
                        } else {
                            break;
                        }
                    }
                    if pairs.len() == runs.len() / 2 {
                        let mut next = b.merge_many(&pairs)?;
                        if runs.len() % 2 == 1 {
                            next.push(runs.pop().unwrap());
                        }
                        runs = next;
                        continue;
                    }
                }
            }
            let mut next = Vec::with_capacity(runs.len() / 2 + 1);
            let mut i = 0;
            while i < runs.len() {
                if i + 1 < runs.len() {
                    let (x, y) = (&runs[i], &runs[i + 1]);
                    if use_xla && x.len() <= cap && y.len() <= cap {
                        next.push(merger.merge(x, y)?);
                    } else {
                        // Upper rounds: the paper's rust parallel merge.
                        next.push(self.rust_merge_blocks(x, y));
                    }
                } else {
                    next.push(runs[i].clone());
                }
                i += 2;
            }
            runs = next;
        }
        Ok(runs.pop().unwrap())
    }

    fn rust_merge_blocks(&self, a: &KeyedBlock, b: &KeyedBlock) -> KeyedBlock {
        merge_blocks(a, b, self.config.threads, self.config.strategy)
    }

    /// Batched stable merge of many small job pairs. The hybrid engine
    /// packs jobs into the `merge_batch*` artifact (one executable call
    /// per `batch` jobs — the dynamic-batching win); the rust engine
    /// distributes jobs over the worker threads.
    pub fn merge_many(
        &self,
        jobs: &[(KeyedBlock, KeyedBlock)],
    ) -> Result<Vec<KeyedBlock>> {
        let t0 = Instant::now();
        let total: usize = jobs.iter().map(|(a, b)| a.len() + b.len()).sum();
        let out = match self.config.engine {
            Engine::Rust => {
                // All jobs fan out over the shared executor in one
                // scope; each job's internal merge phases nest on the
                // same workers.
                let threads = self.config.threads;
                let strategy = self.config.strategy;
                let mut results: Vec<Option<KeyedBlock>> = Vec::with_capacity(jobs.len());
                results.resize_with(jobs.len(), || None);
                crate::exec::global().scope(|s| {
                    for ((a, b), slot) in jobs.iter().zip(results.iter_mut()) {
                        s.spawn(move || {
                            *slot = Some(merge_blocks(a, b, threads, strategy));
                        });
                    }
                });
                results
                    .into_iter()
                    .map(|r| r.expect("merge job completed"))
                    .collect()
            }
            Engine::Hybrid => {
                for (a, b) in jobs {
                    validate_ingress(Engine::Hybrid, a).map_err(|e| anyhow!("{e}"))?;
                    validate_ingress(Engine::Hybrid, b).map_err(|e| anyhow!("{e}"))?;
                }
                let rt = self.runtime.as_ref().expect("hybrid runtime");
                let batcher = crate::runtime::XlaBatchMerger::new(rt)?;
                // Jobs too large for the batch artifact go one-by-one
                // through the plain merger; the rest are batched.
                let merger = XlaMerger::new(rt)?;
                let mut small_idx = Vec::new();
                let mut small = Vec::new();
                let mut results: Vec<Option<KeyedBlock>> = vec![None; jobs.len()];
                for (i, (a, b)) in jobs.iter().enumerate() {
                    if a.len() <= batcher.block && b.len() <= batcher.block {
                        small_idx.push(i);
                        small.push((a.clone(), b.clone()));
                    } else {
                        results[i] = Some(merger.merge(a, b)?);
                    }
                }
                for (i, r) in small_idx.into_iter().zip(batcher.merge_many(&small)?) {
                    results[i] = Some(r);
                }
                self.stats.xla_calls.fetch_add(
                    (batcher.calls.get() + merger.calls.get()) as u64,
                    Ordering::Relaxed,
                );
                results.into_iter().map(|r| r.unwrap()).collect()
            }
        };
        self.stats.jobs.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        self.stats.elements.fetch_add(total as u64, Ordering::Relaxed);
        self.stats
            .busy_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// Start building an asynchronous sort submission: pick a
    /// [`JobClass`] with [`JobBuilder::class`], then submit one block
    /// ([`JobBuilder::submit`]) or a batch ([`JobBuilder::batch`]).
    /// The single entry point the `submit_sort` /
    /// `submit_background` / `submit_sort_batch` wrappers delegate to.
    ///
    /// ```
    /// use traff_merge::coordinator::{Config, MergeService};
    /// use traff_merge::exec::JobClass;
    /// use traff_merge::runtime::KeyedBlock;
    ///
    /// let svc = MergeService::new(Config::default()).unwrap();
    /// let block = KeyedBlock { keys: vec![2.0, 1.0], vals: vec![0, 1] };
    /// let rx = svc.job().class(JobClass::Background).submit(block);
    /// let sorted = rx.recv().unwrap().unwrap();
    /// assert_eq!(sorted.keys, vec![1.0, 2.0]);
    /// ```
    pub fn job(&self) -> JobBuilder<'_> {
        JobBuilder { svc: self, class: self.config.class, strategy: self.config.strategy }
    }

    /// Asynchronous sort submission under the service's configured
    /// class — thin wrapper over [`MergeService::job`]. For the rust
    /// engine the job runs through the admission-controlled worker
    /// pool (data is moved, all-Send); the hybrid engine executes
    /// synchronously on the caller thread because PJRT handles are not
    /// `Send` in the `xla` crate — the pool still decouples
    /// rust-engine traffic, which is the common concurrent case.
    pub fn submit_sort(
        &self,
        data: KeyedBlock,
    ) -> std::sync::mpsc::Receiver<Result<KeyedBlock, String>> {
        self.job().submit(data)
    }

    /// Background-lane sort submission — thin wrapper over
    /// [`MergeService::job`] with [`JobClass::Background`]: the job
    /// enters the executor's background injector lane (yielding to
    /// service traffic fleet-wide) regardless of `Config.class`, while
    /// still counting against this service's admission permits —
    /// maintenance cannot bypass the tenant's concurrency bound.
    pub fn submit_background(
        &self,
        data: KeyedBlock,
    ) -> std::sync::mpsc::Receiver<Result<KeyedBlock, String>> {
        self.job().class(JobClass::Background).submit(data)
    }

    fn submit_sort_class(
        &self,
        class: JobClass,
        strategy: MergeStrategy,
        data: KeyedBlock,
    ) -> std::sync::mpsc::Receiver<Result<KeyedBlock, String>> {
        match self.config.engine {
            Engine::Rust => {
                let threads = self.config.threads;
                let stats = Arc::clone(&self.stats);
                self.pool.submit_with_class(class, move || {
                    let t0 = Instant::now();
                    let mut recs = to_recs(&data);
                    parallel_merge_sort_with(&mut recs, threads, strategy);
                    let out = to_block(&recs);
                    stats.record(out.len(), t0);
                    Ok(out)
                })
            }
            Engine::Hybrid => {
                let (tx, rx) = std::sync::mpsc::channel();
                let _ = tx.send(self.sort(&data).map_err(|e| e.to_string()));
                rx
            }
        }
    }

    /// Batched asynchronous sort submission — thin wrapper over
    /// [`MergeService::job`]: the whole job list is handed to the
    /// admission-controlled pool in one pass — up to `Config.threads`
    /// jobs are in flight at once, the rest follow in submission order
    /// as permits free up. The receiver yields `(job index, result)`
    /// pairs in completion order. The hybrid engine executes inline on
    /// the caller thread (PJRT handles are not `Send`).
    pub fn submit_sort_batch(
        &self,
        blocks: Vec<KeyedBlock>,
    ) -> std::sync::mpsc::Receiver<(usize, Result<KeyedBlock, String>)> {
        self.job().batch(blocks)
    }

    fn submit_sort_batch_class(
        &self,
        class: JobClass,
        strategy: MergeStrategy,
        blocks: Vec<KeyedBlock>,
    ) -> std::sync::mpsc::Receiver<(usize, Result<KeyedBlock, String>)> {
        match self.config.engine {
            Engine::Rust => {
                let threads = self.config.threads;
                let jobs: Vec<_> = blocks
                    .into_iter()
                    .map(|data| {
                        let stats = Arc::clone(&self.stats);
                        move || {
                            let t0 = Instant::now();
                            let mut recs = to_recs(&data);
                            parallel_merge_sort_with(&mut recs, threads, strategy);
                            let out = to_block(&recs);
                            stats.record(out.len(), t0);
                            Ok::<KeyedBlock, String>(out)
                        }
                    })
                    .collect();
                self.pool.submit_many_with_class(class, jobs)
            }
            Engine::Hybrid => {
                let (tx, rx) = std::sync::mpsc::channel();
                for (i, data) in blocks.iter().enumerate() {
                    let _ = tx.send((i, self.sort(data).map_err(|e| e.to_string())));
                }
                rx
            }
        }
    }

    fn note_job(&self, elems: usize, t0: Instant) {
        self.stats.record(elems, t0);
    }

    /// Open an independent stream and return its [`StreamHandle`]: the
    /// handle-based streaming API. Every call opens a fresh tenant
    /// (own store, own sequence clock, own background compaction) —
    /// handles don't touch the service's implicit default stream, so
    /// a service can serve several streams at once. Clone the handle
    /// freely; take one [`StreamHandle::writer`] per writer thread.
    pub fn open_stream(&self, cfg: StreamConfig) -> Result<StreamHandle> {
        Ok(StreamHandle { tenant: StreamTenant::new(cfg, &self.config.tenant)? })
    }

    /// [`MergeService::open_stream`] over a recovered store: rebuild
    /// the stream from the spill directory named in `cfg`
    /// ([`RunStore::recover`]) — the manifest is replayed, orphaned
    /// run files are swept, and every sealed run becomes scan-visible
    /// again behind a fresh handle.
    pub fn open_stream_recovered(&self, cfg: StreamConfig) -> Result<StreamHandle> {
        Ok(StreamHandle { tenant: StreamTenant::recover(cfg, &self.config.tenant)? })
    }

    /// The service's implicit default stream as a [`StreamHandle`] —
    /// what the deprecated single-tenant wrappers delegate to.
    fn default_handle(&self) -> StreamHandle {
        StreamHandle { tenant: Arc::clone(self.stream_tenant()) }
    }

    /// Create this service's **default** streaming tenant with an
    /// explicit [`StreamConfig`]. Optional — the first
    /// [`MergeService::ingest`] or [`MergeService::scan`] lazily
    /// creates an in-memory tenant with default capacity otherwise —
    /// but must come first when used: fails if the tenant already
    /// exists.
    #[deprecated(note = "use `open_stream`, which returns a StreamHandle instead of \
                         binding the service's single implicit stream")]
    pub fn init_stream(&self, cfg: StreamConfig) -> Result<()> {
        let tenant = StreamTenant::new(cfg, &self.config.tenant)?;
        self.stream
            .set(tenant)
            .map_err(|_| anyhow!("stream already initialized for this service"))
    }

    /// Restart this service's **default** streaming tenant from the
    /// spill directory named in `cfg` ([`RunStore::recover`]): the
    /// manifest is replayed, orphaned run files are swept, and every
    /// sealed run becomes scan-visible again. Like `init_stream`, must
    /// come before any lazy tenant creation.
    #[deprecated(note = "use `open_stream_recovered`, which returns a StreamHandle \
                         instead of binding the service's single implicit stream")]
    pub fn recover_stream(&self, cfg: StreamConfig) -> Result<()> {
        let tenant = StreamTenant::recover(cfg, &self.config.tenant)?;
        self.stream
            .set(tenant)
            .map_err(|_| anyhow!("stream already initialized for this service"))
    }

    fn stream_tenant(&self) -> &Arc<StreamTenant> {
        self.stream.get_or_init(|| {
            StreamTenant::new(
                StreamConfig {
                    threads: self.config.threads.max(1),
                    strategy: self.config.strategy,
                    ..StreamConfig::default()
                },
                &self.config.tenant,
            )
            .expect("in-memory stream tenant construction cannot fail")
        })
    }

    /// Streaming ingest into this service's **default** stream: append
    /// a keyed block through the implicit serialized writer. Records
    /// buffer into bounded runs; full runs seal (a stable parallel
    /// sort) and, past the configured fanout, trigger a
    /// background-lane compaction. Admission-controlled like every
    /// submitted job — the call occupies one of the tenant's permits
    /// while it runs. Returns the number of runs this block sealed.
    ///
    /// The stream path is engine-independent (always the rust
    /// total-order path): non-finite keys are accepted and ordered by
    /// `f32::total_cmp`, exactly like [`Engine::Rust`] sorts.
    #[deprecated(note = "use `open_stream` and the StreamHandle's per-thread writers; \
                         this wrapper serializes all callers on one implicit shard")]
    pub fn ingest(&self, block: KeyedBlock) -> Result<usize> {
        let handle = self.default_handle();
        let stats = Arc::clone(&self.stats);
        let rx = self.pool.submit(move || {
            let t0 = Instant::now();
            let r = handle.tenant.ingest_block(&block);
            if r.is_ok() {
                stats.record(block.len(), t0);
            }
            r
        });
        Ok(rx.recv().map_err(|_| anyhow!("ingest job panicked"))??)
    }

    /// Seal the **default** stream's partially filled buffer (if any)
    /// so its records become scan-visible. Returns the sealed
    /// generation.
    #[deprecated(note = "use `open_stream` and StreamHandle::flush (or flush each \
                         per-thread writer)")]
    pub fn flush_stream(&self) -> Result<Option<u64>> {
        let handle = self.default_handle();
        let rx = self.pool.submit(move || handle.tenant.flush());
        Ok(rx.recv().map_err(|_| anyhow!("flush job panicked"))??)
    }

    /// Stable merged scan of the stream's sealed data: globally
    /// key-sorted (under `f32::total_cmp`), duplicate keys in exact
    /// ingest order. Runs against a snapshot, so a concurrent
    /// compaction neither blocks nor disturbs it. Admission-controlled.
    pub fn scan(&self) -> Result<KeyedBlock> {
        let tenant = Arc::clone(self.stream_tenant());
        let stats = Arc::clone(&self.stats);
        let rx = self.pool.submit(move || {
            let t0 = Instant::now();
            let r = tenant.scan_block();
            if let Ok(out) = &r {
                stats.record(out.len(), t0);
            }
            r
        });
        rx.recv().map_err(|_| anyhow!("scan job panicked"))?.map_err(|e| anyhow!("{e}"))
    }

    /// Store statistics of this service's stream, if one exists.
    pub fn stream_stats(&self) -> Option<stream::StoreStats> {
        self.stream.get().map(|t| t.store.stats())
    }

    /// Wait (bounded, ~5s) for any scheduled background compaction
    /// drain of the default stream to go idle — a reporting
    /// convenience so the CLI's final stats describe a settled store;
    /// correctness never needs it.
    pub fn stream_quiesce(&self) {
        if let Some(tenant) = self.stream.get() {
            tenant.quiesce();
        }
    }

    /// End-of-batch telemetry checkpoint: force a window roll on the
    /// shared executor and run the tunables recalibration against the
    /// freshly recorded rates, so a phase shift this batch caused (a
    /// submission burst, a contention spike) is acted on — and
    /// observable via [`crate::exec::recalibration_stats`] — even when
    /// the batch finished inside one periodic epoch. Returns the
    /// windowed rates and the number of tunable adjustments applied.
    pub fn recalibration_checkpoint(
        &self,
    ) -> (crate::exec::telemetry::WindowRates, usize) {
        self.pool.recalibrate_now()
    }
}

/// Builder for asynchronous sort submissions ([`MergeService::job`]):
/// one entry point where `submit_sort`, `submit_background` and
/// `submit_sort_batch` used to be three. Configure the
/// [`JobClass`] with [`JobBuilder::class`] (defaults to the service's
/// `Config.class`), then finish with [`JobBuilder::submit`] for one
/// block or [`JobBuilder::batch`] for many. Either way the job(s) run
/// under the service's admission permits.
#[must_use = "a JobBuilder does nothing until `submit` or `batch` is called"]
pub struct JobBuilder<'a> {
    svc: &'a MergeService,
    class: JobClass,
    strategy: MergeStrategy,
}

impl<'a> JobBuilder<'a> {
    /// Override the [`JobClass`] for this submission (e.g.
    /// [`JobClass::Background`] to yield to service traffic
    /// fleet-wide while still holding one of this service's permits).
    pub fn class(mut self, class: JobClass) -> JobBuilder<'a> {
        self.class = class;
        self
    }

    /// Override the [`MergeStrategy`] for this submission's sort
    /// rounds (defaults to the service's `Config.strategy`).
    pub fn strategy(mut self, strategy: MergeStrategy) -> JobBuilder<'a> {
        self.strategy = strategy;
        self
    }

    /// Submit one sort job; returns a receiver for its result.
    pub fn submit(
        self,
        data: KeyedBlock,
    ) -> std::sync::mpsc::Receiver<Result<KeyedBlock, String>> {
        self.svc.submit_sort_class(self.class, self.strategy, data)
    }

    /// Submit a batch of sort jobs in one admission pass; the receiver
    /// yields `(job index, result)` pairs in completion order.
    pub fn batch(
        self,
        blocks: Vec<KeyedBlock>,
    ) -> std::sync::mpsc::Receiver<(usize, Result<KeyedBlock, String>)> {
        self.svc.submit_sort_batch_class(self.class, self.strategy, blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sorted_block(rng: &mut Rng, n: usize, base: i32) -> KeyedBlock {
        let mut keys: Vec<f32> = (0..n).map(|_| rng.range(0, 1000) as f32).collect();
        keys.sort_by(|a, b| a.total_cmp(b));
        KeyedBlock { keys, vals: (0..n as i32).map(|i| base + i).collect() }
    }

    #[test]
    fn rust_engine_merge_and_sort() {
        let svc = MergeService::new(Config {
            threads: 4,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let mut rng = Rng::new(7);
        let a = sorted_block(&mut rng, 500, 0);
        let b = sorted_block(&mut rng, 700, 10_000);
        let m = svc.merge(&a, &b).unwrap();
        assert!(m.is_key_sorted());
        assert_eq!(m.len(), 1200);

        let raw = KeyedBlock {
            keys: (0..2000).map(|_| rng.range(0, 100) as f32).collect(),
            vals: (0..2000).collect(),
        };
        let s = svc.sort(&raw).unwrap();
        assert!(s.is_key_sorted());
        // Stability: equal keys keep increasing vals.
        for w in s.keys.windows(2).zip(s.vals.windows(2)) {
            if w.0[0] == w.0[1] {
                assert!(w.1[0] < w.1[1], "instability");
            }
        }
        let (jobs, elems, _, _) = svc.stats.snapshot();
        assert_eq!(jobs, 2);
        assert_eq!(elems, 3200);
    }

    #[test]
    fn adaptive_strategy_end_to_end() {
        let svc = MergeService::new(Config {
            threads: 4,
            strategy: MergeStrategy::Adaptive,
            ..Config::default()
        })
        .unwrap();
        let mut rng = Rng::new(23);
        let a = sorted_block(&mut rng, 800, 0);
        let b = sorted_block(&mut rng, 600, 10_000);
        let m = svc.merge(&a, &b).unwrap();
        assert!(m.is_key_sorted());
        assert_eq!(m.len(), 1400);
        let expect = merge_blocks(&a, &b, 1, MergeStrategy::Fixed);
        assert_eq!(m.keys, expect.keys);
        assert_eq!(m.vals, expect.vals);

        let raw = KeyedBlock {
            keys: (0..3000).map(|_| rng.range(0, 50) as f32).collect(),
            vals: (0..3000).collect(),
        };
        let s = svc.sort(&raw).unwrap();
        assert!(s.is_key_sorted());
        for w in s.keys.windows(2).zip(s.vals.windows(2)) {
            if w.0[0] == w.0[1] {
                assert!(w.1[0] < w.1[1], "adaptive sort instability");
            }
        }
        // Per-job override on a Fixed-configured service.
        let fixed_svc = MergeService::new(Config { threads: 4, ..Config::default() }).unwrap();
        let rx = fixed_svc.job().strategy(MergeStrategy::Adaptive).submit(raw);
        let sorted = rx.recv().unwrap().unwrap();
        assert_eq!(sorted.keys, s.keys);
        assert_eq!(sorted.vals, s.vals);
    }

    #[test]
    fn batched_sort_submission() {
        let svc = MergeService::new(Config {
            threads: 4,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let mut rng = Rng::new(19);
        let blocks: Vec<KeyedBlock> = (0..6)
            .map(|_| {
                let n = 500 + rng.index(1500);
                KeyedBlock {
                    keys: (0..n).map(|_| rng.range(0, 200) as f32).collect(),
                    vals: (0..n as i32).collect(),
                }
            })
            .collect();
        let lens: Vec<usize> = blocks.iter().map(|b| b.len()).collect();
        let rx = svc.submit_sort_batch(blocks);
        let mut results: Vec<Option<KeyedBlock>> = (0..6).map(|_| None).collect();
        for (i, r) in rx.iter() {
            results[i] = Some(r.unwrap());
        }
        for (i, out) in results.into_iter().enumerate() {
            let out = out.expect("every job reports back");
            assert_eq!(out.len(), lens[i]);
            assert!(out.is_key_sorted());
            // Stability: equal keys keep increasing vals.
            for w in out.keys.windows(2).zip(out.vals.windows(2)) {
                if w.0[0] == w.0[1] {
                    assert!(w.1[0] < w.1[1], "instability in batched sort");
                }
            }
        }
        let (jobs, _, _, _) = svc.stats.snapshot();
        assert_eq!(jobs, 6);
    }

    #[test]
    fn parallel_merge_many_matches_sequential() {
        let svc = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let mut rng = Rng::new(23);
        let jobs: Vec<(KeyedBlock, KeyedBlock)> = (0..10)
            .map(|_| {
                let n = 300 + rng.index(700);
                let m = 300 + rng.index(700);
                (sorted_block(&mut rng, n, 0), sorted_block(&mut rng, m, 50_000))
            })
            .collect();
        let outs = svc.merge_many(&jobs).unwrap();
        for ((a, b), out) in jobs.iter().zip(&outs) {
            let expect = merge_blocks(a, b, 1, MergeStrategy::Fixed);
            assert_eq!(out.keys, expect.keys);
            assert_eq!(out.vals, expect.vals);
        }
    }

    #[test]
    fn krec_orders_by_key_only() {
        let a = KRec { key: F32Key(1.0), val: 5 };
        let b = KRec { key: F32Key(1.0), val: 9 };
        assert_eq!(a, b);
    }

    /// NaN-key regression: the engines order f32 keys by
    /// `f32::total_cmp` (via `F32Key`), so NaN keys must sort to a
    /// deterministic position (above `+inf` for positive NaN) instead
    /// of violating the sort invariant the service asserts — the old
    /// `<=`-based check was vacuously false next to any NaN.
    #[test]
    fn nan_keys_sort_under_total_order() {
        let svc = MergeService::new(Config {
            threads: 4,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let n = 512usize;
        let mut keys: Vec<f32> = (0..n).map(|i| ((i * 37) % 101) as f32).collect();
        for i in (0..n).step_by(17) {
            keys[i] = f32::NAN;
        }
        let nans = keys.iter().filter(|k| k.is_nan()).count();
        assert!(nans > 0);
        let out = svc
            .sort(&KeyedBlock { keys, vals: (0..n as i32).collect() })
            .unwrap();
        assert!(out.is_key_sorted(), "total-order invariant broken by NaN keys");
        // Positive NaN is the maximum under total_cmp: all NaNs at the
        // tail, the finite prefix ordinarily sorted.
        assert!(out.keys[out.len() - nans..].iter().all(|k| k.is_nan()));
        assert!(out.keys[..out.len() - nans].windows(2).all(|w| w[0] <= w[1]));
        // Stability: the NaN payloads keep their submission order.
        let nan_vals: Vec<i32> = out.vals[out.len() - nans..].to_vec();
        let expect: Vec<i32> = (0..n).step_by(17).map(|i| i as i32).collect();
        assert_eq!(nan_vals, expect, "NaN records lost their stable order");
    }

    /// Satellite: the non-finite-key ingress policy. The hybrid
    /// engine (XLA pads with `+inf`) rejects NaN/±inf at job entry
    /// with the offending index; the rust engine accepts them (it
    /// orders by `total_cmp` end to end).
    #[test]
    fn hybrid_ingress_rejects_non_finite_keys() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let block = KeyedBlock { keys: vec![1.0, bad, 3.0], vals: vec![0, 1, 2] };
            let err = validate_ingress(Engine::Hybrid, &block)
                .expect_err("hybrid must reject non-finite keys");
            assert!(err.contains("index 1"), "error names the index: {err}");
            // The rust engine's policy is acceptance.
            assert!(validate_ingress(Engine::Rust, &block).is_ok());
        }
        let finite = KeyedBlock { keys: vec![1.0, 2.0], vals: vec![0, 1] };
        assert!(validate_ingress(Engine::Hybrid, &finite).is_ok());
    }

    /// The rust engine accepts non-finite keys END TO END (not just in
    /// the validator): ±inf and NaN sort to their total_cmp positions
    /// through the full service path.
    #[test]
    fn rust_engine_sorts_non_finite_keys_end_to_end() {
        let svc = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let keys = vec![2.0, f32::NEG_INFINITY, f32::NAN, 0.5, f32::INFINITY, 1.0];
        let out = svc
            .sort(&KeyedBlock { keys, vals: (0..6).collect() })
            .unwrap();
        assert!(out.is_key_sorted());
        // total_cmp order: -inf < finite < +inf < NaN.
        assert_eq!(out.keys[0], f32::NEG_INFINITY);
        assert_eq!(out.keys[4], f32::INFINITY);
        assert!(out.keys[5].is_nan());
        assert_eq!(&out.keys[1..4], &[0.5, 1.0, 2.0]);
    }

    /// Tentpole: `submit_background` completes through the background
    /// lane and still respects the service's admission bound (it
    /// cannot bypass the tenant's permit count).
    #[test]
    fn background_submission_sorts_and_respects_admission() {
        let svc = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let mut rng = Rng::new(91);
        let blocks: Vec<KeyedBlock> = (0..6)
            .map(|_| KeyedBlock {
                keys: (0..800).map(|_| rng.range(0, 300) as f32).collect(),
                vals: (0..800).collect(),
            })
            .collect();
        let rxs: Vec<_> = blocks.into_iter().map(|b| svc.submit_background(b)).collect();
        for rx in rxs {
            let out = rx.recv().expect("job reports back").expect("sort succeeds");
            assert!(out.is_key_sorted());
        }
        // All jobs went through the pool's permits (none in flight
        // after completion) and the stats counted them.
        let (jobs, _, _, _) = svc.stats.snapshot();
        assert_eq!(jobs, 6);
    }

    /// The stream codec is exact: `f32_ordered` is a total-order
    /// isomorphism onto `i64` (agrees with `total_cmp` on every pair,
    /// NaN and signed zero included) and `f32_unordered` inverts it
    /// bit-for-bit.
    #[test]
    fn stream_key_codec_preserves_total_order() {
        let samples = [
            f32::NEG_INFINITY,
            -1e30,
            -2.0,
            -0.0,
            0.0,
            f32::MIN_POSITIVE,
            0.5,
            1.0,
            1e30,
            f32::INFINITY,
            f32::NAN,
            -f32::NAN,
        ];
        for &x in &samples {
            // Bit-exact round trip (== would fail for NaN).
            assert_eq!(f32_unordered(f32_ordered(x)).to_bits(), x.to_bits(), "{x}");
            for &y in &samples {
                assert_eq!(
                    f32_ordered(x).cmp(&f32_ordered(y)),
                    x.total_cmp(&y),
                    "order mismatch at {x} vs {y}"
                );
            }
        }
        assert_eq!(unpack_val(pack_tag(7, -3).unwrap()), -3);
        assert_eq!(unpack_val(pack_tag(7, i32::MAX).unwrap()), i32::MAX);
        assert_eq!(pack_tag(7, -1).unwrap() >> 32, 7, "sequence rides the high bits");
    }

    /// Satellite: the 2^32-record stream cap fails typed at the exact
    /// boundary instead of silently wrapping the packed tag's sequence
    /// bits (which would corrupt the stability order).
    #[test]
    fn stream_record_cap_is_a_typed_boundary_error() {
        // The last admissible sequence packs fine at both payload
        // extremes, and round-trips the payload.
        let last = STREAM_RECORD_CAP - 1;
        for val in [i32::MIN, -1, 0, i32::MAX] {
            let tag = pack_tag(last, val).unwrap();
            assert_eq!(unpack_val(tag), val);
            assert_eq!(tag >> 32, last);
        }
        // The first inadmissible sequence is refused, typed.
        let err = pack_tag(STREAM_RECORD_CAP, 0).unwrap_err();
        assert_eq!(err, RecordCapExceeded { seq: STREAM_RECORD_CAP });
        assert_eq!(err.seq, STREAM_RECORD_CAP);
        let msg = err.to_string();
        assert!(msg.contains(&STREAM_RECORD_CAP.to_string()), "message names the cap: {msg}");
        assert!(pack_tag(STREAM_RECORD_CAP + 123, 5).is_err());
    }

    /// Tentpole: the streaming facade end to end — ingest across many
    /// runs, background compaction, flush, scan. The scan is globally
    /// sorted and duplicate keys come back in exact ingest order.
    /// Exercises the deprecated single-tenant wrappers on purpose:
    /// they must keep their exact semantics over the default handle.
    #[test]
    #[allow(deprecated)]
    fn stream_ingest_compact_scan_is_sorted_and_stable() {
        let svc = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        svc.init_stream(StreamConfig {
            run_capacity: 64,
            fanout: 2,
            threads: 2,
            ..StreamConfig::default()
        })
        .unwrap();
        let blocks = 5usize;
        let per_block = 50usize;
        for b in 0..blocks {
            let block = KeyedBlock {
                // Heavy duplication across blocks: 13 distinct keys.
                keys: (0..per_block).map(|i| ((b * per_block + i) * 7 % 13) as f32).collect(),
                vals: (0..per_block).map(|i| (b * per_block + i) as i32).collect(),
            };
            svc.ingest(block).unwrap();
        }
        svc.flush_stream().unwrap();
        svc.stream_quiesce();
        let out = svc.scan().unwrap();
        let n = blocks * per_block;
        assert_eq!(out.len(), n);
        assert!(out.is_key_sorted());
        // Stability: vals are the global ingest index, so equal keys
        // must carry strictly increasing vals.
        for i in 1..n {
            if out.keys[i - 1] == out.keys[i] {
                assert!(
                    out.vals[i - 1] < out.vals[i],
                    "ingest order lost at scan index {i}"
                );
            }
        }
        let stats = svc.stream_stats().expect("stream exists");
        assert_eq!(stats.records, n as u64);
        assert!(stats.sealed_runs >= 3, "capacity 64 over 250 records seals >= 3 runs");
        assert!(stats.compactions >= 1, "fanout 2 must have compacted");
        assert!(stats.runs <= 3, "drained to (near) the fanout");
        // Admission/stat bookkeeping: 5 ingests + 1 scan recorded.
        let (jobs, _, _, _) = svc.stats.snapshot();
        assert_eq!(jobs, 6);
        // The tenant exists now; re-initializing must fail.
        assert!(svc.init_stream(StreamConfig::default()).is_err());
    }

    /// Tentpole: the restart facade. A service that spilled its stream
    /// durably can be rebuilt with [`MergeService::recover_stream`] and
    /// serves the identical stable scan.
    #[test]
    #[cfg(not(miri))]
    #[allow(deprecated)]
    fn recover_stream_restores_the_scan() {
        let dir = std::env::temp_dir().join(format!("traff-svc-recover-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = StreamConfig {
            run_capacity: 32,
            fanout: 2,
            threads: 2,
            spill: Some(dir.clone()),
            page_records: 16,
            ..StreamConfig::default()
        };
        let before;
        {
            let svc = MergeService::new(Config {
                threads: 2,
                engine: Engine::Rust,
                leaf_block: 1024,
                ..Config::default()
            })
            .unwrap();
            svc.init_stream(cfg.clone()).unwrap();
            let mut rng = Rng::new(47);
            for _ in 0..4 {
                let block = KeyedBlock {
                    keys: (0..40).map(|_| rng.range(0, 9) as f32).collect(),
                    vals: (0..40).collect(),
                };
                svc.ingest(block).unwrap();
            }
            svc.flush_stream().unwrap();
            svc.stream_quiesce();
            before = svc.scan().unwrap();
        }
        let svc2 = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        svc2.recover_stream(cfg).unwrap();
        let after = svc2.scan().unwrap();
        assert_eq!(after.keys, before.keys);
        assert_eq!(after.vals, before.vals);
        assert!(svc2.init_stream(StreamConfig::default()).is_err(), "tenant already exists");
        drop(svc2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The stream path accepts non-finite keys end to end (it is the
    /// rust total-order path regardless of engine).
    #[test]
    #[allow(deprecated)]
    fn stream_orders_non_finite_keys_like_total_cmp() {
        let svc = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let keys = vec![2.0, f32::NAN, f32::NEG_INFINITY, 0.5, f32::INFINITY];
        svc.ingest(KeyedBlock { keys, vals: (0..5).collect() }).unwrap();
        svc.flush_stream().unwrap();
        let out = svc.scan().unwrap();
        assert!(out.is_key_sorted());
        assert_eq!(out.keys[0], f32::NEG_INFINITY);
        assert_eq!(&out.keys[1..3], &[0.5, 2.0]);
        assert_eq!(out.keys[3], f32::INFINITY);
        assert!(out.keys[4].is_nan());
        assert_eq!(out.vals, vec![2, 3, 0, 4, 1]);
    }

    #[test]
    fn nan_keys_merge_stably() {
        let svc = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        // Both inputs sorted under total_cmp (NaN last).
        let a = KeyedBlock { keys: vec![1.0, 2.0, f32::NAN], vals: vec![0, 1, 2] };
        let b = KeyedBlock { keys: vec![1.5, f32::NAN], vals: vec![10, 11] };
        let m = svc.merge(&a, &b).unwrap();
        assert!(m.is_key_sorted());
        assert_eq!(m.keys.iter().filter(|k| k.is_nan()).count(), 2);
        // Stable: for equal keys (the two NaNs) A's record precedes B's.
        assert_eq!(m.vals, vec![0, 10, 1, 2, 11]);
    }

    /// `IngestWriter` must be `Send`: one per thread, moved in.
    #[test]
    fn ingest_writer_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<IngestWriter>();
        assert_send::<StreamHandle>();
    }

    /// Tentpole: the handle-based API end to end — N writer threads
    /// each holding an owned [`IngestWriter`], duplicate-heavy keys,
    /// background compaction. The scan is globally sorted and each
    /// writer's ingest order survives exactly.
    #[test]
    fn handle_multi_writer_ingest_is_sorted_and_stable() {
        let svc = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let handle = svc
            .open_stream(StreamConfig {
                run_capacity: 32,
                fanout: 2,
                threads: 2,
                ..StreamConfig::default()
            })
            .unwrap();
        let (writers, per_writer) = if cfg!(miri) { (2, 12) } else { (4, 100) };
        std::thread::scope(|s| {
            for w in 0..writers {
                let mut wr = handle.writer();
                s.spawn(move || {
                    for i in 0..per_writer {
                        // 7 distinct keys; val encodes (writer, index).
                        let key = ((w * 5 + i) % 7) as f32;
                        wr.push(key, (w * per_writer + i) as i32).unwrap();
                    }
                    wr.flush().unwrap();
                });
            }
        });
        handle.quiesce();
        let out = handle.scan().unwrap();
        assert_eq!(out.len(), writers * per_writer);
        assert!(out.is_key_sorted());
        // Per-writer, per-key ingest order: vals of one writer within
        // one key group must be strictly increasing.
        let mut last = vec![vec![-1i64; 7]; writers];
        for (k, v) in out.keys.iter().zip(&out.vals) {
            let w = *v as usize / per_writer;
            let key = *k as usize;
            assert!(last[w][key] < *v as i64, "writer {w} reordered at key {key}");
            last[w][key] = *v as i64;
        }
        let stats = handle.stats();
        assert_eq!(stats.records, (writers * per_writer) as u64);
        assert!(stats.sealed_runs >= writers as u64, "each writer sealed at least once");
    }

    /// `open_stream` handles are independent tenants: they never touch
    /// the service's implicit default stream, so the deprecated
    /// `init_stream` still works afterwards — and two handles don't
    /// see each other's data.
    #[test]
    #[allow(deprecated)]
    fn open_stream_is_independent_of_the_default_tenant() {
        let svc = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let h1 = svc.open_stream(StreamConfig::default()).unwrap();
        let h2 = svc.open_stream(StreamConfig::default()).unwrap();
        h1.ingest(&KeyedBlock { keys: vec![1.0], vals: vec![10] }).unwrap();
        h1.flush().unwrap();
        assert_eq!(h1.scan().unwrap().len(), 1);
        assert_eq!(h2.scan().unwrap().len(), 0, "handles are separate tenants");
        // The default tenant is still unbound.
        svc.init_stream(StreamConfig::default()).unwrap();
        assert_eq!(svc.scan().unwrap().len(), 0);
        // A clone shares the tenant.
        let h1b = h1.clone();
        assert_eq!(h1b.scan().unwrap().len(), 1);
    }

    /// The config builder feeds the handle path: an invalid shape is
    /// refused before any store exists (typed, via anyhow).
    #[test]
    fn open_stream_rejects_invalid_config() {
        let svc = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let err = svc
            .open_stream(StreamConfig { fanout: 1, ..StreamConfig::default() })
            .expect_err("fanout < 2 must be refused");
        assert!(err.to_string().contains("fanout"), "names the field: {err}");
        // The typed StreamError variant carries through the boundary:
        // same message as the config validator's Config variant.
        let direct = StreamConfig::builder().fanout(1).build().unwrap_err();
        assert!(matches!(direct, StreamError::Config(_)));
        assert_eq!(err.to_string(), direct.to_string());
    }

    /// `JobBuilder` is the single submission entry point: explicit
    /// class + single and batch submission behave exactly like the
    /// wrappers they replaced (results sorted/stable, jobs counted).
    #[test]
    fn job_builder_submits_single_and_batch() {
        let svc = MergeService::new(Config {
            threads: 2,
            engine: Engine::Rust,
            leaf_block: 1024,
            ..Config::default()
        })
        .unwrap();
        let mut rng = Rng::new(53);
        let block = KeyedBlock {
            keys: (0..400).map(|_| rng.range(0, 50) as f32).collect(),
            vals: (0..400).collect(),
        };
        let out = svc
            .job()
            .class(JobClass::Background)
            .submit(block)
            .recv()
            .unwrap()
            .unwrap();
        assert!(out.is_key_sorted());
        let blocks: Vec<KeyedBlock> = (0..4)
            .map(|_| KeyedBlock {
                keys: (0..300).map(|_| rng.range(0, 40) as f32).collect(),
                vals: (0..300).collect(),
            })
            .collect();
        let rx = svc.job().batch(blocks);
        let mut seen = 0usize;
        for (_, r) in rx.iter() {
            assert!(r.unwrap().is_key_sorted());
            seen += 1;
        }
        assert_eq!(seen, 4);
        let (jobs, _, _, _) = svc.stats.snapshot();
        assert_eq!(jobs, 5, "builder path records every job");
    }
}

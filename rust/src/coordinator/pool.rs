//! Job-level entry point for the service layer: an **admission
//! controller** in front of the process-wide [`crate::exec::Executor`].
//!
//! # History — and what `size` means now
//!
//! Three generations of semantics live behind this one type:
//!
//! 1. **Pre-executor**: an independent mpsc worker pool — `threads = t`
//!    really ran `t` OS threads, *plus* a fresh `std::thread::scope`
//!    fleet inside every merge/sort call, oversubscribing the machine.
//! 2. **PR 1 (facade era)**: execution moved to the shared executor
//!    and `size` degraded into a *granularity hint* — it still set the
//!    `p` handed to the algorithms, but NOTHING bounded how many of a
//!    service's jobs ran at once: a tenant configured with
//!    `threads = 2` could occupy every worker in the fleet the moment
//!    it submitted a burst.
//! 3. **This PR (admission era)**: `size` is a real bound again, but
//!    at the right layer — a **semaphore of `size` permits acquired at
//!    job entry**. At most `size` of this pool's jobs are *admitted*
//!    (submitted to the executor) concurrently; the overflow waits in
//!    a pool-local FIFO and is dispatched, in submission order, as
//!    permits free up. Crucially the permits are NOT thread
//!    reservations: an admitted job still runs on the shared fleet,
//!    its internal parallel phases still fan out over every worker,
//!    and idle workers still help-steal it. Admission bounds a
//!    tenant's *concurrent footprint*, not its *speed*.
//!
//! Permits are released when a job finishes — including by panic (the
//! release rides a drop guard inside the wrapped job, so an unwinding
//! job cannot leak its permit). The caller-facing API is unchanged and
//! non-blocking: `submit` always returns a `Receiver` immediately;
//! admission only delays when the job starts.
//!
//! Each pool also carries a default [`JobClass`]: a background-class
//! pool's jobs enter the executor's background injector lane and yield
//! to service traffic fleet-wide (see [`crate::exec::injector`]). The
//! class decides *which lane* a job queues in; admission decides *how
//! many* of them may be dispatched at all. Note the permit is held
//! from dispatch to completion, INCLUDING any time the job waits in
//! its injector lane — so a background job parked behind fleet-wide
//! service traffic keeps holding its permit. Mixing both classes in
//! one pool therefore lets slow-to-schedule background work crowd out
//! the same pool's service submissions; tenants that want the classes
//! isolated from each other should run one pool per class (as `repro
//! serve` does with its two tenants), which is also the configuration
//! the admission bound is meant to protect.
//!
//! One sharp edge, inherent to any entry semaphore: a job that
//! submits to its OWN pool and blocks on the result can deadlock a
//! fully-admitted pool (the classic semaphore self-wait). Nested
//! parallelism does not do this — `exec::scope` is not admission
//! controlled — but job-level recursion through the same pool is on
//! the caller.

use crate::exec::JobClass;
use crate::obs::{trace, Hist, Registry, SpanKind};
use std::collections::VecDeque;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// The boxed-job shape handed to the executor.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Admission state: free permits plus the not-yet-admitted overflow,
/// in submission order. One short-held Mutex — admission is per JOB
/// (milliseconds of work), not per task, so this lock is nowhere near
/// the executor's lock-free hot paths.
struct AdmissionState {
    available: usize,
    /// `(job, class, queued_at)` — the timestamp feeds the per-class
    /// admission-wait histograms when the job is finally dispatched.
    pending: VecDeque<(Job, JobClass, Instant)>,
}

struct Admission {
    state: Mutex<AdmissionState>,
    /// Admission-wait latency per class (`pool.admission_wait.*`),
    /// indexed by [`JobClass::lane`]: submit → permit granted.
    /// Immediately admitted jobs record 0, so the histogram's count is
    /// the pool's total admissions and its upper buckets isolate the
    /// queued tail.
    wait: [Arc<Hist>; 2],
}

impl Admission {
    fn new(permits: usize) -> Admission {
        let registry = Registry::global();
        Admission {
            state: Mutex::new(AdmissionState {
                available: permits,
                pending: VecDeque::new(),
            }),
            wait: [
                registry.hist("pool.admission_wait.service"),
                registry.hist("pool.admission_wait.background"),
            ],
        }
    }

    /// Admit `job` now if a permit is free, else queue it. Dispatch
    /// happens outside the lock.
    fn admit(&self, job: Job, class: JobClass) {
        let (admitted, depth) = {
            let mut st = self.state.lock().unwrap();
            if st.available > 0 {
                st.available -= 1;
                (Some((job, class)), st.pending.len())
            } else {
                st.pending.push_back((job, class, Instant::now()));
                (None, st.pending.len())
            }
        };
        trace::instant(SpanKind::Submit, depth as u64);
        if let Some((job, class)) = admitted {
            self.wait[class.lane()].record(0);
            trace::instant(SpanKind::Admit, 0);
            crate::exec::global().submit_boxed(job, class);
        }
    }

    /// A job finished: hand its permit to the oldest queued job, or
    /// return it to the pool. Dispatch happens outside the lock (a
    /// worker thread calls this from inside the finished job).
    fn release(&self) {
        let next = {
            let mut st = self.state.lock().unwrap();
            match st.pending.pop_front() {
                Some(queued) => Some(queued),
                None => {
                    st.available += 1;
                    None
                }
            }
        };
        if let Some((job, class, queued_at)) = next {
            let waited = queued_at.elapsed();
            self.wait[class.lane()].record_duration(waited);
            trace::instant(SpanKind::Admit, waited.as_nanos() as u64);
            crate::exec::global().submit_boxed(job, class);
        }
    }
}

/// Releases the permit when dropped — the normal completion path and
/// the unwind path of a panicking job are the same code.
struct PermitGuard(Arc<Admission>);

impl Drop for PermitGuard {
    fn drop(&mut self) {
        self.0.release();
    }
}

/// Per-service admission controller over the shared executor. See the
/// module docs for the semantics of `size`.
pub struct WorkerPool {
    size: usize,
    class: JobClass,
    admission: Arc<Admission>,
}

impl WorkerPool {
    /// A service-class pool with `size` admission permits.
    pub fn new(size: usize) -> WorkerPool {
        WorkerPool::with_class(size, JobClass::Service)
    }

    /// A pool whose jobs default to `class` (see [`JobClass`]).
    pub fn with_class(size: usize, class: JobClass) -> WorkerPool {
        assert!(size > 0);
        WorkerPool { size, class, admission: Arc::new(Admission::new(size)) }
    }

    /// The admission bound (maximum concurrently admitted jobs).
    pub fn size(&self) -> usize {
        self.size
    }

    /// This pool's default job class.
    pub fn class(&self) -> JobClass {
        self.class
    }

    /// Jobs currently admitted (holding a permit). A steering/metrics
    /// snapshot — concurrent submit/release make it advisory.
    pub fn in_flight(&self) -> usize {
        self.size - self.admission.state.lock().unwrap().available
    }

    /// Jobs waiting for a permit.
    pub fn queued(&self) -> usize {
        self.admission.state.lock().unwrap().pending.len()
    }

    /// Snapshot of the shared executor's per-worker counters
    /// (executed / steals / steal misses / injector batches / parks /
    /// per-lane jobs) — the service-level window into the Chase–Lev
    /// substrate. See [`crate::exec::telemetry`] for field semantics.
    pub fn telemetry(&self) -> crate::exec::telemetry::Telemetry {
        crate::exec::global().telemetry()
    }

    /// Windowed (rate-based) view of the shared executor: per-second
    /// steal / injector / execution / per-lane rates over the last
    /// recorded epochs — what a service dashboard should chart instead
    /// of lifetime totals.
    pub fn window_rates(&self) -> crate::exec::telemetry::WindowRates {
        crate::exec::global().window_rates()
    }

    /// Force an epoch roll + tunables recalibration on the shared
    /// executor (the service checkpoint path); returns the fresh rates
    /// and how many tunable adjustments were applied.
    pub fn recalibrate_now(&self) -> (crate::exec::telemetry::WindowRates, usize) {
        crate::exec::global().recalibrate_now()
    }

    /// Submit a job under the pool's default class; returns a receiver
    /// for its result. Non-blocking: if the pool is fully admitted the
    /// job waits in the pool's FIFO, not the caller.
    pub fn submit<R: Send + 'static>(
        &self,
        job: impl FnOnce() -> R + Send + 'static,
    ) -> Receiver<R> {
        self.submit_with_class(self.class, job)
    }

    /// [`WorkerPool::submit`] with an explicit class for this one job.
    /// The job holds one of THIS pool's permits even while it waits in
    /// its injector lane — see the module docs before mixing classes
    /// in one pool (separate per-class pools isolate them).
    pub fn submit_with_class<R: Send + 'static>(
        &self,
        class: JobClass,
        job: impl FnOnce() -> R + Send + 'static,
    ) -> Receiver<R> {
        let (tx, rx) = std::sync::mpsc::channel();
        let admission = Arc::clone(&self.admission);
        self.admission.admit(
            Box::new(move || {
                // Guard first: a panicking `job()` unwinds through it,
                // so the permit is released on every exit path.
                let _permit = PermitGuard(admission);
                let _ = tx.send(job());
            }),
            class,
        );
        rx
    }

    /// Submit a batch of jobs; the receiver yields `(index, result)`
    /// pairs in completion order. The batch shares the pool's permits
    /// in submission order: the prefix that fits the free permits is
    /// dispatched as ONE batched executor pass (single shard push,
    /// single wake-up broadcast — the PR-3 entry path, not a per-job
    /// trickle), and only the overflow waits in the pool FIFO to be
    /// dispatched as permits free up.
    pub fn submit_many<R: Send + 'static, F: FnOnce() -> R + Send + 'static>(
        &self,
        jobs: Vec<F>,
    ) -> Receiver<(usize, R)> {
        self.submit_many_with_class(self.class, jobs)
    }

    /// [`WorkerPool::submit_many`] with an explicit class for the whole
    /// batch (the `JobBuilder` path): every job of the batch — the
    /// dispatched prefix and the queued overflow alike — enters the
    /// executor under `class` instead of the pool default.
    pub fn submit_many_with_class<R: Send + 'static, F: FnOnce() -> R + Send + 'static>(
        &self,
        class: JobClass,
        jobs: Vec<F>,
    ) -> Receiver<(usize, R)> {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut wrapped: Vec<Job> = jobs
            .into_iter()
            .enumerate()
            .map(|(i, job)| {
                let tx = tx.clone();
                let admission = Arc::clone(&self.admission);
                Box::new(move || {
                    // Guard first: a panicking `job()` unwinds through
                    // it, releasing the permit on every exit path.
                    let _permit = PermitGuard(admission);
                    let _ = tx.send((i, job()));
                }) as Job
            })
            .collect();
        {
            let mut st = self.admission.state.lock().unwrap();
            // Invariant: available > 0 implies pending is empty (admit
            // queues only at zero, release refills only from pending),
            // so dispatching this prefix ahead of the queue is FIFO.
            let fits = st.available.min(wrapped.len());
            st.available -= fits;
            for _ in 0..fits {
                self.admission.wait[class.lane()].record(0);
            }
            let overflow = wrapped.split_off(fits);
            let queued_at = Instant::now();
            for job in overflow {
                st.pending.push_back((job, class, queued_at));
            }
            // Dispatch UNDER the lock: once the overflow is queued, a
            // release() on a worker could otherwise pop an overflow
            // job and start it before this prefix reached the
            // executor, breaking the FIFO-dispatch contract. No lock
            // inversion: admit/release also take this lock first, and
            // the executor's wake lock is only ever acquired after it.
            crate::exec::global().submit_boxed_many(wrapped, class);
        }
        rx
    }

    /// Submit and wait.
    pub fn run<R: Send + 'static>(&self, job: impl FnOnce() -> R + Send + 'static) -> R {
        self.submit(job).recv().expect("job completed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sync::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    /// The permit release happens on the worker AFTER the result send,
    /// so a receiver can observe `in_flight == 1` for a moment; settle
    /// before asserting on the permit count.
    fn await_idle(pool: &WorkerPool) {
        for _ in 0..1000 {
            if pool.in_flight() == 0 {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        panic!("pool never returned its permits (in_flight {})", pool.in_flight());
    }

    #[test]
    fn runs_jobs_on_workers() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let rxs: Vec<_> = (0..100)
            .map(|i| {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    i * 2
                })
            })
            .collect();
        let sum: usize = rxs.into_iter().map(|rx| rx.recv().unwrap()).sum();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(sum, (0..100).map(|i| i * 2).sum());
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = WorkerPool::new(2);
        pool.run(|| ());
        drop(pool); // must not hang (the shared executor persists)
    }

    /// Acceptance: a `WorkerPool::new(2)` tenant never has more than 2
    /// jobs admitted concurrently, even under an 8-job burst — the
    /// isolation `Config.threads` lost in PR 1, restored at job entry.
    #[test]
    fn admission_caps_in_flight_jobs_under_burst() {
        let pool = WorkerPool::new(2);
        let running = Arc::new(AtomicUsize::new(0));
        let high_water = Arc::new(AtomicUsize::new(0));
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                let running = Arc::clone(&running);
                let high_water = Arc::clone(&high_water);
                pool.submit(move || {
                    let now = running.fetch_add(1, Ordering::SeqCst) + 1;
                    high_water.fetch_max(now, Ordering::SeqCst);
                    // Long enough that overlap WOULD happen without
                    // admission (8 jobs, >= 4 fleet workers).
                    std::thread::sleep(Duration::from_millis(10));
                    running.fetch_sub(1, Ordering::SeqCst);
                    i
                })
            })
            .collect();
        let got: Vec<usize> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
        assert!(
            high_water.load(Ordering::SeqCst) <= 2,
            "admission violated: {} jobs in flight on a 2-permit pool",
            high_water.load(Ordering::SeqCst)
        );
        await_idle(&pool);
        assert_eq!(pool.queued(), 0);
    }

    /// Queued jobs are dispatched in submission order as permits free
    /// up (the pending queue is FIFO).
    #[test]
    fn overflow_starts_in_submission_order() {
        let pool = WorkerPool::new(1);
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                let order = Arc::clone(&order);
                pool.submit(move || {
                    order.lock().unwrap().push(i);
                })
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        // size = 1: jobs are admitted strictly one at a time, so start
        // order IS submission order.
        assert_eq!(*order.lock().unwrap(), (0..6).collect::<Vec<_>>());
    }

    /// A panicking job must release its permit (drop-guard path) or a
    /// 1-permit pool would wedge forever.
    #[test]
    fn panicking_job_releases_its_permit() {
        let pool = WorkerPool::new(1);
        let rx = pool.submit(|| -> usize { panic!("job boom") });
        // The panic surfaces as a dropped sender.
        assert!(rx.recv().is_err());
        // The pool still has its permit: the next job runs.
        assert_eq!(pool.run(|| 41 + 1), 42);
        await_idle(&pool);
    }

    #[test]
    fn concurrent_jobs_all_complete() {
        let pool = WorkerPool::new(4);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                pool.submit(move || {
                    std::thread::sleep(Duration::from_millis(5));
                    i
                })
            })
            .collect();
        let got: Vec<usize> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn batch_submission_yields_every_job() {
        let pool = WorkerPool::new(3);
        let jobs: Vec<_> = (0..40).map(|i| move || i + 1).collect();
        let rx = pool.submit_many(jobs);
        let mut seen = vec![false; 40];
        for (i, r) in rx.iter() {
            assert_eq!(r, i + 1);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    /// A background-class pool completes its work through the
    /// background lane.
    #[test]
    fn background_pool_completes_jobs() {
        let pool = WorkerPool::with_class(2, JobClass::Background);
        assert_eq!(pool.class(), JobClass::Background);
        let jobs: Vec<_> = (0..12).map(|i| move || i * i).collect();
        let rx = pool.submit_many(jobs);
        let mut got: Vec<usize> = rx.iter().map(|(_, r)| r).collect();
        got.sort();
        let mut want: Vec<usize> = (0..12).map(|i| i * i).collect();
        want.sort();
        assert_eq!(got, want);
    }
}

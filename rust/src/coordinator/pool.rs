//! Persistent worker pool for job-level parallelism.
//!
//! The core algorithms use `std::thread::scope` fork/join (their data
//! is borrowed); the *service* layer runs whole jobs — which own their
//! data — on this persistent pool, so concurrent client jobs don't pay
//! thread spawn costs and can overlap.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Cmd {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool with a shared queue.
pub struct WorkerPool {
    tx: Sender<Cmd>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    pub fn new(size: usize) -> WorkerPool {
        assert!(size > 0);
        let (tx, rx) = channel::<Cmd>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("traff-worker-{i}"))
                    .spawn(move || loop {
                        let cmd = { rx.lock().unwrap().recv() };
                        match cmd {
                            Ok(Cmd::Run(job)) => job(),
                            Ok(Cmd::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { tx, handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job; returns a receiver for its result.
    pub fn submit<R: Send + 'static>(
        &self,
        job: impl FnOnce() -> R + Send + 'static,
    ) -> Receiver<R> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Cmd::Run(Box::new(move || {
                let _ = rtx.send(job());
            })))
            .expect("pool alive");
        rrx
    }

    /// Submit and wait.
    pub fn run<R: Send + 'static>(&self, job: impl FnOnce() -> R + Send + 'static) -> R {
        self.submit(job).recv().expect("job completed")
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Cmd::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_on_workers() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let rxs: Vec<_> = (0..100)
            .map(|i| {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    i * 2
                })
            })
            .collect();
        let sum: usize = rxs.into_iter().map(|rx| rx.recv().unwrap()).sum();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(sum, (0..100).map(|i| i * 2).sum());
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = WorkerPool::new(2);
        pool.run(|| ());
        drop(pool); // must not hang
    }

    #[test]
    fn jobs_overlap_across_workers() {
        use std::time::{Duration, Instant};
        let pool = WorkerPool::new(4);
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..4)
            .map(|_| pool.submit(|| std::thread::sleep(Duration::from_millis(50))))
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        // 4 x 50ms in parallel must take well under 200ms.
        assert!(t0.elapsed() < Duration::from_millis(180));
    }
}

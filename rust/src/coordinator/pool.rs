//! Job-level entry point for the service layer — a thin facade over
//! the process-wide [`crate::exec::Executor`].
//!
//! Historically this was a second, independent mpsc worker pool, so a
//! service with `threads = t` actually ran `t` pool threads *plus* a
//! fresh `std::thread::scope` fleet inside every merge/sort call —
//! oversubscribing the machine. Now service jobs and intra-job
//! parallelism share one persistent thread budget: jobs are pushed to
//! the shared executor's deques, and when a job opens an `exec::scope`
//! for its own parallel phases, the waiting worker helps drain the
//! queues instead of blocking a thread.

use std::sync::mpsc::Receiver;

/// Facade handle kept for API compatibility: `size` records the
/// service's configured concurrency, execution happens on
/// [`crate::exec::global`].
pub struct WorkerPool {
    size: usize,
}

impl WorkerPool {
    pub fn new(size: usize) -> WorkerPool {
        assert!(size > 0);
        WorkerPool { size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Snapshot of the shared executor's per-worker counters
    /// (executed / steals / steal misses / injector batches / parks) —
    /// the service-level window into the Chase–Lev substrate. See
    /// [`crate::exec::telemetry`] for field semantics.
    pub fn telemetry(&self) -> crate::exec::telemetry::Telemetry {
        crate::exec::global().telemetry()
    }

    /// Windowed (rate-based) view of the shared executor: per-second
    /// steal / injector / execution rates over the last recorded
    /// epochs — what a service dashboard should chart instead of
    /// lifetime totals.
    pub fn window_rates(&self) -> crate::exec::telemetry::WindowRates {
        crate::exec::global().window_rates()
    }

    /// Force an epoch roll + tunables recalibration on the shared
    /// executor (the service checkpoint path); returns the fresh rates
    /// and how many tunable adjustments were applied.
    pub fn recalibrate_now(&self) -> (crate::exec::telemetry::WindowRates, usize) {
        crate::exec::global().recalibrate_now()
    }

    /// Submit a job; returns a receiver for its result.
    pub fn submit<R: Send + 'static>(
        &self,
        job: impl FnOnce() -> R + Send + 'static,
    ) -> Receiver<R> {
        crate::exec::global().submit(job)
    }

    /// Submit a batch of jobs in one queue pass; the receiver yields
    /// `(index, result)` pairs in completion order.
    pub fn submit_many<R: Send + 'static, F: FnOnce() -> R + Send + 'static>(
        &self,
        jobs: Vec<F>,
    ) -> Receiver<(usize, R)> {
        crate::exec::global().submit_many(jobs)
    }

    /// Submit and wait.
    pub fn run<R: Send + 'static>(&self, job: impl FnOnce() -> R + Send + 'static) -> R {
        self.submit(job).recv().expect("job completed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn runs_jobs_on_workers() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let rxs: Vec<_> = (0..100)
            .map(|i| {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    i * 2
                })
            })
            .collect();
        let sum: usize = rxs.into_iter().map(|rx| rx.recv().unwrap()).sum();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(sum, (0..100).map(|i| i * 2).sum());
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = WorkerPool::new(2);
        pool.run(|| ());
        drop(pool); // must not hang (the shared executor persists)
    }

    #[test]
    fn concurrent_jobs_all_complete() {
        // Overlap timing is asserted against a private executor in
        // `exec::tests` (immune to sibling-test queue contention); the
        // facade test checks completion through the shared pool.
        use std::time::Duration;
        let pool = WorkerPool::new(4);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                pool.submit(move || {
                    std::thread::sleep(Duration::from_millis(5));
                    i
                })
            })
            .collect();
        let got: Vec<usize> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn batch_submission_yields_every_job() {
        let pool = WorkerPool::new(3);
        let jobs: Vec<_> = (0..40).map(|i| move || i + 1).collect();
        let rx = pool.submit_many(jobs);
        let mut seen = vec![false; 40];
        for (i, r) in rx.iter() {
            assert_eq!(r, i + 1);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}

//! The paper's merge as an explicit PRAM program (E6).
//!
//! Memory layout (word-addressed):
//!
//! ```text
//! [0 .. n)                A
//! [n .. n+m)              B
//! [n+m .. n+m+n+m)        C
//! [c_end .. +p+1)         x̄ array
//! [.. +p+1)               ȳ array
//! ```
//!
//! Phases and their step accounting:
//!
//! 1. **Address/parameter broadcast** — `ceil(log2 p)` steps (parallel
//!    prefix doubling, paper's own remark; simulated as counted steps).
//! 2. **x̄ searches** (Step 1 of the paper): PE `i` binary-searches
//!    `A[x_i]` in B. EREW-legal by *pipelining*: PE `i` starts at step
//!    `i`; at any instant all active PEs are at different levels of the
//!    implicit search tree, and distinct levels touch distinct cells.
//!    Cost: `p - 1 + ceil(log2(m+1))` steps.
//! 3. **ȳ searches** (Step 2) — symmetric, reads A.
//! 4. **Cross-rank fetch**: each PE reads its `x̄_i` then `x̄_{i+1}`
//!    (two offset steps — the paper's trick), then the ȳ/x̄ cells its
//!    case needs, with same-cell reads serialized by a precomputed
//!    schedule (measured, reported; worst case +p, typically +O(1)).
//! 5. **Merges** (Steps 3–4): one output element per PE per step
//!    (up to two reads + one write, all within the PE's disjoint
//!    ranges). Cost: `max_task_group_size` steps ≤ `2*ceil(n/p) + 2`.
//!
//! The *single synchronization point* of the paper is the boundary
//! between phases 3 and 4; phases 4–5 run without any further global
//! coordination (each PE's schedule is self-determined). The simulator
//! still steps synchronously — that is the PRAM execution model, not
//! extra synchronization.

use super::machine::{Pram, RunReport};
use super::memory::{Memory, Variant};
use crate::core::cases::{MergeTask, Partition};
use crate::util::log2_ceil;

/// Result of a PRAM merge run.
pub struct PramMergeReport {
    pub report: RunReport,
    /// Step count per phase: [broadcast, xbar, ybar, fetch, merge].
    pub phase_steps: [usize; 5],
    pub tasks: usize,
}

/// Run the paper's merge on the audited PRAM. Returns the merged
/// output and the report. `variant` selects the audit rule.
pub fn pram_merge(a: &[i64], b: &[i64], p: usize, variant: Variant) -> (Vec<i64>, PramMergeReport) {
    let n = a.len();
    let m = b.len();
    let c_base = n + m;
    let xbar_base = c_base + n + m;
    let ybar_base = xbar_base + p + 1;
    let mem_size = ybar_base + p + 1;

    let mut cells = vec![0i64; mem_size];
    cells[..n].copy_from_slice(a);
    cells[n..n + m].copy_from_slice(b);
    let mem = Memory::from_vec(cells);
    let mut pram = Pram::with_memory(p, mem, variant);

    // Host-side ground truth for schedule construction. The simulator
    // re-derives every value through audited memory; `part` only shapes
    // the schedule (which cells, which steps).
    let part = Partition::compute(a, b, p);
    let tasks = part.tasks();

    let mut phase_steps = [0usize; 5];

    // ---- Phase 1: broadcast (counted; prefix doubling over p PEs) ---
    for _ in 0..log2_ceil(p) {
        pram.step_all(|_, _| {});
        phase_steps[0] += 1;
    }

    // ---- Phase 2: pipelined x̄ searches (PE i searches A[x_i] in B) --
    // PE i is idle until step i, then performs one search level per
    // step. State per PE: (lo, hi, target, done).
    {
        let x = part.x.clone();
        let mut lo = vec![0usize; p];
        let mut hi = vec![m; p];
        let mut target = vec![0i64; p];
        let mut fetched = vec![false; p];
        let max_steps = p + log2_ceil(m + 1) as usize + 1;
        for s in 0..max_steps {
            let before = pram.steps();
            pram.step(
                |pe| pe <= s,
                |pe, mem| {
                    if !fetched[pe] {
                        // First active step: read own pivot A[x_i]
                        // (exclusive: each PE reads its own block start;
                        // staggering also separates these reads).
                        target[pe] = if x[pe] < n { mem.read(pe, x[pe]) } else { i64::MAX };
                        fetched[pe] = true;
                        return;
                    }
                    if lo[pe] < hi[pe] {
                        let mid = (lo[pe] + hi[pe]) >> 1;
                        let v = mem.read(pe, n + mid); // B[mid]
                        if v < target[pe] {
                            lo[pe] = mid + 1;
                        } else {
                            hi[pe] = mid;
                        }
                    }
                },
            );
            phase_steps[1] += pram.steps() - before;
            if fetched.iter().all(|&f| f) && lo.iter().zip(&hi).all(|(l, h)| l >= h) {
                break;
            }
        }
        // Write results (one exclusive write each).
        let before = pram.steps();
        pram.step_all(|pe, mem| {
            mem.write(pe, xbar_base + pe, lo[pe] as i64);
        });
        phase_steps[1] += pram.steps() - before;
        pram.mem.poke(xbar_base + p, m as i64); // sentinel, host-set
        // Cross-check against the reference partition.
        for i in 0..p {
            debug_assert_eq!(pram.mem.peek(xbar_base + i), part.xbar[i] as i64);
        }
    }

    // ---- Phase 3: pipelined ȳ searches (PE j searches B[y_j] in A) --
    {
        let y = part.y.clone();
        let mut lo = vec![0usize; p];
        let mut hi = vec![n; p];
        let mut target = vec![0i64; p];
        let mut fetched = vec![false; p];
        let max_steps = p + log2_ceil(n + 1) as usize + 1;
        for s in 0..max_steps {
            let before = pram.steps();
            pram.step(
                |pe| pe <= s,
                |pe, mem| {
                    if !fetched[pe] {
                        target[pe] = if y[pe] < m { mem.read(pe, n + y[pe]) } else { i64::MAX };
                        fetched[pe] = true;
                        return;
                    }
                    if lo[pe] < hi[pe] {
                        let mid = (lo[pe] + hi[pe]) >> 1;
                        let v = mem.read(pe, mid); // A[mid]
                        // rank_high: first index with A[idx] > target.
                        if v <= target[pe] {
                            lo[pe] = mid + 1;
                        } else {
                            hi[pe] = mid;
                        }
                    }
                },
            );
            phase_steps[2] += pram.steps() - before;
            if fetched.iter().all(|&f| f) && lo.iter().zip(&hi).all(|(l, h)| l >= h) {
                break;
            }
        }
        let before = pram.steps();
        pram.step_all(|pe, mem| {
            mem.write(pe, ybar_base + pe, lo[pe] as i64);
        });
        phase_steps[2] += pram.steps() - before;
        pram.mem.poke(ybar_base + p, n as i64);
        for j in 0..p {
            debug_assert_eq!(pram.mem.peek(ybar_base + j), part.ybar[j] as i64);
        }
    }

    // ================= THE synchronization point =====================

    // ---- Phase 4: cross-rank fetch, conflict-free schedule. ---------
    // Each PE reads: x̄_i (own), x̄_{i+1}, ȳ_j(+1) or x̄ cells as its
    // case demands. Build the read list per PE, then schedule reads so
    // no cell is read twice in one step (greedy slotting).
    {
        let mut reads: Vec<Vec<usize>> = vec![Vec::new(); p]; // absolute addrs per PE
        for i in 0..p {
            // A-side PE i.
            reads[i].push(xbar_base + i);
            reads[i].push(xbar_base + i + 1);
            if let Some(t) = part.a_side_task(i) {
                use crate::core::cases::Case::*;
                let j = if part.xbar[i] < m { part.pb.block_of(part.xbar[i]) } else { 0 };
                match t.case {
                    StartAligned => reads[i].push(ybar_base + j),
                    CrossBlock => reads[i].push(ybar_base + j + 1),
                    _ => {}
                }
            }
            // B-side duties of PE i (paper Step 4, same PE set).
            reads[i].push(ybar_base + i);
            reads[i].push(ybar_base + i + 1);
            if let Some(t) = part.b_side_task(i) {
                use crate::core::cases::Case::*;
                let ii = if part.ybar[i] < n { part.pa.block_of(part.ybar[i]) } else { 0 };
                match t.case {
                    StartAligned => reads[i].push(xbar_base + ii),
                    CrossBlock => reads[i].push(xbar_base + ii + 1),
                    _ => {}
                }
            }
        }
        // Greedy slotting: per step, each PE issues its next read
        // unless another PE already claimed that cell this step.
        let mut cursors = vec![0usize; p];
        while cursors.iter().zip(&reads) .any(|(c, r)| *c < r.len()) {
            let mut claimed: std::collections::HashSet<usize> = std::collections::HashSet::new();
            let mut plan: Vec<Option<usize>> = vec![None; p];
            for pe in 0..p {
                if cursors[pe] < reads[pe].len() {
                    let addr = reads[pe][cursors[pe]];
                    if claimed.insert(addr) {
                        plan[pe] = Some(addr);
                        cursors[pe] += 1;
                    }
                }
            }
            let before = pram.steps();
            pram.step(
                |pe| plan[pe].is_some(),
                |pe, mem| {
                    let _ = mem.read(pe, plan[pe].unwrap());
                },
            );
            phase_steps[3] += pram.steps() - before;
        }
    }

    // ---- Phase 5: the 2p merges, one output element per step. -------
    {
        // Assign tasks to PEs as the paper does: A-side task i and
        // B-side task i both belong to PE i. Each PE processes its
        // tasks one element per step.
        #[derive(Clone)]
        struct Cursor {
            task: MergeTask,
            ai: usize,
            bi: usize,
            ci: usize,
        }
        let mut queues: Vec<Vec<Cursor>> = vec![Vec::new(); p];
        for t in &tasks {
            // Recover the owning PE: A-side tasks start at a block
            // start of A; B-side at a block start of B.
            let pe = match t.side {
                crate::core::cases::Side::A => part.pa.block_of(t.a.start.min(n - 1)),
                crate::core::cases::Side::B => part.pb.block_of(t.b.start.min(m - 1)),
            };
            queues[pe].push(Cursor { task: t.clone(), ai: t.a.start, bi: t.b.start, ci: t.c_off });
        }
        let mut active: Vec<usize> = vec![0; p]; // index into queue
        loop {
            // Snapshot the active set so the body may borrow mutably.
            let is_active: Vec<bool> =
                (0..p).map(|pe| active[pe] < queues[pe].len()).collect();
            if !is_active.iter().any(|&a| a) {
                break;
            }
            let before = pram.steps();
            pram.step(
                |pe| is_active[pe],
                |pe, mem| {
                    let q = &mut queues[pe][active[pe]];
                    let t = &q.task;
                    // One comparison + one write (<= 3 accesses, all in
                    // this PE's disjoint ranges).
                    let take_a = if q.ai < t.a.end && q.bi < t.b.end {
                        let av = mem.read(pe, q.ai);
                        let bv = mem.read(pe, n + q.bi);
                        av <= bv
                    } else {
                        q.ai < t.a.end
                    };
                    let v = if take_a {
                        let v = mem.read(pe, q.ai);
                        q.ai += 1;
                        v
                    } else {
                        let v = mem.read(pe, n + q.bi);
                        q.bi += 1;
                        v
                    };
                    mem.write(pe, c_base + q.ci, v);
                    q.ci += 1;
                    if q.ai >= t.a.end && q.bi >= t.b.end {
                        active[pe] += 1;
                    }
                },
            );
            phase_steps[4] += pram.steps() - before;
        }
    }

    let ntasks = tasks.len();
    let (mem, report) = pram.finish();
    let c = mem.slice(c_base, c_base + n + m).to_vec();
    (c, PramMergeReport { report, phase_steps, tasks: ntasks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sorted(rng: &mut Rng, n: usize, hi: i64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.range(0, hi)).collect();
        v.sort();
        v
    }

    #[test]
    fn merges_correctly_on_erew() {
        let mut rng = Rng::new(41);
        for _ in 0..25 {
            let n = 1 + rng.index(200);
            let m = 1 + rng.index(200);
            let p = 1 + rng.index(8);
            let a = sorted(&mut rng, n, 50);
            let b = sorted(&mut rng, m, 50);
            let (c, rep) = pram_merge(&a, &b, p, Variant::Erew);
            let mut expect = [a, b].concat();
            expect.sort();
            assert_eq!(c, expect, "n={n} m={m} p={p}");
            assert!(
                rep.report.conflict_free(),
                "EREW conflicts (n={n} m={m} p={p}): {:?}",
                &rep.report.conflicts[..rep.report.conflicts.len().min(5)]
            );
        }
    }

    #[test]
    fn figure1_on_erew_is_conflict_free() {
        let a = vec![0, 0, 1, 1, 1, 2, 2, 2, 4, 5, 5, 5, 5, 5, 6, 6, 7, 7];
        let b = vec![1, 1, 3, 3, 3, 3, 4, 5, 6, 6, 6, 6, 7, 7, 7];
        let (c, rep) = pram_merge(&a, &b, 5, Variant::Erew);
        let mut expect = [a, b].concat();
        expect.sort();
        assert_eq!(c, expect);
        assert!(rep.report.conflict_free(), "{:?}", rep.report.conflicts);
        assert_eq!(rep.tasks, 10); // the caption's ten subproblems
    }

    #[test]
    fn step_bound_scales_as_n_over_p_plus_log() {
        // Theorem 1 shape: steps <= c1*(n/p) + c2*log(n) + c3*p (the +p
        // from the honest pipelined search; see module docs).
        let mut rng = Rng::new(43);
        for &(n, p) in &[(256usize, 4usize), (1024, 8), (4096, 16), (8192, 16)] {
            let a = sorted(&mut rng, n, 1 << 30);
            let b = sorted(&mut rng, n, 1 << 30);
            let (_, rep) = pram_merge(&a, &b, p, Variant::Erew);
            let bound = 4 * (2 * n / p) + 8 * (log2_ceil(n + 1) as usize) + 4 * p + 32;
            assert!(
                rep.report.steps <= bound,
                "steps {} > bound {bound} (n={n} p={p}, phases {:?})",
                rep.report.steps,
                rep.phase_steps
            );
        }
    }

    #[test]
    fn all_equal_keys_erew() {
        let a = vec![7i64; 100];
        let b = vec![7i64; 80];
        let (c, rep) = pram_merge(&a, &b, 8, Variant::Erew);
        assert_eq!(c, vec![7i64; 180]);
        assert!(rep.report.conflict_free(), "{:?}", &rep.report.conflicts[..3.min(rep.report.conflicts.len())]);
    }
}

//! Audited PRAM shared memory.
//!
//! Every read/write in a parallel step is logged per address; at the
//! end of the step the machine checks the access pattern against the
//! PRAM variant's rule (EREW: no address touched twice; CREW:
//! concurrent reads allowed, writes exclusive). This turns the paper's
//! "can be implemented on an EREW PRAM" claim into a checkable runtime
//! property (E6).

use std::collections::HashMap;

/// PRAM variants, ordered by permissiveness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Exclusive read, exclusive write.
    Erew,
    /// Concurrent read, exclusive write.
    Crew,
}

/// A conflict detected in one parallel step.
#[derive(Clone, Debug)]
pub struct Conflict {
    pub step: usize,
    pub addr: usize,
    pub readers: Vec<usize>,
    pub writers: Vec<usize>,
}

/// Shared memory of word-sized cells with access auditing.
#[derive(Debug)]
pub struct Memory {
    cells: Vec<i64>,
    /// (pe, is_write) accesses for the current step, per address.
    log: HashMap<usize, Vec<(usize, bool)>>,
    auditing: bool,
}

impl Memory {
    pub fn new(size: usize) -> Memory {
        Memory { cells: vec![0; size], log: HashMap::new(), auditing: true }
    }

    pub fn from_vec(cells: Vec<i64>) -> Memory {
        Memory { cells, log: HashMap::new(), auditing: true }
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Disable auditing (for fast functional runs of the same program).
    pub fn set_auditing(&mut self, on: bool) {
        self.auditing = on;
    }

    /// PE `pe` reads `addr` in the current step.
    pub fn read(&mut self, pe: usize, addr: usize) -> i64 {
        if self.auditing {
            self.log.entry(addr).or_default().push((pe, false));
        }
        self.cells[addr]
    }

    /// PE `pe` writes `addr` in the current step.
    pub fn write(&mut self, pe: usize, addr: usize, val: i64) {
        if self.auditing {
            self.log.entry(addr).or_default().push((pe, true));
        }
        self.cells[addr] = val;
    }

    /// Raw (non-audited) access for setup/verification.
    pub fn peek(&self, addr: usize) -> i64 {
        self.cells[addr]
    }

    pub fn poke(&mut self, addr: usize, val: i64) {
        self.cells[addr] = val;
    }

    pub fn slice(&self, lo: usize, hi: usize) -> &[i64] {
        &self.cells[lo..hi]
    }

    /// Close the current step: return conflicts w.r.t. `variant` and
    /// clear the access log.
    pub fn end_step(&mut self, step: usize, variant: Variant) -> Vec<Conflict> {
        let mut conflicts = Vec::new();
        for (&addr, accesses) in &self.log {
            // PRAM exclusivity is between *distinct processors*; a PE
            // touching its own cell several times within its step is a
            // sequential local matter. Dedup by PE.
            let mut readers: Vec<usize> =
                accesses.iter().filter(|(_, w)| !w).map(|(p, _)| *p).collect();
            let mut writers: Vec<usize> =
                accesses.iter().filter(|(_, w)| *w).map(|(p, _)| *p).collect();
            readers.sort_unstable();
            readers.dedup();
            writers.sort_unstable();
            writers.dedup();
            let mut pes: Vec<usize> = readers.iter().chain(writers.iter()).copied().collect();
            pes.sort_unstable();
            pes.dedup();
            let foreign_read = readers.iter().any(|r| !writers.contains(r));
            let bad = match variant {
                Variant::Erew => pes.len() > 1,
                // CREW: concurrent reads fine; writes must be exclusive
                // and unobserved by other PEs in the same step.
                Variant::Crew => writers.len() > 1 || (writers.len() == 1 && foreign_read),
            };
            if bad {
                conflicts.push(Conflict { step, addr, readers, writers });
            }
        }
        self.log.clear();
        conflicts.sort_by_key(|c| c.addr);
        conflicts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exclusive_access_is_clean() {
        let mut m = Memory::new(8);
        m.write(0, 0, 5);
        m.write(1, 1, 6);
        assert_eq!(m.read(2, 0), 5);
        // PE 2 read addr 0 which PE 0 wrote THIS step — EREW conflict.
        let c = m.end_step(0, Variant::Erew);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].addr, 0);
    }

    #[test]
    fn erew_flags_concurrent_reads() {
        let mut m = Memory::new(4);
        m.read(0, 2);
        m.read(1, 2);
        let c = m.end_step(0, Variant::Erew);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].readers, vec![0, 1]);
    }

    #[test]
    fn crew_allows_concurrent_reads() {
        let mut m = Memory::new(4);
        m.read(0, 2);
        m.read(1, 2);
        assert!(m.end_step(0, Variant::Crew).is_empty());
        m.write(0, 3, 1);
        m.write(1, 3, 2);
        assert_eq!(m.end_step(1, Variant::Crew).len(), 1);
    }

    #[test]
    fn steps_are_independent() {
        let mut m = Memory::new(4);
        m.read(0, 1);
        assert!(m.end_step(0, Variant::Erew).is_empty());
        m.read(1, 1); // same address, next step: fine
        assert!(m.end_step(1, Variant::Erew).is_empty());
    }
}

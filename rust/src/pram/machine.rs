//! Step-synchronous PRAM machine.
//!
//! A PRAM program is a sequence of *parallel steps*: in each step every
//! PE executes a closure that may perform a bounded number of memory
//! accesses. The machine runs PEs one after another within a step (the
//! simulation is sequential — what matters is the per-step access
//! pattern), audits the step against the variant rule, and counts
//! steps. This follows the standard "work/step" PRAM accounting
//! (JáJá [10], Keller–Keßler–Träff [12]).

use super::memory::{Conflict, Memory, Variant};

/// Outcome of a full program run.
#[derive(Debug)]
pub struct RunReport {
    /// Parallel steps executed (the PRAM time).
    pub steps: usize,
    /// Total operations across PEs (the PRAM work).
    pub work: usize,
    /// All conflicts w.r.t. the machine variant.
    pub conflicts: Vec<Conflict>,
}

impl RunReport {
    pub fn conflict_free(&self) -> bool {
        self.conflicts.is_empty()
    }
}

/// The machine: `p` PEs over an audited shared memory.
pub struct Pram {
    pub p: usize,
    pub mem: Memory,
    pub variant: Variant,
    steps: usize,
    work: usize,
    conflicts: Vec<Conflict>,
}

impl Pram {
    pub fn new(p: usize, mem_size: usize, variant: Variant) -> Pram {
        Pram { p, mem: Memory::new(mem_size), variant, steps: 0, work: 0, conflicts: Vec::new() }
    }

    pub fn with_memory(p: usize, mem: Memory, variant: Variant) -> Pram {
        Pram { p, mem, variant, steps: 0, work: 0, conflicts: Vec::new() }
    }

    /// Execute one parallel step: `body(pe, mem)` runs for every active
    /// PE (those for which `active` returns true). Returns per-step
    /// conflicts (also accumulated).
    pub fn step<F, A>(&mut self, mut active: A, mut body: F) -> Vec<Conflict>
    where
        F: FnMut(usize, &mut Memory),
        A: FnMut(usize) -> bool,
    {
        let mut acted = 0usize;
        for pe in 0..self.p {
            if active(pe) {
                body(pe, &mut self.mem);
                acted += 1;
            }
        }
        self.work += acted;
        let conflicts = self.mem.end_step(self.steps, self.variant);
        self.conflicts.extend(conflicts.iter().cloned());
        self.steps += 1;
        conflicts
    }

    /// Convenience: a step where all PEs are active.
    pub fn step_all<F: FnMut(usize, &mut Memory)>(&mut self, body: F) -> Vec<Conflict> {
        self.step(|_| true, body)
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    pub fn work(&self) -> usize {
        self.work
    }

    pub fn finish(self) -> (Memory, RunReport) {
        (
            self.mem,
            RunReport { steps: self.steps, work: self.work, conflicts: self.conflicts },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_steps_and_work() {
        let mut m = Pram::new(4, 16, Variant::Erew);
        m.step_all(|pe, mem| mem.write(pe, pe, pe as i64));
        m.step(|pe| pe < 2, |pe, mem| mem.write(pe, 8 + pe, 1));
        let (mem, report) = m.finish();
        assert_eq!(report.steps, 2);
        assert_eq!(report.work, 6);
        assert!(report.conflict_free());
        assert_eq!(mem.peek(3), 3);
    }

    #[test]
    fn detects_cross_pe_conflicts() {
        let mut m = Pram::new(2, 4, Variant::Erew);
        let c = m.step_all(|pe, mem| {
            let _ = mem.read(pe, 0); // both read addr 0
        });
        assert_eq!(c.len(), 1);
        let (_, report) = m.finish();
        assert!(!report.conflict_free());
    }
}

//! The §3 stable merge sort as an explicit PRAM program (E7's model-
//! level half): first each PE sorts its own block "sequentially in
//! parallel" (counted at one comparison-step per element-move of a
//! bottom-up merge sort, i.e. Θ((n/p)·log(n/p)) steps), then
//! `ceil(log2 p)` rounds of the simplified parallel merge — each round
//! re-using the cross-rank partition, executed under the same audited
//! memory so the EREW property extends to the whole sort.
//!
//! Memory layout: two n-word ping-pong regions plus per-round rank
//! scratch.

use super::machine::{Pram, RunReport};
use super::memory::{Memory, Variant};
use crate::core::blocks::Blocks;
use crate::core::cases::Partition;
use crate::util::log2_ceil;

/// Report for a PRAM sort run.
pub struct PramSortReport {
    pub report: RunReport,
    /// [block sort, merge rounds] step split.
    pub phase_steps: [usize; 2],
    pub rounds: usize,
}

/// Run the §3 sort on the audited PRAM. Returns sorted data + report.
pub fn pram_sort(input: &[i64], p: usize, variant: Variant) -> (Vec<i64>, PramSortReport) {
    let n = input.len();
    let src_base = 0usize;
    let dst_base = n;
    let mem_size = 2 * n + 4;
    let mut cells = vec![0i64; mem_size];
    cells[..n].copy_from_slice(input);
    let mem = Memory::from_vec(cells);
    let mut pram = Pram::with_memory(p, mem, variant);
    let blocks = Blocks::new(n, p);
    let mut phase_steps = [0usize; 2];

    // ---- Phase 1: each PE sorts its block in place. -----------------
    // Simulated faithfully at the access level: a bottom-up merge sort
    // needs ~log2(len) passes; we charge one read+write step per
    // element per pass, all within the PE's own block (EREW-trivial),
    // and materialize the result with a host-computed sort (the
    // *accesses* are what the model costs, and they are block-local).
    {
        let max_len = (0..p).map(|i| blocks.block_len(i)).max().unwrap_or(0);
        let passes = log2_ceil(max_len.max(1)) as usize;
        // Local sorted copies, written back through audited memory.
        let mut sorted_blocks: Vec<Vec<i64>> = (0..p)
            .map(|i| {
                let mut v = input[blocks.start(i)..blocks.start(i + 1)].to_vec();
                v.sort();
                v
            })
            .collect();
        for pass in 0..passes {
            // One pass = each PE touches each of its elements once.
            for k in 0..max_len {
                let before = pram.steps();
                pram.step(
                    |pe| k < blocks.block_len(pe),
                    |pe, mem| {
                        let addr = src_base + blocks.start(pe) + k;
                        let v = mem.read(pe, addr);
                        // Final pass writes the sorted value; earlier
                        // passes model the intermediate shuffles.
                        if pass + 1 == passes {
                            let sv = sorted_blocks[pe][k];
                            mem.write(pe, addr, sv);
                        } else {
                            mem.write(pe, addr, v);
                        }
                    },
                );
                phase_steps[0] += pram.steps() - before;
            }
        }
        if passes == 0 {
            // Single-element blocks: nothing to do.
            for sb in sorted_blocks.iter_mut() {
                sb.clear();
            }
        }
    }

    // ---- Phase 2: ceil(log2 p) merge rounds over audited memory. ----
    let mut runs: Vec<usize> = blocks.starts();
    runs.dedup();
    let mut in_src = true;
    let mut rounds = 0usize;
    while runs.len() > 2 {
        let (from, to) = if in_src { (src_base, dst_base) } else { (dst_base, src_base) };
        let snapshot: Vec<i64> = pram.mem.slice(from, from + n).to_vec();
        // Pair adjacent runs; all pairs' merges execute in the same
        // stepped loop (the paper's "in parallel on the pairs").
        let nruns = runs.len() - 1;
        let npairs = nruns / 2;
        let per_pair = (p / npairs.max(1)).max(1);
        struct Cur {
            a: std::ops::Range<usize>,
            b: std::ops::Range<usize>,
            c: usize,
        }
        let mut queues: Vec<Vec<Cur>> = (0..p).map(|_| Vec::new()).collect();
        let mut pe_rr = 0usize;
        let mut new_runs = vec![0usize];
        for pair in 0..npairs {
            let lo = runs[2 * pair];
            let mid = runs[2 * pair + 1];
            let hi = runs[2 * pair + 2];
            let part = Partition::compute(&snapshot[lo..mid], &snapshot[mid..hi], per_pair);
            for t in part.tasks() {
                queues[pe_rr % p].push(Cur {
                    a: (t.a.start + lo)..(t.a.end + lo),
                    b: (t.b.start + mid)..(t.b.end + mid),
                    c: t.c_off + lo,
                });
                pe_rr += 1;
            }
            new_runs.push(hi);
        }
        if nruns % 2 == 1 {
            let lo = runs[nruns - 1];
            let hi = runs[nruns];
            queues[pe_rr % p].push(Cur { a: lo..hi, b: hi..hi, c: lo });
            new_runs.push(hi);
        }
        // Charge the binary searches: per_pair searches of log n each,
        // pipelined — approximated as one stepped loop of
        // log2(n)+per_pair steps (same accounting as pram_merge).
        for _ in 0..(log2_ceil(n + 1) as usize + per_pair) {
            let before = pram.steps();
            pram.step_all(|_, _| {});
            phase_steps[1] += pram.steps() - before;
        }
        // Execute all tasks one element per step.
        let mut active = vec![0usize; p];
        let mut ai: Vec<usize> = queues.iter().map(|q| q.first().map(|c| c.a.start).unwrap_or(0)).collect();
        let mut bi: Vec<usize> = queues.iter().map(|q| q.first().map(|c| c.b.start).unwrap_or(0)).collect();
        let mut ci: Vec<usize> = queues.iter().map(|q| q.first().map(|c| c.c).unwrap_or(0)).collect();
        loop {
            let is_active: Vec<bool> = (0..p).map(|pe| active[pe] < queues[pe].len()).collect();
            if !is_active.iter().any(|&x| x) {
                break;
            }
            let before = pram.steps();
            pram.step(
                |pe| is_active[pe],
                |pe, mem| {
                    let q = &queues[pe][active[pe]];
                    let take_a = if ai[pe] < q.a.end && bi[pe] < q.b.end {
                        let av = mem.read(pe, from + ai[pe]);
                        let bv = mem.read(pe, from + bi[pe]);
                        av <= bv
                    } else {
                        ai[pe] < q.a.end
                    };
                    let v = if take_a {
                        let v = mem.read(pe, from + ai[pe]);
                        ai[pe] += 1;
                        v
                    } else {
                        let v = mem.read(pe, from + bi[pe]);
                        bi[pe] += 1;
                        v
                    };
                    mem.write(pe, to + ci[pe], v);
                    ci[pe] += 1;
                    if ai[pe] >= q.a.end && bi[pe] >= q.b.end {
                        active[pe] += 1;
                        if active[pe] < queues[pe].len() {
                            let nq = &queues[pe][active[pe]];
                            ai[pe] = nq.a.start;
                            bi[pe] = nq.b.start;
                            ci[pe] = nq.c;
                        }
                    }
                },
            );
            phase_steps[1] += pram.steps() - before;
        }
        runs = new_runs;
        in_src = !in_src;
        rounds += 1;
    }

    let final_base = if in_src { src_base } else { dst_base };
    let (mem, report) = pram.finish();
    let out = mem.slice(final_base, final_base + n).to_vec();
    (out, PramSortReport { report, phase_steps, rounds })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sorts_correctly_and_erew() {
        let mut rng = Rng::new(71);
        for &(n, p) in &[(64usize, 2usize), (200, 4), (1000, 8), (777, 3)] {
            let v: Vec<i64> = (0..n).map(|_| rng.range(0, 100)).collect();
            let (out, rep) = pram_sort(&v, p, Variant::Erew);
            let mut expect = v.clone();
            expect.sort();
            assert_eq!(out, expect, "n={n} p={p}");
            assert!(
                rep.report.conflict_free(),
                "n={n} p={p}: {:?}",
                rep.report.conflicts.first()
            );
            assert_eq!(rep.rounds, crate::core::sort::expected_rounds(p), "n={n} p={p}");
        }
    }

    #[test]
    fn step_bound_n_log_n_over_p() {
        // §3: O(n log n / p + log p log n). Check the dominant term.
        let mut rng = Rng::new(73);
        for &(n, p) in &[(1024usize, 4usize), (4096, 8), (4096, 16)] {
            let v: Vec<i64> = (0..n).map(|_| rng.range(0, 1 << 30)).collect();
            let (_, rep) = pram_sort(&v, p, Variant::Erew);
            let bound = 4 * (n / p) * (log2_ceil(n) as usize)
                + 8 * (log2_ceil(p) as usize) * (log2_ceil(n) as usize)
                + 8 * p
                + 64;
            assert!(
                rep.report.steps <= bound,
                "steps {} > bound {bound} (n={n} p={p}, phases {:?})",
                rep.report.steps,
                rep.phase_steps
            );
        }
    }

    #[test]
    fn tiny_inputs() {
        for n in 0..8 {
            let v: Vec<i64> = (0..n as i64).rev().collect();
            let (out, _) = pram_sort(&v, 2, Variant::Erew);
            let mut expect = v.clone();
            expect.sort();
            assert_eq!(out, expect, "n={n}");
        }
    }
}

//! EREW parallel prefix (scan) — the standard broadcast/aggregation
//! primitive the paper invokes for distributing base addresses
//! ("copied to the p processing elements in O(log p) steps by parallel
//! prefix operations").
//!
//! Implemented as the classic up-sweep/down-sweep over a length-p
//! region of audited memory; both sweeps are EREW-legal by
//! construction (each step touches disjoint (left, right) pairs).

use super::machine::Pram;


/// In-place inclusive prefix sum over `mem[base..base+p]` using the
/// machine's `p` PEs. Returns the number of steps used.
pub fn prefix_sum(pram: &mut Pram, base: usize) -> usize {
    let p = pram.p;
    let steps_before = pram.steps();
    // Up-sweep: stride doubling. At stride s, PE i (with (i+1) % (2s)
    // == 0) adds cell (i - s) into cell i. Disjoint pairs => EREW.
    let mut s = 1usize;
    while s < p {
        let stride = s;
        pram.step(
            |pe| (pe + 1) % (2 * stride) == 0,
            |pe, mem| {
                let l = mem.read(pe, base + pe - stride);
                let r = mem.read(pe, base + pe);
                mem.write(pe, base + pe, l + r);
            },
        );
        s *= 2;
    }
    // Down-sweep for the inclusive scan: at each halving stride, PE i
    // with (i + 1) % (2s) == s and i >= s... propagate partial sums.
    s /= 2;
    while s >= 1 {
        let stride = s;
        pram.step(
            |pe| pe >= 2 * stride - 1 && (pe + 1 - stride) % (2 * stride) == 0,
            |pe, mem| {
                let l = mem.read(pe, base + pe - stride);
                let r = mem.read(pe, base + pe);
                mem.write(pe, base + pe, l + r);
            },
        );
        if s == 1 {
            break;
        }
        s /= 2;
    }
    pram.steps() - steps_before
}

/// Broadcast `mem[base]` into `mem[base..base+p]` by recursive doubling
/// (O(log p) EREW steps): at round r, PEs `2^r..2^(r+1)` copy from
/// `pe - 2^r` — every source cell is read by exactly one PE.
pub fn broadcast(pram: &mut Pram, base: usize) -> usize {
    let p = pram.p;
    let steps_before = pram.steps();
    let mut have = 1usize;
    while have < p {
        let h = have;
        pram.step(
            |pe| pe >= h && pe < 2 * h && pe < p,
            |pe, mem| {
                let v = mem.read(pe, base + pe - h);
                mem.write(pe, base + pe, v);
            },
        );
        have *= 2;
    }
    pram.steps() - steps_before
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pram::memory::Variant;
    use crate::pram::Pram;

    #[test]
    fn prefix_sum_correct_and_erew() {
        for p in [1usize, 2, 3, 4, 7, 8, 16, 33] {
            let mut pram = Pram::new(p, p + 4, Variant::Erew);
            for i in 0..p {
                pram.mem.poke(i, (i + 1) as i64);
            }
            let steps = prefix_sum(&mut pram, 0);
            let (mem, report) = pram.finish();
            assert!(report.conflict_free(), "p={p}: {:?}", report.conflicts);
            // Inclusive prefix of 1..=p is i*(i+1)/2.
            for i in 0..p {
                let expect = ((i + 1) * (i + 2) / 2) as i64;
                assert_eq!(mem.peek(i), expect, "p={p} i={i}");
            }
            assert!(steps <= 2 * (crate::util::log2_ceil(p) as usize) + 2, "p={p} steps={steps}");
        }
    }

    #[test]
    fn broadcast_correct_and_erew() {
        for p in [1usize, 2, 5, 8, 13, 32] {
            let mut pram = Pram::new(p, p, Variant::Erew);
            pram.mem.poke(0, 99);
            let steps = broadcast(&mut pram, 0);
            let (mem, report) = pram.finish();
            assert!(report.conflict_free(), "p={p}");
            for i in 0..p {
                assert_eq!(mem.peek(i), 99, "p={p} i={i}");
            }
            assert!(steps <= crate::util::log2_ceil(p) as usize + 1);
        }
    }

    #[test]
    fn crew_machine_accepts_same_programs() {
        let mut pram = Pram::new(8, 8, Variant::Crew);
        pram.mem.poke(0, 5);
        broadcast(&mut pram, 0);
        let (_, report) = pram.finish();
        assert!(report.conflict_free());
    }
}

//! PRAM model simulator (S11): step-synchronous machine, audited
//! shared memory (EREW/CREW legality), parallel prefix, and the paper's
//! merge as an explicit PRAM program — the substrate for validating the
//! EREW claim and the `O(n/p + log n)` step bound (E6).

pub mod machine;
pub mod memory;
pub mod prefix;
pub mod programs;
pub mod sort_program;

pub use machine::{Pram, RunReport};
pub use memory::{Conflict, Memory, Variant};
pub use prefix::{broadcast, prefix_sum};
pub use programs::{pram_merge, PramMergeReport};
pub use sort_program::{pram_sort, PramSortReport};

//! `model` — a vendored, loom-style deterministic concurrency model
//! checker for the lock-free substrate (ISSUE 6; in the spirit of the
//! repo's minimal vendored `anyhow`).
//!
//! The crate's concurrent modules import their atomics from
//! [`sync`] instead of `std::sync::atomic` (enforced by
//! `clippy.toml`'s `disallowed-types`). In a normal build the types
//! are `#[repr(transparent)]` zero-cost wrappers over the `std`
//! atomics — every method is an `#[inline]` one-liner, so release
//! codegen is identical (acceptance: benches within noise). Under
//! `--features model` the same names route every load/store/RMW
//! through a cooperative scheduler that
//!
//! 1. **enumerates thread interleavings**: real OS threads run under a
//!    token-passing scheduler that context-switches only at visible
//!    operations (atomic ops, mutex ops, spawn/join/yield) and
//!    explores the schedule tree depth-first with sleep-set (DPOR
//!    family) pruning, up to configurable depth/schedule bounds;
//! 2. **simulates release/acquire visibility**: each atomic location
//!    keeps its full store history with vector-clock message stamps,
//!    and a load may read *any* store not yet ordered before the
//!    reader by happens-before — so an `Ordering` that is too weak
//!    actually produces stale values instead of merely "passing on the
//!    interleaving Miri happened to pick";
//! 3. **replays deterministically**: a failing schedule is printed as
//!    a dotted decision string; re-running the test with
//!    `MODEL_SCHEDULE=<string>` replays exactly that execution.
//!
//! Entry point: [`check`] (or [`check_with`] for custom bounds) runs a
//! closure to completion under every explored schedule:
//!
//! ```ignore
//! model::check(|| {
//!     let flag = Arc::new(AtomicBool::new(false));
//!     // ... spawn model::thread threads, assert invariants ...
//! });
//! ```
//!
//! The four protocol suites live next to the code they check:
//! `exec::model_tests` (Chase–Lev steal-vs-pop, injector drain claim +
//! promotion arm/reset, telemetry window-epoch roll) and
//! `stream::model_tests` (compaction claim vs snapshot pin), each
//! `#[cfg(all(test, feature = "model"))]`. The mutation gate there
//! weakens one `Release` to `Relaxed` in a test-only protocol copy and
//! asserts this checker reports the resulting stale read.

pub mod sync;
pub mod thread;

#[cfg(feature = "model")]
mod checker;

#[cfg(feature = "model")]
pub use checker::{check, check_with, Config};

/// Normal-build stand-in so `model::check` exists in both cfgs: runs
/// the closure once on the current thread and reports one "schedule".
/// The real exploration requires `--features model`.
#[cfg(not(feature = "model"))]
pub fn check<F: Fn() + Send + Sync + 'static>(f: F) -> u64 {
    f();
    1
}

//! Drop-in atomics (+ `Mutex`) for the crate's concurrent modules.
//!
//! Normal builds: `#[repr(transparent)]` newtype wrappers over
//! `std::sync::atomic` with `#[inline]` forwarding — zero cost, same
//! codegen. (Wrappers rather than re-exports so clippy's
//! `disallowed-types` ban on the raw `std` atomics cannot be satisfied
//! by accident: the only def-ids allowed in `exec/` and `stream/` are
//! these.)
//!
//! `--features model` builds: the same names route through the
//! cooperative scheduler in [`crate::model::checker`] whenever a model
//! execution is active on the current thread, and fall back to the
//! real inner atomic otherwise (so ordinary tests still pass in a
//! `--features model` test run). Every model-routed store is also
//! written through to the inner `std` atomic — threads are serialized
//! under the scheduler, so the inner value always equals the newest
//! store in the model history, which lets teardown free-run on the
//! real atomics after a failure is recorded.
//!
//! `Mutex` is re-exported from `std` in normal builds and
//! scheduler-aware under `model` — required because `RunStore::seal`
//! performs atomic RMWs *inside* its list-lock critical section, which
//! would deadlock a cooperative scheduler running over a real blocking
//! lock.

#![allow(clippy::disallowed_types, clippy::disallowed_methods)]

pub use std::sync::atomic::Ordering;

// ---------------------------------------------------------------------------
// Normal build: transparent zero-cost wrappers.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "model"))]
mod imp {
    use super::Ordering;

    pub use std::sync::{Mutex, MutexGuard};

    /// Identical to [`std::sync::atomic::fence`].
    #[inline(always)]
    pub fn fence(order: Ordering) {
        std::sync::atomic::fence(order);
    }

    macro_rules! int_atomic {
        ($name:ident, $std:ident, $prim:ty) => {
            #[repr(transparent)]
            #[derive(Default)]
            pub struct $name(std::sync::atomic::$std);

            impl $name {
                #[inline(always)]
                pub const fn new(v: $prim) -> Self {
                    Self(std::sync::atomic::$std::new(v))
                }
                #[inline(always)]
                pub fn load(&self, order: Ordering) -> $prim {
                    self.0.load(order)
                }
                #[inline(always)]
                pub fn store(&self, v: $prim, order: Ordering) {
                    self.0.store(v, order)
                }
                #[inline(always)]
                pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                    self.0.swap(v, order)
                }
                #[inline(always)]
                pub fn compare_exchange(
                    &self,
                    cur: $prim,
                    new: $prim,
                    ok: Ordering,
                    err: Ordering,
                ) -> Result<$prim, $prim> {
                    self.0.compare_exchange(cur, new, ok, err)
                }
                #[inline(always)]
                pub fn compare_exchange_weak(
                    &self,
                    cur: $prim,
                    new: $prim,
                    ok: Ordering,
                    err: Ordering,
                ) -> Result<$prim, $prim> {
                    self.0.compare_exchange_weak(cur, new, ok, err)
                }
                #[inline(always)]
                pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                    self.0.fetch_add(v, order)
                }
                #[inline(always)]
                pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                    self.0.fetch_sub(v, order)
                }
                #[inline(always)]
                pub fn fetch_max(&self, v: $prim, order: Ordering) -> $prim {
                    self.0.fetch_max(v, order)
                }
                #[inline(always)]
                pub fn fetch_min(&self, v: $prim, order: Ordering) -> $prim {
                    self.0.fetch_min(v, order)
                }
                #[inline(always)]
                pub fn get_mut(&mut self) -> &mut $prim {
                    self.0.get_mut()
                }
            }

            impl std::fmt::Debug for $name {
                fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                    self.0.fmt(f)
                }
            }
        };
    }

    int_atomic!(AtomicU64, AtomicU64, u64);
    int_atomic!(AtomicUsize, AtomicUsize, usize);
    int_atomic!(AtomicIsize, AtomicIsize, isize);

    #[repr(transparent)]
    #[derive(Default)]
    pub struct AtomicBool(std::sync::atomic::AtomicBool);

    impl AtomicBool {
        #[inline(always)]
        pub const fn new(v: bool) -> Self {
            Self(std::sync::atomic::AtomicBool::new(v))
        }
        #[inline(always)]
        pub fn load(&self, order: Ordering) -> bool {
            self.0.load(order)
        }
        #[inline(always)]
        pub fn store(&self, v: bool, order: Ordering) {
            self.0.store(v, order)
        }
        #[inline(always)]
        pub fn swap(&self, v: bool, order: Ordering) -> bool {
            self.0.swap(v, order)
        }
        #[inline(always)]
        pub fn compare_exchange(
            &self,
            cur: bool,
            new: bool,
            ok: Ordering,
            err: Ordering,
        ) -> Result<bool, bool> {
            self.0.compare_exchange(cur, new, ok, err)
        }
        #[inline(always)]
        pub fn get_mut(&mut self) -> &mut bool {
            self.0.get_mut()
        }
    }

    impl std::fmt::Debug for AtomicBool {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            self.0.fmt(f)
        }
    }

    #[repr(transparent)]
    pub struct AtomicPtr<T>(std::sync::atomic::AtomicPtr<T>);

    impl<T> AtomicPtr<T> {
        #[inline(always)]
        pub const fn new(p: *mut T) -> Self {
            Self(std::sync::atomic::AtomicPtr::new(p))
        }
        #[inline(always)]
        pub fn load(&self, order: Ordering) -> *mut T {
            self.0.load(order)
        }
        #[inline(always)]
        pub fn store(&self, p: *mut T, order: Ordering) {
            self.0.store(p, order)
        }
        #[inline(always)]
        pub fn swap(&self, p: *mut T, order: Ordering) -> *mut T {
            self.0.swap(p, order)
        }
        #[inline(always)]
        pub fn compare_exchange(
            &self,
            cur: *mut T,
            new: *mut T,
            ok: Ordering,
            err: Ordering,
        ) -> Result<*mut T, *mut T> {
            self.0.compare_exchange(cur, new, ok, err)
        }
        #[inline(always)]
        pub fn get_mut(&mut self) -> &mut *mut T {
            self.0.get_mut()
        }
    }

    impl<T> Default for AtomicPtr<T> {
        fn default() -> Self {
            Self::new(std::ptr::null_mut())
        }
    }

    impl<T> std::fmt::Debug for AtomicPtr<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            self.0.fmt(f)
        }
    }
}

// ---------------------------------------------------------------------------
// Model build: scheduler-routed atomics.
// ---------------------------------------------------------------------------

#[cfg(feature = "model")]
mod imp {
    use super::Ordering;
    use crate::model::checker;

    /// Under an active model execution this is a visible fence event
    /// (release fences publish the thread clock to later relaxed
    /// stores; acquire fences pull in the clocks of earlier relaxed
    /// loads); otherwise a real fence.
    pub fn fence(order: Ordering) {
        if !checker::fence(order) {
            std::sync::atomic::fence(order);
        }
    }

    macro_rules! model_atomic {
        ($name:ident, $std:ident, $prim:ty, $to:expr, $from:expr) => {
            pub struct $name(std::sync::atomic::$std);

            impl $name {
                pub const fn new(v: $prim) -> Self {
                    Self(std::sync::atomic::$std::new(v))
                }

                fn addr(&self) -> usize {
                    self as *const Self as usize
                }

                /// Current inner value, used to seed the model store
                /// history on first touch.
                fn seed(&self) -> u64 {
                    ($to)(self.0.load(Ordering::Relaxed))
                }

                pub fn load(&self, order: Ordering) -> $prim {
                    match checker::atomic_load(self.addr(), self.seed(), order) {
                        Some(v) => ($from)(v),
                        None => self.0.load(order),
                    }
                }

                pub fn store(&self, v: $prim, order: Ordering) {
                    if checker::atomic_store(self.addr(), self.seed(), ($to)(v), order) {
                        // Write-through: threads are serialized under
                        // the scheduler, so inner == newest store.
                        self.0.store(v, Ordering::SeqCst);
                    } else {
                        self.0.store(v, order);
                    }
                }

                pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                    let new = ($to)(v);
                    match checker::atomic_rmw(self.addr(), self.seed(), order, |_| new) {
                        Some(old) => {
                            self.0.store(v, Ordering::SeqCst);
                            ($from)(old)
                        }
                        None => self.0.swap(v, order),
                    }
                }

                pub fn compare_exchange(
                    &self,
                    cur: $prim,
                    new: $prim,
                    ok: Ordering,
                    err: Ordering,
                ) -> Result<$prim, $prim> {
                    match checker::atomic_cas(self.addr(), self.seed(), ($to)(cur), ($to)(new), ok, err)
                    {
                        Some(Ok(old)) => {
                            self.0.store(new, Ordering::SeqCst);
                            Ok(($from)(old))
                        }
                        Some(Err(old)) => Err(($from)(old)),
                        None => self.0.compare_exchange(cur, new, ok, err),
                    }
                }

                /// The model explores no spurious failures: `weak` is
                /// checked as the strong CAS (a sound subset of its
                /// behaviours — spurious failure only adds retries).
                pub fn compare_exchange_weak(
                    &self,
                    cur: $prim,
                    new: $prim,
                    ok: Ordering,
                    err: Ordering,
                ) -> Result<$prim, $prim> {
                    self.compare_exchange(cur, new, ok, err)
                }

                pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                    self.rmw(order, |old| old.wrapping_add(v))
                }

                pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                    self.rmw(order, |old| old.wrapping_sub(v))
                }

                pub fn fetch_max(&self, v: $prim, order: Ordering) -> $prim {
                    self.rmw(order, |old| if v > old { v } else { old })
                }

                pub fn fetch_min(&self, v: $prim, order: Ordering) -> $prim {
                    self.rmw(order, |old| if v < old { v } else { old })
                }

                fn rmw(&self, order: Ordering, f: impl Fn($prim) -> $prim) -> $prim {
                    match checker::atomic_rmw(self.addr(), self.seed(), order, |old| {
                        ($to)(f(($from)(old)))
                    }) {
                        Some(old) => {
                            let old = ($from)(old);
                            self.0.store(f(old), Ordering::SeqCst);
                            old
                        }
                        None => {
                            // No active execution: run the RMW on the
                            // real atomic via a CAS loop (covers every
                            // f uniformly).
                            let mut cur = self.0.load(Ordering::Relaxed);
                            loop {
                                match self.0.compare_exchange_weak(cur, f(cur), order, Ordering::Relaxed)
                                {
                                    Ok(old) => return old,
                                    Err(now) => cur = now,
                                }
                            }
                        }
                    }
                }

                /// `&mut self` access bypasses the scheduler (exclusive
                /// access means no concurrency to model). Only sound
                /// for *reads* during an execution; the migrated code
                /// uses it solely in `Drop` paths.
                pub fn get_mut(&mut self) -> &mut $prim {
                    self.0.get_mut()
                }
            }

            impl Default for $name {
                fn default() -> Self {
                    Self::new(Default::default())
                }
            }

            impl Drop for $name {
                fn drop(&mut self) {
                    // Address reuse safety: a later atomic allocated at
                    // this address must not inherit this history.
                    checker::forget_location(self as *const Self as usize);
                }
            }

            impl std::fmt::Debug for $name {
                fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                    self.0.fmt(f)
                }
            }
        };
    }

    model_atomic!(AtomicU64, AtomicU64, u64, |v: u64| v, |v: u64| v);
    model_atomic!(AtomicUsize, AtomicUsize, usize, |v: usize| v as u64, |v: u64| v as usize);
    model_atomic!(
        AtomicIsize,
        AtomicIsize,
        isize,
        |v: isize| v as i64 as u64,
        |v: u64| v as i64 as isize
    );

    pub struct AtomicBool(std::sync::atomic::AtomicBool);

    impl AtomicBool {
        pub const fn new(v: bool) -> Self {
            Self(std::sync::atomic::AtomicBool::new(v))
        }

        fn addr(&self) -> usize {
            self as *const Self as usize
        }

        fn seed(&self) -> u64 {
            self.0.load(Ordering::Relaxed) as u64
        }

        pub fn load(&self, order: Ordering) -> bool {
            match checker::atomic_load(self.addr(), self.seed(), order) {
                Some(v) => v != 0,
                None => self.0.load(order),
            }
        }

        pub fn store(&self, v: bool, order: Ordering) {
            if checker::atomic_store(self.addr(), self.seed(), v as u64, order) {
                self.0.store(v, Ordering::SeqCst);
            } else {
                self.0.store(v, order);
            }
        }

        pub fn swap(&self, v: bool, order: Ordering) -> bool {
            match checker::atomic_rmw(self.addr(), self.seed(), order, |_| v as u64) {
                Some(old) => {
                    self.0.store(v, Ordering::SeqCst);
                    old != 0
                }
                None => self.0.swap(v, order),
            }
        }

        pub fn compare_exchange(
            &self,
            cur: bool,
            new: bool,
            ok: Ordering,
            err: Ordering,
        ) -> Result<bool, bool> {
            match checker::atomic_cas(self.addr(), self.seed(), cur as u64, new as u64, ok, err) {
                Some(Ok(old)) => {
                    self.0.store(new, Ordering::SeqCst);
                    Ok(old != 0)
                }
                Some(Err(old)) => Err(old != 0),
                None => self.0.compare_exchange(cur, new, ok, err),
            }
        }

        pub fn get_mut(&mut self) -> &mut bool {
            self.0.get_mut()
        }
    }

    impl Default for AtomicBool {
        fn default() -> Self {
            Self::new(false)
        }
    }

    impl Drop for AtomicBool {
        fn drop(&mut self) {
            checker::forget_location(self as *const Self as usize);
        }
    }

    impl std::fmt::Debug for AtomicBool {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            self.0.fmt(f)
        }
    }

    pub struct AtomicPtr<T>(std::sync::atomic::AtomicPtr<T>);

    impl<T> AtomicPtr<T> {
        pub const fn new(p: *mut T) -> Self {
            Self(std::sync::atomic::AtomicPtr::new(p))
        }

        fn addr(&self) -> usize {
            self as *const Self as usize
        }

        fn seed(&self) -> u64 {
            self.0.load(Ordering::Relaxed) as usize as u64
        }

        pub fn load(&self, order: Ordering) -> *mut T {
            match checker::atomic_load(self.addr(), self.seed(), order) {
                Some(v) => v as usize as *mut T,
                None => self.0.load(order),
            }
        }

        pub fn store(&self, p: *mut T, order: Ordering) {
            if checker::atomic_store(self.addr(), self.seed(), p as usize as u64, order) {
                self.0.store(p, Ordering::SeqCst);
            } else {
                self.0.store(p, order);
            }
        }

        pub fn swap(&self, p: *mut T, order: Ordering) -> *mut T {
            match checker::atomic_rmw(self.addr(), self.seed(), order, |_| p as usize as u64) {
                Some(old) => {
                    self.0.store(p, Ordering::SeqCst);
                    old as usize as *mut T
                }
                None => self.0.swap(p, order),
            }
        }

        pub fn compare_exchange(
            &self,
            cur: *mut T,
            new: *mut T,
            ok: Ordering,
            err: Ordering,
        ) -> Result<*mut T, *mut T> {
            match checker::atomic_cas(
                self.addr(),
                self.seed(),
                cur as usize as u64,
                new as usize as u64,
                ok,
                err,
            ) {
                Some(Ok(old)) => {
                    self.0.store(new, Ordering::SeqCst);
                    Ok(old as usize as *mut T)
                }
                Some(Err(old)) => Err(old as usize as *mut T),
                None => self.0.compare_exchange(cur, new, ok, err),
            }
        }

        pub fn get_mut(&mut self) -> &mut *mut T {
            self.0.get_mut()
        }
    }

    impl<T> Default for AtomicPtr<T> {
        fn default() -> Self {
            Self::new(std::ptr::null_mut())
        }
    }

    impl<T> Drop for AtomicPtr<T> {
        fn drop(&mut self) {
            checker::forget_location(self as *const Self as usize);
        }
    }

    impl<T> std::fmt::Debug for AtomicPtr<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            self.0.fmt(f)
        }
    }

    pub use checker::{Mutex, MutexGuard};
}

pub use imp::{fence, AtomicBool, AtomicIsize, AtomicPtr, AtomicU64, AtomicUsize, Mutex, MutexGuard};

//! Thread spawn/join for model tests. Normal builds re-export
//! `std::thread`; model builds route spawn, join, and yield through
//! the cooperative scheduler so they become visible scheduling events
//! (and so a spawned closure inherits the active execution).

#[cfg(not(feature = "model"))]
pub use std::thread::{spawn, yield_now, JoinHandle};

#[cfg(feature = "model")]
pub use crate::model::checker::{spawn, yield_now, JoinHandle};

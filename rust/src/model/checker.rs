//! The model-build core: a token-passing cooperative scheduler over
//! real OS threads, depth-first schedule exploration with sleep-set
//! (DPOR-family) pruning, and a vector-clock weak-memory simulation
//! with per-location store histories. Compiled only under
//! `--features model`.
//!
//! ## Exploration
//!
//! Exactly one thread runs at a time. Before every *visible* operation
//! (atomic op, mutex op, spawn/join/yield) a thread announces the
//! operation and blocks; the scheduler picks the next thread to run
//! from the enabled set. Whenever more than one thread is enabled, the
//! decision is a branch in the schedule tree; [`check_with`] re-runs
//! the closure, advancing the last undecided branch depth-first until
//! the tree is exhausted (or `max_schedules` hits). Sleep sets prune
//! redundant interleavings: after exploring thread `t` at a branch,
//! `t` stays "asleep" in sibling subtrees until some executed
//! operation conflicts with the operation `t` performed — schedules in
//! which `t` runs later but nothing conflicting intervened are
//! permutations of already-explored ones. A state whose every enabled
//! thread is asleep is abandoned (`SleepBlocked`) — every completion
//! of it is equivalent to an explored schedule.
//!
//! ## Weak memory
//!
//! Each atomic location keeps its full modification order as a list of
//! stores, each stamped with `(writer, per-writer event counter)` and
//! a *message* vector clock (the writer's clock for `Release`-or-
//! stronger stores, its release-fence clock for `Relaxed` ones). A
//! load may read **any** store not superseded by one the reader
//! already happens-after (plus per-thread coherence floors); when
//! several stores are readable, the choice is itself a schedule
//! branch, so a too-weak ordering genuinely produces stale values in
//! some explored schedule. `Acquire` loads join the message clock;
//! `Relaxed` loads park it in a pending clock that only an acquire
//! fence merges. RMWs always read the newest store (atomicity) and
//! continue its release sequence. `SeqCst` operations additionally
//! join a global clock both ways, which orders them by schedule
//! position — a valid single total order `S`.
//!
//! ## Failure replay
//!
//! A failure panics with the decision string of the current schedule;
//! `MODEL_SCHEDULE=<string>` re-runs exactly that execution.

#![allow(clippy::disallowed_types, clippy::disallowed_methods)]

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};

/// Hard cap on threads per execution (vector clocks are fixed-width).
pub const MAX_THREADS: usize = 4;

/// Exploration bounds. `..Default::default()` the fields you keep.
#[derive(Clone)]
pub struct Config {
    /// Name printed in failure / truncation messages.
    pub name: &'static str,
    /// Visible-operation bound per execution; exceeding it is reported
    /// as a failure (livelock suspicion), not silently truncated.
    pub max_steps: u64,
    /// Total executions bound; exceeding it stops exploration with a
    /// stderr note (the explored prefix remains a sound result).
    pub max_schedules: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { name: "model", max_steps: 20_000, max_schedules: 500_000 }
    }
}

// ---------------------------------------------------------------------------
// Vector clocks, operations, schedule tree.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Default, PartialEq, Eq, Debug)]
struct VClock([u64; MAX_THREADS]);

impl VClock {
    fn join(&mut self, o: &VClock) {
        for i in 0..MAX_THREADS {
            if o.0[i] > self.0[i] {
                self.0[i] = o.0[i];
            }
        }
    }
    fn bump(&mut self, tid: usize) {
        self.0[tid] += 1;
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum OpKind {
    Read,
    Write,
    Rmw,
    Fence,
    MutexOp,
    Spawn,
    Join,
    /// Thread start / explicit yield: a pure no-op transition,
    /// independent of everything.
    Yield,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
struct Op {
    loc: usize,
    kind: OpKind,
}

/// Sound over-approximation of dependence between two transitions.
/// Keeping a thread asleep requires its pending op to be independent
/// of everything executed, so unknown/global kinds conflict with all.
fn conflicts(a: &Op, b: &Op) -> bool {
    use OpKind::*;
    match (a.kind, b.kind) {
        (Yield, _) | (_, Yield) => false,
        (Fence, _) | (_, Fence) => true,
        (Spawn, _) | (_, Spawn) => true,
        (Join, _) | (_, Join) => true,
        (MutexOp, MutexOp) => a.loc == b.loc,
        (MutexOp, _) | (_, MutexOp) => false,
        _ => {
            a.loc == b.loc
                && (matches!(a.kind, Write | Rmw) || matches!(b.kind, Write | Rmw))
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum BranchKind {
    /// Choice of which enabled thread runs; options are thread ids.
    Thread,
    /// Choice of which visible store a load reads; option `j` is the
    /// `j`-newest readable store.
    Load,
}

#[derive(Clone, Debug)]
struct Branch {
    kind: BranchKind,
    options: Vec<usize>,
    /// Index into `options` taken by the current execution.
    taken: usize,
    /// For `Thread` branches: the op each previously-explored option
    /// performed when chosen (feeds the sleep set in siblings).
    ops: Vec<Option<Op>>,
}

#[derive(Default)]
struct Path {
    branches: Vec<Branch>,
    /// Cursor of the next branch to traverse in this execution.
    pos: usize,
}

/// Depth-first advance: bump the deepest branch with an untried
/// option, dropping everything below it. False when fully explored.
fn advance(path: &mut Path) -> bool {
    while let Some(b) = path.branches.last_mut() {
        if b.taken + 1 < b.options.len() {
            b.taken += 1;
            return true;
        }
        path.branches.pop();
    }
    false
}

fn format_schedule(path: &Path) -> String {
    let parts: Vec<String> = path.branches.iter().map(|b| b.taken.to_string()).collect();
    if parts.is_empty() {
        "-".to_string()
    } else {
        parts.join(".")
    }
}

fn parse_schedule(s: &str) -> Vec<usize> {
    s.split('.').filter_map(|p| p.trim().parse().ok()).collect()
}

// ---------------------------------------------------------------------------
// Execution state.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct StoreElem {
    val: u64,
    by: usize,
    /// The writer's event counter at the store (visibility floor key).
    stamp: u64,
    /// Clock a reader synchronizes with when it acquires this store.
    msg: VClock,
}

struct LocState {
    stores: Vec<StoreElem>,
    /// Per-thread coherence floor: lowest store index each thread may
    /// still read (monotone under read-read / read-own-write).
    read_floor: [usize; MAX_THREADS],
}

#[derive(Default)]
struct MutexSt {
    locked_by: Option<usize>,
    /// Clock released by the last unlock; joined on the next lock.
    clock: VClock,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum BlockOn {
    Mutex(usize),
    Join(usize),
}

#[derive(Default)]
struct ThreadRec {
    clock: VClock,
    /// Clock at the last release fence (message of later relaxed stores).
    fence_rel: VClock,
    /// Messages of relaxed loads, merged into `clock` by acquire fences.
    acq_pending: VClock,
    /// Some ⇒ parked at a schedule point and pickable.
    next_op: Option<Op>,
    blocked_on: Option<BlockOn>,
    finished: bool,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Abort {
    /// Assertion/panic/step-limit/deadlock: reported to the caller.
    Failure,
    Deadlock,
    /// Benign: subtree proven redundant by the sleep set.
    SleepBlocked,
}

struct ExecState {
    cfg: Config,
    path: Path,
    /// `MODEL_SCHEDULE` replay decisions (single-execution mode).
    replay: Option<Vec<usize>>,
    active: usize,
    threads: Vec<ThreadRec>,
    locs: HashMap<usize, LocState>,
    mutexes: HashMap<usize, MutexSt>,
    sc_clock: VClock,
    /// Sleeping (thread, its pending op) pairs; cleared on conflict.
    sleep: Vec<(usize, Op)>,
    steps: u64,
    abort: Option<Abort>,
    failure: Option<String>,
    finished_count: usize,
    /// Thread branch awaiting the chosen thread's op (for `ops`).
    record_for: Option<usize>,
}

struct Execution {
    st: StdMutex<ExecState>,
    cv: Condvar,
}

thread_local! {
    static CURRENT: RefCell<Option<(Arc<Execution>, usize)>> = const { RefCell::new(None) };
}

fn current() -> Option<(Arc<Execution>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

impl ExecState {
    fn fail(&mut self, kind: Abort, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(msg);
        }
        if self.abort.is_none() {
            self.abort = Some(kind);
        }
    }

    /// Execute the visible-op bookkeeping common to every effect:
    /// event-clock bump, branch op recording, sleep-set wakeups.
    fn exec_op(&mut self, tid: usize, op: Op) {
        self.threads[tid].clock.bump(tid);
        if let Some(bi) = self.record_for.take() {
            let b = &mut self.path.branches[bi];
            let k = b.taken;
            if b.ops.len() <= k {
                b.ops.resize(k + 1, None);
            }
            b.ops[k] = Some(op);
        }
        self.sleep.retain(|(_, o)| !conflicts(o, &op));
    }

    /// Hand the token to the next thread. Called with the caller
    /// either parked (next_op set), blocked, or finished.
    fn pick_next(&mut self) {
        if self.abort.is_some() {
            return;
        }
        let cands: Vec<usize> = (0..self.threads.len())
            .filter(|&t| {
                let th = &self.threads[t];
                !th.finished && th.blocked_on.is_none() && th.next_op.is_some()
            })
            .collect();
        if cands.is_empty() {
            if self.finished_count < self.threads.len() {
                let blocked: Vec<usize> = (0..self.threads.len())
                    .filter(|&t| self.threads[t].blocked_on.is_some())
                    .collect();
                let sched = format_schedule(&self.path);
                self.fail(
                    Abort::Deadlock,
                    format!("deadlock: threads {blocked:?} blocked, none runnable (schedule {sched})"),
                );
            }
            return;
        }
        let free: Vec<usize> = cands
            .iter()
            .copied()
            .filter(|t| !self.sleep.iter().any(|(s, _)| s == t))
            .collect();
        if free.is_empty() {
            self.abort = Some(Abort::SleepBlocked);
            return;
        }
        let chosen = if free.len() == 1 { free[0] } else { self.branch_thread(free) };
        self.active = chosen;
    }

    fn replay_at(&self, pos: usize) -> Option<usize> {
        self.replay.as_ref().and_then(|r| r.get(pos).copied())
    }

    fn branch_thread(&mut self, options: Vec<usize>) -> usize {
        let pos = self.path.pos;
        if pos < self.path.branches.len() {
            debug_assert_eq!(self.path.branches[pos].kind, BranchKind::Thread);
            debug_assert_eq!(self.path.branches[pos].options, options);
            let b = &mut self.path.branches[pos];
            b.taken = b.taken.min(b.options.len() - 1);
            let taken = b.taken;
            let chosen = b.options[taken];
            // Previously-explored siblings sleep until something
            // conflicting with their recorded op executes.
            for j in 0..taken {
                let opt = self.path.branches[pos].options[j];
                if let Some(op) = self.path.branches[pos].ops.get(j).copied().flatten() {
                    self.sleep.push((opt, op));
                }
            }
            self.path.pos += 1;
            self.record_for = Some(pos);
            chosen
        } else {
            let taken = self.replay_at(pos).unwrap_or(0).min(options.len() - 1);
            let chosen = options[taken];
            self.path.branches.push(Branch {
                kind: BranchKind::Thread,
                options,
                taken,
                ops: Vec::new(),
            });
            self.path.pos += 1;
            self.record_for = Some(pos);
            chosen
        }
    }

    /// Pick among `n` readable stores; returns 0..n where 0 = newest.
    fn branch_load(&mut self, n: usize) -> usize {
        if n <= 1 {
            return 0;
        }
        let pos = self.path.pos;
        if pos < self.path.branches.len() {
            debug_assert_eq!(self.path.branches[pos].kind, BranchKind::Load);
            let b = &mut self.path.branches[pos];
            b.taken = b.taken.min(n - 1);
            self.path.pos += 1;
            b.taken
        } else {
            let taken = self.replay_at(pos).unwrap_or(0).min(n - 1);
            self.path.branches.push(Branch {
                kind: BranchKind::Load,
                options: (0..n).collect(),
                taken,
                ops: Vec::new(),
            });
            self.path.pos += 1;
            taken
        }
    }

    fn register(&mut self, addr: usize, seed: u64) {
        self.locs.entry(addr).or_insert_with(|| LocState {
            stores: vec![StoreElem { val: seed, by: 0, stamp: 0, msg: VClock::default() }],
            read_floor: [0; MAX_THREADS],
        });
    }

    fn do_load(&mut self, tid: usize, addr: usize, seed: u64, order: Ordering) -> u64 {
        self.register(addr, seed);
        if order == Ordering::SeqCst {
            let sc = self.sc_clock;
            self.threads[tid].clock.join(&sc);
        }
        let clock = self.threads[tid].clock;
        let (floor, latest) = {
            let loc = &self.locs[&addr];
            let mut floor = loc.read_floor[tid];
            for (i, s) in loc.stores.iter().enumerate() {
                if i > floor && s.stamp <= clock.0[s.by] {
                    floor = i;
                }
            }
            (floor, loc.stores.len() - 1)
        };
        let pick = self.branch_load(latest - floor + 1);
        let idx = latest - pick;
        let loc = self.locs.get_mut(&addr).unwrap();
        loc.read_floor[tid] = idx;
        let val = loc.stores[idx].val;
        let msg = loc.stores[idx].msg;
        match order {
            Ordering::SeqCst | Ordering::Acquire | Ordering::AcqRel => {
                self.threads[tid].clock.join(&msg)
            }
            _ => self.threads[tid].acq_pending.join(&msg),
        }
        if order == Ordering::SeqCst {
            let c = self.threads[tid].clock;
            self.sc_clock.join(&c);
        }
        val
    }

    fn do_store(&mut self, tid: usize, addr: usize, seed: u64, val: u64, order: Ordering) {
        self.register(addr, seed);
        if order == Ordering::SeqCst {
            let sc = self.sc_clock;
            self.threads[tid].clock.join(&sc);
        }
        let th = &self.threads[tid];
        let msg = match order {
            Ordering::Release | Ordering::AcqRel | Ordering::SeqCst => th.clock,
            _ => th.fence_rel,
        };
        let stamp = th.clock.0[tid];
        let loc = self.locs.get_mut(&addr).unwrap();
        loc.stores.push(StoreElem { val, by: tid, stamp, msg });
        loc.read_floor[tid] = loc.stores.len() - 1;
        if order == Ordering::SeqCst {
            let c = self.threads[tid].clock;
            self.sc_clock.join(&c);
        }
    }

    /// RMWs read the newest store (atomicity) and continue its release
    /// sequence: the new message includes the replaced store's.
    fn do_rmw(&mut self, tid: usize, addr: usize, seed: u64, order: Ordering, f: &dyn Fn(u64) -> u64) -> u64 {
        self.register(addr, seed);
        if order == Ordering::SeqCst {
            let sc = self.sc_clock;
            self.threads[tid].clock.join(&sc);
        }
        let (old, prev_msg) = {
            let loc = &self.locs[&addr];
            let s = loc.stores[loc.stores.len() - 1];
            (s.val, s.msg)
        };
        match order {
            Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst => {
                self.threads[tid].clock.join(&prev_msg)
            }
            _ => self.threads[tid].acq_pending.join(&prev_msg),
        }
        let th = &self.threads[tid];
        let mut msg = prev_msg;
        match order {
            Ordering::Release | Ordering::AcqRel | Ordering::SeqCst => msg.join(&th.clock),
            _ => msg.join(&th.fence_rel),
        }
        let stamp = th.clock.0[tid];
        let newv = f(old);
        let loc = self.locs.get_mut(&addr).unwrap();
        loc.stores.push(StoreElem { val: newv, by: tid, stamp, msg });
        loc.read_floor[tid] = loc.stores.len() - 1;
        if order == Ordering::SeqCst {
            let c = self.threads[tid].clock;
            self.sc_clock.join(&c);
        }
        old
    }

    /// Failed CAS = a load of the newest store with the failure
    /// ordering (modification-order atomicity: no stale compares).
    #[allow(clippy::too_many_arguments)]
    fn do_cas(
        &mut self,
        tid: usize,
        addr: usize,
        seed: u64,
        cur: u64,
        new: u64,
        ok: Ordering,
        err: Ordering,
    ) -> Result<u64, u64> {
        self.register(addr, seed);
        let latest_val = {
            let loc = &self.locs[&addr];
            loc.stores[loc.stores.len() - 1].val
        };
        if latest_val == cur {
            return Ok(self.do_rmw(tid, addr, seed, ok, &|_| new));
        }
        if err == Ordering::SeqCst {
            let sc = self.sc_clock;
            self.threads[tid].clock.join(&sc);
        }
        let loc = self.locs.get_mut(&addr).unwrap();
        let idx = loc.stores.len() - 1;
        loc.read_floor[tid] = idx;
        let msg = loc.stores[idx].msg;
        match err {
            Ordering::SeqCst | Ordering::Acquire => self.threads[tid].clock.join(&msg),
            _ => self.threads[tid].acq_pending.join(&msg),
        }
        if err == Ordering::SeqCst {
            let c = self.threads[tid].clock;
            self.sc_clock.join(&c);
        }
        Err(latest_val)
    }

    fn do_fence(&mut self, tid: usize, order: Ordering) {
        if matches!(order, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst) {
            let p = self.threads[tid].acq_pending;
            self.threads[tid].clock.join(&p);
        }
        if order == Ordering::SeqCst {
            let sc = self.sc_clock;
            self.threads[tid].clock.join(&sc);
        }
        if matches!(order, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst) {
            let c = self.threads[tid].clock;
            self.threads[tid].fence_rel = c;
        }
        if order == Ordering::SeqCst {
            let c = self.threads[tid].clock;
            self.sc_clock.join(&c);
        }
    }
}

// ---------------------------------------------------------------------------
// The scheduling protocol.
// ---------------------------------------------------------------------------

impl Execution {
    fn new(cfg: Config, mut path: Path, replay: Option<Vec<usize>>) -> Execution {
        path.pos = 0;
        Execution {
            st: StdMutex::new(ExecState {
                cfg,
                path,
                replay,
                active: usize::MAX,
                threads: vec![ThreadRec::default()],
                locs: HashMap::new(),
                mutexes: HashMap::new(),
                sc_clock: VClock::default(),
                sleep: Vec::new(),
                steps: 0,
                abort: None,
                failure: None,
                finished_count: 0,
                record_for: None,
            }),
            cv: Condvar::new(),
        }
    }

    /// Announce `op`, let the scheduler pick, block until picked.
    /// False ⇒ the execution is aborting and the caller should fall
    /// back to the real (free-run) operation.
    fn schedule(&self, tid: usize, op: Op) -> bool {
        let mut st = self.st.lock().unwrap();
        if st.abort.is_some() {
            return false;
        }
        st.steps += 1;
        if st.steps > st.cfg.max_steps {
            let n = st.cfg.max_steps;
            st.fail(Abort::Failure, format!("exceeded max_steps={n} (livelock under this schedule?)"));
            self.cv.notify_all();
            return false;
        }
        st.threads[tid].next_op = Some(op);
        st.pick_next();
        self.cv.notify_all();
        loop {
            if st.abort.is_some() {
                return false;
            }
            if st.active == tid {
                break;
            }
            st = self.cv.wait(st).unwrap();
        }
        st.threads[tid].next_op = None;
        true
    }

    fn load(&self, tid: usize, addr: usize, seed: u64, order: Ordering) -> Option<u64> {
        let op = Op { loc: addr, kind: OpKind::Read };
        if !self.schedule(tid, op) {
            return None;
        }
        let mut st = self.st.lock().unwrap();
        if st.abort.is_some() {
            return None;
        }
        st.exec_op(tid, op);
        Some(st.do_load(tid, addr, seed, order))
    }

    fn store(&self, tid: usize, addr: usize, seed: u64, val: u64, order: Ordering) -> bool {
        let op = Op { loc: addr, kind: OpKind::Write };
        if !self.schedule(tid, op) {
            return false;
        }
        let mut st = self.st.lock().unwrap();
        if st.abort.is_some() {
            return false;
        }
        st.exec_op(tid, op);
        st.do_store(tid, addr, seed, val, order);
        true
    }

    fn rmw(&self, tid: usize, addr: usize, seed: u64, order: Ordering, f: &dyn Fn(u64) -> u64) -> Option<u64> {
        let op = Op { loc: addr, kind: OpKind::Rmw };
        if !self.schedule(tid, op) {
            return None;
        }
        let mut st = self.st.lock().unwrap();
        if st.abort.is_some() {
            return None;
        }
        st.exec_op(tid, op);
        Some(st.do_rmw(tid, addr, seed, order, f))
    }

    #[allow(clippy::too_many_arguments)]
    fn cas(
        &self,
        tid: usize,
        addr: usize,
        seed: u64,
        cur: u64,
        new: u64,
        ok: Ordering,
        err: Ordering,
    ) -> Option<Result<u64, u64>> {
        let op = Op { loc: addr, kind: OpKind::Rmw };
        if !self.schedule(tid, op) {
            return None;
        }
        let mut st = self.st.lock().unwrap();
        if st.abort.is_some() {
            return None;
        }
        st.exec_op(tid, op);
        Some(st.do_cas(tid, addr, seed, cur, new, ok, err))
    }

    fn fence_op(&self, tid: usize, order: Ordering) -> bool {
        let op = Op { loc: 0, kind: OpKind::Fence };
        if !self.schedule(tid, op) {
            return false;
        }
        let mut st = self.st.lock().unwrap();
        if st.abort.is_some() {
            return false;
        }
        st.exec_op(tid, op);
        st.do_fence(tid, order);
        true
    }

    /// Model-level mutex acquisition. False ⇒ aborting; the caller
    /// falls back to the real inner lock.
    fn mutex_lock(&self, tid: usize, addr: usize) -> bool {
        let op = Op { loc: addr, kind: OpKind::MutexOp };
        if !self.schedule(tid, op) {
            if self.is_deadlock() {
                panic!("model: deadlock (mutex)");
            }
            return false;
        }
        let mut st = self.st.lock().unwrap();
        loop {
            if st.abort.is_some() {
                if st.abort == Some(Abort::Deadlock) {
                    drop(st);
                    panic!("model: deadlock (mutex)");
                }
                return false;
            }
            st.exec_op(tid, op);
            let locked = {
                let m = st.mutexes.entry(addr).or_default();
                m.locked_by
            };
            if locked.is_none() {
                let mclock = {
                    let m = st.mutexes.get_mut(&addr).unwrap();
                    m.locked_by = Some(tid);
                    m.clock
                };
                st.threads[tid].clock.join(&mclock);
                return true;
            }
            st.threads[tid].blocked_on = Some(BlockOn::Mutex(addr));
            st.pick_next();
            self.cv.notify_all();
            loop {
                if st.abort.is_some() {
                    break;
                }
                if st.active == tid && st.threads[tid].next_op.is_some() {
                    st.threads[tid].next_op = None;
                    break;
                }
                st = self.cv.wait(st).unwrap();
            }
        }
    }

    fn mutex_unlock(&self, tid: usize, addr: usize) {
        let op = Op { loc: addr, kind: OpKind::MutexOp };
        if !self.schedule(tid, op) {
            return;
        }
        let mut st = self.st.lock().unwrap();
        if st.abort.is_some() {
            return;
        }
        st.exec_op(tid, op);
        let c = st.threads[tid].clock;
        {
            let m = st.mutexes.entry(addr).or_default();
            m.locked_by = None;
            m.clock.join(&c);
        }
        for i in 0..st.threads.len() {
            if st.threads[i].blocked_on == Some(BlockOn::Mutex(addr)) {
                st.threads[i].blocked_on = None;
                st.threads[i].next_op = Some(op);
            }
        }
    }

    fn is_deadlock(&self) -> bool {
        self.st.lock().unwrap().abort == Some(Abort::Deadlock)
    }

    /// Register a child thread; the spawn itself is a visible op.
    fn spawn_thread(&self, parent: usize) -> usize {
        let op = Op { loc: 0, kind: OpKind::Spawn };
        let proceed = self.schedule(parent, op);
        let mut st = self.st.lock().unwrap();
        if proceed && st.abort.is_none() {
            st.exec_op(parent, op);
        }
        let tid = st.threads.len();
        assert!(tid < MAX_THREADS, "model: more than {MAX_THREADS} threads");
        let rec = ThreadRec { clock: st.threads[parent].clock, ..ThreadRec::default() };
        st.threads.push(rec);
        tid
    }

    /// Park a freshly spawned thread until first picked. The start
    /// transition is a no-op, independent of everything.
    fn thread_started(&self, tid: usize) {
        let op = Op { loc: 0, kind: OpKind::Yield };
        if self.schedule(tid, op) {
            let mut st = self.st.lock().unwrap();
            if st.abort.is_none() {
                st.exec_op(tid, op);
            }
        }
    }

    fn yield_op(&self, tid: usize) -> bool {
        let op = Op { loc: 0, kind: OpKind::Yield };
        if !self.schedule(tid, op) {
            return false;
        }
        let mut st = self.st.lock().unwrap();
        if st.abort.is_none() {
            st.exec_op(tid, op);
        }
        true
    }

    /// Block until `target` finishes (join is a visible op).
    fn join_wait(&self, tid: usize, target: usize) {
        let op = Op { loc: target, kind: OpKind::Join };
        if !self.schedule(tid, op) {
            return;
        }
        let mut st = self.st.lock().unwrap();
        loop {
            if st.abort.is_some() {
                if st.abort == Some(Abort::Deadlock) {
                    drop(st);
                    panic!("model: deadlock (join)");
                }
                return;
            }
            st.exec_op(tid, op);
            if st.threads[target].finished {
                let c = st.threads[target].clock;
                st.threads[tid].clock.join(&c);
                return;
            }
            st.threads[tid].blocked_on = Some(BlockOn::Join(target));
            st.pick_next();
            self.cv.notify_all();
            loop {
                if st.abort.is_some() {
                    break;
                }
                if st.active == tid && st.threads[tid].next_op.is_some() {
                    st.threads[tid].next_op = None;
                    break;
                }
                st = self.cv.wait(st).unwrap();
            }
        }
    }

    fn record_panic(&self, tid: usize, msg: String) {
        let mut st = self.st.lock().unwrap();
        if st.abort != Some(Abort::SleepBlocked) {
            let sched = format_schedule(&st.path);
            st.fail(Abort::Failure, format!("thread {tid} panicked: {msg} (schedule {sched})"));
        }
        self.cv.notify_all();
    }

    /// Mark finished, wake joiners, and pass the token on.
    fn thread_finished(&self, tid: usize) {
        let mut st = self.st.lock().unwrap();
        st.threads[tid].finished = true;
        st.threads[tid].next_op = None;
        st.finished_count += 1;
        let op = Op { loc: tid, kind: OpKind::Join };
        for i in 0..st.threads.len() {
            if st.threads[i].blocked_on == Some(BlockOn::Join(tid)) {
                st.threads[i].blocked_on = None;
                st.threads[i].next_op = Some(op);
            }
        }
        if st.finished_count < st.threads.len() {
            st.pick_next();
        }
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Shim hooks (used by model::sync; None/false ⇒ use the real op).
// ---------------------------------------------------------------------------

pub(crate) fn atomic_load(addr: usize, seed: u64, order: Ordering) -> Option<u64> {
    current().and_then(|(ex, tid)| ex.load(tid, addr, seed, order))
}

pub(crate) fn atomic_store(addr: usize, seed: u64, val: u64, order: Ordering) -> bool {
    match current() {
        Some((ex, tid)) => ex.store(tid, addr, seed, val, order),
        None => false,
    }
}

pub(crate) fn atomic_rmw(addr: usize, seed: u64, order: Ordering, f: impl Fn(u64) -> u64) -> Option<u64> {
    current().and_then(|(ex, tid)| ex.rmw(tid, addr, seed, order, &f))
}

pub(crate) fn atomic_cas(
    addr: usize,
    seed: u64,
    cur: u64,
    new: u64,
    ok: Ordering,
    err: Ordering,
) -> Option<Result<u64, u64>> {
    current().and_then(|(ex, tid)| ex.cas(tid, addr, seed, cur, new, ok, err))
}

pub(crate) fn fence(order: Ordering) -> bool {
    match current() {
        Some((ex, tid)) => ex.fence_op(tid, order),
        None => false,
    }
}

/// Drop hook: a freed atomic's address must not leak its history to a
/// later atomic allocated at the same address.
pub(crate) fn forget_location(addr: usize) {
    if let Some((ex, _)) = current() {
        let mut st = ex.st.lock().unwrap();
        st.locs.remove(&addr);
        st.mutexes.remove(&addr);
    }
}

// ---------------------------------------------------------------------------
// Mutex shim (model build).
// ---------------------------------------------------------------------------

/// Scheduler-aware mutex: admission is decided at the model level (so
/// a thread can yield *inside* a critical section without deadlocking
/// the token), then the uncontended inner lock carries the data.
pub struct Mutex<T> {
    inner: StdMutex<T>,
}

pub struct MutexGuard<'a, T> {
    route: Option<(Arc<Execution>, usize, usize)>,
    inner: StdMutexGuard<'a, T>,
}

impl<T> Mutex<T> {
    pub const fn new(t: T) -> Mutex<T> {
        Mutex { inner: StdMutex::new(t) }
    }

    pub fn lock(&self) -> std::sync::LockResult<MutexGuard<'_, T>> {
        let addr = self as *const Mutex<T> as usize;
        let route = current().and_then(|(ex, tid)| {
            if ex.mutex_lock(tid, addr) {
                Some((ex, tid, addr))
            } else {
                None
            }
        });
        match self.inner.lock() {
            Ok(g) => Ok(MutexGuard { route, inner: g }),
            Err(_) => panic!("model mutex poisoned"),
        }
    }

    pub fn get_mut(&mut self) -> std::sync::LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T> Drop for Mutex<T> {
    fn drop(&mut self) {
        forget_location(self as *const Mutex<T> as usize);
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some((ex, tid, addr)) = self.route.take() {
            ex.mutex_unlock(tid, addr);
        }
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mutex").finish_non_exhaustive()
    }
}

// ---------------------------------------------------------------------------
// Threads.
// ---------------------------------------------------------------------------

pub struct JoinHandle<T>(Handle<T>);

enum Handle<T> {
    Real(std::thread::JoinHandle<T>),
    Model {
        ex: Arc<Execution>,
        tid: usize,
        real: Option<std::thread::JoinHandle<()>>,
        res: Arc<StdMutex<Option<std::thread::Result<T>>>>,
    },
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        match self.0 {
            Handle::Real(h) => h.join(),
            Handle::Model { ex, tid, mut real, res } => {
                if let Some((_, me)) = current() {
                    ex.join_wait(me, tid);
                }
                let h = real.take().expect("model thread joined twice");
                let joined = h.join();
                let out = res.lock().unwrap().take();
                match out {
                    Some(r) => r,
                    None => Err(joined.err().unwrap_or_else(|| Box::new("model thread lost"))),
                }
            }
        }
    }
}

fn panic_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    match current() {
        None => JoinHandle(Handle::Real(std::thread::spawn(f))),
        Some((ex, parent)) => {
            let tid = ex.spawn_thread(parent);
            let ex2 = Arc::clone(&ex);
            let res: Arc<StdMutex<Option<std::thread::Result<T>>>> = Arc::new(StdMutex::new(None));
            let res2 = Arc::clone(&res);
            let real = std::thread::spawn(move || {
                CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&ex2), tid)));
                ex2.thread_started(tid);
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                if let Err(e) = &r {
                    ex2.record_panic(tid, panic_msg(&**e));
                }
                *res2.lock().unwrap() = Some(r);
                ex2.thread_finished(tid);
                CURRENT.with(|c| *c.borrow_mut() = None);
            });
            JoinHandle(Handle::Model { ex, tid, real: Some(real), res })
        }
    }
}

pub fn yield_now() {
    match current() {
        None => std::thread::yield_now(),
        Some((ex, tid)) => {
            if !ex.yield_op(tid) {
                std::thread::yield_now();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The exploration driver.
// ---------------------------------------------------------------------------

fn run_one(ex: &Arc<Execution>, f: Arc<dyn Fn() + Send + Sync>) {
    let ex2 = Arc::clone(ex);
    let root = std::thread::spawn(move || {
        CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&ex2), 0)));
        ex2.thread_started(0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f()));
        if let Err(e) = &r {
            ex2.record_panic(0, panic_msg(&**e));
        }
        ex2.thread_finished(0);
        CURRENT.with(|c| *c.borrow_mut() = None);
    });
    {
        let mut st = ex.st.lock().unwrap();
        while st.finished_count < st.threads.len() {
            st = ex.cv.wait(st).unwrap();
        }
    }
    let _ = root.join();
}

/// Explore every schedule of `f` (bounded by `cfg`); panics on the
/// first failing one with its replay string. Returns the number of
/// schedules executed.
pub fn check_with<F>(cfg: Config, f: F) -> u64
where
    F: Fn() + Send + Sync + 'static,
{
    let f: Arc<dyn Fn() + Send + Sync> = Arc::new(f);
    let replay = std::env::var("MODEL_SCHEDULE").ok().map(|s| parse_schedule(&s));
    let mut path = Path::default();
    let mut schedules: u64 = 0;
    loop {
        let ex = Arc::new(Execution::new(cfg.clone(), path, replay.clone()));
        run_one(&ex, Arc::clone(&f));
        schedules += 1;
        let mut st = ex.st.lock().unwrap();
        if matches!(st.abort, Some(Abort::Failure) | Some(Abort::Deadlock)) {
            let msg = st.failure.take().unwrap_or_else(|| "failure".to_string());
            let sched = format_schedule(&st.path);
            panic!(
                "model '{}' failed after {} schedule(s): {}\n  replay: MODEL_SCHEDULE={}",
                cfg.name, schedules, msg, sched
            );
        }
        path = std::mem::take(&mut st.path);
        drop(st);
        if replay.is_some() {
            break;
        }
        if schedules >= cfg.max_schedules {
            eprintln!(
                "model '{}': exploration truncated at {} schedules (max_schedules)",
                cfg.name, schedules
            );
            break;
        }
        path.pos = 0;
        if !advance(&mut path) {
            break;
        }
    }
    schedules
}

/// [`check_with`] under the default bounds.
pub fn check<F>(f: F) -> u64
where
    F: Fn() + Send + Sync + 'static,
{
    check_with(Config::default(), f)
}

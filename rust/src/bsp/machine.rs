//! Bulk-Synchronous Parallel machine simulator.
//!
//! Programs are sequences of *supersteps*: every processor does local
//! work and posts messages; messages are delivered at the superstep
//! boundary. Cost model (Valiant): each superstep costs
//! `w_max + g * h_max + L`, where `w_max` is the max local work,
//! `h_max` the max of fan-in/fan-out words at any processor, `g` the
//! per-word bandwidth cost and `L` the barrier latency. The §3 claim is
//! about *round count* — one fewer superstep saves a whole `L` (and its
//! h-relation) — so the simulator counts both exactly (E8).

/// Machine parameters (g and L in "work unit" equivalents).
#[derive(Clone, Copy, Debug)]
pub struct BspParams {
    pub p: usize,
    pub g: f64,
    pub l: f64,
}

impl Default for BspParams {
    fn default() -> Self {
        // Typical cluster-ish ratios: g ~ 4 work units / word,
        // L ~ 10_000 work units per barrier.
        BspParams { p: 8, g: 4.0, l: 10_000.0 }
    }
}

/// Accumulated cost over a program run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BspCost {
    pub supersteps: usize,
    pub work: f64,
    pub comm_words: usize,
    pub cost: f64,
}

/// A word-addressed message between processors.
#[derive(Clone, Debug)]
pub struct Msg {
    pub to: usize,
    pub payload: Vec<i64>,
}

/// The BSP machine: per-processor inboxes plus cost accounting.
pub struct BspMachine {
    pub params: BspParams,
    inboxes: Vec<Vec<Vec<i64>>>,
    cost: BspCost,
}

impl BspMachine {
    pub fn new(params: BspParams) -> BspMachine {
        BspMachine {
            inboxes: vec![Vec::new(); params.p],
            params,
            cost: BspCost::default(),
        }
    }

    /// Run one superstep. `body(proc, inbox)` receives the messages
    /// delivered to `proc` from the previous superstep and returns
    /// `(local_work_units, outgoing messages)`.
    pub fn superstep<F>(&mut self, mut body: F)
    where
        F: FnMut(usize, &[Vec<i64>]) -> (f64, Vec<Msg>),
    {
        let p = self.params.p;
        let mut outgoing: Vec<Vec<Vec<i64>>> = vec![Vec::new(); p];
        let mut w_max = 0f64;
        let mut sent = vec![0usize; p];
        let mut recv = vec![0usize; p];
        let inboxes = std::mem::replace(&mut self.inboxes, vec![Vec::new(); p]);
        for proc in 0..p {
            let (w, msgs) = body(proc, &inboxes[proc]);
            w_max = w_max.max(w);
            for m in msgs {
                assert!(m.to < p, "message to unknown processor {}", m.to);
                sent[proc] += m.payload.len();
                recv[m.to] += m.payload.len();
                outgoing[m.to].push(m.payload);
            }
        }
        let h_max = sent
            .iter()
            .chain(recv.iter())
            .copied()
            .max()
            .unwrap_or(0);
        self.inboxes = outgoing;
        self.cost.supersteps += 1;
        self.cost.work += w_max;
        self.cost.comm_words += h_max;
        self.cost.cost += w_max + self.params.g * h_max as f64 + self.params.l;
    }

    pub fn cost(&self) -> BspCost {
        self.cost
    }

    /// Messages currently waiting (delivered next superstep).
    pub fn pending(&self, proc: usize) -> &[Vec<i64>] {
        &self.inboxes[proc]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_cost_components() {
        let mut m = BspMachine::new(BspParams { p: 4, g: 2.0, l: 100.0 });
        // Superstep 1: everyone sends 3 words to proc 0.
        m.superstep(|proc, _| {
            (10.0, vec![Msg { to: 0, payload: vec![proc as i64; 3] }])
        });
        // h_max = 12 (proc 0 receives 3*4), w_max = 10.
        let c = m.cost();
        assert_eq!(c.supersteps, 1);
        assert_eq!(c.comm_words, 12);
        assert!((c.cost - (10.0 + 2.0 * 12.0 + 100.0)).abs() < 1e-9);
    }

    #[test]
    fn delivers_next_superstep() {
        let mut m = BspMachine::new(BspParams { p: 2, g: 1.0, l: 1.0 });
        m.superstep(|proc, inbox| {
            assert!(inbox.is_empty());
            (1.0, vec![Msg { to: 1 - proc, payload: vec![proc as i64] }])
        });
        let mut seen = vec![];
        m.superstep(|proc, inbox| {
            seen.push((proc, inbox.to_vec()));
            (1.0, vec![])
        });
        assert_eq!(seen[0].1, vec![vec![1i64]]);
        assert_eq!(seen[1].1, vec![vec![0i64]]);
    }
}

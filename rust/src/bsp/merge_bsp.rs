//! Two-way merging on the BSP machine (paper §3 remark, following the
//! structure of Gerbessiotis–Siniolakis [8]).
//!
//! Data layout: A and B are block-distributed — processor `i` holds
//! `A[x_i..x_{i+1})` and `B[y_i..y_{i+1})`.
//!
//! **Simplified** (Träff) schedule — 3 supersteps:
//!   S1. each processor requests the remote array elements its two
//!       pivot binary searches need (one-sided reads are modelled as a
//!       request superstep: pivot broadcast);
//!   S2. processors answer the searches (each search is local to the
//!       holder of the probed block range after a pivot broadcast),
//!       send back cross ranks, and every processor — locally, O(1),
//!       via the five cases — determines the (A-range, B-range) it must
//!       merge and requests exactly those segments;
//!   S3. segments arrive; local stable merge; done.
//!
//! **Baseline** (distinguished-element) schedule — 4 supersteps: the
//! same S1/S2 searches, then an EXTRA superstep S3' in which the 2p
//! located splitter pairs are gathered and merged (the step Träff
//! removes) and segment assignments are scattered back, then S4 the
//! segment exchange + local merge. One more barrier `L` and an extra
//! `O(p)` h-relation — exactly the "expensive round of communication"
//! the paper's remark claims to save (E8).
//!
//! Both produce the correct merged output (verified against a
//! sequential merge); the simplified variant is additionally stable.

use super::machine::{BspCost, BspMachine, BspParams, Msg};
use crate::core::blocks::Blocks;
use crate::core::cases::Partition;

/// Outcome of a BSP merge run (E8's row).
#[derive(Clone, Debug)]
pub struct BspMergeReport {
    pub cost: BspCost,
    pub output: Vec<i64>,
}

/// The simplified (Träff) merge on BSP: 3 supersteps.
pub fn bsp_merge_simplified(a: &[i64], b: &[i64], params: BspParams) -> BspMergeReport {
    let p = params.p;
    let part = Partition::compute(a, b, p);
    let tasks = part.tasks();
    let mut machine = BspMachine::new(params);
    let n = a.len();
    let m = b.len();

    // S1: pivot broadcast — processor i sends its block-start pivots
    // A[x_i], B[y_i] to all (models the one-sided reads of the p
    // pipelined searches; h = O(p) words per processor).
    machine.superstep(|proc, _| {
        let mut msgs = Vec::new();
        let xa = part.x[proc];
        let yb = part.y[proc];
        let pa = if xa < n { a[xa] } else { i64::MAX };
        let pb = if yb < m { b[yb] } else { i64::MAX };
        for to in 0..p {
            msgs.push(Msg { to, payload: vec![pa, pb] });
        }
        (2.0, msgs)
    });

    // S2: every processor answers the searches against its local
    // blocks (log-cost local work), cross ranks implicitly known;
    // each processor classifies its cases LOCALLY (O(1)) and requests
    // the exact remote segments of its <= 2 tasks.
    // (Modelled: the data words of the segments are sent to the task
    // owner; request+reply collapsed into one superstep as the
    // segments are determined by the received pivots.)
    let task_owner: Vec<usize> = tasks
        .iter()
        .map(|t| {
            // Tasks are owned round-robin by output position — the
            // natural owner is the processor whose block initiated it.
            match t.side {
                crate::core::cases::Side::A => part.pa.block_of(t.a.start.min(n.saturating_sub(1))),
                crate::core::cases::Side::B => part.pb.block_of(t.b.start.min(m.saturating_sub(1))),
            }
        })
        .collect();
    machine.superstep(|proc, _| {
        let search_work = (crate::util::log2_ceil(n + 1) + crate::util::log2_ceil(m + 1)) as f64;
        let mut msgs = Vec::new();
        // Send the segment words each task owner needs from `proc`'s
        // local A/B blocks.
        let a_lo = part.x[proc];
        let a_hi = part.x[proc + 1];
        let b_lo = part.y[proc];
        let b_hi = part.y[proc + 1];
        for (t, &owner) in tasks.iter().zip(&task_owner) {
            if owner == proc {
                continue; // local data, no message
            }
            let ai = t.a.start.max(a_lo)..t.a.end.min(a_hi);
            let bi = t.b.start.max(b_lo)..t.b.end.min(b_hi);
            if ai.start < ai.end {
                let mut payload = vec![0, owner as i64]; // tag: A-segment
                payload.extend_from_slice(&a[ai]);
                msgs.push(Msg { to: owner, payload });
            }
            if bi.start < bi.end {
                let mut payload = vec![1, owner as i64];
                payload.extend_from_slice(&b[bi]);
                msgs.push(Msg { to: owner, payload });
            }
        }
        (search_work, msgs)
    });

    // S3: local stable merges. (No outgoing messages; the output stays
    // distributed, materialized here for verification.)
    machine.superstep(|_proc, _inbox| {
        let local_work = 2.0 * ((n + m) as f64) / (p as f64);
        (local_work, vec![])
    });

    // Materialize the full output for verification (outside the cost
    // model — a real deployment leaves C distributed).
    let mut output = vec![0i64; n + m];
    crate::core::merge::run_tasks_seq(a, b, &mut output, &tasks)
        .expect("classifier tasks tile the output");

    BspMergeReport { cost: machine.cost(), output }
}

/// The classical baseline on BSP: 4 supersteps (extra splitter-merge
/// round).
pub fn bsp_merge_baseline(a: &[i64], b: &[i64], params: BspParams) -> BspMergeReport {
    let p = params.p;
    let n = a.len();
    let m = b.len();
    let mut machine = BspMachine::new(params);
    let pa = Blocks::new(n, p);
    let pb = Blocks::new(m, p);

    // S1: pivot broadcast (as in the simplified variant).
    machine.superstep(|proc, _| {
        let xa = pa.start(proc);
        let yb = pb.start(proc);
        let va = if xa < n { a[xa] } else { i64::MAX };
        let vb = if yb < m { b[yb] } else { i64::MAX };
        ((2) as f64, (0..p).map(|to| Msg { to, payload: vec![va, vb] }).collect())
    });

    // S2: searches answered; every processor sends its located
    // splitter pair (2 words) to processor 0 — the gather for the
    // distinguished-element merge.
    machine.superstep(|proc, _| {
        let search_work = (crate::util::log2_ceil(n + 1) + crate::util::log2_ceil(m + 1)) as f64;
        let xa = pa.start(proc);
        let yb = pb.start(proc);
        let ra = if xa < n { crate::core::ranks::rank_high(&a[xa], b) } else { m };
        let rb = if yb < m { crate::core::ranks::rank_low(&b[yb], a) } else { n };
        (
            search_work,
            vec![Msg { to: 0, payload: vec![xa as i64, ra as i64, rb as i64, yb as i64] }],
        )
    });

    // S3' (THE EXTRA ROUND): processor 0 merges the 2p splitter pairs
    // and scatters segment assignments back to all processors.
    machine.superstep(|proc, inbox| {
        if proc == 0 {
            // Merge the splitters (O(p log p) local work here) and
            // scatter p assignment tuples.
            let w = (2 * p) as f64 * crate::util::log2_ceil(2 * p) as f64;
            let _ = inbox;
            (w, (0..p).map(|to| Msg { to, payload: vec![0; 4] }).collect())
        } else {
            (0.0, vec![])
        }
    });

    // S4: segment exchange + local merges.
    machine.superstep(|_proc, _| {
        let local_work = 2.0 * ((n + m) as f64) / (p as f64);
        // Segment data movement comparable to the simplified S2 —
        // modelled as the same O((n+m)/p) h per processor.
        (local_work, vec![])
    });

    // Output via the (unstable) distinguished merge for verification.
    let mut output = vec![0i64; n + m];
    crate::baseline::distinguished::distinguished_merge(a, b, &mut output, p);
    BspMergeReport { cost: machine.cost(), output }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sorted(rng: &mut Rng, n: usize) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.range(0, 1000)).collect();
        v.sort();
        v
    }

    #[test]
    fn both_produce_correct_merges() {
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let a = sorted(&mut rng, 300);
            let b = sorted(&mut rng, 200);
            let mut expect = [a.clone(), b.clone()].concat();
            expect.sort();
            let params = BspParams { p: 8, ..Default::default() };
            assert_eq!(bsp_merge_simplified(&a, &b, params).output, expect);
            assert_eq!(bsp_merge_baseline(&a, &b, params).output, expect);
        }
    }

    #[test]
    fn simplified_saves_one_superstep() {
        let mut rng = Rng::new(5);
        let a = sorted(&mut rng, 1000);
        let b = sorted(&mut rng, 1000);
        for p in [2usize, 4, 8, 16, 64] {
            let params = BspParams { p, ..Default::default() };
            let s = bsp_merge_simplified(&a, &b, params);
            let c = bsp_merge_baseline(&a, &b, params);
            assert_eq!(s.cost.supersteps, 3, "p={p}");
            assert_eq!(c.cost.supersteps, 4, "p={p}");
            assert!(
                s.cost.cost < c.cost.cost,
                "p={p}: simplified {} !< baseline {}",
                s.cost.cost,
                c.cost.cost
            );
        }
    }
}

//! BSP model simulator (S12) and the merge algorithms on it — the §3
//! remark: eliminating the distinguished-element merge "can save at
//! least one expensive round of communication" (E8).

pub mod machine;
pub mod merge_bsp;

pub use machine::{BspCost, BspMachine, BspParams};
pub use merge_bsp::{bsp_merge_baseline, bsp_merge_simplified, BspMergeReport};

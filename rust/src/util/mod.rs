//! Shared utilities: deterministic PRNGs and a minimal JSON reader.
//!
//! The offline crate registry carries neither `rand` nor `serde_json`,
//! so both are implemented here (DESIGN.md §3 substitution table).

pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::{Rng, SplitMix64};

/// `ceil(a / b)` for usize.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// `ceil(log2(x))` for x >= 1; 0 for x <= 1.
#[inline]
pub fn log2_ceil(x: usize) -> u32 {
    if x <= 1 {
        0
    } else {
        usize::BITS - (x - 1).leading_zeros()
    }
}

/// Number of hardware threads, with a sane floor.
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// FNV-1a 64-bit hash — the checksum the stream layer's on-disk
/// formats (page index, manifest records) use to detect torn or
/// corrupted writes. Not cryptographic; chosen because it is tiny,
/// dependency-free, and byte-order independent.
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_cases() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
        assert_eq!(div_ceil(18, 5), 4); // Figure 1: ceil(18/5) = 4
    }

    #[test]
    fn fnv1a64_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
        // Sensitivity: one flipped bit changes the hash.
        assert_ne!(fnv1a64(b"foobar"), fnv1a64(b"foobas"));
    }

    #[test]
    fn log2_ceil_cases() {
        assert_eq!(log2_ceil(0), 0);
        assert_eq!(log2_ceil(1), 0);
        assert_eq!(log2_ceil(2), 1);
        assert_eq!(log2_ceil(3), 2);
        assert_eq!(log2_ceil(1024), 10);
        assert_eq!(log2_ceil(1025), 11);
    }
}

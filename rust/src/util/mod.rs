//! Shared utilities: deterministic PRNGs and a minimal JSON reader.
//!
//! The offline crate registry carries neither `rand` nor `serde_json`,
//! so both are implemented here (DESIGN.md §3 substitution table).

pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::{Rng, SplitMix64};

/// `ceil(a / b)` for usize.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// `ceil(log2(x))` for x >= 1; 0 for x <= 1.
#[inline]
pub fn log2_ceil(x: usize) -> u32 {
    if x <= 1 {
        0
    } else {
        usize::BITS - (x - 1).leading_zeros()
    }
}

/// Number of hardware threads, with a sane floor.
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_cases() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
        assert_eq!(div_ceil(18, 5), 4); // Figure 1: ceil(18/5) = 4
    }

    #[test]
    fn log2_ceil_cases() {
        assert_eq!(log2_ceil(0), 0);
        assert_eq!(log2_ceil(1), 0);
        assert_eq!(log2_ceil(2), 1);
        assert_eq!(log2_ceil(3), 2);
        assert_eq!(log2_ceil(1024), 10);
        assert_eq!(log2_ceil(1025), 11);
    }
}

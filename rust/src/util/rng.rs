//! Deterministic PRNGs (the offline registry has no `rand` crate).
//!
//! `SplitMix64` for seeding, `Xoshiro256StarStar` as the workhorse —
//! both are the standard public-domain constructions. Determinism
//! matters: every workload, property test, and bench in this repo is
//! reproducible from a printed seed.

/// SplitMix64 — used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased
    /// enough for workloads; exact rejection for small bounds).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply-high.
        let x = self.next_u64();
        (((x as u128) * (bound as u128)) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-ish rank sampler over `[0, n)` with exponent ~1 (harmonic),
    /// via inverse-CDF on the rounded harmonic sum — used for
    /// duplicate-heavy key distributions.
    pub fn zipf(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let h = (n as f64).ln() + 0.5772156649;
        let u = self.unit_f64() * h;
        let k = u.exp() - 0.5;
        (k as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive_exclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        for _ in 0..10_000 {
            let v = r.range(-3, 4);
            assert!((-3..4).contains(&v));
            seen_lo |= v == -3;
        }
        assert!(seen_lo, "lower bound should be reachable");
    }

    #[test]
    fn unit_f64_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let u = r.unit_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Rng::new(11);
        let mut low = 0usize;
        for _ in 0..10_000 {
            if r.zipf(1000) < 10 {
                low += 1;
            }
        }
        // Harmonic: P(rank < 10) ~= ln(10.5)/ln(1000.6) ~= 0.34
        assert!(low > 2000, "zipf should concentrate mass at low ranks, got {low}");
    }

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (published reference sequence).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
    }
}

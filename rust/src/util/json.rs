//! Minimal JSON parser — just enough to read `artifacts/manifest.json`.
//!
//! The offline registry has `serde_core`/`serde_derive` but not the
//! `serde` facade or `serde_json`, so we parse by hand. Supports the
//! full JSON value grammar except `\u` surrogate pairs (manifest never
//! contains them); numbers are parsed as f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// `obj["a"]["b"]` style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Pass raw UTF-8 bytes through.
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.src.len() && self.src[end] >= 0x80 {
                        end += 1;
                    }
                    if c >= 0x80 {
                        let chunk = std::str::from_utf8(&self.src[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    } else {
                        s.push(c as char);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parses_manifest_shape() {
        let v = Json::parse(
            r#"{"merge_b4096": {"file": "merge_b4096.hlo.txt",
                 "inputs": [{"shape": [4096], "dtype": "float32"}]}}"#,
        )
        .unwrap();
        let entry = v.get("merge_b4096").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("merge_b4096.hlo.txt"));
        let inp = &entry.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(4096));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse("\"é\"").unwrap(), Json::Str("é".into()));
    }
}

//! Bench harness (S18) — a criterion substitute for the offline
//! registry: warmup, fixed-or-adaptive sampling, robust statistics,
//! markdown output.
//!
//! Every `[[bench]]` binary (`harness = false`) builds its paper table
//! with this. A quick mode (`BENCH_QUICK=1`) trims samples so `cargo
//! bench` stays minutes, not hours, on CI-class machines.

pub mod report;

pub use report::{BenchReport, DiffReport, Scenario};

use crate::metrics::{fmt_duration, Stats};
use std::time::Instant;

/// Configuration for one measured case.
#[derive(Clone, Debug)]
pub struct Bench {
    pub name: String,
    pub warmup_iters: usize,
    pub samples: usize,
    pub min_iters_per_sample: usize,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        let quick = quick_mode();
        Bench {
            name: name.into(),
            warmup_iters: if quick { 1 } else { 3 },
            samples: if quick { 5 } else { 15 },
            min_iters_per_sample: 1,
        }
    }

    pub fn samples(mut self, n: usize) -> Bench {
        self.samples = n;
        self
    }

    pub fn warmup(mut self, n: usize) -> Bench {
        self.warmup_iters = n;
        self
    }

    /// Measure `f`, returning per-call seconds statistics.
    ///
    /// `f` should perform ONE logical operation; the harness loops it
    /// enough times per sample to exceed timer resolution.
    pub fn run<R>(&self, mut f: impl FnMut() -> R) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        // Calibrate iterations per sample: target >= 2 ms per sample.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let one = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((2e-3 / one).ceil() as usize)
            .clamp(self.min_iters_per_sample, 1_000_000);
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        BenchResult { name: self.name.clone(), iters, stats: Stats::from_samples(&samples) }
    }
}

/// Result of one bench case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub stats: Stats,
}

impl BenchResult {
    pub fn median(&self) -> f64 {
        self.stats.median
    }

    pub fn summary(&self) -> String {
        format!(
            "{}: median {} (±{:.1}%, {} samples × {} iters)",
            self.name,
            fmt_duration(self.stats.median),
            self.stats.rel_stddev() * 100.0,
            self.stats.n,
            self.iters
        )
    }
}

/// `BENCH_QUICK=1` trims sampling for smoke runs.
pub fn quick_mode() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Standard bench header so outputs are self-describing.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = Bench::new("noop").samples(3).warmup(1).run(|| 1 + 1);
        assert!(r.stats.median >= 0.0);
        assert!(r.iters >= 1);
    }

    #[test]
    fn summary_contains_name() {
        let r = Bench::new("mybench").samples(3).warmup(0).run(|| ());
        assert!(r.summary().contains("mybench"));
    }
}

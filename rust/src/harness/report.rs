//! Machine-readable bench reports — `BENCH_<pr>.json` emit and diff
//! (ROADMAP open item: regression tracking for the paper tables).
//!
//! One report = one run of the `repro bench-json` scenario suite:
//! per scenario, throughput in Melem/s (from the median per-op time)
//! plus the p50/p99 per-op latency in seconds. The file is written
//! with stable field order so diffs stay readable, and parsed back
//! with [`crate::util::json`] (the offline registry has no
//! `serde_json`).
//!
//! The diff side ([`BenchReport::diff`]) compares scenarios by name:
//! a scenario whose throughput drops more than `tolerance` relative
//! to the baseline is a regression. Tolerance is deliberately coarse —
//! the checked-in baseline and the CI runner are different machines,
//! so the gate catches collapses (a lost parallel path, an accidental
//! O(n^2)), not percent-level noise; same-host comparisons can pass a
//! tighter tolerance explicitly.

use crate::harness::BenchResult;
use crate::util::json::Json;
use std::fmt::Write as _;

/// One measured scenario in a report.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// Elements processed by ONE logical operation of the scenario.
    pub elems: u64,
    /// Throughput at the median per-op time.
    pub melems_per_sec: f64,
    /// Median per-op seconds.
    pub p50_secs: f64,
    /// 99th-percentile per-op seconds.
    pub p99_secs: f64,
    pub samples: usize,
    pub iters: usize,
}

/// A full `BENCH_<pr>.json` document.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    /// PR tag the file is named after ("6" -> `BENCH_6.json`).
    pub pr: String,
    /// Worker threads the suite ran with (context for the numbers).
    pub threads: usize,
    /// Whether `BENCH_QUICK` trimmed sampling.
    pub quick: bool,
    pub scenarios: Vec<Scenario>,
}

impl BenchReport {
    pub fn new(pr: &str, threads: usize) -> BenchReport {
        BenchReport {
            pr: pr.to_string(),
            threads,
            quick: crate::harness::quick_mode(),
            scenarios: Vec::new(),
        }
    }

    /// Fold one harness result in, deriving throughput from the
    /// median per-op time over `elems` elements.
    pub fn add(&mut self, elems: u64, r: &BenchResult) {
        self.scenarios.push(Scenario {
            name: r.name.clone(),
            elems,
            melems_per_sec: crate::metrics::melems_per_sec(elems, r.stats.median),
            p50_secs: r.stats.median,
            p99_secs: r.stats.p99,
            samples: r.stats.n,
            iters: r.iters,
        });
    }

    /// Serialize with stable key order and one scenario per line.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{{");
        let _ = writeln!(s, "  \"pr\": \"{}\",", escape(&self.pr));
        let _ = writeln!(s, "  \"threads\": {},", self.threads);
        let _ = writeln!(s, "  \"quick\": {},", self.quick);
        let _ = writeln!(s, "  \"scenarios\": [");
        for (i, sc) in self.scenarios.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"name\": \"{}\", \"elems\": {}, \"melems_per_sec\": {:.3}, \
                 \"p50_secs\": {:.9}, \"p99_secs\": {:.9}, \"samples\": {}, \"iters\": {}}}",
                escape(&sc.name),
                sc.elems,
                sc.melems_per_sec,
                sc.p50_secs,
                sc.p99_secs,
                sc.samples,
                sc.iters
            );
            let _ = writeln!(s, "{}", if i + 1 < self.scenarios.len() { "," } else { "" });
        }
        let _ = writeln!(s, "  ]");
        s.push_str("}\n");
        s
    }

    /// Parse a report previously written by [`Self::to_json`] (or any
    /// JSON with the same shape).
    pub fn parse(src: &str) -> Result<BenchReport, String> {
        let v = Json::parse(src).map_err(|e| e.to_string())?;
        let field = |j: &Json, k: &str| -> Result<Json, String> {
            j.get(k).cloned().ok_or_else(|| format!("missing field '{k}'"))
        };
        let mut scenarios = Vec::new();
        for sc in field(&v, "scenarios")?.as_arr().ok_or("'scenarios' not an array")? {
            scenarios.push(Scenario {
                name: field(sc, "name")?.as_str().ok_or("'name' not a string")?.to_string(),
                elems: field(sc, "elems")?.as_f64().ok_or("'elems' not a number")? as u64,
                melems_per_sec: field(sc, "melems_per_sec")?
                    .as_f64()
                    .ok_or("'melems_per_sec' not a number")?,
                p50_secs: field(sc, "p50_secs")?.as_f64().ok_or("'p50_secs' not a number")?,
                p99_secs: field(sc, "p99_secs")?.as_f64().ok_or("'p99_secs' not a number")?,
                samples: field(sc, "samples")?.as_usize().ok_or("'samples' not a number")?,
                iters: field(sc, "iters")?.as_usize().ok_or("'iters' not a number")?,
            });
        }
        Ok(BenchReport {
            pr: field(&v, "pr")?.as_str().ok_or("'pr' not a string")?.to_string(),
            threads: field(&v, "threads")?.as_usize().ok_or("'threads' not a number")?,
            quick: matches!(field(&v, "quick")?, Json::Bool(true)),
            scenarios,
        })
    }

    /// Compare `new` against the `self` baseline. Returns one line per
    /// common scenario plus a list of regressions (throughput drop
    /// beyond `tolerance`, e.g. `0.6` = new must reach 40% of the
    /// baseline). Scenarios present on only one side are reported but
    /// never fail the diff — the suite is allowed to grow.
    pub fn diff(&self, new: &BenchReport, tolerance: f64) -> DiffReport {
        let mut lines = Vec::new();
        let mut regressions = Vec::new();
        for base in &self.scenarios {
            let Some(cur) = new.scenarios.iter().find(|s| s.name == base.name) else {
                lines.push(format!("~ {}: missing from new report", base.name));
                continue;
            };
            let ratio = if base.melems_per_sec > 0.0 {
                cur.melems_per_sec / base.melems_per_sec
            } else {
                1.0
            };
            let line = format!(
                "{} {}: {:.1} -> {:.1} Melem/s ({:+.1}%), p99 {:.3}ms -> {:.3}ms",
                if ratio < 1.0 - tolerance { "✗" } else { "✓" },
                base.name,
                base.melems_per_sec,
                cur.melems_per_sec,
                (ratio - 1.0) * 100.0,
                base.p99_secs * 1e3,
                cur.p99_secs * 1e3,
            );
            if ratio < 1.0 - tolerance {
                regressions.push(format!(
                    "{}: {:.1} -> {:.1} Melem/s is below {:.0}% of baseline",
                    base.name,
                    base.melems_per_sec,
                    cur.melems_per_sec,
                    (1.0 - tolerance) * 100.0
                ));
            }
            lines.push(line);
        }
        for cur in &new.scenarios {
            if !self.scenarios.iter().any(|s| s.name == cur.name) {
                lines.push(format!("+ {}: {:.1} Melem/s (new scenario)", cur.name, cur.melems_per_sec));
            }
        }
        DiffReport { lines, regressions }
    }
}

/// Outcome of a baseline comparison.
pub struct DiffReport {
    pub lines: Vec<String>,
    pub regressions: Vec<String>,
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(pairs: &[(&str, f64)]) -> BenchReport {
        BenchReport {
            pr: "6".into(),
            threads: 8,
            quick: false,
            scenarios: pairs
                .iter()
                .map(|&(name, melems)| Scenario {
                    name: name.into(),
                    elems: 1_000_000,
                    melems_per_sec: melems,
                    p50_secs: 1.0 / melems * 1e-6 * 1_000_000.0,
                    p99_secs: 1.2 / melems * 1e-6 * 1_000_000.0,
                    samples: 15,
                    iters: 1,
                })
                .collect(),
        }
    }

    #[test]
    fn json_round_trips() {
        let r = report(&[("merge_uniform", 450.5), ("sort_uniform", 95.25)]);
        let parsed = BenchReport::parse(&r.to_json()).unwrap();
        assert_eq!(parsed.pr, "6");
        assert_eq!(parsed.threads, 8);
        assert!(!parsed.quick);
        assert_eq!(parsed.scenarios.len(), 2);
        assert_eq!(parsed.scenarios[0].name, "merge_uniform");
        assert!((parsed.scenarios[0].melems_per_sec - 450.5).abs() < 1e-3);
        assert!((parsed.scenarios[1].p99_secs - r.scenarios[1].p99_secs).abs() < 1e-9);
    }

    #[test]
    fn add_derives_throughput_from_median() {
        let mut r = BenchReport::new("7", 4);
        let br = crate::harness::Bench::new("case").samples(3).warmup(0).run(|| ());
        r.add(1_000, &br);
        assert_eq!(r.scenarios[0].name, "case");
        assert_eq!(r.scenarios[0].elems, 1_000);
        assert!(r.scenarios[0].p99_secs >= r.scenarios[0].p50_secs);
    }

    #[test]
    fn diff_flags_collapse_not_noise() {
        let base = report(&[("merge", 400.0), ("sort", 100.0)]);
        // 10% down: within tolerance. 80% down: regression.
        let new = report(&[("merge", 360.0), ("sort", 20.0)]);
        let d = base.diff(&new, 0.5);
        assert_eq!(d.regressions.len(), 1);
        assert!(d.regressions[0].contains("sort"), "{:?}", d.regressions);
        assert!(d.lines.iter().any(|l| l.starts_with("✓ merge")));
        assert!(d.lines.iter().any(|l| l.starts_with("✗ sort")));
    }

    #[test]
    fn diff_tolerates_suite_growth() {
        let base = report(&[("merge", 400.0), ("gone", 50.0)]);
        let new = report(&[("merge", 400.0), ("added", 10.0)]);
        let d = base.diff(&new, 0.5);
        assert!(d.regressions.is_empty());
        assert!(d.lines.iter().any(|l| l.contains("gone") && l.contains("missing")));
        assert!(d.lines.iter().any(|l| l.contains("added") && l.contains("new scenario")));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(BenchReport::parse("{}").is_err());
        assert!(BenchReport::parse("not json").is_err());
        assert!(BenchReport::parse(r#"{"pr": "6", "threads": 8, "quick": false, "scenarios": [{}]}"#).is_err());
    }
}

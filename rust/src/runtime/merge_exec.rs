//! Fixed-shape marshalling for the merge artifacts: pad → execute →
//! slice. This is the L3↔L2 contract (tested against the pure-rust
//! merge in `tests/runtime_xla.rs`).
//!
//! Padding convention (mirrored by `python/tests/test_rank_merge.py::
//! test_merge_with_inf_padding`): keys are padded with `+inf`, which
//! the stable kernel routes to the output tail (A-pads before B-pads,
//! both after every real key since workload keys are finite); the tail
//! is sliced off after execution.

use super::client::{Executable, Tensor, XlaRuntime};
use anyhow::{anyhow, Result};

/// A keyed block in the runtime's interchange layout.
#[derive(Clone, Debug, PartialEq)]
pub struct KeyedBlock {
    pub keys: Vec<f32>,
    pub vals: Vec<i32>,
}

impl KeyedBlock {
    pub fn new(keys: Vec<f32>, vals: Vec<i32>) -> KeyedBlock {
        assert_eq!(keys.len(), vals.len());
        KeyedBlock { keys, vals }
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// True when the keys are non-decreasing under `f32::total_cmp` —
    /// the service's sort invariant. This is the NaN-safe check: a
    /// plain `w[0] <= w[1]` sweep is vacuously *false* next to any NaN
    /// key, so it would reject outputs that are correctly ordered
    /// under the total order the engines actually sort by
    /// (`F32Key`/`total_cmp`, which places NaN above `+inf`).
    pub fn is_key_sorted(&self) -> bool {
        self.keys
            .windows(2)
            .all(|w| w[0].total_cmp(&w[1]) != std::cmp::Ordering::Greater)
    }

    fn padded(&self, to: usize) -> (Vec<f32>, Vec<i32>) {
        let mut k = Vec::with_capacity(to);
        k.extend_from_slice(&self.keys);
        k.resize(to, f32::INFINITY);
        let mut v = Vec::with_capacity(to);
        v.extend_from_slice(&self.vals);
        v.resize(to, -1);
        (k, v)
    }
}

/// Stable-merge executor over the AOT merge artifacts.
pub struct XlaMerger<'rt> {
    /// (block_capacity, executable), descending capacity.
    merges: Vec<(usize, &'rt Executable)>,
    /// Execution counter (metrics).
    pub calls: std::cell::Cell<usize>,
}

impl<'rt> XlaMerger<'rt> {
    pub fn new(rt: &'rt XlaRuntime) -> Result<XlaMerger<'rt>> {
        let mut merges = Vec::new();
        for name in rt.names() {
            if let Some(size) = name.strip_prefix("merge_b").and_then(|s| s.parse::<usize>().ok())
            {
                merges.push((size, rt.get(name).unwrap()));
            }
        }
        if merges.is_empty() {
            return Err(anyhow!("no merge_b* artifacts loaded (run `make artifacts`)"));
        }
        merges.sort_by_key(|(s, _)| std::cmp::Reverse(*s));
        Ok(XlaMerger { merges, calls: std::cell::Cell::new(0) })
    }

    /// Largest block capacity available.
    pub fn max_block(&self) -> usize {
        self.merges[0].0
    }

    /// Pick the smallest artifact that fits both blocks.
    fn pick(&self, need: usize) -> Result<&Executable> {
        self.merges
            .iter()
            .rev()
            .find(|(cap, _)| *cap >= need)
            .map(|(_, e)| *e)
            .ok_or_else(|| {
                anyhow!("block of {need} exceeds largest merge artifact {}", self.max_block())
            })
    }

    /// Stable merge of two sorted keyed blocks on the XLA executable.
    ///
    /// Requires finite keys (the padding sentinel is `+inf`) and block
    /// lengths within the largest artifact capacity.
    pub fn merge(&self, a: &KeyedBlock, b: &KeyedBlock) -> Result<KeyedBlock> {
        let need = a.len().max(b.len());
        let exe = self.pick(need)?;
        let cap = exe.spec.inputs[0].numel();
        let (ak, av) = a.padded(cap);
        let (bk, bv) = b.padded(cap);
        let out = exe.run(&[
            Tensor::F32(ak),
            Tensor::I32(av),
            Tensor::F32(bk),
            Tensor::I32(bv),
        ])?;
        self.calls.set(self.calls.get() + 1);
        let keys = out[0].as_f32().ok_or_else(|| anyhow!("bad output dtype"))?;
        let vals = out[1].as_i32().ok_or_else(|| anyhow!("bad output dtype"))?;
        let real = a.len() + b.len();
        Ok(KeyedBlock { keys: keys[..real].to_vec(), vals: vals[..real].to_vec() })
    }
}

/// Stable-sort executor over the `sort_n*` artifacts (leaf sorting).
pub struct XlaSorter<'rt> {
    sorts: Vec<(usize, &'rt Executable)>,
    pub calls: std::cell::Cell<usize>,
}

impl<'rt> XlaSorter<'rt> {
    pub fn new(rt: &'rt XlaRuntime) -> Result<XlaSorter<'rt>> {
        let mut sorts = Vec::new();
        for name in rt.names() {
            if let Some(size) = name.strip_prefix("sort_n").and_then(|s| s.parse::<usize>().ok()) {
                sorts.push((size, rt.get(name).unwrap()));
            }
        }
        if sorts.is_empty() {
            return Err(anyhow!("no sort_n* artifacts loaded"));
        }
        sorts.sort_by_key(|(s, _)| std::cmp::Reverse(*s));
        Ok(XlaSorter { sorts, calls: std::cell::Cell::new(0) })
    }

    pub fn max_block(&self) -> usize {
        self.sorts[0].0
    }

    /// Stable sort of one keyed block (padded to artifact size).
    pub fn sort(&self, block: &KeyedBlock) -> Result<KeyedBlock> {
        let exe = self
            .sorts
            .iter()
            .rev()
            .find(|(cap, _)| *cap >= block.len())
            .map(|(_, e)| *e)
            .ok_or_else(|| anyhow!("block exceeds sort artifact capacity"))?;
        let cap = exe.spec.inputs[0].numel();
        let (k, v) = block.padded(cap);
        let out = exe.run(&[Tensor::F32(k), Tensor::I32(v)])?;
        self.calls.set(self.calls.get() + 1);
        let keys = out[0].as_f32().ok_or_else(|| anyhow!("bad output dtype"))?;
        let vals = out[1].as_i32().ok_or_else(|| anyhow!("bad output dtype"))?;
        Ok(KeyedBlock {
            keys: keys[..block.len()].to_vec(),
            vals: vals[..block.len()].to_vec(),
        })
    }
}

/// Dynamic batcher over the `merge_batchB_bN` artifacts: packs up to B
/// outstanding small merge jobs into ONE executable call (vLLM-style
/// request batching, applied to merge jobs). Jobs whose blocks exceed
/// N fall back to the caller's per-job path.
pub struct XlaBatchMerger<'rt> {
    exe: &'rt Executable,
    /// Batch width B.
    pub batch: usize,
    /// Per-side block capacity N.
    pub block: usize,
    pub calls: std::cell::Cell<usize>,
}

impl<'rt> XlaBatchMerger<'rt> {
    pub fn new(rt: &'rt XlaRuntime) -> Result<XlaBatchMerger<'rt>> {
        let name = rt
            .names()
            .into_iter()
            .find(|n| n.starts_with("merge_batch"))
            .ok_or_else(|| anyhow!("no merge_batch* artifact loaded (run `make artifacts`)"))?;
        let exe = rt.get(name).unwrap();
        let shape = &exe.spec.inputs[0].shape;
        if shape.len() != 2 {
            return Err(anyhow!("batched merge artifact must be rank-2, got {shape:?}"));
        }
        Ok(XlaBatchMerger {
            exe,
            batch: shape[0],
            block: shape[1],
            calls: std::cell::Cell::new(0),
        })
    }

    /// Stable-merge every (a, b) job. Jobs are packed `batch` at a time
    /// into single executable calls; a short final group is padded with
    /// empty jobs. Every block must be `<= self.block` long.
    pub fn merge_many(&self, jobs: &[(KeyedBlock, KeyedBlock)]) -> Result<Vec<KeyedBlock>> {
        for (i, (a, b)) in jobs.iter().enumerate() {
            if a.len() > self.block || b.len() > self.block {
                return Err(anyhow!(
                    "job {i} exceeds batch block capacity {} ({} / {})",
                    self.block,
                    a.len(),
                    b.len()
                ));
            }
        }
        let mut out = Vec::with_capacity(jobs.len());
        for group in jobs.chunks(self.batch) {
            let cap = self.block;
            let bsz = self.batch;
            let mut ak = Vec::with_capacity(bsz * cap);
            let mut av = Vec::with_capacity(bsz * cap);
            let mut bk = Vec::with_capacity(bsz * cap);
            let mut bv = Vec::with_capacity(bsz * cap);
            for slot in 0..bsz {
                if let Some((a, b)) = group.get(slot) {
                    let (k, v) = a.padded(cap);
                    ak.extend(k);
                    av.extend(v);
                    let (k, v) = b.padded(cap);
                    bk.extend(k);
                    bv.extend(v);
                } else {
                    // Padding job: all +inf keys.
                    ak.extend(std::iter::repeat(f32::INFINITY).take(cap));
                    av.extend(std::iter::repeat(-1).take(cap));
                    bk.extend(std::iter::repeat(f32::INFINITY).take(cap));
                    bv.extend(std::iter::repeat(-1).take(cap));
                }
            }
            let res = self.exe.run(&[
                Tensor::F32(ak),
                Tensor::I32(av),
                Tensor::F32(bk),
                Tensor::I32(bv),
            ])?;
            self.calls.set(self.calls.get() + 1);
            let keys = res[0].as_f32().ok_or_else(|| anyhow!("bad output dtype"))?;
            let vals = res[1].as_i32().ok_or_else(|| anyhow!("bad output dtype"))?;
            let row = 2 * cap;
            for (slot, (a, b)) in group.iter().enumerate() {
                let real = a.len() + b.len();
                out.push(KeyedBlock {
                    keys: keys[slot * row..slot * row + real].to_vec(),
                    vals: vals[slot * row..slot * row + real].to_vec(),
                });
            }
        }
        Ok(out)
    }
}

/// Crossrank executor (paper Steps 1–2 on the accelerator).
pub struct XlaCrossrank<'rt> {
    exe: &'rt Executable,
}

impl<'rt> XlaCrossrank<'rt> {
    pub fn new(rt: &'rt XlaRuntime) -> Result<XlaCrossrank<'rt>> {
        let name = rt
            .names()
            .into_iter()
            .find(|n| n.starts_with("crossrank_"))
            .ok_or_else(|| anyhow!("no crossrank artifact loaded"))?;
        Ok(XlaCrossrank { exe: rt.get(name).unwrap() })
    }

    pub fn array_len(&self) -> usize {
        self.exe.spec.inputs[0].numel()
    }

    pub fn pivot_count(&self) -> usize {
        self.exe.spec.inputs[1].numel()
    }

    /// (rank_low, rank_high) of `pivots` in sorted `arr`; lengths must
    /// match the artifact shape exactly (callers pad with +inf).
    pub fn crossrank(&self, arr: &[f32], pivots: &[f32]) -> Result<(Vec<i32>, Vec<i32>)> {
        let out = self
            .exe
            .run(&[Tensor::F32(arr.to_vec()), Tensor::F32(pivots.to_vec())])?;
        Ok((
            out[0].as_i32().ok_or_else(|| anyhow!("bad dtype"))?.to_vec(),
            out[1].as_i32().ok_or_else(|| anyhow!("bad dtype"))?.to_vec(),
        ))
    }
}

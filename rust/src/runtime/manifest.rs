//! `artifacts/manifest.json` reader — the contract between `aot.py`
//! and the rust runtime (shapes/dtypes per artifact).

use crate::util::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType, String> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(format!("unsupported dtype {other}")),
        }
    }
}

/// One tensor signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub description: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_spec(v: &Json) -> Result<TensorSpec, String> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or("missing shape")?
        .iter()
        .map(|d| d.as_usize().ok_or("bad dim".to_string()))
        .collect::<Result<Vec<_>, _>>()?;
    let dtype = DType::parse(v.get("dtype").and_then(Json::as_str).ok_or("missing dtype")?)?;
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let json = Json::parse(text).map_err(|e| e.to_string())?;
        let obj = json.as_obj().ok_or("manifest must be an object")?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in obj {
            let file = entry.get("file").and_then(Json::as_str).ok_or("missing file")?;
            let spec = ArtifactSpec {
                name: name.clone(),
                file: dir.join(file),
                description: entry
                    .get("description")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                inputs: entry
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .ok_or("missing inputs")?
                    .iter()
                    .map(tensor_spec)
                    .collect::<Result<Vec<_>, _>>()?,
                outputs: entry
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .ok_or("missing outputs")?
                    .iter()
                    .map(tensor_spec)
                    .collect::<Result<Vec<_>, _>>()?,
            };
            artifacts.insert(name.clone(), spec);
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    /// Merge artifacts, sorted by block size descending (offload picks
    /// the largest block that fits).
    pub fn merge_artifacts(&self) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> = self
            .artifacts
            .values()
            .filter(|a| a.name.starts_with("merge_b"))
            .collect();
        v.sort_by_key(|a| std::cmp::Reverse(a.inputs[0].numel()));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "merge_b1024": {
        "file": "merge_b1024.hlo.txt",
        "description": "merge",
        "inputs": [
          {"shape": [1024], "dtype": "float32"},
          {"shape": [1024], "dtype": "int32"},
          {"shape": [1024], "dtype": "float32"},
          {"shape": [1024], "dtype": "int32"}
        ],
        "outputs": [
          {"shape": [2048], "dtype": "float32"},
          {"shape": [2048], "dtype": "int32"}
        ],
        "hlo_bytes": 123
      },
      "merge_b4096": {
        "file": "merge_b4096.hlo.txt",
        "description": "merge",
        "inputs": [{"shape": [4096], "dtype": "float32"}],
        "outputs": [{"shape": [8192], "dtype": "float32"}]
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        let a = m.get("merge_b1024").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[0], TensorSpec { shape: vec![1024], dtype: DType::F32 });
        assert_eq!(a.outputs[0].numel(), 2048);
        assert!(a.file.ends_with("merge_b1024.hlo.txt"));
    }

    #[test]
    fn merge_artifacts_sorted_desc() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        let names: Vec<&str> = m.merge_artifacts().iter().map(|a| a.name.as_str()).collect();
        assert_eq!(names, vec!["merge_b4096", "merge_b1024"]);
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = r#"{"x": {"file": "f", "inputs": [{"shape": [1], "dtype": "float64"}], "outputs": []}}"#;
        assert!(Manifest::parse(bad, Path::new("/x")).is_err());
    }
}

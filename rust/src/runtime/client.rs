//! PJRT client wrapper: load `artifacts/*.hlo.txt`, compile once, run
//! many times. Adapts /opt/xla-example/load_hlo (HLO *text* is the
//! interchange format — see aot.py for why).
//!
//! The actual PJRT backend lives behind the `xla` cargo feature: the
//! offline build environment carries no `xla` crate, so the default
//! build compiles a stub that parses manifests and reports shapes but
//! returns an error from [`XlaRuntime::load_dir`] / [`Executable::run`].
//! Enabling `--features xla` compiles this full path against the
//! vendored API stub (`rust/vendor/xla-stub`) — CI keeps it
//! type-checked — and still fails fast at `PjRtClient::cpu()`;
//! pointing the `xla` path dependency at the real crate restores the
//! execution path unchanged.

use super::manifest::{ArtifactSpec, DType, Manifest};
use anyhow::{anyhow, Result};
#[cfg(feature = "xla")]
use anyhow::Context;
use std::collections::HashMap;
use std::path::Path;

/// Input/output value for an executable call.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32(v) => Some(v),
            _ => None,
        }
    }

    #[cfg(feature = "xla")]
    fn to_literal(&self, spec: &super::manifest::TensorSpec) -> Result<xla::Literal> {
        let lit = match self {
            Tensor::F32(v) => xla::Literal::vec1(v),
            Tensor::I32(v) => xla::Literal::vec1(v),
        };
        // Multi-dimensional artifact inputs (e.g. the batched merge's
        // f32[8,1024]) are marshalled flat and reshaped here.
        if spec.shape.len() > 1 {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        } else {
            Ok(lit)
        }
    }

    fn matches(&self, spec: &super::manifest::TensorSpec) -> bool {
        self.len() == spec.numel()
            && matches!(
                (self, &spec.dtype),
                (Tensor::F32(_), DType::F32) | (Tensor::I32(_), DType::I32)
            )
    }
}

/// One compiled artifact.
pub struct Executable {
    pub spec: ArtifactSpec,
    #[cfg(feature = "xla")]
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with shape/dtype checking against the manifest spec.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if !t.matches(s) {
                return Err(anyhow!(
                    "{}: input {i} mismatch (len {} vs spec {:?})",
                    self.spec.name,
                    t.len(),
                    s
                ));
            }
        }
        self.execute(inputs)
    }

    #[cfg(feature = "xla")]
    fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&self.spec.inputs)
            .map(|(t, s)| t.to_literal(s))
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let elems = result.to_tuple()?;
        let mut out = Vec::with_capacity(elems.len());
        for (lit, spec) in elems.into_iter().zip(&self.spec.outputs) {
            out.push(match spec.dtype {
                DType::F32 => Tensor::F32(lit.to_vec::<f32>()?),
                DType::I32 => Tensor::I32(lit.to_vec::<i32>()?),
            });
        }
        Ok(out)
    }

    #[cfg(not(feature = "xla"))]
    fn execute(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        Err(anyhow!(
            "{}: this binary was built without the `xla` feature; no PJRT backend",
            self.spec.name
        ))
    }
}

/// The runtime: one PJRT CPU client + all compiled artifacts.
pub struct XlaRuntime {
    pub platform: String,
    executables: HashMap<String, Executable>,
}

impl XlaRuntime {
    /// Load every artifact in `dir` (per its manifest) and compile.
    #[cfg(feature = "xla")]
    pub fn load_dir(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let platform = client.platform_name();
        let mut executables = HashMap::new();
        for (name, spec) in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                spec.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            executables.insert(name.clone(), Executable { spec: spec.clone(), exe });
        }
        Ok(XlaRuntime { platform, executables })
    }

    /// Stub loader for builds without the PJRT backend: validates the
    /// manifest (so contract errors still surface) then reports that
    /// execution is unavailable.
    #[cfg(not(feature = "xla"))]
    pub fn load_dir(dir: &Path) -> Result<XlaRuntime> {
        let _ = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        Err(anyhow!(
            "artifacts present at {} but this binary was built without the `xla` feature",
            dir.display()
        ))
    }

    pub fn get(&self, name: &str) -> Option<&Executable> {
        self.executables.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Default artifacts directory: `$REPO/artifacts` or `$ARTIFACTS_DIR`.
    pub fn default_dir() -> std::path::PathBuf {
        if let Ok(d) = std::env::var("ARTIFACTS_DIR") {
            return d.into();
        }
        // Walk up from the executable/cwd looking for artifacts/.
        let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !cur.pop() {
                return "artifacts".into();
            }
        }
    }
}

//! PJRT runtime bridge (S13): load the AOT artifacts produced by
//! `python/compile/aot.py` and execute them from the rust hot path.
//! Python never runs at request time — the HLO text is compiled once
//! at startup by the in-process PJRT CPU client.

pub mod client;
pub mod manifest;
pub mod merge_exec;

pub use client::{Executable, Tensor, XlaRuntime};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
pub use merge_exec::{KeyedBlock, XlaBatchMerger, XlaCrossrank, XlaMerger, XlaSorter};

//! `repro` — the launcher for the traff-merge system.
//!
//! Subcommands:
//! - `demo`      — the paper's Figure 1 worked example, end to end.
//! - `merge`     — generate a workload, run the parallel merge, verify.
//! - `sort`      — parallel merge sort over a workload, verify + stats.
//! - `pram`      — the merge on the audited EREW PRAM simulator.
//! - `bsp`       — superstep comparison: simplified vs baseline.
//! - `serve`     — coordinator service demo over the worker pool.
//! - `stream`    — streaming run-merge workload: ingest + background
//!   compaction + scans over the out-of-core run store.
//! - `metrics`   — run a mixed service workload and emit the process
//!   metrics registry (histograms + counters) as one JSON snapshot.
//! - `trace`     — run a traced workload and export the span rings as
//!   chrome://tracing JSON.
//! - `artifacts` — list loaded XLA artifacts (requires `make artifacts`).

#![deny(unsafe_op_in_unsafe_fn)]

use traff_merge::cli::Args;
use traff_merge::coordinator::{Config, Engine, MergeService};
use traff_merge::core::{
    merge_with_strategy, parallel_merge, parallel_merge_instrumented, parallel_merge_sort,
    parallel_merge_sort_with, MergeStrategy, Partition, Record,
};
use traff_merge::harness::{Bench, BenchReport};
use traff_merge::exec::JobClass;
use traff_merge::metrics::{fmt_duration, melems_per_sec, time, Table};
use traff_merge::obs::{self, HistSnapshot, Registry};
use traff_merge::pram::{pram_merge, Variant};
use traff_merge::runtime::{KeyedBlock, XlaRuntime};
use traff_merge::stream::{PolicyKind, StreamConfig};
use traff_merge::workload::{self, Dist};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.cmd.as_str() {
        "demo" => cmd_demo(),
        "merge" => cmd_merge(&args),
        "sort" => cmd_sort(&args),
        "pram" => cmd_pram(&args),
        "bsp" => cmd_bsp(&args),
        "serve" => cmd_serve(&args),
        "stream" => cmd_stream(&args),
        "metrics" => cmd_metrics(&args),
        "trace" => cmd_trace(&args),
        "bench-json" => cmd_bench_json(&args),
        "bench-diff" => cmd_bench_diff(&args),
        "artifacts" => cmd_artifacts(),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "repro — simplified, stable parallel merging (Träff 2012)\n\n\
         usage: repro <cmd> [--flags]\n\n\
         commands:\n\
         \x20 demo                         Figure 1 worked example\n\
         \x20 merge  --n N --m M --p P --dist D --seed S [--verify] [--strategy S]\n\
         \x20 sort   --n N --p P --dist D --seed S [--verify] [--strategy S]\n\
         \x20 pram   --n N --m M --p P [--crew]\n\
         \x20 bsp    --n N --p P [--g G] [--l L]\n\
         \x20 serve  --jobs J --n N [--background B] [--engine rust|hybrid]\n\
         \x20        [--strategy S] [--metrics-json F]\n\
         \x20 stream --n N --runs R [--writers W] [--block B] [--scans S] [--dist D]\n\
         \x20        [--spill] [--dir PATH] [--recover] [--page K]\n\
         \x20        [--policy adjacent|tiered|overlap] [--strategy S]\n\
         \x20        [--metrics-json F]\n\
         \x20 metrics [--jobs J] [--background B] [--n N] [--out F]\n\
         \x20        run a mixed workload, print the metrics registry JSON\n\
         \x20 trace  [--n N] [--p P] [--out F]   traced workload -> chrome JSON\n\
         \x20 bench-json [--out F] [--pr TAG] [--n N] [--p P]  emit BENCH_<pr>.json\n\
         \x20 bench-diff --old F --new F [--tolerance-pct T]   compare two reports\n\
         \x20 artifacts                    list loaded XLA artifacts\n\n\
         distributions: uniform dupK zipf allequal organpipe presorted\n\
         \x20                reversed runsR advskew\n\
         strategies:    fixed (upfront co-rank partition, default)\n\
         \x20                adaptive (sequential-until-stolen; the poll quantum\n\
         \x20                comes from the tunables — pin it with the\n\
         \x20                EXEC_ADAPTIVE_QUANTUM env var, elements per quantum)"
    );
}

/// `--strategy fixed|adaptive` (shared by merge/sort/serve/stream).
fn strategy_arg(args: &Args) -> Result<MergeStrategy, String> {
    Ok(MergeStrategy::parse(args.get_choice("strategy", &["fixed", "adaptive"], "fixed")?)
        .expect("choice already validated"))
}

fn cmd_demo() -> Result<(), String> {
    println!("Figure 1 (Träff 2012): n=18, m=15, p=5\n");
    let a: Vec<i64> = vec![0, 0, 1, 1, 1, 2, 2, 2, 4, 5, 5, 5, 5, 5, 6, 6, 7, 7];
    let b: Vec<i64> = vec![1, 1, 3, 3, 3, 3, 4, 5, 6, 6, 6, 6, 7, 7, 7];
    let part = Partition::compute(&a, &b, 5);
    println!("A = {a:?}");
    println!("B = {b:?}\n");
    println!("x  = {:?}", part.x);
    println!("x̄  = {:?}   (rank_low of A[x_i] in B)", part.xbar);
    println!("y  = {:?}", part.y);
    println!("ȳ  = {:?}   (rank_high of B[y_j] in A)\n", part.ybar);
    let tasks = part.tasks();
    let mut t = Table::new(vec!["side", "case", "A-range", "B-range", "C-offset"]);
    let mut ordered: Vec<_> = tasks.iter().collect();
    ordered.sort_by_key(|x| x.c_off);
    for task in ordered {
        t.row(vec![
            format!("{:?}", task.side),
            format!("{:?}", task.case),
            format!("{:?}", task.a),
            format!("{:?}", task.b),
            format!("{}", task.c_off),
        ]);
    }
    t.print();
    let mut c = vec![0i64; a.len() + b.len()];
    traff_merge::core::merge::run_tasks_seq(&a, &b, &mut c, &tasks)
        .map_err(|e| e.to_string())?;
    println!("\nC = {c:?}");
    let mut expect = [a, b].concat();
    expect.sort();
    assert_eq!(c, expect);
    println!("\n✓ ten disjoint subproblems, exactly as the Figure 1 caption lists.");
    Ok(())
}

fn cmd_merge(args: &Args) -> Result<(), String> {
    args.expect_known(&["n", "m", "p", "dist", "seed", "verify", "strategy"])?;
    let n = args.get_usize("n", 1_000_000)?;
    let m = args.get_usize("m", n)?;
    let p = args.get_usize("p", traff_merge::util::num_cpus())?;
    let seed = args.get_u64("seed", 42)?;
    let strategy = strategy_arg(args)?;
    let dist = Dist::parse(args.get("dist").unwrap_or("uniform"))
        .ok_or_else(|| format!("unknown distribution {:?}", args.get("dist")))?;
    let a = workload::sorted_keys(dist, n, seed);
    let b = workload::sorted_keys(dist, m, seed.wrapping_add(1));
    let mut c = vec![0i64; n + m];
    if strategy == MergeStrategy::Adaptive {
        // The adaptive kernel has no upfront partition to instrument:
        // splits happen on demand, so there is no task census to print.
        let (secs, ()) = time(|| merge_with_strategy(&a, &b, &mut c, p, strategy));
        println!(
            "merged {n} + {m} ({}) with p={p} strategy={strategy} in {} — {:.1} Melem/s",
            dist.name(),
            fmt_duration(secs),
            melems_per_sec((n + m) as u64, secs)
        );
        if args.get_flag("verify") {
            let (vsecs, ok) = time(|| c.windows(2).all(|w| w[0] <= w[1]));
            assert!(ok, "output not sorted!");
            println!("verified sorted in {}", fmt_duration(vsecs));
        }
        return Ok(());
    }
    let (secs, (part, tasks)) = time(|| parallel_merge_instrumented(&a, &b, &mut c, p));
    println!(
        "merged {n} + {m} ({}) with p={p} in {} — {:.1} Melem/s",
        dist.name(),
        fmt_duration(secs),
        melems_per_sec((n + m) as u64, secs)
    );
    let census = case_census(&tasks);
    println!("tasks: {} | case census: {census}", tasks.len());
    let biggest = tasks.iter().map(|t| t.len()).max().unwrap_or(0);
    println!(
        "largest task: {biggest} elements (bound 2*ceil(n/p) = {})",
        2 * part.pa.big.max(part.pb.big)
    );
    if args.get_flag("verify") {
        let (vsecs, ok) = time(|| c.windows(2).all(|w| w[0] <= w[1]));
        assert!(ok, "output not sorted!");
        println!("verified sorted in {}", fmt_duration(vsecs));
    }
    Ok(())
}

fn cmd_sort(args: &Args) -> Result<(), String> {
    args.expect_known(&["n", "p", "dist", "seed", "verify", "strategy"])?;
    let n = args.get_usize("n", 1_000_000)?;
    let p = args.get_usize("p", traff_merge::util::num_cpus())?;
    let seed = args.get_u64("seed", 42)?;
    let strategy = strategy_arg(args)?;
    let dist = Dist::parse(args.get("dist").unwrap_or("uniform"))
        .ok_or_else(|| format!("unknown distribution {:?}", args.get("dist")))?;
    let mut v = workload::raw_keys(dist, n, seed);
    let mut baseline = v.clone();
    let (secs, ()) = time(|| parallel_merge_sort_with(&mut v, p, strategy));
    println!(
        "sorted {n} ({}) with p={p} strategy={strategy} in {} — {:.1} Melem/s",
        dist.name(),
        fmt_duration(secs),
        melems_per_sec(n as u64, secs)
    );
    let (ssecs, ()) = time(|| baseline.sort());
    println!("std stable sort: {} — speedup {:.2}x", fmt_duration(ssecs), ssecs / secs);
    if args.get_flag("verify") {
        assert_eq!(v, baseline, "sort mismatch");
        println!("verified against std sort");
    }
    Ok(())
}

fn cmd_pram(args: &Args) -> Result<(), String> {
    args.expect_known(&["n", "m", "p", "crew", "seed", "sort"])?;
    let n = args.get_usize("n", 4096)?;
    let m = args.get_usize("m", n)?;
    let p = args.get_usize("p", 8)?;
    let seed = args.get_u64("seed", 42)?;
    let variant = if args.get_flag("crew") { Variant::Crew } else { Variant::Erew };
    if args.get_flag("sort") {
        // §3 sort on the PRAM model.
        let v = workload::raw_keys(Dist::Uniform, n, seed);
        let (out, rep) = traff_merge::pram::pram_sort(&v, p, variant);
        let mut expect = v.clone();
        expect.sort();
        assert_eq!(out, expect, "PRAM sort incorrect");
        println!("PRAM {variant:?} SORT: n={n} p={p}");
        println!(
            "steps: {} (block sort {}, merge rounds {}) | rounds: {} | conflicts: {} {}",
            rep.report.steps,
            rep.phase_steps[0],
            rep.phase_steps[1],
            rep.rounds,
            rep.report.conflicts.len(),
            if rep.report.conflict_free() { "✓" } else { "✗" }
        );
        return Ok(());
    }
    let a = workload::sorted_keys(Dist::Uniform, n, seed);
    let b = workload::sorted_keys(Dist::Uniform, m, seed + 1);
    let (c, rep) = pram_merge(&a, &b, p, variant);
    let mut expect = [a, b].concat();
    expect.sort();
    assert_eq!(c, expect, "PRAM merge incorrect");
    println!("PRAM {variant:?} merge: n={n} m={m} p={p}");
    let mut t = Table::new(vec!["phase", "steps"]);
    for (name, steps) in
        ["broadcast", "x̄ searches", "ȳ searches", "rank fetch", "merges"].iter().zip(rep.phase_steps)
    {
        t.row(vec![name.to_string(), steps.to_string()]);
    }
    t.row(vec!["TOTAL".to_string(), rep.report.steps.to_string()]);
    t.print();
    println!(
        "tasks: {} | work: {} ops | conflicts: {} {}",
        rep.tasks,
        rep.report.work,
        rep.report.conflicts.len(),
        if rep.report.conflict_free() { "✓ (exclusive access holds)" } else { "✗" }
    );
    Ok(())
}

fn cmd_bsp(args: &Args) -> Result<(), String> {
    args.expect_known(&["n", "p", "g", "l", "seed"])?;
    let n = args.get_usize("n", 100_000)?;
    let p = args.get_usize("p", 8)?;
    let g = args.get_usize("g", 4)? as f64;
    let l = args.get_usize("l", 10_000)? as f64;
    let seed = args.get_u64("seed", 42)?;
    let a = workload::sorted_keys(Dist::Uniform, n, seed);
    let b = workload::sorted_keys(Dist::Uniform, n, seed + 1);
    let params = traff_merge::bsp::BspParams { p, g, l };
    let s = traff_merge::bsp::bsp_merge_simplified(&a, &b, params);
    let c = traff_merge::bsp::bsp_merge_baseline(&a, &b, params);
    let mut t = Table::new(vec!["algorithm", "supersteps", "h-words", "BSP cost"]);
    t.row(vec![
        "simplified (Träff)".to_string(),
        s.cost.supersteps.to_string(),
        s.cost.comm_words.to_string(),
        format!("{:.0}", s.cost.cost),
    ]);
    t.row(vec![
        "distinguished (classic)".to_string(),
        c.cost.supersteps.to_string(),
        c.cost.comm_words.to_string(),
        format!("{:.0}", c.cost.cost),
    ]);
    t.print();
    println!(
        "\nsaved rounds: {} (the §3 claim) — cost ratio {:.3}",
        c.cost.supersteps - s.cost.supersteps,
        s.cost.cost / c.cost.cost
    );
    Ok(())
}

/// Drain one batch receiver and validate every job's output. The O(n)
/// invariant sweeps run AFTER the drain so consumer-side validation
/// cost never holds up the arrival loop. Per-job latency is no longer
/// stamped here: the service records every job into its registry
/// histogram (`svc.<tenant>.job_latency`), which is what the latency
/// table prints — exact buckets over ALL jobs instead of a sampled
/// vector, and the same numbers `--metrics-json` exports.
fn drain_batch(
    rx: std::sync::mpsc::Receiver<(usize, Result<KeyedBlock, String>)>,
    expect: usize,
    label: &str,
) -> Result<(), String> {
    let mut completed: Vec<Result<KeyedBlock, String>> = Vec::with_capacity(expect);
    for (_idx, result) in rx.iter() {
        completed.push(result);
    }
    // A job that panicked on a worker drops its result sender without
    // sending; the drain above would just end early. Partial results
    // must be an error, not a rosy report over the survivors.
    if completed.len() != expect {
        return Err(format!("only {} of {expect} {label} jobs reported back", completed.len()));
    }
    for result in completed {
        let out = result?;
        // NaN-safe invariant check: keys ordered under f32::total_cmp.
        if !out.is_key_sorted() {
            return Err(format!("{label} job returned a block unsorted under total order"));
        }
    }
    Ok(())
}

/// The latency table line, fed from a registry histogram snapshot —
/// same printed format the sample-vector path used, but the numbers
/// are exact-bucket percentiles over every recorded job (and therefore
/// match the `--metrics-json` export by construction).
fn print_latency_hist(label: &str, snap: &HistSnapshot) {
    if snap.is_empty() {
        return;
    }
    println!(
        "{label} latency: p50 {} | p99 {} | max {}",
        fmt_duration(snap.p50() as f64 / 1e9),
        fmt_duration(snap.p99() as f64 / 1e9),
        fmt_duration(snap.max_nanos as f64 / 1e9),
    );
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    args.expect_known(&[
        "jobs", "n", "engine", "threads", "seed", "background", "strategy", "metrics-json",
    ])?;
    let jobs = args.get_usize("jobs", 16)?;
    let background = args.get_usize("background", 0)?;
    let n = args.get_usize("n", 100_000)?;
    let threads = args.get_usize("threads", traff_merge::util::num_cpus())?;
    let seed = args.get_u64("seed", 42)?;
    let strategy = strategy_arg(args)?;
    let engine = match args.get_choice("engine", &["rust", "hybrid"], "rust")? {
        "hybrid" => Engine::Hybrid,
        _ => Engine::Rust,
    };
    // Two tenants on the shared executor: a service-class tenant and
    // (with --background > 0) a background-class tenant, each behind
    // its own admission pool of `threads` permits. Mixed-class traffic
    // end to end: the background tenant's jobs enter the injector's
    // background lane and yield to the service tenant's.
    let svc = MergeService::new(Config {
        threads,
        engine,
        leaf_block: 1024,
        strategy,
        tenant: "service".to_string(),
        ..Config::default()
    })
    .map_err(|e| e.to_string())?;
    let bg_svc = if background > 0 {
        Some(
            MergeService::new(Config {
                threads,
                engine,
                leaf_block: 1024,
                class: JobClass::Background,
                strategy,
                tenant: "background".to_string(),
                ..Config::default()
            })
            .map_err(|e| e.to_string())?,
        )
    } else {
        None
    };
    println!(
        "service up: engine={engine:?} strategy={strategy} admission={threads} permits/tenant \
         ({jobs} service + {background} background jobs)"
    );
    let mut rng = traff_merge::util::Rng::new(seed);
    let mut make_blocks = |count: usize| -> Vec<KeyedBlock> {
        (0..count)
            .map(|_| KeyedBlock {
                keys: (0..n).map(|_| rng.range(0, 1 << 20) as f32).collect(),
                vals: (0..n as i32).collect(),
            })
            .collect()
    };
    let service_blocks = make_blocks(jobs);
    let bg_blocks = make_blocks(background);
    // Batched submission; per-job latency is recorded by the service
    // itself into `svc.<tenant>.job_latency` (execution latency —
    // queue wait shows up separately in `pool.admission_wait.*` and
    // the executor's injector-wait histograms). The background flood
    // is submitted FIRST: with the QoS lanes the service batch still
    // overtakes whatever of it is queued.
    let t0 = std::time::Instant::now();
    let bg_rx = bg_svc.as_ref().map(|s| s.submit_sort_batch(bg_blocks));
    let rx = svc.submit_sort_batch(service_blocks);
    // Drain both classes concurrently, validating arrivals per class.
    let (service_res, bg_res) = std::thread::scope(|s| {
        let bg_handle = bg_rx.map(|rx| {
            s.spawn(move || drain_batch(rx, background, "background"))
        });
        let service = drain_batch(rx, jobs, "service");
        let bg = bg_handle
            .map(|h| h.join().expect("background drain thread"))
            .unwrap_or_else(|| Ok(()));
        (service, bg)
    });
    service_res?;
    bg_res?;
    let secs = t0.elapsed().as_secs_f64();
    let (jobs_done, elems, xla_calls, busy) = svc.stats.snapshot();
    let (bg_done, bg_elems, bg_xla, bg_busy) =
        bg_svc.as_ref().map(|s| s.stats.snapshot()).unwrap_or_default();
    println!(
        "{} jobs ({jobs_done} service + {bg_done} background), {} records in {} — \
         {:.2} Melem/s, {} XLA calls, busy {:.2}s (both tenants)",
        jobs_done + bg_done,
        elems + bg_elems,
        fmt_duration(secs),
        melems_per_sec(elems + bg_elems, secs),
        xla_calls + bg_xla,
        busy + bg_busy,
    );
    print_latency_hist("service", &svc.latency_snapshot());
    if let Some(bg) = &bg_svc {
        print_latency_hist("background", &bg.latency_snapshot());
    }
    let tel = svc.pool.telemetry();
    println!(
        "executor: {} jobs executed, {} steals ({} misses), {} injector batches, {} parks",
        tel.executed(),
        tel.steals(),
        tel.steal_misses(),
        tel.injector_pops(),
        tel.parks()
    );
    println!(
        "lanes: {} service / {} background jobs drained, {} anti-starvation promotions",
        tel.service_jobs(),
        tel.background_jobs(),
        tel.bg_promotions()
    );
    // Windowed view + recalibration checkpoint: roll the epoch over
    // this batch's activity and let the tunables react to it, so the
    // rates below describe THIS run (not process lifetime) and any
    // phase shift the batch caused is recorded as an event.
    let (rates, applied) = svc.recalibration_checkpoint();
    println!(
        "windowed ({} epochs, {:.2}s horizon): {:.0} exec/s | {:.0} steals/s \
         (miss ratio {:.2}) | {:.0} injector batches/s | {:.0} parks/s",
        rates.epochs,
        rates.span_secs,
        rates.executed_per_sec,
        rates.steals_per_sec,
        rates.miss_ratio(),
        rates.injector_per_sec,
        rates.parks_per_sec,
    );
    println!(
        "windowed lanes: {:.0} service jobs/s | {:.0} background jobs/s \
         (service share {:.2}) | {:.2} promotions/s",
        rates.service_per_sec,
        rates.background_per_sec,
        rates.service_share(),
        rates.bg_promotions_per_sec,
    );
    if let Some((worker, rate)) = rates.most_loaded() {
        println!(
            "most-loaded worker: #{worker} at {rate:.0} jobs/s (load skew {:.2}x the mean)",
            rates.load_skew()
        );
    }
    if let Some(view) = traff_merge::exec::lane_view() {
        println!(
            "tunables lane view: service share {:.2} over the last recalibration window",
            view.service_share()
        );
    }
    let (events, last) = traff_merge::exec::recalibration_stats();
    match last {
        Some(event) => println!(
            "tunables: {events} recalibration events ({applied} this checkpoint) — last: {event}"
        ),
        None => println!("tunables: no recalibration events (window saw no phase shift)"),
    }
    // The machine-readable twin of the tables above: one registry
    // snapshot, written AFTER the executor quiesced and the tables
    // printed, so the JSON's per-class percentiles are the same
    // numbers the table shows.
    if let Some(path) = args.get("metrics-json") {
        std::fs::write(path, Registry::global().snapshot_json())
            .map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote metrics registry snapshot to {path}");
    }
    Ok(())
}

/// `repro stream` — the streaming run-merge workload on the
/// handle-based API: open a stream (`MergeService::open_stream`),
/// ingest an unbounded-style record stream (runs seal at
/// `--n / --runs` records and compact on the executor's background
/// lane), interleave stable scans, then flush and verify the final
/// scan is globally sorted and stable. With `--writers W > 1` the
/// ingest fans out over W threads, each holding its own owned
/// `IngestWriter` shard — the sharded multi-writer path; per-writer
/// ingest order is verified to survive exactly.
fn cmd_stream(args: &Args) -> Result<(), String> {
    args.expect_known(&[
        "n", "runs", "block", "scans", "dist", "seed", "threads", "spill", "dir", "recover",
        "policy", "page", "writers", "strategy", "metrics-json",
    ])?;
    let n = args.get_usize("n", 200_000)?.max(1);
    let runs = args.get_usize("runs", 8)?.max(1);
    let capacity = traff_merge::util::div_ceil(n, runs).max(1);
    let block = args.get_usize("block", (capacity / 4).max(1))?.max(1);
    let scans = args.get_usize("scans", 3)?;
    let threads = args.get_usize("threads", traff_merge::util::num_cpus())?;
    let writers = args.get_usize("writers", 1)?.max(1);
    let seed = args.get_u64("seed", 42)?;
    let dist = Dist::parse(args.get("dist").unwrap_or("uniform"))
        .ok_or_else(|| format!("unknown distribution {:?}", args.get("dist")))?;
    let policy = PolicyKind::parse(args.get_choice(
        "policy",
        &["adjacent", "tiered", "overlap"],
        "adjacent",
    )?)
    .expect("choice already validated");
    let page = args.get_usize("page", 1024)?.max(1);
    let strategy = strategy_arg(args)?;
    let recover = args.get_flag("recover");
    // --dir names a persistent spill directory (survives this process:
    // the durable/restartable mode); --spill uses a throwaway temp dir.
    let dir = args.get("dir").map(std::path::PathBuf::from);
    if recover && dir.is_none() {
        return Err("--recover requires --dir <spill dir> (the directory to replay)".into());
    }
    let temp_spill = (dir.is_none() && args.get_flag("spill"))
        .then(|| std::env::temp_dir().join(format!("repro-stream-{}", std::process::id())));
    let spill = dir.clone().or_else(|| temp_spill.clone());
    let svc = MergeService::new(Config {
        threads,
        engine: Engine::Rust,
        leaf_block: 1024,
        strategy,
        tenant: "stream".to_string(),
        ..Config::default()
    })
    .map_err(|e| e.to_string())?;
    let mut builder = StreamConfig::builder()
        .run_capacity(capacity)
        .fanout(4)
        .threads(threads)
        .page_records(page)
        .policy(policy)
        .strategy(strategy);
    if let Some(dir) = spill.clone() {
        builder = builder.spill(dir);
    }
    let cfg = builder.build().map_err(|e| e.to_string())?;
    // Records recovered from a previous process's spill dir carry vals
    // below this base; new ingests start above it, so the stability
    // check spans the restart.
    let mut val_base = 0i32;
    let handle = if recover {
        let handle = svc.open_stream_recovered(cfg).map_err(|e| e.to_string())?;
        let recovered = handle.scan().map_err(|e| e.to_string())?;
        if !recovered.is_key_sorted() {
            return Err("recovered scan is not globally sorted".into());
        }
        val_base = recovered.len() as i32;
        println!(
            "recovered {} records from {} — scan sorted ✓",
            recovered.len(),
            dir.as_ref().expect("--recover requires --dir").display()
        );
        handle
    } else {
        svc.open_stream(cfg).map_err(|e| e.to_string())?
    };
    println!(
        "stream up: {n} records ({}) over {writers} writer(s), run capacity {capacity} \
         (~{runs} runs, {:.1}x the per-run buffer), fanout 4, {} policy, {strategy} merges, {}",
        dist.name(),
        n as f64 / capacity as f64,
        policy.name(),
        match &spill {
            Some(dir) => format!("spilling to {} (pages of {page})", dir.display()),
            None => "in-memory runs".to_string(),
        }
    );
    // Keys: the workload distribution folded into exact-in-f32 range;
    // vals: the per-writer ingest index (writer w owns the val range
    // [w*stride, w*stride + its count) — the stability oracle the
    // final verification reads back).
    let raw = workload::raw_keys(dist, n, seed);
    let keys: Vec<f32> = raw.iter().map(|k| k.rem_euclid(1 << 20) as f32).collect();
    let t0 = std::time::Instant::now();
    // Ingest/scan latency is recorded by the stream tenant itself into
    // `stream.<tenant>.{ingest,scan}_latency` registry histograms —
    // printed below and exported by `--metrics-json`.
    let mut scans_done = 0usize;
    let stride = traff_merge::util::div_ceil(n, writers).max(1);
    if writers == 1 {
        // Single-writer path: block ingest on the handle's implicit
        // writer, scans interleaved with ingest.
        let scan_every = (n / (scans + 1)).max(1);
        let mut next_scan = scan_every;
        let mut ingested = 0usize;
        while ingested < n {
            let hi = (ingested + block).min(n);
            let kb = KeyedBlock {
                keys: keys[ingested..hi].to_vec(),
                vals: (val_base + ingested as i32..val_base + hi as i32).collect(),
            };
            handle.ingest(&kb).map_err(|e| e.to_string())?;
            ingested = hi;
            if ingested >= next_scan && ingested < n {
                let out = handle.scan().map_err(|e| e.to_string())?;
                scans_done += 1;
                if !out.is_key_sorted() {
                    return Err("interleaved scan returned unsorted data".into());
                }
                next_scan += scan_every;
            }
        }
        handle.flush().map_err(|e| e.to_string())?;
    } else {
        // Sharded multi-writer path: W threads, each with an owned
        // IngestWriter over its contiguous slice of the workload;
        // scans run concurrently from this thread.
        let errs = std::sync::Mutex::new(Vec::<String>::new());
        std::thread::scope(|s| {
            for w in 0..writers {
                let lo = (w * stride).min(n);
                let hi = ((w + 1) * stride).min(n);
                let keys = &keys[lo..hi];
                let mut wr = handle.writer();
                let errs = &errs;
                s.spawn(move || {
                    let run = || -> Result<(), String> {
                        for (i, k) in keys.iter().enumerate() {
                            wr.push(*k, val_base + (lo + i) as i32)
                                .map_err(|e| e.to_string())?;
                        }
                        wr.flush().map_err(|e| e.to_string())?;
                        Ok(())
                    };
                    if let Err(e) = run() {
                        errs.lock().unwrap().push(format!("writer {w}: {e}"));
                    }
                });
            }
            for _ in 0..scans {
                match handle.scan() {
                    Ok(out) => {
                        scans_done += 1;
                        if !out.is_key_sorted() {
                            errs.lock()
                                .unwrap()
                                .push("concurrent scan returned unsorted data".into());
                        }
                    }
                    Err(e) => errs.lock().unwrap().push(format!("concurrent scan: {e}")),
                }
            }
        });
        let errs = errs.into_inner().unwrap();
        if let Some(e) = errs.into_iter().next() {
            return Err(e);
        }
    }
    handle.quiesce();
    let fin = handle.scan().map_err(|e| e.to_string())?;
    scans_done += 1;
    let secs = t0.elapsed().as_secs_f64();
    // Verification: complete (recovered + new), globally sorted, and
    // stable per writer — each writer's equal-key records keep their
    // push order (with one writer that is the full ingest order;
    // cross-writer order is seal-generation order by design).
    let expect_len = n + val_base as usize;
    if fin.len() != expect_len {
        return Err(format!("final scan returned {} of {expect_len} records", fin.len()));
    }
    if !fin.is_key_sorted() {
        return Err("final scan is not globally sorted".into());
    }
    let mut last_val = vec![i64::MIN; writers];
    let mut last_key = vec![f32::NAN; writers];
    for i in 0..fin.len() {
        let v = fin.vals[i];
        if v < val_base {
            continue; // recovered records: verified sorted above
        }
        let w = ((v - val_base) as usize / stride).min(writers - 1);
        if last_key[w].to_bits() == fin.keys[i].to_bits() && last_val[w] >= v as i64 {
            return Err(format!(
                "stability violated at scan index {i}: writer {w}'s equal keys out of \
                 push order"
            ));
        }
        last_key[w] = fin.keys[i];
        last_val[w] = v as i64;
    }
    println!(
        "ingested {n} records + {scans_done} scans in {} — {:.2} Melem/s end to end; \
         final scan sorted and stable ✓",
        fmt_duration(secs),
        melems_per_sec(n as u64, secs),
    );
    let registry = Registry::global();
    if let Some(snap) = registry.hist_snapshot("stream.stream.ingest_latency") {
        print_latency_hist("ingest", &snap);
    }
    if let Some(snap) = registry.hist_snapshot("stream.stream.scan_latency") {
        print_latency_hist("scan", &snap);
    }
    {
        let stats = handle.stats();
        println!(
            "store: {} live runs ({} records, max level {}), {} sealed, \
             {} compactions ({} failed), {} spilled",
            stats.runs,
            stats.records,
            stats.max_level,
            stats.sealed_runs,
            stats.compactions,
            stats.compaction_failures,
            stats.spilled_runs,
        );
    }
    let tel = svc.pool.telemetry();
    println!(
        "lanes: {} service / {} background jobs drained, {} anti-starvation promotions",
        tel.service_jobs(),
        tel.background_jobs(),
        tel.bg_promotions()
    );
    let (rates, _) = svc.recalibration_checkpoint();
    println!(
        "windowed lanes: {:.0} service jobs/s | {:.0} background jobs/s \
         (service share {:.2}) | {:.2} promotions/s",
        rates.service_per_sec,
        rates.background_per_sec,
        rates.service_share(),
        rates.bg_promotions_per_sec,
    );
    if let Some(path) = args.get("metrics-json") {
        std::fs::write(path, Registry::global().snapshot_json())
            .map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote metrics registry snapshot to {path}");
    }
    // Throwaway --spill dirs are this process's to clean; --dir spill
    // dirs are durable state and stay for a later --recover.
    if let Some(dir) = temp_spill {
        drop(svc);
        let _ = std::fs::remove_dir_all(&dir);
    }
    Ok(())
}

/// `repro metrics` — run a small mixed service workload (a service
/// tenant racing a background tenant, same shape as `repro serve`)
/// and emit the process metrics registry as one JSON snapshot:
/// machine-readable latency histograms (per-tenant job latency, steal
/// latency, injector waits, admission waits) plus counters. Pure JSON
/// on stdout (progress goes to stderr) so the output pipes straight
/// into `jq`; `--out` writes to a file instead.
fn cmd_metrics(args: &Args) -> Result<(), String> {
    args.expect_known(&["jobs", "background", "n", "threads", "seed", "out"])?;
    let jobs = args.get_usize("jobs", 16)?.max(1);
    let background = args.get_usize("background", 8)?;
    let n = args.get_usize("n", 50_000)?.max(16);
    let threads = args.get_usize("threads", traff_merge::util::num_cpus())?;
    let seed = args.get_u64("seed", 42)?;
    let svc = MergeService::new(Config {
        threads,
        tenant: "service".to_string(),
        ..Config::default()
    })
    .map_err(|e| e.to_string())?;
    let bg_svc = MergeService::new(Config {
        threads,
        class: JobClass::Background,
        tenant: "background".to_string(),
        ..Config::default()
    })
    .map_err(|e| e.to_string())?;
    eprintln!(
        "metrics workload: {jobs} service + {background} background sort jobs of {n} records"
    );
    let mut rng = traff_merge::util::Rng::new(seed);
    let mut make_blocks = |count: usize| -> Vec<KeyedBlock> {
        (0..count)
            .map(|_| KeyedBlock {
                keys: (0..n).map(|_| rng.range(0, 1 << 20) as f32).collect(),
                vals: (0..n as i32).collect(),
            })
            .collect()
    };
    let bg_blocks = make_blocks(background);
    let service_blocks = make_blocks(jobs);
    let bg_rx = (background > 0).then(|| bg_svc.submit_sort_batch(bg_blocks));
    let rx = svc.submit_sort_batch(service_blocks);
    drain_batch(rx, jobs, "service")?;
    if let Some(rx) = bg_rx {
        drain_batch(rx, background, "background")?;
    }
    let json = Registry::global().snapshot_json();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &json).map_err(|e| format!("writing {path}: {e}"))?;
            eprintln!("wrote metrics registry snapshot to {path}");
        }
        None => println!("{json}"),
    }
    Ok(())
}

/// `repro trace` — run a traced workload (adaptive merges on the
/// executor plus a small streaming ingest) with span tracing enabled
/// and export every worker ring's events as chrome://tracing JSON
/// (load the file at chrome://tracing or https://ui.perfetto.dev).
fn cmd_trace(args: &Args) -> Result<(), String> {
    args.expect_known(&["n", "p", "seed", "out"])?;
    let n = args.get_usize("n", 200_000)?.max(16);
    let p = args.get_usize("p", traff_merge::util::num_cpus())?.max(1);
    let seed = args.get_u64("seed", 42)?;
    let out_path = args.get("out").unwrap_or("trace.json").to_string();
    obs::trace::set_enabled(true);
    // A service batch (Submit/Admit/Dequeue/Run spans), with adaptive
    // merges inside the jobs (StealRaise/AdaptiveSplit).
    let svc = MergeService::new(Config {
        threads: p,
        strategy: MergeStrategy::Adaptive,
        tenant: "trace".to_string(),
        ..Config::default()
    })
    .map_err(|e| e.to_string())?;
    let mut rng = traff_merge::util::Rng::new(seed);
    let blocks: Vec<KeyedBlock> = (0..8)
        .map(|_| KeyedBlock {
            keys: (0..n).map(|_| rng.range(0, 1 << 20) as f32).collect(),
            vals: (0..n as i32).collect(),
        })
        .collect();
    let expect = blocks.len();
    let rx = svc.submit_sort_batch(blocks);
    drain_batch(rx, expect, "traced")?;
    // A small streaming ingest for the stream spans (seal/compact/
    // publish); in-memory, so no manifest fsyncs — use `repro stream
    // --spill` with EXEC_TRACE=1 for those.
    let handle = svc
        .open_stream(
            StreamConfig::builder()
                .run_capacity((n / 8).max(1))
                .threads(p)
                .build()
                .map_err(|e| e.to_string())?,
        )
        .map_err(|e| e.to_string())?;
    let keys: Vec<f32> = (0..n).map(|_| rng.range(0, 1 << 20) as f32).collect();
    handle
        .ingest(&KeyedBlock { keys, vals: (0..n as i32).collect() })
        .map_err(|e| e.to_string())?;
    handle.flush().map_err(|e| e.to_string())?;
    handle.quiesce();
    let tracer = obs::trace::Tracer::global();
    let events = tracer.drain();
    let json = obs::trace::chrome_trace_json(&events);
    std::fs::write(&out_path, json).map_err(|e| format!("writing {out_path}: {e}"))?;
    println!(
        "wrote {} span events to {out_path} ({} recorded, {} dropped on ring contention) — \
         load at chrome://tracing",
        events.len(),
        tracer.recorded(),
        tracer.dropped(),
    );
    Ok(())
}

/// `repro bench-json` — run the fixed regression-tracking scenario
/// suite and write `BENCH_<pr>.json` (throughput in Melem/s plus
/// p50/p99 per-op latency per scenario). `BENCH_QUICK=1` trims
/// sampling the same way it does for `cargo bench`; `--n` scales the
/// problem so CI can run a fast, smaller-but-same-shape suite.
fn cmd_bench_json(args: &Args) -> Result<(), String> {
    args.expect_known(&["out", "pr", "n", "p"])?;
    let pr = args.get("pr").unwrap_or("10").to_string();
    let n = args.get_usize("n", 1_000_000)?.max(16);
    let p = args.get_usize("p", traff_merge::util::num_cpus())?.max(1);
    let default_out = format!("BENCH_{pr}.json");
    let out_path = args.get("out").unwrap_or(&default_out).to_string();
    let mut report = BenchReport::new(&pr, p);
    println!("bench-json: n={n} p={p} quick={}", traff_merge::harness::quick_mode());

    // Scenario 1/2: the paper's §2 merge, friendly and adversarial
    // key distributions (the dup-heavy case stresses the equal-key
    // block cases of the partition).
    for (name, dist) in [("merge_uniform", Dist::Uniform), ("merge_dupheavy", Dist::DupHeavy(16))] {
        let a = workload::sorted_keys(dist, n / 2, 42);
        let b = workload::sorted_keys(dist, n - n / 2, 43);
        let mut out = vec![0i64; n];
        let r = Bench::new(name).run(|| parallel_merge(&a, &b, &mut out, p));
        println!("  {}", r.summary());
        report.add(n as u64, &r);
    }

    // Scenarios (Bench E12): the adaptive sequential-until-stolen
    // kernel on the shapes where its behavior diverges from the fixed
    // partition — uniform (should match), nearly-disjoint key ranges
    // and dup-heavy keys (quantum-granular triviality fast paths).
    {
        let adaptive = |a: &[i64], b: &[i64], name: &str, report: &mut BenchReport| {
            let mut out = vec![0i64; a.len() + b.len()];
            let r = Bench::new(name)
                .run(|| merge_with_strategy(a, b, &mut out, p, MergeStrategy::Adaptive));
            println!("  {}", r.summary());
            report.add(out.len() as u64, &r);
        };
        let a = workload::sorted_keys(Dist::Uniform, n / 2, 42);
        let b = workload::sorted_keys(Dist::Uniform, n - n / 2, 43);
        adaptive(&a, &b, "merge_adaptive_uniform", &mut report);
        // Nearly-disjoint: consecutive key bands with a thin overlap
        // seam, so almost every quantum (and any stolen half) is a
        // whole-slice block copy.
        let band = n as i64;
        let a: Vec<i64> = (0..n as i64 / 2).collect();
        let b: Vec<i64> = (0..(n as i64 - n as i64 / 2)).map(|k| band / 2 - 16 + k).collect();
        adaptive(&a, &b, "merge_adaptive_disjoint", &mut report);
        let a = workload::sorted_keys(Dist::DupHeavy(16), n / 2, 42);
        let b = workload::sorted_keys(Dist::DupHeavy(16), n - n / 2, 43);
        adaptive(&a, &b, "merge_adaptive_dupheavy", &mut report);
    }

    // Scenario 3: the §3 merge sort (includes the per-op clone; the
    // clone is O(n) against the sort's O(n log n), and every op must
    // start from the same unsorted input).
    {
        let base = workload::raw_keys(Dist::Uniform, n, 42);
        let r = Bench::new("sort_uniform").run(|| {
            let mut v = base.clone();
            parallel_merge_sort(&mut v, p);
            v
        });
        println!("  {}", r.summary());
        report.add(n as u64, &r);
    }

    // Scenario 4: the streaming compactor's pairwise run merge on the
    // background lane — records (key + stability tag), dup-heavy keys.
    {
        let mk = |seed: u64, tag0: u64| -> Vec<Record> {
            let mut keys = workload::raw_keys(Dist::DupHeavy(16), n / 2, seed);
            keys.sort();
            keys.iter().enumerate().map(|(i, &k)| Record::new(k, tag0 + i as u64)).collect()
        };
        let a = mk(7, 0);
        let b = mk(8, (n / 2) as u64);
        let r = Bench::new("stream_compact").run(|| traff_merge::stream::merge_runs_parallel(&a, &b, p));
        println!("  {}", r.summary());
        report.add((a.len() + b.len()) as u64, &r);
    }

    // Scenario 5: k-way major compaction — the paged cursor driver
    // merging a whole backlog of runs in one pass (vs scenario 4's
    // single pair), dup-heavy keys, in-memory store.
    {
        let store = std::sync::Arc::new(
            traff_merge::stream::RunStore::new(
                StreamConfig::builder()
                    .run_capacity((n / 8).max(1))
                    .fanout(64)
                    .threads(p)
                    .build()
                    .map_err(|e| e.to_string())?,
            )
            .map_err(|e| e.to_string())?,
        );
        let mut ing = traff_merge::stream::Ingestor::new(std::sync::Arc::clone(&store));
        for &k in &workload::raw_keys(Dist::DupHeavy(16), n, 9) {
            ing.push_key(k).map_err(|e| e.to_string())?;
        }
        ing.flush().map_err(|e| e.to_string())?;
        let snap = store.snapshot();
        let r = Bench::new("stream_kway_compact")
            .run(|| traff_merge::stream::kway_merge_to_vec(&snap, p).expect("in-memory k-way merge"));
        println!("  {}", r.summary());
        report.add(n as u64, &r);
    }

    // Scenario 6/7 (Bench E11): multi-writer ingest scaling — the same
    // record stream pushed by 8 threads through one shared
    // `Mutex<Ingestor>` (every push serialized on one lock and one
    // buffer) vs one owned `ShardWriter` per thread sealing through
    // the shared generation clock. The throughput ratio is the
    // tentpole's scaling claim; both sides seal identical run shapes.
    {
        let writers = 8usize;
        let keys = workload::raw_keys(Dist::DupHeavy(16), n, 11);
        let chunk = traff_merge::util::div_ceil(n, writers).max(1);
        let cfg = || {
            StreamConfig::builder()
                .run_capacity((n / 16).max(1))
                .fanout(64)
                .threads(1)
                .build()
                .expect("static bench config")
        };
        let r = Bench::new("stream_ingest_mutex").run(|| {
            let store = std::sync::Arc::new(
                traff_merge::stream::RunStore::new(cfg()).expect("in-memory store"),
            );
            let ing = std::sync::Mutex::new(traff_merge::stream::Ingestor::new(
                std::sync::Arc::clone(&store),
            ));
            std::thread::scope(|s| {
                for ch in keys.chunks(chunk) {
                    let ing = &ing;
                    s.spawn(move || {
                        for &k in ch {
                            ing.lock().unwrap().push_key(k).expect("in-memory ingest");
                        }
                    });
                }
            });
            ing.into_inner().unwrap().flush().expect("in-memory flush");
            store.record_count()
        });
        println!("  {}", r.summary());
        report.add(n as u64, &r);
        let r = Bench::new("stream_ingest_sharded").run(|| {
            let store = std::sync::Arc::new(
                traff_merge::stream::RunStore::new(cfg()).expect("in-memory store"),
            );
            let set =
                traff_merge::stream::WriterSet::new(std::sync::Arc::clone(&store), writers);
            std::thread::scope(|s| {
                for ch in keys.chunks(chunk) {
                    let mut w = set.owned_writer();
                    s.spawn(move || {
                        for &k in ch {
                            w.push(k, 0).expect("in-memory ingest");
                        }
                        w.flush().expect("in-memory flush");
                    });
                }
            });
            store.record_count()
        });
        println!("  {}", r.summary());
        report.add(n as u64, &r);
    }

    // Scenario 8 (Bench E13): observability overhead. `obs_overhead`
    // is the merge_uniform shape with tracing explicitly DISABLED —
    // the hot path pays one predictable branch per instrumentation
    // point, so this row must stay within noise of `merge_uniform`
    // (the regression gate below and in the checked-in baseline).
    // The traced twin runs with span rings live for the printed
    // overhead line but is NOT added to the report: enabled-mode cost
    // is informational, not a cross-PR gate.
    {
        let a = workload::sorted_keys(Dist::Uniform, n / 2, 42);
        let b = workload::sorted_keys(Dist::Uniform, n - n / 2, 43);
        let mut out = vec![0i64; n];
        traff_merge::obs::trace::set_enabled(false);
        let r = Bench::new("obs_overhead").run(|| parallel_merge(&a, &b, &mut out, p));
        println!("  {}", r.summary());
        report.add(n as u64, &r);
        traff_merge::obs::trace::set_enabled(true);
        let traced = Bench::new("obs_overhead_traced").run(|| parallel_merge(&a, &b, &mut out, p));
        traff_merge::obs::trace::set_enabled(false);
        println!("  {}", traced.summary());
        let disabled = melems_per_sec(n as u64, r.median());
        let enabled = melems_per_sec(n as u64, traced.median());
        if enabled > 0.0 {
            println!(
                "  obs overhead: disabled {disabled:.1} Melem/s vs traced {enabled:.1} Melem/s \
                 ({:+.1}% when rings are live)",
                (disabled / enabled - 1.0) * 100.0
            );
        }
        // Advisory gate against the previous checked-in baseline:
        // tracing-disabled merge throughput within 3% of BENCH_9's
        // merge_uniform. Printed PASS/FAIL, non-fatal — absolute
        // Melem/s is machine-dependent, so the self-relative check
        // is the per-run comparison of obs_overhead vs merge_uniform
        // in the SAME report, which bench-diff gates across PRs.
        if let Ok(src) = std::fs::read_to_string("BENCH_9.json") {
            if let Ok(old) = BenchReport::parse(&src) {
                if let Some(base) = old.scenarios.iter().find(|s| s.name == "merge_uniform") {
                    let ratio = disabled / base.melems_per_sec;
                    let ok = ratio >= 0.97;
                    println!(
                        "  obs_overhead vs BENCH_9 merge_uniform: {disabled:.1} vs {:.1} \
                         Melem/s ({:+.1}%) — {}",
                        base.melems_per_sec,
                        (ratio - 1.0) * 100.0,
                        if ok { "PASS (within 3%)" } else { "FAIL (advisory; cross-machine)" }
                    );
                }
            }
        }
    }

    std::fs::write(&out_path, report.to_json())
        .map_err(|e| format!("writing {out_path}: {e}"))?;
    println!("wrote {out_path} ({} scenarios)", report.scenarios.len());
    Ok(())
}

/// `repro bench-diff` — compare two `BENCH_*.json` reports, failing
/// (exit 1) on any scenario whose throughput collapsed past the
/// tolerance. The default 60% tolerance is the cross-machine gate:
/// the checked-in baseline and the CI runner differ, so only
/// catastrophic drops (a lost parallel path, an accidental quadratic)
/// should trip it.
fn cmd_bench_diff(args: &Args) -> Result<(), String> {
    args.expect_known(&["old", "new", "tolerance-pct"])?;
    let old_path = args.get("old").ok_or("--old <BENCH_x.json> is required")?;
    let new_path = args.get("new").ok_or("--new <BENCH_y.json> is required")?;
    let tol_pct = args.get_usize("tolerance-pct", 60)?;
    if tol_pct >= 100 {
        return Err(format!("--tolerance-pct {tol_pct}: must be < 100"));
    }
    let read = |path: &str| -> Result<BenchReport, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        BenchReport::parse(&src).map_err(|e| format!("parsing {path}: {e}"))
    };
    let old = read(old_path)?;
    let new = read(new_path)?;
    println!(
        "bench diff: {old_path} (pr {}, {} threads{}) -> {new_path} (pr {}, {} threads{}), tolerance {tol_pct}%",
        old.pr,
        old.threads,
        if old.quick { ", quick" } else { "" },
        new.pr,
        new.threads,
        if new.quick { ", quick" } else { "" },
    );
    let d = old.diff(&new, tol_pct as f64 / 100.0);
    for line in &d.lines {
        println!("  {line}");
    }
    if d.regressions.is_empty() {
        println!("no regressions past the {tol_pct}% gate");
        Ok(())
    } else {
        Err(format!(
            "{} bench regression(s):\n  {}",
            d.regressions.len(),
            d.regressions.join("\n  ")
        ))
    }
}

fn cmd_artifacts() -> Result<(), String> {
    let dir = XlaRuntime::default_dir();
    println!("artifacts dir: {}", dir.display());
    let rt = XlaRuntime::load_dir(&dir).map_err(|e| e.to_string())?;
    println!("platform: {}", rt.platform);
    let mut t = Table::new(vec!["artifact", "inputs", "outputs", "description"]);
    for name in rt.names() {
        let exe = rt.get(name).unwrap();
        t.row(vec![
            name.to_string(),
            format!("{:?}", exe.spec.inputs.iter().map(|s| s.numel()).collect::<Vec<_>>()),
            format!("{:?}", exe.spec.outputs.iter().map(|s| s.numel()).collect::<Vec<_>>()),
            exe.spec.description.clone(),
        ]);
    }
    t.print();
    Ok(())
}

fn case_census(tasks: &[traff_merge::core::MergeTask]) -> String {
    use std::collections::BTreeMap;
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();
    for t in tasks {
        *counts.entry(format!("{:?}", t.case)).or_default() += 1;
    }
    counts.iter().map(|(k, v)| format!("{k}:{v}")).collect::<Vec<_>>().join(" ")
}

//! The process-wide metrics registry: names → histograms/counters,
//! serialized as one machine-readable JSON snapshot.
//!
//! Registration is get-or-create under a `Mutex` (cold path — a
//! handle is fetched once at wiring time and then recorded into
//! lock-free); snapshotting locks only the name maps, never the
//! recording paths.
//!
//! ## Naming scheme
//!
//! Dotted lowercase paths, layer first:
//!
//! - `exec.steal_latency` — idle worker's raise → next job obtained
//! - `exec.steal_take_latency` — steal-signal raise → victim take
//! - `exec.injector_wait.{service,background}` — head-of-batch queue
//!   wait per injector lane
//! - `pool.admission_wait.{service,background}` — submit → dispatch
//!   wait in the admission controller
//! - `svc.<tenant>.job_latency` — per-tenant job submit-to-complete
//! - `stream.<tenant>.{ingest,scan}_latency` — per-tenant stream ops
//!
//! ## Snapshot schema (version 1)
//!
//! ```json
//! {"version": 1,
//!  "histograms": {"<name>": {"count": N, "sum_nanos": N, "max_nanos": N,
//!                            "p50_nanos": N, "p99_nanos": N, "mean_nanos": N,
//!                            "buckets": [[lower_bound_nanos, count], ...]}},
//!  "counters": {"<name>": N}}
//! ```
//!
//! `buckets` lists only non-empty buckets as `[inclusive lower bound,
//! count]` pairs, so `count == sum of bucket counts` is a jq-level
//! invariant CI checks.

use super::hist::{bucket_lower, Hist, HistSnapshot};
use crate::model::sync::{AtomicU64, Mutex, Ordering};
use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

/// Name → instrument maps. See module docs for the naming scheme.
pub struct Registry {
    hists: Mutex<BTreeMap<String, Arc<Hist>>>,
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
}

impl Registry {
    /// An empty registry (tests; production code uses [`global`](Self::global)).
    pub fn new() -> Self {
        Registry {
            hists: Mutex::new(BTreeMap::new()),
            counters: Mutex::new(BTreeMap::new()),
        }
    }

    /// The process-wide registry every runtime component registers in.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    /// Get or create the named histogram. The returned handle is the
    /// thing to keep: recording through it never touches the registry
    /// lock again.
    pub fn hist(&self, name: &str) -> Arc<Hist> {
        let mut map = self.hists.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(Hist::new())))
    }

    /// Get or create the named monotone counter.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut map = self.counters.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicU64::new(0))))
    }

    /// Registered histogram names, sorted.
    pub fn hist_names(&self) -> Vec<String> {
        self.hists.lock().unwrap().keys().cloned().collect()
    }

    /// Snapshot one histogram by name, if registered.
    pub fn hist_snapshot(&self, name: &str) -> Option<HistSnapshot> {
        self.hists.lock().unwrap().get(name).map(|h| h.snapshot())
    }

    /// Serialize every registered instrument as one JSON object (the
    /// version-1 schema in the module docs). Each histogram is
    /// snapshotted once, so its own fields are mutually consistent.
    pub fn snapshot_json(&self) -> String {
        let hists: Vec<(String, HistSnapshot)> = {
            let map = self.hists.lock().unwrap();
            map.iter().map(|(k, v)| (k.clone(), v.snapshot())).collect()
        };
        let counters: Vec<(String, u64)> = {
            let map = self.counters.lock().unwrap();
            map.iter().map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed))).collect()
        };
        let mut out = String::with_capacity(256 + hists.len() * 256);
        out.push_str("{\"version\":1,\"histograms\":{");
        for (i, (name, snap)) in hists.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\"{}\":{{\"count\":{},\"sum_nanos\":{},\"max_nanos\":{},\
                 \"p50_nanos\":{},\"p99_nanos\":{},\"mean_nanos\":{},\"buckets\":[",
                escape(name),
                snap.count(),
                snap.sum_nanos,
                snap.max_nanos,
                snap.p50(),
                snap.p99(),
                snap.mean_nanos()
            ));
            let mut first = true;
            for (b, &c) in snap.buckets.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!("[{},{}]", bucket_lower(b), c));
            }
            out.push_str("]}");
        }
        out.push_str("},\"counters\":{");
        for (i, (name, v)) in counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{}", escape(name), v));
        }
        out.push_str("}}");
        out
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// Minimal JSON string escape. Metric names are dotted lowercase by
/// convention, but tenants are user input — escape defensively.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn get_or_create_returns_same_instrument() {
        let r = Registry::new();
        let a = r.hist("svc.t.job_latency");
        let b = r.hist("svc.t.job_latency");
        assert!(Arc::ptr_eq(&a, &b));
        a.record(1_000);
        assert_eq!(r.hist_snapshot("svc.t.job_latency").unwrap().count(), 1);
        assert!(r.hist_snapshot("missing").is_none());
        let c = r.counter("exec.dropped");
        c.fetch_add(3, Ordering::Relaxed);
        assert_eq!(r.counter("exec.dropped").load(Ordering::Relaxed), 3);
        assert_eq!(r.hist_names(), vec!["svc.t.job_latency".to_string()]);
    }

    #[test]
    fn snapshot_json_matches_schema() {
        let r = Registry::new();
        let h = r.hist("exec.steal_latency");
        h.record(100);
        h.record(100);
        h.record(5_000);
        r.counter("trace.dropped").fetch_add(2, Ordering::Relaxed);
        let doc = Json::parse(&r.snapshot_json()).expect("registry emits valid JSON");
        assert_eq!(doc.get("version").and_then(|v| v.as_usize()), Some(1));
        let hist = doc
            .get("histograms")
            .and_then(|h| h.get("exec.steal_latency"))
            .expect("registered histogram present");
        assert_eq!(hist.get("count").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(hist.get("sum_nanos").and_then(|v| v.as_usize()), Some(5_200));
        assert_eq!(hist.get("max_nanos").and_then(|v| v.as_usize()), Some(5_000));
        // count == sum of bucket counts (the jq-level CI invariant).
        let buckets = hist.get("buckets").and_then(|b| b.as_arr()).unwrap();
        let total: usize = buckets
            .iter()
            .map(|pair| pair.as_arr().unwrap()[1].as_usize().unwrap())
            .sum();
        assert_eq!(total, 3);
        // p50 lives in the [64,127] bucket; p99 clamps to the max.
        assert_eq!(hist.get("p50_nanos").and_then(|v| v.as_usize()), Some(127));
        assert_eq!(hist.get("p99_nanos").and_then(|v| v.as_usize()), Some(5_000));
        assert_eq!(
            doc.get("counters").and_then(|c| c.get("trace.dropped")).and_then(|v| v.as_usize()),
            Some(2)
        );
    }

    #[test]
    fn names_are_escaped() {
        let r = Registry::new();
        r.hist("svc.a\"b\\c.job_latency").record(1);
        let doc = Json::parse(&r.snapshot_json()).expect("escaped names parse");
        assert!(doc
            .get("histograms")
            .and_then(|h| h.get("svc.a\"b\\c.job_latency"))
            .is_some());
    }
}

//! Observability: latency histograms, span tracing, and the metrics
//! registry.
//!
//! Three cooperating pieces, all built on the [`crate::model::sync`]
//! atomics shim so the model checker can exercise their protocols:
//!
//! - [`hist`] — fixed-size log2-bucketed latency histograms. Recording
//!   is a couple of `Relaxed` `fetch_add`s on a per-thread shard (no
//!   locks, no allocation); snapshots fold the shards and derive exact
//!   bucket-resolution percentiles.
//! - [`trace`] — per-thread bounded event rings holding span
//!   begin/end pairs and instants, exportable as chrome://tracing
//!   JSON. Compiled down to a single branch on a process-wide flag
//!   when disabled (`Config.trace` / `EXEC_TRACE=1`).
//! - [`registry`] — the process-wide name → histogram/counter map
//!   serialized as one machine-readable JSON snapshot (`repro
//!   metrics`, `--metrics-json`).
//!
//! Layering: `obs` sits below `exec`/`coordinator`/`stream` (it
//! depends only on `model::sync` and `util`), so every layer may
//! record into it without cycles.

pub mod hist;
pub mod registry;
pub mod trace;

pub use hist::{Hist, HistSnapshot};
pub use registry::Registry;
pub use trace::SpanKind;

use crate::model::sync::{AtomicUsize, Ordering};
use std::cell::Cell;

/// Process-wide recorder-slot allocator; each recording thread gets a
/// stable small integer on first use, which picks its histogram /
/// trace-ring shard (same trick as the injector's submitter id).
static OBS_SLOT_SEQ: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static OBS_SLOT: Cell<usize> = Cell::new(usize::MAX);
}

/// Stable per-thread observability slot (assigned on first record).
pub(crate) fn thread_slot() -> usize {
    OBS_SLOT.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            return v;
        }
        let v = OBS_SLOT_SEQ.fetch_add(1, Ordering::Relaxed);
        c.set(v);
        v
    })
}

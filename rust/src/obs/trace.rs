//! Span tracing: per-thread bounded event rings with a
//! chrome://tracing exporter.
//!
//! ## Hot-path contract
//!
//! Tracing is **off by default** and the entire recording surface is
//! gated on one process-wide flag: [`enabled`] is a single `Relaxed`
//! load, and [`span_start`] / [`span_end`] / [`instant`] return
//! immediately when it is false. Enable with [`set_enabled`] (wired to
//! `Config.trace`) or the `EXEC_TRACE=1` environment variable.
//!
//! ## Ring protocol (unsafe-free seqlock)
//!
//! Each shard is a bounded ring of slots whose fields are all shim
//! atomics — there is no `unsafe` anywhere in this module; the seqlock
//! exists to keep *events* coherent (no mixing of two generations'
//! fields), not to make racy non-atomic access sound.
//!
//! Writer (one at a time per shard, enforced by a `busy` CAS claim —
//! a loser drops its event and bumps `dropped` rather than spin):
//!
//! 1. `seq.store(2c+1, Relaxed)` — mark the slot in-progress,
//! 2. `fence(Release)` — order the mark before the field stores,
//! 3. field stores (`Relaxed`),
//! 4. `seq.store(2c+2, Release)` — publish generation `c`.
//!
//! Reader ([`Tracer::drain`]): `s1 = seq.load(Acquire)`; skip odd or
//! never-written slots; field loads (`Relaxed`); `fence(Acquire)`
//! (orders the field loads before the re-check); `s2 = seq.load
//! (Relaxed)`; keep the event iff `s1 == s2`. A slot overwritten
//! mid-read fails the re-check and is skipped — drain never blocks
//! writers. The wrap-vs-drain race is model-checked below.

use super::thread_slot;
use crate::model::sync::{fence, AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Every span/instant kind the runtime records, spanning the whole
/// stack: pool admission, executor scheduling, the adaptive merge
/// kernel, and the stream store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// Job handed to `WorkerPool::admit` (instant; arg = queue depth).
    Submit = 0,
    /// Job dispatched to the executor after waiting for a permit
    /// (instant; arg = wait in nanos).
    Admit = 1,
    /// Injector batch drained onto a worker (instant; arg = batch size).
    Dequeue = 2,
    /// One job body on a worker (span; arg = worker id).
    Run = 3,
    /// Steal-request flag raised by an idle worker (instant; arg =
    /// raiser id).
    StealRaise = 4,
    /// Steal-request flag consumed by a victim (span over raise→take;
    /// arg = victim id).
    StealTake = 5,
    /// Adaptive merge co-rank split of the remainder (instant; arg =
    /// elements handed to the thief).
    AdaptiveSplit = 6,
    /// Shard buffer sealed into a sorted run (span; arg = records).
    StreamSeal = 7,
    /// One compaction window merged (span; arg = input records).
    Compact = 8,
    /// Compaction result committed/published (span; arg = output runs).
    Publish = 9,
    /// Manifest record appended + fsynced (span; arg = frame bytes).
    ManifestFsync = 10,
}

impl SpanKind {
    /// Every kind, for exporters and round-trip tests.
    pub const ALL: [SpanKind; 11] = [
        SpanKind::Submit,
        SpanKind::Admit,
        SpanKind::Dequeue,
        SpanKind::Run,
        SpanKind::StealRaise,
        SpanKind::StealTake,
        SpanKind::AdaptiveSplit,
        SpanKind::StreamSeal,
        SpanKind::Compact,
        SpanKind::Publish,
        SpanKind::ManifestFsync,
    ];

    /// Stable machine-readable name (chrome trace `name` field).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Submit => "submit",
            SpanKind::Admit => "admit",
            SpanKind::Dequeue => "dequeue",
            SpanKind::Run => "run",
            SpanKind::StealRaise => "steal_raise",
            SpanKind::StealTake => "steal_take",
            SpanKind::AdaptiveSplit => "adaptive_split",
            SpanKind::StreamSeal => "stream_seal",
            SpanKind::Compact => "compact",
            SpanKind::Publish => "publish",
            SpanKind::ManifestFsync => "manifest_fsync",
        }
    }

    /// Layer the span belongs to (chrome trace `cat` field).
    pub fn category(self) -> &'static str {
        match self {
            SpanKind::Submit | SpanKind::Admit => "pool",
            SpanKind::Dequeue | SpanKind::Run | SpanKind::StealRaise | SpanKind::StealTake => {
                "exec"
            }
            SpanKind::AdaptiveSplit => "core",
            SpanKind::StreamSeal | SpanKind::Compact | SpanKind::Publish
            | SpanKind::ManifestFsync => "stream",
        }
    }

    /// Inverse of `as u8`; `None` for out-of-range (e.g. a torn slot).
    pub fn from_u8(v: u8) -> Option<SpanKind> {
        SpanKind::ALL.get(v as usize).copied()
    }
}

/// One decoded trace event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub kind: SpanKind,
    /// Start time, nanos since the process trace origin.
    pub ts_nanos: u64,
    /// Span duration in nanos (0 for instants).
    pub dur_nanos: u64,
    /// Kind-specific argument (see [`SpanKind`] docs).
    pub arg: u64,
    /// Ring shard (≈ thread) the event was recorded on.
    pub shard: usize,
}

/// One ring slot. All fields are atomics; `seq` carries the seqlock
/// generation (odd = write in progress, `2c+2` = generation `c`
/// published, 0 = never written).
struct Slot {
    seq: AtomicU64,
    kind: AtomicU64,
    ts: AtomicU64,
    dur: AtomicU64,
    arg: AtomicU64,
}

impl Slot {
    fn new() -> Self {
        Slot {
            seq: AtomicU64::new(0),
            kind: AtomicU64::new(0),
            ts: AtomicU64::new(0),
            dur: AtomicU64::new(0),
            arg: AtomicU64::new(0),
        }
    }
}

/// One per-thread ring, padded so two recording threads never share a
/// cache line for their cursors.
#[repr(align(128))]
struct Shard {
    /// Power-of-two slot ring.
    slots: Box<[Slot]>,
    /// Monotone event count; `cursor & (len-1)` is the next slot.
    cursor: AtomicU64,
    /// Single-writer claim; contenders drop their event.
    busy: AtomicBool,
    /// Events dropped on claim contention.
    dropped: AtomicU64,
}

impl Shard {
    fn new(capacity: usize) -> Self {
        let cap = capacity.max(1).next_power_of_two();
        Shard {
            slots: (0..cap).map(|_| Slot::new()).collect(),
            cursor: AtomicU64::new(0),
            busy: AtomicBool::new(false),
            dropped: AtomicU64::new(0),
        }
    }

    fn record(&self, kind: SpanKind, ts: u64, dur: u64, arg: u64) {
        if self
            .busy
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            // Another thread hashed onto this shard mid-write: drop
            // rather than spin — tracing must never add a wait.
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let c = self.cursor.load(Ordering::Relaxed);
        let slot = &self.slots[c as usize & (self.slots.len() - 1)];
        slot.seq.store(2 * c + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        slot.kind.store(kind as u8 as u64, Ordering::Relaxed);
        slot.ts.store(ts, Ordering::Relaxed);
        slot.dur.store(dur, Ordering::Relaxed);
        slot.arg.store(arg, Ordering::Relaxed);
        slot.seq.store(2 * c + 2, Ordering::Release);
        self.cursor.store(c + 1, Ordering::Relaxed);
        self.busy.store(false, Ordering::Release);
    }
}

/// A set of per-thread event rings. Recording picks the calling
/// thread's shard; draining sweeps every shard and keeps only slots
/// that pass the seqlock re-check.
pub struct Tracer {
    shards: Box<[Shard]>,
    mask: usize,
}

impl Tracer {
    /// `shards` rings of `capacity` slots each (both rounded up to
    /// powers of two).
    pub fn with_geometry(shards: usize, capacity: usize) -> Self {
        let n = shards.max(1).next_power_of_two();
        Tracer {
            shards: (0..n).map(|_| Shard::new(capacity)).collect(),
            mask: n - 1,
        }
    }

    /// The process-wide tracer every helper records into: 16 rings of
    /// 4096 slots (≈2.5 MiB), allocated on first use.
    pub fn global() -> &'static Tracer {
        static GLOBAL: OnceLock<Tracer> = OnceLock::new();
        GLOBAL.get_or_init(|| Tracer::with_geometry(16, 4096))
    }

    /// Record on the calling thread's shard.
    #[inline]
    pub fn record(&self, kind: SpanKind, ts: u64, dur: u64, arg: u64) {
        self.record_at(thread_slot(), kind, ts, dur, arg);
    }

    /// Record on an explicit shard (tests; `record` routes here).
    pub fn record_at(&self, shard: usize, kind: SpanKind, ts: u64, dur: u64, arg: u64) {
        self.shards[shard & self.mask].record(kind, ts, dur, arg);
    }

    /// Decode every coherent slot, oldest-first by timestamp. Slots
    /// being overwritten during the sweep fail the seqlock re-check
    /// and are skipped; events stay in place (drain is idempotent
    /// until the ring wraps over them).
    pub fn drain(&self) -> Vec<Event> {
        let mut out = Vec::new();
        for (tid, shard) in self.shards.iter().enumerate() {
            for slot in shard.slots.iter() {
                let s1 = slot.seq.load(Ordering::Acquire);
                if s1 == 0 || s1 % 2 == 1 {
                    continue;
                }
                let kind = slot.kind.load(Ordering::Relaxed);
                let ts = slot.ts.load(Ordering::Relaxed);
                let dur = slot.dur.load(Ordering::Relaxed);
                let arg = slot.arg.load(Ordering::Relaxed);
                fence(Ordering::Acquire);
                let s2 = slot.seq.load(Ordering::Relaxed);
                if s1 != s2 {
                    continue;
                }
                let Some(kind) = SpanKind::from_u8(kind as u8) else {
                    continue;
                };
                out.push(Event { kind, ts_nanos: ts, dur_nanos: dur, arg, shard: tid });
            }
        }
        out.sort_by_key(|e| (e.ts_nanos, e.shard));
        out
    }

    /// Total events ever recorded (monotone; the rings keep the most
    /// recent `shards × capacity` of them).
    pub fn recorded(&self) -> u64 {
        self.shards.iter().map(|s| s.cursor.load(Ordering::Relaxed)).sum()
    }

    /// Events dropped on shard-claim contention.
    pub fn dropped(&self) -> u64 {
        self.shards.iter().map(|s| s.dropped.load(Ordering::Relaxed)).sum()
    }
}

// ---------------------------------------------------------------------------
// Process-wide gate, clock, and recording helpers.
// ---------------------------------------------------------------------------

/// The one flag the hot path pays for: every helper is a `Relaxed`
/// load of this plus an early return while tracing is off.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is tracing currently enabled? One `Relaxed` load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on or off process-wide (wired to `Config.trace`).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Enable tracing if `EXEC_TRACE=1` (or `true`) is set. Idempotent;
/// never *disables* (so `Config.trace` and the env compose as OR).
pub fn enable_from_env() {
    if matches!(
        std::env::var("EXEC_TRACE").ok().as_deref(),
        Some("1") | Some("true")
    ) {
        set_enabled(true);
    }
}

/// Nanoseconds since the process trace origin (first call wins).
pub fn now_nanos() -> u64 {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    let origin = *ORIGIN.get_or_init(Instant::now);
    u64::try_from(origin.elapsed().as_nanos()).unwrap_or(u64::MAX)
}

/// Start a span: the current trace timestamp, or 0 when tracing is
/// off (a 0 start makes the matching [`span_end`] a no-op, so a span
/// straddling an enable flip is dropped rather than garbled).
#[inline]
pub fn span_start() -> u64 {
    if enabled() {
        now_nanos().max(1)
    } else {
        0
    }
}

/// Close a span opened by [`span_start`] and record it.
#[inline]
pub fn span_end(kind: SpanKind, start: u64, arg: u64) {
    if start == 0 || !enabled() {
        return;
    }
    let now = now_nanos();
    Tracer::global().record(kind, start, now.saturating_sub(start), arg);
}

/// Record a zero-duration instant event.
#[inline]
pub fn instant(kind: SpanKind, arg: u64) {
    if !enabled() {
        return;
    }
    Tracer::global().record(kind, now_nanos(), 0, arg);
}

/// Record a span with an explicit start timestamp (used when the
/// start was stamped by another thread, e.g. steal raise→take).
#[inline]
pub fn span_between(kind: SpanKind, start_nanos: u64, arg: u64) {
    if !enabled() {
        return;
    }
    let now = now_nanos();
    Tracer::global().record(kind, start_nanos, now.saturating_sub(start_nanos), arg);
}

/// Serialize events as a chrome://tracing (about:tracing, Perfetto)
/// JSON object: `{"traceEvents": [...]}`. Durations and timestamps
/// are microseconds (fractional), `tid` is the ring shard, spans use
/// phase `"X"`, instants phase `"i"` with global scope.
pub fn chrome_trace_json(events: &[Event]) -> String {
    let mut out = String::with_capacity(64 + events.len() * 96);
    out.push_str("{\"traceEvents\":[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let ts_us = e.ts_nanos as f64 / 1_000.0;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"pid\":1,\"tid\":{},\"ts\":{:.3}",
            e.kind.name(),
            e.kind.category(),
            e.shard,
            ts_us
        ));
        if e.dur_nanos == 0 {
            out.push_str(",\"ph\":\"i\",\"s\":\"g\"");
        } else {
            out.push_str(&format!(",\"ph\":\"X\",\"dur\":{:.3}", e.dur_nanos as f64 / 1_000.0));
        }
        out.push_str(&format!(",\"args\":{{\"arg\":{}}}}}", e.arg));
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_from_u8_roundtrips() {
        for k in SpanKind::ALL {
            assert_eq!(SpanKind::from_u8(k as u8), Some(k));
        }
        assert_eq!(SpanKind::from_u8(SpanKind::ALL.len() as u8), None);
        assert_eq!(SpanKind::from_u8(255), None);
    }

    #[test]
    fn disabled_span_start_is_zero() {
        // Tracing defaults off; span_start must be the no-op sentinel
        // and span_end on it must not touch the global tracer.
        assert!(!enabled());
        assert_eq!(span_start(), 0);
        span_end(SpanKind::Run, 0, 7); // must be a no-op
    }

    #[test]
    fn record_drain_roundtrip() {
        let t = Tracer::with_geometry(2, 8);
        t.record_at(0, SpanKind::Run, 100, 50, 3);
        t.record_at(1, SpanKind::StealRaise, 40, 0, 1);
        let evs = t.drain();
        assert_eq!(evs.len(), 2);
        // Sorted by timestamp, oldest first.
        assert_eq!(evs[0].kind, SpanKind::StealRaise);
        assert_eq!(evs[0].ts_nanos, 40);
        assert_eq!(evs[0].shard, 1);
        assert_eq!(evs[1].kind, SpanKind::Run);
        assert_eq!(evs[1].dur_nanos, 50);
        assert_eq!(evs[1].arg, 3);
        assert_eq!(t.recorded(), 2);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_wrap_keeps_most_recent() {
        let t = Tracer::with_geometry(1, 4);
        for i in 0..10u64 {
            t.record_at(0, SpanKind::Dequeue, i + 1, 0, i);
        }
        let evs = t.drain();
        assert_eq!(evs.len(), 4);
        let args: Vec<u64> = evs.iter().map(|e| e.arg).collect();
        assert_eq!(args, vec![6, 7, 8, 9]);
        assert_eq!(t.recorded(), 10);
    }

    #[test]
    fn chrome_json_shape() {
        let t = Tracer::with_geometry(1, 4);
        t.record_at(0, SpanKind::Compact, 2_000, 1_500, 12);
        t.record_at(0, SpanKind::StealRaise, 3_000, 0, 2);
        let json = chrome_trace_json(&t.drain());
        let doc = crate::util::json::Json::parse(&json).expect("exporter emits valid JSON");
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("name").and_then(|v| v.as_str()), Some("compact"));
        assert_eq!(evs[0].get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(evs[0].get("ts").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(evs[0].get("dur").and_then(|v| v.as_f64()), Some(1.5));
        assert_eq!(evs[1].get("ph").and_then(|v| v.as_str()), Some("i"));
        assert_eq!(evs[1].get("s").and_then(|v| v.as_str()), Some("g"));
    }
}

#[cfg(all(test, feature = "model"))]
mod model_tests {
    use super::*;
    use crate::model::thread;
    use crate::model::{check_with, Config};
    use std::sync::Arc;

    /// Ring wrap racing drain: a writer wraps a capacity-2 ring while
    /// the main thread drains. Every event the seqlock lets through
    /// must be coherent — `arg` was written as a function of `ts`, so
    /// a mixed-generation slot would fail the equation.
    #[test]
    fn model_trace_ring_wrap_vs_drain() {
        fn tag(ts: u64) -> u64 {
            ts.wrapping_mul(31) ^ 0x5a
        }
        let schedules = check_with(
            Config { name: "trace_ring_wrap_vs_drain", ..Config::default() },
            || {
                let t = Arc::new(Tracer::with_geometry(1, 2));
                let w = {
                    let t = Arc::clone(&t);
                    thread::spawn(move || {
                        for ts in 1..=3u64 {
                            t.record_at(0, SpanKind::Run, ts, 0, tag(ts));
                        }
                    })
                };
                for e in t.drain() {
                    assert_eq!(e.arg, tag(e.ts_nanos), "torn slot escaped the seqlock");
                }
                w.join().unwrap();
                let evs = t.drain();
                assert_eq!(evs.len(), 2, "capacity-2 ring keeps the last two events");
                assert_eq!(evs[0].ts_nanos, 2);
                assert_eq!(evs[1].ts_nanos, 3);
                for e in &evs {
                    assert_eq!(e.arg, tag(e.ts_nanos));
                }
                assert_eq!(t.recorded() + t.dropped(), 3);
            },
        );
        assert!(schedules > 1, "expected multiple interleavings, got {schedules}");
    }
}

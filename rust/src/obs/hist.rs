//! Log2-bucketed latency histograms with lock-free recording.
//!
//! A [`Hist`] is a small fixed table: 64 buckets where bucket `i`
//! counts samples whose nanosecond value needs `i` bits (bucket 0 is
//! exactly `{0}`, bucket `i` covers `[2^(i-1), 2^i - 1]`, bucket 63
//! absorbs everything `>= 2^62`). That gives ~2x value resolution over
//! the full `u64` range in 64 words — enough to separate a 10µs
//! dequeue from a 10ms fsync, which is all the control plane needs.
//!
//! Recording is sharded by thread (the shared [`super::thread_slot`]
//! allocator) so concurrent recorders touch disjoint cache lines, and
//! every update is a `Relaxed` atomic RMW: two `fetch_add`s and a
//! `fetch_max`, no locks, no allocation — safe from the hottest paths.
//!
//! Snapshots fold all shards into a plain [`HistSnapshot`]. The sample
//! count is *derived* from the bucket sums rather than stored, so a
//! snapshot is always self-consistent: `count()` equals the number of
//! bucket increments it actually observed, even when taken mid-record.
//! `sum`/`max` are updated by separate RMWs and may lag the buckets by
//! an in-flight sample — fine for telemetry, and the model test below
//! pins down exactly this contract.
//!
//! Percentiles are *exact-bucket*: `percentile(q)` returns the upper
//! bound of the bucket holding the q-th sample, clamped to the
//! observed maximum. No interpolation, no sampling error from bounded
//! reservoir vectors — long runs cannot truncate the tail.

use crate::model::sync::{AtomicU64, Ordering};
use std::fmt;
use std::time::Duration;

/// Number of log2 buckets (one per bit of a nanosecond `u64`).
pub const BUCKETS: usize = 64;

/// Default shard count (rounded up to a power of two).
const DEFAULT_SHARDS: usize = 16;

/// Bucket index for a nanosecond sample: 0 for 0, otherwise the
/// sample's bit length, saturating into the last bucket.
#[inline]
pub fn bucket_index(nanos: u64) -> usize {
    if nanos == 0 {
        0
    } else {
        (64 - nanos.leading_zeros() as usize).min(BUCKETS - 1)
    }
}

/// Inclusive lower bound of bucket `i` (0, 1, 2, 4, 8, ...).
#[inline]
pub fn bucket_lower(i: usize) -> u64 {
    match i {
        0 => 0,
        1 => 1,
        _ => 1u64 << (i - 1),
    }
}

/// Inclusive upper bound of bucket `i`; the last bucket is open-ended.
#[inline]
pub fn bucket_upper(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// One recorder shard, padded to its own cache line pair so two
/// recording threads never contend on the same counters.
#[repr(align(128))]
struct Shard {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    max: AtomicU64,
}

impl Shard {
    fn new() -> Self {
        Shard {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

/// A sharded, lock-free log2 histogram of nanosecond samples.
pub struct Hist {
    /// Power-of-two shard table; a recorder picks `thread_slot() & mask`.
    shards: Box<[Shard]>,
    mask: usize,
}

impl Hist {
    /// Histogram with the default shard count.
    pub fn new() -> Self {
        Self::with_shards(DEFAULT_SHARDS)
    }

    /// Histogram with `shards` recorder shards (rounded up to a power
    /// of two, minimum 1). Tests use 1 shard for determinism.
    pub fn with_shards(shards: usize) -> Self {
        let n = shards.max(1).next_power_of_two();
        Hist {
            shards: (0..n).map(|_| Shard::new()).collect(),
            mask: n - 1,
        }
    }

    /// Number of recorder shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Record one nanosecond sample on the calling thread's shard.
    /// Lock-free: two `Relaxed` `fetch_add`s and a `Relaxed`
    /// `fetch_max`.
    #[inline]
    pub fn record(&self, nanos: u64) {
        self.record_in(super::thread_slot(), nanos);
    }

    /// Record into an explicit shard (wrapped into range). Used by
    /// tests that need deterministic shard placement; `record` routes
    /// here with the thread slot.
    #[inline]
    pub fn record_in(&self, shard: usize, nanos: u64) {
        let s = &self.shards[shard & self.mask];
        s.buckets[bucket_index(nanos)].fetch_add(1, Ordering::Relaxed);
        s.sum.fetch_add(nanos, Ordering::Relaxed);
        s.max.fetch_max(nanos, Ordering::Relaxed);
    }

    /// Record an elapsed [`Duration`] (saturating to `u64` nanos).
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Fold every shard into one plain snapshot. `Relaxed` loads: the
    /// result is a consistent-by-construction view (see module docs),
    /// not a linearizable cut.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut snap = HistSnapshot::default();
        for s in self.shards.iter() {
            for (i, b) in s.buckets.iter().enumerate() {
                snap.buckets[i] = snap.buckets[i].saturating_add(b.load(Ordering::Relaxed));
            }
            snap.sum_nanos = snap.sum_nanos.saturating_add(s.sum.load(Ordering::Relaxed));
            snap.max_nanos = snap.max_nanos.max(s.max.load(Ordering::Relaxed));
        }
        snap
    }
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Debug for Hist {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let snap = self.snapshot();
        f.debug_struct("Hist")
            .field("shards", &self.shards.len())
            .field("count", &snap.count())
            .field("max_nanos", &snap.max_nanos)
            .finish()
    }
}

/// A folded, plain-data view of a [`Hist`] at one point in time.
/// Mergeable (shard snapshots from different histograms or windows
/// combine with [`merge`](HistSnapshot::merge)) and subtractable
/// ([`since`](HistSnapshot::since) yields the window between two
/// snapshots of the same histogram).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub sum_nanos: u64,
    pub max_nanos: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { buckets: [0; BUCKETS], sum_nanos: 0, max_nanos: 0 }
    }
}

impl HistSnapshot {
    /// Total samples — derived from the buckets, never stored.
    pub fn count(&self) -> u64 {
        self.buckets.iter().fold(0u64, |a, &b| a.saturating_add(b))
    }

    /// True when no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.buckets.iter().all(|&b| b == 0)
    }

    /// Fold another snapshot into this one (bucket-wise add).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for i in 0..BUCKETS {
            self.buckets[i] = self.buckets[i].saturating_add(other.buckets[i]);
        }
        self.sum_nanos = self.sum_nanos.saturating_add(other.sum_nanos);
        self.max_nanos = self.max_nanos.max(other.max_nanos);
    }

    /// The window between `prev` (an earlier snapshot of the same
    /// histogram) and `self`: bucket-wise difference. `max_nanos`
    /// stays the all-time maximum — the histogram does not keep
    /// per-window maxima, and percentiles clamp against it, which for
    /// a window can only round a percentile *up* to the global max.
    pub fn since(&self, prev: &HistSnapshot) -> HistSnapshot {
        let mut out = HistSnapshot::default();
        for i in 0..BUCKETS {
            out.buckets[i] = self.buckets[i].saturating_sub(prev.buckets[i]);
        }
        out.sum_nanos = self.sum_nanos.saturating_sub(prev.sum_nanos);
        out.max_nanos = self.max_nanos;
        out
    }

    /// Exact-bucket percentile in nanoseconds: the upper bound of the
    /// bucket containing the `q`-th percentile sample, clamped to the
    /// observed maximum. `q` in `[0, 100]`; 0 samples → 0.
    pub fn percentile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let target = ((q / 100.0) * count as f64).ceil().max(1.0) as u64;
        let target = target.min(count);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen = seen.saturating_add(b);
            if seen >= target {
                return bucket_upper(i).min(self.max_nanos);
            }
        }
        self.max_nanos
    }

    /// Median (exact-bucket, see [`percentile`](Self::percentile)).
    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    /// 99th percentile (exact-bucket).
    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    /// Arithmetic mean in nanoseconds (0 when empty).
    pub fn mean_nanos(&self) -> u64 {
        let count = self.count();
        if count == 0 {
            0
        } else {
            self.sum_nanos / count
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(7), 3);
        assert_eq!(bucket_index(8), 4);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        // Every bucket's bounds round-trip through the index.
        for i in 1..BUCKETS {
            assert_eq!(bucket_index(bucket_lower(i)), i, "lower bound of bucket {i}");
        }
        for i in 0..BUCKETS - 1 {
            assert_eq!(bucket_index(bucket_upper(i)), i, "upper bound of bucket {i}");
        }
    }

    #[test]
    fn record_snapshot_roundtrip() {
        let h = Hist::with_shards(4);
        for &v in &[0u64, 1, 100, 1_000, 1_000_000, 1_000_000_000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 6);
        assert_eq!(s.sum_nanos, 1 + 100 + 1_000 + 1_000_000 + 1_000_000_000);
        assert_eq!(s.max_nanos, 1_000_000_000);
        assert!(!s.is_empty());
        assert_eq!(s.buckets[0], 1);
        assert_eq!(s.buckets[bucket_index(100)], 1);
    }

    #[test]
    fn percentiles_are_exact_bucket_and_max_clamped() {
        let h = Hist::with_shards(1);
        // 99 fast samples in bucket_index(100)=7 ([64,127]), one slow.
        for _ in 0..99 {
            h.record_in(0, 100);
        }
        h.record_in(0, 5_000);
        let s = h.snapshot();
        assert_eq!(s.count(), 100);
        assert_eq!(s.p50(), 127); // upper bound of the [64,127] bucket
        assert_eq!(s.p99(), 127); // 99th sample still in the fast bucket
        assert_eq!(s.percentile(100.0), 5_000); // clamped to observed max
        assert_eq!(s.max_nanos, 5_000);
        // Single-sample histogram: every percentile is the max.
        let h1 = Hist::with_shards(1);
        h1.record_in(0, 42);
        let s1 = h1.snapshot();
        assert_eq!(s1.p50(), 42);
        assert_eq!(s1.p99(), 42);
        assert_eq!(s1.mean_nanos(), 42);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Hist::new().snapshot();
        assert!(s.is_empty());
        assert_eq!(s.count(), 0);
        assert_eq!(s.p50(), 0);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.mean_nanos(), 0);
    }

    #[test]
    fn merge_folds_buckets() {
        let a = Hist::with_shards(1);
        let b = Hist::with_shards(1);
        a.record_in(0, 10);
        b.record_in(0, 10);
        b.record_in(0, 1 << 20);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count(), 3);
        assert_eq!(m.sum_nanos, 20 + (1 << 20));
        assert_eq!(m.max_nanos, 1 << 20);
        assert_eq!(m.buckets[bucket_index(10)], 2);
    }

    #[test]
    fn since_yields_the_window() {
        let h = Hist::with_shards(1);
        h.record_in(0, 100);
        let before = h.snapshot();
        h.record_in(0, 1_000);
        h.record_in(0, 1_000);
        let after = h.snapshot();
        let win = after.since(&before);
        assert_eq!(win.count(), 2);
        assert_eq!(win.sum_nanos, 2_000);
        assert_eq!(win.buckets[bucket_index(1_000)], 2);
        assert_eq!(win.buckets[bucket_index(100)], 0);
        // p99 of the window reflects only the window's samples.
        assert_eq!(win.p99(), 1_023.min(win.max_nanos));
    }

    #[test]
    fn record_duration_saturates() {
        let h = Hist::with_shards(1);
        h.record_duration(Duration::from_micros(3));
        let s = h.snapshot();
        assert_eq!(s.sum_nanos, 3_000);
        assert_eq!(s.count(), 1);
    }
}

#[cfg(all(test, feature = "model"))]
mod model_tests {
    use super::*;
    use crate::model::thread;
    use crate::model::{check_with, Config};
    use std::sync::Arc;

    /// Record racing snapshot: a mid-race snapshot must be
    /// self-consistent (derived count never exceeds the records that
    /// actually started), and the post-join snapshot must be exact —
    /// no lost updates under any interleaving.
    #[test]
    fn model_hist_record_vs_snapshot() {
        let schedules = check_with(
            Config { name: "hist_record_vs_snapshot", ..Config::default() },
            || {
                let h = Arc::new(Hist::with_shards(2));
                let w = {
                    let h = Arc::clone(&h);
                    thread::spawn(move || {
                        h.record_in(0, 100);
                        h.record_in(1, 200);
                    })
                };
                let mid = h.snapshot();
                assert!(mid.count() <= 2, "phantom samples in mid-race snapshot");
                assert!(mid.max_nanos <= 200);
                assert!(mid.sum_nanos <= 300);
                w.join().unwrap();
                let fin = h.snapshot();
                assert_eq!(fin.count(), 2);
                assert_eq!(fin.sum_nanos, 300);
                assert_eq!(fin.max_nanos, 200);
                assert_eq!(fin.p99(), 200);
            },
        );
        assert!(schedules > 1, "expected multiple interleavings, got {schedules}");
    }
}

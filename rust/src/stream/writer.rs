//! Sharded multi-writer ingest: per-submitter buffer shards sealing
//! through the shared generation clock — the write path that scales
//! with writer threads instead of serializing on one
//! `Mutex<Ingestor>`.
//!
//! ```text
//!  writer thread 1 ──► [ShardWriter 1]──┐  seal (sorted batch)
//!  writer thread 2 ──► [ShardWriter 2]──┼──► [RunStore] generation
//!  writer thread N ──► [ShardWriter N]──┘    clock: each seal takes
//!        │                   │               the next gen atomically
//!        │ thread-id route   │ 64-bit seq blocks
//!        ▼                   ▼
//!    [WriterSet]         [SeqClock] (shared fetch-add)
//! ```
//!
//! Each [`ShardWriter`] owns its buffer outright — pushes are plain
//! `Vec` appends, no lock, no sharing — and seals full runs through
//! [`RunStore::seal_wide`], where the store's generation clock hands
//! out the seal number *inside* its list-lock critical section. That
//! single serialization point (a fetch-add plus a list insert) is the
//! only thing concurrent writers contend on, which is why ingest
//! throughput scales with submitters (bench E11) while the ordering
//! contract stays exact:
//!
//! - **per-writer order is preserved exactly** — one writer's records
//!   with equal keys emerge in its push order (the buffer holds push
//!   order, the seal sort is stable, and a single writer's successive
//!   seals take monotone generations);
//! - **cross-writer duplicate order is seal-generation order** — two
//!   writers' equal-key records order by which *run* sealed first, the
//!   same arrival semantics the store gives any interleaving of seals.
//!
//! Sequence numbers come from the shared [`SeqClock`] in coarse blocks
//! ([`SEQ_BLOCK`] at a time, one fetch-add per block), so they are
//! globally unique and per-writer monotone; a solo writer's sequence
//! is exactly contiguous from 0, which keeps the single-tenant
//! facade's tag oracle intact. The 64-bit sequence is stored as a
//! **(aux, tag) pair**: the low 32 bits pack into the record tag next
//! to the 32-bit payload (`tag = seq_lo << 32 | payload`), the high 32
//! bits ride out of line in the page format's v2 aux column
//! ([`WideRecord`]), reassembled by [`WideRecord::full_seq`]. Streams
//! no longer cap at 2^32 records — only a store in
//! [`legacy_pages`](super::StreamConfig::legacy_pages) mode (v1 files,
//! no aux column) still refuses sequence numbers past the packed-tag
//! limit, with [`StreamError::CapExceeded`].
//!
//! The thread-id shard routing in [`WriterSet`] mirrors
//! `exec::injector`'s shard-by-submitter trick: a process-wide
//! sequence hands each OS thread a stable small integer on first use,
//! and the thread hashes to `id & (shards - 1)`. Threads that want to
//! skip even that routing hold an owned [`ShardWriter`]
//! ([`WriterSet::owned_writer`], or
//! [`StreamHandle::writer`](crate::coordinator::StreamHandle::writer)
//! at the service layer).

use super::run::WideRecord;
use super::store::RunStore;
use super::StreamError;
use crate::core::record::Record;
use crate::core::sort::parallel_merge_sort;
use crate::model::sync::{AtomicU64, AtomicUsize, Mutex, Ordering};
use std::cell::Cell;
use std::sync::Arc;

/// Sequence numbers a writer takes per clock allocation: coarse enough
/// that the shared fetch-add is off the per-record hot path, fine
/// enough that abandoned tails don't matter (sequence gaps are
/// harmless — ordering only ever reads relative magnitude).
pub const SEQ_BLOCK: u64 = 1 << 16;

/// The shared 64-bit ingest-sequence allocator: one atomic counter,
/// handed out in [`SEQ_BLOCK`]-sized chunks. Every record across every
/// writer of one stream gets a globally unique sequence number;
/// numbers within one writer are strictly increasing.
pub struct SeqClock {
    next: AtomicU64,
}

impl SeqClock {
    /// A clock starting at sequence 0.
    pub fn new() -> SeqClock {
        SeqClock::with_first(0)
    }

    /// A clock starting at `first` — lets tests (and the 2^32 boundary
    /// check) fast-forward a stream without pushing billions of
    /// records.
    pub fn with_first(first: u64) -> SeqClock {
        SeqClock { next: AtomicU64::new(first) }
    }

    /// Claim `n` consecutive sequence numbers; returns the first.
    pub fn alloc_block(&self, n: u64) -> u64 {
        self.next.fetch_add(n, Ordering::Relaxed)
    }

    /// Sequence numbers handed out so far (block granularity).
    pub fn allocated(&self) -> u64 {
        self.next.load(Ordering::Relaxed)
    }
}

impl Default for SeqClock {
    fn default() -> Self {
        SeqClock::new()
    }
}

/// One writer thread's private ingest shard: an owned, unshared buffer
/// that seals full runs into the shared [`RunStore`]. `Send` (move it
/// into the thread that uses it), deliberately not `Sync` in spirit —
/// each thread holds its own.
///
/// Push cost is a `Vec` append plus, every [`SEQ_BLOCK`] records, one
/// shared fetch-add; every `run_capacity` records the buffer is
/// stably sorted and sealed (the seal is where the store's generation
/// clock serializes writers for the cross-writer ordering contract —
/// see the module docs).
pub struct ShardWriter {
    store: Arc<RunStore>,
    clock: Arc<SeqClock>,
    buf: Vec<WideRecord>,
    /// Next sequence number in the writer's current block.
    next_seq: u64,
    /// One past the last sequence number of the current block.
    seq_end: u64,
}

impl ShardWriter {
    /// A writer over `store` drawing sequence numbers from `clock`.
    /// All writers of one logical stream must share one clock.
    pub fn new(store: Arc<RunStore>, clock: Arc<SeqClock>) -> ShardWriter {
        let cap = store.config().run_capacity;
        ShardWriter { store, clock, buf: Vec::with_capacity(cap), next_seq: 0, seq_end: 0 }
    }

    fn alloc_seq(&mut self) -> u64 {
        if self.next_seq == self.seq_end {
            let start = self.clock.alloc_block(SEQ_BLOCK);
            self.next_seq = start;
            self.seq_end = start + SEQ_BLOCK;
        }
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    /// Ingest one `(key, payload)` record. Returns the sealed run's
    /// generation when this push filled the shard's buffer.
    ///
    /// The 64-bit sequence is split `(aux = seq >> 32,
    /// tag = seq_lo << 32 | payload)`; a `legacy_pages` store refuses
    /// sequences past the v1 packed-tag cap with
    /// [`StreamError::CapExceeded`].
    pub fn push(&mut self, key: i64, payload: u32) -> Result<Option<u64>, StreamError> {
        let seq = self.alloc_seq();
        if self.store.config().legacy_pages && seq >= (1u64 << 32) {
            return Err(StreamError::CapExceeded { seq });
        }
        let tag = ((seq & 0xFFFF_FFFF) << 32) | payload as u64;
        let aux = (seq >> 32) as u32;
        self.buf.push(WideRecord::new(Record::new(key, tag), aux));
        if self.buf.len() >= self.store.config().run_capacity {
            return self.seal();
        }
        Ok(None)
    }

    /// Records buffered in this shard (not yet sealed, not yet visible
    /// to scans).
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Seal whatever is buffered (possibly a partial run). `None` when
    /// the buffer was empty. Dropping a writer with pending records
    /// loses them — flush first (the coordinator's handle does this on
    /// its flush paths).
    pub fn flush(&mut self) -> Result<Option<u64>, StreamError> {
        if self.buf.is_empty() {
            return Ok(None);
        }
        self.seal()
    }

    /// The store this writer seals into.
    pub fn store(&self) -> &Arc<RunStore> {
        &self.store
    }

    fn seal(&mut self) -> Result<Option<u64>, StreamError> {
        let cap = self.store.config().run_capacity;
        let mut batch = std::mem::replace(&mut self.buf, Vec::with_capacity(cap));
        let t0 = crate::obs::trace::span_start();
        let n = batch.len();
        // Stable sort keeps push order within equal keys; the
        // generation the store stamps orders this run against every
        // other writer's seals.
        parallel_merge_sort(&mut batch, self.store.config().threads);
        let sealed = self.store.seal_wide(batch);
        crate::obs::trace::span_end(crate::obs::SpanKind::StreamSeal, t0, n as u64);
        sealed
    }
}

/// Process-wide writer-thread numbering (same shard-by-submitter trick
/// as `exec::injector`): each OS thread lazily takes a stable small
/// integer, so shard routing is one TLS read after the first push.
static WRITER_SEQ: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static WRITER_ID: Cell<usize> = Cell::new(usize::MAX);
}

fn writer_thread_id() -> usize {
    WRITER_ID.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            return v;
        }
        let v = WRITER_SEQ.fetch_add(1, Ordering::Relaxed);
        c.set(v);
        v
    })
}

/// A fixed set of [`ShardWriter`]s behind thread-id routing: any
/// thread may call [`WriterSet::push`] and lands on "its" shard
/// (`thread id & (shards - 1)`), so disjoint threads never contend on
/// a buffer. With at least as many shards as writer threads each mutex
/// is effectively uncontended; it exists to keep the routing safe when
/// threads outnumber shards.
///
/// All shards share one [`SeqClock`], so sequence numbers stay
/// globally unique across the set (and across any
/// [`WriterSet::owned_writer`] handed out).
pub struct WriterSet {
    store: Arc<RunStore>,
    clock: Arc<SeqClock>,
    shards: Vec<Mutex<ShardWriter>>,
    mask: usize,
}

impl WriterSet {
    /// A set of (at least) `shards` writer shards over `store`,
    /// rounded up to a power of two for mask routing.
    pub fn new(store: Arc<RunStore>, shards: usize) -> WriterSet {
        WriterSet::with_clock(store, shards, Arc::new(SeqClock::new()))
    }

    /// [`WriterSet::new`] with an explicit shared clock (tests, and
    /// tenants that also vend owned writers off the same sequence
    /// space).
    pub fn with_clock(store: Arc<RunStore>, shards: usize, clock: Arc<SeqClock>) -> WriterSet {
        let n = shards.max(1).next_power_of_two();
        let shards = (0..n)
            .map(|_| Mutex::new(ShardWriter::new(Arc::clone(&store), Arc::clone(&clock))))
            .collect();
        WriterSet { store, clock, shards, mask: n - 1 }
    }

    /// Number of shards (a power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shared sequence clock.
    pub fn clock(&self) -> &Arc<SeqClock> {
        &self.clock
    }

    /// Ingest one record on the calling thread's shard. Same contract
    /// as [`ShardWriter::push`].
    pub fn push(&self, key: i64, payload: u32) -> Result<Option<u64>, StreamError> {
        let idx = writer_thread_id() & self.mask;
        self.shards[idx].lock().unwrap().push(key, payload)
    }

    /// Records buffered across all shards.
    pub fn pending(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().pending()).sum()
    }

    /// Flush every shard's partial buffer; returns how many runs were
    /// sealed.
    pub fn flush_all(&self) -> Result<usize, StreamError> {
        let mut sealed = 0usize;
        for s in &self.shards {
            if s.lock().unwrap().flush()?.is_some() {
                sealed += 1;
            }
        }
        Ok(sealed)
    }

    /// A new owned [`ShardWriter`] sharing this set's store and clock —
    /// for threads that want zero routing overhead and exclusive
    /// buffer ownership.
    pub fn owned_writer(&self) -> ShardWriter {
        ShardWriter::new(Arc::clone(&self.store), Arc::clone(&self.clock))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::{compact_once, compact_to_one, scan_wide, StreamConfig};

    fn mem_store(cap: usize) -> Arc<RunStore> {
        Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: cap,
                fanout: 3,
                threads: 1,
                ..StreamConfig::default()
            })
            .unwrap(),
        )
    }

    /// Payload encoding for the oracle: writer in the high bits, the
    /// writer's push index in the low bits.
    fn payload(w: usize, i: usize) -> u32 {
        ((w as u32) << 24) | i as u32
    }

    /// The tentpole property test: N writer threads x M records with
    /// duplicate-heavy keys, checked at three compaction depths. The
    /// oracle: (1) scans are key-sorted and complete; (2) per-writer
    /// ingest order survives exactly — for every (writer, key) group
    /// the writer's push indices appear in push order; (3) sequence
    /// numbers are globally unique.
    #[test]
    fn multi_writer_oracle_across_compaction_depths() {
        let (writers, per_writer, cap) = if cfg!(miri) { (3, 8, 4) } else { (4, 200, 16) };
        // Depth 0: no compaction. Depth 1: policy-driven. Depth 2: full.
        for depth in 0..3 {
            let store = mem_store(cap);
            let set = Arc::new(WriterSet::new(Arc::clone(&store), writers));
            std::thread::scope(|s| {
                for w in 0..writers {
                    let set = Arc::clone(&set);
                    s.spawn(move || {
                        let mut sw = set.owned_writer();
                        for i in 0..per_writer {
                            // Duplicate-heavy: 5 distinct keys.
                            let key = ((w * 7 + i * 3) % 5) as i64;
                            sw.push(key, payload(w, i)).unwrap();
                        }
                        sw.flush().unwrap();
                    });
                }
            });
            match depth {
                0 => {}
                1 => {
                    while compact_once(&store, 1).unwrap().is_some() {}
                }
                _ => {
                    compact_to_one(&store, 1).unwrap();
                }
            }
            let scanned = scan_wide(&store).unwrap();
            assert_eq!(scanned.len(), writers * per_writer, "depth {depth}: complete");
            assert!(
                scanned.windows(2).all(|p| p[0].rec.key <= p[1].rec.key),
                "depth {depth}: key-sorted"
            );
            // Per-writer, per-key push order survives.
            let mut last_idx = vec![vec![-1i64; 5]; writers];
            for rec in &scanned {
                let p = (rec.rec.tag & 0xFFFF_FFFF) as u32;
                let (w, i) = ((p >> 24) as usize, (p & 0x00FF_FFFF) as i64);
                let k = rec.rec.key as usize;
                assert!(
                    last_idx[w][k] < i,
                    "depth {depth}: writer {w} key {k} pushed #{i} after #{}",
                    last_idx[w][k]
                );
                last_idx[w][k] = i;
            }
            // Sequence numbers are globally unique.
            let mut seqs: Vec<u64> = scanned.iter().map(|r| r.full_seq()).collect();
            seqs.sort_unstable();
            let n = seqs.len();
            seqs.dedup();
            assert_eq!(seqs.len(), n, "depth {depth}: duplicate sequence numbers");
        }
    }

    /// A solo writer's sequence is contiguous from 0 (the deprecated
    /// single-tenant facade's tag oracle depends on this), and the
    /// thread-id routing gives distinct threads distinct shards when
    /// shards >= threads.
    #[test]
    fn solo_writer_sequence_is_contiguous() {
        let store = mem_store(4);
        let clock = Arc::new(SeqClock::new());
        let mut w = ShardWriter::new(Arc::clone(&store), clock);
        for i in 0..10 {
            w.push(i % 3, i as u32).unwrap();
        }
        w.flush().unwrap();
        let mut seqs: Vec<u64> = scan_wide(&store).unwrap().iter().map(|r| r.full_seq()).collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (0..10).collect::<Vec<u64>>());
    }

    /// The 2^32 boundary: sequences crossing the old packed-tag cap
    /// keep working under the v2 format — the high bits land in the
    /// aux column, the reassembled sequence is exact, and the run
    /// reports itself wide.
    #[test]
    fn sequences_cross_the_u32_boundary() {
        let store = mem_store(32);
        let start = (1u64 << 32) - 8;
        let clock = Arc::new(SeqClock::with_first(start));
        let mut w = ShardWriter::new(Arc::clone(&store), clock);
        for i in 0..16 {
            w.push(0, i as u32).unwrap();
        }
        w.flush().unwrap();
        let snap = store.snapshot();
        assert!(snap[0].has_aux(), "post-boundary sequences need the aux column");
        let scanned = scan_wide(&store).unwrap();
        let seqs: Vec<u64> = scanned.iter().map(|r| r.full_seq()).collect();
        assert_eq!(
            seqs,
            (start..start + 16).collect::<Vec<u64>>(),
            "equal keys: scan order is push order, across the boundary"
        );
    }

    /// `legacy_pages` keeps the old contract: the cap is a typed error
    /// at the exact sequence that no longer fits.
    #[test]
    fn legacy_mode_caps_at_u32() {
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: 32,
                fanout: 3,
                threads: 1,
                legacy_pages: true,
                ..StreamConfig::default()
            })
            .unwrap(),
        );
        let clock = Arc::new(SeqClock::with_first((1u64 << 32) - 2));
        let mut w = ShardWriter::new(Arc::clone(&store), clock);
        w.push(1, 0).unwrap();
        w.push(2, 1).unwrap();
        match w.push(3, 2) {
            Err(StreamError::CapExceeded { seq }) => assert_eq!(seq, 1u64 << 32),
            other => panic!("expected CapExceeded, got {other:?}"),
        }
    }

    /// WriterSet routing: pushes from one thread land on one shard;
    /// flush_all drains every shard; pending sums across shards.
    #[test]
    fn writer_set_routes_and_flushes() {
        let store = mem_store(100);
        let set = WriterSet::new(Arc::clone(&store), 3);
        assert_eq!(set.shard_count(), 4, "rounded to a power of two");
        for i in 0..5 {
            set.push(i, i as u32).unwrap();
        }
        assert_eq!(set.pending(), 5, "all buffered on this thread's shard");
        assert_eq!(set.flush_all().unwrap(), 1, "one shard had records");
        assert_eq!(set.pending(), 0);
        assert_eq!(store.record_count(), 5);
        let scanned = scan_wide(&store).unwrap();
        assert!(scanned.windows(2).all(|p| p[0].rec.key <= p[1].rec.key));
    }
}

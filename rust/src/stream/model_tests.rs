//! Model-checked protocol tests for the stream store — compiled only
//! under `--features model` (see [`crate::model`] and the exec-side
//! suite in `exec::model_tests` for the conventions).
//!
//! The store's concurrency surface is small by design: one list lock,
//! one compaction-claim CAS, and lock-free stat counters. The tests
//! here check the two protocol-level promises the rest of the stream
//! layer leans on: the claim admits at most one compactor, and a
//! snapshot taken at ANY point of a racing compaction pins a
//! consistent, stable view (all records exactly once, equal keys in
//! generation order).

use super::run::Run;
use super::store::RunStore;
use super::StreamConfig;
use crate::core::record::Record;
use crate::model::thread;
use crate::model::{check_with, Config};
use std::sync::Arc;

fn mem_config() -> StreamConfig {
    StreamConfig { run_capacity: 16, fanout: 2, threads: 1, ..StreamConfig::default() }
}

/// Equal-key records tagged `tag0..tag0+n`: with every key identical,
/// stable order IS tag order, so stability violations are visible as
/// tag inversions.
fn recs(tag0: u64, n: u64) -> Vec<Record> {
    (tag0..tag0 + n).map(|t| Record::new(0, t)).collect()
}

/// The compaction claim: two racing claimers, at most one may win;
/// after a release the slot is claimable again.
#[test]
fn model_store_claim_exclusive() {
    let schedules = check_with(
        Config { name: "store-claim", ..Config::default() },
        || {
            let store = Arc::new(RunStore::new(mem_config()).unwrap());

            // Neither side releases until both tried: exactly one of
            // the two racing claims may succeed, in every schedule.
            let s1 = Arc::clone(&store);
            let t1 = thread::spawn(move || s1.try_claim_compaction());
            let here = store.try_claim_compaction();
            let there = t1.join().unwrap();

            assert!(here ^ there, "claim must admit exactly one (here={here}, there={there})");
            store.release_compaction();
            // The slot always comes back.
            assert!(store.try_claim_compaction());
            assert!(store.is_compacting());
            store.release_compaction();
            assert!(!store.is_compacting());
        },
    );
    assert!(schedules > 1, "the race must branch (got {schedules} schedule(s))");
}

/// Compaction claim vs snapshot pin: a compactor merges the
/// policy-picked window while a reader snapshots at an arbitrary
/// point. The snapshot must always be one of the two consistent states
/// (pre- or post-commit): every record exactly once, equal-key order =
/// seal order (ascending tags across the `gen_lo`-sorted runs), and
/// the pinned `Arc<Run>`s stay fully readable even after the commit
/// has swapped them out of the live list.
#[test]
fn model_store_compaction_vs_snapshot() {
    let schedules = check_with(
        Config { name: "store-compact-snapshot", ..Config::default() },
        || {
            let store = Arc::new(RunStore::new(mem_config()).unwrap());
            // Three equal-key runs, gens 0/1/2, tags 0..3, 3..6, 6..9.
            for i in 0..3u64 {
                store.seal(recs(i * 3, 3)).unwrap();
            }

            let cs = Arc::clone(&store);
            let compactor = thread::spawn(move || {
                assert!(cs.try_claim_compaction(), "claim is uncontended here");
                let window = cs.pick_window().expect("three runs yield a window");
                assert_eq!(window.len(), 2, "adjacent-pair default policy");
                // Stable merge of equal-key runs = generation order.
                let mut merged = Vec::new();
                for run in &window {
                    merged.extend(run.load().unwrap());
                }
                let prepared = Run::prepare(merged, Vec::new(), None, 1024, false).unwrap();
                let stats = cs.commit_compaction(&window, prepared).unwrap();
                cs.release_compaction();
                assert_eq!((stats.gen_lo, stats.gen_hi, stats.level), (0, 1, 1));
                // The inputs we still hold are pinned: fully readable
                // after the commit removed them from the live list.
                let pinned: usize = window.iter().map(|r| r.load().unwrap().len()).sum();
                assert_eq!(pinned, 6);
            });

            let ss = Arc::clone(&store);
            let snapshotter = thread::spawn(move || {
                let snap = ss.snapshot();
                // Pre-commit (3 runs) or post-commit (2 runs) — never
                // a torn in-between.
                assert!(
                    snap.len() == 2 || snap.len() == 3,
                    "snapshot saw {} runs",
                    snap.len()
                );
                // gen_lo-sorted, generation ranges disjoint + contiguous.
                let mut next_gen = 0;
                let mut tags = Vec::new();
                for run in &snap {
                    assert_eq!(run.gen_lo(), next_gen, "gen-sorted, gap-free");
                    next_gen = run.gen_hi() + 1;
                    tags.extend(run.load().unwrap().iter().map(|r| r.tag));
                }
                assert_eq!(next_gen, 3, "snapshot covers every sealed generation");
                // All nine records exactly once, in stable (seal) order.
                assert_eq!(tags, (0..9).collect::<Vec<u64>>(), "stability broken");
            });

            compactor.join().unwrap();
            snapshotter.join().unwrap();

            // Post-join: committed state, and the claim is free again.
            let snap = store.snapshot();
            assert_eq!(snap.len(), 2);
            assert_eq!(store.run_count(), 2);
            assert_eq!(store.record_count(), 9);
            assert!(store.try_claim_compaction());
            store.release_compaction();
        },
    );
    assert!(schedules > 1, "the race must branch (got {schedules} schedule(s))");
}

//! Append-only, checksummed store manifest — the durability spine of
//! the stream layer.
//!
//! The manifest (`MANIFEST.log` in the spill dir) is the single source
//! of truth for which run files are live. Every mutation of the run
//! list lands here **before** it is published to readers:
//!
//! - a seal appends [`ManifestRecord::AddRun`] and fsyncs, *then*
//!   inserts the run into the in-memory list;
//! - a compaction commit appends [`ManifestRecord::Replace`] (inputs
//!   removed, output added) and fsyncs, *then* swaps the window.
//!
//! Run files themselves are written and fsynced before their manifest
//! record, so a record never references bytes that might not survive a
//! crash. The converse — a run file with no manifest record — is an
//! **orphan** that recovery deletes.
//!
//! # Frame format
//!
//! ```text
//! file   = header frames*
//! header = magic "TMMANIF1" (8 B)
//! frame  = payload_len u32 LE ·· payload ·· fnv1a64(payload) u64 LE
//! ```
//!
//! A crash mid-append leaves a torn tail: a short frame, or a frame
//! whose checksum does not match. [`read_manifest`] stops at the first
//! such frame and returns everything before it — the torn record was
//! never published (publication happens after fsync), so dropping it
//! is exactly correct. Recovery then rewrites a compact manifest via
//! temp-file + rename.

use crate::util::fnv1a64;
use std::io::{Read, Write};
use std::path::Path;

/// Manifest header magic.
pub const MANIFEST_MAGIC: &[u8; 8] = b"TMMANIF1";
/// Manifest file name within a store's spill dir.
pub const MANIFEST_NAME: &str = "MANIFEST.log";

/// Everything recovery needs to reopen a run without touching its
/// record pages: identity, generation range, level, and the metadata
/// that is cross-checked against the run file itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RunMeta {
    /// Spill-file id: the run lives at `run-{id}.bin`.
    pub id: u64,
    /// Oldest seal generation covered.
    pub gen_lo: u64,
    /// Newest seal generation covered.
    pub gen_hi: u64,
    /// Compaction depth.
    pub level: u32,
    /// Record count.
    pub len: u64,
    /// Smallest key.
    pub min_key: i64,
    /// Largest key.
    pub max_key: i64,
}

/// Bytes of an encoded [`RunMeta`].
pub const RUN_META_BYTES: usize = 52;

fn encode_run_meta(m: &RunMeta, out: &mut Vec<u8>) {
    out.extend_from_slice(&m.id.to_le_bytes());
    out.extend_from_slice(&m.gen_lo.to_le_bytes());
    out.extend_from_slice(&m.gen_hi.to_le_bytes());
    out.extend_from_slice(&m.level.to_le_bytes());
    out.extend_from_slice(&m.len.to_le_bytes());
    out.extend_from_slice(&m.min_key.to_le_bytes());
    out.extend_from_slice(&m.max_key.to_le_bytes());
}

fn decode_run_meta(bytes: &[u8]) -> Result<RunMeta, String> {
    if bytes.len() < RUN_META_BYTES {
        return Err(format!("run meta is {} bytes, expected {RUN_META_BYTES}", bytes.len()));
    }
    let u64_at = |o: usize| {
        let mut b = [0u8; 8];
        b.copy_from_slice(&bytes[o..o + 8]);
        u64::from_le_bytes(b)
    };
    let mut l = [0u8; 4];
    l.copy_from_slice(&bytes[24..28]);
    Ok(RunMeta {
        id: u64_at(0),
        gen_lo: u64_at(8),
        gen_hi: u64_at(16),
        level: u32::from_le_bytes(l),
        len: u64_at(28),
        min_key: u64_at(36) as i64,
        max_key: u64_at(44) as i64,
    })
}

/// One manifest mutation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ManifestRecord {
    /// A freshly sealed run joined the store.
    AddRun(RunMeta),
    /// A compaction replaced `removed` (run ids, oldest first) with
    /// `added`.
    Replace { removed: Vec<u64>, added: RunMeta },
}

const TAG_ADD: u8 = 1;
const TAG_REPLACE: u8 = 2;

/// Encode one record's frame payload (no length/checksum). Pure —
/// unit-tested under Miri.
pub fn encode_record(rec: &ManifestRecord) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + RUN_META_BYTES);
    match rec {
        ManifestRecord::AddRun(meta) => {
            out.push(TAG_ADD);
            encode_run_meta(meta, &mut out);
        }
        ManifestRecord::Replace { removed, added } => {
            out.push(TAG_REPLACE);
            out.extend_from_slice(&(removed.len() as u32).to_le_bytes());
            for id in removed {
                out.extend_from_slice(&id.to_le_bytes());
            }
            encode_run_meta(added, &mut out);
        }
    }
    out
}

/// Decode one frame payload. Pure.
pub fn decode_record(bytes: &[u8]) -> Result<ManifestRecord, String> {
    match bytes.first() {
        Some(&TAG_ADD) => {
            if bytes.len() != 1 + RUN_META_BYTES {
                return Err(format!("add-run payload is {} bytes", bytes.len()));
            }
            Ok(ManifestRecord::AddRun(decode_run_meta(&bytes[1..])?))
        }
        Some(&TAG_REPLACE) => {
            if bytes.len() < 5 {
                return Err("replace payload truncated".to_string());
            }
            let mut c = [0u8; 4];
            c.copy_from_slice(&bytes[1..5]);
            let count = u32::from_le_bytes(c) as usize;
            let need = 5 + count * 8 + RUN_META_BYTES;
            if bytes.len() != need {
                return Err(format!(
                    "replace payload is {} bytes, {count} removed ids imply {need}",
                    bytes.len()
                ));
            }
            let mut removed = Vec::with_capacity(count);
            for i in 0..count {
                let mut b = [0u8; 8];
                b.copy_from_slice(&bytes[5 + i * 8..5 + (i + 1) * 8]);
                removed.push(u64::from_le_bytes(b));
            }
            let added = decode_run_meta(&bytes[5 + count * 8..])?;
            Ok(ManifestRecord::Replace { removed, added })
        }
        Some(&t) => Err(format!("unknown manifest record tag {t}")),
        None => Err("empty manifest payload".to_string()),
    }
}

/// Frame a payload: `len u32 ·· payload ·· fnv1a64(payload)`. Pure.
pub fn encode_frame(rec: &ManifestRecord) -> Vec<u8> {
    let payload = encode_record(rec);
    let mut out = Vec::with_capacity(12 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    out
}

/// Decode a sequence of frames, stopping silently at the first torn
/// one (short frame or checksum mismatch). Returns the records and how
/// many bytes of `bytes` were consumed by intact frames. Pure.
pub fn decode_frames(bytes: &[u8]) -> (Vec<ManifestRecord>, usize) {
    let mut records = Vec::new();
    let mut pos = 0;
    loop {
        if bytes.len() - pos < 4 {
            break;
        }
        let mut l = [0u8; 4];
        l.copy_from_slice(&bytes[pos..pos + 4]);
        let payload_len = u32::from_le_bytes(l) as usize;
        if bytes.len() - pos < 4 + payload_len + 8 {
            break; // torn tail: frame extends past EOF
        }
        let payload = &bytes[pos + 4..pos + 4 + payload_len];
        let mut c = [0u8; 8];
        c.copy_from_slice(&bytes[pos + 4 + payload_len..pos + 12 + payload_len]);
        if fnv1a64(payload) != u64::from_le_bytes(c) {
            break; // torn tail: partially written payload
        }
        match decode_record(payload) {
            Ok(rec) => records.push(rec),
            Err(_) => break, // checksummed but unparseable: stop, don't guess
        }
        pos += 12 + payload_len;
    }
    (records, pos)
}

/// Read a manifest file, tolerating a torn tail. A missing header is
/// an error (the file is not a manifest); a torn or trailing-garbage
/// tail is not (the crash case this format exists for).
pub fn read_manifest(path: &Path) -> Result<Vec<ManifestRecord>, String> {
    let mut file =
        std::fs::File::open(path).map_err(|e| format!("open {}: {e}", path.display()))?;
    let mut bytes = Vec::new();
    file.read_to_end(&mut bytes)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    if bytes.len() < 8 || &bytes[..8] != MANIFEST_MAGIC {
        return Err(format!("{}: not a manifest (bad magic)", path.display()));
    }
    Ok(decode_frames(&bytes[8..]).0)
}

/// Fold a record log into the list of live runs, in log order: adds
/// append, replaces remove-by-id then append.
pub fn replay(records: &[ManifestRecord]) -> Vec<RunMeta> {
    let mut live: Vec<RunMeta> = Vec::new();
    for rec in records {
        match rec {
            ManifestRecord::AddRun(meta) => live.push(*meta),
            ManifestRecord::Replace { removed, added } => {
                live.retain(|m| !removed.contains(&m.id));
                live.push(*added);
            }
        }
    }
    live
}

/// Appender over an open manifest. Every append is fsynced before it
/// returns — callers publish the mutation to readers only afterwards.
pub struct ManifestWriter {
    file: std::fs::File,
}

impl ManifestWriter {
    /// Create (truncate) a fresh manifest: header only, fsynced.
    pub fn create(path: &Path) -> Result<ManifestWriter, String> {
        let mut file =
            std::fs::File::create(path).map_err(|e| format!("create {}: {e}", path.display()))?;
        file.write_all(MANIFEST_MAGIC)
            .map_err(|e| format!("write header {}: {e}", path.display()))?;
        file.sync_all().map_err(|e| format!("fsync {}: {e}", path.display()))?;
        Ok(ManifestWriter { file })
    }

    /// Open an existing manifest for appending (recovery path; the
    /// caller has already validated/rewritten the contents).
    pub fn open_append(path: &Path) -> Result<ManifestWriter, String> {
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .map_err(|e| format!("open {}: {e}", path.display()))?;
        Ok(ManifestWriter { file })
    }

    /// Append one record and fsync it.
    pub fn append(&mut self, rec: &ManifestRecord) -> Result<(), String> {
        let t0 = crate::obs::trace::span_start();
        self.file
            .write_all(&encode_frame(rec))
            .map_err(|e| format!("manifest append: {e}"))?;
        self.file.sync_data().map_err(|e| format!("manifest fsync: {e}"))?;
        crate::obs::trace::span_end(crate::obs::SpanKind::ManifestFsync, t0, 0);
        Ok(())
    }
}

/// Atomically replace the manifest with a compact one holding exactly
/// `live` (recovery's post-replay rewrite): write `MANIFEST.tmp`,
/// fsync, rename over the old file, best-effort fsync the directory.
pub fn rewrite(path: &Path, live: &[RunMeta]) -> Result<(), String> {
    let tmp = path.with_extension("tmp");
    {
        let mut w = ManifestWriter::create(&tmp)?;
        for meta in live {
            w.append(&ManifestRecord::AddRun(*meta))?;
        }
    }
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(id: u64) -> RunMeta {
        RunMeta {
            id,
            gen_lo: id * 2,
            gen_hi: id * 2 + 1,
            level: id as u32 % 3,
            len: 100 + id,
            min_key: -(id as i64),
            max_key: id as i64 * 10,
        }
    }

    // ---- pure codec tests (run under Miri) --------------------------

    #[test]
    fn record_roundtrip() {
        let add = ManifestRecord::AddRun(meta(7));
        assert_eq!(decode_record(&encode_record(&add)).unwrap(), add);
        let rep = ManifestRecord::Replace { removed: vec![1, 2, 5], added: meta(9) };
        assert_eq!(decode_record(&encode_record(&rep)).unwrap(), rep);
        let none = ManifestRecord::Replace { removed: vec![], added: meta(0) };
        assert_eq!(decode_record(&encode_record(&none)).unwrap(), none);
        assert!(decode_record(&[]).is_err());
        assert!(decode_record(&[99, 0, 0]).is_err());
        let mut short = encode_record(&add);
        short.pop();
        assert!(decode_record(&short).is_err());
    }

    #[test]
    fn frames_roundtrip_and_tolerate_torn_tail() {
        let recs = vec![
            ManifestRecord::AddRun(meta(0)),
            ManifestRecord::AddRun(meta(1)),
            ManifestRecord::Replace { removed: vec![0, 1], added: meta(2) },
        ];
        let mut bytes = Vec::new();
        for r in &recs {
            bytes.extend_from_slice(&encode_frame(r));
        }
        let intact = bytes.len();
        let (back, used) = decode_frames(&bytes);
        assert_eq!(back, recs);
        assert_eq!(used, intact);

        // Torn tail: a partially written fourth frame is dropped.
        let mut torn = bytes.clone();
        let frame = encode_frame(&ManifestRecord::AddRun(meta(3)));
        torn.extend_from_slice(&frame[..frame.len() - 5]);
        let (back, used) = decode_frames(&torn);
        assert_eq!(back, recs);
        assert_eq!(used, intact);

        // Corrupt payload byte in the tail frame: checksum rejects it.
        let mut corrupt = bytes.clone();
        corrupt.extend_from_slice(&frame);
        let flip = intact + 6; // inside the fourth frame's payload
        corrupt[flip] ^= 0x10;
        let (back, _) = decode_frames(&corrupt);
        assert_eq!(back, recs);

        // Garbage tail that cannot even frame.
        let mut junk = bytes;
        junk.extend_from_slice(&[0xFF, 0xFF]);
        let (back, used) = decode_frames(&junk);
        assert_eq!(back, recs);
        assert_eq!(used, intact);
    }

    #[test]
    fn replay_folds_adds_and_replaces() {
        let live = replay(&[
            ManifestRecord::AddRun(meta(0)),
            ManifestRecord::AddRun(meta(1)),
            ManifestRecord::AddRun(meta(2)),
            ManifestRecord::Replace { removed: vec![0, 1], added: meta(3) },
        ]);
        let ids: Vec<u64> = live.iter().map(|m| m.id).collect();
        assert_eq!(ids, vec![2, 3]);
    }

    // ---- filesystem tests -------------------------------------------

    #[test]
    #[cfg(not(miri))]
    fn write_read_append_rewrite() {
        let dir = std::env::temp_dir().join(format!("traff-manifest-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(MANIFEST_NAME);
        {
            let mut w = ManifestWriter::create(&path).unwrap();
            w.append(&ManifestRecord::AddRun(meta(0))).unwrap();
            w.append(&ManifestRecord::AddRun(meta(1))).unwrap();
        }
        {
            let mut w = ManifestWriter::open_append(&path).unwrap();
            w.append(&ManifestRecord::Replace { removed: vec![0, 1], added: meta(2) }).unwrap();
        }
        let recs = read_manifest(&path).unwrap();
        assert_eq!(recs.len(), 3);
        let live = replay(&recs);
        assert_eq!(live, vec![meta(2)]);

        // Rewrite compacts to the live set only.
        rewrite(&path, &live).unwrap();
        let recs = read_manifest(&path).unwrap();
        assert_eq!(recs, vec![ManifestRecord::AddRun(meta(2))]);
        assert!(!path.with_extension("tmp").exists());

        // A non-manifest file is an error, not an empty log.
        let bogus = dir.join("bogus");
        std::fs::write(&bogus, b"what even is this").unwrap();
        assert!(read_manifest(&bogus).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! The leveled run store: live [`Run`]s plus the lock-free bookkeeping
//! around them.
//!
//! # Structure
//!
//! The store holds the live runs in one `Mutex<Vec<Arc<Run>>>` kept
//! **sorted by `gen_lo`** — the short-held lock covers only list
//! surgery (a seal's insert, a compaction's two-out-one-in swap) and
//! snapshot clones; record data never moves under it. Everything a
//! concurrent reader or telemetry probe needs is published in
//! **lock-free state** next to the list:
//!
//! - the **generation clock** (`next_gen`, a fetch-add): every seal
//!   takes a unique, monotone generation number — the stability order
//!   across runs. Allocation happens *inside* the seal's list-lock
//!   critical section (insertion and numbering are atomic together;
//!   see [`RunStore::seal`]), but the counter stays an atomic so
//!   telemetry can read it lock-free;
//! - published counters (`live_runs`, `live_records`, `sealed_runs`,
//!   `compactions`, `spilled_runs`): the backlog/progress signals the
//!   compaction trigger and the CLI read without taking the list lock;
//! - the **compaction claim** (`compacting`, a CAS flag): at most one
//!   compaction plans/commits at a time, claimed and released without
//!   blocking anyone (losers simply skip — the same try-flag shape as
//!   the executor's window roll).
//!
//! # The adjacency invariant (stability)
//!
//! Scans order runs by `gen_lo` and resolve equal keys to the earlier
//! run. For that order to equal ingest order, the generation ranges of
//! live runs must stay **pairwise disjoint and totally ordered** —
//! which holds inductively: seals append fresh maximal generations,
//! and the pair picker (`pick_adjacent_pair`) only offers runs
//! *adjacent in the `gen_lo`-sorted list* for compaction (no third
//! run's range can sit between the pair's), so the merged run's union range slots back
//! into the same total order. Merging a NON-adjacent pair would break
//! this: a key duplicated in runs `g0`, `g1`, `g2` with `g0`+`g2`
//! merged (range `[g0, g2]`, sorted before `g1`) would put `g2`'s copy
//! ahead of `g1`'s on scan.
//!
//! Readers take [`RunStore::snapshot`] clones of the `Arc` list;
//! a compaction commits by swapping the list under the lock, so an
//! in-flight scan keeps its pre-compaction runs alive and sees a
//! consistent (if slightly stale) view — reads-before-compaction
//! semantics.

use super::run::Run;
use super::StreamConfig;
use crate::core::record::Record;
use crate::model::sync::{AtomicBool, AtomicU64, Mutex, Ordering};
use std::sync::Arc;

/// Point-in-time store statistics (folded from the published atomics
/// plus one short lock for the level map).
#[derive(Clone, Debug, Default)]
pub struct StoreStats {
    /// Live runs right now.
    pub runs: usize,
    /// Live records right now (invariant under compaction).
    pub records: u64,
    /// Runs sealed over the store's lifetime.
    pub sealed_runs: u64,
    /// Compactions committed over the store's lifetime.
    pub compactions: u64,
    /// Compaction attempts that failed (e.g. spill I/O errors) — a
    /// growing value with a growing `runs` backlog means the store
    /// can no longer compact and needs operator attention.
    pub compaction_failures: u64,
    /// Live runs currently spilled to disk.
    pub spilled_runs: u64,
    /// Deepest live compaction level.
    pub max_level: u32,
}

/// Outcome of one committed compaction (see [`super::compact`]).
#[derive(Clone, Debug)]
pub struct CompactionStats {
    /// Records in the merged output run.
    pub merged_records: usize,
    /// Level of the merged run (`max(inputs) + 1`).
    pub level: u32,
    /// Generation range the merged run covers.
    pub gen_lo: u64,
    /// Generation range the merged run covers.
    pub gen_hi: u64,
}

/// The leveled run store. See the module docs.
pub struct RunStore {
    config: StreamConfig,
    /// Live runs, sorted by `gen_lo`. Short-held lock; see module docs.
    runs: Mutex<Vec<Arc<Run>>>,
    /// Generation clock (unique, monotone seal numbers); bumped only
    /// inside [`RunStore::seal`]'s critical section, read lock-free.
    next_gen: AtomicU64,
    live_runs: AtomicU64,
    live_records: AtomicU64,
    sealed_runs: AtomicU64,
    compactions: AtomicU64,
    compaction_failures: AtomicU64,
    spilled_runs: AtomicU64,
    /// Compaction claim: CAS-held by at most one compactor at a time.
    compacting: AtomicBool,
}

impl RunStore {
    /// Build a store; creates the spill directory when one is
    /// configured.
    pub fn new(config: StreamConfig) -> Result<RunStore, String> {
        if let Some(dir) = &config.spill {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("spill dir {}: {e}", dir.display()))?;
        }
        Ok(RunStore {
            config,
            runs: Mutex::new(Vec::new()),
            next_gen: AtomicU64::new(0),
            live_runs: AtomicU64::new(0),
            live_records: AtomicU64::new(0),
            sealed_runs: AtomicU64::new(0),
            compactions: AtomicU64::new(0),
            compaction_failures: AtomicU64::new(0),
            spilled_runs: AtomicU64::new(0),
            compacting: AtomicBool::new(false),
        })
    }

    /// The configuration the store (and its tenant ingestors /
    /// compactors) runs under.
    pub fn config(&self) -> &StreamConfig {
        &self.config
    }

    /// Seal a sorted record batch into a fresh level-0 run; returns
    /// its generation, or `None` for an empty batch. Spills when the
    /// store has a spill dir.
    ///
    /// The spill write (the slow part) happens BEFORE the list lock;
    /// the generation is allocated and the run inserted *under* it.
    /// Allocating the generation first (outside the lock) would let a
    /// stalled seal insert an old generation after a compaction
    /// merged past it — overlapping ranges, stability broken — so
    /// generation allocation and insertion are one critical section.
    /// Fresh generations are therefore maximal and the list stays
    /// `gen_lo`-sorted by construction.
    pub fn seal(&self, records: Vec<Record>) -> Result<Option<u64>, String> {
        if records.is_empty() {
            return Ok(None);
        }
        let len = records.len() as u64;
        let prepared = Run::prepare(records, self.config.spill.as_deref())?;
        if prepared.is_spilled() {
            self.spilled_runs.fetch_add(1, Ordering::Relaxed);
        }
        let gen = {
            let mut runs = self.runs.lock().unwrap();
            let gen = self.next_gen.fetch_add(1, Ordering::Relaxed);
            runs.push(Arc::new(prepared.into_run(gen, gen, 0)));
            gen
        };
        self.live_runs.fetch_add(1, Ordering::Relaxed);
        self.live_records.fetch_add(len, Ordering::Relaxed);
        self.sealed_runs.fetch_add(1, Ordering::Relaxed);
        Ok(Some(gen))
    }

    /// Clone the live run list (sorted by `gen_lo`). The `Arc`s keep
    /// the snapshot's runs alive across concurrent compactions.
    pub fn snapshot(&self) -> Vec<Arc<Run>> {
        self.runs.lock().unwrap().clone()
    }

    /// Live run count, from the published counter (lock-free).
    pub fn run_count(&self) -> usize {
        self.live_runs.load(Ordering::Relaxed) as usize
    }

    /// Live record count, from the published counter (lock-free).
    pub fn record_count(&self) -> u64 {
        self.live_records.load(Ordering::Relaxed)
    }

    /// Whether the backlog exceeds the configured fanout — the
    /// compaction trigger, readable without the list lock.
    pub fn needs_compaction(&self) -> bool {
        self.run_count() > self.config.fanout.max(1)
    }

    /// Fold the published counters (plus one short lock for the level
    /// scan) into a [`StoreStats`].
    pub fn stats(&self) -> StoreStats {
        let max_level =
            self.runs.lock().unwrap().iter().map(|r| r.level()).max().unwrap_or(0);
        StoreStats {
            runs: self.run_count(),
            records: self.record_count(),
            sealed_runs: self.sealed_runs.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            compaction_failures: self.compaction_failures.load(Ordering::Relaxed),
            spilled_runs: self.spilled_runs.load(Ordering::Relaxed),
            max_level,
        }
    }

    /// Record a failed compaction attempt (surfaced via
    /// [`StoreStats::compaction_failures`]); the backlog the failure
    /// left behind is what the next trigger retries.
    pub fn note_compaction_failure(&self) {
        self.compaction_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Try to claim the (single) compaction slot. Non-blocking: `false`
    /// means another compactor holds it — skip, don't wait.
    pub(crate) fn try_claim_compaction(&self) -> bool {
        self.compacting
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    /// Release the compaction claim.
    pub(crate) fn release_compaction(&self) {
        self.compacting.store(false, Ordering::Release);
    }

    /// Whether a compaction currently holds the claim.
    pub fn is_compacting(&self) -> bool {
        self.compacting.load(Ordering::Relaxed)
    }

    /// Pick the compaction pair: among the ADJACENT pairs of the
    /// `gen_lo`-sorted live list (the only stability-safe candidates —
    /// see the module docs), prefer the smallest-combined-length pair
    /// whose key ranges overlap; with no overlapping pair, the
    /// smallest pair outright (still correct, it just degenerates to
    /// concatenation-by-merge). `None` with fewer than two runs.
    ///
    /// Caller must hold the compaction claim: the returned runs stay
    /// adjacent because only the claim holder removes runs and seals
    /// only append maximal generations.
    pub(crate) fn pick_adjacent_pair(&self) -> Option<(Arc<Run>, Arc<Run>)> {
        let runs = self.runs.lock().unwrap();
        if runs.len() < 2 {
            return None;
        }
        let mut best: Option<(usize, usize, bool)> = None; // (index, combined, overlaps)
        for i in 0..runs.len() - 1 {
            let combined = runs[i].len() + runs[i + 1].len();
            let overlaps = runs[i].overlaps(&runs[i + 1]);
            let better = match best {
                None => true,
                // Overlap beats no-overlap; then smaller combined size.
                Some((_, bc, bo)) => (overlaps, std::cmp::Reverse(combined))
                    > (bo, std::cmp::Reverse(bc)),
            };
            if better {
                best = Some((i, combined, overlaps));
            }
        }
        let (i, _, _) = best?;
        Some((Arc::clone(&runs[i]), Arc::clone(&runs[i + 1])))
    }

    /// Commit a compaction: replace the adjacent pair `(a, b)` with
    /// the merged run (level `max + 1`, generation range
    /// `[a.gen_lo, b.gen_hi]`). Caller must hold the compaction claim
    /// and `merged` must be the stable merge of the pair (older run's
    /// records first on ties).
    pub(crate) fn commit_compaction(
        &self,
        a: &Arc<Run>,
        b: &Arc<Run>,
        merged: Vec<Record>,
    ) -> Result<CompactionStats, String> {
        debug_assert_eq!(merged.len(), a.len() + b.len());
        let level = a.level().max(b.level()) + 1;
        let (gen_lo, gen_hi) = (a.gen_lo(), b.gen_hi());
        let merged_records = merged.len();
        let run =
            Arc::new(Run::create(merged, gen_lo, gen_hi, level, self.config.spill.as_deref())?);
        let spilled_delta: i64 = run.is_spilled() as i64
            - a.is_spilled() as i64
            - b.is_spilled() as i64;
        {
            let mut runs = self.runs.lock().unwrap();
            let pos = runs
                .iter()
                .position(|r| Arc::ptr_eq(r, a))
                .ok_or_else(|| "compaction input vanished from the store".to_string())?;
            if pos + 1 >= runs.len() || !Arc::ptr_eq(&runs[pos + 1], b) {
                return Err("compaction pair no longer adjacent".to_string());
            }
            runs[pos] = run;
            runs.remove(pos + 1);
        }
        self.live_runs.fetch_sub(1, Ordering::Relaxed);
        self.compactions.fetch_add(1, Ordering::Relaxed);
        if spilled_delta > 0 {
            self.spilled_runs.fetch_add(spilled_delta as u64, Ordering::Relaxed);
        } else if spilled_delta < 0 {
            self.spilled_runs.fetch_sub((-spilled_delta) as u64, Ordering::Relaxed);
        }
        Ok(CompactionStats { merged_records, level, gen_lo, gen_hi })
    }
}

impl Drop for RunStore {
    fn drop(&mut self) {
        if let Some(dir) = &self.config.spill {
            // Drop the runs first (each deletes its spill file), then
            // best-effort remove the now-empty dir. Outstanding
            // snapshot Arcs may keep files alive; the remove simply
            // fails then.
            self.runs.lock().unwrap().clear();
            let _ = std::fs::remove_dir(dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recs(keys: &[i64], tag0: u64) -> Vec<Record> {
        keys.iter().enumerate().map(|(i, &k)| Record::new(k, tag0 + i as u64)).collect()
    }

    fn mem_store() -> RunStore {
        RunStore::new(StreamConfig {
            run_capacity: 16,
            fanout: 2,
            threads: 1,
            spill: None,
        })
        .unwrap()
    }

    #[test]
    fn seal_assigns_monotone_generations_and_counts() {
        let store = mem_store();
        assert_eq!(store.seal(Vec::new()).unwrap(), None, "empty batch seals nothing");
        let g0 = store.seal(recs(&[1, 3], 0)).unwrap().unwrap();
        let g1 = store.seal(recs(&[2, 2, 4], 10)).unwrap().unwrap();
        assert!(g1 > g0);
        assert_eq!(store.run_count(), 2);
        assert_eq!(store.record_count(), 5);
        let stats = store.stats();
        assert_eq!((stats.runs, stats.records, stats.sealed_runs), (2, 5, 2));
        assert_eq!((stats.compactions, stats.spilled_runs, stats.max_level), (0, 0, 0));
        // Snapshot is gen-sorted.
        let snap = store.snapshot();
        assert_eq!(snap.len(), 2);
        assert!(snap[0].gen_lo() < snap[1].gen_lo());
    }

    /// The lock-free generation clock hands out unique generations
    /// under concurrent seals, and the published counters converge
    /// (the Miri target: this is the store's lock-free state).
    #[test]
    fn concurrent_seals_get_unique_generations() {
        let store = std::sync::Arc::new(mem_store());
        let per_thread = if cfg!(miri) { 4 } else { 64 };
        let threads = 2;
        std::thread::scope(|s| {
            for t in 0..threads {
                let store = std::sync::Arc::clone(&store);
                s.spawn(move || {
                    for i in 0..per_thread {
                        let key = (t * per_thread + i) as i64;
                        store.seal(recs(&[key], 0)).unwrap().unwrap();
                    }
                });
            }
        });
        let total = threads * per_thread;
        assert_eq!(store.run_count(), total);
        assert_eq!(store.record_count(), total as u64);
        let snap = store.snapshot();
        let mut gens: Vec<u64> = snap.iter().map(|r| r.gen_lo()).collect();
        let sorted = gens.clone();
        gens.sort_unstable();
        gens.dedup();
        assert_eq!(gens.len(), total, "generations must be unique");
        assert_eq!(sorted, gens, "snapshot must be gen-sorted");
    }

    /// The compaction claim is exclusive and releasable — the CAS
    /// protocol the compactor relies on.
    #[test]
    fn compaction_claim_is_exclusive() {
        let store = mem_store();
        assert!(!store.is_compacting());
        assert!(store.try_claim_compaction());
        assert!(store.is_compacting());
        assert!(!store.try_claim_compaction(), "second claim must lose");
        store.release_compaction();
        assert!(store.try_claim_compaction());
        store.release_compaction();
    }

    #[test]
    fn pick_prefers_overlapping_adjacent_pair() {
        let store = mem_store();
        // Runs 0 and 1 are disjoint; runs 1 and 2 overlap.
        store.seal(recs(&[0, 5], 0)).unwrap();
        store.seal(recs(&[10, 20], 0)).unwrap();
        store.seal(recs(&[15, 30], 0)).unwrap();
        assert!(store.try_claim_compaction());
        let (a, b) = store.pick_adjacent_pair().expect("three runs yield a pair");
        assert_eq!((a.gen_lo(), b.gen_lo()), (1, 2), "overlapping pair preferred");
        store.release_compaction();
    }

    #[test]
    fn commit_replaces_adjacent_pair_and_keeps_records() {
        let store = mem_store();
        store.seal(recs(&[1, 4], 0)).unwrap();
        store.seal(recs(&[2, 3], 10)).unwrap();
        store.seal(recs(&[9], 20)).unwrap();
        assert!(store.try_claim_compaction());
        let snap = store.snapshot();
        let (a, b) = (std::sync::Arc::clone(&snap[0]), std::sync::Arc::clone(&snap[1]));
        // Stable merge of the pair by hand.
        let merged = recs(&[1, 2, 3, 4], 0)
            .into_iter()
            .zip([0u64, 10, 11, 1])
            .map(|(r, tag)| Record::new(r.key, tag))
            .collect();
        let st = store.commit_compaction(&a, &b, merged).unwrap();
        store.release_compaction();
        assert_eq!((st.merged_records, st.level), (4, 1));
        assert_eq!((st.gen_lo, st.gen_hi), (0, 1));
        assert_eq!(store.run_count(), 2);
        assert_eq!(store.record_count(), 5, "compaction preserves record count");
        let snap = store.snapshot();
        assert_eq!(snap[0].gen_lo(), 0);
        assert_eq!(snap[0].gen_hi(), 1);
        assert_eq!(snap[0].level(), 1);
        assert_eq!(snap[1].gen_lo(), 2);
        let stats = store.stats();
        assert_eq!((stats.compactions, stats.max_level), (1, 1));
    }

    #[test]
    fn needs_compaction_tracks_fanout() {
        let store = mem_store(); // fanout 2
        store.seal(recs(&[1], 0)).unwrap();
        store.seal(recs(&[2], 0)).unwrap();
        assert!(!store.needs_compaction());
        store.seal(recs(&[3], 0)).unwrap();
        assert!(store.needs_compaction());
    }
}

//! The leveled run store: live [`Run`]s, the lock-free bookkeeping
//! around them, and the durability spine (manifest + recovery).
//!
//! # Structure
//!
//! The store holds the live runs in one `Mutex<Vec<Arc<Run>>>` kept
//! **sorted by `gen_lo`** — the short-held lock covers only list
//! surgery (a seal's insert, a compaction's window swap) and snapshot
//! clones; record data never moves under it. Everything a concurrent
//! reader or telemetry probe needs is published in **lock-free state**
//! next to the list:
//!
//! - the **generation clock** (`next_gen`, a fetch-add): every seal
//!   takes a unique, monotone generation number — the stability order
//!   across runs. Allocation happens *inside* the seal's list-lock
//!   critical section (insertion and numbering are atomic together;
//!   see [`RunStore::seal`]), but the counter stays an atomic so
//!   telemetry can read it lock-free;
//! - published counters (`live_runs`, `live_records`, `sealed_runs`,
//!   `compactions`, `spilled_runs`): the backlog/progress signals the
//!   compaction trigger and the CLI read without taking the list lock;
//! - the **compaction claim** (`compacting`, a CAS flag): at most one
//!   compaction plans/commits at a time, claimed and released without
//!   blocking anyone (losers simply skip — the same try-flag shape as
//!   the executor's window roll).
//!
//! # The contiguity invariant (stability)
//!
//! Scans order runs by `gen_lo` and resolve equal keys to the earlier
//! run. For that order to equal ingest order, the generation ranges of
//! live runs must stay **pairwise disjoint and totally ordered** —
//! which holds inductively: seals append fresh maximal generations,
//! and every [`super::policy::CompactionPolicy`] returns a window of
//! runs *contiguous in the `gen_lo`-sorted list* (no third run's range
//! can sit between two window members), so the merged run's union
//! range slots back into the same total order. Merging a
//! NON-contiguous set would break this: a key duplicated in runs `g0`,
//! `g1`, `g2` with `g0`+`g2` merged (range `[g0, g2]`, sorted before
//! `g1`) would put `g2`'s copy ahead of `g1`'s on scan.
//!
//! Readers take [`RunStore::snapshot`] clones of the `Arc` list; a
//! compaction commits by swapping the list under the lock, so an
//! in-flight scan keeps its pre-compaction runs alive (their spill
//! files pinned through open fds even after unlink) and sees a
//! consistent (if slightly stale) view.
//!
//! # Durability (spilled stores only)
//!
//! When the store has a spill dir it also keeps an append-only,
//! checksummed **manifest** ([`super::manifest`]) — the source of
//! truth for which run files are live. The write protocol is
//! fsync-before-publish, in two layers: a run file is fully written
//! and fsynced *before* its manifest record is appended, and the
//! manifest record is fsynced *before* the run is inserted into (or a
//! window swapped out of) the in-memory list. A crash therefore leaves
//! at worst (a) orphan run files never referenced by the manifest and
//! (b) a torn final manifest record — both of which
//! [`RunStore::recover`] discards, reconstructing exactly the last
//! published state. Lock order is always runs-list, then manifest.
//!
//! Counter caveat: lifetime counters (`sealed_runs`, `compactions`)
//! are not persisted; recovery re-seeds `sealed_runs` with the live
//! run count and restarts the rest from zero.

use super::manifest::{self, ManifestRecord, ManifestWriter, RunMeta};
use super::policy::CompactionPolicy;
use super::run::{bump_file_seq, PreparedRun, Run, WideRecord};
use super::{StreamConfig, StreamError};
use crate::core::record::Record;
use crate::model::sync::{AtomicBool, AtomicU64, Mutex, Ordering};
use std::sync::Arc;

/// Point-in-time store statistics (folded from the published atomics
/// plus one short lock for the level map).
#[derive(Clone, Debug, Default)]
pub struct StoreStats {
    /// Live runs right now.
    pub runs: usize,
    /// Live records right now (invariant under compaction).
    pub records: u64,
    /// Runs sealed over the store's lifetime (re-seeded with the live
    /// count after a recovery).
    pub sealed_runs: u64,
    /// Compactions committed over the store's lifetime.
    pub compactions: u64,
    /// Compaction attempts that failed (e.g. spill I/O errors) — a
    /// growing value with a growing `runs` backlog means the store
    /// can no longer compact and needs operator attention.
    pub compaction_failures: u64,
    /// Live runs currently spilled to disk.
    pub spilled_runs: u64,
    /// Deepest live compaction level.
    pub max_level: u32,
}

/// Outcome of one committed compaction (see [`super::compact`]).
#[derive(Clone, Debug)]
pub struct CompactionStats {
    /// Records in the merged output run.
    pub merged_records: usize,
    /// How many input runs the window merged.
    pub inputs: usize,
    /// Level of the merged run (`max(inputs) + 1`).
    pub level: u32,
    /// Generation range the merged run covers.
    pub gen_lo: u64,
    /// Generation range the merged run covers.
    pub gen_hi: u64,
}

/// The leveled run store. See the module docs.
pub struct RunStore {
    config: StreamConfig,
    /// The compaction policy ([`StreamConfig::policy`]), instantiated
    /// once.
    policy: Box<dyn CompactionPolicy>,
    /// Live runs, sorted by `gen_lo`. Short-held lock; see module docs.
    runs: Mutex<Vec<Arc<Run>>>,
    /// Manifest appender — `Some` iff the store has a spill dir.
    /// Locked only AFTER the runs lock (see module docs).
    manifest: Option<Mutex<ManifestWriter>>,
    /// Generation clock (unique, monotone seal numbers); bumped only
    /// inside [`RunStore::seal`]'s critical section, read lock-free.
    next_gen: AtomicU64,
    live_runs: AtomicU64,
    live_records: AtomicU64,
    sealed_runs: AtomicU64,
    compactions: AtomicU64,
    compaction_failures: AtomicU64,
    spilled_runs: AtomicU64,
    /// Compaction claim: CAS-held by at most one compactor at a time.
    compacting: AtomicBool,
}

impl RunStore {
    /// Build a fresh store; creates the spill directory and a fresh
    /// (truncated) manifest when a spill dir is configured. Validates
    /// the configuration ([`StreamConfig::builder`] shapes always
    /// pass; hand-rolled configs may not). Use [`RunStore::recover`]
    /// to reopen an existing durable store.
    pub fn new(config: StreamConfig) -> Result<RunStore, StreamError> {
        config.validate()?;
        let manifest = match &config.spill {
            None => None,
            Some(dir) => {
                std::fs::create_dir_all(dir)
                    .map_err(|e| StreamError::Io(format!("spill dir {}: {e}", dir.display())))?;
                Some(Mutex::new(
                    ManifestWriter::create(&dir.join(manifest::MANIFEST_NAME))
                        .map_err(StreamError::Io)?,
                ))
            }
        };
        let policy = config.policy.build();
        Ok(RunStore {
            config,
            policy,
            runs: Mutex::new(Vec::new()),
            manifest,
            next_gen: AtomicU64::new(0),
            live_runs: AtomicU64::new(0),
            live_records: AtomicU64::new(0),
            sealed_runs: AtomicU64::new(0),
            compactions: AtomicU64::new(0),
            compaction_failures: AtomicU64::new(0),
            spilled_runs: AtomicU64::new(0),
            compacting: AtomicBool::new(false),
        })
    }

    /// Reopen a durable store from its spill dir: replay the manifest
    /// (tolerating a torn tail), reopen every live run (validating
    /// page checksums and manifest metadata), delete orphan
    /// `run-*.bin` files, and rewrite a compact manifest. With no
    /// manifest on disk the result is a fresh empty store.
    pub fn recover(config: StreamConfig) -> Result<RunStore, StreamError> {
        config.validate()?;
        let dir = config
            .spill
            .clone()
            .ok_or_else(|| StreamError::Config("recover requires a spill dir".to_string()))?;
        std::fs::create_dir_all(&dir)
            .map_err(|e| StreamError::Io(format!("spill dir {}: {e}", dir.display())))?;
        let manifest_path = dir.join(manifest::MANIFEST_NAME);
        if !manifest_path.exists() {
            return RunStore::new(config);
        }
        let log = manifest::read_manifest(&manifest_path).map_err(StreamError::Corrupt)?;
        let mut live = manifest::replay(&log);
        live.sort_by_key(|m| m.gen_lo);
        for w in live.windows(2) {
            if w[0].gen_hi >= w[1].gen_lo {
                return Err(StreamError::Corrupt(format!(
                    "manifest corrupt: generation ranges overlap ({:?} vs {:?})",
                    w[0], w[1]
                )));
            }
        }
        let mut runs = Vec::with_capacity(live.len());
        for meta in &live {
            runs.push(Arc::new(Run::open(meta, &dir).map_err(StreamError::Corrupt)?));
        }
        // Orphan sweep: every file in the spill dir that is not the
        // manifest or a live run file is crash debris (an unpublished
        // spill, a retired run whose unlink never landed, a stray
        // MANIFEST.tmp).
        for entry in std::fs::read_dir(&dir)
            .map_err(|e| StreamError::Io(format!("read spill dir {}: {e}", dir.display())))?
        {
            let entry = entry
                .map_err(|e| StreamError::Io(format!("read spill dir {}: {e}", dir.display())))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name == manifest::MANIFEST_NAME {
                continue;
            }
            let live_file = name
                .strip_prefix("run-")
                .and_then(|s| s.strip_suffix(".bin"))
                .and_then(|s| s.parse::<u64>().ok())
                .map_or(false, |id| live.iter().any(|m| m.id == id));
            if !live_file {
                let _ = std::fs::remove_file(entry.path());
            }
        }
        // Compact the manifest (drops the torn tail + folded history)
        // and keep appending to the rewritten file.
        manifest::rewrite(&manifest_path, &live).map_err(StreamError::Io)?;
        let writer = ManifestWriter::open_append(&manifest_path).map_err(StreamError::Io)?;
        bump_file_seq(live.iter().map(|m| m.id).max().map_or(0, |id| id + 1));
        let next_gen = live.iter().map(|m| m.gen_hi + 1).max().unwrap_or(0);
        let live_records: u64 = live.iter().map(|m| m.len).sum();
        let count = live.len() as u64;
        let policy = config.policy.build();
        Ok(RunStore {
            config,
            policy,
            runs: Mutex::new(runs),
            manifest: Some(Mutex::new(writer)),
            next_gen: AtomicU64::new(next_gen),
            live_runs: AtomicU64::new(count),
            live_records: AtomicU64::new(live_records),
            // Best effort: lifetime counters are not persisted.
            sealed_runs: AtomicU64::new(count),
            compactions: AtomicU64::new(0),
            compaction_failures: AtomicU64::new(0),
            spilled_runs: AtomicU64::new(count),
            compacting: AtomicBool::new(false),
        })
    }

    /// The configuration the store (and its tenant ingestors /
    /// compactors) runs under.
    pub fn config(&self) -> &StreamConfig {
        &self.config
    }

    /// The spill directory, when configured.
    pub(crate) fn spill_dir(&self) -> Option<&std::path::Path> {
        self.config.spill.as_deref()
    }

    /// Seal a sorted record batch into a fresh level-0 run; returns
    /// its generation, or `None` for an empty batch. Spills when the
    /// store has a spill dir.
    ///
    /// The spill write + fsync (the slow part) happens BEFORE the list
    /// lock; the generation allocation, manifest append, and insertion
    /// are one critical section. Allocating the generation outside the
    /// lock would let a stalled seal insert an old generation after a
    /// compaction merged past it — overlapping ranges, stability
    /// broken. A manifest-append failure aborts the seal: the
    /// unpublished run deletes its spill file on drop, and the skipped
    /// generation leaves a harmless gap in the clock.
    pub fn seal(&self, records: Vec<Record>) -> Result<Option<u64>, StreamError> {
        self.seal_columns(records, Vec::new())
    }

    /// [`RunStore::seal`] for wide records: splits the aux column out
    /// and stores it in the v2 page format (an all-zero column
    /// collapses back to the narrow layout). This is the
    /// [`super::writer`] shard seal path.
    pub fn seal_wide(&self, records: Vec<WideRecord>) -> Result<Option<u64>, StreamError> {
        let mut recs = Vec::with_capacity(records.len());
        let mut aux = Vec::with_capacity(records.len());
        for w in &records {
            recs.push(w.rec);
            aux.push(w.aux);
        }
        self.seal_columns(recs, aux)
    }

    fn seal_columns(
        &self,
        records: Vec<Record>,
        aux: Vec<u32>,
    ) -> Result<Option<u64>, StreamError> {
        if records.is_empty() {
            return Ok(None);
        }
        let len = records.len() as u64;
        let prepared = Run::prepare(
            records,
            aux,
            self.config.spill.as_deref(),
            self.config.page_records,
            self.config.legacy_pages,
        )
        .map_err(StreamError::Io)?;
        let spilled = prepared.is_spilled();
        let gen = {
            let mut runs = self.runs.lock().unwrap();
            let gen = self.next_gen.fetch_add(1, Ordering::Relaxed);
            let run = Arc::new(prepared.into_run(gen, gen, 0));
            if let Some(m) = &self.manifest {
                m.lock()
                    .unwrap()
                    .append(&ManifestRecord::AddRun(run.meta()))
                    .map_err(StreamError::Io)?;
            }
            // Manifest-published: the file now outlives this process.
            run.set_delete_on_drop(false);
            runs.push(run);
            gen
        };
        if spilled {
            self.spilled_runs.fetch_add(1, Ordering::Relaxed);
        }
        self.live_runs.fetch_add(1, Ordering::Relaxed);
        self.live_records.fetch_add(len, Ordering::Relaxed);
        self.sealed_runs.fetch_add(1, Ordering::Relaxed);
        Ok(Some(gen))
    }

    /// Clone the live run list (sorted by `gen_lo`). The `Arc`s keep
    /// the snapshot's runs alive across concurrent compactions.
    pub fn snapshot(&self) -> Vec<Arc<Run>> {
        self.runs.lock().unwrap().clone()
    }

    /// Live run count, from the published counter (lock-free).
    pub fn run_count(&self) -> usize {
        self.live_runs.load(Ordering::Relaxed) as usize
    }

    /// Live record count, from the published counter (lock-free).
    pub fn record_count(&self) -> u64 {
        self.live_records.load(Ordering::Relaxed)
    }

    /// Whether the backlog exceeds the configured fanout — the
    /// compaction trigger, readable without the list lock.
    pub fn needs_compaction(&self) -> bool {
        self.run_count() > self.config.fanout
    }

    /// Fold the published counters (plus one short lock for the level
    /// scan) into a [`StoreStats`].
    pub fn stats(&self) -> StoreStats {
        let max_level =
            self.runs.lock().unwrap().iter().map(|r| r.level()).max().unwrap_or(0);
        StoreStats {
            runs: self.run_count(),
            records: self.record_count(),
            sealed_runs: self.sealed_runs.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            compaction_failures: self.compaction_failures.load(Ordering::Relaxed),
            spilled_runs: self.spilled_runs.load(Ordering::Relaxed),
            max_level,
        }
    }

    /// Record a failed compaction attempt (surfaced via
    /// [`StoreStats::compaction_failures`]); the backlog the failure
    /// left behind is what the next trigger retries.
    pub fn note_compaction_failure(&self) {
        self.compaction_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Try to claim the (single) compaction slot. Non-blocking: `false`
    /// means another compactor holds it — skip, don't wait.
    pub(crate) fn try_claim_compaction(&self) -> bool {
        self.compacting
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
    }

    /// Release the compaction claim.
    pub(crate) fn release_compaction(&self) {
        self.compacting.store(false, Ordering::Release);
    }

    /// Whether a compaction currently holds the claim.
    pub fn is_compacting(&self) -> bool {
        self.compacting.load(Ordering::Relaxed)
    }

    /// Ask the configured policy for the next window to merge: a
    /// generation-contiguous slice of the live list, at most `fanout`
    /// wide (see [`super::policy`]). `None` when the policy finds
    /// nothing worth merging.
    ///
    /// Caller must hold the compaction claim: the returned runs stay
    /// contiguous because only the claim holder removes runs and
    /// seals only append maximal generations.
    pub(crate) fn pick_window(&self) -> Option<Vec<Arc<Run>>> {
        let runs = self.runs.lock().unwrap();
        let w = self.policy.pick(&runs, self.config.fanout)?;
        debug_assert!(w.len() >= 2 && w.end <= runs.len(), "policy returned a bad window");
        Some(runs[w].to_vec())
    }

    /// The whole live list as one window (major compaction /
    /// [`super::compact::compact_to_one`]); `None` with fewer than two
    /// runs. Same claim-holder contract as [`RunStore::pick_window`].
    pub(crate) fn pick_all(&self) -> Option<Vec<Arc<Run>>> {
        let runs = self.runs.lock().unwrap();
        if runs.len() < 2 {
            None
        } else {
            Some(runs.clone())
        }
    }

    /// Commit a compaction: replace the generation-contiguous window
    /// `inputs` with the merged run `prepared` (level `max + 1`,
    /// generation range `[inputs.first.gen_lo, inputs.last.gen_hi]`).
    /// Caller must hold the compaction claim and `prepared` must be
    /// the stable merge of the window (older run's records first on
    /// ties).
    ///
    /// Durable stores append a `Replace` manifest record (fsynced)
    /// before the in-memory swap; the retired inputs delete their
    /// spill files when the last snapshot reference drops.
    pub(crate) fn commit_compaction(
        &self,
        inputs: &[Arc<Run>],
        prepared: PreparedRun,
    ) -> Result<CompactionStats, String> {
        assert!(inputs.len() >= 2, "a compaction window is at least a pair");
        let level = inputs.iter().map(|r| r.level()).max().unwrap_or(0) + 1;
        let (gen_lo, gen_hi) = (inputs[0].gen_lo(), inputs[inputs.len() - 1].gen_hi());
        let spilled = prepared.is_spilled();
        let run = Arc::new(prepared.into_run(gen_lo, gen_hi, level));
        let merged_records = run.len();
        debug_assert_eq!(
            merged_records,
            inputs.iter().map(|r| r.len()).sum::<usize>(),
            "compaction must preserve record count"
        );
        {
            let mut runs = self.runs.lock().unwrap();
            let pos = runs
                .iter()
                .position(|r| Arc::ptr_eq(r, &inputs[0]))
                .ok_or_else(|| "compaction input vanished from the store".to_string())?;
            if pos + inputs.len() > runs.len()
                || !inputs.iter().enumerate().all(|(j, r)| Arc::ptr_eq(r, &runs[pos + j]))
            {
                return Err("compaction window no longer contiguous".to_string());
            }
            if let Some(m) = &self.manifest {
                let removed = inputs.iter().map(|r| r.id()).collect();
                m.lock().unwrap().append(&ManifestRecord::Replace { removed, added: run.meta() })?;
            }
            run.set_delete_on_drop(false);
            for r in inputs {
                // Retired: delete the file once the last snapshot lets go.
                r.set_delete_on_drop(true);
            }
            runs[pos] = Arc::clone(&run);
            runs.drain(pos + 1..pos + inputs.len());
        }
        self.live_runs.fetch_sub(inputs.len() as u64 - 1, Ordering::Relaxed);
        self.compactions.fetch_add(1, Ordering::Relaxed);
        let spilled_delta =
            spilled as i64 - inputs.iter().filter(|r| r.is_spilled()).count() as i64;
        if spilled_delta > 0 {
            self.spilled_runs.fetch_add(spilled_delta as u64, Ordering::Relaxed);
        } else if spilled_delta < 0 {
            self.spilled_runs.fetch_sub((-spilled_delta) as u64, Ordering::Relaxed);
        }
        Ok(CompactionStats { merged_records, inputs: inputs.len(), level, gen_lo, gen_hi })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recs(keys: &[i64], tag0: u64) -> Vec<Record> {
        keys.iter().enumerate().map(|(i, &k)| Record::new(k, tag0 + i as u64)).collect()
    }

    fn mem_store() -> RunStore {
        RunStore::new(StreamConfig {
            run_capacity: 16,
            fanout: 2,
            threads: 1,
            ..StreamConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn seal_assigns_monotone_generations_and_counts() {
        let store = mem_store();
        assert_eq!(store.seal(Vec::new()).unwrap(), None, "empty batch seals nothing");
        let g0 = store.seal(recs(&[1, 3], 0)).unwrap().unwrap();
        let g1 = store.seal(recs(&[2, 2, 4], 10)).unwrap().unwrap();
        assert!(g1 > g0);
        assert_eq!(store.run_count(), 2);
        assert_eq!(store.record_count(), 5);
        let stats = store.stats();
        assert_eq!((stats.runs, stats.records, stats.sealed_runs), (2, 5, 2));
        assert_eq!((stats.compactions, stats.spilled_runs, stats.max_level), (0, 0, 0));
        // Snapshot is gen-sorted.
        let snap = store.snapshot();
        assert_eq!(snap.len(), 2);
        assert!(snap[0].gen_lo() < snap[1].gen_lo());
    }

    /// The lock-free generation clock hands out unique generations
    /// under concurrent seals, and the published counters converge
    /// (the Miri target: this is the store's lock-free state).
    #[test]
    fn concurrent_seals_get_unique_generations() {
        let store = std::sync::Arc::new(mem_store());
        let per_thread = if cfg!(miri) { 4 } else { 64 };
        let threads = 2;
        std::thread::scope(|s| {
            for t in 0..threads {
                let store = std::sync::Arc::clone(&store);
                s.spawn(move || {
                    for i in 0..per_thread {
                        let key = (t * per_thread + i) as i64;
                        store.seal(recs(&[key], 0)).unwrap().unwrap();
                    }
                });
            }
        });
        let total = threads * per_thread;
        assert_eq!(store.run_count(), total);
        assert_eq!(store.record_count(), total as u64);
        let snap = store.snapshot();
        let mut gens: Vec<u64> = snap.iter().map(|r| r.gen_lo()).collect();
        let sorted = gens.clone();
        gens.sort_unstable();
        gens.dedup();
        assert_eq!(gens.len(), total, "generations must be unique");
        assert_eq!(sorted, gens, "snapshot must be gen-sorted");
    }

    /// The compaction claim is exclusive and releasable — the CAS
    /// protocol the compactor relies on.
    #[test]
    fn compaction_claim_is_exclusive() {
        let store = mem_store();
        assert!(!store.is_compacting());
        assert!(store.try_claim_compaction());
        assert!(store.is_compacting());
        assert!(!store.try_claim_compaction(), "second claim must lose");
        store.release_compaction();
        assert!(store.try_claim_compaction());
        store.release_compaction();
    }

    #[test]
    fn pick_window_uses_the_configured_policy() {
        let store = mem_store(); // default policy: adjacent-pair
        // Runs 0 and 1 are disjoint; runs 1 and 2 overlap.
        store.seal(recs(&[0, 5], 0)).unwrap();
        store.seal(recs(&[10, 20], 0)).unwrap();
        store.seal(recs(&[15, 30], 0)).unwrap();
        assert!(store.try_claim_compaction());
        let w = store.pick_window().expect("three runs yield a window");
        assert_eq!(w.len(), 2);
        assert_eq!((w[0].gen_lo(), w[1].gen_lo()), (1, 2), "overlapping pair preferred");
        let all = store.pick_all().expect("pick_all takes the whole list");
        assert_eq!(all.len(), 3);
        store.release_compaction();
    }

    #[test]
    fn commit_replaces_window_and_keeps_records() {
        let store = mem_store();
        store.seal(recs(&[1, 4], 0)).unwrap();
        store.seal(recs(&[2, 3], 10)).unwrap();
        store.seal(recs(&[9], 20)).unwrap();
        assert!(store.try_claim_compaction());
        let snap = store.snapshot();
        // Stable merge of the first two runs by hand.
        let merged: Vec<Record> = [(1, 0u64), (2, 10), (3, 11), (4, 1)]
            .iter()
            .map(|&(k, tag)| Record::new(k, tag))
            .collect();
        let prepared = Run::prepare(merged, Vec::new(), None, 1024, false).unwrap();
        let st = store.commit_compaction(&snap[..2], prepared).unwrap();
        store.release_compaction();
        assert_eq!((st.merged_records, st.inputs, st.level), (4, 2, 1));
        assert_eq!((st.gen_lo, st.gen_hi), (0, 1));
        assert_eq!(store.run_count(), 2);
        assert_eq!(store.record_count(), 5, "compaction preserves record count");
        let snap = store.snapshot();
        assert_eq!((snap[0].gen_lo(), snap[0].gen_hi(), snap[0].level()), (0, 1, 1));
        assert_eq!(snap[1].gen_lo(), 2);
        let stats = store.stats();
        assert_eq!((stats.compactions, stats.max_level), (1, 1));
    }

    #[test]
    fn commit_rejects_a_stale_window() {
        let store = mem_store();
        store.seal(recs(&[1], 0)).unwrap();
        store.seal(recs(&[2], 10)).unwrap();
        let stale = store.snapshot();
        // The window swaps out from under the (hypothetical) planner.
        let prepared = Run::prepare(recs(&[1, 2], 0), Vec::new(), None, 1024, false).unwrap();
        store.commit_compaction(&stale, prepared).unwrap();
        let prepared = Run::prepare(recs(&[1, 2], 0), Vec::new(), None, 1024, false).unwrap();
        assert!(store.commit_compaction(&stale, prepared).is_err());
    }

    #[test]
    fn seal_wide_keeps_the_aux_column() {
        let store = mem_store();
        let wide: Vec<WideRecord> = [(1i64, 10u64, 0u32), (2, 11, 5), (2, 12, 0)]
            .iter()
            .map(|&(k, t, a)| WideRecord::new(Record::new(k, t), a))
            .collect();
        store.seal_wide(wide).unwrap().unwrap();
        let snap = store.snapshot();
        assert!(snap[0].has_aux());
        let back = snap[0].load_wide().unwrap();
        assert_eq!(
            back.iter().map(|w| (w.rec.key, w.rec.tag, w.aux)).collect::<Vec<_>>(),
            vec![(1, 10, 0), (2, 11, 5), (2, 12, 0)]
        );
        // All-zero aux collapses to a narrow run.
        let wide: Vec<WideRecord> =
            (0..3).map(|i| WideRecord::new(Record::new(i, i as u64), 0)).collect();
        store.seal_wide(wide).unwrap().unwrap();
        assert!(!store.snapshot()[1].has_aux());
        // A validated config is a construction-time contract now.
        let bad = StreamConfig { fanout: 1, ..StreamConfig::default() };
        assert!(matches!(RunStore::new(bad), Err(StreamError::Config(_))));
    }

    #[test]
    fn needs_compaction_tracks_fanout() {
        let store = mem_store(); // fanout 2
        store.seal(recs(&[1], 0)).unwrap();
        store.seal(recs(&[2], 0)).unwrap();
        assert!(!store.needs_compaction());
        store.seal(recs(&[3], 0)).unwrap();
        assert!(store.needs_compaction());
    }

    #[test]
    #[cfg(not(miri))] // touches the real filesystem
    fn durable_store_recovers_run_list() {
        let dir = std::env::temp_dir().join(format!("traff-store-rec-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = StreamConfig {
            run_capacity: 16,
            fanout: 2,
            threads: 1,
            spill: Some(dir.clone()),
            page_records: 4,
            ..StreamConfig::default()
        };
        let expect: Vec<RunMeta>;
        {
            let store = RunStore::new(cfg.clone()).unwrap();
            store.seal(recs(&[1, 3, 5], 0)).unwrap();
            store.seal(recs(&[2, 2], 10)).unwrap();
            expect = store.snapshot().iter().map(|r| r.meta()).collect();
        } // drop: manifest-published files persist
        assert!(dir.join(manifest::MANIFEST_NAME).exists());
        let store = RunStore::recover(cfg.clone()).unwrap();
        let got: Vec<RunMeta> = store.snapshot().iter().map(|r| r.meta()).collect();
        assert_eq!(got, expect, "recovery restores the exact leveled run list");
        assert_eq!((store.run_count(), store.record_count()), (2, 5));
        assert_eq!(store.stats().spilled_runs, 2);
        // New seals take fresh generations (and fresh run ids).
        let g = store.seal(recs(&[9], 20)).unwrap().unwrap();
        assert!(g > expect[1].gen_hi);
        let ids: Vec<u64> = store.snapshot().iter().map(|r| r.id()).collect();
        let mut unique = ids.clone();
        unique.sort_unstable();
        unique.dedup();
        assert_eq!(unique.len(), ids.len(), "run ids stay unique across recovery");
        drop(store);
        // Recovering into a store and dropping it again keeps the data.
        let store = RunStore::recover(cfg).unwrap();
        assert_eq!(store.record_count(), 6);
        drop(store);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[cfg(not(miri))]
    fn recover_without_manifest_is_a_fresh_store() {
        let dir = std::env::temp_dir().join(format!("traff-store-fresh-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = StreamConfig { spill: Some(dir.clone()), ..StreamConfig::default() };
        let store = RunStore::recover(cfg).unwrap();
        assert_eq!(store.run_count(), 0);
        store.seal(recs(&[1], 0)).unwrap();
        drop(store);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

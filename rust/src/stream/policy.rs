//! Pluggable compaction policies: *which* generation-contiguous window
//! of runs to merge next.
//!
//! Buss & Knop ("Strategies for Stable Merge Sorting") make the case
//! that the merge *schedule* is a first-class lever on total work; for
//! an LSM-style store the same choice governs write amplification. The
//! store gives every policy the same contract and the same safety
//! net:
//!
//! - a policy sees the run list **sorted by `gen_lo`** and returns a
//!   window of **adjacent indices** (length ≥ 2, capped at the
//!   configured fanout) — generation contiguity is what preserves the
//!   exact-ingest-order stability invariant, so it is structural here,
//!   not a policy decision;
//! - returning `None` means "nothing worth merging"; the store's
//!   backlog trigger ([`super::store::RunStore::needs_compaction`])
//!   still decides *when* a policy is consulted.
//!
//! Three implementations ship: the PR-5 adjacent-pair rule as the
//! baseline, a size-tiered policy (merge windows of similarly sized
//! runs, widest first — k-way merges amortize rewrites), and a
//! key-range-overlap-aware policy (merge the longest chain of
//! pairwise-overlapping neighbors — disjoint runs cost a rewrite but
//! save no scan work).

use std::ops::Range;
use std::sync::Arc;

use super::run::Run;

/// A compaction policy picks the next window to merge. Implementations
/// must return a window `w` with `w.len() >= 2` and
/// `w.end <= runs.len()`; the store clamps nothing — a bad window is a
/// bug, caught by `debug_assert` in the store.
pub trait CompactionPolicy: Send + Sync {
    /// Human-readable name (CLI/telemetry).
    fn name(&self) -> &'static str;

    /// Choose a generation-adjacent window of `runs` (sorted by
    /// `gen_lo`) to merge, at most `fanout` wide.
    fn pick(&self, runs: &[Arc<Run>], fanout: usize) -> Option<Range<usize>>;
}

/// Effective window-width cap: at least a pair, even for degenerate
/// fanout configs.
fn max_width(runs: &[Arc<Run>], fanout: usize) -> usize {
    fanout.max(2).min(runs.len())
}

/// The PR-5 baseline: merge one adjacent pair, preferring key-range
/// overlap, then the smallest combined size (cheapest useful merge).
pub struct AdjacentPair;

impl CompactionPolicy for AdjacentPair {
    fn name(&self) -> &'static str {
        "adjacent"
    }

    fn pick(&self, runs: &[Arc<Run>], _fanout: usize) -> Option<Range<usize>> {
        if runs.len() < 2 {
            return None;
        }
        let mut best: Option<(bool, usize, usize)> = None; // (overlaps, combined, index)
        for i in 0..runs.len() - 1 {
            let overlaps = runs[i].overlaps(&runs[i + 1]);
            let combined = runs[i].len() + runs[i + 1].len();
            let better = match best {
                None => true,
                Some((bo, bc, _)) => {
                    (overlaps, std::cmp::Reverse(combined)) > (bo, std::cmp::Reverse(bc))
                }
            };
            if better {
                best = Some((overlaps, combined, i));
            }
        }
        best.map(|(_, _, i)| i..i + 2)
    }
}

/// Size-tiered: find windows (up to the fanout) whose runs are within
/// a 4x size band of each other, and merge the widest such window —
/// ties broken toward the smallest total bytes. A k-way merge of
/// similar-size runs does one rewrite where a pairwise cascade does
/// `k - 1`. Falls back to [`AdjacentPair`] so the store always makes
/// progress once the backlog trigger fires.
pub struct SizeTiered;

/// Largest/smallest run-length ratio still considered "one tier".
const TIER_RATIO: usize = 4;

impl CompactionPolicy for SizeTiered {
    fn name(&self) -> &'static str {
        "tiered"
    }

    fn pick(&self, runs: &[Arc<Run>], fanout: usize) -> Option<Range<usize>> {
        if runs.len() < 2 {
            return None;
        }
        let cap = max_width(runs, fanout);
        let mut best: Option<(usize, usize, Range<usize>)> = None; // (width, total, window)
        for start in 0..runs.len() - 1 {
            let mut min_len = runs[start].len();
            let mut max_len = min_len;
            let mut total = min_len;
            for end in start + 1..runs.len().min(start + cap) {
                let l = runs[end].len();
                min_len = min_len.min(l);
                max_len = max_len.max(l);
                total += l;
                if max_len > TIER_RATIO * min_len {
                    break; // window left the tier; wider is only worse
                }
                let width = end - start + 1;
                let better = match &best {
                    None => true,
                    Some((bw, bt, _)) => width > *bw || (width == *bw && total < *bt),
                };
                if better {
                    best = Some((width, total, start..end + 1));
                }
            }
        }
        best.map(|(_, _, w)| w).or_else(|| AdjacentPair.pick(runs, fanout))
    }
}

/// Key-range-overlap-aware: merge the longest chain of neighbors that
/// pairwise overlap the next run in the chain (up to the fanout) —
/// ties broken toward the smallest total size. Merging disjoint runs
/// rewrites bytes without reducing per-key scan fan-in; this policy
/// spends its write budget only where key ranges actually interleave.
/// Falls back to [`AdjacentPair`] when every neighbor pair is
/// disjoint.
pub struct OverlapAware;

impl CompactionPolicy for OverlapAware {
    fn name(&self) -> &'static str {
        "overlap"
    }

    fn pick(&self, runs: &[Arc<Run>], fanout: usize) -> Option<Range<usize>> {
        if runs.len() < 2 {
            return None;
        }
        let cap = max_width(runs, fanout);
        let mut best: Option<(usize, usize, Range<usize>)> = None; // (width, total, window)
        for start in 0..runs.len() - 1 {
            let mut total = runs[start].len();
            for end in start + 1..runs.len().min(start + cap) {
                if !runs[end - 1].overlaps(&runs[end]) {
                    break; // chain broken
                }
                total += runs[end].len();
                let width = end - start + 1;
                let better = match &best {
                    None => true,
                    Some((bw, bt, _)) => width > *bw || (width == *bw && total < *bt),
                };
                if better {
                    best = Some((width, total, start..end + 1));
                }
            }
        }
        best.map(|(_, _, w)| w).or_else(|| AdjacentPair.pick(runs, fanout))
    }
}

/// Config-level policy selector ([`super::StreamConfig::policy`]),
/// parseable from the CLI's `--policy` flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// [`AdjacentPair`] — the baseline.
    AdjacentPair,
    /// [`SizeTiered`].
    SizeTiered,
    /// [`OverlapAware`].
    OverlapAware,
}

impl PolicyKind {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "adjacent" => Some(PolicyKind::AdjacentPair),
            "tiered" => Some(PolicyKind::SizeTiered),
            "overlap" => Some(PolicyKind::OverlapAware),
            _ => None,
        }
    }

    /// The CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::AdjacentPair => "adjacent",
            PolicyKind::SizeTiered => "tiered",
            PolicyKind::OverlapAware => "overlap",
        }
    }

    /// Instantiate the policy.
    pub fn build(self) -> Box<dyn CompactionPolicy> {
        match self {
            PolicyKind::AdjacentPair => Box::new(AdjacentPair),
            PolicyKind::SizeTiered => Box::new(SizeTiered),
            PolicyKind::OverlapAware => Box::new(OverlapAware),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::record::Record;

    /// A level-0 mem run with the given key span and length.
    fn run(gen: u64, lo: i64, hi: i64, len: usize) -> Arc<Run> {
        assert!(len >= 2 || lo == hi);
        let mut records = Vec::with_capacity(len);
        records.push(Record::new(lo, 0));
        for i in 1..len.saturating_sub(1) {
            records.push(Record::new(lo + (hi - lo) / 2, i as u64));
        }
        if len > 1 {
            records.push(Record::new(hi, len as u64 - 1));
        }
        Arc::new(Run::create(records, gen, gen, 0, None, 1024).unwrap())
    }

    #[test]
    fn adjacent_pair_prefers_overlap_then_smallest() {
        // (0) [0,5]x2  (1) [10,20]x2  (2) [15,30]x2 — only 1-2 overlap.
        let runs = vec![run(0, 0, 5, 2), run(1, 10, 20, 2), run(2, 15, 30, 2)];
        assert_eq!(AdjacentPair.pick(&runs, 4), Some(1..3));
        // All disjoint: pick the smallest combined pair.
        let runs = vec![run(0, 0, 1, 8), run(1, 10, 11, 2), run(2, 20, 21, 2)];
        assert_eq!(AdjacentPair.pick(&runs, 4), Some(1..3));
        assert_eq!(AdjacentPair.pick(&runs[..1], 4), None);
    }

    #[test]
    fn size_tiered_merges_widest_similar_window() {
        // A big old run and four small fresh ones: the tier is 1..5.
        let runs = vec![
            run(0, 0, 100, 1000),
            run(1, 0, 10, 8),
            run(2, 5, 15, 10),
            run(3, 8, 30, 16),
            run(4, 2, 9, 12),
        ];
        assert_eq!(SizeTiered.pick(&runs, 8), Some(1..5));
        // Fanout caps the window width.
        assert_eq!(SizeTiered.pick(&runs, 3), Some(1..4));
        // Nothing in one tier: falls back to the adjacent-pair rule.
        let skewed = vec![run(0, 0, 9, 1000), run(1, 0, 9, 100), run(2, 0, 9, 2)];
        assert!(skewed[0].len() > TIER_RATIO * skewed[1].len());
        assert_eq!(SizeTiered.pick(&skewed, 8), AdjacentPair.pick(&skewed, 8));
    }

    #[test]
    fn overlap_aware_merges_longest_overlap_chain() {
        // Chain 0-1-2 overlaps; 3 is disjoint from 2.
        let runs = vec![
            run(0, 0, 10, 4),
            run(1, 5, 20, 4),
            run(2, 18, 40, 4),
            run(3, 100, 120, 4),
        ];
        assert_eq!(OverlapAware.pick(&runs, 8), Some(0..3));
        // All disjoint: falls back to the adjacent-pair rule.
        let disjoint = vec![run(0, 0, 1, 2), run(1, 10, 11, 2), run(2, 20, 21, 2)];
        assert_eq!(OverlapAware.pick(&disjoint, 8), AdjacentPair.pick(&disjoint, 8));
    }

    #[test]
    fn policy_kind_parses_and_builds() {
        for (s, kind) in [
            ("adjacent", PolicyKind::AdjacentPair),
            ("tiered", PolicyKind::SizeTiered),
            ("overlap", PolicyKind::OverlapAware),
        ] {
            assert_eq!(PolicyKind::parse(s), Some(kind));
            assert_eq!(kind.name(), s);
            assert_eq!(kind.build().name(), s);
        }
        assert_eq!(PolicyKind::parse("leveled"), None);
    }
}

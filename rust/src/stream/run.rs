//! One sealed, immutable sorted run — the unit the [`super::store`]
//! levels and the [`super::compact`] merger operate on.
//!
//! A run is born from one seal: a sorted batch of [`Record`]s, stamped
//! with a **generation range** `[gen_lo, gen_hi]` (seal sequence
//! numbers from the store's lock-free generation clock). A freshly
//! sealed run has `gen_lo == gen_hi`; a compacted run covers the union
//! of its inputs' ranges. The generation range is the stability
//! anchor: readers order runs by `gen_lo`, and the compactor only ever
//! merges runs whose ranges are adjacent in that order, so "older
//! generation" remains a total order over equal keys end to end (see
//! [`super::store`] for the adjacency invariant).
//!
//! Storage is either in-memory or **spilled** to a fixed-width binary
//! file under the store's temp dir (16 bytes per record: `key` i64 LE,
//! `tag` u64 LE). Spilled runs keep only their metadata (length,
//! generation range, level, key span) resident; [`Run::load`] reads
//! the records back on demand. A disk run deletes its file on drop.

use crate::core::record::Record;
use std::path::{Path, PathBuf};
use crate::model::sync::{AtomicU64, Ordering};

/// Bytes per record in the spill encoding (i64 key + u64 tag, LE).
pub const RECORD_BYTES: usize = 16;

/// Encode records into the fixed-width spill representation.
pub(crate) fn encode_records(records: &[Record]) -> Vec<u8> {
    let mut out = Vec::with_capacity(records.len() * RECORD_BYTES);
    for r in records {
        out.extend_from_slice(&r.key.to_le_bytes());
        out.extend_from_slice(&r.tag.to_le_bytes());
    }
    out
}

/// Decode the fixed-width spill representation.
pub(crate) fn decode_records(bytes: &[u8]) -> Result<Vec<Record>, String> {
    if bytes.len() % RECORD_BYTES != 0 {
        return Err(format!(
            "spill file corrupt: {} bytes is not a multiple of {RECORD_BYTES}",
            bytes.len()
        ));
    }
    let mut out = Vec::with_capacity(bytes.len() / RECORD_BYTES);
    for chunk in bytes.chunks_exact(RECORD_BYTES) {
        let mut k = [0u8; 8];
        let mut t = [0u8; 8];
        k.copy_from_slice(&chunk[..8]);
        t.copy_from_slice(&chunk[8..]);
        out.push(Record::new(i64::from_le_bytes(k), u64::from_le_bytes(t)));
    }
    Ok(out)
}

/// Process-wide spill-file name allocator (distinct from the store's
/// generation clock so re-compacted ranges never collide on a name).
static FILE_SEQ: AtomicU64 = AtomicU64::new(0);

enum Storage {
    /// Records resident in memory.
    Mem(Vec<Record>),
    /// Records spilled to `path`; only metadata stays resident.
    Disk(PathBuf),
}

/// One immutable sorted run. See the module docs.
pub struct Run {
    gen_lo: u64,
    gen_hi: u64,
    level: u32,
    len: usize,
    min_key: i64,
    max_key: i64,
    storage: Storage,
}

/// A run with its storage materialized (spill write already done) but
/// no generation assigned yet. Lets the store do the I/O-heavy part
/// OUTSIDE its list lock and then allocate the generation + insert
/// atomically under it — a stalled seal can therefore never interleave
/// an old generation into a list a compaction has since rewritten
/// (the disjoint-generation-range invariant, see [`super::store`]).
pub(crate) struct PreparedRun {
    len: usize,
    min_key: i64,
    max_key: i64,
    storage: Storage,
}

impl PreparedRun {
    /// Stamp the generation range and level, completing the run.
    pub(crate) fn into_run(self, gen_lo: u64, gen_hi: u64, level: u32) -> Run {
        Run {
            gen_lo,
            gen_hi,
            level,
            len: self.len,
            min_key: self.min_key,
            max_key: self.max_key,
            storage: self.storage,
        }
    }

    /// Whether the prepared storage is spilled to disk.
    pub(crate) fn is_spilled(&self) -> bool {
        matches!(self.storage, Storage::Disk(_))
    }
}

impl Run {
    /// Materialize storage for sorted records, spilling to `spill_dir`
    /// when one is configured. `records` must be non-empty and
    /// key-sorted (the seal path sorts; compaction merges sorted
    /// inputs).
    pub(crate) fn prepare(
        records: Vec<Record>,
        spill_dir: Option<&Path>,
    ) -> Result<PreparedRun, String> {
        assert!(!records.is_empty(), "a run is never empty");
        debug_assert!(
            records.windows(2).all(|w| w[0].key <= w[1].key),
            "runs hold key-sorted records"
        );
        let len = records.len();
        let min_key = records[0].key;
        let max_key = records[len - 1].key;
        let storage = match spill_dir {
            None => Storage::Mem(records),
            Some(dir) => {
                let id = FILE_SEQ.fetch_add(1, Ordering::Relaxed);
                let path = dir.join(format!("run-{id}.bin"));
                std::fs::write(&path, encode_records(&records))
                    .map_err(|e| format!("spill write {}: {e}", path.display()))?;
                Storage::Disk(path)
            }
        };
        Ok(PreparedRun { len, min_key, max_key, storage })
    }

    /// [`Run::prepare`] + [`PreparedRun::into_run`] in one step, for
    /// callers whose generation range is already fixed (compaction
    /// commits, tests).
    pub(crate) fn create(
        records: Vec<Record>,
        gen_lo: u64,
        gen_hi: u64,
        level: u32,
        spill_dir: Option<&Path>,
    ) -> Result<Run, String> {
        Ok(Run::prepare(records, spill_dir)?.into_run(gen_lo, gen_hi, level))
    }

    /// Oldest seal generation this run covers (the reader's sort key).
    pub fn gen_lo(&self) -> u64 {
        self.gen_lo
    }

    /// Newest seal generation this run covers.
    pub fn gen_hi(&self) -> u64 {
        self.gen_hi
    }

    /// Compaction depth: 0 for a freshly sealed run, `max + 1` of its
    /// inputs after a compaction.
    pub fn level(&self) -> u32 {
        self.level
    }

    /// Number of records in the run.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Runs are never empty; kept for API completeness.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Smallest key in the run.
    pub fn min_key(&self) -> i64 {
        self.min_key
    }

    /// Largest key in the run.
    pub fn max_key(&self) -> i64 {
        self.max_key
    }

    /// Whether this run is spilled to disk.
    pub fn is_spilled(&self) -> bool {
        matches!(self.storage, Storage::Disk(_))
    }

    /// Key-range overlap test — the compactor prefers overlapping
    /// pairs (merging disjoint runs is legal but pure copying).
    pub fn overlaps(&self, other: &Run) -> bool {
        self.min_key <= other.max_key && other.min_key <= self.max_key
    }

    /// The run's records without copying, borrowed for memory runs
    /// and read + decoded for spilled ones. This is what [`scan`]
    /// (`super::reader`) and the compactor use — an in-memory store
    /// never pays a per-run clone on the read/compact path. Callers
    /// that must OWN the data (e.g. [`super::reader::ScanIter`])
    /// use [`Run::load`].
    ///
    /// [`scan`]: super::reader::scan
    pub fn data(&self) -> Result<std::borrow::Cow<'_, [Record]>, String> {
        match &self.storage {
            Storage::Mem(records) => Ok(std::borrow::Cow::Borrowed(records.as_slice())),
            Storage::Disk(_) => Ok(std::borrow::Cow::Owned(self.load()?)),
        }
    }

    /// Materialize an owned copy of the run's records (clone for
    /// memory runs, read + decode for spilled runs). Prefer
    /// [`Run::data`] wherever a borrow suffices.
    pub fn load(&self) -> Result<Vec<Record>, String> {
        match &self.storage {
            Storage::Mem(records) => Ok(records.clone()),
            Storage::Disk(path) => {
                let bytes = std::fs::read(path)
                    .map_err(|e| format!("spill read {}: {e}", path.display()))?;
                let records = decode_records(&bytes)?;
                if records.len() != self.len {
                    return Err(format!(
                        "spill file {} holds {} records, expected {}",
                        path.display(),
                        records.len(),
                        self.len
                    ));
                }
                Ok(records)
            }
        }
    }
}

impl Drop for Run {
    fn drop(&mut self) {
        if let Storage::Disk(path) = &self.storage {
            // Best effort: a leaked spill file is a disk-space leak,
            // not a correctness problem.
            let _ = std::fs::remove_file(path);
        }
    }
}

impl std::fmt::Debug for Run {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Run")
            .field("gen", &(self.gen_lo..=self.gen_hi))
            .field("level", &self.level)
            .field("len", &self.len)
            .field("keys", &(self.min_key..=self.max_key))
            .field("spilled", &self.is_spilled())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recs(keys: &[i64]) -> Vec<Record> {
        keys.iter().enumerate().map(|(i, &k)| Record::new(k, i as u64)).collect()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let records = recs(&[-5, 0, 3, 3, i64::MAX]);
        let bytes = encode_records(&records);
        assert_eq!(bytes.len(), records.len() * RECORD_BYTES);
        let back = decode_records(&bytes).unwrap();
        let pairs: Vec<(i64, u64)> = back.iter().map(|r| (r.key, r.tag)).collect();
        let expect: Vec<(i64, u64)> = records.iter().map(|r| (r.key, r.tag)).collect();
        assert_eq!(pairs, expect);
        assert!(decode_records(&bytes[..RECORD_BYTES + 1]).is_err());
    }

    #[test]
    fn mem_run_metadata_and_load() {
        let run = Run::create(recs(&[1, 2, 2, 9]), 4, 4, 0, None).unwrap();
        assert_eq!((run.gen_lo(), run.gen_hi(), run.level(), run.len()), (4, 4, 0, 4));
        assert_eq!((run.min_key(), run.max_key()), (1, 9));
        assert!(!run.is_spilled());
        let data = run.load().unwrap();
        assert_eq!(data.iter().map(|r| r.key).collect::<Vec<_>>(), vec![1, 2, 2, 9]);
    }

    #[test]
    fn overlap_detection() {
        let a = Run::create(recs(&[0, 10]), 0, 0, 0, None).unwrap();
        let b = Run::create(recs(&[5, 20]), 1, 1, 0, None).unwrap();
        let c = Run::create(recs(&[11, 30]), 2, 2, 0, None).unwrap();
        assert!(a.overlaps(&b));
        assert!(b.overlaps(&a));
        assert!(!a.overlaps(&c));
        assert!(b.overlaps(&c));
    }

    #[test]
    #[cfg(not(miri))] // touches the real filesystem
    fn spilled_run_loads_and_cleans_up() {
        let dir = std::env::temp_dir().join(format!("traff-run-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let records = recs(&[3, 4, 4, 4, 7]);
        let path;
        {
            let run = Run::create(records.clone(), 0, 2, 1, Some(&dir)).unwrap();
            assert!(run.is_spilled());
            path = match &run.storage {
                Storage::Disk(p) => p.clone(),
                Storage::Mem(_) => unreachable!(),
            };
            assert!(path.exists());
            let back = run.load().unwrap();
            assert_eq!(back.iter().map(|r| (r.key, r.tag)).collect::<Vec<_>>(),
                       records.iter().map(|r| (r.key, r.tag)).collect::<Vec<_>>());
        }
        // Drop removed the spill file.
        assert!(!path.exists());
        let _ = std::fs::remove_dir(&dir);
    }
}

//! One sealed, immutable sorted run — the unit the [`super::store`]
//! levels and the [`super::compact`] merger operate on.
//!
//! A run is born from one seal: a sorted batch of [`Record`]s, stamped
//! with a **generation range** `[gen_lo, gen_hi]` (seal sequence
//! numbers from the store's lock-free generation clock). A freshly
//! sealed run has `gen_lo == gen_hi`; a compacted run covers the union
//! of its inputs' ranges. The generation range is the stability
//! anchor: readers order runs by `gen_lo`, and the compactor only ever
//! merges generation-contiguous windows in that order, so "older
//! generation" remains a total order over equal keys end to end (see
//! [`super::store`] for the contiguity invariant).
//!
//! Storage is either in-memory or **spilled** as a paged file
//! (`run-{id}.bin`, format in [`super::page`]): fixed-size record
//! pages plus a checksummed per-page min/max-key index. A spilled run
//! keeps only its metadata and page index resident; records are read
//! one page at a time through a [`RunCursor`], so scan and compaction
//! memory is O(pages buffered), never O(run). [`Run::open`] reopens a
//! spilled run from its manifest [`RunMeta`] on recovery.
//!
//! Spill files are deleted when the last reference drops **only** if
//! the run was never published to the manifest (or was compacted
//! away): the store flips [`Run::set_delete_on_drop`] off at
//! manifest-publication time and back on when a compaction retires the
//! run — see the lifecycle diagram in ARCHITECTURE.md.

use crate::core::record::Record;
use crate::model::sync::{AtomicBool, AtomicU64, Ordering};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::manifest::RunMeta;
use super::page::{self, PageFileWriter, PageFormat, PageMeta};

/// Bytes per record in the spill encoding (i64 key + u64 tag, LE).
pub const RECORD_BYTES: usize = 16;

/// A [`Record`] paired with its out-of-line aux value — the high half
/// of the 64-bit ingest sequence, stored in the page format's v2 aux
/// column rather than widening the hot 16-byte record. Orders by the
/// record key ONLY (exactly like [`Record`]), so the generic stable
/// merge kernels (`parallel_merge_sort`, `parallel_kway_merge`) carry
/// the aux column through seal sorts and compaction merges unchanged.
#[derive(Clone, Copy, Debug)]
pub struct WideRecord {
    /// The 16-byte record (key + packed tag).
    pub rec: Record,
    /// Out-of-line sequence high bits (0 for streams under 2^32
    /// records and for all legacy/v1 data).
    pub aux: u32,
}

impl WideRecord {
    /// Pair a record with its aux value.
    pub fn new(rec: Record, aux: u32) -> WideRecord {
        WideRecord { rec, aux }
    }

    /// Reassemble the full 64-bit ingest sequence for tags packed by
    /// [`super::writer`] (`tag = seq_lo << 32 | payload`, `aux =
    /// seq >> 32`). Meaningless for raw-tag ingest paths like
    /// [`super::Ingestor::push_key`], where aux is always 0.
    pub fn full_seq(&self) -> u64 {
        ((self.aux as u64) << 32) | (self.rec.tag >> 32)
    }
}

impl PartialEq for WideRecord {
    #[inline]
    fn eq(&self, other: &Self) -> bool {
        self.rec.key == other.rec.key
    }
}

impl Eq for WideRecord {}

impl PartialOrd for WideRecord {
    #[inline]
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for WideRecord {
    /// Orders by key ONLY — equal keys are `Equal` regardless of tag
    /// or aux, which is what lets the full sequence observe stability.
    #[inline]
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.rec.key.cmp(&other.rec.key)
    }
}

/// Encode records into the fixed-width spill representation.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn encode_records(records: &[Record]) -> Vec<u8> {
    let mut out = Vec::with_capacity(records.len() * RECORD_BYTES);
    for r in records {
        out.extend_from_slice(&r.key.to_le_bytes());
        out.extend_from_slice(&r.tag.to_le_bytes());
    }
    out
}

/// Decode the fixed-width spill representation.
pub(crate) fn decode_records(bytes: &[u8]) -> Result<Vec<Record>, String> {
    if bytes.len() % RECORD_BYTES != 0 {
        return Err(format!(
            "spill file corrupt: {} bytes is not a multiple of {RECORD_BYTES}",
            bytes.len()
        ));
    }
    let mut out = Vec::with_capacity(bytes.len() / RECORD_BYTES);
    for chunk in bytes.chunks_exact(RECORD_BYTES) {
        let mut k = [0u8; 8];
        let mut t = [0u8; 8];
        k.copy_from_slice(&chunk[..8]);
        t.copy_from_slice(&chunk[8..]);
        out.push(Record::new(i64::from_le_bytes(k), u64::from_le_bytes(t)));
    }
    Ok(out)
}

/// Process-wide run-id allocator (distinct from the store's generation
/// clock so re-compacted ranges never collide on a file name). Bumped
/// past recovered ids by [`bump_file_seq`].
static FILE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Ensure future run ids are `>= min_next` (recovery calls this with
/// `max recovered id + 1` so new spill files never collide with live
/// ones).
pub(crate) fn bump_file_seq(min_next: u64) {
    FILE_SEQ.fetch_max(min_next, Ordering::Relaxed);
}

enum Storage {
    /// Records resident in memory. `aux` is either empty (all aux
    /// values are 0 — the common narrow case) or exactly
    /// `recs.len()` long, one aux value per record.
    Mem { recs: Vec<Record>, aux: Vec<u32> },
    /// Records spilled to a paged file; only the page index stays
    /// resident.
    Disk {
        path: PathBuf,
        page_records: usize,
        index: Vec<PageMeta>,
        /// Whether the file carries the v2 out-of-line aux column.
        has_aux: bool,
        /// Whether dropping the last reference deletes the file.
        /// `true` until the run is published to the manifest; flipped
        /// back on when a compaction retires it.
        delete_on_drop: AtomicBool,
    },
}

impl Drop for Storage {
    fn drop(&mut self) {
        if let Storage::Disk { path, delete_on_drop, .. } = self {
            if delete_on_drop.load(Ordering::Relaxed) {
                // Best effort: a leaked spill file is a disk-space
                // leak (and recovery deletes it as an orphan), not a
                // correctness problem.
                let _ = std::fs::remove_file(path);
            }
        }
    }
}

/// One immutable sorted run. See the module docs.
pub struct Run {
    id: u64,
    gen_lo: u64,
    gen_hi: u64,
    level: u32,
    len: usize,
    min_key: i64,
    max_key: i64,
    storage: Storage,
}

/// A run with its storage materialized (spill write + fsync already
/// done) but no generation assigned yet. Lets the store do the
/// I/O-heavy part OUTSIDE its list lock and then allocate the
/// generation + append the manifest record + insert atomically under
/// it. Dropping a `PreparedRun` before publication deletes its spill
/// file (the file was never referenced by the manifest).
pub(crate) struct PreparedRun {
    id: u64,
    len: usize,
    min_key: i64,
    max_key: i64,
    storage: Storage,
}

impl PreparedRun {
    /// Stamp the generation range and level, completing the run.
    pub(crate) fn into_run(self, gen_lo: u64, gen_hi: u64, level: u32) -> Run {
        Run {
            id: self.id,
            gen_lo,
            gen_hi,
            level,
            len: self.len,
            min_key: self.min_key,
            max_key: self.max_key,
            storage: self.storage,
        }
    }

    /// Whether the prepared storage is spilled to disk.
    pub(crate) fn is_spilled(&self) -> bool {
        matches!(self.storage, Storage::Disk { .. })
    }
}

/// Incremental builder for one run's storage: records are pushed in
/// key order and either buffered in memory or streamed straight into a
/// paged spill file — the compactor's output path never materializes a
/// merged run in RAM. [`RunWriter::finish`] yields a [`PreparedRun`].
pub(crate) struct RunWriter {
    id: u64,
    page_records: usize,
    format: PageFormat,
    first_key: i64,
    last_key: i64,
    inner: WriterInner,
}

enum WriterInner {
    /// `aux` mirrors the storage convention: empty means all zero.
    Mem { recs: Vec<Record>, aux: Vec<u32> },
    Disk { writer: PageFileWriter, path: PathBuf },
}

impl RunWriter {
    /// Start a run: in memory when `spill_dir` is `None`, else as the
    /// paged file `run-{id}.bin` under `spill_dir` using `format`.
    /// Memory writers ignore `format` (they always accept aux values);
    /// spilled writers reject nonzero aux unless the format carries
    /// the aux column.
    pub(crate) fn new(
        spill_dir: Option<&Path>,
        page_records: usize,
        cap_hint: usize,
        format: PageFormat,
    ) -> Result<RunWriter, String> {
        let id = FILE_SEQ.fetch_add(1, Ordering::Relaxed);
        let inner = match spill_dir {
            None => WriterInner::Mem { recs: Vec::with_capacity(cap_hint), aux: Vec::new() },
            Some(dir) => {
                let path = dir.join(format!("run-{id}.bin"));
                let writer = PageFileWriter::create(&path, page_records, format)?;
                WriterInner::Disk { writer, path }
            }
        };
        Ok(RunWriter { id, page_records, format, first_key: 0, last_key: 0, inner })
    }

    /// An in-memory writer (never fails).
    pub(crate) fn mem(cap_hint: usize) -> RunWriter {
        RunWriter::new(None, 1, cap_hint, PageFormat::V2 { has_aux: false })
            .expect("mem writer is infallible")
    }

    /// Records written so far.
    pub(crate) fn len(&self) -> usize {
        match &self.inner {
            WriterInner::Mem { recs, .. } => recs.len(),
            WriterInner::Disk { writer, .. } => writer.len(),
        }
    }

    /// Append one record (non-decreasing key order).
    pub(crate) fn push(&mut self, rec: Record) -> Result<(), String> {
        self.push_wide(WideRecord::new(rec, 0))
    }

    /// Append one record with its aux value (non-decreasing key
    /// order).
    pub(crate) fn push_wide(&mut self, wrec: WideRecord) -> Result<(), String> {
        let rec = wrec.rec;
        if self.len() == 0 {
            self.first_key = rec.key;
        }
        debug_assert!(self.len() == 0 || rec.key >= self.last_key, "runs hold key-sorted records");
        self.last_key = rec.key;
        match &mut self.inner {
            WriterInner::Mem { recs, aux } => {
                if wrec.aux != 0 && aux.is_empty() {
                    // First nonzero aux: backfill the implicit zeros.
                    aux.resize(recs.len(), 0);
                }
                recs.push(rec);
                if !aux.is_empty() {
                    aux.push(wrec.aux);
                }
                Ok(())
            }
            WriterInner::Disk { writer, .. } => {
                if self.format.has_aux() {
                    writer.push_wide(rec, wrec.aux)
                } else {
                    if wrec.aux != 0 {
                        return Err(format!(
                            "run {} format {:?} cannot store nonzero aux {}",
                            self.id, self.format, wrec.aux
                        ));
                    }
                    writer.push(rec)
                }
            }
        }
    }

    /// Append a sorted slice (all aux values 0).
    pub(crate) fn extend(&mut self, recs: &[Record]) -> Result<(), String> {
        if recs.is_empty() {
            return Ok(());
        }
        debug_assert!(self.len() == 0 || recs[0].key >= self.last_key);
        if self.len() == 0 {
            self.first_key = recs[0].key;
        }
        self.last_key = recs[recs.len() - 1].key;
        match &mut self.inner {
            WriterInner::Mem { recs: v, aux } => {
                v.extend_from_slice(recs);
                if !aux.is_empty() {
                    aux.resize(v.len(), 0);
                }
                Ok(())
            }
            WriterInner::Disk { writer, .. } => writer.extend(recs),
        }
    }

    /// Seal the storage (for disk: index + footer + fsync).
    pub(crate) fn finish(self) -> Result<PreparedRun, String> {
        let len = self.len();
        assert!(len > 0, "a run is never empty");
        let storage = match self.inner {
            WriterInner::Mem { recs, aux } => {
                debug_assert!(aux.is_empty() || aux.len() == recs.len());
                Storage::Mem { recs, aux }
            }
            WriterInner::Disk { writer, path } => {
                let index = writer.finish()?;
                Storage::Disk {
                    path,
                    page_records: self.page_records,
                    index,
                    has_aux: self.format.has_aux(),
                    delete_on_drop: AtomicBool::new(true),
                }
            }
        };
        Ok(PreparedRun {
            id: self.id,
            len,
            min_key: self.first_key,
            max_key: self.last_key,
            storage,
        })
    }

    /// Take the buffered records of an in-memory writer (the
    /// non-mutating merge path, [`super::compact::kway_merge_to_vec`]).
    /// Drops the aux column. Panics on a spilled writer.
    pub(crate) fn into_records(self) -> Vec<Record> {
        match self.inner {
            WriterInner::Mem { recs, .. } => recs,
            WriterInner::Disk { .. } => panic!("into_records on a spilled run writer"),
        }
    }
}

impl Run {
    /// Materialize storage for sorted records, spilling to `spill_dir`
    /// when one is configured. `records` must be non-empty and
    /// key-sorted (the seal path sorts; compaction merges sorted
    /// inputs). `aux` is either empty (all zero) or exactly one value
    /// per record; `legacy` forces the v1 page format on spill (only
    /// valid with an empty/all-zero aux column).
    pub(crate) fn prepare(
        records: Vec<Record>,
        aux: Vec<u32>,
        spill_dir: Option<&Path>,
        page_records: usize,
        legacy: bool,
    ) -> Result<PreparedRun, String> {
        assert!(!records.is_empty(), "a run is never empty");
        debug_assert!(
            records.windows(2).all(|w| w[0].key <= w[1].key),
            "runs hold key-sorted records"
        );
        debug_assert!(aux.is_empty() || aux.len() == records.len());
        // Drop an all-zero aux column — it carries no information and
        // would force every downstream run into the wide format.
        let aux = if aux.iter().all(|&a| a == 0) { Vec::new() } else { aux };
        if legacy && !aux.is_empty() {
            return Err("legacy v1 page format cannot store an aux column".to_string());
        }
        match spill_dir {
            None => {
                let mut w = RunWriter::mem(0);
                w.first_key = records[0].key;
                w.last_key = records[records.len() - 1].key;
                w.inner = WriterInner::Mem { recs: records, aux };
                w.finish()
            }
            Some(dir) => {
                let format = if legacy {
                    PageFormat::V1
                } else {
                    PageFormat::V2 { has_aux: !aux.is_empty() }
                };
                let mut w = RunWriter::new(Some(dir), page_records, records.len(), format)?;
                if aux.is_empty() {
                    w.extend(&records)?;
                } else {
                    for (r, a) in records.iter().zip(aux.iter()) {
                        w.push_wide(WideRecord::new(*r, *a))?;
                    }
                }
                w.finish()
            }
        }
    }

    /// [`Run::prepare`] + [`PreparedRun::into_run`] in one step, for
    /// callers whose generation range is already fixed (compaction
    /// commits, tests). Aux-free, current format.
    pub(crate) fn create(
        records: Vec<Record>,
        gen_lo: u64,
        gen_hi: u64,
        level: u32,
        spill_dir: Option<&Path>,
        page_records: usize,
    ) -> Result<Run, String> {
        Ok(Run::prepare(records, Vec::new(), spill_dir, page_records, false)?
            .into_run(gen_lo, gen_hi, level))
    }

    /// Reopen a spilled run from its manifest record (recovery path):
    /// validates the paged file's magics, checksum, and shape, then
    /// cross-checks length and key span against the manifest. The
    /// reopened run does NOT delete its file on drop — it is
    /// manifest-published by definition.
    pub(crate) fn open(meta: &RunMeta, dir: &Path) -> Result<Run, String> {
        let path = dir.join(format!("run-{}.bin", meta.id));
        let pf = page::PageFile::open(&path)?;
        if pf.num_records as u64 != meta.len || pf.num_records == 0 {
            return Err(format!(
                "{}: holds {} records, manifest says {}",
                path.display(),
                pf.num_records,
                meta.len
            ));
        }
        let (min_key, max_key) = (pf.index[0].min_key, pf.index[pf.index.len() - 1].max_key);
        if (min_key, max_key) != (meta.min_key, meta.max_key) {
            return Err(format!(
                "{}: key span {min_key}..={max_key} disagrees with manifest {}..={}",
                path.display(),
                meta.min_key,
                meta.max_key
            ));
        }
        Ok(Run {
            id: meta.id,
            gen_lo: meta.gen_lo,
            gen_hi: meta.gen_hi,
            level: meta.level,
            len: pf.num_records,
            min_key,
            max_key,
            storage: Storage::Disk {
                path,
                page_records: pf.page_records,
                index: pf.index,
                has_aux: pf.has_aux,
                delete_on_drop: AtomicBool::new(false),
            },
        })
    }

    /// Spill-file id (also the manifest identity).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The manifest record describing this run.
    pub fn meta(&self) -> RunMeta {
        RunMeta {
            id: self.id,
            gen_lo: self.gen_lo,
            gen_hi: self.gen_hi,
            level: self.level,
            len: self.len as u64,
            min_key: self.min_key,
            max_key: self.max_key,
        }
    }

    /// Oldest seal generation this run covers (the reader's sort key).
    pub fn gen_lo(&self) -> u64 {
        self.gen_lo
    }

    /// Newest seal generation this run covers.
    pub fn gen_hi(&self) -> u64 {
        self.gen_hi
    }

    /// Compaction depth: 0 for a freshly sealed run, `max + 1` of its
    /// inputs after a compaction.
    pub fn level(&self) -> u32 {
        self.level
    }

    /// Number of records in the run.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Runs are never empty; kept for API completeness.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Smallest key in the run.
    pub fn min_key(&self) -> i64 {
        self.min_key
    }

    /// Largest key in the run.
    pub fn max_key(&self) -> i64 {
        self.max_key
    }

    /// Whether this run is spilled to disk.
    pub fn is_spilled(&self) -> bool {
        matches!(self.storage, Storage::Disk { .. })
    }

    /// Key-range overlap test — compaction policies prefer overlapping
    /// windows (merging disjoint runs is legal but pure copying).
    pub fn overlaps(&self, other: &Run) -> bool {
        self.min_key <= other.max_key && other.min_key <= self.max_key
    }

    /// Set whether dropping the last reference deletes the spill file.
    /// No-op for memory runs. See the module docs for the lifecycle.
    pub(crate) fn set_delete_on_drop(&self, delete: bool) {
        if let Storage::Disk { delete_on_drop, .. } = &self.storage {
            delete_on_drop.store(delete, Ordering::Relaxed);
        }
    }

    /// Number of pages a cursor will read (0 for memory runs, whose
    /// cursor borrows the resident records directly).
    pub fn num_pages(&self) -> usize {
        match &self.storage {
            Storage::Mem { .. } => 0,
            Storage::Disk { index, .. } => index.len(),
        }
    }

    /// Whether this run carries a (non-trivial) out-of-line aux
    /// column. Compaction uses this to decide its output format: a
    /// merge of aux-free inputs stays aux-free.
    pub fn has_aux(&self) -> bool {
        match &self.storage {
            Storage::Mem { aux, .. } => !aux.is_empty(),
            Storage::Disk { has_aux, .. } => *has_aux,
        }
    }

    /// Materialize an owned copy of the run's records (clone for
    /// memory runs, sequential page reads for spilled runs). This is
    /// the ONE whole-run materialization left, for callers that truly
    /// need a `Vec` (tests, oracles, the model checker); scans and
    /// compaction stream through [`RunCursor`] instead.
    pub fn load(&self) -> Result<Vec<Record>, String> {
        match &self.storage {
            Storage::Mem { recs, .. } => Ok(recs.clone()),
            Storage::Disk { path, page_records, index, has_aux, .. } => {
                let mut file = std::fs::File::open(path)
                    .map_err(|e| format!("spill read {}: {e}", path.display()))?;
                let mut out = Vec::with_capacity(self.len);
                for p in 0..index.len() {
                    let (recs, _aux) =
                        page::read_page(&mut file, *page_records, self.len, *has_aux, p)?;
                    out.extend(recs);
                }
                if out.len() != self.len {
                    return Err(format!(
                        "spill file {} holds {} records, expected {}",
                        path.display(),
                        out.len(),
                        self.len
                    ));
                }
                Ok(out)
            }
        }
    }

    /// Like [`Run::load`], but keeps the aux column paired with each
    /// record (aux 0 for narrow runs). Same tests-and-oracles caveat.
    pub fn load_wide(&self) -> Result<Vec<WideRecord>, String> {
        match &self.storage {
            Storage::Mem { recs, aux } => Ok(recs
                .iter()
                .enumerate()
                .map(|(i, r)| WideRecord::new(*r, aux.get(i).copied().unwrap_or(0)))
                .collect()),
            Storage::Disk { path, page_records, index, has_aux, .. } => {
                let mut file = std::fs::File::open(path)
                    .map_err(|e| format!("spill read {}: {e}", path.display()))?;
                let mut out = Vec::with_capacity(self.len);
                for p in 0..index.len() {
                    let (recs, aux) =
                        page::read_page(&mut file, *page_records, self.len, *has_aux, p)?;
                    for (i, r) in recs.iter().enumerate() {
                        out.push(WideRecord::new(*r, aux.get(i).copied().unwrap_or(0)));
                    }
                }
                if out.len() != self.len {
                    return Err(format!(
                        "spill file {} holds {} records, expected {}",
                        path.display(),
                        out.len(),
                        self.len
                    ));
                }
                Ok(out)
            }
        }
    }
}

impl std::fmt::Debug for Run {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Run")
            .field("id", &self.id)
            .field("gen", &(self.gen_lo..=self.gen_hi))
            .field("level", &self.level)
            .field("len", &self.len)
            .field("keys", &(self.min_key..=self.max_key))
            .field("spilled", &self.is_spilled())
            .finish()
    }
}

/// A streaming reader over one run: holds the [`Run`] alive (pinning
/// its spill file even if a concurrent compaction retires and unlinks
/// it — POSIX keeps the bytes readable through the open fd) and
/// buffers **one page at a time** for spilled runs. Memory runs are
/// borrowed in place, so a cursor's resident footprint is
/// O(page_records), never O(run).
///
/// Invariant: `buffered()` is empty iff the cursor is exhausted —
/// advancing past a page boundary eagerly loads the next page, so
/// `peek()` is always O(1) on live cursors.
pub struct RunCursor {
    run: Arc<Run>,
    consumed: usize,
    state: CursorState,
}

enum CursorState {
    Mem { pos: usize },
    Disk {
        file: std::fs::File,
        page: Vec<Record>,
        /// Aux values parallel to `page` (empty = all zero / narrow
        /// file).
        aux: Vec<u32>,
        page_pos: usize,
        next_page: usize,
    },
}

impl RunCursor {
    /// Open a cursor at the start of `run` (loads page 0 of a spilled
    /// run).
    pub fn new(run: Arc<Run>) -> Result<RunCursor, String> {
        let state = match &run.storage {
            Storage::Mem { .. } => CursorState::Mem { pos: 0 },
            Storage::Disk { path, page_records, has_aux, .. } => {
                let mut file = std::fs::File::open(path)
                    .map_err(|e| format!("cursor open {}: {e}", path.display()))?;
                let (page, aux) = page::read_page(&mut file, *page_records, run.len, *has_aux, 0)?;
                CursorState::Disk { file, page, aux, page_pos: 0, next_page: 1 }
            }
        };
        Ok(RunCursor { run, consumed: 0, state })
    }

    /// The run this cursor reads.
    pub fn run(&self) -> &Arc<Run> {
        &self.run
    }

    /// The records currently resident, starting at the cursor head.
    /// Empty iff the cursor is exhausted.
    pub fn buffered(&self) -> &[Record] {
        match &self.state {
            CursorState::Mem { pos } => match &self.run.storage {
                Storage::Mem { recs, .. } => &recs[*pos..],
                Storage::Disk { .. } => unreachable!("mem cursor on disk run"),
            },
            CursorState::Disk { page, page_pos, .. } => &page[*page_pos..],
        }
    }

    /// Aux values parallel to [`RunCursor::buffered`]. May be SHORTER
    /// than `buffered()` (in particular empty) when the run carries no
    /// aux column — missing entries read as 0. Callers should index
    /// with `aux.get(i).copied().unwrap_or(0)`.
    pub fn buffered_aux(&self) -> &[u32] {
        match &self.state {
            CursorState::Mem { pos } => match &self.run.storage {
                Storage::Mem { aux, .. } => {
                    if aux.is_empty() {
                        &[]
                    } else {
                        &aux[*pos..]
                    }
                }
                Storage::Disk { .. } => unreachable!("mem cursor on disk run"),
            },
            CursorState::Disk { aux, page_pos, .. } => {
                if aux.is_empty() {
                    &[]
                } else {
                    &aux[*page_pos..]
                }
            }
        }
    }

    /// The record at the cursor head, if any.
    pub fn peek(&self) -> Option<Record> {
        self.buffered().first().copied()
    }

    /// Whether records beyond `buffered()` exist on disk (false for
    /// memory runs and for a spilled run's last page).
    pub fn has_unloaded(&self) -> bool {
        match &self.state {
            CursorState::Mem { .. } => false,
            CursorState::Disk { next_page, .. } => *next_page < self.run.num_pages(),
        }
    }

    /// Consume `k <= buffered().len()` records, eagerly loading the
    /// next page when the current one is drained.
    pub fn advance_buffered(&mut self, k: usize) -> Result<(), String> {
        if k == 0 {
            return Ok(());
        }
        assert!(k <= self.buffered().len(), "advance past the buffered window");
        self.consumed += k;
        match &mut self.state {
            CursorState::Mem { pos } => {
                *pos += k;
            }
            CursorState::Disk { file, page, aux, page_pos, next_page } => {
                *page_pos += k;
                if *page_pos >= page.len() {
                    let (page_records, num_pages, has_aux) = match &self.run.storage {
                        Storage::Disk { page_records, index, has_aux, .. } => {
                            (*page_records, index.len(), *has_aux)
                        }
                        Storage::Mem { .. } => unreachable!("disk cursor on mem run"),
                    };
                    if *next_page < num_pages {
                        let (p, a) =
                            page::read_page(file, page_records, self.run.len, has_aux, *next_page)?;
                        *page = p;
                        *aux = a;
                        *page_pos = 0;
                        *next_page += 1;
                    } else {
                        page.clear();
                        aux.clear();
                        *page_pos = 0;
                    }
                }
            }
        }
        Ok(())
    }

    /// Pop the head record.
    pub fn next_record(&mut self) -> Result<Option<Record>, String> {
        match self.peek() {
            None => Ok(None),
            Some(r) => {
                self.advance_buffered(1)?;
                Ok(Some(r))
            }
        }
    }

    /// Pop the head record with its aux value (0 for narrow runs).
    pub fn next_wide(&mut self) -> Result<Option<WideRecord>, String> {
        match self.peek() {
            None => Ok(None),
            Some(r) => {
                let aux = self.buffered_aux().first().copied().unwrap_or(0);
                self.advance_buffered(1)?;
                Ok(Some(WideRecord::new(r, aux)))
            }
        }
    }

    /// Records not yet consumed.
    pub fn remaining(&self) -> usize {
        self.run.len - self.consumed
    }

    /// Records this cursor holds in memory right now (0 for memory
    /// runs — those are borrowed, not copied).
    pub fn resident_records(&self) -> usize {
        match &self.state {
            CursorState::Mem { .. } => 0,
            CursorState::Disk { page, page_pos, .. } => page.len() - *page_pos,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recs(keys: &[i64]) -> Vec<Record> {
        keys.iter().enumerate().map(|(i, &k)| Record::new(k, i as u64)).collect()
    }

    fn pairs(records: &[Record]) -> Vec<(i64, u64)> {
        records.iter().map(|r| (r.key, r.tag)).collect()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let records = recs(&[-5, 0, 3, 3, i64::MAX]);
        let bytes = encode_records(&records);
        assert_eq!(bytes.len(), records.len() * RECORD_BYTES);
        let back = decode_records(&bytes).unwrap();
        assert_eq!(pairs(&back), pairs(&records));
        assert!(decode_records(&bytes[..RECORD_BYTES + 1]).is_err());
    }

    #[test]
    fn mem_run_metadata_and_load() {
        let run = Run::create(recs(&[1, 2, 2, 9]), 4, 4, 0, None, 1024).unwrap();
        assert_eq!((run.gen_lo(), run.gen_hi(), run.level(), run.len()), (4, 4, 0, 4));
        assert_eq!((run.min_key(), run.max_key()), (1, 9));
        assert!(!run.is_spilled());
        assert_eq!(run.num_pages(), 0);
        let data = run.load().unwrap();
        assert_eq!(data.iter().map(|r| r.key).collect::<Vec<_>>(), vec![1, 2, 2, 9]);
        let m = run.meta();
        assert_eq!((m.gen_lo, m.gen_hi, m.level, m.len, m.min_key, m.max_key), (4, 4, 0, 4, 1, 9));
    }

    #[test]
    fn overlap_detection() {
        let a = Run::create(recs(&[0, 10]), 0, 0, 0, None, 1024).unwrap();
        let b = Run::create(recs(&[5, 20]), 1, 1, 0, None, 1024).unwrap();
        let c = Run::create(recs(&[11, 30]), 2, 2, 0, None, 1024).unwrap();
        assert!(a.overlaps(&b));
        assert!(b.overlaps(&a));
        assert!(!a.overlaps(&c));
        assert!(b.overlaps(&c));
    }

    #[test]
    fn mem_cursor_streams_in_order() {
        let run = Arc::new(Run::create(recs(&[1, 3, 3, 7]), 0, 0, 0, None, 1024).unwrap());
        let mut cur = RunCursor::new(run).unwrap();
        assert_eq!(cur.remaining(), 4);
        assert_eq!(cur.resident_records(), 0, "memory runs are borrowed, not copied");
        assert!(!cur.has_unloaded());
        assert_eq!(cur.peek().map(|r| r.key), Some(1));
        assert_eq!(cur.buffered().len(), 4);
        cur.advance_buffered(2).unwrap();
        assert_eq!(pairs(cur.buffered()), vec![(3, 2), (7, 3)]);
        assert_eq!(cur.next_record().unwrap().map(|r| (r.key, r.tag)), Some((3, 2)));
        assert_eq!(cur.next_record().unwrap().map(|r| r.key), Some(7));
        assert_eq!(cur.next_record().unwrap(), None);
        assert_eq!((cur.remaining(), cur.peek()), (0, None));
    }

    #[test]
    #[cfg(not(miri))] // touches the real filesystem
    fn spilled_run_pages_cursor_and_lifecycle() {
        let dir = std::env::temp_dir().join(format!("traff-run-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let records = recs(&[3, 4, 4, 4, 7, 8, 9, 9, 12, 15, 20]); // 11 records
        let run = Run::create(records.clone(), 0, 2, 1, Some(&dir), 4).unwrap();
        assert!(run.is_spilled());
        assert_eq!(run.num_pages(), 3, "ceil(11/4)");
        let path = dir.join(format!("run-{}.bin", run.id()));
        assert!(path.exists());
        assert_eq!(pairs(&run.load().unwrap()), pairs(&records));

        // Cursor reads one page at a time.
        let run = Arc::new(run);
        let mut cur = RunCursor::new(Arc::clone(&run)).unwrap();
        assert!(cur.has_unloaded());
        assert!(cur.resident_records() <= 4);
        let mut streamed = Vec::new();
        while let Some(r) = cur.next_record().unwrap() {
            assert!(cur.resident_records() <= 4, "never more than one page resident");
            streamed.push(r);
        }
        assert_eq!(pairs(&streamed), pairs(&records));
        assert!(!cur.has_unloaded());

        // Published runs survive drop; unpublished ones are deleted.
        run.set_delete_on_drop(false);
        let meta = run.meta();
        drop(cur);
        drop(run);
        assert!(path.exists(), "manifest-published run file persists");

        // Recovery reopens from the manifest record and cross-checks.
        let reopened = Run::open(&meta, &dir).unwrap();
        assert_eq!(reopened.meta(), meta);
        assert_eq!(pairs(&reopened.load().unwrap()), pairs(&records));
        let mut bad = meta;
        bad.len += 1;
        assert!(Run::open(&bad, &dir).is_err());
        let mut bad = meta;
        bad.max_key -= 1;
        assert!(Run::open(&bad, &dir).is_err());

        reopened.set_delete_on_drop(true); // retired by "compaction"
        drop(reopened);
        assert!(!path.exists(), "retired run file is deleted");
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    #[cfg(not(miri))]
    fn run_writer_streams_to_disk() {
        let dir = std::env::temp_dir().join(format!("traff-runw-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = RunWriter::new(Some(&dir), 3, 0, PageFormat::V2 { has_aux: false }).unwrap();
        w.push(Record::new(-2, 0)).unwrap();
        w.extend(&recs(&[1, 1, 5, 9])).unwrap();
        assert_eq!(w.len(), 5);
        let run = w.finish().unwrap().into_run(7, 9, 2);
        assert_eq!((run.len(), run.min_key(), run.max_key()), (5, -2, 9));
        assert_eq!(run.num_pages(), 2);
        let keys: Vec<i64> = run.load().unwrap().iter().map(|r| r.key).collect();
        assert_eq!(keys, vec![-2, 1, 1, 5, 9]);
        drop(run); // unpublished: deletes its file
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn run_writer_mem_into_records() {
        let mut w = RunWriter::mem(4);
        w.extend(&recs(&[2, 4, 4])).unwrap();
        let out = w.into_records();
        assert_eq!(out.iter().map(|r| r.key).collect::<Vec<_>>(), vec![2, 4, 4]);
    }

    #[test]
    fn wide_record_orders_by_key_only() {
        let a = WideRecord::new(Record::new(5, 100), 7);
        let b = WideRecord::new(Record::new(5, 200), 0);
        let c = WideRecord::new(Record::new(6, 0), 0);
        assert_eq!(a, b, "equal keys compare Equal regardless of tag/aux");
        assert!(a < c);
        assert_eq!(a.cmp(&b), std::cmp::Ordering::Equal);
        let w = WideRecord::new(Record::new(0, (3u64 << 32) | 42), 2);
        assert_eq!(w.full_seq(), (2u64 << 32) | 3, "aux carries the seq high half");
    }

    #[test]
    fn wide_mem_run_roundtrips_aux() {
        // A mem run with a mixed aux column: prepare keeps the pairing
        // and cursors hand it back next to each record.
        let records = recs(&[1, 2, 2, 9]);
        let aux = vec![0, 3, 0, 7];
        let run = Arc::new(
            Run::prepare(records, aux.clone(), None, 1024, false)
                .unwrap()
                .into_run(0, 0, 0),
        );
        assert!(run.has_aux());
        let wide = run.load_wide().unwrap();
        assert_eq!(wide.iter().map(|w| w.aux).collect::<Vec<_>>(), aux);
        let mut cur = RunCursor::new(Arc::clone(&run)).unwrap();
        let mut seen = Vec::new();
        while let Some(w) = cur.next_wide().unwrap() {
            seen.push((w.rec.key, w.aux));
        }
        assert_eq!(seen, vec![(1, 0), (2, 3), (2, 0), (9, 7)]);

        // An all-zero aux column collapses back to a narrow run.
        let run = Run::prepare(recs(&[1, 2]), vec![0, 0], None, 1024, false)
            .unwrap()
            .into_run(1, 1, 0);
        assert!(!run.has_aux());
        // Legacy format refuses a real aux column.
        assert!(Run::prepare(recs(&[1, 2]), vec![0, 5], None, 1024, true).is_err());
    }

    #[test]
    #[cfg(not(miri))] // touches the real filesystem
    fn wide_spilled_run_roundtrips_aux() {
        let dir = std::env::temp_dir().join(format!("traff-widerun-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let records = recs(&[3, 4, 4, 7, 8, 9, 12]); // 7 records, 3 pages of 3
        let aux: Vec<u32> = (0..7).map(|i| if i % 2 == 0 { i as u32 + 1 } else { 0 }).collect();
        let run = Arc::new(
            Run::prepare(records.clone(), aux.clone(), Some(&dir), 3, false)
                .unwrap()
                .into_run(0, 0, 0),
        );
        assert!(run.is_spilled() && run.has_aux());
        let wide = run.load_wide().unwrap();
        assert_eq!(wide.iter().map(|w| w.aux).collect::<Vec<_>>(), aux);
        assert_eq!(pairs(&run.load().unwrap()), pairs(&records));

        // Cursor pages the aux column alongside the records.
        let mut cur = RunCursor::new(Arc::clone(&run)).unwrap();
        let mut seen = Vec::new();
        while let Some(w) = cur.next_wide().unwrap() {
            seen.push(w.aux);
        }
        assert_eq!(seen, aux);

        // Reopen via the manifest record: has_aux survives recovery.
        run.set_delete_on_drop(false);
        let meta = run.meta();
        drop(cur);
        drop(run);
        let reopened = Run::open(&meta, &dir).unwrap();
        assert!(reopened.has_aux());
        assert_eq!(
            reopened.load_wide().unwrap().iter().map(|w| w.aux).collect::<Vec<_>>(),
            aux
        );
        reopened.set_delete_on_drop(true);
        drop(reopened);
        let _ = std::fs::remove_dir(&dir);
    }
}

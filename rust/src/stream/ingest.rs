//! Stream ingestion: buffer unbounded record arrivals into bounded
//! in-memory runs and seal full runs into the [`RunStore`].
//!
//! An [`Ingestor`] owns the one mutable piece of the pipeline — the
//! current unsorted buffer. Records accumulate until the configured
//! `run_capacity`, then the buffer is **sorted stably** (the paper's
//! [`parallel_merge_sort`], so duplicate keys keep their arrival
//! order) and sealed as a level-0 run; [`Ingestor::flush`] seals a
//! partial buffer. The generation the store stamps on each seal is
//! what lets readers and the compactor preserve arrival order for
//! duplicates *across* runs (see [`super::store`]).
//!
//! Buffered (unsealed) records are not yet visible to
//! [`super::reader`] scans — the stream's visibility unit is the
//! sealed run. Callers wanting read-your-writes flush first.

use super::store::RunStore;
use super::StreamError;
use crate::core::record::Record;
use crate::core::sort::parallel_merge_sort;
use std::sync::Arc;

/// Buffering front end of one ingest stream. See the module docs.
///
/// One `Ingestor` serializes its callers; for a write path that scales
/// with submitter threads, see [`super::writer`].
pub struct Ingestor {
    store: Arc<RunStore>,
    buf: Vec<Record>,
    /// Records pushed over this ingestor's lifetime — the auto-tag
    /// sequence ([`Ingestor::push_key`]) and the caller-visible ingest
    /// order oracle.
    seq: u64,
}

impl Ingestor {
    /// A fresh ingestor over `store` (capacity and sort parallelism
    /// come from the store's [`super::StreamConfig`], which the store
    /// validated at construction — no clamping here).
    pub fn new(store: Arc<RunStore>) -> Ingestor {
        let cap = store.config().run_capacity;
        Ingestor { store, buf: Vec::with_capacity(cap), seq: 0 }
    }

    /// Records pushed so far (== the next auto-assigned tag).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Records currently buffered (not yet sealed).
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Ingest one record with an explicit tag. Returns the sealed
    /// run's generation when this push filled the buffer.
    pub fn push(&mut self, rec: Record) -> Result<Option<u64>, StreamError> {
        self.buf.push(rec);
        self.seq += 1;
        if self.buf.len() >= self.store.config().run_capacity {
            return self.seal();
        }
        Ok(None)
    }

    /// Ingest one key with an auto-assigned tag (the ingest sequence
    /// number — the stability observation convention). Returns the
    /// tag, plus the sealed generation if the buffer filled.
    pub fn push_key(&mut self, key: i64) -> Result<(u64, Option<u64>), StreamError> {
        let tag = self.seq;
        let sealed = self.push(Record::new(key, tag))?;
        Ok((tag, sealed))
    }

    /// Seal whatever is buffered (possibly a partial run). `None` when
    /// the buffer was empty.
    pub fn flush(&mut self) -> Result<Option<u64>, StreamError> {
        if self.buf.is_empty() {
            return Ok(None);
        }
        self.seal()
    }

    fn seal(&mut self) -> Result<Option<u64>, StreamError> {
        let cap = self.store.config().run_capacity;
        let mut records = std::mem::replace(&mut self.buf, Vec::with_capacity(cap));
        // Stable sort: duplicate keys keep their arrival order inside
        // the run; the generation stamp orders them across runs.
        parallel_merge_sort(&mut records, self.store.config().threads);
        self.store.seal(records)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::StreamConfig;

    fn store(cap: usize) -> Arc<RunStore> {
        Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: cap,
                fanout: 64,
                threads: 2,
                ..StreamConfig::default()
            })
            .unwrap(),
        )
    }

    #[test]
    fn seals_exactly_at_capacity() {
        let store = store(4);
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut sealed = Vec::new();
        for key in [5i64, 1, 5, 2, 9, 0, 3] {
            let (_, gen) = ing.push_key(key).unwrap();
            if let Some(g) = gen {
                sealed.push(g);
            }
        }
        assert_eq!(sealed.len(), 1, "one full run of 4 sealed");
        assert_eq!(ing.pending(), 3);
        assert_eq!(ing.seq(), 7);
        assert_eq!(store.record_count(), 4);
        let g = ing.flush().unwrap().expect("partial run seals");
        assert!(g > sealed[0]);
        assert_eq!(ing.pending(), 0);
        assert_eq!(store.record_count(), 7);
        assert_eq!(ing.flush().unwrap(), None, "empty flush is a no-op");
    }

    #[test]
    fn sealed_runs_are_sorted_and_stable() {
        let store = store(6);
        let mut ing = Ingestor::new(Arc::clone(&store));
        // Duplicates inside one run: tags must stay in arrival order.
        for key in [3i64, 1, 3, 3, 1, 2] {
            ing.push_key(key).unwrap();
        }
        let snap = store.snapshot();
        assert_eq!(snap.len(), 1);
        let data = snap[0].load().unwrap();
        let keys: Vec<i64> = data.iter().map(|r| r.key).collect();
        assert_eq!(keys, vec![1, 1, 2, 3, 3, 3]);
        let tags: Vec<u64> = data.iter().map(|r| r.tag).collect();
        assert_eq!(tags, vec![1, 4, 5, 0, 2, 3], "stable: arrival order within equal keys");
    }
}

//! The paged spill-file format: fixed-size record pages plus a
//! checksummed per-page key index, so readers can stream one page at a
//! time instead of materializing a whole run.
//!
//! # Layout (v2; v1 files remain readable)
//!
//! ```text
//! ┌──────────────────────┐ offset 0
//! │ header (16 B)        │ magic "TMPG0002" ·· page_records u32 ·· flags u32
//! ├──────────────────────┤ offset 16
//! │ record pages         │ page i = [n_i × 16 B records (key i64 LE, tag u64 LE)]
//! │                      │          [n_i × 4 B aux u32 LE — only if flags bit 0]
//! │                      │ n_i = page_records except the last page (partial,
//! │                      │ no padding); pages are laid out back to back
//! ├──────────────────────┤
//! │ page index           │ num_pages × (min_key i64 LE, max_key i64 LE)
//! ├──────────────────────┤
//! │ footer (32 B)        │ num_records u64 ·· num_pages u32 ·· page_records u32
//! │                      │ ·· fnv1a64(index bytes) u64 ·· magic "TMPGEND1"
//! └──────────────────────┘
//! ```
//!
//! **Versioning:** the v1 format (magic `TMPG0001`, flags always 0)
//! is the same layout with no aux column; [`PageFile::open`] accepts
//! both magics, and a v1 file simply reads back with every aux value
//! zero. The aux column is the out-of-line high half of the 64-bit
//! ingest sequence — it is what lifts the packed-tag record cap from
//! 2^32 to 2^64 without widening the hot 16-byte record. New files are
//! written v2 (with the aux column only when the run actually carries
//! nonzero aux values); [`super::StreamConfig::legacy_pages`] forces
//! v1 output for downgrade compatibility and re-imposes the cap.
//!
//! All integers little-endian. The record area is written first and
//! streamed (a crash mid-write leaves a file without a valid footer —
//! [`PageFile::open`] rejects it, and the store's manifest never
//! references it); the index + footer land in one final flush followed
//! by `fsync`. The footer checksum covers the index, and the index
//! bounds are revalidated against the record area on open, so a
//! truncated or torn file is detected rather than read.

use crate::core::record::Record;
use crate::util::fnv1a64;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::run::{decode_records, RECORD_BYTES};

/// Bytes in the file header.
pub const HEADER_BYTES: usize = 16;
/// Bytes per page-index entry (min_key + max_key).
pub const INDEX_ENTRY_BYTES: usize = 16;
/// Bytes in the file footer.
pub const FOOTER_BYTES: usize = 32;
/// Header magic of the legacy v1 format (no aux column, flags 0).
pub const HEADER_MAGIC: &[u8; 8] = b"TMPG0001";
/// Header magic of the v2 format (flags word is live).
pub const HEADER_MAGIC_V2: &[u8; 8] = b"TMPG0002";
/// Footer magic (shared by both versions).
pub const FOOTER_MAGIC: &[u8; 8] = b"TMPGEND1";
/// v2 header flag: each page carries a trailing `n × u32` aux column.
pub const FLAG_HAS_AUX: u32 = 1;
/// Bytes per out-of-line aux value.
pub const AUX_BYTES: usize = 4;

/// Which on-disk format a [`PageFileWriter`] emits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageFormat {
    /// Legacy format: magic `TMPG0001`, no aux column possible.
    V1,
    /// Current format: magic `TMPG0002`; the aux column is present
    /// only when `has_aux` is set.
    V2 {
        /// Whether pages carry the out-of-line aux column.
        has_aux: bool,
    },
}

impl PageFormat {
    /// Whether this format writes the per-page aux column.
    pub fn has_aux(self) -> bool {
        matches!(self, PageFormat::V2 { has_aux: true })
    }
}

/// Per-page key span, resident while the run is live (16 B per page —
/// the only metadata a scan needs to keep in memory).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageMeta {
    /// Smallest key on the page.
    pub min_key: i64,
    /// Largest key on the page.
    pub max_key: i64,
}

/// Encode the 16-byte header. Pure — unit-tested under Miri.
pub fn encode_header(page_records: u32, format: PageFormat) -> [u8; HEADER_BYTES] {
    let mut out = [0u8; HEADER_BYTES];
    let (magic, flags) = match format {
        PageFormat::V1 => (HEADER_MAGIC, 0u32),
        PageFormat::V2 { has_aux } => {
            (HEADER_MAGIC_V2, if has_aux { FLAG_HAS_AUX } else { 0 })
        }
    };
    out[..8].copy_from_slice(magic);
    out[8..12].copy_from_slice(&page_records.to_le_bytes());
    out[12..16].copy_from_slice(&flags.to_le_bytes());
    out
}

/// Decode the aux column of one page. Pure.
pub fn decode_aux(bytes: &[u8]) -> Result<Vec<u32>, String> {
    if bytes.len() % AUX_BYTES != 0 {
        return Err(format!(
            "aux column corrupt: {} bytes is not a multiple of {AUX_BYTES}",
            bytes.len()
        ));
    }
    let mut out = Vec::with_capacity(bytes.len() / AUX_BYTES);
    for chunk in bytes.chunks_exact(AUX_BYTES) {
        let mut b = [0u8; AUX_BYTES];
        b.copy_from_slice(chunk);
        out.push(u32::from_le_bytes(b));
    }
    Ok(out)
}

/// Encode the page index. Pure.
pub fn encode_index(index: &[PageMeta]) -> Vec<u8> {
    let mut out = Vec::with_capacity(index.len() * INDEX_ENTRY_BYTES);
    for m in index {
        out.extend_from_slice(&m.min_key.to_le_bytes());
        out.extend_from_slice(&m.max_key.to_le_bytes());
    }
    out
}

/// Decode the page index. Pure.
pub fn decode_index(bytes: &[u8]) -> Result<Vec<PageMeta>, String> {
    if bytes.len() % INDEX_ENTRY_BYTES != 0 {
        return Err(format!(
            "page index corrupt: {} bytes is not a multiple of {INDEX_ENTRY_BYTES}",
            bytes.len()
        ));
    }
    let mut out = Vec::with_capacity(bytes.len() / INDEX_ENTRY_BYTES);
    for chunk in bytes.chunks_exact(INDEX_ENTRY_BYTES) {
        let mut lo = [0u8; 8];
        let mut hi = [0u8; 8];
        lo.copy_from_slice(&chunk[..8]);
        hi.copy_from_slice(&chunk[8..]);
        out.push(PageMeta {
            min_key: i64::from_le_bytes(lo),
            max_key: i64::from_le_bytes(hi),
        });
    }
    Ok(out)
}

/// Encode the 32-byte footer. Pure.
pub fn encode_footer(num_records: u64, num_pages: u32, page_records: u32, index_checksum: u64) -> [u8; FOOTER_BYTES] {
    let mut out = [0u8; FOOTER_BYTES];
    out[..8].copy_from_slice(&num_records.to_le_bytes());
    out[8..12].copy_from_slice(&num_pages.to_le_bytes());
    out[12..16].copy_from_slice(&page_records.to_le_bytes());
    out[16..24].copy_from_slice(&index_checksum.to_le_bytes());
    out[24..].copy_from_slice(FOOTER_MAGIC);
    out
}

/// Decode the footer: `(num_records, num_pages, page_records,
/// index_checksum)`. Pure.
pub fn decode_footer(bytes: &[u8]) -> Result<(u64, u32, u32, u64), String> {
    if bytes.len() != FOOTER_BYTES {
        return Err(format!("page footer is {} bytes, expected {FOOTER_BYTES}", bytes.len()));
    }
    if &bytes[24..] != FOOTER_MAGIC {
        return Err("page footer magic mismatch (truncated or torn file)".to_string());
    }
    let mut b8 = [0u8; 8];
    let mut b4 = [0u8; 4];
    b8.copy_from_slice(&bytes[..8]);
    let num_records = u64::from_le_bytes(b8);
    b4.copy_from_slice(&bytes[8..12]);
    let num_pages = u32::from_le_bytes(b4);
    b4.copy_from_slice(&bytes[12..16]);
    let page_records = u32::from_le_bytes(b4);
    b8.copy_from_slice(&bytes[16..24]);
    let checksum = u64::from_le_bytes(b8);
    Ok((num_records, num_pages, page_records, checksum))
}

/// Streaming writer for one paged run file: records are pushed in key
/// order and buffered through a `BufWriter`; [`PageFileWriter::finish`]
/// appends the index + footer and fsyncs. On any error the caller
/// drops the writer and deletes the file — a file without a valid
/// footer is never published.
pub struct PageFileWriter {
    file: std::io::BufWriter<std::fs::File>,
    path: PathBuf,
    page_records: usize,
    format: PageFormat,
    index: Vec<PageMeta>,
    len: usize,
    /// Records on the (partial) current page.
    in_page: usize,
    /// Encoded aux column of the current page (only when the format
    /// carries one); flushed when the page closes.
    aux_page: Vec<u8>,
    cur_min: i64,
    cur_max: i64,
}

impl PageFileWriter {
    /// Create (truncate) `path` and write the header.
    pub fn create(
        path: &Path,
        page_records: usize,
        format: PageFormat,
    ) -> Result<PageFileWriter, String> {
        assert!(page_records > 0, "page_records must be positive");
        let file = std::fs::File::create(path)
            .map_err(|e| format!("create {}: {e}", path.display()))?;
        let mut file = std::io::BufWriter::new(file);
        file.write_all(&encode_header(page_records as u32, format))
            .map_err(|e| format!("write header {}: {e}", path.display()))?;
        Ok(PageFileWriter {
            file,
            path: path.to_path_buf(),
            page_records,
            format,
            index: Vec::new(),
            len: 0,
            in_page: 0,
            aux_page: Vec::new(),
            cur_min: 0,
            cur_max: 0,
        })
    }

    /// Records written so far.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether nothing has been written yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append one record (must be pushed in key order).
    pub fn push(&mut self, rec: Record) -> Result<(), String> {
        self.push_wide(rec, 0)
    }

    /// Append one record with its out-of-line aux value (must be
    /// pushed in key order). A nonzero aux requires a format with the
    /// aux column — the seal path decides that before creating the
    /// writer.
    pub fn push_wide(&mut self, rec: Record, aux: u32) -> Result<(), String> {
        debug_assert!(self.in_page > 0 || self.len % self.page_records == 0);
        if aux != 0 && !self.format.has_aux() {
            return Err(format!(
                "{}: nonzero aux value in a format without an aux column",
                self.path.display()
            ));
        }
        if self.in_page == 0 {
            self.cur_min = rec.key;
        }
        debug_assert!(self.in_page == 0 || rec.key >= self.cur_max, "pages hold sorted records");
        self.cur_max = rec.key;
        let mut buf = [0u8; RECORD_BYTES];
        buf[..8].copy_from_slice(&rec.key.to_le_bytes());
        buf[8..].copy_from_slice(&rec.tag.to_le_bytes());
        self.file
            .write_all(&buf)
            .map_err(|e| format!("write record {}: {e}", self.path.display()))?;
        if self.format.has_aux() {
            self.aux_page.extend_from_slice(&aux.to_le_bytes());
        }
        self.len += 1;
        self.in_page += 1;
        if self.in_page == self.page_records {
            self.close_page()?;
        }
        Ok(())
    }

    /// Close the current page: record its key span and (in aux
    /// formats) write the buffered aux column behind its records.
    fn close_page(&mut self) -> Result<(), String> {
        self.index.push(PageMeta { min_key: self.cur_min, max_key: self.cur_max });
        self.in_page = 0;
        if self.format.has_aux() {
            self.file
                .write_all(&self.aux_page)
                .map_err(|e| format!("write aux column {}: {e}", self.path.display()))?;
            self.aux_page.clear();
        }
        Ok(())
    }

    /// Append a sorted slice of records.
    pub fn extend(&mut self, recs: &[Record]) -> Result<(), String> {
        for &r in recs {
            self.push(r)?;
        }
        Ok(())
    }

    /// Seal the file: close the partial page, write index + footer,
    /// flush, fsync. Returns the page index.
    pub fn finish(mut self) -> Result<Vec<PageMeta>, String> {
        if self.in_page > 0 {
            self.close_page()?;
        }
        let index_bytes = encode_index(&self.index);
        self.file
            .write_all(&index_bytes)
            .map_err(|e| format!("write index {}: {e}", self.path.display()))?;
        let footer = encode_footer(
            self.len as u64,
            self.index.len() as u32,
            self.page_records as u32,
            fnv1a64(&index_bytes),
        );
        self.file
            .write_all(&footer)
            .map_err(|e| format!("write footer {}: {e}", self.path.display()))?;
        self.file
            .flush()
            .map_err(|e| format!("flush {}: {e}", self.path.display()))?;
        self.file
            .get_ref()
            .sync_all()
            .map_err(|e| format!("fsync {}: {e}", self.path.display()))?;
        Ok(std::mem::take(&mut self.index))
    }
}

/// An opened, validated paged run file: the resident metadata a
/// [`super::run::Run`] keeps (index + shape); record pages are read on
/// demand with [`read_page`].
pub struct PageFile {
    /// Records per full page.
    pub page_records: usize,
    /// Total records in the file.
    pub num_records: usize,
    /// Whether pages carry the out-of-line aux column (v2 only; a v1
    /// file reads back with all aux values zero).
    pub has_aux: bool,
    /// Per-page key spans.
    pub index: Vec<PageMeta>,
}

impl PageFile {
    /// Open and validate `path`: magics, shape arithmetic, total file
    /// size, and the index checksum. Any mismatch (truncation, torn
    /// write, junk) is an error — recovery treats such files as
    /// orphans.
    pub fn open(path: &Path) -> Result<PageFile, String> {
        let mut file = std::fs::File::open(path)
            .map_err(|e| format!("open {}: {e}", path.display()))?;
        let total = file
            .metadata()
            .map_err(|e| format!("stat {}: {e}", path.display()))?
            .len();
        let min = (HEADER_BYTES + FOOTER_BYTES) as u64;
        if total < min {
            return Err(format!(
                "{}: {total} bytes is smaller than an empty paged run ({min})",
                path.display()
            ));
        }
        let mut header = [0u8; HEADER_BYTES];
        file.read_exact(&mut header)
            .map_err(|e| format!("read header {}: {e}", path.display()))?;
        let v2 = match &header[..8] {
            m if m == HEADER_MAGIC => false,
            m if m == HEADER_MAGIC_V2 => true,
            _ => return Err(format!("{}: bad header magic", path.display())),
        };
        let mut fl = [0u8; 4];
        fl.copy_from_slice(&header[12..16]);
        let flags = u32::from_le_bytes(fl);
        if (!v2 && flags != 0) || (v2 && flags & !FLAG_HAS_AUX != 0) {
            return Err(format!("{}: unknown header flags {flags:#x}", path.display()));
        }
        let has_aux = v2 && flags & FLAG_HAS_AUX != 0;
        let mut footer = [0u8; FOOTER_BYTES];
        file.seek(SeekFrom::End(-(FOOTER_BYTES as i64)))
            .map_err(|e| format!("seek footer {}: {e}", path.display()))?;
        file.read_exact(&mut footer)
            .map_err(|e| format!("read footer {}: {e}", path.display()))?;
        let (num_records, num_pages, page_records, checksum) =
            decode_footer(&footer).map_err(|e| format!("{}: {e}", path.display()))?;
        let mut hp = [0u8; 4];
        hp.copy_from_slice(&header[8..12]);
        let header_page_records = u32::from_le_bytes(hp);
        if header_page_records != page_records || page_records == 0 {
            return Err(format!(
                "{}: header/footer page size mismatch ({header_page_records} vs {page_records})",
                path.display()
            ));
        }
        let expect_pages = crate::util::div_ceil(num_records as usize, page_records as usize);
        if expect_pages != num_pages as usize {
            return Err(format!(
                "{}: {num_records} records at {page_records}/page needs {expect_pages} pages, footer says {num_pages}",
                path.display()
            ));
        }
        let record_stride = RECORD_BYTES + if has_aux { AUX_BYTES } else { 0 };
        let expect_total = (HEADER_BYTES
            + num_records as usize * record_stride
            + num_pages as usize * INDEX_ENTRY_BYTES
            + FOOTER_BYTES) as u64;
        if total != expect_total {
            return Err(format!(
                "{}: file is {total} bytes, layout implies {expect_total}",
                path.display()
            ));
        }
        let index_off = (HEADER_BYTES + num_records as usize * record_stride) as u64;
        file.seek(SeekFrom::Start(index_off))
            .map_err(|e| format!("seek index {}: {e}", path.display()))?;
        let mut index_bytes = vec![0u8; num_pages as usize * INDEX_ENTRY_BYTES];
        file.read_exact(&mut index_bytes)
            .map_err(|e| format!("read index {}: {e}", path.display()))?;
        if fnv1a64(&index_bytes) != checksum {
            return Err(format!("{}: index checksum mismatch (torn write)", path.display()));
        }
        let index = decode_index(&index_bytes).map_err(|e| format!("{}: {e}", path.display()))?;
        // Index sanity: spans sorted and internally consistent.
        for (i, m) in index.iter().enumerate() {
            if m.min_key > m.max_key || (i > 0 && index[i - 1].max_key > m.min_key) {
                return Err(format!("{}: page index not key-sorted at page {i}", path.display()));
            }
        }
        Ok(PageFile {
            page_records: page_records as usize,
            num_records: num_records as usize,
            has_aux,
            index,
        })
    }
}

/// Read page `page_idx` of an opened run file (the caller supplies
/// the shape from the validated [`PageFile`]). Returns the page's
/// records and its aux column — empty when the file has none, which
/// readers must treat as all-zero.
pub fn read_page(
    file: &mut std::fs::File,
    page_records: usize,
    num_records: usize,
    has_aux: bool,
    page_idx: usize,
) -> Result<(Vec<Record>, Vec<u32>), String> {
    let start = page_idx * page_records;
    assert!(start < num_records, "page {page_idx} out of range");
    let n = page_records.min(num_records - start);
    // Every page before this one is full, so the byte offset is the
    // per-record stride (records + aux column) over `start` records.
    let stride = RECORD_BYTES + if has_aux { AUX_BYTES } else { 0 };
    let off = (HEADER_BYTES + start * stride) as u64;
    file.seek(SeekFrom::Start(off)).map_err(|e| format!("seek page {page_idx}: {e}"))?;
    let mut bytes = vec![0u8; n * RECORD_BYTES];
    file.read_exact(&mut bytes).map_err(|e| format!("read page {page_idx}: {e}"))?;
    let records = decode_records(&bytes)?;
    let aux = if has_aux {
        let mut abytes = vec![0u8; n * AUX_BYTES];
        file.read_exact(&mut abytes)
            .map_err(|e| format!("read aux column of page {page_idx}: {e}"))?;
        decode_aux(&abytes)?
    } else {
        Vec::new()
    };
    Ok((records, aux))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recs(keys: &[i64]) -> Vec<Record> {
        keys.iter().enumerate().map(|(i, &k)| Record::new(k, i as u64)).collect()
    }

    // ---- pure codec tests (run under Miri) --------------------------

    #[test]
    fn header_and_footer_roundtrip() {
        let h = encode_header(1024, PageFormat::V1);
        assert_eq!(&h[..8], HEADER_MAGIC);
        assert_eq!(&h[12..16], &[0, 0, 0, 0], "v1 flags word is zero");
        let h2 = encode_header(1024, PageFormat::V2 { has_aux: false });
        assert_eq!(&h2[..8], HEADER_MAGIC_V2);
        assert_eq!(&h2[12..16], &[0, 0, 0, 0]);
        let hw = encode_header(1024, PageFormat::V2 { has_aux: true });
        assert_eq!(u32::from_le_bytes(hw[12..16].try_into().unwrap()), FLAG_HAS_AUX);
        let f = encode_footer(5_000, 5, 1024, 0xDEAD_BEEF);
        assert_eq!(decode_footer(&f).unwrap(), (5_000, 5, 1024, 0xDEAD_BEEF));
        let mut torn = f;
        torn[30] ^= 1; // corrupt the magic
        assert!(decode_footer(&torn).is_err());
        assert!(decode_footer(&f[..FOOTER_BYTES - 1]).is_err());
    }

    #[test]
    fn aux_column_codec_roundtrip() {
        let vals = [0u32, 1, u32::MAX, 42];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        assert_eq!(decode_aux(&bytes).unwrap(), vals);
        assert!(decode_aux(&bytes[..5]).is_err());
    }

    #[test]
    fn index_roundtrip_and_corruption() {
        let index = vec![
            PageMeta { min_key: -5, max_key: 3 },
            PageMeta { min_key: 3, max_key: 99 },
        ];
        let bytes = encode_index(&index);
        assert_eq!(bytes.len(), 2 * INDEX_ENTRY_BYTES);
        assert_eq!(decode_index(&bytes).unwrap(), index);
        assert!(decode_index(&bytes[..INDEX_ENTRY_BYTES + 3]).is_err());
        // The checksum catches a flipped index byte.
        let mut bad = bytes.clone();
        bad[4] ^= 0x40;
        assert_ne!(fnv1a64(&bad), fnv1a64(&bytes));
    }

    // ---- filesystem tests -------------------------------------------

    #[test]
    #[cfg(not(miri))]
    fn write_open_read_pages() {
        let dir = std::env::temp_dir().join(format!("traff-page-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run-pages.bin");
        let records = recs(&[-9, -9, 0, 1, 1, 2, 5, 5, 5, 8, 11]); // 11 records
        let mut w = PageFileWriter::create(&path, 4, PageFormat::V2 { has_aux: false }).unwrap();
        w.extend(&records).unwrap();
        assert_eq!(w.len(), 11);
        let index = w.finish().unwrap();
        assert_eq!(index.len(), 3, "ceil(11/4) pages");
        assert_eq!(index[0], PageMeta { min_key: -9, max_key: 1 });
        assert_eq!(index[2], PageMeta { min_key: 5, max_key: 11 - 3 });

        let pf = PageFile::open(&path).unwrap();
        assert_eq!((pf.page_records, pf.num_records), (4, 11));
        assert!(!pf.has_aux);
        assert_eq!(pf.index, index);
        let mut file = std::fs::File::open(&path).unwrap();
        let mut back = Vec::new();
        for page in 0..pf.index.len() {
            let (page_recs, aux) =
                read_page(&mut file, pf.page_records, pf.num_records, pf.has_aux, page).unwrap();
            assert!(aux.is_empty(), "no aux column in this format");
            back.extend(page_recs);
        }
        let pairs: Vec<(i64, u64)> = back.iter().map(|r| (r.key, r.tag)).collect();
        let expect: Vec<(i64, u64)> = records.iter().map(|r| (r.key, r.tag)).collect();
        assert_eq!(pairs, expect);
        assert_eq!(
            read_page(&mut file, 4, 11, false, 2).unwrap().0.len(),
            3,
            "last page is partial"
        );
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    /// The v2 aux column round-trips per page, and a v1 file written
    /// byte-for-byte in the legacy layout still opens (back-compat is
    /// a format contract, not an accident of shared code).
    #[test]
    #[cfg(not(miri))]
    fn aux_column_and_v1_back_compat() {
        let dir = std::env::temp_dir().join(format!("traff-page-v2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Wide file: aux values survive page-by-page.
        let path = dir.join("wide.bin");
        let records = recs(&[1, 1, 2, 3, 3, 3, 7]); // 7 records, 2 pages at 4/page
        let mut w = PageFileWriter::create(&path, 4, PageFormat::V2 { has_aux: true }).unwrap();
        for (i, &r) in records.iter().enumerate() {
            w.push_wide(r, (i as u32) * 11 + 1).unwrap();
        }
        w.finish().unwrap();
        let pf = PageFile::open(&path).unwrap();
        assert!(pf.has_aux);
        let mut file = std::fs::File::open(&path).unwrap();
        let mut aux_back = Vec::new();
        for page in 0..pf.index.len() {
            let (page_recs, aux) = read_page(&mut file, 4, 7, true, page).unwrap();
            assert_eq!(page_recs.len(), aux.len());
            aux_back.extend(aux);
        }
        let expect: Vec<u32> = (0..7).map(|i| i * 11 + 1).collect();
        assert_eq!(aux_back, expect);
        // Nonzero aux without the column is a caller bug, reported.
        let narrow = dir.join("narrow.bin");
        let mut w = PageFileWriter::create(&narrow, 4, PageFormat::V1).unwrap();
        assert!(w.push_wide(Record::new(1, 0), 9).is_err());
        drop(w);
        // v1 back-compat: legacy-format output opens and reads.
        let v1 = dir.join("v1.bin");
        let mut w = PageFileWriter::create(&v1, 4, PageFormat::V1).unwrap();
        w.extend(&records).unwrap();
        w.finish().unwrap();
        let pf = PageFile::open(&v1).unwrap();
        assert!(!pf.has_aux);
        assert_eq!(pf.num_records, 7);
        let mut file = std::fs::File::open(&v1).unwrap();
        let (page0, aux0) = read_page(&mut file, 4, 7, false, 0).unwrap();
        assert_eq!(page0.len(), 4);
        assert!(aux0.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg(not(miri))]
    fn open_rejects_truncation_and_junk() {
        let dir = std::env::temp_dir().join(format!("traff-page-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Truncated mid-records (the crash-mid-spill shape).
        let path = dir.join("truncated.bin");
        let mut w = PageFileWriter::create(&path, 4, PageFormat::V2 { has_aux: false }).unwrap();
        w.extend(&recs(&[1, 2, 3, 4, 5, 6, 7, 8])).unwrap();
        w.finish().unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 7]).unwrap();
        assert!(PageFile::open(&path).is_err());
        // Pure junk.
        let junk = dir.join("junk.bin");
        std::fs::write(&junk, b"not a paged run at all, definitely not 48 bytes+").unwrap();
        assert!(PageFile::open(&junk).is_err());
        // Too short to even hold header + footer.
        let tiny = dir.join("tiny.bin");
        std::fs::write(&tiny, b"TMPG0001").unwrap();
        assert!(PageFile::open(&tiny).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

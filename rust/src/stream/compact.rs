//! Background compaction: merge a generation-contiguous window of
//! runs with the paper's co-rank partition, executing the segment
//! merges on the executor's **background lane** — streaming pages in
//! and out, never materializing a whole run.
//!
//! This is the paper's §2 primitive doing LSM work. The driver
//! ([`merge_cursors_into`]) advances one [`RunCursor`] per input run
//! (one resident page each) and alternates two phases per iteration:
//!
//! - **Phase A** — compute the *safe horizon*: the smallest
//!   last-buffered key among cursors that still have unloaded pages.
//!   Every record with key strictly below the horizon is provably
//!   resident (its cursor's buffered max is ≥ the horizon), so those
//!   prefixes are merged in one shot with
//!   [`parallel_kway_merge_with`] — `ceil(log2 k)` levels of §3
//!   merge rounds, each level one parallel phase of co-rank tasks
//!   under [`JobClass::Background`] — and streamed to the output
//!   [`RunWriter`] (which pages straight to disk for spilled stores).
//! - **Phase B** — the duplicate group *at* the horizon is drained
//!   cursor-by-cursor in generation order, crossing page boundaries
//!   one page at a time, so even a duplicate group larger than RAM
//!   keeps the resident set at O(k × page_records).
//!
//! Queued service-lane traffic (`MergeService` merge/sort jobs) drains
//! strictly ahead of a compaction's segment work at the injector,
//! which is what keeps the service p99 flat while compaction proceeds
//! (measured in bench E10); the anti-starvation bounds
//! (`EXEC_BG_STARVATION_LIMIT`, `EXEC_BG_MAX_DELAY_MS`) keep the
//! compaction itself from parking forever under a service flood.
//!
//! Stability: the window comes from the store's policy picker oldest
//! generation first, Phase A's k-way merge favours the earlier
//! (older) run on ties, and Phase B emits the horizon group in cursor
//! order — so arrival order for duplicate keys survives any
//! compaction schedule (property-tested in [`crate::stream`]).
//!
//! Concurrency: one compaction at a time, claimed via the store's CAS
//! flag; losers skip (`Ok(None)`) instead of queueing, so any number
//! of triggers can fire the compactor idempotently.

use super::page::PageFormat;
use super::run::{Run, RunCursor, RunWriter, WideRecord};
use super::store::{CompactionStats, RunStore};
use crate::core::adaptive::{merge_adaptive_scoped, MergeStrategy};
use crate::core::cases::Partition;
use crate::core::merge::{carve_output, chunk_tasks};
use crate::core::multiway::{loser_tree_merge, parallel_kway_merge_with};
use crate::core::record::Record;
use crate::core::seqmerge::merge_into;
use crate::exec::JobClass;
use std::sync::Arc;

/// Releases the store's compaction claim on every exit path (including
/// a panicking segment merge).
struct ClaimGuard<'a>(&'a RunStore);

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        self.0.release_compaction();
    }
}

/// Stable merge of two sorted runs (`a` older, first on ties) with the
/// co-rank partition, segment merges on the background lane. Public
/// for the E10 bench (the pairwise baseline the k-way driver is
/// measured against); the store paths go through [`compact_once`].
pub fn merge_runs_parallel(a: &[Record], b: &[Record], p: usize) -> Vec<Record> {
    merge_runs_parallel_with(a, b, p, MergeStrategy::Fixed)
}

/// [`merge_runs_parallel`] with an explicit [`MergeStrategy`]:
/// `Fixed` takes the upfront co-rank partition, `Adaptive` runs the
/// sequential-until-stolen kernel — both on the background lane. The
/// store paths pick the strategy up from [`StreamConfig`]
/// (`store.config().strategy`).
///
/// [`StreamConfig`]: crate::stream::StreamConfig
pub fn merge_runs_parallel_with(
    a: &[Record],
    b: &[Record],
    p: usize,
    strategy: MergeStrategy,
) -> Vec<Record> {
    let n = a.len() + b.len();
    let mut out = vec![Record::new(0, 0); n];
    if a.is_empty() {
        out.copy_from_slice(b);
        return out;
    }
    if b.is_empty() {
        out.copy_from_slice(a);
        return out;
    }
    let p = p.max(1);
    if p == 1 || n < crate::exec::tunables_for::<Record>().parallel_merge_cutoff {
        merge_into(a, b, &mut out);
        return out;
    }
    if strategy == MergeStrategy::Adaptive {
        let quantum = crate::exec::adaptive_quantum_for::<Record>();
        let slice = &mut out[..];
        crate::exec::global().scope_with_class(JobClass::Background, |s| {
            merge_adaptive_scoped(s, a, b, slice, quantum, None);
        });
        return out;
    }
    // Same fine-chunking policy as the service merge path: partition
    // granularity is decided once, from the windowed steal telemetry.
    let lanes = crate::exec::chunk_groups_for::<Record>(n, p);
    let part = Partition::compute(a, b, lanes);
    let tasks = part.tasks();
    let pairs = carve_output(&tasks, &mut out).expect("classifier produced non-tiling tasks");
    let groups = chunk_tasks(pairs, lanes);
    crate::exec::global().scope_with_class(JobClass::Background, |s| {
        for group in groups {
            s.spawn(move || {
                for (t, slice) in group {
                    merge_into(&a[t.a.clone()], &b[t.b.clone()], slice);
                }
            });
        }
    });
    out
}

/// The sequential baseline compactor: one-pass two-run loser-tree
/// merge (`ties -> lower run index`, i.e. the older run — the same
/// stability contract). Bench E10 measures [`merge_runs_parallel`]
/// against this.
pub fn merge_runs_sequential(a: &[Record], b: &[Record]) -> Vec<Record> {
    loser_tree_merge(&[a, b])
}

/// The streaming k-way merge driver — see the module docs for the
/// safe-horizon / duplicate-group phase structure. `cursors` must be
/// ordered oldest generation first; the output receives the exact
/// stable merge.
fn merge_cursors_into(
    cursors: &mut [RunCursor],
    p: usize,
    strategy: MergeStrategy,
    out: &mut RunWriter,
) -> Result<(), String> {
    loop {
        // Safe horizon: min last-buffered key among cursors with
        // unloaded pages. Records below it are fully resident.
        let mut safe: Option<i64> = None;
        for c in cursors.iter() {
            if c.has_unloaded() {
                let last = c.buffered().last().expect("eager refill keeps live cursors non-empty");
                safe = Some(match safe {
                    None => last.key,
                    Some(s) => s.min(last.key),
                });
            }
        }
        let Some(safe_key) = safe else {
            // Everything left is resident: one final k-way merge.
            let slices: Vec<&[Record]> = cursors.iter().map(|c| c.buffered()).collect();
            let merged = parallel_kway_merge_with(&slices, p, JobClass::Background, strategy);
            out.extend(&merged)?;
            let counts: Vec<usize> = cursors.iter().map(|c| c.buffered().len()).collect();
            for (c, k) in cursors.iter_mut().zip(counts) {
                c.advance_buffered(k)?;
            }
            return Ok(());
        };
        // Phase A: stable k-way merge of the strictly-below-horizon
        // prefixes. A cursor with unloaded pages never drains here
        // (its buffered max is >= the horizon), so no refill races the
        // borrowed slices.
        let cuts: Vec<usize> =
            cursors.iter().map(|c| c.buffered().partition_point(|r| r.key < safe_key)).collect();
        let slices: Vec<&[Record]> =
            cursors.iter().zip(&cuts).map(|(c, &k)| &c.buffered()[..k]).collect();
        let merged = parallel_kway_merge_with(&slices, p, JobClass::Background, strategy);
        out.extend(&merged)?;
        for (c, k) in cursors.iter_mut().zip(cuts) {
            c.advance_buffered(k)?;
        }
        // Phase B: the duplicate group AT the horizon, in generation
        // order, page by page. The horizon-defining cursor drains its
        // page here and refills — that per-iteration page load is the
        // progress guarantee.
        for c in cursors.iter_mut() {
            while c.peek().map_or(false, |r| r.key == safe_key) {
                let r = c.next_record()?.expect("peeked record");
                out.push(r)?;
            }
        }
    }
}

/// [`merge_cursors_into`] for windows where at least one input carries
/// an aux column: the same safe-horizon / duplicate-group driver, but
/// each merged element is a [`WideRecord`] so the aux column rides
/// through the generic stable k-way kernel. Phase A materializes the
/// below-horizon prefixes (records + aux zipped) instead of borrowing
/// them — the price of the 20-byte element; narrow windows keep the
/// zero-copy path above.
fn merge_cursors_into_wide(
    cursors: &mut [RunCursor],
    p: usize,
    strategy: MergeStrategy,
    out: &mut RunWriter,
) -> Result<(), String> {
    fn wide_prefix(c: &RunCursor, k: usize) -> Vec<WideRecord> {
        let recs = &c.buffered()[..k];
        let aux = c.buffered_aux();
        recs.iter()
            .enumerate()
            .map(|(i, r)| WideRecord::new(*r, aux.get(i).copied().unwrap_or(0)))
            .collect()
    }
    loop {
        let mut safe: Option<i64> = None;
        for c in cursors.iter() {
            if c.has_unloaded() {
                let last = c.buffered().last().expect("eager refill keeps live cursors non-empty");
                safe = Some(match safe {
                    None => last.key,
                    Some(s) => s.min(last.key),
                });
            }
        }
        let Some(safe_key) = safe else {
            let owned: Vec<Vec<WideRecord>> =
                cursors.iter().map(|c| wide_prefix(c, c.buffered().len())).collect();
            let slices: Vec<&[WideRecord]> = owned.iter().map(|v| v.as_slice()).collect();
            let merged = parallel_kway_merge_with(&slices, p, JobClass::Background, strategy);
            for w in &merged {
                out.push_wide(*w)?;
            }
            let counts: Vec<usize> = cursors.iter().map(|c| c.buffered().len()).collect();
            for (c, k) in cursors.iter_mut().zip(counts) {
                c.advance_buffered(k)?;
            }
            return Ok(());
        };
        let cuts: Vec<usize> =
            cursors.iter().map(|c| c.buffered().partition_point(|r| r.key < safe_key)).collect();
        let owned: Vec<Vec<WideRecord>> =
            cursors.iter().zip(&cuts).map(|(c, &k)| wide_prefix(c, k)).collect();
        let slices: Vec<&[WideRecord]> = owned.iter().map(|v| v.as_slice()).collect();
        let merged = parallel_kway_merge_with(&slices, p, JobClass::Background, strategy);
        for w in &merged {
            out.push_wide(*w)?;
        }
        for (c, k) in cursors.iter_mut().zip(cuts) {
            c.advance_buffered(k)?;
        }
        for c in cursors.iter_mut() {
            while c.peek().map_or(false, |r| r.key == safe_key) {
                let w = c.next_wide()?.expect("peeked record");
                out.push_wide(w)?;
            }
        }
    }
}

/// Stable k-way merge of a window of runs (oldest generation first)
/// into an in-memory `Vec`, streaming input pages through cursors.
/// Non-mutating — the benches and tests use this to measure/verify the
/// k-way driver against the pairwise baseline without a store commit.
pub fn kway_merge_to_vec(inputs: &[Arc<Run>], p: usize) -> Result<Vec<Record>, String> {
    let mut cursors = inputs
        .iter()
        .map(|r| RunCursor::new(Arc::clone(r)))
        .collect::<Result<Vec<_>, String>>()?;
    let total = inputs.iter().map(|r| r.len()).sum();
    let mut out = RunWriter::mem(total);
    merge_cursors_into(&mut cursors, p, MergeStrategy::Fixed, &mut out)?;
    Ok(out.into_records())
}

/// Merge one picked window and commit it: cursors in, paged run out
/// (spilled stores never hold the merged run in RAM), manifest-logged
/// swap. Caller holds the compaction claim.
fn compact_window(
    store: &RunStore,
    inputs: Vec<Arc<Run>>,
    p: usize,
) -> Result<CompactionStats, String> {
    debug_assert!(inputs.len() >= 2);
    let total: usize = inputs.iter().map(|r| r.len()).sum();
    let mut cursors = inputs
        .iter()
        .map(|r| RunCursor::new(Arc::clone(r)))
        .collect::<Result<Vec<_>, String>>()?;
    // The output format is decided upfront: wide iff any input carries
    // an aux column (a merge of narrow runs stays narrow), v1 only for
    // a legacy-format store (which never holds wide runs — the writer
    // refuses sequences past the v1 cap before they get here).
    let wide = inputs.iter().any(|r| r.has_aux());
    let format = if store.config().legacy_pages {
        PageFormat::V1
    } else {
        PageFormat::V2 { has_aux: wide }
    };
    let strategy = store.config().strategy;
    let mut out = RunWriter::new(store.spill_dir(), store.config().page_records, total, format)?;
    if wide {
        merge_cursors_into_wide(&mut cursors, p, strategy, &mut out)?;
    } else {
        merge_cursors_into(&mut cursors, p, strategy, &mut out)?;
    }
    let prepared = out.finish()?;
    let t0 = crate::obs::trace::span_start();
    let committed = store.commit_compaction(&inputs, prepared);
    crate::obs::trace::span_end(crate::obs::SpanKind::Publish, t0, total as u64);
    committed
}

/// Run one policy-driven compaction if the store's backlog asks for
/// one and the claim is free. Returns `Ok(None)` when there is
/// nothing to do (backlog under fanout, no window worth merging, or
/// another compactor holds the claim) — safe to call from any number
/// of concurrent triggers.
pub fn compact_once(store: &RunStore, p: usize) -> Result<Option<CompactionStats>, String> {
    if !store.needs_compaction() {
        return Ok(None);
    }
    if !store.try_claim_compaction() {
        return Ok(None);
    }
    let _claim = ClaimGuard(store);
    let Some(window) = store.pick_window() else {
        return Ok(None);
    };
    let t0 = crate::obs::trace::span_start();
    let fanin = window.len() as u64;
    let stats = compact_window(store, window, p);
    crate::obs::trace::span_end(crate::obs::SpanKind::Compact, t0, fanin);
    stats.map(Some)
}

/// Major compaction: merge the WHOLE store down to one run in a single
/// k-way pass, ignoring the fanout policy — the final consolidation
/// used by tests and the CLI. Spins on the claim (yielding) if a
/// concurrent compactor holds it. Returns the number of compactions
/// performed (1 for a multi-run store, 0 if already consolidated;
/// >1 only if concurrent seals land between passes).
pub fn compact_to_one(store: &RunStore, p: usize) -> Result<usize, String> {
    let mut done = 0usize;
    loop {
        while !store.try_claim_compaction() {
            std::thread::yield_now();
        }
        let _claim = ClaimGuard(store);
        let Some(window) = store.pick_all() else {
            return Ok(done);
        };
        compact_window(store, window, p)?;
        done += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::{Ingestor, StreamConfig};
    use crate::util::Rng;

    fn sorted_records(rng: &mut Rng, n: usize, key_range: i64, tag0: u64) -> Vec<Record> {
        let mut keys: Vec<i64> = (0..n).map(|_| rng.range(0, key_range)).collect();
        keys.sort();
        keys.iter().enumerate().map(|(i, &k)| Record::new(k, tag0 + i as u64)).collect()
    }

    fn as_pairs(v: &[Record]) -> Vec<(i64, u64)> {
        v.iter().map(|r| (r.key, r.tag)).collect()
    }

    #[test]
    fn parallel_sequential_and_oracle_agree() {
        let mut rng = Rng::new(41);
        // Miri runs the same shapes minus the largest (interpreter
        // cost), keeping the empty-side and odd-size cases.
        let shapes: &[(usize, usize)] = if cfg!(miri) {
            &[(0, 5), (7, 0), (40, 60)]
        } else {
            &[(0, 5), (7, 0), (40, 60), (333, 200)]
        };
        for &(n, m) in shapes {
            let a = sorted_records(&mut rng, n, 20, 0);
            let b = sorted_records(&mut rng, m, 20, 1000);
            let mut oracle = vec![Record::new(0, 0); n + m];
            if n + m > 0 {
                merge_into(&a, &b, &mut oracle);
            }
            assert_eq!(as_pairs(&merge_runs_parallel(&a, &b, 4)), as_pairs(&oracle));
            assert_eq!(as_pairs(&merge_runs_sequential(&a, &b)), as_pairs(&oracle));
        }
    }

    /// Large enough to cross the wide-class merge cutoff, so the
    /// background-lane scope path actually executes.
    #[test]
    #[cfg(not(miri))]
    fn background_lane_merge_matches_oracle_at_scale() {
        let mut rng = Rng::new(42);
        let a = sorted_records(&mut rng, 150_000, 5_000, 0);
        let b = sorted_records(&mut rng, 130_000, 5_000, 1_000_000);
        let mut oracle = vec![Record::new(0, 0); a.len() + b.len()];
        merge_into(&a, &b, &mut oracle);
        let got = merge_runs_parallel(&a, &b, crate::util::num_cpus());
        assert_eq!(as_pairs(&got), as_pairs(&oracle));
    }

    /// The streaming cursor driver is an exact stable k-way merge
    /// (loser tree over materialized runs as the oracle; ties favour
    /// the earlier run).
    #[test]
    fn kway_cursor_merge_matches_loser_tree_oracle() {
        let mut rng = Rng::new(43);
        let sizes: &[usize] = if cfg!(miri) { &[5, 0, 9, 3] } else { &[40, 0, 77, 15, 120, 1] };
        let mut runs = Vec::new();
        let mut tag0 = 0u64;
        for (g, &n) in sizes.iter().enumerate() {
            if n == 0 {
                continue; // runs are never empty; the shape just skips
            }
            let records = sorted_records(&mut rng, n, 7, tag0); // heavy duplicates
            tag0 += n as u64;
            runs.push(Arc::new(
                Run::create(records, g as u64, g as u64, 0, None, 1024).unwrap(),
            ));
        }
        let loaded: Vec<Vec<Record>> = runs.iter().map(|r| r.load().unwrap()).collect();
        let refs: Vec<&[Record]> = loaded.iter().map(|v| v.as_slice()).collect();
        let oracle = loser_tree_merge(&refs);
        let got = kway_merge_to_vec(&runs, 2).unwrap();
        assert_eq!(as_pairs(&got), as_pairs(&oracle));
        assert!(kway_merge_to_vec(&[], 2).unwrap().is_empty());
    }

    #[test]
    fn compact_once_reduces_backlog_and_preserves_records() {
        // Four full runs; Miri shrinks the run size, not the shape.
        let cap = if cfg!(miri) { 8 } else { 50 };
        let n = 4 * cap;
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: cap,
                fanout: 2,
                threads: 2,
                ..StreamConfig::default()
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut rng = Rng::new(7);
        for _ in 0..n {
            ing.push_key(rng.range(0, 30)).unwrap();
        }
        assert_eq!(store.run_count(), 4);
        let st = compact_once(&store, 2).unwrap().expect("backlog over fanout compacts");
        assert_eq!(st.merged_records, 2 * cap);
        assert_eq!(st.inputs, 2, "adjacent-pair policy merges a pair");
        assert_eq!(store.run_count(), 3);
        assert_eq!(store.record_count(), n as u64);
        // Backlog now exceeds fanout by one more; compact again then stop.
        assert!(compact_once(&store, 2).unwrap().is_some());
        assert!(compact_once(&store, 2).unwrap().is_none(), "under fanout: no-op");
        assert_eq!(store.run_count(), 2);
    }

    #[test]
    fn compact_once_skips_when_claim_held() {
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: 4,
                fanout: 2,
                threads: 1,
                ..StreamConfig::default()
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        for k in 0..12i64 {
            ing.push_key(k).unwrap();
        }
        assert_eq!(store.run_count(), 3, "backlog over fanout");
        assert!(store.try_claim_compaction());
        assert!(compact_once(&store, 1).unwrap().is_none(), "claim held: skip");
        store.release_compaction();
        assert!(compact_once(&store, 1).unwrap().is_some());
    }

    /// Wide runs (out-of-line aux column) compact exactly like narrow
    /// ones: the aux value stays glued to its record through the
    /// safe-horizon k-way driver, and the merged run is wide iff any
    /// input was.
    #[test]
    fn compaction_carries_the_aux_column() {
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: 16,
                fanout: 64,
                threads: 2,
                ..StreamConfig::default()
            })
            .unwrap(),
        );
        // Three equal-key runs sealed in generation order; (aux, tag)
        // encodes a strictly increasing 40-bit sequence so stability
        // is visible as full_seq order after the merge.
        let mut seq = 0u64;
        for _ in 0..3 {
            let batch: Vec<WideRecord> = (0..4)
                .map(|_| {
                    let w = WideRecord::new(
                        Record::new(0, (seq & 0xFF) << 32),
                        (seq >> 8) as u32 + 1, // nonzero aux: forces wide
                    );
                    seq += 1;
                    w
                })
                .collect();
            store.seal_wide(batch).unwrap().unwrap();
        }
        assert_eq!(compact_to_one(&store, 2).unwrap(), 1);
        let run = Arc::clone(&store.snapshot()[0]);
        assert!(run.has_aux(), "merged run keeps the aux column");
        let wide = run.load_wide().unwrap();
        assert_eq!(wide.len(), 12);
        let seqs: Vec<u64> =
            wide.iter().map(|w| ((w.aux as u64 - 1) << 8) | (w.rec.tag >> 32)).collect();
        assert_eq!(seqs, (0..12).collect::<Vec<u64>>(), "aux stayed paired and stable");
    }

    #[test]
    fn compact_to_one_consolidates_in_a_single_kway_pass() {
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: 10,
                fanout: 64,
                threads: 2,
                ..StreamConfig::default()
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut rng = Rng::new(11);
        for _ in 0..55 {
            ing.push_key(rng.range(0, 9)).unwrap();
        }
        ing.flush().unwrap();
        assert_eq!(store.run_count(), 6);
        let done = compact_to_one(&store, 2).unwrap();
        assert_eq!(done, 1, "major compaction merges the whole store in one k-way pass");
        assert_eq!(store.run_count(), 1);
        assert_eq!(store.record_count(), 55);
        let data = store.snapshot()[0].load().unwrap();
        assert!(data.windows(2).all(|w| w[0].key <= w[1].key));
        // Full-store stability: equal keys keep ingest (tag) order.
        assert!(data
            .windows(2)
            .all(|w| w[0].key < w[1].key || w[0].tag < w[1].tag));
    }

    /// An adaptive-configured store compacts to the exact same stable
    /// result as the fixed-partition default: the strategy changes how
    /// segment merges parallelize, never what they produce.
    #[test]
    fn adaptive_store_compaction_matches_fixed() {
        let mut results = Vec::new();
        for strategy in [MergeStrategy::Fixed, MergeStrategy::Adaptive] {
            let store = Arc::new(
                RunStore::new(StreamConfig {
                    run_capacity: 10,
                    fanout: 64,
                    threads: 2,
                    strategy,
                    ..StreamConfig::default()
                })
                .unwrap(),
            );
            let mut ing = Ingestor::new(Arc::clone(&store));
            let mut rng = Rng::new(17);
            for _ in 0..55 {
                ing.push_key(rng.range(0, 9)).unwrap();
            }
            ing.flush().unwrap();
            assert_eq!(compact_to_one(&store, 2).unwrap(), 1);
            let data = store.snapshot()[0].load().unwrap();
            assert!(data
                .windows(2)
                .all(|w| w[0].key < w[1].key || (w[0].key == w[1].key && w[0].tag < w[1].tag)));
            results.push(as_pairs(&data));
        }
        assert_eq!(results[0], results[1], "strategies agree record-for-record");
    }

    /// The strategy-aware pairwise compactor crosses the parallel
    /// cutoff with the adaptive kernel and still matches the oracle.
    #[test]
    #[cfg(not(miri))]
    fn adaptive_pairwise_compactor_matches_oracle_at_scale() {
        let mut rng = Rng::new(44);
        let a = sorted_records(&mut rng, 150_000, 5_000, 0);
        let b = sorted_records(&mut rng, 130_000, 5_000, 1_000_000);
        let mut oracle = vec![Record::new(0, 0); a.len() + b.len()];
        merge_into(&a, &b, &mut oracle);
        let got =
            merge_runs_parallel_with(&a, &b, crate::util::num_cpus(), MergeStrategy::Adaptive);
        assert_eq!(as_pairs(&got), as_pairs(&oracle));
    }

    /// Spilled k-way major compaction: pages stream through cursors
    /// (tiny pages force many refills and horizon-group drains) and
    /// the result is exact, sorted, stable, and durable.
    #[test]
    #[cfg(not(miri))]
    fn spilled_kway_compaction_streams_pages() {
        let dir =
            std::env::temp_dir().join(format!("traff-compact-spill-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: 100,
                fanout: 64,
                threads: 2,
                spill: Some(dir.clone()),
                page_records: 16, // many pages per run, giant dup groups
                ..StreamConfig::default()
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut rng = Rng::new(13);
        let n = 700;
        for _ in 0..n {
            ing.push_key(rng.range(0, 3)).unwrap(); // keys in {0, 1, 2}
        }
        ing.flush().unwrap();
        assert_eq!(store.run_count(), 7);
        assert_eq!(compact_to_one(&store, 2).unwrap(), 1);
        assert_eq!((store.run_count(), store.record_count()), (1, n as u64));
        let run = Arc::clone(&store.snapshot()[0]);
        assert!(run.is_spilled());
        let data = run.load().unwrap();
        assert_eq!(data.len(), n);
        assert!(data.windows(2).all(|w| w[0].key <= w[1].key));
        assert!(
            data.windows(2).all(|w| w[0].key < w[1].key || w[0].tag < w[1].tag),
            "duplicate keys must keep exact ingest order through the paged k-way merge"
        );
        drop(run);
        drop(ing);
        drop(store);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! Background compaction: merge adjacent run pairs with the paper's
//! co-rank partition, executing the segment merges on the executor's
//! **background lane**.
//!
//! This is the paper's §2 primitive doing LSM work: the two runs are
//! split by [`Partition::compute`] — `2(p+1)` co-rank binary searches
//! ([`crate::core::ranks`]) — into disjoint, independently mergeable
//! segments, which then run as one parallel phase under
//! [`JobClass::Background`]
//! ([`Executor::scope_with_class`](crate::exec::Executor::scope_with_class)).
//! Queued service-lane traffic (`MergeService` merge/sort jobs)
//! therefore drains strictly ahead of a compaction's segment work at
//! the injector, which is what keeps the service p99 flat while
//! compaction proceeds (measured in bench E10); the anti-starvation
//! bounds (`EXEC_BG_STARVATION_LIMIT`, `EXEC_BG_MAX_DELAY_MS`) keep
//! the compaction itself from parking forever under a service flood.
//!
//! Stability: the pair comes from the store's adjacent-pair picker
//! with the OLDER run as the merge's `a` side, and the stable two-way
//! merge puts `a`'s records first on ties — so arrival order for
//! duplicate keys survives any compaction schedule (property-tested
//! in [`crate::stream`]).
//!
//! Concurrency: one compaction at a time, claimed via the store's CAS
//! flag; losers skip (`Ok(None)`) instead of queueing, so any number
//! of triggers can fire the compactor idempotently.

use super::store::{CompactionStats, RunStore};
use crate::core::cases::Partition;
use crate::core::merge::{carve_output, chunk_tasks};
use crate::core::multiway::loser_tree_merge;
use crate::core::record::Record;
use crate::core::seqmerge::merge_into;
use crate::exec::JobClass;

/// Releases the store's compaction claim on every exit path (including
/// a panicking segment merge).
struct ClaimGuard<'a>(&'a RunStore);

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        self.0.release_compaction();
    }
}

/// Stable merge of two sorted runs (`a` older, first on ties) with the
/// co-rank partition, segment merges on the background lane. Public
/// for the E10 bench; the store paths go through [`compact_once`].
pub fn merge_runs_parallel(a: &[Record], b: &[Record], p: usize) -> Vec<Record> {
    let n = a.len() + b.len();
    let mut out = vec![Record::new(0, 0); n];
    if a.is_empty() {
        out.copy_from_slice(b);
        return out;
    }
    if b.is_empty() {
        out.copy_from_slice(a);
        return out;
    }
    let p = p.max(1);
    if p == 1 || n < crate::exec::tunables_for::<Record>().parallel_merge_cutoff {
        merge_into(a, b, &mut out);
        return out;
    }
    // Same fine-chunking policy as the service merge path: partition
    // granularity is decided once, from the windowed steal telemetry.
    let lanes = crate::exec::chunk_groups_for::<Record>(n, p);
    let part = Partition::compute(a, b, lanes);
    let tasks = part.tasks();
    let pairs = carve_output(&tasks, &mut out).expect("classifier produced non-tiling tasks");
    let groups = chunk_tasks(pairs, lanes);
    crate::exec::global().scope_with_class(JobClass::Background, |s| {
        for group in groups {
            s.spawn(move || {
                for (t, slice) in group {
                    merge_into(&a[t.a.clone()], &b[t.b.clone()], slice);
                }
            });
        }
    });
    out
}

/// The sequential baseline compactor: one-pass two-run loser-tree
/// merge (`ties -> lower run index`, i.e. the older run — the same
/// stability contract). Bench E10 measures [`merge_runs_parallel`]
/// against this.
pub fn merge_runs_sequential(a: &[Record], b: &[Record]) -> Vec<Record> {
    loser_tree_merge(&[a, b])
}

/// Run one policy-driven compaction if the store's backlog asks for
/// one and the claim is free. Returns `Ok(None)` when there is
/// nothing to do (backlog under fanout, fewer than two runs, or
/// another compactor holds the claim) — safe to call from any number
/// of concurrent triggers.
pub fn compact_once(store: &RunStore, p: usize) -> Result<Option<CompactionStats>, String> {
    if !store.needs_compaction() {
        return Ok(None);
    }
    if !store.try_claim_compaction() {
        return Ok(None);
    }
    let _claim = ClaimGuard(store);
    let Some((a, b)) = store.pick_adjacent_pair() else {
        return Ok(None);
    };
    // Borrow memory-resident runs directly; only spilled runs are
    // read into temporaries (`Run::data`).
    let da = a.data()?;
    let db = b.data()?;
    let merged = merge_runs_parallel(&da, &db, p);
    store.commit_compaction(&a, &b, merged).map(Some)
}

/// Compact the whole store down to (at most) one run, ignoring the
/// fanout policy — the "major compaction" used by tests and the CLI's
/// final consolidation. Spins on the claim (yielding) if a concurrent
/// compactor holds it. Returns the number of compactions performed.
pub fn compact_to_one(store: &RunStore, p: usize) -> Result<usize, String> {
    let mut done = 0usize;
    loop {
        while !store.try_claim_compaction() {
            std::thread::yield_now();
        }
        let _claim = ClaimGuard(store);
        let Some((a, b)) = store.pick_adjacent_pair() else {
            return Ok(done);
        };
        let da = a.data()?;
        let db = b.data()?;
        let merged = merge_runs_parallel(&da, &db, p);
        store.commit_compaction(&a, &b, merged)?;
        done += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::{Ingestor, StreamConfig};
    use crate::util::Rng;
    use std::sync::Arc;

    fn sorted_records(rng: &mut Rng, n: usize, key_range: i64, tag0: u64) -> Vec<Record> {
        let mut keys: Vec<i64> = (0..n).map(|_| rng.range(0, key_range)).collect();
        keys.sort();
        keys.iter().enumerate().map(|(i, &k)| Record::new(k, tag0 + i as u64)).collect()
    }

    fn as_pairs(v: &[Record]) -> Vec<(i64, u64)> {
        v.iter().map(|r| (r.key, r.tag)).collect()
    }

    #[test]
    fn parallel_sequential_and_oracle_agree() {
        let mut rng = Rng::new(41);
        // Miri runs the same shapes minus the largest (interpreter
        // cost), keeping the empty-side and odd-size cases.
        let shapes: &[(usize, usize)] = if cfg!(miri) {
            &[(0, 5), (7, 0), (40, 60)]
        } else {
            &[(0, 5), (7, 0), (40, 60), (333, 200)]
        };
        for &(n, m) in shapes {
            let a = sorted_records(&mut rng, n, 20, 0);
            let b = sorted_records(&mut rng, m, 20, 1000);
            let mut oracle = vec![Record::new(0, 0); n + m];
            if n + m > 0 {
                merge_into(&a, &b, &mut oracle);
            }
            assert_eq!(as_pairs(&merge_runs_parallel(&a, &b, 4)), as_pairs(&oracle));
            assert_eq!(as_pairs(&merge_runs_sequential(&a, &b)), as_pairs(&oracle));
        }
    }

    /// Large enough to cross the wide-class merge cutoff, so the
    /// background-lane scope path actually executes.
    #[test]
    #[cfg(not(miri))]
    fn background_lane_merge_matches_oracle_at_scale() {
        let mut rng = Rng::new(42);
        let a = sorted_records(&mut rng, 150_000, 5_000, 0);
        let b = sorted_records(&mut rng, 130_000, 5_000, 1_000_000);
        let mut oracle = vec![Record::new(0, 0); a.len() + b.len()];
        merge_into(&a, &b, &mut oracle);
        let got = merge_runs_parallel(&a, &b, crate::util::num_cpus());
        assert_eq!(as_pairs(&got), as_pairs(&oracle));
    }

    #[test]
    fn compact_once_reduces_backlog_and_preserves_records() {
        // Four full runs; Miri shrinks the run size, not the shape.
        let cap = if cfg!(miri) { 8 } else { 50 };
        let n = 4 * cap;
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: cap,
                fanout: 2,
                threads: 2,
                spill: None,
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut rng = Rng::new(7);
        for _ in 0..n {
            ing.push_key(rng.range(0, 30)).unwrap();
        }
        assert_eq!(store.run_count(), 4);
        let st = compact_once(&store, 2).unwrap().expect("backlog over fanout compacts");
        assert_eq!(st.merged_records, 2 * cap);
        assert_eq!(store.run_count(), 3);
        assert_eq!(store.record_count(), n as u64);
        // Backlog now exceeds fanout by one more; compact again then stop.
        assert!(compact_once(&store, 2).unwrap().is_some());
        assert!(compact_once(&store, 2).unwrap().is_none(), "under fanout: no-op");
        assert_eq!(store.run_count(), 2);
    }

    #[test]
    fn compact_once_skips_when_claim_held() {
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: 4,
                fanout: 1,
                threads: 1,
                spill: None,
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        for k in 0..8i64 {
            ing.push_key(k).unwrap();
        }
        assert!(store.try_claim_compaction());
        assert!(compact_once(&store, 1).unwrap().is_none(), "claim held: skip");
        store.release_compaction();
        assert!(compact_once(&store, 1).unwrap().is_some());
    }

    #[test]
    fn compact_to_one_consolidates_fully() {
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: 10,
                fanout: 64,
                threads: 2,
                spill: None,
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut rng = Rng::new(11);
        for _ in 0..55 {
            ing.push_key(rng.range(0, 9)).unwrap();
        }
        ing.flush().unwrap();
        assert_eq!(store.run_count(), 6);
        let done = compact_to_one(&store, 2).unwrap();
        assert_eq!(done, 5);
        assert_eq!(store.run_count(), 1);
        assert_eq!(store.record_count(), 55);
        let data = store.snapshot()[0].load().unwrap();
        assert!(data.windows(2).all(|w| w[0].key <= w[1].key));
        // Full-store stability: equal keys keep ingest (tag) order.
        assert!(data
            .windows(2)
            .all(|w| w[0].key < w[1].key || w[0].tag < w[1].tag));
    }
}

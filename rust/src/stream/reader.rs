//! Stable merged reads over the live runs — reads-before-compaction.
//!
//! A scan takes a [`RunStore::snapshot`] (the `Arc`s pin the runs, so
//! a compaction committing mid-scan cannot pull data out from under
//! it), loads each run's records, and merges the runs' heads with the
//! k-way machinery from [`crate::core::multiway`]:
//!
//! - [`scan`] materializes the full merge via
//!   [`loser_tree_merge`] — the one-pass tournament over run heads;
//! - [`scan_iter`] yields the same sequence lazily ([`ScanIter`]), for
//!   consumers that stop early or process incrementally.
//!
//! Both are **stable across runs**: the snapshot is ordered by
//! `gen_lo` and ties resolve to the lower run index — i.e. the older
//! generation — which, combined with the store's adjacency invariant
//! and the stable seal sort, yields duplicate keys in exact ingest
//! order. Buffered-but-unsealed records are not visible (see
//! [`super::ingest`]).

use super::store::RunStore;
use crate::core::multiway::loser_tree_merge;
use crate::core::record::Record;

/// Materialized stable merged view of the store's live runs. Memory
/// runs are merged in place (borrowed via [`Run::data`](super::Run::data) —
/// no per-run clone); only spilled runs are read into temporaries.
pub fn scan(store: &RunStore) -> Result<Vec<Record>, String> {
    let snap = store.snapshot();
    let data: Vec<std::borrow::Cow<'_, [Record]>> =
        snap.iter().map(|r| r.data()).collect::<Result<_, _>>()?;
    let refs: Vec<&[Record]> = data.iter().map(|d| d.as_ref()).collect();
    Ok(loser_tree_merge(&refs))
}

/// Lazy stable merged view of the store's live runs. The iterator
/// must own its data (it outlives the snapshot it was built from), so
/// this path pays the per-run copy [`scan`] avoids; prefer [`scan`]
/// when the whole merge is consumed anyway.
pub fn scan_iter(store: &RunStore) -> Result<ScanIter, String> {
    let snap = store.snapshot();
    let runs: Vec<Vec<Record>> = snap.iter().map(|r| r.load()).collect::<Result<_, _>>()?;
    let pos = vec![0usize; runs.len()];
    Ok(ScanIter { runs, pos })
}

/// Incremental k-way merge over a loaded snapshot: each `next` takes
/// the minimum head, ties to the lowest run index (the older
/// generation). `O(k)` per element — the runs-per-scan `k` is bounded
/// by the compaction fanout, so a heap buys nothing at this shape.
pub struct ScanIter {
    runs: Vec<Vec<Record>>,
    pos: Vec<usize>,
}

impl ScanIter {
    /// Records remaining to be yielded.
    pub fn remaining(&self) -> usize {
        self.runs.iter().zip(&self.pos).map(|(r, &p)| r.len() - p).sum()
    }
}

impl Iterator for ScanIter {
    type Item = Record;

    fn next(&mut self) -> Option<Record> {
        let mut best: Option<usize> = None;
        for r in 0..self.runs.len() {
            let i = self.pos[r];
            if i >= self.runs[r].len() {
                continue;
            }
            best = match best {
                None => Some(r),
                // Strict `<` on keys keeps the lowest run index (the
                // older generation) on ties — the stability order.
                Some(br) if self.runs[r][i].key < self.runs[br][self.pos[br]].key => Some(r),
                other => other,
            };
        }
        let r = best?;
        let rec = self.runs[r][self.pos[r]];
        self.pos[r] += 1;
        Some(rec)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.remaining();
        (n, Some(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::{Ingestor, StreamConfig};
    use crate::util::Rng;
    use std::sync::Arc;

    fn store(cap: usize) -> Arc<RunStore> {
        Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: cap,
                fanout: 64,
                threads: 2,
                spill: None,
            })
            .unwrap(),
        )
    }

    #[test]
    fn empty_store_scans_empty() {
        let store = store(4);
        assert!(scan(&store).unwrap().is_empty());
        assert_eq!(scan_iter(&store).unwrap().count(), 0);
    }

    #[test]
    fn scan_and_iter_agree_with_stable_oracle() {
        let store = store(16);
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut rng = Rng::new(23);
        let n = 100;
        let keys: Vec<i64> = (0..n).map(|_| rng.range(0, 12)).collect();
        for &k in &keys {
            ing.push_key(k).unwrap();
        }
        ing.flush().unwrap();
        assert!(store.run_count() > 1, "multiple runs exercise the k-way path");
        // Oracle: stable sort of the ingest-ordered (key, tag) stream.
        let mut expect: Vec<(i64, u64)> =
            keys.iter().enumerate().map(|(i, &k)| (k, i as u64)).collect();
        expect.sort_by_key(|&(k, _)| k); // Vec sort is stable
        let got: Vec<(i64, u64)> =
            scan(&store).unwrap().iter().map(|r| (r.key, r.tag)).collect();
        assert_eq!(got, expect);
        let it = scan_iter(&store).unwrap();
        assert_eq!(it.size_hint(), (n, Some(n)));
        let lazy: Vec<(i64, u64)> = it.map(|r| (r.key, r.tag)).collect();
        assert_eq!(lazy, expect);
    }

    /// Reads-before-compaction: a snapshot taken before a compaction
    /// commit still drains its original runs and yields the same
    /// stable sequence as a post-compaction scan.
    #[test]
    fn snapshot_survives_concurrent_compaction() {
        let store = store(8);
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut rng = Rng::new(29);
        for _ in 0..32 {
            ing.push_key(rng.range(0, 6)).unwrap();
        }
        let before = scan_iter(&store).unwrap(); // snapshot pinned
        let done = crate::stream::compact_to_one(&store, 2).unwrap();
        assert!(done > 0);
        let after: Vec<(i64, u64)> =
            scan(&store).unwrap().iter().map(|r| (r.key, r.tag)).collect();
        let pinned: Vec<(i64, u64)> = before.map(|r| (r.key, r.tag)).collect();
        assert_eq!(pinned, after, "pre-compaction snapshot reads the same data");
    }
}

//! Stable merged reads over the live runs — reads-before-compaction,
//! one resident page per run.
//!
//! A scan takes a [`RunStore::snapshot`] (the `Arc`s pin the runs —
//! and, via the page files' open handles, the *bytes* of spilled runs
//! even after a compaction unlinks them — so a commit mid-scan cannot
//! pull data out from under it) and merges the runs through one
//! [`RunCursor`] each:
//!
//! - [`scan_iter`] yields the merged sequence lazily ([`ScanIter`]),
//!   holding at most one page per run resident at any time;
//! - [`scan`] drains the same iterator into a `Vec` for consumers that
//!   want the whole merge anyway.
//!
//! Both are **stable across runs**: the snapshot is ordered by
//! `gen_lo` and ties resolve to the lower run index — i.e. the older
//! generation — which, combined with the store's contiguity invariant
//! and the stable seal sort, yields duplicate keys in exact ingest
//! order. Buffered-but-unsealed records are not visible (see
//! [`super::ingest`]).
//!
//! Peak scan memory is `O(runs × page_records)` regardless of run
//! sizes — [`ScanIter::peak_resident`] reports the high-water mark so
//! tests can pin the bound.

use super::run::{RunCursor, WideRecord};
use super::store::RunStore;
use crate::core::record::Record;
use std::sync::Arc;

/// Materialized stable merged view of the store's live runs, streamed
/// through per-run page cursors — a whole run is never resident.
pub fn scan(store: &RunStore) -> Result<Vec<Record>, String> {
    let mut it = scan_iter(store)?;
    let mut out = Vec::with_capacity(it.remaining());
    while let Some(rec) = it.next_record()? {
        out.push(rec);
    }
    Ok(out)
}

/// [`scan`] with the out-of-line aux column kept paired with each
/// record (aux 0 for narrow runs) — the read side of the widened
/// (gen, seq) tag: `WideRecord::full_seq` reassembles the full 64-bit
/// ingest sequence for [`super::writer`]-packed streams. Same snapshot
/// pinning, ordering, and paging behaviour as [`scan`].
pub fn scan_wide(store: &RunStore) -> Result<Vec<WideRecord>, String> {
    let mut it = scan_iter(store)?;
    let mut out = Vec::with_capacity(it.remaining());
    while let Some(w) = it.next_wide()? {
        out.push(w);
    }
    Ok(out)
}

/// Lazy stable merged view of the store's live runs. The snapshot's
/// `Arc`s (and open page-file handles) keep every run readable for the
/// iterator's lifetime, compactions notwithstanding.
pub fn scan_iter(store: &RunStore) -> Result<ScanIter, String> {
    let snap = store.snapshot();
    let cursors = snap
        .into_iter()
        .map(RunCursor::new)
        .collect::<Result<Vec<_>, String>>()?;
    Ok(ScanIter { cursors, peak_resident: 0, error: None })
}

/// Incremental k-way merge over a pinned snapshot: each `next` takes
/// the minimum buffered head, ties to the lowest run index (the older
/// generation). `O(k)` per element — the runs-per-scan `k` is bounded
/// by the compaction fanout, so a heap buys nothing at this shape.
/// Spilled runs stream page by page; see [`ScanIter::peak_resident`].
pub struct ScanIter {
    /// One cursor per snapshotted run, oldest generation first.
    cursors: Vec<RunCursor>,
    /// High-water mark of records resident in page buffers.
    peak_resident: usize,
    /// First page-read error, latched by the `Iterator` impl (which
    /// cannot return `Err`); [`ScanIter::next_record`] reports it
    /// directly.
    error: Option<String>,
}

impl ScanIter {
    /// Records remaining to be yielded.
    pub fn remaining(&self) -> usize {
        self.cursors.iter().map(|c| c.remaining()).sum()
    }

    /// High-water mark of records held in page buffers so far — the
    /// scan-path memory bound (`<= runs × page_records` plus one
    /// refill). Memory-backed runs count 0 (they borrow the run).
    pub fn peak_resident(&self) -> usize {
        self.peak_resident
    }

    /// The first page-read error swallowed by the `Iterator` impl, if
    /// any. A scan that ends with `error().is_none()` was complete.
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }

    /// Yield the next record of the stable merge, or `Err` on a page
    /// read/decode failure (the fallible twin of `Iterator::next`).
    pub fn next_record(&mut self) -> Result<Option<Record>, String> {
        Ok(self.next_wide()?.map(|w| w.rec))
    }

    /// [`ScanIter::next_record`] with the aux column attached (aux 0
    /// for narrow runs).
    pub fn next_wide(&mut self) -> Result<Option<WideRecord>, String> {
        let mut best: Option<usize> = None;
        for (i, c) in self.cursors.iter().enumerate() {
            let Some(head) = c.peek() else { continue };
            best = match best {
                None => Some(i),
                // Strict `<` keeps the lowest run index (the older
                // generation) on ties — the stability order.
                Some(b) if head.key < self.cursors[b].peek().expect("best has a head").key => {
                    Some(i)
                }
                other => other,
            };
        }
        let Some(i) = best else { return Ok(None) };
        let w = self.cursors[i].next_wide()?.expect("peeked head");
        let resident: usize = self.cursors.iter().map(|c| c.resident_records()).sum();
        self.peak_resident = self.peak_resident.max(resident);
        Ok(Some(w))
    }
}

impl Iterator for ScanIter {
    type Item = Record;

    fn next(&mut self) -> Option<Record> {
        if self.error.is_some() {
            return None;
        }
        match self.next_record() {
            Ok(rec) => rec,
            Err(e) => {
                self.error = Some(e);
                None
            }
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        if self.error.is_some() {
            return (0, Some(0));
        }
        let n = self.remaining();
        (n, Some(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::{Ingestor, StreamConfig};
    use crate::util::Rng;

    fn store(cap: usize) -> Arc<RunStore> {
        Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: cap,
                fanout: 64,
                threads: 2,
                ..StreamConfig::default()
            })
            .unwrap(),
        )
    }

    #[test]
    fn empty_store_scans_empty() {
        let store = store(4);
        assert!(scan(&store).unwrap().is_empty());
        assert_eq!(scan_iter(&store).unwrap().count(), 0);
    }

    #[test]
    fn scan_and_iter_agree_with_stable_oracle() {
        let store = store(16);
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut rng = Rng::new(23);
        let n = 100;
        let keys: Vec<i64> = (0..n).map(|_| rng.range(0, 12)).collect();
        for &k in &keys {
            ing.push_key(k).unwrap();
        }
        ing.flush().unwrap();
        assert!(store.run_count() > 1, "multiple runs exercise the k-way path");
        // Oracle: stable sort of the ingest-ordered (key, tag) stream.
        let mut expect: Vec<(i64, u64)> =
            keys.iter().enumerate().map(|(i, &k)| (k, i as u64)).collect();
        expect.sort_by_key(|&(k, _)| k); // Vec sort is stable
        let got: Vec<(i64, u64)> =
            scan(&store).unwrap().iter().map(|r| (r.key, r.tag)).collect();
        assert_eq!(got, expect);
        let it = scan_iter(&store).unwrap();
        assert_eq!(it.size_hint(), (n, Some(n)));
        let lazy: Vec<(i64, u64)> = it.map(|r| (r.key, r.tag)).collect();
        assert_eq!(lazy, expect);
    }

    /// Reads-before-compaction: a snapshot taken before a compaction
    /// commit still drains its original runs and yields the same
    /// stable sequence as a post-compaction scan.
    #[test]
    fn snapshot_survives_concurrent_compaction() {
        let store = store(8);
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut rng = Rng::new(29);
        for _ in 0..32 {
            ing.push_key(rng.range(0, 6)).unwrap();
        }
        let before = scan_iter(&store).unwrap(); // snapshot pinned
        let done = crate::stream::compact_to_one(&store, 2).unwrap();
        assert!(done > 0);
        let after: Vec<(i64, u64)> =
            scan(&store).unwrap().iter().map(|r| (r.key, r.tag)).collect();
        let pinned: Vec<(i64, u64)> = before.map(|r| (r.key, r.tag)).collect();
        assert_eq!(pinned, after, "pre-compaction snapshot reads the same data");
    }

    /// Satellite regression: scanning a spilled store must never
    /// materialize whole runs — peak resident page memory stays at
    /// O(runs × page_records), far below the total record count.
    #[test]
    #[cfg(not(miri))]
    fn spilled_scan_memory_stays_paged() {
        let dir = std::env::temp_dir().join(format!("traff-scan-mem-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let page = 32usize;
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: 1000,
                fanout: 64,
                threads: 2,
                spill: Some(dir.clone()),
                page_records: page,
                ..StreamConfig::default()
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        let mut rng = Rng::new(31);
        let n = 5000;
        for _ in 0..n {
            ing.push_key(rng.range(0, 1000)).unwrap();
        }
        ing.flush().unwrap();
        let runs = store.run_count();
        assert!(runs >= 5);
        let mut it = scan_iter(&store).unwrap();
        let mut count = 0usize;
        while it.next_record().unwrap().is_some() {
            count += 1;
        }
        assert_eq!(count, n);
        // One page per run, plus one page of slack for the eager
        // refill at a page boundary.
        let bound = runs * page + page;
        assert!(
            it.peak_resident() <= bound,
            "peak resident {} exceeds paged bound {}",
            it.peak_resident(),
            bound
        );
        assert!(it.peak_resident() < n / 4, "must be far below whole-store materialization");
        drop(it);
        drop(ing);
        drop(store);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! `stream` — the streaming run-merge subsystem: a durable, paged,
//! restartable sorted-run store with k-way background compaction on
//! the executor's QoS lanes.
//!
//! Everything below this module used to be batch-shaped: a job's data
//! had to fit in memory and arrive whole before `MergeService::sort`
//! touched it. This layer decouples **total data size from job size**
//! twice over: unbounded record streams buffer into bounded runs, and
//! every heavy operation — run sort, k-way compaction, scan — streams
//! fixed-size pages, so no run is ever whole in memory.
//!
//! ```text
//!            push/push_key             seal (sorted, gen-stamped)
//! records ──► [ingest::Ingestor] ─────► [store::RunStore] ◄─ snapshot ── [reader]
//!              bounded buffer            leveled Arc<Run> list            scan /
//!              (core::sort seals         gen clock · CAS claim            scan_iter
//!               stably in parallel)         │         │                  (1 page/run
//!                                           │         ▼                   resident)
//!                    [page] fixed pages     │   [policy] picks a
//!                    + min/max index        │   gen-contiguous window
//!                    [manifest] append-only │         │
//!                    fsync'd log — recovery │         ▼
//!                    replays it on restart  └──► [compact] streaming k-way
//!                    ([`RunStore::recover`])     merge: co-rank rounds (§2/§3)
//!                                                as JobClass::Background jobs
//! ```
//!
//! The paper connection: [`compact`] is the §2 co-rank split doing
//! LSM-compaction work. A picked window of k runs is merged in ONE
//! pass — `ceil(log2 k)` levels of the simplified two-way merge, each
//! level a single background-lane parallel phase
//! ([`crate::core::multiway`]) — instead of k−1 pairwise rewrites, and
//! the driver streams input/output pages so the merge runs out-of-core
//! (bench E10). Service traffic keeps its latency while the store
//! compacts.
//!
//! Durability (spilled stores): run files are page-formatted
//! ([`page`]) and published in two fsync'd steps — the run file is
//! synced before its manifest record is appended, and the manifest
//! record is synced before the run becomes visible in memory. The
//! [`manifest`] is an append-only checksummed log of `AddRun`/`Replace`
//! records; [`RunStore::recover`] replays it, tolerates a torn tail,
//! deletes orphaned run files, and restores the exact leveled run
//! list — a SIGKILL at any point loses only unsealed buffered records.
//!
//! Stability end to end (property-tested below): the seal sort is
//! stable, the store's generation clock orders runs by arrival, the
//! compactor only merges generation-contiguous windows (older run
//! first on ties), and readers resolve ties to the older generation —
//! so duplicate keys emerge from any seal/compact/scan/recover
//! schedule in exact ingest order.
//!
//! The service facade is
//! [`MergeService::ingest`](crate::coordinator::MergeService::ingest) /
//! [`flush_stream`](crate::coordinator::MergeService::flush_stream) /
//! [`scan`](crate::coordinator::MergeService::scan), and `repro
//! stream` drives the mixed ingest + scan + compaction workload
//! (`--recover` restarts from a previous run's spill dir).

pub mod compact;
pub mod ingest;
pub mod manifest;
#[cfg(all(test, feature = "model"))]
mod model_tests;
pub mod page;
pub mod policy;
pub mod reader;
pub mod run;
pub mod store;

pub use compact::{
    compact_once, compact_to_one, kway_merge_to_vec, merge_runs_parallel, merge_runs_sequential,
};
pub use ingest::Ingestor;
pub use manifest::RunMeta;
pub use policy::{CompactionPolicy, PolicyKind};
pub use reader::{scan, scan_iter, ScanIter};
pub use run::{Run, RunCursor};
pub use store::{CompactionStats, RunStore, StoreStats};

use std::path::PathBuf;

/// Configuration of one stream (store + its ingestors/compactors).
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Records buffered before a run seals (the bounded in-memory
    /// working set per ingest stream).
    pub run_capacity: usize,
    /// Live-run backlog tolerated before the compaction policy
    /// triggers ([`RunStore::needs_compaction`]); also the width cap
    /// for a policy-picked k-way window.
    pub fanout: usize,
    /// Parallelism granularity for seal sorts and compaction merges
    /// (the `p` handed to the paper's algorithms; the process-wide
    /// executor still bounds real concurrency).
    pub threads: usize,
    /// Spill directory: `Some(dir)` stores runs as paged binary files
    /// under `dir` with an fsync'd manifest (durable — survives
    /// restart via [`RunStore::recover`]), `None` keeps them in
    /// memory.
    pub spill: Option<PathBuf>,
    /// Records per page in spilled run files — the granularity of
    /// cursor reads and the per-run resident bound for scans and
    /// compactions.
    pub page_records: usize,
    /// Which compaction policy picks the next window
    /// ([`policy::PolicyKind`]).
    pub policy: PolicyKind,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            run_capacity: 1 << 16,
            fanout: 4,
            threads: crate::util::num_cpus(),
            spill: None,
            page_records: 1024,
            policy: PolicyKind::AdjacentPair,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::{raw_keys, Dist};
    use std::sync::Arc;

    fn oracle(keys: &[i64]) -> Vec<(i64, u64)> {
        let mut expect: Vec<(i64, u64)> =
            keys.iter().enumerate().map(|(i, &k)| (k, i as u64)).collect();
        expect.sort_by_key(|&(k, _)| k); // stable: ingest order within equal keys
        expect
    }

    fn pairs(records: &[crate::core::record::Record]) -> Vec<(i64, u64)> {
        records.iter().map(|r| (r.key, r.tag)).collect()
    }

    /// Satellite: cross-run stability. Duplicate keys ingested across
    /// runs keep ingest order through seal -> compact -> scan, over
    /// every workload distribution, at three compaction depths (none,
    /// policy-driven, full). Sizes shrink under Miri.
    #[test]
    fn cross_run_stability_over_all_distributions() {
        let (n, cap) = if cfg!(miri) { (60, 8) } else { (6_000, 256) };
        for dist in Dist::all() {
            let keys = raw_keys(dist, n, 0xD15);
            let expect = oracle(&keys);
            let store = Arc::new(
                RunStore::new(StreamConfig {
                    run_capacity: cap,
                    fanout: 4,
                    threads: 2,
                    ..StreamConfig::default()
                })
                .unwrap(),
            );
            let mut ing = Ingestor::new(Arc::clone(&store));
            for &k in &keys {
                ing.push_key(k).unwrap();
            }
            ing.flush().unwrap();
            let name = dist.name();
            // Depth 0: no compaction.
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "{name}: uncompacted");
            // Depth 1: policy-driven compactions until the backlog is
            // back under fanout.
            while compact_once(&store, 2).unwrap().is_some() {}
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "{name}: policy-compacted");
            // Depth 2: full consolidation to a single run.
            compact_to_one(&store, 2).unwrap();
            assert!(store.run_count() <= 1);
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "{name}: fully compacted");
            // The exact-permutation form of the same claim: the fully
            // compacted scan is THE stable sort of the ingest stream.
            let ingested: Vec<crate::core::record::Record> = keys
                .iter()
                .enumerate()
                .map(|(i, &k)| crate::core::record::Record::new(k, i as u64))
                .collect();
            crate::testing::assert_stable_permutation(&[&ingested], &scan(&store).unwrap())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    /// Every policy preserves the stable-scan contract at every
    /// compaction depth (the window *choice* differs; the merge result
    /// must not).
    #[test]
    fn all_policies_preserve_the_stable_scan() {
        let (n, cap) = if cfg!(miri) { (48, 6) } else { (3_000, 128) };
        let keys = raw_keys(Dist::DupHeavy(8), n, 0xB0B);
        let expect = oracle(&keys);
        for kind in
            [PolicyKind::AdjacentPair, PolicyKind::SizeTiered, PolicyKind::OverlapAware]
        {
            let store = Arc::new(
                RunStore::new(StreamConfig {
                    run_capacity: cap,
                    fanout: 4,
                    threads: 2,
                    policy: kind,
                    ..StreamConfig::default()
                })
                .unwrap(),
            );
            let mut ing = Ingestor::new(Arc::clone(&store));
            for &k in &keys {
                ing.push_key(k).unwrap();
            }
            ing.flush().unwrap();
            while compact_once(&store, 2).unwrap().is_some() {}
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "policy {}", kind.name());
            compact_to_one(&store, 2).unwrap();
            assert_eq!(
                pairs(&scan(&store).unwrap()),
                expect,
                "policy {} fully compacted",
                kind.name()
            );
        }
    }

    /// The acceptance shape end to end at the library layer: total
    /// ingested data exceeds the per-run buffer by >= 8x, compaction
    /// runs concurrently with scans, and the final scan is globally
    /// sorted and stable.
    #[test]
    #[cfg(not(miri))]
    fn ingest_exceeds_buffer_8x_with_interleaved_scans() {
        let cap = 512usize;
        let n = cap * 10; // > 8x the per-run buffer
        let keys = raw_keys(Dist::Zipf, n, 77);
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: cap,
                fanout: 3,
                threads: 2,
                ..StreamConfig::default()
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        for (i, &k) in keys.iter().enumerate() {
            let (_, sealed) = ing.push_key(k).unwrap();
            if sealed.is_some() {
                // Interleave: compact on the policy, then scan the
                // sealed prefix — must always be sorted and complete.
                while compact_once(&store, 2).unwrap().is_some() {}
                let seen = scan(&store).unwrap();
                assert_eq!(seen.len() as u64, store.record_count());
                assert_eq!(seen.len(), i + 1 - ing.pending());
                assert!(seen.windows(2).all(|w| w[0].key <= w[1].key));
            }
        }
        ing.flush().unwrap();
        assert_eq!(pairs(&scan(&store).unwrap()), oracle(&keys));
        assert!(store.stats().compactions > 0, "compaction must have run");
    }

    /// Spill-to-disk round trip with durable restart: the paged
    /// pipeline matches the in-memory oracle, the files survive the
    /// store's drop, and [`RunStore::recover`] restores the identical
    /// stable view.
    #[test]
    #[cfg(not(miri))]
    fn spilled_pipeline_is_durable_across_restart() {
        let dir = std::env::temp_dir()
            .join(format!("traff-stream-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let keys = raw_keys(Dist::DupHeavy(16), 2_000, 5);
        let expect = oracle(&keys);
        let cfg = StreamConfig {
            run_capacity: 128,
            fanout: 3,
            threads: 2,
            spill: Some(dir.clone()),
            page_records: 64,
            ..StreamConfig::default()
        };
        {
            let store = Arc::new(RunStore::new(cfg.clone()).unwrap());
            let mut ing = Ingestor::new(Arc::clone(&store));
            for &k in &keys {
                ing.push_key(k).unwrap();
            }
            ing.flush().unwrap();
            assert!(store.stats().spilled_runs > 0, "runs must spill");
            while compact_once(&store, 2).unwrap().is_some() {}
            assert_eq!(pairs(&scan(&store).unwrap()), expect);
            compact_to_one(&store, 2).unwrap();
            assert_eq!(pairs(&scan(&store).unwrap()), expect);
        }
        // Durable: the run files and manifest survive the drop.
        assert!(dir.join(manifest::MANIFEST_NAME).exists());
        let recovered = Arc::new(RunStore::recover(cfg).unwrap());
        assert_eq!(recovered.record_count(), keys.len() as u64);
        assert_eq!(pairs(&scan(&recovered).unwrap()), expect, "recovered view is identical");
        drop(recovered);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

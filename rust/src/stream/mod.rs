//! `stream` — the streaming run-merge subsystem: an out-of-core
//! sorted-run store with background compaction on the executor's QoS
//! lanes.
//!
//! Everything below this module used to be batch-shaped: a job's data
//! had to fit in memory and arrive whole before `MergeService::sort`
//! touched it. This layer decouples **total data size from job size**:
//! unbounded record streams buffer into bounded runs, and every heavy
//! operation — run sort, pairwise compaction — is a bounded job on the
//! shared executor.
//!
//! ```text
//!            push/push_key             seal (sorted, gen-stamped)
//! records ──► [ingest::Ingestor] ─────► [store::RunStore]  ◄─ snapshot ─ [reader]
//!              bounded buffer           leveled Arc<Run> list              scan /
//!              (core::sort seals        lock-free gen clock + stats        scan_iter
//!               stably in parallel)        │ claim (CAS)                  (loser-tree
//!                                          ▼                               heads)
//!                                    [compact] co-rank partition
//!                                      (core::ranks, §2) ──► segment merges as
//!                                                            JobClass::Background
//!                                                            on crate::exec
//! ```
//!
//! The paper connection: [`compact`] is the §2 co-rank split doing
//! LSM-compaction work — each run pair is carved into independent,
//! stably mergeable segments by `2(p+1)` binary searches, and the
//! segments run as one background-lane parallel phase, so service
//! traffic keeps its latency while the store compacts (bench E10).
//!
//! Stability end to end (property-tested below): the seal sort is
//! stable, the store's generation clock orders runs by arrival, the
//! compactor only merges generation-adjacent pairs (older run first on
//! ties), and readers resolve ties to the older generation — so
//! duplicate keys emerge from any seal/compact/scan schedule in exact
//! ingest order.
//!
//! Spill: with [`StreamConfig::spill`] set, sealed and compacted runs
//! live as fixed-width binary files under the configured temp dir and
//! are loaded on demand (see [`run`]); without it the store is purely
//! in-memory. The service facade is
//! [`MergeService::ingest`](crate::coordinator::MergeService::ingest) /
//! [`flush_stream`](crate::coordinator::MergeService::flush_stream) /
//! [`scan`](crate::coordinator::MergeService::scan), and `repro
//! stream` drives the mixed ingest + scan + compaction workload.

pub mod compact;
pub mod ingest;
#[cfg(all(test, feature = "model"))]
mod model_tests;
pub mod reader;
pub mod run;
pub mod store;

pub use compact::{compact_once, compact_to_one, merge_runs_parallel, merge_runs_sequential};
pub use ingest::Ingestor;
pub use reader::{scan, scan_iter, ScanIter};
pub use run::Run;
pub use store::{CompactionStats, RunStore, StoreStats};

use std::path::PathBuf;

/// Configuration of one stream (store + its ingestors/compactors).
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Records buffered before a run seals (the bounded in-memory
    /// working set per ingest stream).
    pub run_capacity: usize,
    /// Live-run backlog tolerated before the compaction policy
    /// triggers ([`RunStore::needs_compaction`]).
    pub fanout: usize,
    /// Parallelism granularity for seal sorts and compaction merges
    /// (the `p` handed to the paper's algorithms; the process-wide
    /// executor still bounds real concurrency).
    pub threads: usize,
    /// Spill directory: `Some(dir)` stores runs as binary files under
    /// `dir` (created on demand, cleaned up on drop), `None` keeps
    /// them in memory.
    pub spill: Option<PathBuf>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            run_capacity: 1 << 16,
            fanout: 4,
            threads: crate::util::num_cpus(),
            spill: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::{raw_keys, Dist};
    use std::sync::Arc;

    fn oracle(keys: &[i64]) -> Vec<(i64, u64)> {
        let mut expect: Vec<(i64, u64)> =
            keys.iter().enumerate().map(|(i, &k)| (k, i as u64)).collect();
        expect.sort_by_key(|&(k, _)| k); // stable: ingest order within equal keys
        expect
    }

    fn pairs(records: &[crate::core::record::Record]) -> Vec<(i64, u64)> {
        records.iter().map(|r| (r.key, r.tag)).collect()
    }

    /// Satellite: cross-run stability. Duplicate keys ingested across
    /// runs keep ingest order through seal -> compact -> scan, over
    /// every workload distribution, at three compaction depths (none,
    /// policy-driven, full). Sizes shrink under Miri.
    #[test]
    fn cross_run_stability_over_all_distributions() {
        let (n, cap) = if cfg!(miri) { (60, 8) } else { (6_000, 256) };
        for dist in Dist::all() {
            let keys = raw_keys(dist, n, 0xD15);
            let expect = oracle(&keys);
            let store = Arc::new(
                RunStore::new(StreamConfig {
                    run_capacity: cap,
                    fanout: 4,
                    threads: 2,
                    spill: None,
                })
                .unwrap(),
            );
            let mut ing = Ingestor::new(Arc::clone(&store));
            for &k in &keys {
                ing.push_key(k).unwrap();
            }
            ing.flush().unwrap();
            let name = dist.name();
            // Depth 0: no compaction.
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "{name}: uncompacted");
            // Depth 1: policy-driven compactions until the backlog is
            // back under fanout.
            while compact_once(&store, 2).unwrap().is_some() {}
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "{name}: policy-compacted");
            // Depth 2: full consolidation to a single run.
            compact_to_one(&store, 2).unwrap();
            assert!(store.run_count() <= 1);
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "{name}: fully compacted");
            // The exact-permutation form of the same claim: the fully
            // compacted scan is THE stable sort of the ingest stream.
            let ingested: Vec<crate::core::record::Record> = keys
                .iter()
                .enumerate()
                .map(|(i, &k)| crate::core::record::Record::new(k, i as u64))
                .collect();
            crate::testing::assert_stable_permutation(&[&ingested], &scan(&store).unwrap())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    /// The acceptance shape end to end at the library layer: total
    /// ingested data exceeds the per-run buffer by >= 8x, compaction
    /// runs concurrently with scans, and the final scan is globally
    /// sorted and stable.
    #[test]
    #[cfg(not(miri))]
    fn ingest_exceeds_buffer_8x_with_interleaved_scans() {
        let cap = 512usize;
        let n = cap * 10; // > 8x the per-run buffer
        let keys = raw_keys(Dist::Zipf, n, 77);
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: cap,
                fanout: 3,
                threads: 2,
                spill: None,
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        for (i, &k) in keys.iter().enumerate() {
            let (_, sealed) = ing.push_key(k).unwrap();
            if sealed.is_some() {
                // Interleave: compact on the policy, then scan the
                // sealed prefix — must always be sorted and complete.
                while compact_once(&store, 2).unwrap().is_some() {}
                let seen = scan(&store).unwrap();
                assert_eq!(seen.len() as u64, store.record_count());
                assert_eq!(seen.len(), i + 1 - ing.pending());
                assert!(seen.windows(2).all(|w| w[0].key <= w[1].key));
            }
        }
        ing.flush().unwrap();
        assert_eq!(pairs(&scan(&store).unwrap()), oracle(&keys));
        assert!(store.stats().compactions > 0, "compaction must have run");
    }

    /// Spill-to-disk round trip: the same pipeline with runs on disk.
    #[test]
    #[cfg(not(miri))]
    fn spilled_pipeline_matches_memory_pipeline() {
        let dir = std::env::temp_dir()
            .join(format!("traff-stream-test-{}", std::process::id()));
        let keys = raw_keys(Dist::DupHeavy(16), 2_000, 5);
        let expect = oracle(&keys);
        {
            let store = Arc::new(
                RunStore::new(StreamConfig {
                    run_capacity: 128,
                    fanout: 3,
                    threads: 2,
                    spill: Some(dir.clone()),
                })
                .unwrap(),
            );
            let mut ing = Ingestor::new(Arc::clone(&store));
            for &k in &keys {
                ing.push_key(k).unwrap();
            }
            ing.flush().unwrap();
            assert!(store.stats().spilled_runs > 0, "runs must spill");
            while compact_once(&store, 2).unwrap().is_some() {}
            assert_eq!(pairs(&scan(&store).unwrap()), expect);
            compact_to_one(&store, 2).unwrap();
            assert_eq!(pairs(&scan(&store).unwrap()), expect);
        }
        // Store drop removed the spill files and (best effort) the dir.
        assert!(!dir.exists() || std::fs::read_dir(&dir).map(|mut d| d.next().is_none()).unwrap_or(true));
        let _ = std::fs::remove_dir(&dir);
    }
}

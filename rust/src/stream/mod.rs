//! `stream` — the streaming run-merge subsystem: a durable, paged,
//! restartable sorted-run store with k-way background compaction on
//! the executor's QoS lanes.
//!
//! Everything below this module used to be batch-shaped: a job's data
//! had to fit in memory and arrive whole before `MergeService::sort`
//! touched it. This layer decouples **total data size from job size**
//! twice over: unbounded record streams buffer into bounded runs, and
//! every heavy operation — run sort, k-way compaction, scan — streams
//! fixed-size pages, so no run is ever whole in memory.
//!
//! ```text
//!            push/push_key             seal (sorted, gen-stamped)
//! records ──► [ingest::Ingestor] ─────► [store::RunStore] ◄─ snapshot ── [reader]
//!              bounded buffer            leveled Arc<Run> list            scan /
//!              (core::sort seals         gen clock · CAS claim            scan_iter
//!               stably in parallel)         │         │                  (1 page/run
//!                                           │         ▼                   resident)
//!                    [page] fixed pages     │   [policy] picks a
//!                    + min/max index        │   gen-contiguous window
//!                    [manifest] append-only │         │
//!                    fsync'd log — recovery │         ▼
//!                    replays it on restart  └──► [compact] streaming k-way
//!                    ([`RunStore::recover`])     merge: co-rank rounds (§2/§3)
//!                                                as JobClass::Background jobs
//! ```
//!
//! The paper connection: [`compact`] is the §2 co-rank split doing
//! LSM-compaction work. A picked window of k runs is merged in ONE
//! pass — `ceil(log2 k)` levels of the simplified two-way merge, each
//! level a single background-lane parallel phase
//! ([`crate::core::multiway`]) — instead of k−1 pairwise rewrites, and
//! the driver streams input/output pages so the merge runs out-of-core
//! (bench E10). Service traffic keeps its latency while the store
//! compacts.
//!
//! Durability (spilled stores): run files are page-formatted
//! ([`page`]) and published in two fsync'd steps — the run file is
//! synced before its manifest record is appended, and the manifest
//! record is synced before the run becomes visible in memory. The
//! [`manifest`] is an append-only checksummed log of `AddRun`/`Replace`
//! records; [`RunStore::recover`] replays it, tolerates a torn tail,
//! deletes orphaned run files, and restores the exact leveled run
//! list — a SIGKILL at any point loses only unsealed buffered records.
//!
//! Stability end to end (property-tested below): the seal sort is
//! stable, the store's generation clock orders runs by arrival, the
//! compactor only merges generation-contiguous windows (older run
//! first on ties), and readers resolve ties to the older generation —
//! so duplicate keys emerge from any seal/compact/scan/recover
//! schedule in exact ingest order.
//!
//! Write paths: [`Ingestor`] is the original single-owner buffer;
//! [`writer`] shards the ingest path per submitter thread (each writer
//! owns a lock-free buffer shard, sealed round-robin through the
//! store's shared generation clock), which is what lets N concurrent
//! writers scale instead of serializing on one mutex. The service
//! facade is
//! [`MergeService::open_stream`](crate::coordinator::MergeService::open_stream)
//! returning a [`StreamHandle`](crate::coordinator::StreamHandle) with
//! per-thread [`IngestWriter`](crate::coordinator::IngestWriter)s (the
//! old implicit `ingest`/`flush_stream` trio survives as deprecated
//! wrappers over a default handle), and `repro stream` drives the
//! mixed ingest + scan + compaction workload (`--writers W` for the
//! sharded path, `--recover` to restart from a previous run's spill
//! dir).

pub mod compact;
pub mod ingest;
pub mod manifest;
#[cfg(all(test, feature = "model"))]
mod model_tests;
pub mod page;
pub mod policy;
pub mod reader;
pub mod run;
pub mod store;
pub mod writer;

pub use compact::{
    compact_once, compact_to_one, kway_merge_to_vec, merge_runs_parallel,
    merge_runs_parallel_with, merge_runs_sequential,
};
pub use ingest::Ingestor;
pub use manifest::RunMeta;
pub use policy::{CompactionPolicy, PolicyKind};
pub use reader::{scan, scan_iter, scan_wide, ScanIter};
pub use run::{Run, RunCursor, WideRecord};
pub use store::{CompactionStats, RunStore, StoreStats};
pub use writer::{SeqClock, ShardWriter, WriterSet};

use std::fmt;
use std::path::PathBuf;

/// Typed error surface of the stream layer's write path.
///
/// Replaces the stringly `Result<_, String>` that [`Ingestor`] and
/// [`RunStore::seal`] used to return. The enum is `#[non_exhaustive]`
/// so future failure classes can be added without a breaking change;
/// it implements [`std::error::Error`], so it converts into `anyhow`
/// at the coordinator boundary with plain `?`.
///
/// Read-side paths (`scan`, cursor IO) still surface `String` errors;
/// the store wraps those into [`StreamError::Io`] / [`StreamError::Corrupt`]
/// where they cross the write path.
#[non_exhaustive]
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamError {
    /// An IO failure (spill file or manifest write).
    Io(String),
    /// On-disk state failed validation (checksum, framing, layout).
    Corrupt(String),
    /// A stream in `legacy_pages` mode ran past the v1 format's 2^32
    /// packed-tag record cap. The v2 page format (the default) stores
    /// the sequence's high bits out of line and has no such cap.
    CapExceeded {
        /// The 64-bit ingest sequence number that did not fit.
        seq: u64,
    },
    /// A [`StreamConfig`] failed construction-time validation.
    Config(String),
}

impl fmt::Display for StreamError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StreamError::Io(m) => write!(f, "stream io error: {m}"),
            StreamError::Corrupt(m) => write!(f, "stream corruption: {m}"),
            StreamError::CapExceeded { seq } => write!(
                f,
                "stream record cap exceeded: sequence {seq} does not fit the \
                 legacy v1 page format's 2^32 packed-tag cap (disable \
                 legacy_pages to lift it)"
            ),
            StreamError::Config(m) => write!(f, "invalid stream config: {m}"),
        }
    }
}

impl std::error::Error for StreamError {}

impl From<StreamError> for String {
    fn from(e: StreamError) -> String {
        e.to_string()
    }
}

/// Configuration of one stream (store + its ingestors/compactors).
///
/// Construct via [`StreamConfig::builder`], which validates the shape
/// at construction time (`run_capacity >= 1`, `fanout >= 2`,
/// `page_records >= 1`, `threads >= 1`) instead of scattering runtime
/// clamps through the ingest and store paths. The struct is
/// `#[non_exhaustive]`: downstream crates cannot build it with a bare
/// struct literal (or a `..default()` functional update), so every
/// externally-built config has passed validation.
#[non_exhaustive]
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Records buffered before a run seals (the bounded in-memory
    /// working set per ingest stream).
    pub run_capacity: usize,
    /// Live-run backlog tolerated before the compaction policy
    /// triggers ([`RunStore::needs_compaction`]); also the width cap
    /// for a policy-picked k-way window.
    pub fanout: usize,
    /// Parallelism granularity for seal sorts and compaction merges
    /// (the `p` handed to the paper's algorithms; the process-wide
    /// executor still bounds real concurrency).
    pub threads: usize,
    /// Spill directory: `Some(dir)` stores runs as paged binary files
    /// under `dir` with an fsync'd manifest (durable — survives
    /// restart via [`RunStore::recover`]), `None` keeps them in
    /// memory.
    pub spill: Option<PathBuf>,
    /// Records per page in spilled run files — the granularity of
    /// cursor reads and the per-run resident bound for scans and
    /// compactions.
    pub page_records: usize,
    /// Which compaction policy picks the next window
    /// ([`policy::PolicyKind`]).
    pub policy: PolicyKind,
    /// Write spilled runs in the legacy v1 page format (no out-of-line
    /// sequence column). A legacy stream keeps the old packed-tag
    /// limit: ingesting past 2^32 records fails with
    /// [`StreamError::CapExceeded`]. Off by default — the v2 format
    /// stores the high sequence bits out of line and has no cap; v1
    /// files remain readable either way.
    pub legacy_pages: bool,
    /// Merge kernel for compaction and scan merges:
    /// [`MergeStrategy::Fixed`] pre-partitions each merge round,
    /// [`MergeStrategy::Adaptive`] merges sequentially in bounded
    /// quanta and splits on observed steal requests
    /// ([`crate::core::adaptive`]).
    pub strategy: crate::core::MergeStrategy,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            run_capacity: 1 << 16,
            fanout: 4,
            threads: crate::util::num_cpus(),
            spill: None,
            page_records: 1024,
            policy: PolicyKind::AdjacentPair,
            legacy_pages: false,
            strategy: crate::core::MergeStrategy::Fixed,
        }
    }
}

impl StreamConfig {
    /// Start building a validated config.
    ///
    /// ```
    /// use traff_merge::stream::StreamConfig;
    ///
    /// let cfg = StreamConfig::builder()
    ///     .run_capacity(4096)
    ///     .fanout(6)
    ///     .threads(2)
    ///     .build()
    ///     .unwrap();
    /// assert_eq!(cfg.run_capacity, 4096);
    ///
    /// // Degenerate shapes are rejected at construction, not clamped
    /// // deep inside the ingest path.
    /// assert!(StreamConfig::builder().run_capacity(0).build().is_err());
    /// assert!(StreamConfig::builder().fanout(1).build().is_err());
    /// assert!(StreamConfig::builder().page_records(0).build().is_err());
    /// ```
    pub fn builder() -> StreamConfigBuilder {
        StreamConfigBuilder { cfg: StreamConfig::default() }
    }

    /// Escape hatch for code migrating off bare struct-literal
    /// construction (which `#[non_exhaustive]` now forbids outside
    /// this crate). Performs NO validation — a degenerate shape will
    /// be rejected later by [`RunStore::new`] instead.
    #[deprecated(note = "use StreamConfig::builder(), which validates at construction")]
    pub fn unvalidated(
        run_capacity: usize,
        fanout: usize,
        threads: usize,
        spill: Option<PathBuf>,
        page_records: usize,
        policy: PolicyKind,
    ) -> StreamConfig {
        StreamConfig {
            run_capacity,
            fanout,
            threads,
            spill,
            page_records,
            policy,
            legacy_pages: false,
            strategy: crate::core::MergeStrategy::Fixed,
        }
    }

    /// Shape validation shared by [`StreamConfigBuilder::build`] and
    /// the store constructors (defense in depth for same-crate literal
    /// construction, which bypasses the builder).
    pub(crate) fn validate(&self) -> Result<(), StreamError> {
        if self.run_capacity == 0 {
            return Err(StreamError::Config("run_capacity must be >= 1".into()));
        }
        if self.fanout < 2 {
            return Err(StreamError::Config("fanout must be >= 2".into()));
        }
        if self.page_records == 0 {
            return Err(StreamError::Config("page_records must be >= 1".into()));
        }
        if self.threads == 0 {
            return Err(StreamError::Config("threads must be >= 1".into()));
        }
        Ok(())
    }
}

/// Builder for [`StreamConfig`] — the only construction path outside
/// this crate. [`build`](StreamConfigBuilder::build) validates the
/// shape and returns [`StreamError::Config`] on a degenerate one.
#[derive(Clone, Debug)]
pub struct StreamConfigBuilder {
    cfg: StreamConfig,
}

impl StreamConfigBuilder {
    /// Records buffered before a run seals.
    pub fn run_capacity(mut self, n: usize) -> Self {
        self.cfg.run_capacity = n;
        self
    }

    /// Live-run backlog tolerated before compaction triggers (also the
    /// k-way window width cap). Must be >= 2.
    pub fn fanout(mut self, n: usize) -> Self {
        self.cfg.fanout = n;
        self
    }

    /// Parallelism granularity for seal sorts and compaction merges.
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.threads = n;
        self
    }

    /// Spill runs to paged files under `dir` (durable via
    /// [`RunStore::recover`]). Without this call the store stays in
    /// memory.
    pub fn spill(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.spill = Some(dir.into());
        self
    }

    /// Records per page in spilled run files.
    pub fn page_records(mut self, n: usize) -> Self {
        self.cfg.page_records = n;
        self
    }

    /// Which compaction policy picks the next window.
    pub fn policy(mut self, kind: PolicyKind) -> Self {
        self.cfg.policy = kind;
        self
    }

    /// Write legacy v1 pages (and keep the 2^32 record cap). See
    /// [`StreamConfig::legacy_pages`].
    pub fn legacy_pages(mut self, on: bool) -> Self {
        self.cfg.legacy_pages = on;
        self
    }

    /// Merge kernel for compaction and scan merges. See
    /// [`StreamConfig::strategy`].
    pub fn strategy(mut self, strategy: crate::core::MergeStrategy) -> Self {
        self.cfg.strategy = strategy;
        self
    }

    /// Validate and produce the config.
    pub fn build(self) -> Result<StreamConfig, StreamError> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::{raw_keys, Dist};
    use std::sync::Arc;

    fn oracle(keys: &[i64]) -> Vec<(i64, u64)> {
        let mut expect: Vec<(i64, u64)> =
            keys.iter().enumerate().map(|(i, &k)| (k, i as u64)).collect();
        expect.sort_by_key(|&(k, _)| k); // stable: ingest order within equal keys
        expect
    }

    fn pairs(records: &[crate::core::record::Record]) -> Vec<(i64, u64)> {
        records.iter().map(|r| (r.key, r.tag)).collect()
    }

    /// Satellite: construction-time validation replaces the scattered
    /// runtime clamps — degenerate shapes are a typed `Config` error.
    #[test]
    fn builder_validates_shape() {
        let ok = StreamConfig::builder().run_capacity(8).fanout(2).threads(1).build().unwrap();
        assert_eq!(ok.run_capacity, 8);
        assert_eq!(ok.fanout, 2);
        assert!(!ok.legacy_pages);
        for bad in [
            StreamConfig::builder().run_capacity(0).build(),
            StreamConfig::builder().fanout(0).build(),
            StreamConfig::builder().fanout(1).build(),
            StreamConfig::builder().page_records(0).build(),
            StreamConfig::builder().threads(0).build(),
        ] {
            match bad {
                Err(StreamError::Config(_)) => {}
                other => panic!("expected Config error, got {other:?}"),
            }
        }
        // The store constructors re-validate, so same-crate literal
        // construction cannot smuggle a degenerate shape past them.
        let cfg = StreamConfig { fanout: 1, ..StreamConfig::default() };
        assert!(matches!(RunStore::new(cfg), Err(StreamError::Config(_))));
    }

    /// Satellite: cross-run stability. Duplicate keys ingested across
    /// runs keep ingest order through seal -> compact -> scan, over
    /// every workload distribution, at three compaction depths (none,
    /// policy-driven, full). Sizes shrink under Miri.
    #[test]
    fn cross_run_stability_over_all_distributions() {
        let (n, cap) = if cfg!(miri) { (60, 8) } else { (6_000, 256) };
        for dist in Dist::all() {
            let keys = raw_keys(dist, n, 0xD15);
            let expect = oracle(&keys);
            let store = Arc::new(
                RunStore::new(StreamConfig {
                    run_capacity: cap,
                    fanout: 4,
                    threads: 2,
                    ..StreamConfig::default()
                })
                .unwrap(),
            );
            let mut ing = Ingestor::new(Arc::clone(&store));
            for &k in &keys {
                ing.push_key(k).unwrap();
            }
            ing.flush().unwrap();
            let name = dist.name();
            // Depth 0: no compaction.
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "{name}: uncompacted");
            // Depth 1: policy-driven compactions until the backlog is
            // back under fanout.
            while compact_once(&store, 2).unwrap().is_some() {}
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "{name}: policy-compacted");
            // Depth 2: full consolidation to a single run.
            compact_to_one(&store, 2).unwrap();
            assert!(store.run_count() <= 1);
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "{name}: fully compacted");
            // The exact-permutation form of the same claim: the fully
            // compacted scan is THE stable sort of the ingest stream.
            let ingested: Vec<crate::core::record::Record> = keys
                .iter()
                .enumerate()
                .map(|(i, &k)| crate::core::record::Record::new(k, i as u64))
                .collect();
            crate::testing::assert_stable_permutation(&[&ingested], &scan(&store).unwrap())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    /// Every policy preserves the stable-scan contract at every
    /// compaction depth (the window *choice* differs; the merge result
    /// must not).
    #[test]
    fn all_policies_preserve_the_stable_scan() {
        let (n, cap) = if cfg!(miri) { (48, 6) } else { (3_000, 128) };
        let keys = raw_keys(Dist::DupHeavy(8), n, 0xB0B);
        let expect = oracle(&keys);
        for kind in
            [PolicyKind::AdjacentPair, PolicyKind::SizeTiered, PolicyKind::OverlapAware]
        {
            let store = Arc::new(
                RunStore::new(StreamConfig {
                    run_capacity: cap,
                    fanout: 4,
                    threads: 2,
                    policy: kind,
                    ..StreamConfig::default()
                })
                .unwrap(),
            );
            let mut ing = Ingestor::new(Arc::clone(&store));
            for &k in &keys {
                ing.push_key(k).unwrap();
            }
            ing.flush().unwrap();
            while compact_once(&store, 2).unwrap().is_some() {}
            assert_eq!(pairs(&scan(&store).unwrap()), expect, "policy {}", kind.name());
            compact_to_one(&store, 2).unwrap();
            assert_eq!(
                pairs(&scan(&store).unwrap()),
                expect,
                "policy {} fully compacted",
                kind.name()
            );
        }
    }

    /// The acceptance shape end to end at the library layer: total
    /// ingested data exceeds the per-run buffer by >= 8x, compaction
    /// runs concurrently with scans, and the final scan is globally
    /// sorted and stable.
    #[test]
    #[cfg(not(miri))]
    fn ingest_exceeds_buffer_8x_with_interleaved_scans() {
        let cap = 512usize;
        let n = cap * 10; // > 8x the per-run buffer
        let keys = raw_keys(Dist::Zipf, n, 77);
        let store = Arc::new(
            RunStore::new(StreamConfig {
                run_capacity: cap,
                fanout: 3,
                threads: 2,
                ..StreamConfig::default()
            })
            .unwrap(),
        );
        let mut ing = Ingestor::new(Arc::clone(&store));
        for (i, &k) in keys.iter().enumerate() {
            let (_, sealed) = ing.push_key(k).unwrap();
            if sealed.is_some() {
                // Interleave: compact on the policy, then scan the
                // sealed prefix — must always be sorted and complete.
                while compact_once(&store, 2).unwrap().is_some() {}
                let seen = scan(&store).unwrap();
                assert_eq!(seen.len() as u64, store.record_count());
                assert_eq!(seen.len(), i + 1 - ing.pending());
                assert!(seen.windows(2).all(|w| w[0].key <= w[1].key));
            }
        }
        ing.flush().unwrap();
        assert_eq!(pairs(&scan(&store).unwrap()), oracle(&keys));
        assert!(store.stats().compactions > 0, "compaction must have run");
    }

    /// Spill-to-disk round trip with durable restart: the paged
    /// pipeline matches the in-memory oracle, the files survive the
    /// store's drop, and [`RunStore::recover`] restores the identical
    /// stable view.
    #[test]
    #[cfg(not(miri))]
    fn spilled_pipeline_is_durable_across_restart() {
        let dir = std::env::temp_dir()
            .join(format!("traff-stream-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let keys = raw_keys(Dist::DupHeavy(16), 2_000, 5);
        let expect = oracle(&keys);
        let cfg = StreamConfig {
            run_capacity: 128,
            fanout: 3,
            threads: 2,
            spill: Some(dir.clone()),
            page_records: 64,
            ..StreamConfig::default()
        };
        {
            let store = Arc::new(RunStore::new(cfg.clone()).unwrap());
            let mut ing = Ingestor::new(Arc::clone(&store));
            for &k in &keys {
                ing.push_key(k).unwrap();
            }
            ing.flush().unwrap();
            assert!(store.stats().spilled_runs > 0, "runs must spill");
            while compact_once(&store, 2).unwrap().is_some() {}
            assert_eq!(pairs(&scan(&store).unwrap()), expect);
            compact_to_one(&store, 2).unwrap();
            assert_eq!(pairs(&scan(&store).unwrap()), expect);
        }
        // Durable: the run files and manifest survive the drop.
        assert!(dir.join(manifest::MANIFEST_NAME).exists());
        let recovered = Arc::new(RunStore::recover(cfg).unwrap());
        assert_eq!(recovered.record_count(), keys.len() as u64);
        assert_eq!(pairs(&scan(&recovered).unwrap()), expect, "recovered view is identical");
        drop(recovered);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

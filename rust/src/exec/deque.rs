//! Lock-free Chase–Lev work-stealing deque (Chase & Lev, SPAA'05),
//! with the memory orderings of Lê, Pop, Cohen & Zappa Nardelli,
//! "Correct and Efficient Work-Stealing for Weakly Ordered Memory
//! Models" (PPoPP'13).
//!
//! Shape: the owning worker pushes and pops at the *bottom* end (LIFO,
//! cache-warm); thieves take from the *top* end (FIFO) with a single
//! CAS. Only that CAS is a synchronizing read-modify-write — the
//! owner's push and (non-racing) pop are plain loads/stores plus
//! fences, which is what makes the owner's fast path cheaper than any
//! `Mutex<VecDeque>` round-trip.
//!
//! # Memory-ordering invariants (the correctness argument)
//!
//! `top` and `bottom` are `isize` indices into an infinite logical
//! array (the buffer is a power-of-two circular window onto it). `top`
//! only ever increases; the live window is `[top, bottom)`.
//!
//! - **Publish** (`push`): the slot write is `Relaxed`, followed by a
//!   `Release` fence, then the `bottom` store. A thief that observes
//!   the incremented `bottom` through its `Acquire` load therefore
//!   also observes the slot contents (fence/fence pairing), so a thief
//!   can never steal an uninitialized or half-written slot.
//! - **Claim** (`steal`): `top` is loaded `Acquire`, then a `SeqCst`
//!   fence, then `bottom` is loaded `Acquire`. The fence keeps the two
//!   loads ordered, so the window the thief computes is never wider
//!   than a real historical window. The slot is read *before* the
//!   `SeqCst` CAS on `top`: a successful CAS proves `top` was still
//!   `t` at the claim, and logical slot `t` is immutable while
//!   `t >= top` — the owner only writes slots `>= bottom`, growth
//!   copies the live window unchanged, and the owner can only recycle
//!   the physical slot `t % cap` for logical index `t + cap` after
//!   `top` has moved past `t`, which would make this CAS fail. The
//!   winning CAS transfers sole ownership of the boxed job.
//! - **Take race** (`pop`): the owner stores the decremented `bottom`,
//!   executes a `SeqCst` fence, and only then loads `top`. The fence
//!   places the decrement before the inspection in the single total
//!   order that `SeqCst` fences and the thieves' `SeqCst` CASes agree
//!   on, so when owner and thieves race for the last element exactly
//!   one wins: either the thief's CAS lands first (the owner then sees
//!   `top == bottom` and must CAS too, losing), or the owner's
//!   decrement is visible first (the thief's recheck of `bottom` sees
//!   an empty window, or its CAS fails).
//! - **Growth** (`grow`): only the owner grows, so the buffer swap
//!   itself is unsynchronized with other writers. The new buffer is
//!   published with a `Release` store, paired with the thief's
//!   `Acquire` load of the buffer pointer. The old buffer is *retired,
//!   not freed*, until the deque is dropped — a thief still holding a
//!   stale buffer pointer reads valid memory, and any value it reads
//!   from a recycled slot is rejected by its subsequent CAS (see
//!   Claim).
//!
//! Jobs are stored as thin raw pointers (`*mut Job`, a pointer to the
//! boxed closure) so slots can be read speculatively; ownership is
//! materialized back into a `Box` only by the unique claimant.

use std::ptr;
use crate::model::sync::{fence, AtomicBool, AtomicIsize, AtomicPtr, AtomicU64, Mutex, Ordering};

/// The job type stored in the deque (same shape as `exec::Job`).
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// Outcome of a [`Deque::steal`] attempt.
pub enum Steal {
    /// The deque was (or appeared) empty.
    Empty,
    /// Lost the `top` CAS race to the owner or another thief. The
    /// victim still has (or very recently had) work — retrying can pay.
    Retry,
    /// A job, now exclusively owned by the caller.
    Success(Job),
}

/// Power-of-two circular slot array, indexed by the logical position.
struct Buffer {
    mask: usize,
    slots: Box<[AtomicPtr<Job>]>,
}

impl Buffer {
    fn alloc(cap: usize) -> *mut Buffer {
        debug_assert!(cap.is_power_of_two());
        let slots: Box<[AtomicPtr<Job>]> =
            (0..cap).map(|_| AtomicPtr::new(ptr::null_mut())).collect();
        Box::into_raw(Box::new(Buffer { mask: cap - 1, slots }))
    }

    #[inline]
    fn cap(&self) -> usize {
        self.slots.len()
    }

    #[inline]
    fn get(&self, i: isize) -> *mut Job {
        self.slots[i as usize & self.mask].load(Ordering::Relaxed)
    }

    #[inline]
    fn put(&self, i: isize, p: *mut Job) {
        self.slots[i as usize & self.mask].store(p, Ordering::Relaxed);
    }
}

/// The deque proper. `push` and `pop` MUST only be called by the
/// owning worker thread (the `exec` module guarantees this via the
/// worker-id TLS); `steal`, `len` and `is_empty` are safe from any
/// thread.
pub struct Deque {
    /// Steal end; only ever incremented, always through `SeqCst` CAS
    /// (thieves) or the owner's last-element CAS.
    top: AtomicIsize,
    /// Owner end; written only by the owner.
    bottom: AtomicIsize,
    /// Current buffer; swapped only by the owner in `grow`.
    buf: AtomicPtr<Buffer>,
    /// Buffers replaced by growth, kept alive until drop so a thief
    /// holding a stale pointer never reads freed memory. Touched only
    /// on growth (owner) and on drop — never on the hot path.
    retired: Mutex<Vec<*mut Buffer>>,
}

// SAFETY: the raw buffer pointers are managed per the protocol above —
// slots transfer job ownership through the `top` CAS, buffers are
// freed only under `&mut self` in `Drop`.
unsafe impl Send for Deque {}
unsafe impl Sync for Deque {}

impl Deque {
    pub fn new() -> Deque {
        Deque {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Buffer::alloc(64)),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Approximate live length — monitoring and sleep checks only.
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        if b > t {
            (b - t) as usize
        } else {
            0
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Owner-only: push a job at the bottom.
    pub fn push(&self, job: Job) {
        let p = Box::into_raw(Box::new(job));
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = self.buf.load(Ordering::Relaxed);
        unsafe {
            if b - t >= (*buf).cap() as isize {
                buf = self.grow(buf, b, t);
            }
            (*buf).put(b, p);
        }
        fence(Ordering::Release);
        self.bottom.store(b + 1, Ordering::Relaxed);
    }

    /// Owner-only: publish a whole batch at the bottom with a single
    /// release fence and a single `bottom` store — the entry point the
    /// injector drain uses to move an external batch onto a worker's
    /// deque without paying one publish per job.
    ///
    /// Ordering: identical to [`Deque::push`] — all slots are written
    /// (`Relaxed`) before one `Release` fence, then `bottom` jumps by
    /// the batch length. A thief that observes the new `bottom`
    /// observes every slot in the batch. Capacity is ensured up front
    /// (`grow` only copies the live window `[top, bottom)`, so staged
    /// slots must never straddle a growth).
    pub fn push_batch(&self, jobs: Vec<Job>) {
        if jobs.is_empty() {
            return;
        }
        let n = jobs.len() as isize;
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = self.buf.load(Ordering::Relaxed);
        unsafe {
            // A stale (small) `top` only over-estimates the live
            // window: growth may be pessimistic, never unsound.
            while b - t + n > (*buf).cap() as isize {
                buf = self.grow(buf, b, t);
            }
            for (k, job) in jobs.into_iter().enumerate() {
                (*buf).put(b + k as isize, Box::into_raw(Box::new(job)));
            }
        }
        fence(Ordering::Release);
        self.bottom.store(b + n, Ordering::Relaxed);
    }

    /// Owner-only: pop from the bottom (LIFO).
    pub fn pop(&self) -> Option<Job> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = self.buf.load(Ordering::Relaxed);
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t > b {
            // Empty: undo the speculative decrement.
            self.bottom.store(b + 1, Ordering::Relaxed);
            return None;
        }
        let p = unsafe { (*buf).get(b) };
        if t == b {
            // Last element: race the thieves for it with the same CAS
            // they use, then restore `bottom` to the canonical empty
            // position either way.
            let won = self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok();
            self.bottom.store(b + 1, Ordering::Relaxed);
            if !won {
                return None;
            }
        }
        Some(unsafe { *Box::from_raw(p) })
    }

    /// Any thread: try to take the oldest job from the top (FIFO).
    pub fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        // Read the slot BEFORE claiming it: after a successful CAS the
        // owner may recycle the slot at any time. A stale read here is
        // harmless — it implies `top` already moved, so the CAS fails.
        let buf = self.buf.load(Ordering::Acquire);
        let p = unsafe { (*buf).get(t) };
        if self
            .top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
            .is_err()
        {
            return Steal::Retry;
        }
        Steal::Success(unsafe { *Box::from_raw(p) })
    }

    /// Owner-only (from `push`): double the buffer, copy the live
    /// window, publish the new buffer, retire the old one.
    fn grow(&self, old: *mut Buffer, b: isize, t: isize) -> *mut Buffer {
        let new = Buffer::alloc(unsafe { (*old).cap() } * 2);
        unsafe {
            for i in t..b {
                (*new).put(i, (*old).get(i));
            }
        }
        self.buf.store(new, Ordering::Release);
        self.retired.lock().unwrap().push(old);
        new
    }
}

impl Default for Deque {
    fn default() -> Deque {
        Deque::new()
    }
}

/// Per-worker steal-request flags — the demand signal behind the
/// adaptive "sequential-until-stolen" merge kernel
/// ([`crate::core::adaptive`]).
///
/// An idle worker *raises* the flag of a victim it found empty before
/// parking; a running task *takes* (consumes) a raised flag between
/// work quanta and reacts by splitting off a stealable half. The flag
/// is intentionally a saturating one-bit signal per worker: concurrent
/// raises coalesce (one split feeds the whole idle set, which then
/// steals or re-raises), and `take` consumes with a single
/// read-modify-write so one raise can never trigger two splits.
///
/// # Ordering protocol (model-tested, Miri-covered)
///
/// - `raise` is a `Release` store of `true`: everything the idle
///   worker did before asking (notably its own deque going empty) is
///   visible to the poller that `Acquire`-consumes the flag.
/// - `take` is a `Relaxed` fast-path load (the between-quanta poll
///   must cost one uncontended cache hit) followed, only when the
///   flag was seen raised, by a `swap(false, AcqRel)` — the swap is
///   the single consumption point, so a raise is taken at most once
///   (*no phantom split*).
/// - A raise can never be lost: the flag stays `true` until some
///   poller's swap observes it, and the split that poller publishes
///   goes through `Executor::push_job` → `notify_one`, which wakes
///   parked workers under the sleep lock (*no lost wake*). If no task
///   is running, the idle worker parks with a bounded timeout and
///   re-checks, so a stale raise costs one timeout tick at worst.
pub struct StealSignal {
    flags: Box<[AtomicBool]>,
    /// Raise timestamps (obs clock, nanos), index-aligned with
    /// `flags`. Best-effort observability only: a re-raise before the
    /// take overwrites the stamp (latest raise wins), and `Relaxed`
    /// suffices because the value rides the flag's Release/Acquire
    /// edge in the common case and a torn window merely mis-sizes one
    /// histogram sample.
    raised_at: Box<[AtomicU64]>,
    /// Take-side latency sink (`exec.steal_take_latency`), injected by
    /// the executor after construction. `None` (model tests, bare
    /// signals) keeps raise/take free of histogram traffic.
    hist: std::sync::OnceLock<std::sync::Arc<crate::obs::Hist>>,
}

impl StealSignal {
    pub fn new(workers: usize) -> StealSignal {
        StealSignal {
            flags: (0..workers.max(1)).map(|_| AtomicBool::new(false)).collect(),
            raised_at: (0..workers.max(1)).map(|_| AtomicU64::new(0)).collect(),
            hist: std::sync::OnceLock::new(),
        }
    }

    /// Route raise→take latencies into `h` (at most once; later calls
    /// are ignored). Called by `Executor::new` with the process
    /// registry's `exec.steal_take_latency` histogram.
    pub fn set_latency_hist(&self, h: std::sync::Arc<crate::obs::Hist>) {
        let _ = self.hist.set(h);
    }

    /// Number of per-worker flags (== executor worker count).
    pub fn workers(&self) -> usize {
        self.flags.len()
    }

    /// Idle side: ask worker `victim` to split its current work.
    /// Saturating — raising an already-raised flag is a no-op.
    pub fn raise(&self, victim: usize) {
        let i = victim % self.flags.len();
        if self.hist.get().is_some() {
            self.raised_at[i].store(crate::obs::trace::now_nanos(), Ordering::Relaxed);
        }
        self.flags[i].store(true, Ordering::Release);
    }

    /// Running side: consume a steal request aimed at `worker`.
    /// Returns `true` at most once per raise (swap is the single
    /// consumption point). The fast path is one `Relaxed` load.
    pub fn take(&self, worker: usize) -> bool {
        let i = worker % self.flags.len();
        let flag = &self.flags[i];
        if flag.load(Ordering::Relaxed) && flag.swap(false, Ordering::AcqRel) {
            if let Some(h) = self.hist.get() {
                let raised = self.raised_at[i].load(Ordering::Relaxed);
                h.record(crate::obs::trace::now_nanos().saturating_sub(raised));
            }
            return true;
        }
        false
    }

    /// Running side, for threads that are not workers (e.g. the scope
    /// waiter executing the root task on the caller's thread): sweep
    /// all flags starting at `start` and consume the first raised one.
    pub fn take_any(&self, start: usize) -> bool {
        let n = self.flags.len();
        for k in 0..n {
            if self.take(start.wrapping_add(k) % n) {
                return true;
            }
        }
        false
    }

    /// Monitoring only: is a request currently pending for `worker`?
    pub fn is_raised(&self, worker: usize) -> bool {
        self.flags[worker % self.flags.len()].load(Ordering::Relaxed)
    }
}

impl Drop for Deque {
    fn drop(&mut self) {
        // `&mut self`: no concurrent owner or thieves remain. Drop the
        // unconsumed jobs, then every buffer ever allocated.
        while let Some(job) = self.pop() {
            drop(job);
        }
        unsafe {
            drop(Box::from_raw(self.buf.load(Ordering::Relaxed)));
            for old in self.retired.lock().unwrap().drain(..) {
                drop(Box::from_raw(old));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sync::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn owner_is_lifo_thief_is_fifo() {
        let d = Deque::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..3 {
            let log = Arc::clone(&log);
            d.push(Box::new(move || log.lock().unwrap().push(i)));
        }
        // The thief takes the oldest job...
        match d.steal() {
            Steal::Success(job) => job(),
            _ => panic!("steal from a 3-element deque failed"),
        }
        // ...the owner takes the newest.
        d.pop().expect("two jobs left")();
        d.pop().expect("one job left")();
        assert!(d.pop().is_none());
        assert_eq!(*log.lock().unwrap(), vec![0, 2, 1]);
    }

    #[test]
    fn growth_preserves_every_job() {
        let d = Deque::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let n = if cfg!(miri) { 200 } else { 1000 }; // past the initial capacity of 64
        for _ in 0..n {
            let hits = Arc::clone(&hits);
            d.push(Box::new(move || {
                hits.fetch_add(1, Ordering::Relaxed);
            }));
        }
        let mut ran = 0;
        while let Some(job) = d.pop() {
            job();
            ran += 1;
        }
        assert_eq!(ran, n);
        assert_eq!(hits.load(Ordering::Relaxed), n);
    }

    #[test]
    fn push_batch_publishes_in_order_across_growth() {
        let d = Deque::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        let n = if cfg!(miri) { 100 } else { 500 }; // forces growth (cap 64)
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                let log = Arc::clone(&log);
                Box::new(move || log.lock().unwrap().push(i)) as Job
            })
            .collect();
        d.push_batch(jobs);
        assert_eq!(d.len(), n);
        // Thieves see the batch oldest-first (top end), in batch order.
        match d.steal() {
            Steal::Success(job) => job(),
            _ => panic!("steal from a freshly published batch failed"),
        }
        match d.steal() {
            Steal::Success(job) => job(),
            _ => panic!("second steal failed"),
        }
        // The owner drains the rest newest-first.
        while let Some(job) = d.pop() {
            job();
        }
        let got = log.lock().unwrap().clone();
        assert_eq!(got[0], 0);
        assert_eq!(got[1], 1);
        let mut rest = got[2..].to_vec();
        rest.sort_unstable();
        assert_eq!(rest, (2..n).collect::<Vec<_>>());
        // LIFO on the owner side: after the two steals, pops run n-1
        // down to 2.
        assert_eq!(got[2], n - 1);
    }

    #[test]
    fn unconsumed_jobs_are_dropped_not_leaked() {
        struct Canary(Arc<AtomicUsize>);
        impl Drop for Canary {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::Relaxed);
            }
        }
        let drops = Arc::new(AtomicUsize::new(0));
        let d = Deque::new();
        for _ in 0..10 {
            let canary = Canary(Arc::clone(&drops));
            d.push(Box::new(move || {
                let _keep = &canary;
            }));
        }
        drop(d);
        assert_eq!(drops.load(Ordering::Relaxed), 10);
    }

    /// Forced-steal correctness at the deque level: the owner only
    /// pushes, so every job MUST arrive through a steal — across
    /// growth, contention and CAS races, each job runs exactly once.
    #[test]
    fn concurrent_thieves_deliver_each_job_exactly_once() {
        const JOBS: usize = if cfg!(miri) { 300 } else { 10_000 };
        const THIEVES: usize = if cfg!(miri) { 2 } else { 4 };
        let d = Arc::new(Deque::new());
        let seen: Arc<Vec<AtomicUsize>> =
            Arc::new((0..JOBS).map(|_| AtomicUsize::new(0)).collect());
        let stolen = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..THIEVES {
                let d = Arc::clone(&d);
                let stolen = Arc::clone(&stolen);
                s.spawn(move || loop {
                    if stolen.load(Ordering::Relaxed) >= JOBS {
                        break;
                    }
                    match d.steal() {
                        Steal::Success(job) => {
                            job();
                            stolen.fetch_add(1, Ordering::Relaxed);
                        }
                        Steal::Empty | Steal::Retry => std::hint::spin_loop(),
                    }
                });
            }
            // This thread is the owner: push while the thieves race.
            for i in 0..JOBS {
                let seen = Arc::clone(&seen);
                d.push(Box::new(move || {
                    seen[i].fetch_add(1, Ordering::Relaxed);
                }));
            }
        });
        assert_eq!(stolen.load(Ordering::Relaxed), JOBS);
        for (i, count) in seen.iter().enumerate() {
            assert_eq!(count.load(Ordering::Relaxed), 1, "job {i} misdelivered");
        }
    }

    #[test]
    fn steal_signal_take_consumes_exactly_once() {
        let s = StealSignal::new(4);
        assert!(!s.take(2), "no raise yet");
        s.raise(2);
        assert!(s.is_raised(2));
        assert!(s.take(2), "first take consumes the raise");
        assert!(!s.take(2), "a raise is consumed at most once");
        // Raises coalesce: two raises, one take.
        s.raise(1);
        s.raise(1);
        assert!(s.take(1));
        assert!(!s.take(1));
    }

    #[test]
    fn steal_signal_take_any_sweeps_from_start() {
        let s = StealSignal::new(4);
        s.raise(1);
        s.raise(3);
        // Sweep starting at 2 finds 3 first, then wraps to 1.
        assert!(s.take_any(2));
        assert!(!s.is_raised(3));
        assert!(s.is_raised(1));
        assert!(s.take_any(2));
        assert!(!s.take_any(0), "all consumed");
    }

    #[test]
    fn steal_signal_zero_workers_is_inert() {
        // Degenerate executor shapes must not panic on modulo-0.
        let s = StealSignal::new(0);
        assert_eq!(s.workers(), 1);
        s.raise(0);
        assert!(s.take(0));
    }

    /// Concurrent raisers against one polling consumer: every raise
    /// is eventually observed (no lost wake) and the number of
    /// successful takes never exceeds the number of raises (no
    /// phantom split).
    #[test]
    fn steal_signal_raise_vs_poll_race() {
        const RAISERS: usize = if cfg!(miri) { 2 } else { 4 };
        const ROUNDS: usize = if cfg!(miri) { 50 } else { 5_000 };
        let s = Arc::new(StealSignal::new(1));
        let raised = Arc::new(AtomicUsize::new(0));
        let taken = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        std::thread::scope(|scope| {
            for _ in 0..RAISERS {
                let s = Arc::clone(&s);
                let raised = Arc::clone(&raised);
                scope.spawn(move || {
                    for _ in 0..ROUNDS {
                        s.raise(0);
                        // Release so the main thread's Acquire count
                        // read orders this raise before `stop`.
                        raised.fetch_add(1, Ordering::Release);
                    }
                });
            }
            let poller = {
                let s = Arc::clone(&s);
                let taken = Arc::clone(&taken);
                let stop = Arc::clone(&stop);
                scope.spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        if s.take(0) {
                            taken.fetch_add(1, Ordering::Relaxed);
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                    // Final drain: a raise left pending when the
                    // raisers finished must still be observable.
                    if s.take(0) {
                        taken.fetch_add(1, Ordering::Relaxed);
                    }
                })
            };
            // Wait for the raisers (scope joins them on drop order is
            // not guaranteed, so join explicitly via counting).
            while raised.load(Ordering::Acquire) < RAISERS * ROUNDS {
                std::hint::spin_loop();
            }
            stop.store(true, Ordering::Release);
            let _ = poller;
        });
        let t = taken.load(Ordering::Relaxed);
        let r = raised.load(Ordering::Relaxed);
        assert!(t >= 1, "at least one raise must be observed");
        assert!(t <= r, "takes ({t}) exceeded raises ({r}) — phantom split");
        assert!(!s.is_raised(0), "final drain left a pending raise");
    }

    /// Owner pops race thief steals for the same jobs: nothing is lost
    /// and nothing runs twice, including the 1-element take race.
    #[test]
    fn owner_pops_race_thief_steals() {
        const JOBS: usize = if cfg!(miri) { 400 } else { 20_000 };
        let d = Arc::new(Deque::new());
        let seen: Arc<Vec<AtomicUsize>> =
            Arc::new((0..JOBS).map(|_| AtomicUsize::new(0)).collect());
        let done = Arc::new(AtomicBool::new(false));
        std::thread::scope(|s| {
            for _ in 0..3 {
                let d = Arc::clone(&d);
                let done = Arc::clone(&done);
                s.spawn(move || loop {
                    match d.steal() {
                        Steal::Success(job) => job(),
                        Steal::Retry => {}
                        Steal::Empty => {
                            if done.load(Ordering::Acquire) {
                                break;
                            }
                            std::hint::spin_loop();
                        }
                    }
                });
            }
            // Owner: interleave pushes with pops, then drain. After
            // `pop` returns None the deque holds nothing (None means
            // empty or the last element went to a thief), so setting
            // `done` afterwards cannot strand jobs.
            for i in 0..JOBS {
                let seen = Arc::clone(&seen);
                d.push(Box::new(move || {
                    seen[i].fetch_add(1, Ordering::Relaxed);
                }));
                if i % 3 == 0 {
                    if let Some(job) = d.pop() {
                        job();
                    }
                }
            }
            while let Some(job) = d.pop() {
                job();
            }
            done.store(true, Ordering::Release);
        });
        for (i, count) in seen.iter().enumerate() {
            assert_eq!(count.load(Ordering::Relaxed), 1, "job {i} misdelivered");
        }
    }
}

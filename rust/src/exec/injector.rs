//! Lock-free sharded injector — the external entry queue of the
//! executor — with **two priority lanes per shard** (PR 4).
//!
//! Before this module the injector was one `Mutex<VecDeque>`: every
//! submission from a non-worker thread and every worker drain crossed
//! the same lock, so under high external submission rates the entry
//! point serialized exactly the way the paper's single-synchronization
//! merge works to avoid. The replacement shards the entry queue:
//!
//! - **Submitters** pick a shard by a thread-local submitter id (one
//!   cheap TLS read; distinct submitter threads spread over shards, so
//!   concurrent producers rarely touch the same cache line). A push is
//!   one `swap` on the lane's tail plus one `Release` store — no lock,
//!   no CAS loop, O(1) regardless of contention.
//! - **Workers** drain in batches, round-robin from a per-worker
//!   starting offset. A worker claims a shard with a single CAS on its
//!   `draining` flag; a claim failure means another worker is already
//!   moving that shard's backlog onto its deque, so the sweep just
//!   tries the next shard — a worker never waits on a drain in
//!   progress.
//! - **Per-shard, per-lane FIFO**: each lane of each shard is a FIFO
//!   queue and a batch submitted by one thread lands in one lane of
//!   one shard, so jobs drain in exactly their submission order (the
//!   property that keeps `submit_many` job-list order — and with it
//!   the stable, index-aligned delivery the coordinator's batched sort
//!   relies on — intact within a shard).
//!
//! # Priority lanes ([`JobClass`])
//!
//! Every shard holds two lanes: **service** (latency-sensitive jobs —
//! the default for every legacy entry point) and **background**
//! (maintenance, rebuilds, anything that should yield to user-facing
//! traffic). A drain sweep takes from the service lanes *strictly
//! first*: background jobs run only when no shard has claimable
//! service work. Two mechanisms keep that strictness safe and cheap:
//!
//! - **Anti-starvation escape hatch**: a fleet-wide counter of
//!   consecutive service-class drains *performed while background
//!   work was waiting* (a service drain with an empty background lane
//!   resets it, so an all-service phase cannot bank a stale streak).
//!   Once it reaches the starvation limit
//!   (`EXEC_BG_STARVATION_LIMIT`, default
//!   [`DEFAULT_BG_STARVATION_LIMIT`]), exactly one background batch
//!   is *promoted* ahead of the service lanes and the counter
//!   resets — a saturating service stream can delay background work,
//!   never park it forever. The counter is `Relaxed` and
//!   fleet-shared: it is a fairness heuristic, not an exact schedule.
//! - **Time-based promotion bound** (`EXEC_BG_MAX_DELAY_MS`, off by
//!   default): with a bound set, a background batch is also promoted
//!   once the oldest waiting background job has queued past the bound
//!   — an actual queueing-delay guarantee, not just a drain-count
//!   fairness heuristic; the counted limit stays as the fallback
//!   trigger. The clock is a fleet-wide "oldest waiting arrival"
//!   timestamp: armed by the first background push into an idle lane
//!   set (*after* the job is visible, so a racing drain's reset can
//!   never erase the arm of a job that is actually waiting — the
//!   residual stale-arm race only promotes early, which is safe),
//!   re-armed (to *now*, an undercount — deliberately conservative)
//!   by a background drain that leaves backlog behind, cleared when
//!   the background lanes go empty. Like the streak it is `Relaxed`
//!   and approximate; promotion latency, not exact ordering, is what
//!   it bounds.
//! - **Shallow-backlog merging**: when the first claimed shard yields
//!   fewer than a quarter of the batch budget, the sweep keeps going
//!   and merges the *same lane's* backlog from further shards into one
//!   batch — at low load a worker wakes once for the fleet's dribble
//!   of jobs instead of once per shard. Deep backlogs keep the old
//!   one-shard-per-sweep behavior (locality, claim fairness), and the
//!   concatenation preserves per-shard FIFO order within the batch.
//!
//! # Lane structure and memory ordering
//!
//! Each lane is a Vyukov-style intrusive MPSC queue: producers link
//! nodes at the tail with an atomic `swap`, the (single, at a time)
//! consumer unlinks at the head. The "single consumer" is whoever
//! holds the shard's `draining` flag (one flag covers both lanes), so
//! across the whole fleet the queue is multi-producer/multi-consumer
//! while every individual drain session sees the simple MPSC
//! invariants:
//!
//! - **Push**: the node is fully initialized before the `AcqRel`
//!   `swap` publishes it as the new tail; the `Release` store of
//!   `prev.next` is what makes it reachable. A consumer that observes
//!   `next` non-null (`Acquire`) therefore observes the node's
//!   contents. The `swap` linearizes concurrent producers — FIFO
//!   order is swap order.
//! - **Pop** (drain-claim holder only): read `head.next` `Acquire`;
//!   null means empty *or* a producer is between its `swap` and its
//!   `next` store — both are "nothing takeable now". Otherwise move
//!   the job out of the next node, advance `head`, and free the old
//!   head. The old head's `next` was already observed non-null, and a
//!   node's `next` is written exactly once (by the one producer whose
//!   `swap` returned it), so nobody can touch the freed node again.
//! - **Claim**: `draining` CAS `Acquire` on claim / `Release` store on
//!   release orders consumer sessions, so `head` itself needs no
//!   ordering beyond the flag's.
//! - **`len`**: a published length per lane, incremented after a push
//!   completes and decremented per pop. It is the *lock-free idleness
//!   signal*: `Shared::is_idle` sums these instead of taking any lock.
//!   It can transiently undercount a push in flight; the executor's
//!   park protocol tolerates that because a submitter always notifies
//!   *after* its push (and its `len` increment) completes.
//!
//! The momentary `len > 0` / `pop == None` inconsistency window (a
//! producer preempted between `swap` and the `next` store) only makes
//! a draining worker fall through to stealing and re-sweep; it cannot
//! park (idleness keys off `len`) and it cannot lose the job.

use std::cell::{Cell, UnsafeCell};
use std::ptr;
use crate::model::sync::{fence, AtomicBool, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// The job type stored in the injector (same shape as `exec::Job`).
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// Priority class of a submitted job. Every legacy entry point
/// defaults to [`JobClass::Service`]; background work must opt in.
/// The enum is deliberately small but extensible — adding a lane means
/// adding a variant, bumping [`JobClass::LANES`], and giving it a slot
/// in the drain preference order.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum JobClass {
    /// Latency-sensitive traffic: user-facing service jobs and every
    /// job submitted through a class-less API.
    #[default]
    Service,
    /// Yielding traffic: maintenance, rebuilds, prefetch — drained
    /// only when no service work is claimable (plus the counted
    /// anti-starvation promotion).
    Background,
}

impl JobClass {
    /// Number of lanes (enum variants).
    pub const LANES: usize = 2;

    /// This class' lane index within a shard.
    #[inline]
    pub(crate) fn lane(self) -> usize {
        match self {
            JobClass::Service => 0,
            JobClass::Background => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            JobClass::Service => "service",
            JobClass::Background => "background",
        }
    }
}

/// Consecutive service-class drains tolerated while background work
/// waits before one background batch is promoted (overridable via
/// `EXEC_BG_STARVATION_LIMIT`).
pub const DEFAULT_BG_STARVATION_LIMIT: usize = 8;

/// One drained batch: jobs from one lane (concatenated per-shard FIFO
/// runs), the lane they came from, and whether an anti-starvation
/// promotion (counted-limit or time-bound trigger) put a background
/// batch ahead of queued service work.
pub struct Drained {
    pub jobs: Vec<Job>,
    pub class: JobClass,
    pub promoted: bool,
    /// Queueing delay of the batch head (oldest job drained), in
    /// nanoseconds on the injector's monotone clock — the per-lane
    /// wait-time sample the observability layer records. 0 when the
    /// head's enqueue stamp predates the injector (never in practice).
    pub head_wait_nanos: u64,
}

/// Process-wide submitter-id allocator; each submitting thread gets a
/// stable small integer on first use, which picks its shard.
static SUBMITTER_SEQ: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static SUBMITTER_ID: Cell<usize> = Cell::new(usize::MAX);
}

/// Stable per-thread submitter id (assigned on first submission).
fn submitter_id() -> usize {
    SUBMITTER_ID.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            return v;
        }
        let v = SUBMITTER_SEQ.fetch_add(1, Ordering::Relaxed);
        c.set(v);
        v
    })
}

/// One queue node. `next` is written once by the producer that pushed
/// the *following* node; `job` is moved out once by the consumer that
/// pops it (the node then lives on as the queue's stub).
struct Node {
    next: AtomicPtr<Node>,
    job: UnsafeCell<Option<Job>>,
    /// Enqueue time (injector clock, nanos), written before the node
    /// is published through the `tail` swap / `next` Release store and
    /// read only by the exclusive drain-claim holder — a plain field
    /// riding the existing publication ordering.
    enq_ns: u64,
}

impl Node {
    fn alloc(job: Option<Job>, enq_ns: u64) -> *mut Node {
        Box::into_raw(Box::new(Node {
            next: AtomicPtr::new(ptr::null_mut()),
            job: UnsafeCell::new(job),
            enq_ns,
        }))
    }
}

/// One lane of one shard: an intrusive FIFO queue (see module docs)
/// plus its published length. Padded so the two lanes' producers never
/// write the same cache line.
#[repr(align(128))]
struct Lane {
    /// Producers `swap` here; the returned previous tail is the node
    /// whose `next` the producer links.
    tail: AtomicPtr<Node>,
    /// Consumer end; the current node is the stub (job already taken).
    head: AtomicPtr<Node>,
    /// Published length — the lock-free idleness/backlog signal.
    len: AtomicUsize,
}

// SAFETY: the raw node pointers follow the single-writer protocols in
// the module docs — `next` has one writer, `job` is moved out by the
// exclusive drain-claim holder, nodes are freed only after their
// `next` link was observed (no later access can exist).
unsafe impl Send for Lane {}
unsafe impl Sync for Lane {}

impl Lane {
    fn new() -> Lane {
        let stub = Node::alloc(None, 0);
        Lane {
            tail: AtomicPtr::new(stub),
            head: AtomicPtr::new(stub),
            len: AtomicUsize::new(0),
        }
    }

    /// Lock-free FIFO push from any thread. `enq_ns` is the enqueue
    /// stamp (injector clock) the drain side reads back as the job's
    /// queueing delay.
    fn push(&self, job: Job, enq_ns: u64) {
        let node = Node::alloc(Some(job), enq_ns);
        // AcqRel: Release publishes our node's initialization to the
        // producer that will link behind it; Acquire makes the previous
        // producer's node allocation visible before we store into it.
        let prev = self.tail.swap(node, Ordering::AcqRel);
        // SAFETY: `prev` is a live node — nodes are freed only after
        // their `next` is observed non-null by the consumer, and only
        // this producer ever writes this `next`.
        unsafe { (*prev).next.store(node, Ordering::Release) };
        self.len.fetch_add(1, Ordering::Release);
    }

    /// Pop the oldest job and its enqueue stamp.
    ///
    /// # Safety
    /// Caller must hold the owning shard's `draining` claim (exclusive
    /// consumer); the `Injector::drain` sweep is the only caller.
    unsafe fn pop(&self) -> Option<(Job, u64)> {
        let head = self.head.load(Ordering::Relaxed);
        // SAFETY: the claim holder is the only thread that frees
        // nodes, so the current head is a live allocation.
        let next = unsafe { (*head).next.load(Ordering::Acquire) };
        if next.is_null() {
            // Empty, or a producer is mid-push: nothing takeable now.
            return None;
        }
        // SAFETY: the Acquire above makes `next`'s contents visible;
        // the node becomes the new stub once its job is moved out.
        // Only the claim holder touches `job`, so the &mut through the
        // UnsafeCell cannot alias another access.
        let job = unsafe { (*(*next).job.get()).take() };
        debug_assert!(job.is_some(), "non-stub node without a job");
        // SAFETY: same Acquire as above — `enq_ns` is plain data
        // written before the node was published, read by the exclusive
        // claim holder.
        let enq_ns = unsafe { (*next).enq_ns };
        self.head.store(next, Ordering::Relaxed);
        // SAFETY: the old stub's `next` was observed non-null: its one
        // writer is done and no other thread holds it — safe to free.
        drop(unsafe { Box::from_raw(head) });
        self.len.fetch_sub(1, Ordering::Release);
        job.map(|j| (j, enq_ns))
    }
}

impl Drop for Lane {
    fn drop(&mut self) {
        // `&mut self`: workers are joined and no external submitter
        // can hold a reference (dropping the Executor requires
        // ownership). Walk the chain, dropping unconsumed jobs.
        let mut p = *self.head.get_mut();
        while !p.is_null() {
            // SAFETY: exclusive access; every node in the chain is a
            // live allocation from `Node::alloc`.
            let next = unsafe { (*p).next.load(Ordering::Relaxed) };
            drop(unsafe { Box::from_raw(p) });
            p = next;
        }
    }
}

/// One injector shard: one FIFO lane per [`JobClass`] plus the drain
/// claim shared by both lanes. Padded so neighbouring shards'
/// producers never write the same cache line.
#[repr(align(128))]
struct Shard {
    lanes: [Lane; JobClass::LANES],
    /// Drain claim: exactly one worker at a time pops this shard
    /// (either lane).
    draining: AtomicBool,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            lanes: [Lane::new(), Lane::new()],
            draining: AtomicBool::new(false),
        }
    }
}

/// Sentinel for "no background job waiting" in the delay clock.
const BG_CLOCK_IDLE: u64 = u64::MAX;

/// Sentinel for "time-based promotion disabled" in `bg_max_delay_ns`
/// (a zero bound is valid: promote any waiting background batch).
const BG_DELAY_DISABLED: u64 = u64::MAX;

/// The sharded two-lane external-entry queue. See the module docs.
pub struct Injector {
    shards: Box<[Shard]>,
    /// `shards.len() - 1`; shard count is a power of two.
    mask: usize,
    /// Fleet-wide consecutive service-drain counter (the
    /// anti-starvation clock). Relaxed heuristic — see module docs.
    service_streak: AtomicUsize,
    /// Promotion threshold for `service_streak`.
    starvation_limit: usize,
    /// Maximum background queueing delay before promotion, in
    /// nanoseconds; [`BG_DELAY_DISABLED`] turns the time-based
    /// trigger off.
    bg_max_delay_ns: u64,
    /// Monotone origin for the delay clock.
    t0: Instant,
    /// Nanoseconds (since `t0`) when the oldest currently-waiting
    /// background job was observed enqueued; [`BG_CLOCK_IDLE`] when
    /// the background lanes are believed empty. Relaxed heuristic —
    /// see module docs.
    bg_oldest_ns: AtomicU64,
}

impl Injector {
    /// Build an injector with at least `shards` shards (rounded up to
    /// a power of two); the starvation limit comes from
    /// `EXEC_BG_STARVATION_LIMIT` (default
    /// [`DEFAULT_BG_STARVATION_LIMIT`]) and the time bound from
    /// `EXEC_BG_MAX_DELAY_MS` (default: disabled).
    pub fn new(shards: usize) -> Injector {
        let limit = super::tunables::env_usize("EXEC_BG_STARVATION_LIMIT")
            .unwrap_or(DEFAULT_BG_STARVATION_LIMIT)
            .max(1);
        let delay = super::tunables::env_usize("EXEC_BG_MAX_DELAY_MS")
            .filter(|&ms| ms > 0)
            .map(|ms| Duration::from_millis(ms as u64));
        Injector::with_promotion_bounds(shards, limit, delay)
    }

    /// [`Injector::new`] with an explicit starvation limit and the
    /// time bound disabled (tests pin the counted promotion point
    /// deterministically).
    pub fn with_starvation_limit(shards: usize, limit: usize) -> Injector {
        Injector::with_promotion_bounds(shards, limit, None)
    }

    /// [`Injector::new`] with both promotion triggers explicit: the
    /// counted fallback `limit` and the optional max background
    /// queueing delay.
    pub fn with_promotion_bounds(
        shards: usize,
        limit: usize,
        max_delay: Option<Duration>,
    ) -> Injector {
        let n = shards.max(1).next_power_of_two();
        Injector {
            shards: (0..n).map(|_| Shard::new()).collect(),
            mask: n - 1,
            service_streak: AtomicUsize::new(0),
            starvation_limit: limit.max(1),
            bg_max_delay_ns: max_delay
                .map_or(BG_DELAY_DISABLED, |d| {
                    d.as_nanos().min((BG_DELAY_DISABLED - 1) as u128) as u64
                }),
            t0: Instant::now(),
            bg_oldest_ns: AtomicU64::new(BG_CLOCK_IDLE),
        }
    }

    /// Nanoseconds on the injector's monotone delay clock.
    fn now_ns(&self) -> u64 {
        self.t0.elapsed().as_nanos() as u64
    }

    /// Arm the delay clock for a background arrival (first waiter
    /// only — the clock tracks the OLDEST waiting job). No-op with the
    /// time bound disabled.
    fn note_bg_arrival(&self) {
        if self.bg_max_delay_ns == BG_DELAY_DISABLED {
            return;
        }
        // SeqCst fence, paired with the one in `reset_bg_clock`: the
        // caller stored our job's `len` increment before this fence,
        // and the resetter stores IDLE before ITS fence. Whichever
        // fence comes first in the SC order, the other side's
        // subsequent read sees the store — so either our CAS below
        // observes the resetter's IDLE (and arms), or the resetter's
        // re-check observes our `len` (and re-arms for us). Without
        // the fences both reads may be stale (the classic store-buffer
        // outcome) and a waiting job is left unarmed, silently voiding
        // its delay bound — `exec::model_tests::model_injector_bg_arm_vs_reset`
        // catches exactly that if either fence is dropped.
        fence(Ordering::SeqCst);
        let now = self.now_ns();
        let _ = self.bg_oldest_ns.compare_exchange(
            BG_CLOCK_IDLE,
            now,
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
    }

    /// Whether the oldest waiting background job has queued past the
    /// time bound.
    fn bg_overdue(&self) -> bool {
        if self.bg_max_delay_ns == BG_DELAY_DISABLED {
            return false;
        }
        let armed = self.bg_oldest_ns.load(Ordering::Relaxed);
        if armed == BG_CLOCK_IDLE {
            return false;
        }
        self.now_ns().saturating_sub(armed) >= self.bg_max_delay_ns
    }

    /// Re-arm (or clear) the delay clock after a background drain:
    /// remaining backlog restarts the clock at *now* (conservative —
    /// the true head may be older), an empty lane set clears it.
    fn reset_bg_clock(&self) {
        if self.bg_max_delay_ns == BG_DELAY_DISABLED {
            return;
        }
        if self.lane_len(JobClass::Background) > 0 {
            self.bg_oldest_ns.store(self.now_ns(), Ordering::Relaxed);
            return;
        }
        self.bg_oldest_ns.store(BG_CLOCK_IDLE, Ordering::Relaxed);
        // Close the reset/arm race: a job pushed between the emptiness
        // check above and the IDLE store had its arm CAS fail against
        // the stale pre-reset value and would be left unarmed (bound
        // silently voided). Re-check and re-arm through the same
        // IDLE-only CAS: if the re-check sees the job, it gets an arm
        // from us; if the push happens after this re-check, its own
        // CAS sees the IDLE we just stored and arms itself. Either
        // way a waiting job always holds an arm; the CAS (not a plain
        // store) keeps us from clobbering a fresher pusher's arm.
        //
        // The SeqCst fence (paired with `note_bg_arrival`'s) is what
        // makes "either way" airtight: it orders our IDLE store before
        // the `len` re-check in the SC order, so our re-check and the
        // pusher's arm CAS cannot BOTH read stale values — without it
        // the store-buffer outcome (we miss the pushed job, the pusher
        // misses our IDLE) loses the arm. See the model test
        // `exec::model_tests::model_injector_bg_arm_vs_reset`.
        fence(Ordering::SeqCst);
        if self.lane_len(JobClass::Background) > 0 {
            let _ = self.bg_oldest_ns.compare_exchange(
                BG_CLOCK_IDLE,
                self.now_ns(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn home_shard(&self) -> &Shard {
        &self.shards[submitter_id() & self.mask]
    }

    /// Push one job from any thread (lock-free) into its class' lane.
    pub fn push(&self, job: Job, class: JobClass) {
        self.home_shard().lanes[class.lane()].push(job, self.now_ns());
        // Arm AFTER the push: if a concurrent drain emptied the lanes
        // and reset the clock between our push and this arm, the job
        // is already visible to its `lane_len` re-arm; arming first
        // would let that reset erase the arm for a job still in
        // flight, silently voiding its delay bound. The residual race
        // (a stale arm surviving for an already-drained job) only
        // promotes EARLY, which is safe.
        if class == JobClass::Background {
            self.note_bg_arrival();
        }
    }

    /// Push a whole batch from any thread into ONE lane of ONE shard,
    /// preserving its order — the per-shard FIFO guarantee
    /// `submit_many` relies on.
    pub fn push_batch(&self, jobs: Vec<Job>, class: JobClass) {
        let pushed = !jobs.is_empty();
        let lane = &self.home_shard().lanes[class.lane()];
        // One clock read stamps the whole batch — per-job precision is
        // not worth a vDSO call per element on the bulk path.
        let enq_ns = self.now_ns();
        for job in jobs {
            lane.push(job, enq_ns);
        }
        // Arm after the batch is visible — see `push` for the race
        // direction argument.
        if class == JobClass::Background && pushed {
            self.note_bg_arrival();
        }
    }

    /// Drain up to `max` jobs, sweeping shards round-robin from
    /// `start`. Service lanes are drained strictly before background
    /// lanes, except when the anti-starvation counter — or, with
    /// `EXEC_BG_MAX_DELAY_MS` set, the head-wait time bound —
    /// promotes one background batch (see module docs). `None` means
    /// every lane was empty or being drained by another worker.
    pub fn drain(&self, start: usize, max: usize) -> Option<Drained> {
        let bg_waiting = self.lane_len(JobClass::Background) > 0;
        let promote = bg_waiting
            && (self.service_streak.load(Ordering::Relaxed) >= self.starvation_limit
                || self.bg_overdue());
        let order = if promote {
            [JobClass::Background, JobClass::Service]
        } else {
            [JobClass::Service, JobClass::Background]
        };
        for class in order {
            let (jobs, head_enq_ns) = self.drain_class(start, max, class);
            if jobs.is_empty() {
                continue;
            }
            match class {
                // Relaxed RMWs: the streak is a fairness heuristic, not
                // an exact schedule (concurrent drains may interleave).
                // It only accumulates while background work is actually
                // WAITING — a service drain with an empty background
                // lane resets it, so a background job arriving after a
                // long all-service phase starts a fresh count instead
                // of being promoted ahead of queued service work by a
                // stale streak.
                JobClass::Service => {
                    if bg_waiting {
                        self.service_streak.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.service_streak.store(0, Ordering::Relaxed);
                    }
                }
                JobClass::Background => {
                    self.service_streak.store(0, Ordering::Relaxed);
                    self.reset_bg_clock();
                }
            }
            let promoted = promote && class == JobClass::Background;
            let head_wait_nanos =
                head_enq_ns.map_or(0, |enq| self.now_ns().saturating_sub(enq));
            return Some(Drained { jobs, class, promoted, head_wait_nanos });
        }
        None
    }

    /// Sweep one class' lanes. The first claimed shard is drained up
    /// to `max`; if its yield was shallow (under a quarter of the
    /// budget) the sweep keeps merging further shards' backlogs of the
    /// SAME lane into the batch — one wake-up serves the fleet's
    /// dribble at low load. Per-shard FIFO runs concatenate in sweep
    /// order, so order within each shard is preserved.
    fn drain_class(
        &self,
        start: usize,
        max: usize,
        class: JobClass,
    ) -> (Vec<Job>, Option<u64>) {
        let n = self.shards.len();
        let shallow = (max / 4).max(1);
        let mut out = Vec::new();
        // Oldest enqueue stamp across the batch — the head-of-batch
        // wait sample. Stamps from different shards are on the same
        // injector clock, so min() is meaningful.
        let mut head_enq_ns: Option<u64> = None;
        for k in 0..n {
            if out.len() >= shallow {
                break;
            }
            let shard = &self.shards[(start + k) & self.mask];
            if shard.lanes[class.lane()].len.load(Ordering::Acquire) == 0 {
                continue;
            }
            if shard
                .draining
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
            {
                // Another worker is already distributing this backlog.
                continue;
            }
            while out.len() < max {
                // SAFETY: we hold the drain claim.
                match unsafe { shard.lanes[class.lane()].pop() } {
                    Some((job, enq_ns)) => {
                        out.push(job);
                        head_enq_ns =
                            Some(head_enq_ns.map_or(enq_ns, |h: u64| h.min(enq_ns)));
                    }
                    None => break,
                }
            }
            shard.draining.store(false, Ordering::Release);
        }
        (out, head_enq_ns)
    }

    /// Published backlog of one class across all shards — lock-free;
    /// may transiently undercount a push in flight (see module docs).
    pub fn lane_len(&self, class: JobClass) -> usize {
        self.shards
            .iter()
            .map(|s| s.lanes[class.lane()].len.load(Ordering::Acquire))
            .sum()
    }

    /// Published backlog across all shards and lanes.
    pub fn len(&self) -> usize {
        self.lane_len(JobClass::Service) + self.lane_len(JobClass::Background)
    }

    /// Lock-free idleness check against the published lengths.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| {
            s.lanes.iter().all(|l| l.len.load(Ordering::Acquire) == 0)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sync::AtomicUsize;
    use std::sync::{Arc, Mutex};

    fn log_job(log: &Arc<Mutex<Vec<usize>>>, i: usize) -> Job {
        let log = Arc::clone(log);
        Box::new(move || log.lock().unwrap().push(i))
    }

    #[test]
    fn single_submitter_drains_in_fifo_order() {
        // One shard so the single submitting thread and the drain see
        // the same queue regardless of this thread's submitter id.
        let inj = Injector::new(1);
        let log = Arc::new(Mutex::new(Vec::new()));
        let n = if cfg!(miri) { 40 } else { 400 };
        for i in 0..n {
            inj.push(log_job(&log, i), JobClass::Service);
        }
        assert_eq!(inj.len(), n);
        assert_eq!(inj.lane_len(JobClass::Service), n);
        assert_eq!(inj.lane_len(JobClass::Background), 0);
        // Drain in bounded batches, running jobs in drained order.
        let mut drained = 0;
        while drained < n {
            let batch = inj.drain(drained, 32).expect("backlog yields a batch");
            assert_eq!(batch.class, JobClass::Service);
            assert!(!batch.promoted);
            assert!(batch.jobs.len() <= 32, "drain ignored the batch cap");
            drained += batch.jobs.len();
            for job in batch.jobs {
                job();
            }
        }
        assert!(inj.is_empty());
        assert_eq!(*log.lock().unwrap(), (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn batch_push_keeps_submission_order_in_one_shard() {
        let inj = Injector::new(8);
        let log = Arc::new(Mutex::new(Vec::new()));
        let n = if cfg!(miri) { 30 } else { 300 };
        let jobs: Vec<Job> = (0..n).map(|i| log_job(&log, i)).collect();
        inj.push_batch(jobs, JobClass::Service);
        // The batch went to ONE shard; a sweep from any start must
        // return it in submission order.
        let mut drained = 0;
        while drained < n {
            let batch = inj.drain(3, n).expect("backlog yields a batch");
            drained += batch.jobs.len();
            for job in batch.jobs {
                job();
            }
        }
        assert_eq!(*log.lock().unwrap(), (0..n).collect::<Vec<_>>());
    }

    /// Tentpole: the service lane is drained strictly before queued
    /// background work, even when background was submitted FIRST.
    #[test]
    fn service_lane_drains_before_queued_background() {
        // Promotion disabled (huge limit) so strict priority is pure
        // regardless of any EXEC_BG_STARVATION_LIMIT in the env.
        let inj = Injector::with_starvation_limit(1, usize::MAX);
        let log = Arc::new(Mutex::new(Vec::new()));
        let nb = if cfg!(miri) { 10 } else { 100 };
        let ns = if cfg!(miri) { 6 } else { 60 };
        for i in 0..nb {
            inj.push(log_job(&log, 1_000 + i), JobClass::Background);
        }
        for i in 0..ns {
            inj.push(log_job(&log, i), JobClass::Service);
        }
        let mut service_done = 0;
        let mut background_done = 0;
        while let Some(batch) = inj.drain(0, 16) {
            match batch.class {
                JobClass::Service => {
                    // No background job may have run before the
                    // service lane went dry.
                    assert_eq!(background_done, 0, "background overtook service");
                    service_done += batch.jobs.len();
                }
                JobClass::Background => {
                    assert_eq!(service_done, ns, "background before service drained");
                    background_done += batch.jobs.len();
                }
            }
            for job in batch.jobs {
                job();
            }
        }
        assert_eq!((service_done, background_done), (ns, nb));
        // Per-lane FIFO: both classes kept their own submission order.
        let log = log.lock().unwrap();
        let service: Vec<usize> = log.iter().copied().filter(|&i| i < 1_000).collect();
        let background: Vec<usize> = log.iter().copied().filter(|&i| i >= 1_000).collect();
        assert_eq!(service, (0..ns).collect::<Vec<_>>());
        assert_eq!(background, (0..nb).map(|i| 1_000 + i).collect::<Vec<_>>());
    }

    /// Satellite: after `limit` consecutive service drains with
    /// background queued, exactly one background batch is promoted
    /// (flagged), then service resumes.
    #[test]
    fn anti_starvation_promotes_one_background_batch() {
        let limit = 3;
        let inj = Injector::with_starvation_limit(1, limit);
        let ran = Arc::new(AtomicUsize::new(0));
        let noop = || {
            let ran = Arc::clone(&ran);
            Box::new(move || {
                ran.fetch_add(1, Ordering::Relaxed);
            }) as Job
        };
        // Plenty of both classes; drain one job at a time so each
        // drain is one "consecutive service drain" tick.
        for _ in 0..limit + 4 {
            inj.push(noop(), JobClass::Service);
        }
        for _ in 0..2 {
            inj.push(noop(), JobClass::Background);
        }
        for i in 0..limit {
            let batch = inj.drain(0, 1).unwrap();
            assert_eq!(batch.class, JobClass::Service, "drain {i} before the limit");
            assert!(!batch.promoted);
            for j in batch.jobs {
                j();
            }
        }
        // The limit is reached: the next drain promotes background.
        let promoted = inj.drain(0, 1).unwrap();
        assert_eq!(promoted.class, JobClass::Background);
        assert!(promoted.promoted, "promotion must be flagged");
        for j in promoted.jobs {
            j();
        }
        // The streak reset: service runs again immediately after.
        let next = inj.drain(0, 1).unwrap();
        assert_eq!(next.class, JobClass::Service);
        assert!(!next.promoted);
        for j in next.jobs {
            j();
        }
        // Drain everything; totals must balance.
        while let Some(batch) = inj.drain(0, 64) {
            for j in batch.jobs {
                j();
            }
        }
        assert_eq!(ran.load(Ordering::Relaxed), limit + 4 + 2);
        assert!(inj.is_empty());
    }

    /// Regression: a long all-service phase must NOT bank a stale
    /// streak — a background job arriving afterwards waits a full
    /// fresh `limit` of service drains before promotion, instead of
    /// jumping a deep service queue immediately.
    #[test]
    fn stale_service_streak_does_not_promote_fresh_background() {
        let limit = 2;
        let inj = Injector::with_starvation_limit(1, limit);
        // Phase 1: many service drains with NO background queued —
        // each one must reset (not grow) the streak.
        for _ in 0..limit * 3 {
            inj.push(Box::new(|| {}), JobClass::Service);
        }
        for _ in 0..limit * 3 {
            for j in inj.drain(0, 1).expect("service queued").jobs {
                j();
            }
        }
        // Phase 2: background arrives behind a service backlog.
        for _ in 0..limit + 1 {
            inj.push(Box::new(|| {}), JobClass::Service);
        }
        inj.push(Box::new(|| {}), JobClass::Background);
        // A fresh count: the next `limit` drains are still service...
        for i in 0..limit {
            let b = inj.drain(0, 1).unwrap();
            assert_eq!(b.class, JobClass::Service, "stale streak promoted bg at drain {i}");
            for j in b.jobs {
                j();
            }
        }
        // ...and only then the promotion fires.
        let b = inj.drain(0, 1).unwrap();
        assert_eq!(b.class, JobClass::Background);
        assert!(b.promoted);
        for j in b.jobs {
            j();
        }
        while let Some(b) = inj.drain(0, 8) {
            for j in b.jobs {
                j();
            }
        }
        assert!(inj.is_empty());
    }

    /// Satellite: the TIME trigger. With a zero max-delay bound any
    /// waiting background job is overdue, so the very next drain
    /// promotes it — no service streak required (the counted limit
    /// here is effectively infinite). Once the background lane
    /// empties, the clock clears and service drains cleanly again.
    #[test]
    fn time_bound_promotes_waiting_background_without_streak() {
        let inj =
            Injector::with_promotion_bounds(1, usize::MAX, Some(Duration::ZERO));
        let ran = Arc::new(AtomicUsize::new(0));
        let noop = || {
            let ran = Arc::clone(&ran);
            Box::new(move || {
                ran.fetch_add(1, Ordering::Relaxed);
            }) as Job
        };
        for _ in 0..4 {
            inj.push(noop(), JobClass::Service);
        }
        inj.push(noop(), JobClass::Background);
        // Drain 1: the background job is already overdue -> promoted.
        let batch = inj.drain(0, 1).unwrap();
        assert_eq!(batch.class, JobClass::Background);
        assert!(batch.promoted, "time-bound promotion must be flagged");
        for j in batch.jobs {
            j();
        }
        // The lane is empty again: service drains with no promotion.
        for i in 0..4 {
            let b = inj.drain(0, 1).unwrap();
            assert_eq!(b.class, JobClass::Service, "drain {i} after the lane emptied");
            assert!(!b.promoted);
            for j in b.jobs {
                j();
            }
        }
        assert_eq!(ran.load(Ordering::Relaxed), 5);
        assert!(inj.is_empty());
    }

    /// The time trigger respects a non-zero bound: not overdue right
    /// after the push, overdue once the bound has really elapsed.
    /// (Wall-clock sleep — skipped under Miri.)
    #[test]
    #[cfg(not(miri))]
    fn time_bound_waits_for_the_bound_to_elapse() {
        // A generous bound: the pre-sleep drain would only see an
        // overdue job if this thread stalled 500ms between two
        // adjacent statements.
        let inj = Injector::with_promotion_bounds(
            1,
            usize::MAX,
            Some(Duration::from_millis(500)),
        );
        inj.push(Box::new(|| {}), JobClass::Background);
        inj.push(Box::new(|| {}), JobClass::Service);
        inj.push(Box::new(|| {}), JobClass::Service);
        // Immediately: within the bound -> strict priority holds.
        let b = inj.drain(0, 1).unwrap();
        assert_eq!(b.class, JobClass::Service);
        assert!(!b.promoted);
        for j in b.jobs {
            j();
        }
        std::thread::sleep(Duration::from_millis(600));
        // Past the bound: the background head is promoted.
        let b = inj.drain(0, 1).unwrap();
        assert_eq!(b.class, JobClass::Background);
        assert!(b.promoted);
        for j in b.jobs {
            j();
        }
        while let Some(b) = inj.drain(0, 8) {
            for j in b.jobs {
                j();
            }
        }
        assert!(inj.is_empty());
    }

    /// The counted limit stays live as the fallback when the time
    /// bound is set but far away: promotion still fires after `limit`
    /// consecutive service drains.
    #[test]
    fn counted_limit_remains_fallback_with_time_bound_set() {
        let limit = 2;
        let inj = Injector::with_promotion_bounds(
            1,
            limit,
            Some(Duration::from_secs(3600)),
        );
        for _ in 0..limit + 2 {
            inj.push(Box::new(|| {}), JobClass::Service);
        }
        inj.push(Box::new(|| {}), JobClass::Background);
        for i in 0..limit {
            let b = inj.drain(0, 1).unwrap();
            assert_eq!(b.class, JobClass::Service, "drain {i} under the limit");
            for j in b.jobs {
                j();
            }
        }
        let b = inj.drain(0, 1).unwrap();
        assert_eq!(b.class, JobClass::Background, "counted fallback fired");
        assert!(b.promoted);
        for j in b.jobs {
            j();
        }
        while let Some(b) = inj.drain(0, 8) {
            for j in b.jobs {
                j();
            }
        }
        assert!(inj.is_empty());
    }

    /// Satellite: shallow per-shard backlogs merge into ONE drained
    /// batch across shards (fewer wake-ups at low load), preserving
    /// each shard's FIFO order within the concatenation.
    #[test]
    fn shallow_backlogs_merge_across_shards() {
        let inj = Arc::new(Injector::new(4));
        let per_thread = 2usize;
        let threads = 4usize;
        let log = Arc::new(Mutex::new(Vec::new()));
        // Distinct submitter threads land in (up to) distinct shards;
        // each pushes a tiny FIFO run.
        std::thread::scope(|s| {
            for t in 0..threads {
                let inj = Arc::clone(&inj);
                let log = Arc::clone(&log);
                s.spawn(move || {
                    for j in 0..per_thread {
                        let log = Arc::clone(&log);
                        inj.push(
                            Box::new(move || log.lock().unwrap().push(t * 10 + j)),
                            JobClass::Service,
                        );
                    }
                });
            }
        });
        let total = threads * per_thread;
        assert_eq!(inj.len(), total);
        // total (8) <= shallow threshold budget: ONE sweep must merge
        // every shard's dribble into a single batch.
        let batch = inj.drain(0, 32).expect("sweep finds the backlog");
        assert_eq!(batch.jobs.len(), total, "shallow backlogs not merged");
        assert!(inj.is_empty());
        for job in batch.jobs {
            job();
        }
        // Per-shard FIFO survived the merge: within each submitter's
        // run, order is preserved.
        let log = log.lock().unwrap();
        for t in 0..threads {
            let run: Vec<usize> =
                log.iter().copied().filter(|&v| v / 10 == t).collect();
            assert_eq!(run, (0..per_thread).map(|j| t * 10 + j).collect::<Vec<_>>());
        }
    }

    /// A deep first shard still returns alone (the old one-shard-per-
    /// sweep locality), capped at the batch budget.
    #[test]
    fn deep_backlog_keeps_batch_cap() {
        let inj = Injector::new(1);
        let n = if cfg!(miri) { 40 } else { 200 };
        for _ in 0..n {
            inj.push(Box::new(|| {}), JobClass::Service);
        }
        let batch = inj.drain(0, 32).unwrap();
        assert_eq!(batch.jobs.len(), 32, "deep backlog must cap at max");
        assert_eq!(inj.len(), n - 32);
        for j in batch.jobs {
            j();
        }
        while let Some(b) = inj.drain(0, 64) {
            for j in b.jobs {
                j();
            }
        }
        assert!(inj.is_empty());
    }

    /// Satellite stress: N submitter threads × M batches (mixed
    /// classes) race the drains; every job must execute exactly once.
    #[test]
    fn concurrent_submitters_and_drains_exactly_once() {
        let submitters = if cfg!(miri) { 2 } else { 8 };
        let batches = if cfg!(miri) { 3 } else { 40 };
        let batch_len = if cfg!(miri) { 8 } else { 32 };
        let total = submitters * batches * batch_len;
        let inj = Arc::new(Injector::new(4));
        let seen: Arc<Vec<AtomicUsize>> =
            Arc::new((0..total).map(|_| AtomicUsize::new(0)).collect());
        let done = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for t in 0..submitters {
                let inj = Arc::clone(&inj);
                let seen = Arc::clone(&seen);
                s.spawn(move || {
                    for b in 0..batches {
                        let jobs: Vec<Job> = (0..batch_len)
                            .map(|j| {
                                let seen = Arc::clone(&seen);
                                let idx = t * batches * batch_len + b * batch_len + j;
                                Box::new(move || {
                                    seen[idx].fetch_add(1, Ordering::Relaxed);
                                }) as Job
                            })
                            .collect();
                        // Alternate lanes so both are stressed.
                        let class = if b % 2 == 0 {
                            JobClass::Service
                        } else {
                            JobClass::Background
                        };
                        inj.push_batch(jobs, class);
                    }
                });
            }
            // Two draining "workers" race the submitters and each
            // other (drain-claim CAS churn included).
            for w in 0..2 {
                let inj = Arc::clone(&inj);
                let done = Arc::clone(&done);
                s.spawn(move || loop {
                    match inj.drain(w, 16) {
                        None => {
                            if done.load(Ordering::Acquire) >= total {
                                break;
                            }
                            std::hint::spin_loop();
                        }
                        Some(batch) => {
                            let got = batch.jobs.len();
                            for job in batch.jobs {
                                job();
                            }
                            done.fetch_add(got, Ordering::AcqRel);
                        }
                    }
                });
            }
        });
        for (i, count) in seen.iter().enumerate() {
            assert_eq!(count.load(Ordering::Relaxed), 1, "job {i} misdelivered");
        }
        assert!(inj.is_empty());
        assert_eq!(inj.len(), 0);
    }

    #[test]
    fn unconsumed_jobs_are_dropped_not_leaked() {
        struct Canary(Arc<AtomicUsize>);
        impl Drop for Canary {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::Relaxed);
            }
        }
        let drops = Arc::new(AtomicUsize::new(0));
        let inj = Injector::new(4);
        // Both lanes hold unconsumed jobs at drop.
        for i in 0..10 {
            let canary = Canary(Arc::clone(&drops));
            let class = if i % 2 == 0 { JobClass::Service } else { JobClass::Background };
            inj.push(
                Box::new(move || {
                    let _keep = &canary;
                }),
                class,
            );
        }
        // Drain (and drop unrun) a couple, leave the rest to Drop.
        let batch = inj.drain(0, 3);
        drop(batch);
        drop(inj);
        assert_eq!(drops.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        assert_eq!(Injector::new(1).shard_count(), 1);
        assert_eq!(Injector::new(3).shard_count(), 4);
        assert_eq!(Injector::new(8).shard_count(), 8);
        assert_eq!(Injector::new(9).shard_count(), 16);
    }
}

//! Lock-free sharded injector — the external entry queue of the
//! executor.
//!
//! Before this module the injector was one `Mutex<VecDeque>`: every
//! submission from a non-worker thread and every worker drain crossed
//! the same lock, so under high external submission rates the entry
//! point serialized exactly the way the paper's single-synchronization
//! merge works to avoid. The replacement shards the entry queue:
//!
//! - **Submitters** pick a shard by a thread-local submitter id (one
//!   cheap TLS read; distinct submitter threads spread over shards, so
//!   concurrent producers rarely touch the same cache line). A push is
//!   one `swap` on the shard's tail plus one `Release` store — no lock,
//!   no CAS loop, O(1) regardless of contention.
//! - **Workers** drain a shard in batches, round-robin from a
//!   per-worker starting offset. A worker claims a shard with a single
//!   CAS on its `draining` flag; a claim failure means another worker
//!   is already moving that shard's backlog onto its deque, so the
//!   sweep just tries the next shard — a worker never waits on a
//!   drain in progress.
//! - **Per-shard FIFO**: each shard is a FIFO queue and a batch
//!   submitted by one thread lands in one shard, so jobs drain in
//!   exactly their submission order (the property that keeps
//!   `submit_many` job-list order — and with it the stable, index-
//!   aligned delivery the coordinator's batched sort relies on —
//!   intact within a shard).
//!
//! # Shard structure and memory ordering
//!
//! Each `Shard` is a Vyukov-style intrusive MPSC queue: producers
//! link nodes at the tail with an atomic `swap`, the (single, at a
//! time) consumer unlinks at the head. The "single consumer" is
//! whoever holds the shard's `draining` flag, so across the whole
//! fleet the queue is multi-producer/multi-consumer while every
//! individual drain session sees the simple MPSC invariants:
//!
//! - **Push**: the node is fully initialized before the `AcqRel`
//!   `swap` publishes it as the new tail; the `Release` store of
//!   `prev.next` is what makes it reachable. A consumer that observes
//!   `next` non-null (`Acquire`) therefore observes the node's
//!   contents. The `swap` linearizes concurrent producers — FIFO
//!   order is swap order.
//! - **Pop** (drain-claim holder only): read `head.next` `Acquire`;
//!   null means empty *or* a producer is between its `swap` and its
//!   `next` store — both are "nothing takeable now". Otherwise move
//!   the job out of the next node, advance `head`, and free the old
//!   head. The old head's `next` was already observed non-null, and a
//!   node's `next` is written exactly once (by the one producer whose
//!   `swap` returned it), so nobody can touch the freed node again.
//! - **Claim**: `draining` CAS `Acquire` on claim / `Release` store on
//!   release orders consumer sessions, so `head` itself needs no
//!   ordering beyond the flag's.
//! - **`len`**: a published length per shard, incremented after a push
//!   completes and decremented per pop. It is the *lock-free idleness
//!   signal*: `Shared::is_idle` sums these instead of taking any lock.
//!   It can transiently undercount a push in flight; the executor's
//!   park protocol tolerates that because a submitter always notifies
//!   *after* its push (and its `len` increment) completes.
//!
//! The momentary `len > 0` / `pop == None` inconsistency window (a
//! producer preempted between `swap` and the `next` store) only makes
//! a draining worker fall through to stealing and re-sweep; it cannot
//! park (idleness keys off `len`) and it cannot lose the job.

use std::cell::{Cell, UnsafeCell};
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicUsize, Ordering};

/// The job type stored in the injector (same shape as `exec::Job`).
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// Process-wide submitter-id allocator; each submitting thread gets a
/// stable small integer on first use, which picks its shard.
static SUBMITTER_SEQ: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static SUBMITTER_ID: Cell<usize> = Cell::new(usize::MAX);
}

/// Stable per-thread submitter id (assigned on first submission).
fn submitter_id() -> usize {
    SUBMITTER_ID.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            return v;
        }
        let v = SUBMITTER_SEQ.fetch_add(1, Ordering::Relaxed);
        c.set(v);
        v
    })
}

/// One queue node. `next` is written once by the producer that pushed
/// the *following* node; `job` is moved out once by the consumer that
/// pops it (the node then lives on as the queue's stub).
struct Node {
    next: AtomicPtr<Node>,
    job: UnsafeCell<Option<Job>>,
}

impl Node {
    fn alloc(job: Option<Job>) -> *mut Node {
        Box::into_raw(Box::new(Node {
            next: AtomicPtr::new(ptr::null_mut()),
            job: UnsafeCell::new(job),
        }))
    }
}

/// One injector shard: an intrusive FIFO queue (see module docs) plus
/// its drain claim and published length. Padded so neighbouring
/// shards' producers never write the same cache line.
#[repr(align(128))]
struct Shard {
    /// Producers `swap` here; the returned previous tail is the node
    /// whose `next` the producer links.
    tail: AtomicPtr<Node>,
    /// Consumer end; the current node is the stub (job already taken).
    head: AtomicPtr<Node>,
    /// Drain claim: exactly one worker at a time pops this shard.
    draining: AtomicBool,
    /// Published length — the lock-free idleness/backlog signal.
    len: AtomicUsize,
}

// SAFETY: the raw node pointers follow the single-writer protocols in
// the module docs — `next` has one writer, `job` is moved out by the
// exclusive drain-claim holder, nodes are freed only after their
// `next` link was observed (no later access can exist).
unsafe impl Send for Shard {}
unsafe impl Sync for Shard {}

impl Shard {
    fn new() -> Shard {
        let stub = Node::alloc(None);
        Shard {
            tail: AtomicPtr::new(stub),
            head: AtomicPtr::new(stub),
            draining: AtomicBool::new(false),
            len: AtomicUsize::new(0),
        }
    }

    /// Lock-free FIFO push from any thread.
    fn push(&self, job: Job) {
        let node = Node::alloc(Some(job));
        // AcqRel: Release publishes our node's initialization to the
        // producer that will link behind it; Acquire makes the previous
        // producer's node allocation visible before we store into it.
        let prev = self.tail.swap(node, Ordering::AcqRel);
        // SAFETY: `prev` is a live node — nodes are freed only after
        // their `next` is observed non-null by the consumer, and only
        // this producer ever writes this `next`.
        unsafe { (*prev).next.store(node, Ordering::Release) };
        self.len.fetch_add(1, Ordering::Release);
    }

    /// Pop the oldest job.
    ///
    /// # Safety
    /// Caller must hold this shard's `draining` claim (exclusive
    /// consumer); the `Injector::drain` sweep is the only caller.
    unsafe fn pop(&self) -> Option<Job> {
        let head = self.head.load(Ordering::Relaxed);
        let next = (*head).next.load(Ordering::Acquire);
        if next.is_null() {
            // Empty, or a producer is mid-push: nothing takeable now.
            return None;
        }
        // The Acquire above makes `next`'s contents visible; the node
        // becomes the new stub once its job is moved out. Only the
        // claim holder touches `job`, so the &mut through the
        // UnsafeCell cannot alias another access.
        let job = (*(*next).job.get()).take();
        debug_assert!(job.is_some(), "non-stub node without a job");
        self.head.store(next, Ordering::Relaxed);
        // The old stub's `next` was observed non-null: its one writer
        // is done and no other thread holds it — safe to free.
        drop(Box::from_raw(head));
        self.len.fetch_sub(1, Ordering::Release);
        job
    }
}

impl Drop for Shard {
    fn drop(&mut self) {
        // `&mut self`: workers are joined and no external submitter
        // can hold a reference (dropping the Executor requires
        // ownership). Walk the chain, dropping unconsumed jobs.
        let mut p = *self.head.get_mut();
        while !p.is_null() {
            // SAFETY: exclusive access; every node in the chain is a
            // live allocation from `Node::alloc`.
            let next = unsafe { (*p).next.load(Ordering::Relaxed) };
            drop(unsafe { Box::from_raw(p) });
            p = next;
        }
    }
}

/// The sharded external-entry queue. See the module docs.
pub struct Injector {
    shards: Box<[Shard]>,
    /// `shards.len() - 1`; shard count is a power of two.
    mask: usize,
}

impl Injector {
    /// Build an injector with at least `shards` shards (rounded up to
    /// a power of two).
    pub fn new(shards: usize) -> Injector {
        let n = shards.max(1).next_power_of_two();
        Injector {
            shards: (0..n).map(|_| Shard::new()).collect(),
            mask: n - 1,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn home_shard(&self) -> &Shard {
        &self.shards[submitter_id() & self.mask]
    }

    /// Push one job from any thread (lock-free).
    pub fn push(&self, job: Job) {
        self.home_shard().push(job);
    }

    /// Push a whole batch from any thread into ONE shard, preserving
    /// its order — the per-shard FIFO guarantee `submit_many` relies
    /// on.
    pub fn push_batch(&self, jobs: Vec<Job>) {
        let shard = self.home_shard();
        for job in jobs {
            shard.push(job);
        }
    }

    /// Drain up to `max` jobs from the first claimable non-empty
    /// shard, sweeping round-robin from `start`. Returns in per-shard
    /// FIFO order; an empty result means every shard was empty or
    /// being drained by another worker.
    pub fn drain(&self, start: usize, max: usize) -> Vec<Job> {
        let n = self.shards.len();
        let mut out = Vec::new();
        for k in 0..n {
            let shard = &self.shards[(start + k) & self.mask];
            if shard.len.load(Ordering::Acquire) == 0 {
                continue;
            }
            if shard
                .draining
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
            {
                // Another worker is already distributing this backlog.
                continue;
            }
            while out.len() < max {
                // SAFETY: we hold the drain claim.
                match unsafe { shard.pop() } {
                    Some(job) => out.push(job),
                    None => break,
                }
            }
            shard.draining.store(false, Ordering::Release);
            if !out.is_empty() {
                break;
            }
        }
        out
    }

    /// Published backlog across all shards — lock-free; may
    /// transiently undercount a push in flight (see module docs).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len.load(Ordering::Acquire)).sum()
    }

    /// Lock-free idleness check against the published lengths.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.len.load(Ordering::Acquire) == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::{Arc, Mutex};

    #[test]
    fn single_submitter_drains_in_fifo_order() {
        // One shard so the single submitting thread and the drain see
        // the same queue regardless of this thread's submitter id.
        let inj = Injector::new(1);
        let log = Arc::new(Mutex::new(Vec::new()));
        let n = if cfg!(miri) { 40 } else { 400 };
        for i in 0..n {
            let log = Arc::clone(&log);
            inj.push(Box::new(move || log.lock().unwrap().push(i)));
        }
        assert_eq!(inj.len(), n);
        // Drain in bounded batches, running jobs in drained order.
        let mut drained = 0;
        while drained < n {
            let batch = inj.drain(drained, 32);
            assert!(!batch.is_empty(), "backlog of {} yielded nothing", n - drained);
            assert!(batch.len() <= 32, "drain ignored the batch cap");
            drained += batch.len();
            for job in batch {
                job();
            }
        }
        assert!(inj.is_empty());
        assert_eq!(*log.lock().unwrap(), (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn batch_push_keeps_submission_order_in_one_shard() {
        let inj = Injector::new(8);
        let log = Arc::new(Mutex::new(Vec::new()));
        let n = if cfg!(miri) { 30 } else { 300 };
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                let log = Arc::clone(&log);
                Box::new(move || log.lock().unwrap().push(i)) as Job
            })
            .collect();
        inj.push_batch(jobs);
        // The batch went to ONE shard; a sweep from any start must
        // return it in submission order.
        let mut drained = 0;
        while drained < n {
            let batch = inj.drain(3, n);
            drained += batch.len();
            for job in batch {
                job();
            }
        }
        assert_eq!(*log.lock().unwrap(), (0..n).collect::<Vec<_>>());
    }

    /// Satellite stress: N submitter threads × M batches race the
    /// drains; every job must execute exactly once.
    #[test]
    fn concurrent_submitters_and_drains_exactly_once() {
        let submitters = if cfg!(miri) { 2 } else { 8 };
        let batches = if cfg!(miri) { 3 } else { 40 };
        let batch_len = if cfg!(miri) { 8 } else { 32 };
        let total = submitters * batches * batch_len;
        let inj = Arc::new(Injector::new(4));
        let seen: Arc<Vec<AtomicUsize>> =
            Arc::new((0..total).map(|_| AtomicUsize::new(0)).collect());
        let done = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for t in 0..submitters {
                let inj = Arc::clone(&inj);
                let seen = Arc::clone(&seen);
                s.spawn(move || {
                    for b in 0..batches {
                        let jobs: Vec<Job> = (0..batch_len)
                            .map(|j| {
                                let seen = Arc::clone(&seen);
                                let idx = t * batches * batch_len + b * batch_len + j;
                                Box::new(move || {
                                    seen[idx].fetch_add(1, Ordering::Relaxed);
                                }) as Job
                            })
                            .collect();
                        inj.push_batch(jobs);
                    }
                });
            }
            // Two draining "workers" race the submitters and each
            // other (drain-claim CAS churn included).
            for w in 0..2 {
                let inj = Arc::clone(&inj);
                let done = Arc::clone(&done);
                s.spawn(move || loop {
                    let batch = inj.drain(w, 16);
                    if batch.is_empty() {
                        if done.load(Ordering::Acquire) >= total {
                            break;
                        }
                        std::hint::spin_loop();
                        continue;
                    }
                    let got = batch.len();
                    for job in batch {
                        job();
                    }
                    done.fetch_add(got, Ordering::AcqRel);
                });
            }
        });
        for (i, count) in seen.iter().enumerate() {
            assert_eq!(count.load(Ordering::Relaxed), 1, "job {i} misdelivered");
        }
        assert!(inj.is_empty());
        assert_eq!(inj.len(), 0);
    }

    #[test]
    fn unconsumed_jobs_are_dropped_not_leaked() {
        struct Canary(Arc<AtomicUsize>);
        impl Drop for Canary {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::Relaxed);
            }
        }
        let drops = Arc::new(AtomicUsize::new(0));
        let inj = Injector::new(4);
        for _ in 0..10 {
            let canary = Canary(Arc::clone(&drops));
            inj.push(Box::new(move || {
                let _keep = &canary;
            }));
        }
        // Drain (and drop unrun) a couple, leave the rest to Drop.
        let batch = inj.drain(0, 3);
        drop(batch);
        drop(inj);
        assert_eq!(drops.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        assert_eq!(Injector::new(1).shard_count(), 1);
        assert_eq!(Injector::new(3).shard_count(), 4);
        assert_eq!(Injector::new(8).shard_count(), 8);
        assert_eq!(Injector::new(9).shard_count(), 16);
    }
}

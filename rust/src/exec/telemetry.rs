//! Per-worker executor counters — the `exec` telemetry surface.
//!
//! Each worker owns one cache-line-padded `Counters` block and is the
//! only thread that ever writes it (`Relaxed` increments, so the hot
//! path pays a single uncontended RMW and no false sharing). Readers
//! take [`crate::exec::Executor::telemetry`] snapshots from any thread:
//! each field is monotone, but a snapshot is not a globally
//! instantaneous cut — it is meant for steering heuristics (the
//! steal-driven fine-chunking mode), benchmarks and monitoring, not
//! for exact accounting.
//!
//! Field semantics (one [`WorkerTelemetry`] per worker):
//!
//! - `executed` — jobs this worker picked up and ran, from any source
//!   (own deque, injector batch, or stolen); counted at pick-up so the
//!   bump is visible to anything the job publishes. Scope tasks
//!   drained by a *waiting* thread are not counted here — the waiter
//!   is not a worker.
//! - `steals` — successful Chase–Lev steals from sibling deques: the
//!   load-rebalancing traffic. Cheap, plentiful steals are what make
//!   fine-grained chunking profitable.
//! - `steal_misses` — steal attempts that lost the `top` CAS race to
//!   the owner or another thief. Empty probes are *not* counted; a
//!   miss always means the victim's deque was contended, so a high
//!   miss:steal ratio is the signal to fall back to the greedy
//!   pre-balanced chunking.
//! - `injector_pops` — batches taken from the sharded injector (the
//!   entry path for jobs submitted from non-worker threads).
//! - `parks` — times the worker went to sleep with nothing to run
//!   anywhere: the idleness signal.
//! - `service_jobs` / `bg_jobs` — JOBS (not batches) this worker
//!   drained from the injector's service / background lane: the
//!   per-class traffic split. Counted at drain, so jobs a sibling
//!   later steals are attributed to the draining worker; jobs pushed
//!   directly onto a worker's own deque (nested spawns, worker-side
//!   service submissions) never cross the injector and are not in
//!   either lane count.
//! - `bg_promotions` — background batches this worker took through
//!   the anti-starvation escape hatches (promoted ahead of queued
//!   service work after `EXEC_BG_STARVATION_LIMIT` consecutive
//!   service drains, or once the head waited past
//!   `EXEC_BG_MAX_DELAY_MS` when that bound is set).
//!
//! # Windowed (rate-based) telemetry
//!
//! Lifetime counters answer "what happened since the process
//! started"; steering heuristics need "what is happening *now*". The
//! `WindowRing` turns the lifetime counters into per-epoch deltas: a
//! fixed-size ring of snapshots, where the epoch is rolled by the
//! first worker to notice the interval elapsed (a single CAS on the
//! epoch start picks the winner; losers carry on). Each roll writes
//! one slot: the fleet-wide counter deltas since the previous roll,
//! plus the epoch's real span. [`WindowRates`] folds the live slots
//! into per-second rates over the window's horizon — the signal
//! [`crate::exec::chunk_groups`] and the `Tunables` recalibration
//! consume, so a phase change (a burst of external submissions, a
//! skew-heavy merge) steers the fleet within one window instead of
//! being averaged away by the whole process history.
//!
//! Slot writes are serialized by the winner flag (a forced roll can
//! never interleave with a periodic one mid-write); a reader folding
//! rates may still see one slot mid-update. Like the lifetime
//! snapshots, window rates steer heuristics — they are not exact
//! accounting.

use crate::model::sync::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// One worker's live counters, padded to (at least) a cache line so
/// neighbouring workers never write the same line.
#[repr(align(128))]
#[derive(Default)]
pub(super) struct Counters {
    pub executed: AtomicU64,
    pub steals: AtomicU64,
    pub steal_misses: AtomicU64,
    pub injector_pops: AtomicU64,
    pub parks: AtomicU64,
    pub service_jobs: AtomicU64,
    pub bg_jobs: AtomicU64,
    pub bg_promotions: AtomicU64,
}

impl Counters {
    pub(super) fn snapshot(&self) -> WorkerTelemetry {
        WorkerTelemetry {
            executed: self.executed.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            steal_misses: self.steal_misses.load(Ordering::Relaxed),
            injector_pops: self.injector_pops.load(Ordering::Relaxed),
            parks: self.parks.load(Ordering::Relaxed),
            service_jobs: self.service_jobs.load(Ordering::Relaxed),
            bg_jobs: self.bg_jobs.load(Ordering::Relaxed),
            bg_promotions: self.bg_promotions.load(Ordering::Relaxed),
        }
    }
}

/// Snapshot of one worker's lifetime counters. See the module docs for
/// field semantics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerTelemetry {
    pub executed: u64,
    pub steals: u64,
    pub steal_misses: u64,
    pub injector_pops: u64,
    pub parks: u64,
    pub service_jobs: u64,
    pub bg_jobs: u64,
    pub bg_promotions: u64,
}

/// Whole-fleet snapshot: one entry per worker, plus summing helpers.
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    pub workers: Vec<WorkerTelemetry>,
}

impl Telemetry {
    pub fn executed(&self) -> u64 {
        self.workers.iter().map(|w| w.executed).sum()
    }

    pub fn steals(&self) -> u64 {
        self.workers.iter().map(|w| w.steals).sum()
    }

    pub fn steal_misses(&self) -> u64 {
        self.workers.iter().map(|w| w.steal_misses).sum()
    }

    pub fn injector_pops(&self) -> u64 {
        self.workers.iter().map(|w| w.injector_pops).sum()
    }

    pub fn parks(&self) -> u64 {
        self.workers.iter().map(|w| w.parks).sum()
    }

    /// Jobs drained from the injector's service lane, fleet-wide.
    pub fn service_jobs(&self) -> u64 {
        self.workers.iter().map(|w| w.service_jobs).sum()
    }

    /// Jobs drained from the injector's background lane, fleet-wide.
    pub fn background_jobs(&self) -> u64 {
        self.workers.iter().map(|w| w.bg_jobs).sum()
    }

    /// Anti-starvation background promotions, fleet-wide.
    pub fn bg_promotions(&self) -> u64 {
        self.workers.iter().map(|w| w.bg_promotions).sum()
    }
}

/// The one service-share fold, shared by [`WindowRates::service_share`]
/// and the tunables lane view: service fraction of the two lanes'
/// traffic, `1.0` when both lanes are quiet (nothing to yield to).
pub(crate) fn service_share_of(service: f64, background: f64) -> f64 {
    let total = service + background;
    if total > 0.0 {
        service / total
    } else {
        1.0
    }
}

/// Number of epochs the window ring holds; the rate horizon is
/// `WINDOW_EPOCHS x` the roll interval.
pub const WINDOW_EPOCHS: usize = 8;

/// Counter fields tracked per epoch, in `Counters` declaration
/// order: executed, steals, steal_misses, injector_pops, parks,
/// service_jobs, bg_jobs, bg_promotions.
const NFIELDS: usize = 8;

/// One epoch's fleet-wide counter deltas, plus per-worker `executed`
/// deltas (so readers can spot one hot deque the fleet average
/// hides). All-atomic so the roll winner can write and readers can
/// fold without locks.
struct EpochSlot {
    fields: [AtomicU64; NFIELDS],
    /// Per-worker `executed` delta for this epoch.
    per_worker: Box<[AtomicU64]>,
    span_nanos: AtomicU64,
}

impl EpochSlot {
    fn new(workers: usize) -> EpochSlot {
        EpochSlot {
            fields: Default::default(),
            per_worker: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            span_nanos: AtomicU64::new(0),
        }
    }
}

/// Fixed-size ring of per-epoch snapshots. See the module docs for
/// the roll protocol.
pub(super) struct WindowRing {
    /// Epoch length in nanoseconds (monotone executor clock).
    interval: u64,
    /// Start of the current epoch; written only under `rolling`.
    epoch_start: AtomicU64,
    /// Winner exclusion: the whole roll (epoch advance + slot write)
    /// happens under this try-flag, so a forced roll can never
    /// interleave with a periodic one mid-slot-write. Losers return
    /// immediately — nobody ever waits on it.
    rolling: AtomicBool,
    /// Fleet totals at the last roll (written by roll winners only).
    last: [AtomicU64; NFIELDS],
    /// Per-worker `executed` totals at the last roll.
    last_worker: Box<[AtomicU64]>,
    slots: Vec<EpochSlot>,
    cursor: AtomicUsize,
    rolls: AtomicU64,
}

impl WindowRing {
    pub(super) fn new(interval_nanos: u64, workers: usize) -> WindowRing {
        let workers = workers.max(1);
        WindowRing {
            interval: interval_nanos.max(1),
            epoch_start: AtomicU64::new(0),
            rolling: AtomicBool::new(false),
            last: Default::default(),
            last_worker: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            slots: (0..WINDOW_EPOCHS).map(|_| EpochSlot::new(workers)).collect(),
            cursor: AtomicUsize::new(0),
            rolls: AtomicU64::new(0),
        }
    }

    /// Roll the epoch if the interval elapsed (or `force`). `now` is
    /// nanoseconds on the executor's monotone clock. Exactly one
    /// caller at a time holds the `rolling` flag through the whole
    /// winner section (epoch advance, `last` swap, slot write), so a
    /// forced roll racing a periodic one cannot interleave writes;
    /// everyone else returns `false` immediately. Returns `true` to
    /// the winner so it can feed the fresh window to recalibration.
    pub(super) fn maybe_roll(&self, now: u64, counters: &[Counters], force: bool) -> bool {
        let start = self.epoch_start.load(Ordering::Relaxed);
        if now <= start || (!force && now - start < self.interval) {
            return false;
        }
        if self
            .rolling
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            return false;
        }
        // Re-check under the flag: the previous holder may have just
        // advanced the epoch past `now`.
        let start = self.epoch_start.load(Ordering::Relaxed);
        if now <= start || (!force && now - start < self.interval) {
            self.rolling.store(false, Ordering::Release);
            return false;
        }
        self.epoch_start.store(now, Ordering::Relaxed);
        // Winner: fold the fleet totals into one per-epoch delta slot.
        let mut totals = [0u64; NFIELDS];
        for c in counters {
            totals[0] += c.executed.load(Ordering::Relaxed);
            totals[1] += c.steals.load(Ordering::Relaxed);
            totals[2] += c.steal_misses.load(Ordering::Relaxed);
            totals[3] += c.injector_pops.load(Ordering::Relaxed);
            totals[4] += c.parks.load(Ordering::Relaxed);
            totals[5] += c.service_jobs.load(Ordering::Relaxed);
            totals[6] += c.bg_jobs.load(Ordering::Relaxed);
            totals[7] += c.bg_promotions.load(Ordering::Relaxed);
        }
        let idx = self.cursor.load(Ordering::Relaxed) % WINDOW_EPOCHS;
        let slot = &self.slots[idx];
        for (i, &total) in totals.iter().enumerate() {
            let prev = self.last[i].swap(total, Ordering::Relaxed);
            slot.fields[i].store(total.saturating_sub(prev), Ordering::Relaxed);
        }
        // Per-worker `executed` deltas (hot-victim signal). The ring
        // is sized to the fleet; a shorter `counters` slice (tests)
        // just leaves the tail at 0.
        for (w, lw) in self.last_worker.iter().enumerate() {
            let total = match counters.get(w) {
                Some(c) => c.executed.load(Ordering::Relaxed),
                None => continue,
            };
            let prev = lw.swap(total, Ordering::Relaxed);
            slot.per_worker[w].store(total.saturating_sub(prev), Ordering::Relaxed);
        }
        slot.span_nanos.store(now - start, Ordering::Relaxed);
        self.cursor.store(idx + 1, Ordering::Relaxed);
        self.rolls.fetch_add(1, Ordering::Relaxed);
        self.rolling.store(false, Ordering::Release);
        true
    }

    /// Fold the live slots into per-second rates.
    pub(super) fn rates(&self) -> WindowRates {
        let mut sums = [0u64; NFIELDS];
        let mut worker_sums = vec![0u64; self.last_worker.len()];
        let mut span = 0u64;
        let mut epochs = 0usize;
        for slot in &self.slots {
            let s = slot.span_nanos.load(Ordering::Relaxed);
            if s == 0 {
                continue; // never written
            }
            span += s;
            epochs += 1;
            for (acc, field) in sums.iter_mut().zip(&slot.fields) {
                *acc += field.load(Ordering::Relaxed);
            }
            for (acc, field) in worker_sums.iter_mut().zip(slot.per_worker.iter()) {
                *acc += field.load(Ordering::Relaxed);
            }
        }
        let secs = span as f64 / 1e9;
        let per_sec = |v: u64| if secs > 0.0 { v as f64 / secs } else { 0.0 };
        WindowRates {
            span_secs: secs,
            epochs,
            executed_per_sec: per_sec(sums[0]),
            steals_per_sec: per_sec(sums[1]),
            steal_misses_per_sec: per_sec(sums[2]),
            injector_per_sec: per_sec(sums[3]),
            parks_per_sec: per_sec(sums[4]),
            service_per_sec: per_sec(sums[5]),
            background_per_sec: per_sec(sums[6]),
            bg_promotions_per_sec: per_sec(sums[7]),
            per_worker_per_sec: worker_sums.into_iter().map(per_sec).collect(),
        }
    }

    pub(super) fn rolls(&self) -> u64 {
        self.rolls.load(Ordering::Relaxed)
    }
}

/// Per-second counter rates over the windowed horizon (the last
/// [`WINDOW_EPOCHS`] epochs actually recorded). `epochs == 0` means
/// the window has never rolled — callers should fall back to the
/// lifetime counters.
#[derive(Clone, Debug, Default)]
pub struct WindowRates {
    /// Real time covered by the recorded epochs, in seconds.
    pub span_secs: f64,
    /// Number of recorded epochs contributing to the rates.
    pub epochs: usize,
    pub executed_per_sec: f64,
    pub steals_per_sec: f64,
    pub steal_misses_per_sec: f64,
    pub injector_per_sec: f64,
    pub parks_per_sec: f64,
    /// Injector service-lane jobs per second (the per-class split —
    /// worker-local deque pushes are not injector traffic and are not
    /// counted here; see the module docs).
    pub service_per_sec: f64,
    /// Injector background-lane jobs per second.
    pub background_per_sec: f64,
    /// Anti-starvation background promotions per second.
    pub bg_promotions_per_sec: f64,
    /// Per-worker `executed` jobs per second over the same window —
    /// the view that exposes one hot victim deque the fleet-average
    /// `executed_per_sec` hides.
    pub per_worker_per_sec: Vec<f64>,
}

impl WindowRates {
    /// `true` when the window holds at least one recorded epoch.
    pub fn has_signal(&self) -> bool {
        self.epochs > 0 && self.span_secs > 0.0
    }

    /// Service share of the windowed injector job traffic, in
    /// `[0, 1]`; `1.0` for an all-service (or idle) window — with no
    /// background traffic there is nothing to yield to.
    pub fn service_share(&self) -> f64 {
        service_share_of(self.service_per_sec, self.background_per_sec)
    }

    /// Windowed miss:steal ratio — the contention signal. Zero when
    /// the fleet neither stole nor missed in the window.
    pub fn miss_ratio(&self) -> f64 {
        if self.steals_per_sec > 0.0 {
            self.steal_misses_per_sec / self.steals_per_sec
        } else if self.steal_misses_per_sec > 0.0 {
            f64::INFINITY
        } else {
            0.0
        }
    }

    /// Per-worker windowed `executed` rates (index = worker id).
    pub fn per_worker(&self) -> &[f64] {
        &self.per_worker_per_sec
    }

    /// The busiest worker in the window: `(worker id, jobs/sec)`.
    /// `None` when the window has no signal or every worker was idle.
    pub fn most_loaded(&self) -> Option<(usize, f64)> {
        let (mut best, mut rate) = (None, 0.0f64);
        for (w, &r) in self.per_worker_per_sec.iter().enumerate() {
            if r > rate {
                best = Some(w);
                rate = r;
            }
        }
        best.map(|w| (w, rate))
    }

    /// How skewed the fleet is: busiest worker's rate over the fleet
    /// mean (`1.0` = perfectly balanced, `0.0` = no signal). The
    /// chunking heuristics treat a high ratio like steal pressure —
    /// one overloaded deque needs finer chunks to shed work.
    pub fn load_skew(&self) -> f64 {
        let n = self.per_worker_per_sec.len();
        if n == 0 {
            return 0.0;
        }
        let mean = self.per_worker_per_sec.iter().sum::<f64>() / n as f64;
        match self.most_loaded() {
            Some((_, hot)) if mean > 0.0 => hot / mean,
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_counter(executed: u64, steals: u64, misses: u64) -> Vec<Counters> {
        let c = Counters::default();
        c.executed.store(executed, Ordering::Relaxed);
        c.steals.store(steals, Ordering::Relaxed);
        c.steal_misses.store(misses, Ordering::Relaxed);
        vec![c]
    }

    #[test]
    fn roll_records_deltas_not_totals() {
        let ring = WindowRing::new(1_000, 1);
        let counters = one_counter(100, 10, 2);
        assert!(ring.maybe_roll(2_000, &counters, false));
        counters[0].executed.store(180, Ordering::Relaxed);
        counters[0].steals.store(14, Ordering::Relaxed);
        assert!(ring.maybe_roll(4_000, &counters, false));
        let rates = ring.rates();
        assert_eq!(rates.epochs, 2);
        assert_eq!(ring.rolls(), 2);
        // 180 executed over 4 microseconds of span.
        let span = 4_000.0 / 1e9;
        assert!((rates.span_secs - span).abs() < 1e-12);
        assert!((rates.executed_per_sec - 180.0 / span).abs() < 1e-3);
        assert!((rates.steals_per_sec - 14.0 / span).abs() < 1e-3);
    }

    #[test]
    fn roll_respects_interval_unless_forced() {
        let ring = WindowRing::new(1_000_000, 1);
        let counters = one_counter(5, 0, 0);
        assert!(!ring.maybe_roll(10, &counters, false), "interval not elapsed");
        assert!(ring.maybe_roll(10, &counters, true), "force ignores interval");
        assert!(!ring.maybe_roll(10, &counters, true), "clock tie cannot roll");
        let rates = ring.rates();
        assert_eq!(rates.epochs, 1);
        assert!(rates.has_signal());
    }

    #[test]
    fn window_evicts_oldest_epochs() {
        let ring = WindowRing::new(1, 1);
        let counters = one_counter(0, 0, 0);
        // 3 x WINDOW_EPOCHS rolls: the ring must only ever report
        // WINDOW_EPOCHS epochs.
        for i in 1..=(3 * WINDOW_EPOCHS as u64) {
            counters[0].executed.store(10 * i, Ordering::Relaxed);
            assert!(ring.maybe_roll(i * 100, &counters, false));
        }
        let rates = ring.rates();
        assert_eq!(rates.epochs, WINDOW_EPOCHS);
        // Only the last 8 epochs' deltas (10 each over 100ns epochs).
        let span = (WINDOW_EPOCHS as f64 * 100.0) / 1e9;
        assert!((rates.span_secs - span).abs() < 1e-12);
        assert!((rates.executed_per_sec - (WINDOW_EPOCHS as f64 * 10.0) / span).abs() < 1.0);
    }

    /// The two-lane counters ride the same ring: rolls record per-lane
    /// deltas, and `service_share` folds them into the [0,1] mix.
    #[test]
    fn roll_records_lane_deltas_and_share() {
        let ring = WindowRing::new(1_000, 1);
        let counters = one_counter(10, 0, 0);
        counters[0].service_jobs.store(30, Ordering::Relaxed);
        counters[0].bg_jobs.store(10, Ordering::Relaxed);
        counters[0].bg_promotions.store(1, Ordering::Relaxed);
        assert!(ring.maybe_roll(2_000, &counters, false));
        let rates = ring.rates();
        let span = 2_000.0 / 1e9;
        assert!((rates.service_per_sec - 30.0 / span).abs() < 1e-3);
        assert!((rates.background_per_sec - 10.0 / span).abs() < 1e-3);
        assert!((rates.bg_promotions_per_sec - 1.0 / span).abs() < 1e-3);
        assert!((rates.service_share() - 0.75).abs() < 1e-12);
        // An idle window has full service share (nothing to yield to).
        assert_eq!(WindowRates::default().service_share(), 1.0);
    }

    #[test]
    fn miss_ratio_handles_zero_steals() {
        let mut r =
            WindowRates { steals_per_sec: 0.0, steal_misses_per_sec: 0.0, ..Default::default() };
        assert_eq!(r.miss_ratio(), 0.0);
        r.steal_misses_per_sec = 5.0;
        assert!(r.miss_ratio().is_infinite());
        r.steals_per_sec = 10.0;
        assert!((r.miss_ratio() - 0.5).abs() < 1e-12);
    }
}


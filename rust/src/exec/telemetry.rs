//! Per-worker executor counters — the `exec` telemetry surface.
//!
//! Each worker owns one cache-line-padded [`Counters`] block and is the
//! only thread that ever writes it (`Relaxed` increments, so the hot
//! path pays a single uncontended RMW and no false sharing). Readers
//! take [`crate::exec::Executor::telemetry`] snapshots from any thread:
//! each field is monotone, but a snapshot is not a globally
//! instantaneous cut — it is meant for steering heuristics (the
//! steal-driven fine-chunking mode), benchmarks and monitoring, not
//! for exact accounting.
//!
//! Field semantics (one [`WorkerTelemetry`] per worker):
//!
//! - `executed` — jobs this worker picked up and ran, from any source
//!   (own deque, injector batch, or stolen); counted at pick-up so the
//!   bump is visible to anything the job publishes. Scope tasks
//!   drained by a *waiting* thread are not counted here — the waiter
//!   is not a worker.
//! - `steals` — successful Chase–Lev steals from sibling deques: the
//!   load-rebalancing traffic. Cheap, plentiful steals are what make
//!   fine-grained chunking profitable.
//! - `steal_misses` — steal attempts that lost the `top` CAS race to
//!   the owner or another thief. Empty probes are *not* counted; a
//!   miss always means the victim's deque was contended, so a high
//!   miss:steal ratio is the signal to fall back to the greedy
//!   pre-balanced chunking.
//! - `injector_pops` — batches taken from the global injector (the
//!   entry path for jobs submitted from non-worker threads).
//! - `parks` — times the worker went to sleep with nothing to run
//!   anywhere: the idleness signal.

use std::sync::atomic::{AtomicU64, Ordering};

/// One worker's live counters, padded to (at least) a cache line so
/// neighbouring workers never write the same line.
#[repr(align(128))]
#[derive(Default)]
pub(super) struct Counters {
    pub executed: AtomicU64,
    pub steals: AtomicU64,
    pub steal_misses: AtomicU64,
    pub injector_pops: AtomicU64,
    pub parks: AtomicU64,
}

impl Counters {
    pub(super) fn snapshot(&self) -> WorkerTelemetry {
        WorkerTelemetry {
            executed: self.executed.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            steal_misses: self.steal_misses.load(Ordering::Relaxed),
            injector_pops: self.injector_pops.load(Ordering::Relaxed),
            parks: self.parks.load(Ordering::Relaxed),
        }
    }
}

/// Snapshot of one worker's lifetime counters. See the module docs for
/// field semantics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerTelemetry {
    pub executed: u64,
    pub steals: u64,
    pub steal_misses: u64,
    pub injector_pops: u64,
    pub parks: u64,
}

/// Whole-fleet snapshot: one entry per worker, plus summing helpers.
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    pub workers: Vec<WorkerTelemetry>,
}

impl Telemetry {
    pub fn executed(&self) -> u64 {
        self.workers.iter().map(|w| w.executed).sum()
    }

    pub fn steals(&self) -> u64 {
        self.workers.iter().map(|w| w.steals).sum()
    }

    pub fn steal_misses(&self) -> u64 {
        self.workers.iter().map(|w| w.steal_misses).sum()
    }

    pub fn injector_pops(&self) -> u64 {
        self.workers.iter().map(|w| w.injector_pops).sum()
    }

    pub fn parks(&self) -> u64 {
        self.workers.iter().map(|w| w.parks).sum()
    }
}
